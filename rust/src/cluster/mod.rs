//! Cluster model: slots, gate bandwidth, heterogeneous power, and the
//! cluster-level unreachability process (paper Sec 3.2/3.3, Table 2).
//!
//! The *ground truth* lives here: true per-cluster power distribution, true
//! per-pair WAN bandwidth distribution, true unreachability probability.
//! Schedulers never see these — they see the performance modeler's estimates
//! built from execution logs (`perfmodel`), exactly as in the paper.

use crate::config::spec::{ScaleClass, SystemSpec};
use crate::topology::{ClusterScale, Topology};
use crate::util::rng::Rng;

/// Ground-truth parameters of one cluster.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub id: usize,
    pub scale: ClusterScale,
    /// Computing slots M_k.
    pub slots: usize,
    /// Mean data-processing power of one slot (data units per time slot).
    pub power_mean: f64,
    /// Std-dev of slot power (mean × RSD).
    pub power_std: f64,
    /// Ingress gate bandwidth Ing_k (data units per time slot).
    pub ingress: f64,
    /// Egress gate bandwidth Eg_k.
    pub egress: f64,
    /// Cluster-level unreachability probability p_m as quoted in Table 2
    /// (per *task epoch* — the expected task execution length).
    pub unreach_p: f64,
}

/// Slots per task epoch: Table 2's unreachability probabilities are quoted
/// per task execution (~this many slots); the per-slot Bernoulli uses
/// `p / FAILURE_EPOCH_SLOTS`. Without this, p=0.5 over a 10-slot task gives
/// survival 2^-10 per attempt and single-copy baselines never finish —
/// failures in the paper are "occasional", not per-slot coin flips.
pub const FAILURE_EPOCH_SLOTS: f64 = 20.0;

impl Cluster {
    /// Draw one task's true processing speed in this cluster, with a
    /// per-operation skew factor (different RDD operations process data at
    /// different rates — the paper models a distribution per operation).
    pub fn draw_power(&self, op_skew: f64, rng: &mut Rng) -> f64 {
        // floor at 2% of the mean: even a badly interfered slot makes some
        // progress (a zero-rate slot would manufacture unbounded stragglers)
        let mean = self.power_mean * op_skew;
        rng.normal_pos(mean, self.power_std * op_skew, 0.02 * mean)
    }
}

/// The whole geo-distributed system: clusters + WAN + failure processes.
#[derive(Clone, Debug)]
pub struct GeoSystem {
    pub clusters: Vec<Cluster>,
    pub topology: Topology,
    /// Per-pair WAN bandwidth mean, row-major n×n (diagonal = intra, fast).
    wan_mean: Vec<f64>,
    /// Per-pair WAN bandwidth std.
    wan_std: Vec<f64>,
    /// Upper bound of slot power across clusters (grid sizing).
    pub max_power: f64,
    /// Upper bound of WAN mean across pairs (grid sizing).
    pub max_wan: f64,
}

impl GeoSystem {
    /// Build from a [`SystemSpec`], drawing Table-2 parameters per cluster.
    pub fn generate(spec: &SystemSpec, rng: &mut Rng) -> GeoSystem {
        let topology = Topology::generate(spec.n_clusters, 2, rng);
        let mut clusters = Vec::with_capacity(spec.n_clusters);
        for id in 0..spec.n_clusters {
            let scale = topology.scales[id];
            let class: &ScaleClass = &spec.classes[scale.class_index()];
            let slots = rng.range_u64(class.vm_count.0, class.vm_count.1) as usize;
            let power_mean = rng.range_f64(class.power_mean.0, class.power_mean.1);
            let rsd = rng.range_f64(class.power_rsd.0, class.power_rsd.1);
            let gate_ratio = rng.range_f64(class.gate_ratio.0, class.gate_ratio.1);
            let gate = gate_ratio * slots as f64 * spec.vm_ext_bw;
            let unreach_p = rng.range_f64(class.unreach_p.0, class.unreach_p.1);
            clusters.push(Cluster {
                id,
                scale,
                slots,
                power_mean,
                power_std: power_mean * rsd,
                ingress: gate,
                egress: gate,
                unreach_p,
            });
        }
        // Per-pair WAN: mean drawn from the spec range, attenuated by hop
        // distance (multi-hop WAN paths bottleneck on their worst link).
        let n = spec.n_clusters;
        let mut wan_mean = vec![0.0; n * n];
        let mut wan_std = vec![0.0; n * n];
        for a in 0..n {
            for b in (a + 1)..n {
                let base = rng.range_f64(spec.wan_mean.0, spec.wan_mean.1);
                let rsd = rng.range_f64(spec.wan_rsd.0, spec.wan_rsd.1);
                let hops = topology.hops(a, b).max(1) as f64;
                let mean = base / hops.sqrt();
                wan_mean[a * n + b] = mean;
                wan_mean[b * n + a] = mean;
                wan_std[a * n + b] = mean * rsd;
                wan_std[b * n + a] = mean * rsd;
            }
            // intra-cluster "transfer" is effectively local disk/LAN: fast.
            wan_mean[a * n + a] = 8.0 * spec.wan_mean.1;
            wan_std[a * n + a] = 0.5 * spec.wan_mean.1;
        }
        let max_power = clusters
            .iter()
            .map(|c| c.power_mean + 3.0 * c.power_std)
            .fold(0.0, f64::max);
        // grid sizing excludes the (fast) intra-cluster diagonal: rates are
        // min(P, T), so transfer values beyond max_power never matter, and
        // including the 8x intra bandwidth would waste grid resolution
        let mut max_wan = 0.0f64;
        for a in 0..n {
            for b in 0..n {
                if a != b {
                    max_wan = max_wan.max(wan_mean[a * n + b] + 3.0 * wan_std[a * n + b]);
                }
            }
        }
        GeoSystem {
            clusters,
            topology,
            wan_mean,
            wan_std,
            max_power,
            max_wan,
        }
    }

    pub fn n(&self) -> usize {
        self.clusters.len()
    }

    pub fn total_slots(&self) -> usize {
        self.clusters.iter().map(|c| c.slots).sum()
    }

    pub fn wan_mean(&self, from: usize, to: usize) -> f64 {
        self.wan_mean[from * self.n() + to]
    }

    pub fn wan_std(&self, from: usize, to: usize) -> f64 {
        self.wan_std[from * self.n() + to]
    }

    /// Draw a true transfer bandwidth for one copy's fetch from `from` into
    /// `to` (captured at the download end, per the paper).
    pub fn draw_wan(&self, from: usize, to: usize, rng: &mut Rng) -> f64 {
        let mean = self.wan_mean(from, to);
        // floor at 2% of the mean (see draw_power)
        rng.normal_pos(mean, self.wan_std(from, to), 0.02 * mean)
    }

    /// Per-slot Bernoulli draws of cluster-level unreachability (Table-2
    /// p scaled to per-slot, see [`FAILURE_EPOCH_SLOTS`]).
    pub fn draw_failures(&self, rng: &mut Rng) -> Vec<bool> {
        self.clusters
            .iter()
            .map(|c| rng.chance(c.unreach_p / FAILURE_EPOCH_SLOTS))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::SystemSpec;

    fn system() -> GeoSystem {
        let mut rng = Rng::new(5);
        GeoSystem::generate(&SystemSpec::small(20), &mut rng)
    }

    #[test]
    fn parameters_within_table2_ranges() {
        let mut rng = Rng::new(5);
        let spec = SystemSpec::default();
        let sys = GeoSystem::generate(&spec, &mut rng);
        for c in &sys.clusters {
            let class = &spec.classes[c.scale.class_index()];
            assert!(
                (class.vm_count.0..=class.vm_count.1).contains(&(c.slots as u64)),
                "slots {} out of range for {:?}",
                c.slots,
                c.scale
            );
            assert!(c.power_mean >= class.power_mean.0 && c.power_mean <= class.power_mean.1);
            assert!(c.unreach_p >= class.unreach_p.0 && c.unreach_p <= class.unreach_p.1);
            assert!(c.ingress > 0.0 && c.egress > 0.0);
        }
    }

    #[test]
    fn large_clusters_outpower_small() {
        let mut rng = Rng::new(6);
        let sys = GeoSystem::generate(&SystemSpec::default(), &mut rng);
        let avg = |s: ClusterScale| {
            let v: Vec<f64> = sys
                .clusters
                .iter()
                .filter(|c| c.scale == s)
                .map(|c| c.power_mean)
                .collect();
            v.iter().sum::<f64>() / v.len() as f64
        };
        assert!(avg(ClusterScale::Large) > avg(ClusterScale::Small));
    }

    #[test]
    fn wan_symmetric_and_intra_fast() {
        let sys = system();
        for a in 0..sys.n() {
            for b in 0..sys.n() {
                assert_eq!(sys.wan_mean(a, b), sys.wan_mean(b, a));
            }
            for b in 0..sys.n() {
                if a != b {
                    assert!(sys.wan_mean(a, a) > sys.wan_mean(a, b));
                }
            }
        }
    }

    #[test]
    fn farther_pairs_slower_on_average() {
        let sys = system();
        let mut near = Vec::new();
        let mut far = Vec::new();
        for a in 0..sys.n() {
            for b in (a + 1)..sys.n() {
                let h = sys.topology.hops(a, b);
                if h == 1 {
                    near.push(sys.wan_mean(a, b));
                } else if h >= 3 {
                    far.push(sys.wan_mean(a, b));
                }
            }
        }
        if !near.is_empty() && !far.is_empty() {
            let m = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
            assert!(m(&near) > m(&far));
        }
    }

    #[test]
    fn draws_positive() {
        let sys = system();
        let mut rng = Rng::new(7);
        for _ in 0..100 {
            assert!(sys.draw_wan(0, 1, &mut rng) > 0.0);
            assert!(sys.clusters[0].draw_power(1.0, &mut rng) > 0.0);
        }
    }

    #[test]
    fn failure_rates_track_p() {
        let sys = system();
        let mut rng = Rng::new(8);
        let trials = 4000;
        let mut counts = vec![0usize; sys.n()];
        for _ in 0..trials {
            for (i, f) in sys.draw_failures(&mut rng).iter().enumerate() {
                if *f {
                    counts[i] += 1;
                }
            }
        }
        for (i, c) in counts.iter().enumerate() {
            let observed = *c as f64 / trials as f64;
            let expected = sys.clusters[i].unreach_p / FAILURE_EPOCH_SLOTS;
            assert!(
                (observed - expected).abs() < 0.01 + 0.5 * expected,
                "cluster {i}: observed {observed} vs p {expected}"
            );
        }
    }
}
