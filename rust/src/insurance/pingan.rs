//! Algorithm 1 — the PingAn insurer as a [`Scheduler`].

use super::scoring::{self, CandidateScore};
use crate::config::spec::{Allocation, PingAnSpec, Principle};
use crate::dist::Hist;
use crate::sched::{Action, Assignment, SchedView, Scheduler};

/// Which criterion a round optimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Criterion {
    Efficiency,
    Reliability,
}

/// Per-slot memo: candidate solo rates and the global-best floor do not
/// change within one scheduling slot, but the round structure re-visits
/// tasks several times — caching them turns the inner loop from
/// O(rounds × clusters × V) into O(clusters × V) per task per slot.
#[derive(Default)]
struct SlotCache {
    /// (job, task) -> per-cluster (solo rate, rate hist).
    solo: std::collections::HashMap<(usize, usize), Vec<(f64, Hist)>>,
    /// (job, task) -> E^O[r(1)] global best.
    global_best: std::collections::HashMap<(usize, usize), f64>,
}

/// The PingAn insurance scheduler.
pub struct PingAn {
    spec: PingAnSpec,
    name: String,
    cache: SlotCache,
}

impl PingAn {
    pub fn new(spec: PingAnSpec) -> PingAn {
        spec.validate().expect("invalid PingAnSpec");
        let name = format!(
            "pingan(eps={},{},{})",
            spec.epsilon,
            spec.principle.name(),
            spec.allocation.name()
        );
        PingAn {
            spec,
            name,
            cache: SlotCache::default(),
        }
    }

    pub fn with_epsilon(epsilon: f64) -> PingAn {
        PingAn::new(PingAnSpec::with_epsilon(epsilon))
    }

    pub fn spec(&self) -> &PingAnSpec {
        &self.spec
    }

    fn round_criterion(&self, round: usize) -> Criterion {
        match (round, self.spec.principle) {
            (1, Principle::EffReli) | (1, Principle::EffEff) => Criterion::Efficiency,
            (1, _) => Criterion::Reliability,
            (2, Principle::EffReli) | (2, Principle::ReliReli) => Criterion::Reliability,
            (2, _) => Criterion::Efficiency,
            // rounds >= 3 always efficiency-first + resource-saving rule
            _ => Criterion::Efficiency,
        }
    }

    /// Compute (or fetch) the per-cluster solo rate hists for a task.
    fn solo_rates<'c>(
        cache: &'c mut SlotCache,
        view: &SchedView<'_>,
        job: usize,
        task: usize,
    ) -> &'c Vec<(f64, Hist)> {
        cache.solo.entry((job, task)).or_insert_with(|| {
            let rt = &view.jobs[job].tasks[task];
            let op = view.jobs[job].spec.tasks[task].op;
            (0..view.system.n())
                .map(|m| {
                    let h = view.model.rate_hist(&rt.sources, m, op);
                    (h.mean(), h)
                })
                .collect()
        })
    }

    /// Try to insure one copy of (`job`,`task`) under `criterion`; mutates
    /// the view's ledgers on success. `round` selects admission rules.
    fn try_insure(
        &mut self,
        view: &mut SchedView<'_>,
        job: usize,
        task: usize,
        criterion: Criterion,
        round: usize,
        out: &mut Vec<Action>,
    ) -> bool {
        let spec_task = &view.jobs[job].spec.tasks[task];
        let (op, datasize) = (spec_task.op, spec_task.datasize);
        let _ = op;
        let rt = &view.jobs[job].tasks[task];
        let sources = rt.sources.clone();
        let existing_clusters = rt.copy_clusters();
        let n_existing = existing_clusters.len();
        if n_existing >= self.spec.max_copies {
            return false;
        }
        let solo = Self::solo_rates(&mut self.cache, view, job, task).clone();
        // existing copy-rate hists: the solo hists of occupied clusters
        let existing: Vec<Hist> = existing_clusters
            .iter()
            .map(|&m| solo[m].1.clone())
            .collect();
        let current_rate = if existing.is_empty() {
            0.0
        } else {
            let refs: Vec<&Hist> = existing.iter().collect();
            Hist::expected_max(&refs)
        };
        // candidates: clusters with free slots
        let candidates: Vec<usize> = (0..view.system.n())
            .filter(|&m| view.free_slots[m] > 0)
            .collect();
        if candidates.is_empty() {
            return false;
        }
        let global_best = *self
            .cache
            .global_best
            .entry((job, task))
            .or_insert_with(|| solo.iter().map(|(r, _)| *r).fold(0.0, f64::max));
        let scores = scoring::score_candidates_cached(
            view.model,
            datasize,
            &solo,
            &existing,
            &existing_clusters,
            &candidates,
        );
        // admission filters, then criterion ordering
        let mut admissible: Vec<&CandidateScore> = scores
            .iter()
            .filter(|s| scoring::passes_rate_floor(s.solo_rate, global_best, self.spec.epsilon))
            .collect();
        if admissible.is_empty() {
            log::debug!(
                "task ({job},{task}): no admissible cluster (best solo {:.3} vs floor {:.3}, {} candidates)",
                scores.iter().map(|s| s.solo_rate).fold(0.0, f64::max),
                global_best / (1.0 + self.spec.epsilon),
                scores.len()
            );
            return false;
        }
        match criterion {
            Criterion::Efficiency => {
                admissible.sort_by(|a, b| b.rate.partial_cmp(&a.rate).unwrap());
            }
            Criterion::Reliability => {
                admissible.sort_by(|a, b| b.pro.partial_cmp(&a.pro).unwrap());
            }
        }
        let (mut rej_saving, mut rej_slot, mut rej_bw) = (0u32, 0u32, 0u32);
        for s in admissible {
            // resource-saving admission for the 3rd+ copy (Sec 4.1)
            if round >= 3 || n_existing >= 2 {
                let c = n_existing; // deciding the (c+1)-th copy; paper's c >= 2
                if !scoring::resource_saving_ok(datasize, current_rate, s.rate, c.max(2)) {
                    rej_saving += 1;
                    continue;
                }
            }
            if !view.try_reserve_slot(s.cluster) {
                rej_slot += 1;
                continue;
            }
            let reserved = if n_existing == 0 {
                view.try_reserve_bandwidth(&sources, s.cluster, s.solo_rate)
            } else {
                view.try_reserve_bandwidth_full(&sources, s.cluster, s.solo_rate)
            };
            if !reserved {
                // roll the slot back and try the next candidate
                view.free_slots[s.cluster] += 1;
                rej_bw += 1;
                log::debug!(
                    "  bw reject: cluster {} rate {:.1} ing_free {:.1} sources {:?} eg_free {:?}",
                    s.cluster,
                    s.solo_rate,
                    view.ingress_free[s.cluster],
                    sources,
                    sources.iter().map(|&x| view.egress_free[x]).collect::<Vec<_>>()
                );
                continue;
            }
            out.push(Action::Launch(Assignment {
                job,
                task,
                cluster: s.cluster,
            }));
            return true;
        }
        log::debug!(
            "task ({job},{task}) round {round}: rejected everywhere (saving {rej_saving}, slot {rej_slot}, bw {rej_bw})"
        );
        false
    }

    /// One EFA round over `prior` jobs. Returns slots assigned.
    #[allow(clippy::too_many_arguments)]
    fn run_round(
        &mut self,
        view: &mut SchedView<'_>,
        prior: &[usize],
        budget: &mut Vec<usize>, // h_i - θ_i per prior index
        round: usize,
        copied_last_round: &mut Vec<Vec<(usize, usize)>>,
        out: &mut Vec<Action>,
    ) -> usize {
        let criterion = self.round_criterion(round);
        let mut assigned = 0usize;
        for (pi, &ji) in prior.iter().enumerate() {
            if budget[pi] == 0 {
                continue;
            }
            let mut targets: Vec<(usize, usize)> = match round {
                1 => view
                    .ready_tasks(ji)
                    .into_iter()
                    .map(|t| (ji, t))
                    .collect(),
                2 => {
                    // running tasks ordered by ascending pro (worst first)
                    let mut ts: Vec<(f64, (usize, usize))> = view
                        .running_tasks(ji)
                        .into_iter()
                        .map(|t| {
                            let rt = &view.jobs[ji].tasks[t];
                            let spec = &view.jobs[ji].spec.tasks[t];
                            let clusters = rt.copy_clusters();
                            let rate = view
                                .model
                                .exp_rate1(&rt.sources, clusters[0], spec.op)
                                .max(1e-9);
                            let pro = view.model.pro(&clusters, spec.datasize, rate);
                            (pro, (ji, t))
                        })
                        .collect();
                    ts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    ts.into_iter().map(|(_, t)| t).collect()
                }
                _ => std::mem::take(&mut copied_last_round[pi]),
            };
            let mut copied_now: Vec<(usize, usize)> = Vec::new();
            for (ji, ti) in targets.drain(..) {
                if budget[pi] == 0 {
                    break;
                }
                if self.try_insure(view, ji, ti, criterion, round, out) {
                    budget[pi] -= 1;
                    assigned += 1;
                    copied_now.push((ji, ti));
                }
            }
            copied_last_round[pi] = copied_now;
        }
        assigned
    }
}

impl Scheduler for PingAn {
    fn name(&self) -> &str {
        &self.name
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        let mut out: Vec<Action> = Vec::new();
        // estimates shift as the modeler absorbs logs: memoize within the
        // slot only
        self.cache.solo.clear();
        self.cache.global_best.clear();
        let n_alive = view.alive.len();
        if n_alive == 0 {
            return out;
        }
        // 1. job priority: ascending unprocessed datasize
        let mut order: Vec<usize> = view.alive.to_vec();
        order.sort_by(|&a, &b| {
            view.unprocessed(a)
                .partial_cmp(&view.unprocessed(b))
                .unwrap()
                .then(a.cmp(&b))
        });
        // 2. the first ⌈εN⌉ jobs share the plant
        let n_prior = ((self.spec.epsilon * n_alive as f64).ceil() as usize)
            .clamp(1, n_alive);
        let prior: Vec<usize> = order[..n_prior].to_vec();
        let total_slots: usize = view.system.total_slots();
        let h = (total_slots / n_prior).max(1);
        // θ_i: slots already running this job's copies
        let mut budget: Vec<usize> = prior
            .iter()
            .map(|&ji| {
                let theta: usize = view.jobs[ji]
                    .tasks
                    .iter()
                    .map(|t| t.alive_copies())
                    .sum();
                h.saturating_sub(theta)
            })
            .collect();
        let mut copied_last: Vec<Vec<(usize, usize)>> = vec![Vec::new(); prior.len()];

        log::debug!(
            "t={}: alive {}, prior {:?}, budgets {:?}, ready {:?}, free {}",
            view.now,
            n_alive,
            prior,
            budget,
            prior.iter().map(|&j| view.ready_tasks(j).len()).collect::<Vec<_>>(),
            view.total_free()
        );
        match self.spec.allocation {
            Allocation::Efa => {
                // rounds sweep across all prior jobs (the paper's EFA)
                let mut round = 1usize;
                loop {
                    let assigned =
                        self.run_round(view, &prior, &mut budget, round, &mut copied_last, &mut out);
                    if assigned == 0 {
                        break;
                    }
                    round += 1;
                    if round > self.spec.max_copies + 1 {
                        break;
                    }
                }
            }
            Allocation::Jga => {
                // job-greedy: a job exhausts all its rounds before the next
                for (pi, &ji) in prior.iter().enumerate() {
                    let single_prior = vec![ji];
                    let mut single_budget = vec![budget[pi]];
                    let mut single_copied = vec![Vec::new()];
                    let mut round = 1usize;
                    loop {
                        let assigned = self.run_round(
                            view,
                            &single_prior,
                            &mut single_budget,
                            round,
                            &mut single_copied,
                            &mut out,
                        );
                        if assigned == 0 {
                            break;
                        }
                        round += 1;
                        if round > self.spec.max_copies + 1 {
                            break;
                        }
                    }
                    budget[pi] = single_budget[0];
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::simulator::{SimConfig, Simulation};
    use crate::util::rng::Rng;
    use crate::workload::montage;

    fn setup(n_jobs: usize, seed: u64) -> (GeoSystem, Vec<crate::workload::job::JobSpec>) {
        let mut rng = Rng::new(seed);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, 0.05);
        w.datasize = (50.0, 400.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        (sys, jobs)
    }

    #[test]
    fn completes_all_jobs() {
        let (sys, jobs) = setup(10, 61);
        let res = Simulation::new(&sys, jobs, SimConfig::default())
            .run(&mut PingAn::with_epsilon(0.6));
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(res.copies_launched > 0);
    }

    #[test]
    fn insures_extra_copies() {
        // abundant gates so round-2 reliability copies (which must fit
        // their full stream) are admissible
        let mut rng = Rng::new(62);
        let mut sspec = SystemSpec::small(6);
        sspec.vm_ext_bw *= 8.0;
        let sys = GeoSystem::generate(&sspec, &mut rng);
        let mut w = WorkloadSpec::scaled(4, 0.05);
        w.datasize = (200.0, 800.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let n_tasks: u64 = jobs.iter().map(|j| j.n_tasks() as u64).sum();
        let res = Simulation::new(&sys, jobs, SimConfig::default())
            .run(&mut PingAn::with_epsilon(0.8));
        assert!(
            res.copies_launched > n_tasks,
            "expected insurance copies: {} copies for {} tasks",
            res.copies_launched,
            n_tasks
        );
    }

    #[test]
    fn respects_max_copy_cap() {
        let (sys, jobs) = setup(3, 63);
        let mut spec = PingAnSpec::with_epsilon(0.8);
        spec.max_copies = 2;
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let mut p = PingAn::new(spec);
        for _ in 0..400 {
            sim.step(&mut p);
            for j in &sim.jobs {
                for t in &j.tasks {
                    assert!(t.alive_copies() <= 2, "copy cap violated");
                }
            }
        }
    }

    #[test]
    fn all_variants_run() {
        for principle in [
            Principle::EffReli,
            Principle::ReliEff,
            Principle::EffEff,
            Principle::ReliReli,
        ] {
            for allocation in [Allocation::Efa, Allocation::Jga] {
                let (sys, jobs) = setup(4, 64);
                let mut spec = PingAnSpec::with_epsilon(0.6);
                spec.principle = principle;
                spec.allocation = allocation;
                let res =
                    Simulation::new(&sys, jobs, SimConfig::default()).run(&mut PingAn::new(spec));
                assert_eq!(
                    res.finished_jobs, res.total_jobs,
                    "{principle:?}/{allocation:?}"
                );
            }
        }
    }

    #[test]
    fn epsilon_shapes_sharing() {
        // With tiny epsilon only the smallest jobs get slots each round;
        // both must still finish, and small-eps should not launch more
        // copies than large-eps under light load.
        let (sys, jobs) = setup(8, 65);
        let r_small = Simulation::new(&sys, jobs.clone(), SimConfig::default())
            .run(&mut PingAn::with_epsilon(0.2));
        let r_large =
            Simulation::new(&sys, jobs, SimConfig::default()).run(&mut PingAn::with_epsilon(0.8));
        assert_eq!(r_small.finished_jobs, r_small.total_jobs);
        assert_eq!(r_large.finished_jobs, r_large.total_jobs);
    }

    #[test]
    fn invariants_under_pingan() {
        let (sys, jobs) = setup(6, 66);
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let mut p = PingAn::with_epsilon(0.6);
        for _ in 0..300 {
            sim.step(&mut p);
            sim.check_invariants().unwrap();
        }
    }
}
