//! End-to-end driver (DESIGN.md §End-to-end validation): run the
//! Spark-on-Yarn testbed mode on the Table-1 workload with **real XLA
//! payload execution per task** through the PJRT runtime, comparing
//! PingAn against default and speculative Spark — the Fig 2/3 experiment
//! and the proof that all three layers compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example geo_analytics -- [n_jobs]
//! ```

use pingan::experiments::figures;
use pingan::metrics::cdf::Cdf;

fn main() {
    let n_jobs: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    println!("running testbed: {n_jobs} Table-1 jobs over 10 heterogeneous clusters");
    println!("(payloads: wordcount/pagerank/logreg HLO artifacts via PJRT)\n");

    let runs = match figures::run_testbed(n_jobs, 5) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e:#}\nhint: run `make artifacts` first");
            std::process::exit(1);
        }
    };
    print!("{}", figures::fig2(&runs));
    print!("{}", figures::fig3(&runs));

    // headline metric: average flowtime reduction vs speculative spark
    let avg = |flows: &[f64]| {
        let v: Vec<f64> = flows.iter().copied().filter(|f| f.is_finite()).collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let pingan = avg(&runs.results[0].flowtimes);
    let spec = avg(&runs.results[2].flowtimes);
    println!(
        "\nheadline: PingAn {:.1} vs speculative Spark {:.1} slots -> {:.1}% reduction (paper: 39.6%)",
        pingan,
        spec,
        100.0 * (spec - pingan) / spec
    );
    let errors: u64 = runs.results.iter().map(|r| r.payload_errors).sum();
    let execs: u64 = runs.results.iter().map(|r| r.payload_execs).sum();
    println!("payload executions: {execs} ({errors} validation errors)");
    let c = Cdf::new(&runs.results[0].flowtimes);
    println!(
        "PingAn flowtime quartiles: p25 {:.0} / p50 {:.0} / p75 {:.0}",
        c.quantile(0.25),
        c.quantile(0.5),
        c.quantile(0.75)
    );
    if errors > 0 {
        std::process::exit(1);
    }
}
