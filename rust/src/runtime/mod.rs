//! PJRT runtime: load the AOT HLO artifacts and execute them on the
//! request path — python never runs here.
//!
//! * [`pjrt`] — artifact discovery (`artifacts/manifest.toml`), HLO-text
//!   loading, compilation on the CPU PJRT client, typed execution helpers.
//! * [`scorer`] — the insurer's batched copy-placement scorer with two
//!   interchangeable backends: the compiled `score` artifact (L1/L2 math)
//!   and a pure-rust fallback ([`scorer::CpuScorer`]) that mirrors the
//!   histogram algebra exactly; tests assert they agree bin-for-bin.
//! * [`payload`] — the testbed task payloads (wordcount / pagerank /
//!   logreg) used by the Spark-on-Yarn mode to run real compute per task.

pub mod payload;
pub mod pjrt;
pub mod scorer;

pub use pjrt::{ArtifactSet, Engine};
pub use scorer::{CpuScorer, HloScorer, ScoreBatch, Scorer};
