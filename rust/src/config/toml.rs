//! TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supports what experiment configs need: `[section]` and `[a.b]` tables,
//! `key = value` with strings, integers, floats, booleans, and flat arrays
//! of those; `#` comments. No multi-line strings, no datetimes, no nested
//! inline tables — configs that need more should be split.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64_array(&self) -> Option<Vec<f64>> {
        match self {
            Value::Array(xs) => xs.iter().map(|v| v.as_f64()).collect(),
            _ => None,
        }
    }

    pub fn as_str_array(&self) -> Option<Vec<String>> {
        match self {
            Value::Array(xs) => xs
                .iter()
                .map(|v| v.as_str().map(|s| s.to_string()))
                .collect(),
            _ => None,
        }
    }
}

/// A parsed document: dotted-path key -> value. Section `[a.b]` plus
/// `k = v` yields key `a.b.k`.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub entries: BTreeMap<String, Value>,
}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, String> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(format!("line {}: unterminated section", lineno + 1));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(format!("line {}: empty section name", lineno + 1));
                }
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = k.trim();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let val = parse_value(v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
            doc.entries.insert(full, val);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| format!("{key}: expected number")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_i64()
                .filter(|x| *x >= 0)
                .map(|x| x as usize)
                .ok_or_else(|| format!("{key}: expected non-negative integer")),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> Result<&'a str, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_str().ok_or_else(|| format!("{key}: expected string")),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool().ok_or_else(|| format!("{key}: expected bool")),
        }
    }

    /// Optional numeric array (sweep axes): `Ok(None)` when absent,
    /// `Err` when present but not an array of numbers.
    pub fn get_f64s(&self, key: &str) -> Result<Option<Vec<f64>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_f64_array()
                .map(Some)
                .ok_or_else(|| format!("{key}: expected array of numbers")),
        }
    }

    /// Optional string array (sweep axes): `Ok(None)` when absent,
    /// `Err` when present but not an array of strings.
    pub fn get_strs(&self, key: &str) -> Result<Option<Vec<String>>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .as_str_array()
                .map(Some)
                .ok_or_else(|| format!("{key}: expected array of strings")),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` inside a quoted string must survive.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err(format!("unterminated string: {s}"));
        }
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(format!("unterminated array: {s}"));
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let items: Result<Vec<Value>, String> = split_top_level(inner)
            .into_iter()
            .map(|p| parse_value(p.trim()))
            .collect();
        return Ok(Value::Array(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// Split on commas not inside quotes (arrays are flat; no nesting).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
# experiment config
name = "fig4"
[sim]
clusters = 100
epsilon = 0.6
verbose = true
lambdas = [0.02, 0.07, 0.15]
[sim.wan]
mean_kbps = 128
"#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("fig4"));
        assert_eq!(doc.get("sim.clusters").unwrap().as_i64(), Some(100));
        assert_eq!(doc.get("sim.epsilon").unwrap().as_f64(), Some(0.6));
        assert_eq!(doc.get("sim.verbose").unwrap().as_bool(), Some(true));
        assert_eq!(
            doc.get("sim.lambdas").unwrap().as_f64_array(),
            Some(vec![0.02, 0.07, 0.15])
        );
        assert_eq!(doc.get("sim.wan.mean_kbps").unwrap().as_i64(), Some(128));
    }

    #[test]
    fn comments_inside_strings_survive() {
        let doc = Doc::parse(r##"tag = "a#b" # trailing"##).unwrap();
        assert_eq!(doc.get("tag").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("x 1").unwrap_err();
        assert!(err.contains("line 1"), "{err}");
        let err = Doc::parse("\n\nkey = @@").unwrap_err();
        assert!(err.contains("line 3"), "{err}");
    }

    #[test]
    fn typed_getters_with_defaults() {
        let doc = Doc::parse("a = 1\nb = 2.5").unwrap();
        assert_eq!(doc.get_f64("a", 0.0).unwrap(), 1.0);
        assert_eq!(doc.get_f64("b", 0.0).unwrap(), 2.5);
        assert_eq!(doc.get_f64("missing", 9.0).unwrap(), 9.0);
        assert!(doc.get_str("a", "x").is_err());
        assert_eq!(doc.get_usize("a", 0).unwrap(), 1);
    }

    #[test]
    fn empty_and_string_arrays() {
        let doc = Doc::parse(r#"xs = []
ys = ["a", "b,c"]"#)
            .unwrap();
        assert_eq!(doc.get("xs").unwrap(), &Value::Array(vec![]));
        match doc.get("ys").unwrap() {
            Value::Array(v) => {
                assert_eq!(v[0].as_str(), Some("a"));
                assert_eq!(v[1].as_str(), Some("b,c"));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn optional_array_getters() {
        let doc = Doc::parse(r#"xs = [0.2, 0.4]
names = ["a", "b"]
n = 3"#)
            .unwrap();
        assert_eq!(doc.get_f64s("xs").unwrap(), Some(vec![0.2, 0.4]));
        assert_eq!(
            doc.get_strs("names").unwrap(),
            Some(vec!["a".to_string(), "b".to_string()])
        );
        assert_eq!(doc.get_f64s("missing").unwrap(), None);
        assert!(doc.get_f64s("n").is_err());
        assert!(doc.get_strs("xs").is_err());
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = Doc::parse("a = -3\nb = 1e-3").unwrap();
        assert_eq!(doc.get("a").unwrap().as_i64(), Some(-3));
        assert_eq!(doc.get("b").unwrap().as_f64(), Some(1e-3));
    }
}
