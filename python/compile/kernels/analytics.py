"""Pallas kernels for the testbed task payloads (Sec 5 workloads).

The Spark-on-Yarn testbed mode executes *real compute* per task; these are
the three applications of Table 1 reduced to their numeric hot loops:

* ``wordcount``     — token histogram via one-hot matmul (MXU-friendly:
  the [TILE, vocab] one-hot block contracts on the MXU at bf16/f32),
* ``pagerank_step`` — damped power-iteration step (matvec on the MXU),
* ``logreg_step``   — logistic-regression gradient step (two matmuls).

Each kernel tiles its batch dimension through the Pallas grid with
accumulation in f32, the layout a TPU implementation would use.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---- wordcount -----------------------------------------------------------

def _wordcount_kernel(tok_ref, out_ref):
    """One TILE of tokens -> partial histogram, accumulated across the grid."""
    toks = tok_ref[...]  # [TILE] int32
    vocab = out_ref.shape[0]
    onehot = jnp.asarray(
        toks[:, None] == jnp.arange(vocab, dtype=jnp.int32)[None, :], jnp.float32
    )
    partial = jnp.sum(onehot, axis=0)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


def wordcount(tokens, vocab, *, tile=512, interpret=True):
    """Histogram of token ids: [N] int32 -> [vocab] f32. N % tile == 0."""
    (n,) = tokens.shape
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    return pl.pallas_call(
        _wordcount_kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((vocab,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((vocab,), jnp.float32),
        interpret=interpret,
    )(tokens)


# ---- pagerank ------------------------------------------------------------

def _pagerank_kernel(ranks_ref, norm_adj_t_ref, out_ref, *, damping):
    ranks = ranks_ref[...]  # [N]
    nat = norm_adj_t_ref[...]  # [N, N] column-normalized adjacency, transposed
    contrib = nat @ ranks
    n = ranks.shape[0]
    out_ref[...] = (1.0 - damping) / n + damping * contrib


def pagerank_step(ranks, adj, *, damping=0.85, interpret=True):
    """One PageRank step: [N] × [N,N] -> [N]."""
    n = ranks.shape[0]
    deg = jnp.maximum(jnp.sum(adj, axis=1, keepdims=True), 1.0)
    norm_adj_t = (adj / deg).T
    from functools import partial

    return pl.pallas_call(
        partial(_pagerank_kernel, damping=damping),
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), ranks.dtype),
        interpret=interpret,
    )(ranks, norm_adj_t)


# ---- logistic regression -------------------------------------------------

def _logreg_kernel(x_ref, y_ref, w_ref, out_ref, *, lr, n_total):
    x = x_ref[...]  # [TILE, D]
    y = y_ref[...]  # [TILE]
    w = w_ref[...]  # [D]
    logits = x @ w
    p = 1.0 / (1.0 + jnp.exp(-logits))
    grad = x.T @ (p - y) / n_total

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = w

    out_ref[...] -= lr * grad


def logreg_step(x, y, w, *, lr=0.1, tile=64, interpret=True):
    """One gradient step: [N,D] × [N] × [D] -> [D]. N % tile == 0."""
    n, d = x.shape
    assert n % tile == 0, f"N={n} must be a multiple of tile={tile}"
    from functools import partial

    return pl.pallas_call(
        partial(_logreg_kernel, lr=lr, n_total=float(n)),
        grid=(n // tile,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=interpret,
    )(x, y, w)
