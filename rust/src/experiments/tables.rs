//! Table regenerators: Table 1 (testbed workload constitution) and
//! Table 2 (simulated cluster parameters as actually generated).
//!
//! Both tables report what the sweep subsystem *actually materializes*:
//! they build a [`Scenario`] and read its environment, so a sweep cell
//! with the same coordinates sees exactly the constitution printed here.

use crate::cluster::GeoSystem;
use crate::sweep::{Scenario, WorkloadMix};
use crate::topology::ClusterScale;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::{fnum, fpct, Table};
use crate::workload::job::JobSpec;
use crate::workload::testbed::AppKind;

/// Table 1: the testbed workload mix as a sweep scenario's environment.
pub fn table1(n_jobs: usize, seed: u64) -> String {
    let mut sc = Scenario::default();
    sc.mix = WorkloadMix::Testbed;
    sc.n_jobs = n_jobs;
    sc.n_clusters = 10;
    sc.slot_divisor = 1;
    let (_sys, jobs) = sc.build_env(seed);
    let mut t = Table::new(
        &format!("Table 1 — workload constitution ({n_jobs} jobs)"),
        &["app", "jobs", "share", "input range (MB)", "tasks p50"],
    );
    for app in AppKind::ALL {
        let of_app: Vec<&JobSpec> = jobs
            .iter()
            .filter(|j| j.name.starts_with(app.name()))
            .collect();
        let sizes: Vec<f64> = of_app.iter().map(|j| input_mb(j)).collect();
        let tasks: Vec<f64> = of_app.iter().map(|j| j.n_tasks() as f64).collect();
        t.row(&[
            app.name().to_string(),
            of_app.len().to_string(),
            fpct(of_app.len() as f64 / jobs.len() as f64),
            format!(
                "{}-{}",
                fnum(sizes.iter().cloned().fold(f64::INFINITY, f64::min), 0),
                fnum(sizes.iter().cloned().fold(0.0, f64::max), 0)
            ),
            fnum(stats::median(&tasks), 0),
        ]);
    }
    t.render()
}

fn input_mb(j: &JobSpec) -> f64 {
    j.tasks
        .iter()
        .filter(|t| t.deps.is_empty())
        .map(|t| t.datasize)
        .sum()
}

/// Table 2: generate the simulated plant a sweep scenario would run on
/// and report observed parameter ranges per scale class, next to the
/// paper's configured ranges.
pub fn table2(n_clusters: usize, seed: u64) -> String {
    let mut sc = Scenario::default();
    sc.n_clusters = n_clusters;
    sc.slot_divisor = 1;
    let env_seed = sc.env_seed(seed);
    let spec = sc.system_spec(env_seed);
    let mut rng = Rng::new(env_seed);
    let sys = GeoSystem::generate(&spec, &mut rng);
    let mut t = Table::new(
        &format!("Table 2 — generated cluster parameters ({n_clusters} clusters)"),
        &[
            "class",
            "share",
            "slots range",
            "power mean range",
            "unreach p range",
            "gate/extbw",
        ],
    );
    for scale in [ClusterScale::Large, ClusterScale::Medium, ClusterScale::Small] {
        let cs: Vec<&crate::cluster::Cluster> = sys
            .clusters
            .iter()
            .filter(|c| c.scale == scale)
            .collect();
        if cs.is_empty() {
            continue;
        }
        let slots: Vec<f64> = cs.iter().map(|c| c.slots as f64).collect();
        let power: Vec<f64> = cs.iter().map(|c| c.power_mean).collect();
        let unreach: Vec<f64> = cs.iter().map(|c| c.unreach_p).collect();
        let gate_ratio: Vec<f64> = cs
            .iter()
            .map(|c| c.ingress / (c.slots as f64 * spec.vm_ext_bw))
            .collect();
        let rng_of = |v: &[f64], d: usize| {
            format!(
                "{}-{}",
                fnum(v.iter().cloned().fold(f64::INFINITY, f64::min), d),
                fnum(v.iter().cloned().fold(0.0, f64::max), d)
            )
        };
        t.row(&[
            scale.name().to_string(),
            fpct(cs.len() as f64 / sys.n() as f64),
            rng_of(&slots, 0),
            rng_of(&power, 0),
            rng_of(&unreach, 3),
            rng_of(&gate_ratio, 2),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_renders_all_apps() {
        let s = table1(200, 7);
        assert!(s.contains("wordcount"));
        assert!(s.contains("iter-ml"));
        assert!(s.contains("pagerank"));
    }

    #[test]
    fn table2_shares_match_paper() {
        let s = table2(100, 7);
        assert!(s.contains("5.0%"), "{s}");
        assert!(s.contains("20.0%"), "{s}");
        assert!(s.contains("75.0%"), "{s}");
    }
}
