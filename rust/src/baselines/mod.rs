//! Baseline schedulers the paper compares against (Sec 5 & 6.1):
//!
//! * [`spark`] — default Spark: fair sharing across jobs + delay scheduling
//!   for data locality, one copy per task, no speculation. Also the
//!   speculative variant (Spark's default speculation mechanism).
//! * [`flutter`] — WAN-aware stage-completion-time-minimizing placement
//!   (Hu et al., INFOCOM'16). The reference scheduler for the reduction
//!   ratios in Fig 5.
//! * [`iridium`] — data/task placement minimizing WAN transfer
//!   (Pu et al., SIGCOMM'15), approximated by most-data-local placement.
//! * [`mantri`] — Flutter placement + Mantri's detection-based speculation
//!   (duplicate when t_rem > 2·t_new, i.e. only when it saves resources).
//! * [`dolly`] — Flutter placement + Dolly's proactive cloning for small
//!   jobs within a spare-resource budget.
//!
//! All baselines read the same [`PerfModel`](crate::perfmodel::PerfModel)
//! estimates PingAn does — differences in results come from *policy*, not
//! from information asymmetry.

pub mod dolly;
pub mod flutter;
pub mod iridium;
pub mod mantri;
pub mod spark;

pub use dolly::Dolly;
pub use flutter::Flutter;
pub use iridium::Iridium;
pub use mantri::Mantri;
pub use spark::{Spark, SpeculativeSpark};

use crate::sched::SchedView;

/// Estimated-best free cluster for one copy by expected rate; `None` when
/// no cluster has a free slot.
pub(crate) fn best_free_cluster(
    view: &SchedView<'_>,
    sources: &[usize],
    op: crate::workload::job::OpKind,
) -> Option<(usize, f64)> {
    let mut best: Option<(usize, f64)> = None;
    for m in 0..view.system.n() {
        if view.free_slots[m] == 0 {
            continue;
        }
        let r = view.model.exp_rate1(sources, m, op);
        if best.map(|(_, b)| r > b).unwrap_or(true) {
            best = Some((m, r));
        }
    }
    best
}

/// Observed progress rate of a copy (progress / elapsed), the quantity a
/// real monitor sees.
pub(crate) fn observed_rate(
    copy: &crate::simulator::state::CopyRt,
    now: u64,
) -> f64 {
    let elapsed = now.saturating_sub(copy.launched_at).max(1) as f64;
    copy.processed / elapsed
}
