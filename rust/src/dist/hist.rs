//! Histograms over a [`Grid`] and their composition algebra.

use super::grid::Grid;

/// A discrete probability distribution of a task-execution rate, held as a
/// pmf over a fixed [`Grid`]. The pmf is kept normalized (sums to 1) by
/// every constructor and operation.
#[derive(Clone, Debug)]
pub struct Hist {
    grid: Grid,
    pmf: Vec<f64>,
}

impl Hist {
    // ---- constructors ----

    /// Discretized normal: each bin receives the Gaussian mass between its
    /// edges (centers ± step/2); the first and last bins absorb the tails,
    /// so truncation never loses mass. `std <= 0` degenerates to
    /// [`Hist::point`] at `mean`.
    pub fn normal(grid: &Grid, mean: f64, std: f64) -> Hist {
        if std.is_nan() || std <= 0.0 {
            return Hist::point(grid, mean);
        }
        let bins = grid.bins();
        let half = 0.5 * grid.step();
        let mut pmf = Vec::with_capacity(bins);
        let mut prev_phi = 0.0;
        for j in 0..bins {
            let phi = if j + 1 == bins {
                1.0
            } else {
                std_normal_cdf((grid.value(j) + half - mean) / std)
            };
            pmf.push((phi - prev_phi).max(0.0));
            prev_phi = phi;
        }
        Hist::from_pmf(grid, &pmf)
    }

    /// All mass on the bin nearest to `v` (an exact observation).
    pub fn point(grid: &Grid, v: f64) -> Hist {
        let mut pmf = vec![0.0; grid.bins()];
        pmf[grid.index_of(v)] = 1.0;
        Hist {
            grid: grid.clone(),
            pmf,
        }
    }

    /// Build from a raw pmf (one weight per grid bin). Negative weights are
    /// clamped to zero and the result is renormalized; a (near-)zero total
    /// degenerates to a point mass on the lowest bin — the pessimistic
    /// "no usable estimate" rate.
    pub fn from_pmf(grid: &Grid, pmf: &[f64]) -> Hist {
        assert_eq!(
            pmf.len(),
            grid.bins(),
            "pmf length {} != grid bins {}",
            pmf.len(),
            grid.bins()
        );
        let mut pmf: Vec<f64> = pmf.iter().map(|&p| p.max(0.0)).collect();
        let total: f64 = pmf.iter().sum();
        if total > 1e-300 {
            let inv = 1.0 / total;
            for p in &mut pmf {
                *p *= inv;
            }
        } else {
            pmf.iter_mut().for_each(|p| *p = 0.0);
            pmf[0] = 1.0;
        }
        Hist {
            grid: grid.clone(),
            pmf,
        }
    }

    // ---- accessors & statistics ----

    pub fn grid(&self) -> &Grid {
        &self.grid
    }

    /// The normalized pmf, indexed by grid bin.
    pub fn pmf(&self) -> &[f64] {
        &self.pmf
    }

    /// Cumulative distribution at each bin: `cdf[j] = P(X <= value(j))`.
    pub fn cdf(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.pmf
            .iter()
            .map(|&p| {
                acc += p;
                acc.min(1.0)
            })
            .collect()
    }

    /// `E[X]` — pmf-weighted sum of bin values.
    pub fn mean(&self) -> f64 {
        self.pmf
            .iter()
            .zip(self.grid.values())
            .map(|(&p, &v)| p * v)
            .sum()
    }

    /// Standard deviation on the grid.
    pub fn std(&self) -> f64 {
        let m = self.mean();
        let var: f64 = self
            .pmf
            .iter()
            .zip(self.grid.values())
            .map(|(&p, &v)| p * (v - m) * (v - m))
            .sum();
        var.max(0.0).sqrt()
    }

    // ---- algebra ----

    /// In-place mixture update: `self <- (1-w)·self + w·obs`, with `w`
    /// clamped to `[0, 1]`. This is the modeler's recency-weighted
    /// observation absorption: `w = 1` replaces the estimate, `w = 0`
    /// leaves it untouched, and the `max(1/n, w_min)` schedule in between
    /// keeps estimates tracking drift.
    pub fn blend(&mut self, obs: &Hist, w: f64) {
        assert!(
            self.grid.same_shape(&obs.grid),
            "blend across incompatible grids"
        );
        let w = w.clamp(0.0, 1.0);
        for (a, &b) in self.pmf.iter_mut().zip(&obs.pmf) {
            *a = (1.0 - w) * *a + w * b;
        }
        // both inputs are normalized, so this only scrubs fp drift
        let total: f64 = self.pmf.iter().sum();
        if total > 1e-300 {
            let inv = 1.0 / total;
            for p in &mut self.pmf {
                *p *= inv;
            }
        }
    }

    /// Distribution of `min(self, other)` for independent variables on the
    /// same grid — the bottleneck of compute and transfer (Sec 3.2).
    ///
    /// One backward pass over the survival functions:
    /// `P(min = v_j) = p[j]·P(other > v_j) + q[j]·P(self > v_j) + p[j]·q[j]`,
    /// identical to the batched `CpuScorer` kernel.
    pub fn min_compose(&self, other: &Hist) -> Hist {
        assert!(
            self.grid.same_shape(&other.grid),
            "min_compose across incompatible grids"
        );
        let bins = self.grid.bins();
        let mut out = vec![0.0; bins];
        let mut sf_a = 0.0; // P(self > v_j), accumulated from the top
        let mut sf_b = 0.0;
        for j in (0..bins).rev() {
            out[j] = self.pmf[j] * sf_b + other.pmf[j] * sf_a + self.pmf[j] * other.pmf[j];
            sf_a += self.pmf[j];
            sf_b += other.pmf[j];
        }
        Hist::from_pmf(&self.grid, &out)
    }

    /// Equal-weight mixture of a family — the modeler's effective estimate
    /// when a task pulls from several sources at once.
    ///
    /// Modeling note: the *exact* distribution of the per-source average
    /// would be a k-fold convolution (off-grid and O(V^k)); the mixture has
    /// the same expectation — which is what the rate model consumes — and
    /// conservatively keeps the per-source spread instead of the
    /// concentration of the sample mean.
    pub fn average_of(hists: &[&Hist]) -> Hist {
        assert!(!hists.is_empty(), "average_of needs at least one hist");
        let grid = &hists[0].grid;
        let w = 1.0 / hists.len() as f64;
        let mut pmf = vec![0.0; grid.bins()];
        for h in hists {
            assert!(
                grid.same_shape(&h.grid),
                "average_of across incompatible grids"
            );
            for (acc, &p) in pmf.iter_mut().zip(&h.pmf) {
                *acc += w * p;
            }
        }
        Hist::from_pmf(grid, &pmf)
    }

    /// `E[max]` over an independent family — the expected progress rate of
    /// a copy set, via the product of CDFs:
    /// `P(max <= v_j) = Π_i F_i(v_j)`, then the expectation of the implied
    /// pmf. Matches the batched scorer's E\[max\] stage bin-for-bin.
    pub fn expected_max(hists: &[&Hist]) -> f64 {
        assert!(!hists.is_empty(), "expected_max needs at least one hist");
        let grid = &hists[0].grid;
        for h in hists {
            assert!(
                grid.same_shape(&h.grid),
                "expected_max across incompatible grids"
            );
        }
        let bins = grid.bins();
        let mut cdfs = vec![0.0; hists.len()];
        let mut prev = 0.0;
        let mut e = 0.0;
        for j in 0..bins {
            let mut combined = 1.0;
            for (acc, h) in cdfs.iter_mut().zip(hists) {
                *acc += h.pmf[j];
                combined *= acc.min(1.0);
            }
            e += grid.value(j) * (combined - prev);
            prev = combined;
        }
        e
    }
}

/// Standard normal CDF via the Abramowitz–Stegun 7.1.26 erf polynomial
/// (|error| < 1.5e-7 — far below grid resolution). `std::f64::erf` is
/// unstable, and no external math crate is available offline.
fn std_normal_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    const A1: f64 = 0.254_829_592;
    const A2: f64 = -0.284_496_736;
    const A3: f64 = 1.421_413_741;
    const A4: f64 = -1.453_152_027;
    const A5: f64 = 1.061_405_429;
    const P: f64 = 0.327_591_1;
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + P * x);
    let poly = ((((A5 * t + A4) * t + A3) * t + A2) * t + A1) * t;
    sign * (1.0 - poly * (-x * x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    const EPS: f64 = 1e-9;

    fn grid() -> Grid {
        Grid::uniform(0.0, 20.0, 64)
    }

    fn mass(h: &Hist) -> f64 {
        h.pmf().iter().sum()
    }

    fn random_hist(rng: &mut Rng, grid: &Grid) -> Hist {
        match rng.range_usize(0, 2) {
            0 => Hist::normal(grid, rng.range_f64(1.0, 18.0), rng.range_f64(0.1, 5.0)),
            1 => Hist::point(grid, rng.range_f64(0.0, 20.0)),
            _ => {
                let pmf: Vec<f64> = (0..grid.bins()).map(|_| rng.f64() + 1e-6).collect();
                Hist::from_pmf(grid, &pmf)
            }
        }
    }

    #[test]
    fn erf_matches_known_values() {
        // reference values to 7 decimals
        for (x, want) in [
            (0.0, 0.0),
            (0.5, 0.520_500_0),
            (1.0, 0.842_700_8),
            (2.0, 0.995_322_3),
            (-1.0, -0.842_700_8),
        ] {
            assert!((erf(x) - want).abs() < 2e-6, "erf({x}) = {}", erf(x));
        }
    }

    #[test]
    fn constructors_conserve_mass() {
        let g = grid();
        let mut rng = Rng::new(7);
        for _ in 0..50 {
            let h = random_hist(&mut rng, &g);
            assert!((mass(&h) - 1.0).abs() < EPS, "mass {}", mass(&h));
        }
        // tails clipped by the grid still land on the grid
        let clipped = Hist::normal(&g, 19.0, 8.0);
        assert!((mass(&clipped) - 1.0).abs() < EPS);
        let below = Hist::normal(&g, -5.0, 1.0);
        assert!((mass(&below) - 1.0).abs() < EPS);
    }

    #[test]
    fn normal_recovers_mean_and_std_on_coarse_grid() {
        // regression pin: 64 bins over [0, 20], step ~0.317
        let g = grid();
        let h = Hist::normal(&g, 8.0, 2.0);
        assert!((h.mean() - 8.0).abs() < 0.05, "mean {}", h.mean());
        assert!((h.std() - 2.0).abs() < 0.05, "std {}", h.std());
        // even a 16-bin grid keeps the mean within a bin
        let coarse = Grid::uniform(0.0, 20.0, 16);
        let hc = Hist::normal(&coarse, 8.0, 2.0);
        assert!((hc.mean() - 8.0).abs() < coarse.step(), "mean {}", hc.mean());
    }

    #[test]
    fn point_mass_sits_on_nearest_bin() {
        let g = Grid::uniform(0.0, 10.0, 11);
        let h = Hist::point(&g, 3.2);
        assert!((h.mean() - 3.0).abs() < EPS);
        assert!((h.std() - 0.0).abs() < EPS);
        // clamped outside the grid
        assert!((Hist::point(&g, 42.0).mean() - 10.0).abs() < EPS);
        assert!((Hist::point(&g, -1.0).mean() - 0.0).abs() < EPS);
    }

    #[test]
    fn from_pmf_normalizes_and_handles_degenerate() {
        let g = Grid::uniform(0.0, 3.0, 4);
        let h = Hist::from_pmf(&g, &[2.0, 2.0, 0.0, 0.0]);
        assert!((h.pmf()[0] - 0.5).abs() < EPS);
        assert!((h.mean() - 0.5).abs() < EPS);
        // negatives clamp, zeros degenerate to the pessimistic point mass
        let z = Hist::from_pmf(&g, &[0.0, -1.0, 0.0, 0.0]);
        assert!((z.pmf()[0] - 1.0).abs() < EPS);
        assert!((z.mean() - 0.0).abs() < EPS);
    }

    #[test]
    fn blend_fixed_points_and_convergence() {
        let g = grid();
        let base = Hist::normal(&g, 10.0, 2.0);
        let obs = Hist::point(&g, 4.0);
        // w = 0: untouched
        let mut h = base.clone();
        h.blend(&obs, 0.0);
        for (a, b) in h.pmf().iter().zip(base.pmf()) {
            assert!((a - b).abs() < EPS);
        }
        // w = 1: replaced
        let mut h = base.clone();
        h.blend(&obs, 1.0);
        for (a, b) in h.pmf().iter().zip(obs.pmf()) {
            assert!((a - b).abs() < EPS);
        }
        // repeated absorption converges toward the observation
        let mut h = base.clone();
        for _ in 0..200 {
            h.blend(&obs, 0.1);
            assert!((mass(&h) - 1.0).abs() < EPS);
        }
        assert!((h.mean() - obs.mean()).abs() < 0.01, "mean {}", h.mean());
    }

    #[test]
    fn min_compose_bounded_by_min_of_means() {
        let g = grid();
        let mut rng = Rng::new(11);
        for trial in 0..50 {
            let a = random_hist(&mut rng, &g);
            let b = random_hist(&mut rng, &g);
            let m = a.min_compose(&b);
            assert!((mass(&m) - 1.0).abs() < EPS, "trial {trial}");
            assert!(
                m.mean() <= a.mean().min(b.mean()) + EPS,
                "trial {trial}: E[min] {} vs means {} / {}",
                m.mean(),
                a.mean(),
                b.mean()
            );
        }
    }

    #[test]
    fn min_compose_commutes_and_handles_points() {
        let g = grid();
        let a = Hist::normal(&g, 12.0, 3.0);
        let b = Hist::normal(&g, 6.0, 1.0);
        let ab = a.min_compose(&b);
        let ba = b.min_compose(&a);
        for (x, y) in ab.pmf().iter().zip(ba.pmf()) {
            assert!((x - y).abs() < EPS);
        }
        // min with a far-lower point mass is (nearly) that point mass —
        // up to the ~1e-4 normal mass sitting below it on the grid
        let p = Hist::point(&g, 1.0);
        let m = a.min_compose(&p);
        assert!((m.mean() - p.mean()).abs() < 1e-3, "mean {}", m.mean());
        // min with itself as a point is itself
        let pp = p.min_compose(&p);
        assert!((pp.mean() - p.mean()).abs() < EPS);
    }

    #[test]
    fn expected_max_lower_bounded_by_best_mean() {
        let g = grid();
        let mut rng = Rng::new(13);
        for trial in 0..50 {
            let fam: Vec<Hist> = (0..rng.range_usize(1, 5))
                .map(|_| random_hist(&mut rng, &g))
                .collect();
            let refs: Vec<&Hist> = fam.iter().collect();
            let e = Hist::expected_max(&refs);
            let best = fam.iter().map(|h| h.mean()).fold(f64::NEG_INFINITY, f64::max);
            assert!(e >= best - EPS, "trial {trial}: E[max] {e} < best mean {best}");
            assert!(e <= g.hi() + EPS, "trial {trial}: E[max] {e} off-grid");
        }
    }

    #[test]
    fn expected_max_of_one_is_its_mean() {
        let g = grid();
        let h = Hist::normal(&g, 7.0, 2.5);
        assert!((Hist::expected_max(&[&h]) - h.mean()).abs() < EPS);
    }

    #[test]
    fn expected_max_of_points_is_max() {
        let g = Grid::uniform(0.0, 10.0, 11);
        let a = Hist::point(&g, 3.0);
        let b = Hist::point(&g, 7.0);
        assert!((Hist::expected_max(&[&a, &b]) - 7.0).abs() < EPS);
    }

    #[test]
    fn average_of_mixes_with_matching_mean() {
        let g = grid();
        let a = Hist::normal(&g, 4.0, 1.0);
        let b = Hist::normal(&g, 12.0, 1.0);
        let avg = Hist::average_of(&[&a, &b]);
        assert!((mass(&avg) - 1.0).abs() < EPS);
        let want = 0.5 * (a.mean() + b.mean());
        assert!((avg.mean() - want).abs() < 1e-6, "mean {}", avg.mean());
        // averaging one hist is the identity
        let solo = Hist::average_of(&[&a]);
        for (x, y) in solo.pmf().iter().zip(a.pmf()) {
            assert!((x - y).abs() < EPS);
        }
    }

    #[test]
    fn average_of_mean_exact_and_spread_conservative_vs_convolution() {
        // Documents the ROADMAP note on `average_of`: it is an equal-weight
        // MIXTURE, not the distribution of the per-source sample mean. The
        // exact sample-mean law of (X1 + X2)/2 is brute-forced here on a
        // small grid — every bin pair (i, j) drops mass p_i·q_j on the bin
        // nearest (v_i + v_j)/2 — and the approximation's contract is:
        //   1. mean-exactness: the mixture mean equals the average of the
        //      source means EXACTLY (what the rate model consumes), and
        //      matches the snapped convolution's mean to grid resolution;
        //   2. conservative spread: the mixture std never UNDERSTATES the
        //      sample mean's (averaging concentrates; mixing does not).
        let g = Grid::uniform(0.0, 16.0, 33); // step 0.5
        let cases = [
            (Hist::normal(&g, 4.0, 1.0), Hist::normal(&g, 12.0, 1.0)),
            (Hist::normal(&g, 8.0, 2.0), Hist::normal(&g, 8.0, 2.0)),
            (Hist::point(&g, 3.0), Hist::normal(&g, 10.0, 1.5)),
        ];
        for (idx, (a, b)) in cases.iter().enumerate() {
            let mix = Hist::average_of(&[a, b]);
            // brute-force convolution of the sample mean on the grid
            let mut conv_pmf = vec![0.0f64; g.bins()];
            for i in 0..g.bins() {
                for j in 0..g.bins() {
                    let w = a.pmf()[i] * b.pmf()[j];
                    if w > 0.0 {
                        conv_pmf[g.index_of(0.5 * (g.value(i) + g.value(j)))] += w;
                    }
                }
            }
            let conv = Hist::from_pmf(&g, &conv_pmf);
            let want_mean = 0.5 * (a.mean() + b.mean());
            assert!(
                (mix.mean() - want_mean).abs() < 1e-9,
                "case {idx}: mixture mean {} != averaged source means {want_mean}",
                mix.mean()
            );
            // the snapped convolution can only drift by the bin-rounding
            assert!(
                (conv.mean() - want_mean).abs() <= 0.5 * g.step() + 1e-9,
                "case {idx}: convolution mean {} vs {want_mean}",
                conv.mean()
            );
            assert!(
                mix.std() + 1e-9 >= conv.std(),
                "case {idx}: mixture std {} understates sample-mean std {}",
                mix.std(),
                conv.std()
            );
        }
        // distant equal-spread sources: the gap is large and one-sided —
        // mixture keeps the full between-source spread (~4.1) while the
        // true sample mean concentrates to ~0.71
        let (a, b) = &cases[0];
        let mix = Hist::average_of(&[a, b]);
        assert!(mix.std() > 3.5, "mixture spread collapsed: {}", mix.std());
    }

    #[test]
    fn cdf_is_monotone_and_reaches_one() {
        let g = grid();
        let mut rng = Rng::new(17);
        for _ in 0..20 {
            let h = random_hist(&mut rng, &g);
            let cdf = h.cdf();
            let mut prev = 0.0;
            for &c in &cdf {
                assert!(c + EPS >= prev && c <= 1.0 + EPS);
                prev = c;
            }
            assert!((cdf[g.bins() - 1] - 1.0).abs() < EPS);
        }
    }

    #[test]
    #[should_panic]
    fn blend_rejects_grid_mismatch() {
        let a = Grid::uniform(0.0, 10.0, 16);
        let b = Grid::uniform(0.0, 10.0, 32);
        let mut h = Hist::point(&a, 5.0);
        h.blend(&Hist::point(&b, 5.0), 0.5);
    }

    #[test]
    #[should_panic]
    fn min_compose_rejects_grid_mismatch() {
        let a = Grid::uniform(0.0, 10.0, 16);
        let b = Grid::uniform(0.0, 12.0, 16);
        let _ = Hist::point(&a, 5.0).min_compose(&Hist::point(&b, 5.0));
    }
}
