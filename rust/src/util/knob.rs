//! Environment-backed configuration knobs.
//!
//! One generic parser replaces the per-knob copy-pasted pairs that used to
//! live in `config::spec` (`parse_score_threads`/`default_score_threads`,
//! `parse_engine_threads`/`default_engine_threads`). Every knob is composed
//! from a *value parser* (`&str -> Option<T>`, e.g. [`thread_count`] or
//! [`switch`]) plus the name the error or warning should carry, and comes
//! in two failure disciplines:
//!
//! * **Fallible** ([`try_knob`], [`try_env_knob`]) — the CLI discipline.
//!   Absent or empty input is `Ok(None)` (the caller applies its default);
//!   garbage is `Err` naming the flag or env var, so a typo'd
//!   `--score-threads=lots` dies with `error: --score-threads: invalid
//!   value \`lots\`` instead of a backtrace or a silent fallback.
//! * **Total** ([`parse_knob`], [`env_knob`]) — the defaults discipline,
//!   for `Default::default()` paths that cannot propagate a `Result`.
//!   Garbage degrades to the documented fallback, but no longer silently:
//!   `env_knob` logs a warning naming the variable.
//!
//! ```ignore
//! let threads = knob::try_knob("--score-threads", args.get("score-threads"),
//!                              knob::thread_count)?.unwrap_or(1);
//! let default = knob::env_knob("PINGAN_SCORE_THREADS", knob::thread_count, 1);
//! ```

/// Fallible knob parse: `Ok(None)` when the input is absent or empty
/// after trimming, `Ok(Some(v))` on success, `Err` naming the knob on
/// garbage. The error shape matches `util::cli`'s flag errors so every
/// `--*` flag and env var rejects bad input the same way.
pub fn try_knob<T>(
    name: &str,
    s: Option<&str>,
    parse: fn(&str) -> Option<T>,
) -> Result<Option<T>, String> {
    match s.map(str::trim).filter(|t| !t.is_empty()) {
        None => Ok(None),
        Some(t) => parse(t)
            .map(Some)
            .ok_or_else(|| format!("{name}: invalid value `{t}`")),
    }
}

/// Read knob `var` from the environment fallibly; the error names the
/// variable. An unset variable is `Ok(None)`.
pub fn try_env_knob<T>(var: &str, parse: fn(&str) -> Option<T>) -> Result<Option<T>, String> {
    match std::env::var(var) {
        Ok(v) => try_knob(var, Some(&v), parse),
        Err(_) => Ok(None),
    }
}

/// Parse an optional knob string with `parse`, falling back on absent,
/// empty-after-trim, or unparsable input. Total: never errors. Prefer
/// [`try_knob`] on CLI paths, where the user can actually be told.
pub fn parse_knob<T>(s: Option<&str>, parse: fn(&str) -> Option<T>, fallback: T) -> T {
    s.and_then(|x| parse(x.trim())).unwrap_or(fallback)
}

/// Read knob `var` from the environment, degrading to `fallback` — with a
/// logged warning naming the variable — on unparsable input. An unset
/// variable falls back silently (that is the normal case).
pub fn env_knob<T>(var: &str, parse: fn(&str) -> Option<T>, fallback: T) -> T {
    match try_env_knob(var, parse) {
        Ok(Some(v)) => v,
        Ok(None) => fallback,
        Err(e) => {
            log::warn!("{e}; using the default");
            fallback
        }
    }
}

/// Value parser for thread-count knobs: a positive integer. Zero is
/// rejected (callers fall back to serial) — thread budgets are ≥ 1 by
/// contract everywhere in the engine.
pub fn thread_count(s: &str) -> Option<usize> {
    s.parse::<usize>().ok().filter(|&t| t >= 1)
}

/// Value parser for boolean switches: `1`/`true`/`on`/`yes` and
/// `0`/`false`/`off`/`no`, case-insensitive. Anything else falls back.
pub fn switch(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_is_total_and_falls_back() {
        assert_eq!(parse_knob(None, thread_count, 1), 1);
        assert_eq!(parse_knob(Some(""), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("  "), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("abc"), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("0"), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("-3"), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("4"), thread_count, 1), 4);
        assert_eq!(parse_knob(Some(" 8 "), thread_count, 1), 8);
    }

    #[test]
    fn try_knob_absent_is_none_garbage_is_named_error() {
        assert_eq!(try_knob("--x", None, thread_count), Ok(None));
        assert_eq!(try_knob("--x", Some(""), thread_count), Ok(None));
        assert_eq!(try_knob("--x", Some("  "), thread_count), Ok(None));
        assert_eq!(try_knob("--x", Some(" 4 "), thread_count), Ok(Some(4)));
        assert_eq!(
            try_knob("--score-threads", Some("lots"), thread_count),
            Err("--score-threads: invalid value `lots`".into())
        );
        assert_eq!(
            try_knob("PINGAN_STREAM_METRICS", Some("maybe"), switch),
            Err("PINGAN_STREAM_METRICS: invalid value `maybe`".into())
        );
    }

    #[test]
    fn switch_accepts_common_spellings() {
        for on in ["1", "true", "on", "yes", "TRUE", "On", "YES"] {
            assert_eq!(switch(on), Some(true), "{on}");
        }
        for off in ["0", "false", "off", "no", "False"] {
            assert_eq!(switch(off), Some(false), "{off}");
        }
        assert_eq!(switch("maybe"), None);
        assert!(!parse_knob(Some("maybe"), switch, false));
        assert!(parse_knob(Some("maybe"), switch, true));
    }

    #[test]
    fn env_knob_reads_and_falls_back() {
        // unset → fallback (no unsafe env mutation in tests; the var name
        // is namespaced so nothing in CI sets it)
        assert_eq!(env_knob("PINGAN_KNOB_TEST_UNSET_XYZ", thread_count, 7), 7);
        assert_eq!(try_env_knob("PINGAN_KNOB_TEST_UNSET_XYZ", thread_count), Ok(None));
    }
}
