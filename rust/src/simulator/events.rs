//! The event queue behind the event-skip time core: a `BinaryHeap` of
//! timestamped [`Event`]s with fully deterministic ordering.
//!
//! Events at the same slot drain in the dense engine's within-slot phase
//! order — arrivals, then cluster failures, then copy completions, then
//! policy wakes — and ties inside a phase break on the event's own indices
//! and finally on insertion order, so two runs of the same seed pop the
//! exact same sequence regardless of heap internals. (Note: the *policy
//! epoch* itself runs after the slot's completions are applied, so a
//! scheduler at event-time t sees what the dense scheduler would first
//! see at t+1 — see `engine::run_events`.)

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One schedulable occurrence. `CopyCompletion` carries the task's copy-set
/// epoch at push time: any change to the copy set bumps the epoch and
/// re-pushes, so stale predictions are skipped on pop instead of searched
/// for and removed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// A job reaches its arrival slot.
    Arrival { job: usize },
    /// Cluster `cluster`'s sampled geometric failure gap elapses.
    ClusterFailure { cluster: usize },
    /// Task (`job`, `task`)'s fastest alive copy finishes its datasize.
    CopyCompletion { job: usize, task: usize, epoch: u64 },
    /// A scheduler-requested wake ([`crate::sched::Scheduler::next_wake`]).
    PolicyEpoch,
}

impl Event {
    /// Within-slot phase rank (the dense engine's step order).
    fn rank(&self) -> u8 {
        match self {
            Event::Arrival { .. } => 0,
            Event::ClusterFailure { .. } => 1,
            Event::CopyCompletion { .. } => 2,
            Event::PolicyEpoch => 3,
        }
    }

    /// Intra-phase tie-break indices.
    fn keys(&self) -> (usize, usize, u64) {
        match *self {
            Event::Arrival { job } => (job, 0, 0),
            Event::ClusterFailure { cluster } => (cluster, 0, 0),
            Event::CopyCompletion { job, task, epoch } => (job, task, epoch),
            Event::PolicyEpoch => (0, 0, 0),
        }
    }
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    time: u64,
    seq: u64,
    event: Event,
}

impl Entry {
    fn key(&self) -> (u64, u8, usize, usize, u64, u64) {
        let (a, b, e) = self.event.keys();
        (self.time, self.event.rank(), a, b, e, self.seq)
    }
}

impl PartialEq for Entry {
    fn eq(&self, other: &Entry) -> bool {
        self.key() == other.key()
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    /// Reversed so `BinaryHeap` (a max-heap) pops the earliest entry.
    fn cmp(&self, other: &Entry) -> Ordering {
        other.key().cmp(&self.key())
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Entry) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-queue of future events.
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute slot `time`.
    pub fn push(&mut self, time: u64, event: Event) {
        self.heap.push(Entry {
            time,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Earliest scheduled slot, if any.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the next event *only* if it is scheduled exactly at `time` —
    /// the engine drains one slot's batch with `while let Some(ev) =
    /// queue.pop_at(t)`.
    pub fn pop_at(&mut self, time: u64) -> Option<Event> {
        if self.heap.peek().map(|e| e.time) == Some(time) {
            self.heap.pop().map(|e| e.event)
        } else {
            None
        }
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(9, Event::PolicyEpoch);
        q.push(3, Event::Arrival { job: 1 });
        q.push(7, Event::ClusterFailure { cluster: 0 });
        assert_eq!(q.peek_time(), Some(3));
        assert_eq!(q.pop_at(3), Some(Event::Arrival { job: 1 }));
        assert_eq!(q.pop_at(3), None, "nothing else at slot 3");
        assert_eq!(q.peek_time(), Some(7));
    }

    #[test]
    fn same_slot_drains_in_dense_phase_order() {
        let mut q = EventQueue::new();
        q.push(5, Event::PolicyEpoch);
        q.push(
            5,
            Event::CopyCompletion {
                job: 0,
                task: 2,
                epoch: 1,
            },
        );
        q.push(5, Event::ClusterFailure { cluster: 3 });
        q.push(5, Event::Arrival { job: 4 });
        assert_eq!(q.pop_at(5), Some(Event::Arrival { job: 4 }));
        assert_eq!(q.pop_at(5), Some(Event::ClusterFailure { cluster: 3 }));
        assert_eq!(
            q.pop_at(5),
            Some(Event::CopyCompletion {
                job: 0,
                task: 2,
                epoch: 1
            })
        );
        assert_eq!(q.pop_at(5), Some(Event::PolicyEpoch));
        assert!(q.is_empty());
    }

    #[test]
    fn intra_phase_ties_break_on_indices_then_insertion() {
        let mut q = EventQueue::new();
        q.push(2, Event::Arrival { job: 7 });
        q.push(2, Event::Arrival { job: 1 });
        q.push(2, Event::Arrival { job: 1 }); // duplicate: insertion order
        assert_eq!(q.pop_at(2), Some(Event::Arrival { job: 1 }));
        assert_eq!(q.pop_at(2), Some(Event::Arrival { job: 1 }));
        assert_eq!(q.pop_at(2), Some(Event::Arrival { job: 7 }));
    }

    #[test]
    fn ordering_is_deterministic_across_interleavings() {
        // two different push orders, same pop sequence
        let evs = [
            (4, Event::CopyCompletion { job: 1, task: 0, epoch: 2 }),
            (4, Event::Arrival { job: 0 }),
            (1, Event::PolicyEpoch),
            (4, Event::ClusterFailure { cluster: 2 }),
        ];
        let mut a = EventQueue::new();
        for &(t, e) in &evs {
            a.push(t, e);
        }
        let mut b = EventQueue::new();
        for &(t, e) in evs.iter().rev() {
            b.push(t, e);
        }
        for _ in 0..evs.len() {
            let t = a.peek_time().unwrap();
            assert_eq!(b.peek_time(), Some(t));
            assert_eq!(a.pop_at(t), b.pop_at(t));
        }
    }
}
