//! Algorithm 1 — the PingAn insurer as a [`Scheduler`].
//!
//! Scoring architecture (the batched hot path): within one scheduling
//! slot the view's job/task state is frozen — launches apply only after
//! `schedule` returns — so every (task, candidate) score is invariant
//! across the slot's rounds. The insurer exploits that: each round
//! collects its not-yet-scored tasks into one [`ScoreBatch`] (every
//! admissible candidate cluster per task), runs it through a pluggable
//! [`Scorer`] backend, and memoizes the resulting [`CandidateScore`]s in
//! the per-slot [`SlotCache`]. `try_insure` then only filters the cached
//! scores against the live slot/bandwidth ledgers. The `CpuScorer`
//! backend is bit-identical to the scalar `dist::Hist` algebra (see
//! `runtime::scorer`), so batching cannot flip an admission decision;
//! `--scorer scalar` keeps the per-candidate reference path alive for
//! agreement tests and benches.
//!
//! Intra-slot parallelism: when the engine grants a thread budget
//! (`SchedView::score_threads` > 1, from `SimConfig::score_threads`),
//! the round batch's rows are sharded into contiguous ranges and scored
//! on a `std::thread::scope` pool through
//! `runtime::scorer::score_rows_sharded`, each shard filling its own
//! reusable scratch `ScoreBatch`. Shard outputs merge back into the
//! per-slot score tables in row order, so admissions are **bit-identical
//! at any thread count** — the same guarantee the sweep runner makes
//! across cells, proven by the determinism suite over both time models
//! and scorer backends.

use std::sync::Arc;
use std::time::Instant;

use super::scoring::{self, CandidateScore};
use crate::config::spec::{Allocation, PingAnSpec, Principle, ScorerKind};
use crate::dist::Hist;
use crate::obs::{Counters, SpanKind, Spans, TraceRecord, TraceSink};
use crate::perfmodel::PerfModel;
use crate::runtime::{scorer, CpuScorer, ScoreBatch, Scorer};
use crate::sched::{Action, Assignment, SchedView, Scheduler};
use crate::workload::job::OpKind;

/// Which criterion a round optimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Criterion {
    Efficiency,
    Reliability,
}

/// Everything the insurer knows about one task within one slot. Solo
/// rates, the frozen copy set and its CDF product, the flat pmf tensors
/// the batched scorer consumes, and — once the round batch ran — the
/// all-cluster candidate scores. None of it changes within the slot, so
/// the round structure reads it O(1) instead of recomputing per visit.
struct TaskSlotState {
    /// Per-cluster (solo rate E[r(1)], composed rate hist).
    solo: Vec<(f64, Hist)>,
    /// [n_clusters * V] processing pmfs on the model grid.
    proc_pmf: Vec<f64>,
    /// [n_clusters * V] source-averaged transfer pmfs.
    trans_pmf: Vec<f64>,
    /// No sources → the rate pmf is the proc pmf alone.
    proc_only: bool,
    /// E^O[r(1)]: the task's global-best solo rate (round-1 floor).
    global_best: f64,
    /// Clusters hosting alive copies at slot start (frozen).
    existing_clusters: Vec<usize>,
    /// [V] product of the existing copies' CDFs (ones when no copies).
    existing_cdf: Vec<f64>,
    /// E[max] over the existing copy set (0.0 when no copies).
    current_rate: f64,
    /// All-cluster candidate scores, filled by the round's score batch.
    scores: Option<Vec<CandidateScore>>,
}

/// Per-slot memo: estimates shift as the modeler absorbs logs, but within
/// one slot everything scoring reads is frozen — caching turns the inner
/// loop from O(rounds × clusters × V) into O(clusters × V) per task.
#[derive(Default)]
struct SlotCache {
    tasks: std::collections::HashMap<(usize, usize), TaskSlotState>,
}

/// The scoring engine behind `try_insure`.
enum ScoreBackend {
    /// Per-candidate `dist::Hist` reference (`--scorer scalar`).
    Scalar,
    /// Batched backend: `CpuScorer` (default) or `HloScorer` (`pjrt`).
    Batched(Box<dyn Scorer>),
}

/// The PingAn insurance scheduler.
pub struct PingAn {
    spec: PingAnSpec,
    name: String,
    cache: SlotCache,
    backend: ScoreBackend,
    /// Reusable per-shard scratch batches — grown to the engine's thread
    /// budget on first use, then one allocation set for the whole run
    /// (`scratch[0]` doubles as the serial batch when the budget is 1).
    scratch: Vec<ScoreBatch>,
    /// Plane-A decision counters: rounds, rows scored, admissions and
    /// rejections by reason. Pure integer bumps on paths the insurer
    /// already takes — they can never perturb an admission decision.
    counters: Counters,
    /// Plane-B span sink, handed over by the engine at run start
    /// (`Scheduler::attach_spans`). `None` ⇒ zero clock reads.
    spans: Option<Arc<Spans>>,
    /// Opt-in per-decision trace (`--trace-file`). Write-only observer:
    /// records are emitted after each admit/reject is already decided.
    trace: Option<TraceSink>,
}

/// Per-candidate scalar scoring over ALL clusters (the `--scorer scalar`
/// reference). The pre-batching path scored only the currently-free
/// subset, but scores depend solely on frozen slot state, so computing
/// the full vector once per slot and filtering at use time yields the
/// same admissible sets in the same order.
fn scalar_scores(model: &PerfModel, st: &TaskSlotState, datasize: f64) -> Vec<CandidateScore> {
    let existing: Vec<Hist> = st
        .existing_clusters
        .iter()
        .map(|&m| st.solo[m].1.clone())
        .collect();
    let all: Vec<usize> = (0..st.solo.len()).collect();
    scoring::score_candidates_cached(
        model,
        datasize,
        &st.solo,
        &existing,
        &st.existing_clusters,
        &all,
    )
}

impl PingAn {
    /// Build an insurer, or explain why the spec (or its scorer backend)
    /// cannot be constructed — the sweep runner records this per cell.
    pub fn try_new(spec: PingAnSpec) -> Result<PingAn, String> {
        spec.validate()?;
        let backend = match spec.scorer {
            ScorerKind::Scalar => ScoreBackend::Scalar,
            ScorerKind::Cpu => ScoreBackend::Batched(Box::new(CpuScorer)),
            ScorerKind::Hlo => Self::hlo_backend()?,
        };
        let scorer_tag = match spec.scorer {
            ScorerKind::Cpu => String::new(),
            other => format!(",{}", other.name()),
        };
        let name = format!(
            "pingan(eps={},{},{}{})",
            spec.epsilon,
            spec.principle.name(),
            spec.allocation.name(),
            scorer_tag
        );
        Ok(PingAn {
            spec,
            name,
            cache: SlotCache::default(),
            backend,
            scratch: Vec::new(),
            counters: Counters::default(),
            spans: None,
            trace: None,
        })
    }

    pub fn new(spec: PingAnSpec) -> PingAn {
        PingAn::try_new(spec).unwrap_or_else(|e| panic!("invalid PingAnSpec: {e}"))
    }

    pub fn with_epsilon(epsilon: f64) -> PingAn {
        PingAn::new(PingAnSpec::with_epsilon(epsilon))
    }

    #[cfg(feature = "pjrt")]
    fn hlo_backend() -> Result<ScoreBackend, String> {
        let engine =
            crate::runtime::Engine::new("artifacts").map_err(|e| format!("hlo scorer: {e:#}"))?;
        let hlo =
            crate::runtime::HloScorer::new(&engine).map_err(|e| format!("hlo scorer: {e:#}"))?;
        Ok(ScoreBackend::Batched(Box::new(hlo)))
    }

    #[cfg(not(feature = "pjrt"))]
    fn hlo_backend() -> Result<ScoreBackend, String> {
        Err("scorer `hlo` needs a build with `--features pjrt`".into())
    }

    pub fn spec(&self) -> &PingAnSpec {
        &self.spec
    }

    fn round_criterion(&self, round: usize) -> Criterion {
        match (round, self.spec.principle) {
            (1, Principle::EffReli) | (1, Principle::EffEff) => Criterion::Efficiency,
            (1, _) => Criterion::Reliability,
            (2, Principle::EffReli) | (2, Principle::ReliReli) => Criterion::Reliability,
            (2, _) => Criterion::Efficiency,
            // rounds >= 3 always efficiency-first + resource-saving rule
            _ => Criterion::Efficiency,
        }
    }

    /// Compute (or fetch) the task's frozen per-slot scoring state: solo
    /// rates and hists for every cluster, the pmf tensors the batch rows
    /// copy from, and the existing-copy CDF product. `op` is threaded in
    /// from the caller's spec lookup — it selects the proc histograms.
    fn task_state<'c>(
        cache: &'c mut SlotCache,
        view: &SchedView<'_>,
        job: usize,
        task: usize,
        op: OpKind,
    ) -> &'c mut TaskSlotState {
        cache.tasks.entry((job, task)).or_insert_with(|| {
            let rt = &view.jobs[job].tasks[task];
            let n = view.system.n();
            let grid = view.model.grid();
            let v = grid.bins();
            let proc_only = rt.sources.is_empty();
            let mut solo = Vec::with_capacity(n);
            let mut proc_pmf = vec![0.0f64; n * v];
            let mut trans_pmf = vec![0.0f64; n * v];
            for m in 0..n {
                let (p, t_avg) = view.model.rate_components(&rt.sources, m, op);
                proc_pmf[m * v..(m + 1) * v].copy_from_slice(p.pmf());
                let h = match &t_avg {
                    Some(t) => {
                        trans_pmf[m * v..(m + 1) * v].copy_from_slice(t.pmf());
                        p.min_compose(t)
                    }
                    None => p.clone(),
                };
                solo.push((h.mean(), h));
            }
            let global_best = solo.iter().map(|(r, _)| *r).fold(0.0, f64::max);
            let existing_clusters = rt.copy_clusters();
            let ex_refs: Vec<&Hist> = existing_clusters.iter().map(|&m| &solo[m].1).collect();
            let (existing_cdf, current_rate) =
                scoring::existing_cdf_and_rate(&ex_refs, grid.values());
            TaskSlotState {
                solo,
                proc_pmf,
                trans_pmf,
                proc_only,
                global_best,
                existing_clusters,
                existing_cdf,
                current_rate,
                scores: None,
            }
        })
    }

    /// Score every not-yet-scored task in `tasks` through the batched
    /// backend: tasks with existing copies become rows of ONE
    /// [`ScoreBatch`] (every cluster as a candidate); tasks without
    /// copies take the solo fast path — their combined rate is the solo
    /// rate by definition, exactly as in the scalar branch.
    fn score_batch(&mut self, view: &SchedView<'_>, tasks: &[(usize, usize)]) {
        let mut rows: Vec<(usize, usize)> = Vec::new();
        for &(ji, ti) in tasks {
            let spec_task = &view.jobs[ji].spec.tasks[ti];
            let (op, datasize) = (spec_task.op, spec_task.datasize);
            let st = Self::task_state(&mut self.cache, view, ji, ti, op);
            if st.scores.is_some() {
                continue;
            }
            if st.existing_clusters.is_empty() {
                let scores = (0..st.solo.len())
                    .map(|m| {
                        scoring::assemble_score(
                            view.model,
                            &st.existing_clusters,
                            m,
                            datasize,
                            st.solo[m].0,
                            None,
                        )
                    })
                    .collect();
                st.scores = Some(scores);
            } else {
                rows.push((ji, ti));
            }
        }
        if rows.is_empty() {
            return;
        }
        let n = view.system.n();
        let grid = view.model.grid();
        let ScoreBackend::Batched(backend) = &self.backend else {
            unreachable!("score_batch is only called with a batched backend");
        };
        self.counters.rows_scored += (rows.len() * n) as u64;
        // Borrow the cached flat tensors per row; score sharded across the
        // engine's thread budget. Shard boundaries and output order are
        // pure functions of the row list, so `rates` is bit-identical at
        // any `score_threads` (see `runtime::scorer::score_rows_sharded`).
        let t_fill = self.spans.as_ref().map(|_| Instant::now());
        let inputs: Vec<scorer::RowInput<'_>> = rows
            .iter()
            .map(|key| {
                let st = &self.cache.tasks[key];
                scorer::RowInput {
                    proc: &st.proc_pmf,
                    trans: &st.trans_pmf,
                    proc_only: st.proc_only,
                    existing_cdf: &st.existing_cdf,
                }
            })
            .collect();
        if let (Some(sp), Some(t0)) = (self.spans.as_ref(), t_fill) {
            sp.record(SpanKind::BatchFill, t0.elapsed());
        }
        let t_exec = self.spans.as_ref().map(|_| Instant::now());
        let rates = scorer::score_rows_sharded(
            backend.as_ref(),
            n,
            grid.bins(),
            grid.values(),
            &inputs,
            view.score_threads,
            &mut self.scratch,
        )
        .unwrap_or_else(|e| panic!("scorer `{}` failed: {e:#}", backend.name()));
        if let (Some(sp), Some(t0)) = (self.spans.as_ref(), t_exec) {
            sp.record(SpanKind::BatchExec, t0.elapsed());
        }
        for (bi, &(ji, ti)) in rows.iter().enumerate() {
            let datasize = view.jobs[ji].spec.tasks[ti].datasize;
            let st = self.cache.tasks.get_mut(&(ji, ti)).expect("row state exists");
            let scores = (0..n)
                .map(|m| {
                    scoring::assemble_score(
                        view.model,
                        &st.existing_clusters,
                        m,
                        datasize,
                        st.solo[m].0,
                        Some(rates[bi * n + m]),
                    )
                })
                .collect();
            st.scores = Some(scores);
        }
    }

    /// Guarantee `(job, task)` has cached scores: the round batch usually
    /// prefilled them; the scalar backend (and any stragglers, as a B=1
    /// batch) score here on demand.
    fn ensure_scored(&mut self, view: &SchedView<'_>, job: usize, task: usize, datasize: f64) {
        let op = view.jobs[job].spec.tasks[task].op;
        let scored = Self::task_state(&mut self.cache, view, job, task, op)
            .scores
            .is_some();
        if scored {
            return;
        }
        if matches!(self.backend, ScoreBackend::Scalar) {
            let st = self.cache.tasks.get_mut(&(job, task)).expect("state above");
            let scores = scalar_scores(view.model, st, datasize);
            self.counters.rows_scored += scores.len() as u64;
            st.scores = Some(scores);
        } else {
            self.score_batch(view, &[(job, task)]);
        }
    }

    /// Emit one decision-trace record (no-op without `--trace-file`).
    /// Called strictly *after* the admit/reject decision is made, so the
    /// sink observes the Action stream without ever influencing it.
    fn trace_decision(
        &self,
        now: u64,
        job: usize,
        task: usize,
        s: &CandidateScore,
        reason: &'static str,
    ) {
        if let Some(sink) = &self.trace {
            sink.emit(
                &TraceRecord {
                    slot: now,
                    job,
                    task,
                    cluster: s.cluster,
                    solo_rate: s.solo_rate,
                    rate: s.rate,
                    pro: s.pro,
                    reason,
                }
                .to_json(),
            );
        }
    }

    /// Try to insure one copy of (`job`,`task`) under `criterion`; mutates
    /// the view's ledgers on success. `round` selects admission rules.
    fn try_insure(
        &mut self,
        view: &mut SchedView<'_>,
        job: usize,
        task: usize,
        criterion: Criterion,
        round: usize,
        out: &mut Vec<Action>,
    ) -> bool {
        let datasize = view.jobs[job].spec.tasks[task].datasize;
        let rt = &view.jobs[job].tasks[task];
        let sources = rt.sources.clone();
        let n_existing = rt.copy_clusters().len();
        if n_existing >= self.spec.max_copies {
            return false;
        }
        // candidates: clusters with free slots at this moment (scores are
        // slot-frozen; only this filter sees the live ledgers)
        let candidates: Vec<usize> = (0..view.system.n())
            .filter(|&m| view.free_slots[m] > 0)
            .collect();
        if candidates.is_empty() {
            return false;
        }
        self.ensure_scored(view, job, task, datasize);
        let st = &self.cache.tasks[&(job, task)];
        let global_best = st.global_best;
        let current_rate = st.current_rate;
        let scores = st.scores.as_ref().expect("ensure_scored filled scores");
        let cand_scores: Vec<&CandidateScore> = candidates.iter().map(|&m| &scores[m]).collect();
        // admission filters, then criterion ordering
        let mut admissible: Vec<&CandidateScore> = Vec::with_capacity(cand_scores.len());
        for s in cand_scores.iter().copied() {
            if scoring::passes_rate_floor(s.solo_rate, global_best, self.spec.epsilon) {
                admissible.push(s);
            } else {
                self.counters.rej_rate_floor += 1;
                self.trace_decision(view.now, job, task, s, "rate-floor");
            }
        }
        if admissible.is_empty() {
            log::debug!(
                "task ({job},{task}): no admissible cluster (best solo {:.3} vs floor {:.3}, {} candidates)",
                cand_scores.iter().map(|s| s.solo_rate).fold(0.0, f64::max),
                global_best / (1.0 + self.spec.epsilon),
                cand_scores.len()
            );
            return false;
        }
        match criterion {
            Criterion::Efficiency => {
                admissible.sort_by(|a, b| b.rate.partial_cmp(&a.rate).unwrap());
            }
            Criterion::Reliability => {
                admissible.sort_by(|a, b| b.pro.partial_cmp(&a.pro).unwrap());
            }
        }
        let (mut rej_saving, mut rej_slot, mut rej_bw) = (0u32, 0u32, 0u32);
        for s in admissible {
            // resource-saving admission for the 3rd+ copy (Sec 4.1)
            if round >= 3 || n_existing >= 2 {
                let c = n_existing; // deciding the (c+1)-th copy; paper's c >= 2
                if !scoring::resource_saving_ok(datasize, current_rate, s.rate, c.max(2)) {
                    rej_saving += 1;
                    self.counters.rej_saving += 1;
                    self.trace_decision(view.now, job, task, s, "saving");
                    continue;
                }
            }
            if !view.try_reserve_slot(s.cluster) {
                rej_slot += 1;
                self.counters.rej_slot += 1;
                self.trace_decision(view.now, job, task, s, "slot");
                continue;
            }
            let reserved = if n_existing == 0 {
                view.try_reserve_bandwidth(&sources, s.cluster, s.solo_rate)
            } else {
                view.try_reserve_bandwidth_full(&sources, s.cluster, s.solo_rate)
            };
            if !reserved {
                // roll the slot back and try the next candidate
                view.free_slots[s.cluster] += 1;
                rej_bw += 1;
                self.counters.rej_bw += 1;
                self.trace_decision(view.now, job, task, s, "bw");
                log::debug!(
                    "  bw reject: cluster {} rate {:.1} ing_free {:.1} sources {:?} eg_free {:?}",
                    s.cluster,
                    s.solo_rate,
                    view.ingress_free[s.cluster],
                    sources,
                    sources.iter().map(|&x| view.egress_free[x]).collect::<Vec<_>>()
                );
                continue;
            }
            self.counters.admissions += 1;
            self.trace_decision(view.now, job, task, s, "admit");
            out.push(Action::Launch(Assignment {
                job,
                task,
                cluster: s.cluster,
            }));
            return true;
        }
        log::debug!(
            "task ({job},{task}) round {round}: rejected everywhere (saving {rej_saving}, slot {rej_slot}, bw {rej_bw})"
        );
        false
    }

    /// One EFA round over `prior` jobs. Returns slots assigned.
    #[allow(clippy::too_many_arguments)]
    fn run_round(
        &mut self,
        view: &mut SchedView<'_>,
        prior: &[usize],
        budget: &mut Vec<usize>, // h_i - θ_i per prior index
        round: usize,
        copied_last_round: &mut Vec<Vec<(usize, usize)>>,
        out: &mut Vec<Action>,
    ) -> usize {
        self.counters.insurer_rounds += 1;
        let criterion = self.round_criterion(round);
        // pass 1 — target lists. view.jobs is frozen within the slot
        // (launches apply after schedule returns) and budget[pi] only
        // moves in job pi's own iteration, so collecting the lists up
        // front is identical to the old lazy per-job computation.
        let mut per_job: Vec<Vec<(usize, usize)>> = Vec::with_capacity(prior.len());
        for (pi, &ji) in prior.iter().enumerate() {
            if budget[pi] == 0 {
                per_job.push(Vec::new());
                continue;
            }
            let targets: Vec<(usize, usize)> = match round {
                1 => view
                    .ready_tasks(ji)
                    .into_iter()
                    .map(|t| (ji, t))
                    .collect(),
                2 => {
                    // running tasks ordered by ascending pro (worst first)
                    let mut ts: Vec<(f64, (usize, usize))> = view
                        .running_tasks(ji)
                        .into_iter()
                        .map(|t| {
                            let rt = &view.jobs[ji].tasks[t];
                            let spec = &view.jobs[ji].spec.tasks[t];
                            let clusters = rt.copy_clusters();
                            let rate = view
                                .model
                                .exp_rate1(&rt.sources, clusters[0], spec.op)
                                .max(1e-9);
                            let pro = view.model.pro(&clusters, spec.datasize, rate);
                            (pro, (ji, t))
                        })
                        .collect();
                    ts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    ts.into_iter().map(|(_, t)| t).collect()
                }
                _ => std::mem::take(&mut copied_last_round[pi]),
            };
            per_job.push(targets);
        }
        // pass 2 — ONE score batch for every (task, candidate) pair the
        // round can touch (already-scored and copy-capped tasks drop out;
        // the scalar reference scores lazily inside try_insure instead)
        if matches!(self.backend, ScoreBackend::Batched(_)) {
            let fresh: Vec<(usize, usize)> = per_job
                .iter()
                .flatten()
                .filter(|&&(ji, ti)| {
                    view.jobs[ji].tasks[ti].copy_clusters().len() < self.spec.max_copies
                })
                .copied()
                .collect();
            self.score_batch(view, &fresh);
        }
        // pass 3 — the assignment sweep (semantics unchanged)
        let mut assigned = 0usize;
        for (pi, targets) in per_job.iter_mut().enumerate() {
            if budget[pi] == 0 {
                continue;
            }
            let mut copied_now: Vec<(usize, usize)> = Vec::new();
            for (ji, ti) in targets.drain(..) {
                if budget[pi] == 0 {
                    break;
                }
                if self.try_insure(view, ji, ti, criterion, round, out) {
                    budget[pi] -= 1;
                    assigned += 1;
                    copied_now.push((ji, ti));
                }
            }
            copied_last_round[pi] = copied_now;
        }
        assigned
    }
}

impl Scheduler for PingAn {
    fn name(&self) -> &str {
        &self.name
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        let mut out: Vec<Action> = Vec::new();
        // estimates shift as the modeler absorbs logs: memoize within the
        // slot only
        self.cache.tasks.clear();
        let n_alive = view.alive.len();
        if n_alive == 0 {
            return out;
        }
        // 1. job priority: ascending unprocessed datasize
        let mut order: Vec<usize> = view.alive.to_vec();
        order.sort_by(|&a, &b| {
            view.unprocessed(a)
                .partial_cmp(&view.unprocessed(b))
                .unwrap()
                .then(a.cmp(&b))
        });
        // 2. the first ⌈εN⌉ jobs share the plant
        let n_prior = ((self.spec.epsilon * n_alive as f64).ceil() as usize)
            .clamp(1, n_alive);
        let prior: Vec<usize> = order[..n_prior].to_vec();
        let total_slots: usize = view.system.total_slots();
        let h = (total_slots / n_prior).max(1);
        // θ_i: slots already running this job's copies
        let mut budget: Vec<usize> = prior
            .iter()
            .map(|&ji| {
                let theta: usize = view.jobs[ji]
                    .tasks
                    .iter()
                    .map(|t| t.alive_copies())
                    .sum();
                h.saturating_sub(theta)
            })
            .collect();
        let mut copied_last: Vec<Vec<(usize, usize)>> = vec![Vec::new(); prior.len()];

        log::debug!(
            "t={}: alive {}, prior {:?}, budgets {:?}, ready {:?}, free {}",
            view.now,
            n_alive,
            prior,
            budget,
            prior.iter().map(|&j| view.ready_tasks(j).len()).collect::<Vec<_>>(),
            view.total_free()
        );
        match self.spec.allocation {
            Allocation::Efa => {
                // rounds sweep across all prior jobs (the paper's EFA)
                let mut round = 1usize;
                loop {
                    let assigned =
                        self.run_round(view, &prior, &mut budget, round, &mut copied_last, &mut out);
                    if assigned == 0 {
                        break;
                    }
                    round += 1;
                    if round > self.spec.max_copies + 1 {
                        break;
                    }
                }
            }
            Allocation::Jga => {
                // job-greedy: a job exhausts all its rounds before the next
                for (pi, &ji) in prior.iter().enumerate() {
                    let single_prior = vec![ji];
                    let mut single_budget = vec![budget[pi]];
                    let mut single_copied = vec![Vec::new()];
                    let mut round = 1usize;
                    loop {
                        let assigned = self.run_round(
                            view,
                            &single_prior,
                            &mut single_budget,
                            round,
                            &mut single_copied,
                            &mut out,
                        );
                        if assigned == 0 {
                            break;
                        }
                        round += 1;
                        if round > self.spec.max_copies + 1 {
                            break;
                        }
                    }
                    budget[pi] = single_budget[0];
                }
            }
        }
        out
    }

    /// PingAn is fully epoch-driven: every trigger it acts on — a task
    /// turning Ready (arrival or completion), copies dying (failure),
    /// slots or gate bandwidth freeing (completion or kill) — coincides
    /// with an engine event, and within one epoch the round structure
    /// already insures up to its budget. Nothing changes between events
    /// that another invocation could exploit, so no timed wake is needed.
    fn next_wake(&mut self, _now: u64) -> Option<u64> {
        None
    }

    fn telemetry(&self) -> Option<&Counters> {
        Some(&self.counters)
    }

    fn attach_spans(&mut self, spans: Arc<Spans>) {
        self.spans = Some(spans);
    }

    fn set_trace(&mut self, sink: TraceSink) {
        self.trace = Some(sink);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::simulator::{SimConfig, Simulation};
    use crate::util::rng::Rng;
    use crate::workload::montage;

    fn setup(n_jobs: usize, seed: u64) -> (GeoSystem, Vec<crate::workload::job::JobSpec>) {
        let mut rng = Rng::new(seed);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, 0.05);
        w.datasize = (50.0, 400.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        (sys, jobs)
    }

    #[test]
    fn completes_all_jobs() {
        let (sys, jobs) = setup(10, 61);
        let res = Simulation::new(&sys, jobs, SimConfig::default())
            .run(&mut PingAn::with_epsilon(0.6));
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(res.copies_launched > 0);
    }

    #[test]
    fn insures_extra_copies() {
        // abundant gates so round-2 reliability copies (which must fit
        // their full stream) are admissible
        let mut rng = Rng::new(62);
        let mut sspec = SystemSpec::small(6);
        sspec.vm_ext_bw *= 8.0;
        let sys = GeoSystem::generate(&sspec, &mut rng);
        let mut w = WorkloadSpec::scaled(4, 0.05);
        w.datasize = (200.0, 800.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let n_tasks: u64 = jobs.iter().map(|j| j.n_tasks() as u64).sum();
        let res = Simulation::new(&sys, jobs, SimConfig::default())
            .run(&mut PingAn::with_epsilon(0.8));
        assert!(
            res.copies_launched > n_tasks,
            "expected insurance copies: {} copies for {} tasks",
            res.copies_launched,
            n_tasks
        );
    }

    #[test]
    fn respects_max_copy_cap() {
        let (sys, jobs) = setup(3, 63);
        let mut spec = PingAnSpec::with_epsilon(0.8);
        spec.max_copies = 2;
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let mut p = PingAn::new(spec);
        for _ in 0..400 {
            sim.step(&mut p);
            for j in &sim.jobs {
                for t in &j.tasks {
                    assert!(t.alive_copies() <= 2, "copy cap violated");
                }
            }
        }
    }

    #[test]
    fn all_variants_run() {
        for principle in [
            Principle::EffReli,
            Principle::ReliEff,
            Principle::EffEff,
            Principle::ReliReli,
        ] {
            for allocation in [Allocation::Efa, Allocation::Jga] {
                let (sys, jobs) = setup(4, 64);
                let mut spec = PingAnSpec::with_epsilon(0.6);
                spec.principle = principle;
                spec.allocation = allocation;
                let res =
                    Simulation::new(&sys, jobs, SimConfig::default()).run(&mut PingAn::new(spec));
                assert_eq!(
                    res.finished_jobs, res.total_jobs,
                    "{principle:?}/{allocation:?}"
                );
            }
        }
    }

    #[test]
    fn scorer_backends_all_complete() {
        // cpu (batched default) and scalar (reference) must both drive a
        // run to completion; their full Action-stream agreement is pinned
        // in tests/end_to_end.rs
        for kind in [ScorerKind::Cpu, ScorerKind::Scalar] {
            let (sys, jobs) = setup(4, 68);
            let mut spec = PingAnSpec::with_epsilon(0.6);
            spec.scorer = kind;
            let mut p = PingAn::new(spec);
            assert_eq!(
                p.name().contains("scalar"),
                kind == ScorerKind::Scalar,
                "backend tag in {}",
                p.name()
            );
            let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut p);
            assert_eq!(res.finished_jobs, res.total_jobs, "{kind:?}");
        }
    }

    #[test]
    fn score_threads_only_move_wall_time() {
        // full-run smoke for the intra-slot sharding: identical flowtime
        // series (to the bit) and copy counts at 1/2/4 scoring threads.
        // The exhaustive pin across time models, scorers and the λ/ε grid
        // lives in tests/end_to_end.rs.
        let baseline = {
            let (sys, jobs) = setup(6, 69);
            let mut cfg = SimConfig::default();
            cfg.score_threads = 1;
            Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6))
        };
        assert_eq!(baseline.finished_jobs, baseline.total_jobs);
        for threads in [2usize, 4] {
            let (sys, jobs) = setup(6, 69);
            let mut cfg = SimConfig::default();
            cfg.score_threads = threads;
            let res = Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6));
            assert_eq!(res.copies_launched, baseline.copies_launched, "threads={threads}");
            assert_eq!(res.copies_failed, baseline.copies_failed, "threads={threads}");
            assert_eq!(res.slots, baseline.slots, "threads={threads}");
            for (a, b) in res.flowtimes.iter().zip(&baseline.flowtimes) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn epsilon_shapes_sharing() {
        // With tiny epsilon only the smallest jobs get slots each round
        // AND the rate floor 1/(1+ε) is stricter, so under light load
        // small-eps should not launch more copies than large-eps. One
        // draw is noisy — assert the direction on a 3-seed aggregate.
        let (mut copies_small, mut copies_large) = (0u64, 0u64);
        for seed in [65u64, 66, 67] {
            let (sys, jobs) = setup(8, seed);
            let r_small = Simulation::new(&sys, jobs.clone(), SimConfig::default())
                .run(&mut PingAn::with_epsilon(0.2));
            let r_large = Simulation::new(&sys, jobs, SimConfig::default())
                .run(&mut PingAn::with_epsilon(0.8));
            assert_eq!(r_small.finished_jobs, r_small.total_jobs, "seed {seed}");
            assert_eq!(r_large.finished_jobs, r_large.total_jobs, "seed {seed}");
            copies_small += r_small.copies_launched;
            copies_large += r_large.copies_launched;
        }
        assert!(
            copies_small <= copies_large,
            "ε=0.2 launched {copies_small} copies vs {copies_large} at ε=0.8"
        );
    }

    #[test]
    fn insurer_counters_reconcile_with_engine() {
        let (sys, jobs) = setup(6, 70);
        let mut p = PingAn::with_epsilon(0.6);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut p);
        assert_eq!(res.finished_jobs, res.total_jobs);
        let c = &res.telemetry;
        assert!(c.insurer_rounds > 0, "rounds were counted");
        assert!(c.rows_scored > 0, "scored rows were counted");
        // every launch the engine applied was an admission the insurer
        // recorded (the view ledgers mirror the engine's, so no action
        // is dropped at validation)
        assert_eq!(c.admissions, res.copies_launched);
    }

    #[test]
    fn trace_sink_does_not_perturb_decisions() {
        // the decision trace is a pure observer: identical flowtimes (to
        // the bit) and counters with and without a sink attached, and the
        // sink saw one record per admission at minimum
        let base = {
            let (sys, jobs) = setup(6, 71);
            Simulation::new(&sys, jobs, SimConfig::default()).run(&mut PingAn::with_epsilon(0.6))
        };
        let (sink, buf) = crate::obs::TraceSink::in_memory();
        let (sys, jobs) = setup(6, 71);
        let mut p = PingAn::with_epsilon(0.6);
        p.set_trace(sink);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut p);
        assert_eq!(res.telemetry, base.telemetry);
        for (a, b) in res.flowtimes.iter().zip(&base.flowtimes) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(
            lines.len() as u64 >= base.telemetry.admissions,
            "at least one record per admission"
        );
        assert!(lines.iter().all(|l| l.contains("\"reason\":")));
    }

    #[test]
    fn invariants_under_pingan() {
        let (sys, jobs) = setup(6, 66);
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let mut p = PingAn::with_epsilon(0.6);
        for _ in 0..300 {
            sim.step(&mut p);
            sim.check_invariants().unwrap();
        }
    }
}
