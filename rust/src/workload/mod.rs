//! Job, task and workload-trace model.
//!
//! * [`job`] — the DAG job model with precedence constraints (Eq. 8) and
//!   per-task input locations (the paper's `I_l^i` input-location sets).
//! * [`montage`] — Montage-workflow-shaped DAG generator used by the
//!   simulation experiments (Sec 6.1), with the Facebook-trace job-size mix.
//! * [`testbed`] — the Table-1 testbed mix (WordCount / Iterative ML /
//!   PageRank at 46/40/14% small/medium/large input sizes).
//! * [`arrivals`] — Poisson / exponential job arrival processes.
//! * [`source`] — the pull-based [`WorkloadSource`] intake API: the engine
//!   admits jobs lazily from a source instead of an eager `Vec`, keeping
//!   resident state O(clusters + alive jobs) on million-job replays.
//!   [`EagerSource`] wraps materialized workloads (bit-identical to the
//!   pre-redesign path); `GenSource` streams the Montage generator;
//!   [`ChannelSource`] is the *live* intake `pingan serve` feeds over a
//!   channel (the one source that can answer "no job yet" through
//!   [`source::SourcePoll`] instead of "drained").
//! * [`trace`] — [`TraceSource`], an Azure-Functions-style CSV/JSONL
//!   arrival-trace reader with deterministic per-job-id seeding
//!   (`pingan replay --trace <file>`). Malformed input surfaces as a
//!   [`trace::TraceError`] from the fallible API; the batch replay path
//!   wraps it in the loud historical panic, while `serve` turns the same
//!   error into a per-submission error response and keeps running.

pub mod arrivals;
pub mod job;
pub mod montage;
pub mod source;
pub mod testbed;
pub mod trace;

pub use job::{JobSpec, OpKind, TaskSpec};
pub use source::{ChannelSource, EagerSource, JobSender, WorkloadSource};
pub use trace::{TraceError, TraceSource};
