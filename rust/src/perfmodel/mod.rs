//! Performance modeler (the paper's PM component, Fig 1b, Sec 3.2).
//!
//! Collects execution information reported by finished tasks — data
//! processing speed per (cluster, operation) and transfer bandwidth per
//! cluster pair — plus observed cluster-level unreachability, and serves
//! distribution estimates to the insurer:
//!
//! * `f^P_m(v)` — processing-speed histogram per cluster & operation,
//! * `f^T_{m1,m2}(v)` — transfer-bandwidth histogram per pair,
//! * `p̂_m` — unreachability probability (Laplace-smoothed frequency),
//! * `rate_hist` — the copy execution-rate distribution
//!   `min(V^P, mean_src V^T)` used for r(x) scoring.
//!
//! Estimates start from a deliberately *blurred* prior (published instance
//! specs give coarse expectations; the modeler must still learn the real
//! behaviour from logs, as the paper requires "no a-priori knowledge").

pub mod modeler;

pub use modeler::PerfModel;
