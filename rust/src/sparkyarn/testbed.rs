//! The testbed driver: Fig-1 control plane over the execution engine, with
//! real XLA payload execution per completed task.

use anyhow::Result;

use super::components::{AppMaster, ResourceManager, TaskSetPool};
use crate::cluster::GeoSystem;
use crate::config::spec::SystemSpec;
#[cfg(feature = "pjrt")]
use crate::runtime::payload::Payloads;
#[cfg(feature = "pjrt")]
use crate::runtime::Engine;
use crate::sched::Scheduler;
use crate::simulator::{SimConfig, Simulation};
use crate::util::rng::Rng;
use crate::workload::job::JobSpec;
#[cfg(feature = "pjrt")]
use crate::workload::testbed::AppKind;

/// Testbed knobs.
#[derive(Clone, Debug)]
pub struct TestbedConfig {
    /// Wall milliseconds per simulated slot; 0 = as fast as possible.
    pub slot_ms: u64,
    /// Execute a real payload for every `payload_every`-th completed task
    /// (1 = all tasks; larger values bound wall time on big workloads).
    pub payload_every: usize,
    /// Artifacts directory; `None` disables payload execution (pure
    /// control-plane run, used in tests without artifacts). Payloads also
    /// require the `pjrt` cargo feature — without it the testbed always
    /// runs control-plane only.
    pub artifact_dir: Option<String>,
    pub seed: u64,
}

impl Default for TestbedConfig {
    fn default() -> Self {
        TestbedConfig {
            slot_ms: 0,
            payload_every: 1,
            artifact_dir: Some("artifacts".to_string()),
            seed: 3,
        }
    }
}

/// Outcome of one testbed run.
#[derive(Clone, Debug)]
pub struct TestbedResult {
    pub scheduler: String,
    pub flowtimes: Vec<f64>,
    pub finished_jobs: usize,
    pub total_jobs: usize,
    /// Real payload executions performed (and validated).
    pub payload_execs: u64,
    /// Payload validation failures (must be 0 for a healthy run).
    pub payload_errors: u64,
    /// Total containers granted across RMs.
    pub containers_granted: u64,
}

/// The paper's testbed: 10 heterogeneous edge clusters (Sec 5 uses 10 VMs).
pub fn testbed_system(seed: u64) -> GeoSystem {
    let mut spec = SystemSpec::small(10);
    spec.seed = seed;
    let mut rng = Rng::new(seed);
    GeoSystem::generate(&spec, &mut rng)
}

/// One testbed run of `jobs` under `policy`.
pub struct Testbed {
    cfg: TestbedConfig,
    #[cfg(feature = "pjrt")]
    payloads: Option<Payloads>,
}

impl Testbed {
    pub fn new(cfg: TestbedConfig) -> Result<Testbed> {
        #[cfg(feature = "pjrt")]
        let payloads = match &cfg.artifact_dir {
            Some(dir) if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() => {
                let engine = Engine::new(dir)?;
                Some(Payloads::new(&engine)?)
            }
            _ => None,
        };
        #[cfg(not(feature = "pjrt"))]
        if let Some(dir) = &cfg.artifact_dir {
            if std::path::Path::new(&format!("{dir}/manifest.toml")).exists() {
                log::warn!(
                    "artifacts found in {dir} but this build lacks the `pjrt` feature; \
                     payload execution disabled"
                );
            }
        }
        Ok(Testbed {
            cfg,
            #[cfg(feature = "pjrt")]
            payloads,
        })
    }

    /// Whether real payload execution is enabled (requires the `pjrt`
    /// feature and a compiled artifacts directory).
    pub fn has_payloads(&self) -> bool {
        #[cfg(feature = "pjrt")]
        {
            self.payloads.is_some()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            false
        }
    }

    pub fn run(
        &self,
        system: &GeoSystem,
        jobs: Vec<JobSpec>,
        policy: &mut dyn Scheduler,
    ) -> TestbedResult {
        #[cfg(feature = "pjrt")]
        let app_of: Vec<AppKind> = jobs
            .iter()
            .map(|j| {
                AppKind::ALL
                    .iter()
                    .copied()
                    .find(|a| j.name.starts_with(a.name()))
                    .unwrap_or(AppKind::WordCount)
            })
            .collect();
        let mut sim_cfg = SimConfig::default();
        sim_cfg.seed = self.cfg.seed;
        let total_jobs = jobs.len();
        let mut sim = Simulation::new(system, jobs, sim_cfg);
        // control plane state
        let mut rms: Vec<ResourceManager> = system
            .clusters
            .iter()
            .map(|c| ResourceManager::new(c.id, c.slots))
            .collect();
        let ams: Vec<AppMaster> = (0..total_jobs).map(AppMaster::new).collect();
        let mut pool = TaskSetPool::new();
        #[cfg(feature = "pjrt")]
        let mut payload_rng = Rng::new(self.cfg.seed ^ 0x9E37);
        let mut done_before = vec![0usize; total_jobs];
        #[cfg(feature = "pjrt")]
        let mut payload_execs = 0u64;
        #[cfg(feature = "pjrt")]
        let mut payload_errors = 0u64;
        #[cfg(not(feature = "pjrt"))]
        let (payload_execs, payload_errors) = (0u64, 0u64);
        #[cfg(feature = "pjrt")]
        let mut completed_counter = 0usize;

        loop {
            let alive_empty = {
                // workflow step a/b: AMs emit TaskSets into the pool
                let mut any_alive = false;
                for (ji, am) in ams.iter().enumerate() {
                    let rt = &sim.jobs[ji];
                    if rt.alive_at(sim.now()) {
                        any_alive = true;
                        if let Some(ts) = am.emit_taskset(rt) {
                            pool.submit(ts);
                        }
                    }
                }
                // the pool's ordering is the same priority the insurer
                // recomputes; drain it to keep the queue bounded and to
                // surface ordering in the control-plane metrics
                let _ordered = pool.drain_ordered();
                !any_alive
            };
            if alive_empty && sim.now() > 0 && sim.jobs.iter().all(|j| j.is_done()) {
                break;
            }
            if sim.now() >= 1_000_000 {
                log::warn!("testbed wall: bailing at slot {}", sim.now());
                break;
            }
            // step c/d/e: modeler feeds the insurer inside sim.step
            let before_grants: Vec<usize> =
                rms.iter().map(|r| r.granted).collect();
            sim.step(policy);
            // reconcile RM ledgers with engine slot usage
            for (m, rm) in rms.iter_mut().enumerate() {
                let in_use: usize = sim
                    .jobs
                    .iter()
                    .flat_map(|j| &j.tasks)
                    .flat_map(|t| &t.copies)
                    .filter(|c| c.alive && c.cluster == m)
                    .count();
                while rm.granted < in_use {
                    rm.try_grant();
                }
                while rm.granted > in_use {
                    rm.release();
                }
                let _ = before_grants[m];
            }
            // payload execution per newly completed task (workflow step 1)
            for ji in 0..total_jobs {
                let done_now = sim.jobs[ji].n_done();
                if done_now > done_before[ji] {
                    #[cfg(feature = "pjrt")]
                    for _ in done_before[ji]..done_now {
                        completed_counter += 1;
                        if let Some(p) = &self.payloads {
                            if completed_counter % self.cfg.payload_every == 0 {
                                match p.run(app_of[ji], &mut payload_rng) {
                                    Ok(_) => payload_execs += 1,
                                    Err(e) => {
                                        payload_errors += 1;
                                        log::error!("payload validation: {e:#}");
                                    }
                                }
                            }
                        }
                    }
                    done_before[ji] = done_now;
                }
            }
            if self.cfg.slot_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(self.cfg.slot_ms));
            }
        }

        let flowtimes: Vec<f64> = sim
            .jobs
            .iter()
            .map(|j| j.flowtime().map(|f| f as f64).unwrap_or(f64::NAN))
            .collect();
        let finished = sim.jobs.iter().filter(|j| j.is_done()).count();
        TestbedResult {
            scheduler: policy.name().to_string(),
            flowtimes,
            finished_jobs: finished,
            total_jobs,
            payload_execs,
            payload_errors,
            containers_granted: rms.iter().map(|r| r.total_grants).sum(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Spark;
    #[cfg(feature = "pjrt")]
    use crate::insurance::PingAn;
    use crate::workload::testbed::{generate, TestbedSpec};

    fn small_jobs(system: &GeoSystem, n: usize) -> Vec<JobSpec> {
        let mut spec = TestbedSpec::default();
        spec.n_jobs = n;
        let sites: Vec<usize> = (0..system.n()).collect();
        let mut rng = Rng::new(17);
        generate(&spec, &sites, &mut rng)
    }

    #[test]
    fn control_plane_runs_without_artifacts() {
        let sys = testbed_system(2);
        let jobs = small_jobs(&sys, 6);
        let mut cfg = TestbedConfig::default();
        cfg.artifact_dir = None;
        let tb = Testbed::new(cfg).unwrap();
        assert!(!tb.has_payloads());
        let res = tb.run(&sys, jobs, &mut Spark::new());
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(res.containers_granted > 0);
        assert_eq!(res.payload_execs, 0);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn payloads_execute_when_artifacts_present() {
        if !std::path::Path::new("artifacts/manifest.toml").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let sys = testbed_system(4);
        let jobs = small_jobs(&sys, 4);
        let mut cfg = TestbedConfig::default();
        cfg.payload_every = 5; // keep the test quick
        let tb = Testbed::new(cfg).unwrap();
        let res = tb.run(&sys, jobs, &mut PingAn::with_epsilon(0.6));
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(res.payload_execs > 0, "no payloads ran");
        assert_eq!(res.payload_errors, 0, "payload validation failed");
    }
}
