//! Substrate benches: histogram algebra, topology/workload generation and
//! raw engine throughput — the denominators of every experiment.
//!
//! Run: `cargo bench --bench bench_simulator`
//! (set PINGAN_BENCH_FAST=1 for a quick smoke pass)

use pingan::baselines::Flutter;
use pingan::bench_harness::Bench;
use pingan::cluster::GeoSystem;
use pingan::config::spec::{SystemSpec, WorkloadSpec};
use pingan::dist::{Grid, Hist};
use pingan::simulator::{SimConfig, Simulation};
use pingan::topology::Topology;
use pingan::util::rng::Rng;
use pingan::workload::montage;

fn main() {
    let mut b = Bench::new("simulator");

    // histogram algebra (the scoring inner loop)
    let grid = Grid::uniform(0.0, 400.0, 64);
    let h1 = Hist::normal(&grid, 120.0, 30.0);
    let h2 = Hist::normal(&grid, 90.0, 40.0);
    let h3 = Hist::normal(&grid, 150.0, 20.0);
    b.case("hist_min_compose_64bins", || {
        h1.min_compose(&h2).mean()
    });
    b.case("hist_expected_max_3x64bins", || {
        Hist::expected_max(&[&h1, &h2, &h3])
    });
    b.case("hist_normal_fit_64bins", || {
        Hist::normal(&grid, 100.0, 25.0).mean()
    });

    // generation
    b.case("topology_100_clusters", || {
        let mut rng = Rng::new(1);
        Topology::generate(100, 2, &mut rng).degree(0) as f64
    });
    b.case("geosystem_100_clusters", || {
        let mut rng = Rng::new(2);
        GeoSystem::generate(&SystemSpec::default(), &mut rng).total_slots() as f64
    });
    b.case("montage_100_jobs", || {
        let mut rng = Rng::new(3);
        let w = WorkloadSpec::scaled(100, 0.07);
        montage::generate(&w, &[0, 1, 2, 3], &mut rng).len() as f64
    });

    // engine throughput: one full small run under a cheap policy
    b.case("engine_run_12jobs_6clusters", || {
        let mut rng = Rng::new(4);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(12, 0.05);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut Flutter::new());
        res.slots as f64
    });
}
