//! Barabási–Albert heavy-tailed topology with degree-ranked scale classes.

use crate::util::rng::Rng;

/// Scale class of a cluster (Table 2 rows).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterScale {
    Large,
    Medium,
    Small,
}

impl ClusterScale {
    pub fn name(&self) -> &'static str {
        match self {
            ClusterScale::Large => "large",
            ClusterScale::Medium => "medium",
            ClusterScale::Small => "small",
        }
    }

    pub fn class_index(&self) -> usize {
        match self {
            ClusterScale::Large => 0,
            ClusterScale::Medium => 1,
            ClusterScale::Small => 2,
        }
    }
}

/// Undirected cluster graph with hop-count distances.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n: usize,
    adj: Vec<Vec<usize>>,
    /// Scale per node, after degree ranking.
    pub scales: Vec<ClusterScale>,
    /// Hop distance matrix (BFS all-pairs), n×n row-major.
    hops: Vec<u32>,
}

impl Topology {
    /// Generate `n` nodes; each newcomer attaches to `m_edges` existing
    /// nodes with probability proportional to degree (BA model). Fractions
    /// follow the paper: top 5% by degree large, next 20% medium, rest small.
    pub fn generate(n: usize, m_edges: usize, rng: &mut Rng) -> Topology {
        assert!(n >= 2, "need at least two clusters");
        let m_edges = m_edges.max(1).min(n - 1);
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut degree = vec![0usize; n];
        // seed clique over the first m_edges+1 nodes
        let seed = (m_edges + 1).min(n);
        for i in 0..seed {
            for j in (i + 1)..seed {
                adj[i].push(j);
                adj[j].push(i);
                degree[i] += 1;
                degree[j] += 1;
            }
        }
        for v in seed..n {
            let mut targets: Vec<usize> = Vec::with_capacity(m_edges);
            let weights: Vec<f64> = degree[..v].iter().map(|&d| (d + 1) as f64).collect();
            while targets.len() < m_edges.min(v) {
                let t = rng.weighted_index(&weights);
                if !targets.contains(&t) {
                    targets.push(t);
                }
            }
            for t in targets {
                adj[v].push(t);
                adj[t].push(v);
                degree[v] += 1;
                degree[t] += 1;
            }
        }
        // degree ranking -> scales (ties broken by index for determinism)
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| degree[b].cmp(&degree[a]).then(a.cmp(&b)));
        let n_large = ((n as f64) * 0.05).round().max(1.0) as usize;
        let n_medium = ((n as f64) * 0.20).round().max(1.0) as usize;
        let mut scales = vec![ClusterScale::Small; n];
        for (rank, &node) in order.iter().enumerate() {
            scales[node] = if rank < n_large {
                ClusterScale::Large
            } else if rank < n_large + n_medium {
                ClusterScale::Medium
            } else {
                ClusterScale::Small
            };
        }
        let hops = all_pairs_hops(&adj);
        Topology {
            n,
            adj,
            scales,
            hops,
        }
    }

    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    pub fn neighbors(&self, v: usize) -> &[usize] {
        &self.adj[v]
    }

    /// Shortest-path hop count between clusters (0 on the diagonal).
    pub fn hops(&self, a: usize, b: usize) -> u32 {
        self.hops[a * self.n + b]
    }

    /// Degree sequence sorted descending (for heavy-tail checks).
    pub fn degree_sequence(&self) -> Vec<usize> {
        let mut d: Vec<usize> = (0..self.n).map(|v| self.degree(v)).collect();
        d.sort_unstable_by(|a, b| b.cmp(a));
        d
    }

    pub fn count_scale(&self, s: ClusterScale) -> usize {
        self.scales.iter().filter(|&&x| x == s).count()
    }
}

fn all_pairs_hops(adj: &[Vec<usize>]) -> Vec<u32> {
    let n = adj.len();
    let mut hops = vec![u32::MAX; n * n];
    let mut queue = std::collections::VecDeque::new();
    for src in 0..n {
        let row = &mut hops[src * n..(src + 1) * n];
        row[src] = 0;
        queue.clear();
        queue.push_back(src);
        while let Some(v) = queue.pop_front() {
            let dv = row[v];
            for &w in &adj[v] {
                if row[w] == u32::MAX {
                    row[w] = dv + 1;
                    queue.push_back(w);
                }
            }
        }
    }
    hops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn topo(n: usize) -> Topology {
        let mut rng = Rng::new(1);
        Topology::generate(n, 2, &mut rng)
    }

    #[test]
    fn connected_and_symmetric() {
        let t = topo(100);
        for a in 0..t.n {
            for b in 0..t.n {
                assert_ne!(t.hops(a, b), u32::MAX, "disconnected {a}-{b}");
                assert_eq!(t.hops(a, b), t.hops(b, a));
            }
            assert_eq!(t.hops(a, a), 0);
        }
    }

    #[test]
    fn scale_fractions_match_paper() {
        let t = topo(100);
        assert_eq!(t.count_scale(ClusterScale::Large), 5);
        assert_eq!(t.count_scale(ClusterScale::Medium), 20);
        assert_eq!(t.count_scale(ClusterScale::Small), 75);
    }

    #[test]
    fn large_clusters_have_top_degrees() {
        let t = topo(100);
        let max_small = (0..t.n)
            .filter(|&v| t.scales[v] == ClusterScale::Small)
            .map(|v| t.degree(v))
            .max()
            .unwrap();
        let min_large = (0..t.n)
            .filter(|&v| t.scales[v] == ClusterScale::Large)
            .map(|v| t.degree(v))
            .min()
            .unwrap();
        assert!(min_large >= max_small, "large {min_large} < small {max_small}");
    }

    #[test]
    fn heavy_tail_shape() {
        // hubs dominate: max degree should be several times the median.
        let t = topo(200);
        let d = t.degree_sequence();
        let median = d[d.len() / 2] as f64;
        assert!(d[0] as f64 >= 3.0 * median, "max={} median={}", d[0], median);
    }

    #[test]
    fn deterministic_for_seed() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let a = Topology::generate(50, 2, &mut r1);
        let b = Topology::generate(50, 2, &mut r2);
        assert_eq!(a.degree_sequence(), b.degree_sequence());
        for v in 0..50 {
            assert_eq!(a.scales[v], b.scales[v]);
        }
    }

    #[test]
    fn tiny_graph_ok() {
        let t = topo(2);
        assert_eq!(t.hops(0, 1), 1);
    }
}
