//! # PingAn — insurance-based job acceleration for geo-distributed analytics
//!
//! Reproduction of *"PingAn: An Insurance Scheme for Job Acceleration in
//! Geo-distributed Big Data Analytics System"* (Wang, Qian, Lu — 2018).
//!
//! PingAn speeds up geo-distributed data-analytics jobs by *insuring* tasks:
//! launching extra copies of a task in other clusters, chosen with an
//! efficiency-first / reliability-aware policy, so that cluster heterogeneity,
//! overload and cluster-level unreachability do not stall jobs.
//!
//! The crate is the Layer-3 (coordinator) of a three-layer stack:
//!
//! * **L3 (this crate)** — the PingAn insurer, the baseline schedulers, a
//!   slotted discrete-event geo-cluster simulator (the CloudSim substitute),
//!   and a mini Spark-on-Yarn testbed mode that executes real compute via
//!   PJRT-compiled XLA artifacts.
//! * **L2 (python/compile/model.py)** — JAX compute graphs (plan scoring and
//!   the analytics task payloads), AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the scoring
//!   hot-spot (bottleneck-composition + E\[max\] over copy sets).
//!
//! Python never runs on the request path: `make artifacts` lowers everything
//! once; the rust binary loads `artifacts/*.hlo.txt` through the PJRT C API.
//!
//! ## Crate layout
//!
//! * [`dist`] — fixed-grid histogram algebra ([`dist::Grid`],
//!   [`dist::Hist`]): the Sec-3.2 rate model's numeric substrate
//!   (bottleneck min-composition, multi-source averaging, E\[max\] over
//!   copy sets, recency-weighted blending). Everything numeric sits on it.
//! * [`perfmodel`] — execution-log driven per-(cluster, op) and per-pair
//!   histogram estimates served to the insurer.
//! * [`insurance`] — Algorithm 1 (the insurer) and its scoring rules.
//!   `PingAn::schedule` batches each round's (task, candidate) pairs
//!   through a pluggable `runtime::Scorer` (`--scorer cpu|hlo|scalar`);
//!   the per-candidate scalar path survives as the bit-exact reference.
//!   [`baselines`] — Spark/speculation/Flutter/Iridium/Mantri/Dolly.
//! * [`simulator`], [`cluster`], [`topology`], [`workload`] — the
//!   geo-cluster engine and its inputs; [`sparkyarn`] — the testbed mode.
//!   Workloads reach the engine through the pull-based
//!   [`workload::WorkloadSource`] iterator ([`workload::EagerSource`]
//!   wraps a pre-built `Vec` bit-identically; `workload::source::GenSource`
//!   draws Montage jobs incrementally; [`workload::TraceSource`] replays
//!   external CSV/JSONL arrival traces with per-job-id seeding — the
//!   `pingan replay` command and the sweep's `trace` key). Combined with
//!   `SimConfig::stream_metrics` (`--stream-metrics`,
//!   `PINGAN_STREAM_METRICS`), which swaps the per-job flowtime `Vec` for
//!   the [`metrics::FlowStats`] sketch and recycles engine job slots, a
//!   million-job replay runs in O(clusters + alive jobs) memory.
//!   The simulator is a **dual-mode time core** (`--time-model`,
//!   [`simulator::TimeModel`]): `simulator::engine` orchestrates either
//!   the dense slotted reference loop (bit-reproducible, every slot
//!   redraws processes and invokes the policy) or the event-skip core —
//!   `simulator::events` is the `BinaryHeap` event queue (arrival /
//!   copy-completion / cluster-failure / policy-epoch, deterministic
//!   tie-breaking) and `simulator::processes` lifts the per-slot
//!   stochastic processes into skippable form (geometric inter-failure
//!   gaps, exact k-step AR(1) congestion transitions), so `now` jumps to
//!   the next event and empty slots cost nothing. Schedulers see
//!   epoch-driven invocation (`SchedView::elapsed`, `Scheduler::
//!   next_wake`); `SimResult::events_processed` exposes skip efficiency.
//!   Under both cores the *plant* — per-cluster failure gaps, AR(1)
//!   congestion, slot/ingress/egress ledgers — lives in
//!   `simulator::shard` ([`simulator::EngineShards`]): each shard owns a
//!   contiguous cluster range with its own per-cluster RNG streams and
//!   advances independently between policy epochs, syncing at a
//!   deterministic barrier (`std::thread::scope`, shard-order merge)
//!   before each scheduler invocation. `SimConfig::engine_threads`
//!   (`--engine-threads`, default from `PINGAN_ENGINE_THREADS`) sets the
//!   shard-thread budget — a pure wall-time knob, bit-identical Action
//!   streams and results at any value.
//!   `SimConfig::score_threads` (`--score-threads`, default from
//!   `PINGAN_SCORE_THREADS`) adds **intra-cell parallelism**: the engine
//!   hands the budget to the policy via `SchedView::score_threads` and
//!   PingAn shards each round's scoring batch across that many OS
//!   threads — bit-identical admissions at any value, on either time
//!   core, composing with the sweep runner's across-cell workers.
//!   `SimConfig::bandwidth_model` (`--bandwidth-model`, default from
//!   `PINGAN_BANDWIDTH_MODEL`) picks the WAN transfer model:
//!   `constant` keeps each copy's launch-time rate draw, while `shared`
//!   puts every copy with remote inputs into a max-min fair-share solve
//!   over cluster ingress/egress gates and per-pair WAN links
//!   (`simulator::bandwidth`, two proptest-pinned bit-identical
//!   backends — a progressive-filling reference and the incremental
//!   solver the engine uses). Re-rates apply only at the epoch barrier
//!   (a shared WAN link couples transfers homed in different shards),
//!   checkpointing each affected copy into a fresh closed-form progress
//!   segment and bumping its task's copy-set epoch under event-skip —
//!   so `shared` results also stay bit-identical at any
//!   `engine_threads`, and `--bandwidth-models constant,shared` sweeps
//!   paired contention comparisons.
//! * [`runtime`] — batched copy-placement scoring, the insurer's hot
//!   path. The pure-rust `CpuScorer` (f64, bit-identical to the
//!   `dist::Hist` algebra) is always available, and
//!   `runtime::scorer::score_rows_sharded` shards a round's rows across
//!   a scoped thread pool with order-preserving merge (bit-identical
//!   output at any thread count); the XLA/PJRT artifact
//!   path (`runtime::pjrt`, `runtime::payload`, `HloScorer` — f32, so
//!   admissions agree only to tolerance) is compiled only with the
//!   **`pjrt` cargo feature** (off by default, so the tier-1 build is
//!   hermetic — no native XLA libraries needed). Without the feature,
//!   `pingan validate` self-checks the CPU backend and the testbed runs
//!   control-plane only.
//! * [`sweep`] — the declarative, parallel scenario-sweep engine:
//!   [`sweep::SweepSpec`] expands named axes (scheduler, λ, ε, cluster
//!   count, failure scale, workload mix, replicas, bandwidth model) into
//!   a deterministic
//!   cell grid; a work-stealing threaded runner executes it with
//!   per-cell panic isolation and thread-count-invariant seeding; and
//!   [`sweep::SweepReport`] aggregates mean/p50/p95/p99 flowtime,
//!   confidence intervals and copy costs with CSV/JSON emitters. Every
//!   figure, table, bench and the `pingan sweep` command run on it.
//! * [`obs`] — zero-perturbation telemetry on two strictly separated
//!   planes: deterministic counters ([`obs::Counters`] — logical event
//!   counts, RNG- and clock-free, bit-identical at any
//!   `score_threads` × `engine_threads` and allowed into
//!   equality-checked JSON) vs wall-clock spans ([`obs::Spans`] —
//!   lock-free log2 latency histograms for scheduling rounds, shard
//!   advances, barrier waits and scorer batches, quarantined like
//!   `wall_secs`), plus the opt-in `--trace-file` JSONL decision trace
//!   ([`obs::TraceSink`]) and the [`obs::CountersCell`] live mirror the
//!   service mode's stats reader loads mid-run.
//! * [`serve`] — `pingan serve`, the online half of the online
//!   algorithm: a long-lived TCP service accepting newline-delimited
//!   JSON job submissions (the JSONL trace row grammar), admitting and
//!   placing them through the same insurer against a live engine fed
//!   over a [`workload::ChannelSource`], answering `/stats` with live
//!   decision-latency percentiles (p50/p99 from the `Sched` span
//!   histogram, rounds/sec, admissions/rejections) and draining
//!   gracefully on `/shutdown` or `SIGTERM`. Malformed submissions get
//!   a per-line error response — the same [`workload::TraceError`] text
//!   `pingan replay` aborts with — and the server keeps running. All
//!   of `/stats` is monitoring-plane output; the two-plane rule above
//!   is untouched.
//! * [`analysis`], [`experiments`], [`metrics`] — Proposition 1 /
//!   Theorem 2 numeric checks and the table/figure regenerators (thin
//!   [`sweep`] constructions). [`metrics::FlowStats`] is the shared
//!   flowtime-statistics surface: exact count/mean/sum/CI plus an HDR
//!   log-linear quantile sketch (≤ ~1.6 % relative error, mergeable
//!   across cells), populated identically whether or not the raw
//!   per-job series was kept.

pub mod analysis;
pub mod baselines;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod dist;
pub mod experiments;
pub mod insurance;
pub mod metrics;
pub mod obs;
pub mod perfmodel;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod simulator;
pub mod sparkyarn;
pub mod sweep;
pub mod topology;
pub mod util;
pub mod workload;
