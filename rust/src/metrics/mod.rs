//! Flowtime metrics: averages, CDFs and reduction ratios (the paper's
//! evaluation metrics — Sec 5 "Metric" and Sec 6.1 "Metric").
//!
//! The scalar surface (mean / sum / percentiles) is unified on
//! [`crate::metrics::flowstats::FlowStats`], the bounded-memory sketch
//! every run carries in [`SimResult::stats`]: emitters call the accessors
//! there instead of re-deriving statistics from the raw flowtime `Vec`
//! (which is empty under `--stream-metrics`). The free functions below
//! remain for exact whole-series work — CDF plots, per-job averaging.

pub mod cdf;
pub mod flowstats;

pub use cdf::{Cdf, reduction_ratios};
pub use flowstats::FlowStats;

use crate::simulator::SimResult;

/// Sample the p50/p95/p99 quantiles of a series *exactly* (non-finite
/// entries are skipped by [`Cdf`]). Sorts its input once per call —
/// callers holding a series they interrogate repeatedly should compute
/// this once and share the tuple (the sweep report does), or use the
/// [`FlowStats`] sketch when bounded memory matters.
pub fn percentiles(xs: &[f64]) -> (f64, f64, f64) {
    let c = Cdf::new(xs);
    (c.quantile(0.5), c.quantile(0.95), c.quantile(0.99))
}

/// (p50, p95, p99) of a run's *finished* job flowtimes: exact (from the
/// raw series) when the run kept it, sketch-derived from
/// [`SimResult::stats`] under `--stream-metrics` (bounded relative error,
/// see [`flowstats`]).
pub fn flowtime_percentiles(res: &SimResult) -> (f64, f64, f64) {
    if res.flowtimes.is_empty() && res.stats.finished() > 0 {
        return res.stats.percentiles();
    }
    percentiles(&res.flowtimes)
}

/// Per-job mean across repeated runs of the same job set, skipping
/// non-finite (unfinished) entries — the paper averages each workload's
/// ten repetitions per job. A job unfinished in every run stays NaN.
/// Returns an empty vector when `runs` is empty.
pub fn average_per_job(runs: &[&[f64]]) -> Vec<f64> {
    let Some(first) = runs.first() else {
        return Vec::new();
    };
    let n = first.len();
    let mut sums = vec![0.0f64; n];
    let mut counts = vec![0u32; n];
    for r in runs {
        assert_eq!(r.len(), n, "job sets must match across reps");
        for (i, f) in r.iter().enumerate() {
            if f.is_finite() {
                sums[i] += f;
                counts[i] += 1;
            }
        }
    }
    sums.iter()
        .zip(&counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { f64::NAN })
        .collect()
}

/// Fraction of jobs finishing within `within` slots (Fig 3/5 commentary).
/// Needs the exact per-job series — returns 0.0 under `--stream-metrics`,
/// where the run keeps only the [`FlowStats`] sketch.
pub fn frac_within(res: &SimResult, within: f64) -> f64 {
    if res.flowtimes.is_empty() {
        return 0.0;
    }
    res.flowtimes
        .iter()
        .filter(|f| f.is_finite() && **f <= within)
        .count() as f64
        / res.flowtimes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimResult;

    fn result(flows: &[f64]) -> SimResult {
        SimResult::synthetic("t", flows.to_vec())
    }

    #[test]
    fn averages_skip_unfinished() {
        let r = result(&[10.0, 20.0, f64::NAN]);
        assert!((r.avg_flowtime() - 15.0).abs() < 1e-12);
        assert!((r.sum_flowtime() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn flowtime_percentiles_fall_back_to_sketch_when_streaming() {
        let flows: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let mut r = result(&flows);
        let exact = flowtime_percentiles(&r);
        // simulate --stream-metrics: raw series dropped, sketch kept
        r.flowtimes.clear();
        let (s50, s95, s99) = flowtime_percentiles(&r);
        assert!(s50 > 0.0 && s50 <= s95 && s95 <= s99);
        // sketch stays within its documented relative error of exact
        for (s, e) in [(s50, exact.0), (s95, exact.1), (s99, exact.2)] {
            assert!((s - e).abs() <= e / 32.0 + 2.0, "sketch {s} vs exact {e}");
        }
    }

    #[test]
    fn frac_within_counts_all_jobs() {
        let r = result(&[10.0, 200.0, f64::NAN]);
        assert!((frac_within(&r, 100.0) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentiles_skip_nan_and_order() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let (p50, p95, p99) = percentiles(&xs);
        assert!((p50 - 50.5).abs() < 1e-9);
        assert!(p50 <= p95 && p95 <= p99);
        // NaN excluded: quantiles interpolate over the two finite samples
        let with_nan = [10.0, f64::NAN, 20.0];
        let (p50, _, p99) = percentiles(&with_nan);
        assert!((p50 - 15.0).abs() < 1e-9);
        assert!((p99 - 19.9).abs() < 1e-9);
    }

    #[test]
    fn average_per_job_skips_nan() {
        let a = [10.0, f64::NAN, f64::NAN];
        let b = [20.0, 30.0, f64::NAN];
        let avg = average_per_job(&[&a, &b]);
        assert_eq!(avg[0], 15.0);
        assert_eq!(avg[1], 30.0);
        assert!(avg[2].is_nan());
        assert!(average_per_job(&[]).is_empty());
    }
}
