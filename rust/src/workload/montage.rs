//! Montage-workflow-shaped job generator (simulation workload, Sec 6.1).
//!
//! Montage assembles sky mosaics: a wide fan of projection tasks over raw
//! tiles, pairwise overlap-difference tasks, a background-correction layer,
//! and a final mosaic add — tasks with high demand of both transfer and
//! compute. We generate that four-level shape, sized by the Facebook-trace
//! mix the paper quotes (89% small 1–150 tasks, 8% medium 151–500, 3% large
//! >500), with raw inputs scattered over edge and medium clusters.

use super::job::{JobSpec, OpKind, TaskSpec};
use crate::config::spec::WorkloadSpec;
use crate::util::rng::Rng;

/// Generate the full workload: `spec.n_jobs` Montage workflows with Poisson
/// arrivals of rate `spec.lambda`, raw inputs placed on `input_sites`.
pub fn generate(spec: &WorkloadSpec, input_sites: &[usize], rng: &mut Rng) -> Vec<JobSpec> {
    assert!(!input_sites.is_empty(), "need input sites");
    let mut jobs = Vec::with_capacity(spec.n_jobs);
    let mut t = 0.0f64;
    for id in 0..spec.n_jobs {
        t += rng.exponential(spec.lambda);
        let n_tasks = draw_size(spec, rng);
        let job = montage_dag(id, t as u64, n_tasks, spec, input_sites, rng);
        debug_assert!(job.validate().is_ok());
        jobs.push(job);
    }
    jobs
}

/// Draw a job's task count from the Facebook-trace size mix. Crate-visible
/// so `workload::source::GenSource` can replicate [`generate`]'s exact draw
/// sequence incrementally.
pub(crate) fn draw_size(spec: &WorkloadSpec, rng: &mut Rng) -> usize {
    let weights: Vec<f64> = spec.size_classes.iter().map(|c| c.0).collect();
    let class = rng.weighted_index(&weights);
    let (lo, hi) = spec.size_classes[class].1;
    rng.range_usize(lo, hi)
}

/// Build one Montage-shaped DAG with ~`n_tasks` tasks.
pub fn montage_dag(
    id: usize,
    arrival: u64,
    n_tasks: usize,
    spec: &WorkloadSpec,
    input_sites: &[usize],
    rng: &mut Rng,
) -> JobSpec {
    let n_tasks = n_tasks.max(1);
    // Level split: ~50% project, ~30% overlap, ~15% background, rest add.
    let n_proj = ((n_tasks as f64) * 0.5).ceil().max(1.0) as usize;
    let n_over = ((n_tasks as f64) * 0.3).round().max(0.0) as usize;
    let n_bg = ((n_tasks as f64) * 0.15).round().max(0.0) as usize;
    let n_add = n_tasks.saturating_sub(n_proj + n_over + n_bg).max(1);

    let mut tasks: Vec<TaskSpec> = Vec::with_capacity(n_proj + n_over + n_bg + n_add);
    let per_task = rng.range_f64(spec.datasize.0, spec.datasize.1) / n_proj as f64;

    // L0: projections over raw tiles (1-3 scattered input partitions each)
    for _ in 0..n_proj {
        let idx = tasks.len();
        let n_parts = rng.range_usize(1, 3.min(input_sites.len()));
        let mut locs = Vec::with_capacity(n_parts);
        for _ in 0..n_parts {
            locs.push(*rng.choose(input_sites));
        }
        tasks.push(TaskSpec {
            idx,
            op: OpKind::Map,
            datasize: per_task * rng.range_f64(0.5, 1.5),
            deps: vec![],
            input_locations: locs,
        });
    }
    // L1: overlaps — each depends on 2 adjacent projections
    let proj_range = 0..n_proj;
    for k in 0..n_over {
        let idx = tasks.len();
        let a = proj_range.start + k % n_proj;
        let b = proj_range.start + (k + 1) % n_proj;
        let deps = if a == b { vec![a] } else { vec![a.min(b), a.max(b)] };
        let dep_data: f64 = deps.iter().map(|&d| tasks[d].datasize).sum::<f64>() * 0.4;
        tasks.push(TaskSpec {
            idx,
            op: OpKind::Shuffle,
            datasize: dep_data.max(1.0),
            deps,
            input_locations: vec![],
        });
    }
    // L2: background correction — fan-in over a window of overlaps (or
    // projections when there are no overlaps)
    let (lvl_start, lvl_len) = if n_over > 0 {
        (n_proj, n_over)
    } else {
        (0, n_proj)
    };
    for k in 0..n_bg {
        let idx = tasks.len();
        let fan = rng.range_usize(2, 4.min(lvl_len).max(2));
        let mut deps: Vec<usize> = (0..fan)
            .map(|j| lvl_start + (k * 3 + j) % lvl_len)
            .collect();
        deps.sort_unstable();
        deps.dedup();
        let dep_data: f64 = deps.iter().map(|&d| tasks[d].datasize).sum::<f64>() * 0.3;
        tasks.push(TaskSpec {
            idx,
            op: OpKind::Iterate,
            datasize: dep_data.max(1.0),
            deps,
            input_locations: vec![],
        });
    }
    // L3: final mosaic add(s) — depend on everything in the previous level
    let (prev_start, prev_len) = if n_bg > 0 {
        (n_proj + n_over, n_bg)
    } else if n_over > 0 {
        (n_proj, n_over)
    } else {
        (0, n_proj)
    };
    for _ in 0..n_add {
        let idx = tasks.len();
        let deps: Vec<usize> = (prev_start..prev_start + prev_len).collect();
        let dep_data: f64 = deps.iter().map(|&d| tasks[d].datasize).sum::<f64>() * 0.2;
        tasks.push(TaskSpec {
            idx,
            op: OpKind::Reduce,
            datasize: dep_data.max(1.0),
            deps,
            input_locations: vec![],
        });
    }

    JobSpec {
        id,
        name: format!("montage-{id}"),
        arrival,
        tasks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::WorkloadSpec;

    fn spec(n: usize, lambda: f64) -> WorkloadSpec {
        WorkloadSpec::scaled(n, lambda)
    }

    #[test]
    fn generates_valid_dags() {
        let mut rng = Rng::new(2);
        let jobs = generate(&spec(50, 0.07), &[0, 1, 2, 3], &mut rng);
        assert_eq!(jobs.len(), 50);
        for j in &jobs {
            j.validate().unwrap();
            assert!(j.critical_path() >= 2, "montage must be multi-stage");
        }
    }

    #[test]
    fn arrivals_are_nondecreasing_and_poissonish() {
        let mut rng = Rng::new(3);
        let lambda = 0.07;
        let jobs = generate(&spec(400, lambda), &[0, 1], &mut rng);
        for w in jobs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = jobs.last().unwrap().arrival as f64;
        let rate = jobs.len() as f64 / span;
        assert!((rate - lambda).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn size_mix_matches_facebook_trace() {
        let mut rng = Rng::new(4);
        let jobs = generate(&spec(3000, 0.07), &[0], &mut rng);
        let small = jobs.iter().filter(|j| j.n_tasks() <= 150).count() as f64;
        let frac = small / jobs.len() as f64;
        assert!((frac - 0.89).abs() < 0.03, "small frac={frac}");
    }

    #[test]
    fn tiny_jobs_work() {
        let mut rng = Rng::new(5);
        for n in 1..6 {
            let j = montage_dag(0, 0, n, &spec(1, 0.1), &[0, 1], &mut rng);
            j.validate().unwrap();
            assert!(j.n_tasks() >= 1);
        }
    }

    #[test]
    fn roots_have_input_locations() {
        let mut rng = Rng::new(6);
        let j = montage_dag(0, 0, 40, &spec(1, 0.1), &[2, 5, 7], &mut rng);
        for r in j.roots() {
            let t = &j.tasks[r];
            assert!(!t.input_locations.is_empty());
            for &l in &t.input_locations {
                assert!([2usize, 5, 7].contains(&l));
            }
        }
    }

    #[test]
    fn final_adds_depend_on_previous_level() {
        let mut rng = Rng::new(7);
        let j = montage_dag(0, 0, 60, &spec(1, 0.1), &[0], &mut rng);
        let depths = j.depths();
        let max_d = *depths.iter().max().unwrap();
        assert!(max_d >= 2);
    }
}
