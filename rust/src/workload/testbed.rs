//! Table-1 testbed workload: 88 jobs of WordCount, Iterative ML and
//! PageRank with the paper's size mix (46% small, 40% medium, 14% large)
//! and input-size ranges, arriving at ~3 jobs per 5 minutes (exponential
//! inter-arrival). Used by the Spark-on-Yarn testbed mode (Sec 5, Fig 2/3).

use super::job::{JobSpec, OpKind, TaskSpec};
use crate::util::rng::Rng;

/// Application type in the testbed mix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppKind {
    WordCount,
    IterativeMl,
    PageRank,
}

impl AppKind {
    pub const ALL: [AppKind; 3] = [AppKind::WordCount, AppKind::IterativeMl, AppKind::PageRank];

    pub fn name(&self) -> &'static str {
        match self {
            AppKind::WordCount => "wordcount",
            AppKind::IterativeMl => "iter-ml",
            AppKind::PageRank => "pagerank",
        }
    }

    /// Input-size range (MB) per Table 1, by size class 0/1/2.
    pub fn size_range(&self, class: usize) -> (f64, f64) {
        match (self, class) {
            (AppKind::WordCount, 0) => (100.0, 200.0),
            (AppKind::WordCount, 1) => (700.0, 1500.0),
            (AppKind::WordCount, _) => (3000.0, 5000.0),
            (AppKind::IterativeMl, 0) => (130.0, 300.0),
            (AppKind::IterativeMl, 1) => (1300.0, 1800.0),
            (AppKind::IterativeMl, _) => (2500.0, 4000.0),
            (AppKind::PageRank, 0) => (150.0, 400.0),
            (AppKind::PageRank, 1) => (1000.0, 2000.0),
            (AppKind::PageRank, _) => (3500.0, 6000.0),
        }
    }
}

/// Size-class mix per Table 1: (fraction, class index).
pub const SIZE_MIX: [(f64, usize); 3] = [(0.46, 0), (0.40, 1), (0.14, 2)];

/// Table-1 generation parameters.
#[derive(Clone, Debug)]
pub struct TestbedSpec {
    pub n_jobs: usize,
    /// Mean inter-arrival in time slots (paper: 3 jobs / 5 min -> 100 s).
    pub mean_interarrival: f64,
    /// Data units per map task (controls task counts).
    pub split_mb: f64,
    pub seed: u64,
}

impl Default for TestbedSpec {
    fn default() -> Self {
        TestbedSpec {
            n_jobs: 88,
            mean_interarrival: 100.0,
            split_mb: 128.0,
            seed: 505,
        }
    }
}

/// Generate the testbed workload with raw inputs scattered over `sites`.
pub fn generate(spec: &TestbedSpec, sites: &[usize], rng: &mut Rng) -> Vec<JobSpec> {
    let mut jobs = Vec::with_capacity(spec.n_jobs);
    let mut t = 0.0f64;
    for id in 0..spec.n_jobs {
        t += rng.exponential(1.0 / spec.mean_interarrival);
        let app = *rng.choose(&AppKind::ALL);
        let weights: Vec<f64> = SIZE_MIX.iter().map(|m| m.0).collect();
        let class = SIZE_MIX[rng.weighted_index(&weights)].1;
        let (lo, hi) = app.size_range(class);
        let input_mb = rng.range_f64(lo, hi);
        let job = build_app(id, t as u64, app, input_mb, spec.split_mb, sites, rng);
        debug_assert!(job.validate().is_ok());
        jobs.push(job);
    }
    jobs
}

/// Build one application DAG.
pub fn build_app(
    id: usize,
    arrival: u64,
    app: AppKind,
    input_mb: f64,
    split_mb: f64,
    sites: &[usize],
    rng: &mut Rng,
) -> JobSpec {
    let n_maps = ((input_mb / split_mb).ceil() as usize).max(1);
    let mut tasks: Vec<TaskSpec> = Vec::new();
    let map_size = input_mb / n_maps as f64;
    let push_maps = |tasks: &mut Vec<TaskSpec>, op: OpKind, rng: &mut Rng| -> Vec<usize> {
        let start = tasks.len();
        for _ in 0..n_maps {
            let idx = tasks.len();
            tasks.push(TaskSpec {
                idx,
                op,
                datasize: map_size,
                deps: vec![],
                input_locations: vec![*rng.choose(sites)],
            });
        }
        (start..start + n_maps).collect()
    };
    match app {
        AppKind::WordCount => {
            // map wave -> reduce wave (n/4 reducers)
            let maps = push_maps(&mut tasks, OpKind::Map, rng);
            let n_red = (n_maps / 4).max(1);
            for r in 0..n_red {
                let idx = tasks.len();
                let deps: Vec<usize> = maps.iter().copied().filter(|m| m % n_red == r).collect();
                let dep_data: f64 = deps.iter().map(|&d| tasks[d].datasize).sum::<f64>() * 0.3;
                tasks.push(TaskSpec {
                    idx,
                    op: OpKind::Reduce,
                    datasize: dep_data.max(1.0),
                    deps,
                    input_locations: vec![],
                });
            }
        }
        AppKind::IterativeMl => {
            // gradient waves chained through a combiner, 3 iterations
            let mut prev: Vec<usize> = push_maps(&mut tasks, OpKind::Iterate, rng);
            for _ in 0..2 {
                // combine
                let idx = tasks.len();
                let dep_data: f64 =
                    prev.iter().map(|&d| tasks[d].datasize).sum::<f64>() * 0.05;
                tasks.push(TaskSpec {
                    idx,
                    op: OpKind::Reduce,
                    datasize: dep_data.max(1.0),
                    deps: prev.clone(),
                    input_locations: vec![],
                });
                let comb = idx;
                // next wave re-reads the (cached) partitions + model
                let start = tasks.len();
                for k in 0..n_maps {
                    let idx = tasks.len();
                    tasks.push(TaskSpec {
                        idx,
                        op: OpKind::Iterate,
                        datasize: map_size * 0.9,
                        deps: vec![comb],
                        input_locations: vec![sites[k % sites.len()]],
                    });
                }
                prev = (start..start + n_maps).collect();
            }
            let idx = tasks.len();
            let dep_data: f64 = prev.iter().map(|&d| tasks[d].datasize).sum::<f64>() * 0.05;
            tasks.push(TaskSpec {
                idx,
                op: OpKind::Reduce,
                datasize: dep_data.max(1.0),
                deps: prev,
                input_locations: vec![],
            });
        }
        AppKind::PageRank => {
            // contribution waves with shuffles, 2 supersteps
            let mut prev: Vec<usize> = push_maps(&mut tasks, OpKind::Map, rng);
            for _ in 0..2 {
                let n_shuf = (n_maps / 2).max(1);
                let start = tasks.len();
                for s in 0..n_shuf {
                    let idx = tasks.len();
                    let deps: Vec<usize> =
                        prev.iter().copied().filter(|p| p % n_shuf == s).collect();
                    let dep_data: f64 =
                        deps.iter().map(|&d| tasks[d].datasize).sum::<f64>() * 0.5;
                    tasks.push(TaskSpec {
                        idx,
                        op: OpKind::Shuffle,
                        datasize: dep_data.max(1.0),
                        deps,
                        input_locations: vec![],
                    });
                }
                prev = (start..start + n_shuf).collect();
            }
            let idx = tasks.len();
            let dep_data: f64 = prev.iter().map(|&d| tasks[d].datasize).sum::<f64>() * 0.2;
            tasks.push(TaskSpec {
                idx,
                op: OpKind::Reduce,
                datasize: dep_data.max(1.0),
                deps: prev,
                input_locations: vec![],
            });
        }
    }
    JobSpec {
        id,
        name: format!("{}-{id}", app.name()),
        arrival,
        tasks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_generates_88_valid_jobs() {
        let mut rng = Rng::new(11);
        let jobs = generate(&TestbedSpec::default(), &[0, 1, 2], &mut rng);
        assert_eq!(jobs.len(), 88);
        for j in &jobs {
            j.validate().unwrap();
        }
    }

    #[test]
    fn all_apps_build_and_are_multistage() {
        let mut rng = Rng::new(12);
        for app in AppKind::ALL {
            let j = build_app(0, 0, app, 1000.0, 128.0, &[0, 1], &mut rng);
            j.validate().unwrap();
            assert!(j.critical_path() >= 2, "{}", app.name());
        }
    }

    #[test]
    fn iter_ml_has_three_waves() {
        let mut rng = Rng::new(13);
        let j = build_app(0, 0, AppKind::IterativeMl, 500.0, 128.0, &[0], &mut rng);
        // 3 iterate waves + 3 reduces
        let iters = j.tasks.iter().filter(|t| t.op == OpKind::Iterate).count();
        let n_maps = (500.0f64 / 128.0).ceil() as usize;
        assert_eq!(iters, 3 * n_maps);
    }

    #[test]
    fn size_mix_roughly_table1() {
        let mut rng = Rng::new(14);
        let mut spec = TestbedSpec::default();
        spec.n_jobs = 2000;
        let jobs = generate(&spec, &[0], &mut rng);
        // small jobs are <= ~400MB input -> few tasks
        let small = jobs
            .iter()
            .filter(|j| j.tasks.iter().filter(|t| t.deps.is_empty()).count() <= 4)
            .count() as f64
            / jobs.len() as f64;
        assert!((small - 0.46).abs() < 0.1, "small frac={small}");
    }

    #[test]
    fn interarrival_mean_close_to_spec() {
        let mut rng = Rng::new(15);
        let mut spec = TestbedSpec::default();
        spec.n_jobs = 2000;
        let jobs = generate(&spec, &[0], &mut rng);
        let span = jobs.last().unwrap().arrival as f64;
        let mean = span / jobs.len() as f64;
        assert!((mean - 100.0).abs() < 10.0, "mean={mean}");
    }
}
