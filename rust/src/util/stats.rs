//! Summary statistics and empirical CDFs used by the metrics layer.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Relative standard deviation (Table 2 parameterizes dispersion by RSD).
pub fn rsd(xs: &[f64]) -> f64 {
    let m = mean(xs);
    if m == 0.0 {
        0.0
    } else {
        std_dev(xs) / m
    }
}

/// Quantile with linear interpolation, q in [0,1]. Sorts a copy.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Quantile over an already-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = pos - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

/// Median convenience.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Fraction of samples <= x (empirical CDF evaluated at x).
pub fn ecdf_at(xs: &[f64], x: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().filter(|&&v| v <= x).count() as f64 / xs.len() as f64
}

/// Streaming mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Fold another accumulator in (Chan et al.'s parallel update). Used
    /// when per-replica streaming sketches pool into a scenario row.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ecdf_values() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(ecdf_at(&xs, 0.5), 0.0);
        assert_eq!(ecdf_at(&xs, 2.0), 0.5);
        assert_eq!(ecdf_at(&xs, 9.0), 1.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std_dev() - std_dev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn rsd_of_constant_is_zero() {
        assert_eq!(rsd(&[3.0, 3.0, 3.0]), 0.0);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut whole = Welford::new();
        for &x in &xs {
            whole.push(x);
        }
        let (mut a, mut b) = (Welford::new(), Welford::new());
        for &x in &xs[..3] {
            a.push(x);
        }
        for &x in &xs[3..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
        // merging into/with an empty accumulator is the identity
        let mut e = Welford::new();
        e.merge(&whole);
        assert!((e.mean() - whole.mean()).abs() < 1e-12);
        let mut w2 = whole.clone();
        w2.merge(&Welford::new());
        assert_eq!(w2.count(), whole.count());
    }
}
