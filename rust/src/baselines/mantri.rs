//! Flutter + Mantri (Ananthanarayanan et al. — OSDI'10): detection-based
//! speculation that only acts when it saves resources — duplicate a running
//! task when its remaining time exceeds twice the estimated fresh-copy time
//! (`t_rem > 2·t_new`), and kill-restart hopeless copies.

use super::flutter::Flutter;
use super::observed_rate;
use crate::sched::{Action, Assignment, SchedView, Scheduler};

pub struct Mantri {
    /// Minimum elapsed slots before a copy is judged (progress smoothing).
    warmup: u64,
    /// Monitoring cadence: the paper stresses that monitoring remote tasks
    /// across the WAN is costly and detection is delayed, so the outlier
    /// pass runs periodically, not every slot.
    monitor_every: u64,
    /// Next absolute slot the outlier pass is due, kept aligned to
    /// multiples of `monitor_every`. Under the dense core this reproduces
    /// the old `now % monitor_every == 0` gate's actions exactly: the
    /// pass runs at 0, 4, 8, ... and the only extra invocations are at
    /// post-idle-jump slots, where nothing is running yet (jumps happen
    /// only when the alive set is empty) so the pass is a no-op. Under
    /// event-skip it survives `now` jumps and doubles as the
    /// [`Scheduler::next_wake`] hint.
    next_monitor: u64,
    /// Whether this epoch left copies running (worth waking for) —
    /// including ones it just launched.
    monitoring: bool,
}

impl Mantri {
    pub fn new() -> Mantri {
        Mantri {
            warmup: 5,
            monitor_every: 4,
            next_monitor: 0,
            monitoring: false,
        }
    }
}

impl Default for Mantri {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Mantri {
    fn name(&self) -> &str {
        "flutter+mantri"
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        // base placement pass (Flutter)
        let mut out = Vec::new();
        let mut order: Vec<usize> = view.alive.to_vec();
        order.sort_by_key(|&ji| view.jobs[ji].spec.arrival);
        for &ji in &order {
            for ti in view.ready_tasks(ji) {
                Flutter::place(view, ji, ti, &mut out);
            }
        }
        // Mantri outlier pass (periodic: WAN monitoring is not free).
        // `monitoring` counts work this epoch *launched* too — the view is
        // pre-action, so freshly placed copies would otherwise go
        // unwatched until the next unrelated event.
        self.monitoring = !out.is_empty()
            || order
                .iter()
                .any(|&ji| !view.running_tasks(ji).is_empty());
        if view.now < self.next_monitor {
            return out;
        }
        // realign to the next absolute multiple (see the field docs)
        self.next_monitor = (view.now / self.monitor_every + 1) * self.monitor_every;
        for &ji in &order {
            for ti in view.running_tasks(ji) {
                let rt = &view.jobs[ji].tasks[ti];
                if rt.alive_copies() >= 2 {
                    // check for kill-restart: a copy whose remaining time
                    // dwarfs its sibling's is released (saves its slot)
                    let spec = &view.jobs[ji].spec.tasks[ti];
                    let mut rems: Vec<(f64, usize)> = rt
                        .copies
                        .iter()
                        .filter(|c| c.alive)
                        .map(|c| {
                            let rate = observed_rate(c, view.now).max(1e-9);
                            ((spec.datasize - c.processed).max(0.0) / rate, c.cluster)
                        })
                        .collect();
                    rems.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
                    if rems.len() >= 2 && rems.last().unwrap().0 > 3.0 * rems[0].0 {
                        out.push(Action::Kill {
                            job: ji,
                            task: ti,
                            cluster: rems.last().unwrap().1,
                        });
                    }
                    continue;
                }
                let spec = &view.jobs[ji].spec.tasks[ti];
                let copy = rt.copies.iter().find(|c| c.alive).unwrap();
                let elapsed = view.now.saturating_sub(copy.launched_at);
                if elapsed < self.warmup {
                    continue;
                }
                let rate = observed_rate(copy, view.now).max(1e-9);
                let t_rem = (spec.datasize - copy.processed).max(0.0) / rate;
                // fresh copy estimate on the best free cluster
                let sources = rt.sources.clone();
                if let Some((m, est)) = super::best_free_cluster(view, &sources, spec.op) {
                    let t_new = spec.datasize / est.max(1e-9);
                    // Mantri's resource-aware duplicate rule
                    if t_rem > 2.0 * t_new {
                        if view.try_reserve_slot(m) {
                            if view.try_reserve_bandwidth_full(&sources, m, est) {
                                out.push(Action::Launch(Assignment {
                                    job: ji,
                                    task: ti,
                                    cluster: m,
                                }));
                            } else {
                                view.free_slots[m] += 1;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// Event-skip hook: while copies run, ask for an epoch at the next
    /// monitoring deadline so outlier detection keeps its cadence even
    /// when no event lands on it.
    fn next_wake(&mut self, _now: u64) -> Option<u64> {
        self.monitoring.then_some(self.next_monitor)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::simulator::{SimConfig, Simulation};
    use crate::util::rng::Rng;
    use crate::workload::montage;

    #[test]
    fn mantri_completes_and_duplicates() {
        let mut rng = Rng::new(83);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(10, 0.05);
        w.datasize = (50.0, 400.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let n_tasks: u64 = jobs.iter().map(|j| j.n_tasks() as u64).sum();
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut Mantri::new());
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(res.copies_launched >= n_tasks);
    }
}
