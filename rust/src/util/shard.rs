//! Contiguous range partitioning shared by every sharded subsystem.
//!
//! Both the insurer's parallel scorer (`runtime::scorer`) and the
//! cluster-sharded simulation engine (`simulator::shard`) split an index
//! space `0..n` across worker threads. They share one partition function so
//! the boundary arithmetic — and the determinism argument that rests on it —
//! lives in exactly one place.

use std::ops::Range;

/// Partition `0..n` into `min(shards, max(n, 1))` contiguous, in-order,
/// near-equal ranges (the first `n % t` ranges take one extra element). Pure
/// function of `(n, shards)` — shard boundaries never depend on execution
/// order, which is half of the bit-identity argument for every consumer.
pub fn shard_ranges(n: usize, shards: usize) -> Vec<Range<usize>> {
    let t = shards.max(1).min(n.max(1));
    let base = n / t;
    let extra = n % t;
    let mut out = Vec::with_capacity(t);
    let mut start = 0usize;
    for i in 0..t {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_ranges_cover_in_order_and_balance() {
        for (n, t) in [(0usize, 3usize), (1, 4), (7, 3), (8, 4), (5, 1), (9, 16)] {
            let ranges = shard_ranges(n, t);
            assert_eq!(ranges.len(), t.max(1).min(n.max(1)), "n={n} t={t}");
            let mut next = 0usize;
            let mut lens: Vec<usize> = Vec::new();
            for r in &ranges {
                assert_eq!(r.start, next, "n={n} t={t}: gap or overlap");
                next = r.end;
                lens.push(r.len());
            }
            assert_eq!(next, n, "n={n} t={t}: rows dropped");
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1, "n={n} t={t}: unbalanced shards {lens:?}");
        }
    }
}
