//! Environment-backed configuration knobs.
//!
//! One generic parser replaces the per-knob copy-pasted pairs that used to
//! live in `config::spec` (`parse_score_threads`/`default_score_threads`,
//! `parse_engine_threads`/`default_engine_threads`): every knob is a *total*
//! function from an optional string to a value — absent, empty, or
//! unparsable input falls back, never errors — so a typo'd environment
//! variable degrades to the documented default instead of aborting a sweep.
//!
//! A knob is composed from a *value parser* (`&str -> Option<T>`, e.g.
//! [`thread_count`] or [`switch`]) and a fallback:
//!
//! ```ignore
//! let threads = knob::env_knob("PINGAN_SCORE_THREADS", knob::thread_count, 1);
//! let stream  = knob::parse_knob(args.get("stream-metrics"), knob::switch, false);
//! ```

/// Parse an optional knob string with `parse`, falling back on absent,
/// empty-after-trim, or unparsable input. Total: never errors.
pub fn parse_knob<T>(s: Option<&str>, parse: fn(&str) -> Option<T>, fallback: T) -> T {
    s.and_then(|x| parse(x.trim())).unwrap_or(fallback)
}

/// Read knob `var` from the environment through `parse_knob`. An unset
/// variable behaves exactly like an unparsable one: the fallback wins.
pub fn env_knob<T>(var: &str, parse: fn(&str) -> Option<T>, fallback: T) -> T {
    match std::env::var(var) {
        Ok(v) => parse_knob(Some(&v), parse, fallback),
        Err(_) => fallback,
    }
}

/// Value parser for thread-count knobs: a positive integer. Zero is
/// rejected (callers fall back to serial) — thread budgets are ≥ 1 by
/// contract everywhere in the engine.
pub fn thread_count(s: &str) -> Option<usize> {
    s.parse::<usize>().ok().filter(|&t| t >= 1)
}

/// Value parser for boolean switches: `1`/`true`/`on`/`yes` and
/// `0`/`false`/`off`/`no`, case-insensitive. Anything else falls back.
pub fn switch(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_is_total_and_falls_back() {
        assert_eq!(parse_knob(None, thread_count, 1), 1);
        assert_eq!(parse_knob(Some(""), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("  "), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("abc"), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("0"), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("-3"), thread_count, 1), 1);
        assert_eq!(parse_knob(Some("4"), thread_count, 1), 4);
        assert_eq!(parse_knob(Some(" 8 "), thread_count, 1), 8);
    }

    #[test]
    fn switch_accepts_common_spellings() {
        for on in ["1", "true", "on", "yes", "TRUE", "On", "YES"] {
            assert_eq!(switch(on), Some(true), "{on}");
        }
        for off in ["0", "false", "off", "no", "False"] {
            assert_eq!(switch(off), Some(false), "{off}");
        }
        assert_eq!(switch("maybe"), None);
        assert!(!parse_knob(Some("maybe"), switch, false));
        assert!(parse_knob(Some("maybe"), switch, true));
    }

    #[test]
    fn env_knob_reads_and_falls_back() {
        // unset → fallback (no unsafe env mutation in tests; the var name
        // is namespaced so nothing in CI sets it)
        assert_eq!(env_knob("PINGAN_KNOB_TEST_UNSET_XYZ", thread_count, 7), 7);
    }
}
