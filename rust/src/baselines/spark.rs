//! Default Spark execution: fair sharing across jobs, delay scheduling for
//! locality, one copy per task — plus the speculative variant.

use super::{best_free_cluster, observed_rate};
use crate::sched::{Action, Assignment, SchedView, Scheduler};
use crate::util::stats;
use std::collections::HashMap;

/// Slots a task waits for a local slot before settling for any cluster
/// (delay scheduling).
const LOCALITY_DELAY: u64 = 3;

/// Plain Spark (fair job sharing + delay scheduling).
pub struct Spark {
    /// (job, task) -> first slot we saw it ready (for the locality delay).
    first_seen: HashMap<(usize, usize), u64>,
    /// Earliest locality-delay expiry among tasks told to keep waiting in
    /// the last pass — the event-skip wake hint, so a task that waited out
    /// its delay gets its fallback placement even if no event fires.
    wait_deadline: Option<u64>,
}

impl Spark {
    pub fn new() -> Spark {
        Spark {
            first_seen: HashMap::new(),
            wait_deadline: None,
        }
    }

    /// Locality-aware placement: prefer clusters holding input data.
    fn place(
        &mut self,
        view: &mut SchedView<'_>,
        ji: usize,
        ti: usize,
        out: &mut Vec<Action>,
    ) -> bool {
        let sources = view.jobs[ji].tasks[ti].sources.clone();
        let op = view.jobs[ji].spec.tasks[ti].op;
        let seen = *self
            .first_seen
            .entry((ji, ti))
            .or_insert(view.now);
        // 1. local cluster with a free slot
        let local = sources
            .iter()
            .copied()
            .find(|&m| view.free_slots[m] > 0);
        let chosen = match local {
            Some(m) => Some(m),
            None if view.now.saturating_sub(seen) < LOCALITY_DELAY && !sources.is_empty() => {
                // keep waiting for locality; note the expiry for next_wake
                let expiry = seen + LOCALITY_DELAY;
                self.wait_deadline = Some(self.wait_deadline.map_or(expiry, |d| d.min(expiry)));
                None
            }
            None => best_free_cluster(view, &sources, op).map(|(m, _)| m),
        };
        if let Some(m) = chosen {
            let est = view.model.exp_rate1(&sources, m, op);
            if view.try_reserve_slot(m) {
                if view.try_reserve_bandwidth(&sources, m, est) {
                    out.push(Action::Launch(Assignment {
                        job: ji,
                        task: ti,
                        cluster: m,
                    }));
                    return true;
                }
                view.free_slots[m] += 1;
            }
        }
        false
    }

    /// Fair-share scheduling pass shared with the speculative variant.
    fn schedule_fair(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        let mut out = Vec::new();
        self.wait_deadline = None;
        let n_alive = view.alive.len().max(1);
        let fair = (view.system.total_slots() / n_alive).max(1);
        for &ji in &view.alive.to_vec() {
            let running: usize = view.jobs[ji]
                .tasks
                .iter()
                .map(|t| t.alive_copies())
                .sum();
            let mut budget = fair.saturating_sub(running);
            for ti in view.ready_tasks(ji) {
                if budget == 0 {
                    break;
                }
                if self.place(view, ji, ti, &mut out) {
                    budget -= 1;
                }
            }
        }
        out
    }
}

impl Default for Spark {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Spark {
    fn name(&self) -> &str {
        "spark"
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        self.schedule_fair(view)
    }

    fn next_wake(&mut self, _now: u64) -> Option<u64> {
        self.wait_deadline
    }

    fn on_job_retired(&mut self, job: usize) {
        // drop the job's delay-scheduling stamps: under slab recycling the
        // index will be reused, and a stale first-seen slot would skip the
        // recycled job's locality delay entirely
        self.first_seen.retain(|&(j, _), _| j != job);
    }
}

/// Spark with its default speculation: duplicate a running task when it has
/// run 1.5× longer than the median completed duration in its job and its
/// progress is below 75%.
pub struct SpeculativeSpark {
    inner: Spark,
    /// Completed task durations per job (progress-monitor state).
    durations: HashMap<usize, Vec<f64>>,
    /// Elapsed at completion, recorded via `on_task_done`.
    started: HashMap<(usize, usize), u64>,
    /// Whether the last epoch saw monitorable running work.
    monitoring: bool,
}

/// Cadence of the speculation monitor's event-skip wake: the `elapsed >
/// 1.5·median` trigger depends on wall time passing, so the monitor must
/// re-check even when no event fires.
const SPECULATION_RECHECK: u64 = 4;

impl SpeculativeSpark {
    pub fn new() -> SpeculativeSpark {
        SpeculativeSpark {
            inner: Spark::new(),
            durations: HashMap::new(),
            started: HashMap::new(),
            monitoring: false,
        }
    }
}

impl Default for SpeculativeSpark {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for SpeculativeSpark {
    fn name(&self) -> &str {
        "spark-spec"
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        let mut out = self.inner.schedule_fair(view);
        self.monitoring = false;
        // speculation pass over running tasks
        for &ji in &view.alive.to_vec() {
            let med = self
                .durations
                .get(&ji)
                .map(|d| stats::median(d))
                .unwrap_or(0.0);
            if med <= 0.0 {
                continue;
            }
            self.monitoring |= !view.running_tasks(ji).is_empty();
            for ti in view.running_tasks(ji) {
                let rt = &view.jobs[ji].tasks[ti];
                if rt.alive_copies() != 1 {
                    continue; // already speculated
                }
                let spec_t = &view.jobs[ji].spec.tasks[ti];
                let copy = rt.copies.iter().find(|c| c.alive).unwrap();
                let elapsed = view.now.saturating_sub(copy.launched_at) as f64;
                let progress = copy.processed / spec_t.datasize;
                if elapsed > 1.5 * med && progress < 0.75 {
                    let sources = rt.sources.clone();
                    if let Some((m, est)) = best_free_cluster(view, &sources, spec_t.op) {
                        // avoid re-running in the straggling cluster
                        if m != copy.cluster && observed_rate(copy, view.now) < est {
                            if view.try_reserve_slot(m) {
                                if view.try_reserve_bandwidth_full(&sources, m, est) {
                                    out.push(Action::Launch(Assignment {
                                        job: ji,
                                        task: ti,
                                        cluster: m,
                                    }));
                                } else {
                                    view.free_slots[m] += 1;
                                }
                            }
                        }
                    }
                }
            }
        }
        // remember start slots for duration bookkeeping
        for &ji in view.alive {
            for (ti, t) in view.jobs[ji].tasks.iter().enumerate() {
                if let Some(c) = t.copies.iter().find(|c| c.alive) {
                    self.started.entry((ji, ti)).or_insert(c.launched_at);
                }
            }
        }
        // the view is pre-action: work launched this epoch also needs the
        // straggler monitor once there are durations to compare against
        self.monitoring |= !self.durations.is_empty() && !out.is_empty();
        out
    }

    fn on_task_done(&mut self, job: usize, task: usize, now: u64) {
        if let Some(start) = self.started.remove(&(job, task)) {
            self.durations
                .entry(job)
                .or_default()
                .push(now.saturating_sub(start) as f64);
        }
    }

    fn next_wake(&mut self, now: u64) -> Option<u64> {
        // locality-delay expiries from the placement pass, plus a periodic
        // re-check while the straggler monitor has something to watch
        let spark = self.inner.next_wake(now);
        let monitor = self.monitoring.then_some(now + SPECULATION_RECHECK);
        match (spark, monitor) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        }
    }

    fn on_job_retired(&mut self, job: usize) {
        // duration samples and start stamps are keyed by slab index — a
        // recycled slot must start with a clean progress monitor, and on
        // million-job replays these maps would otherwise grow unbounded
        self.inner.on_job_retired(job);
        self.durations.remove(&job);
        self.started.retain(|&(j, _), _| j != job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::simulator::{SimConfig, Simulation};
    use crate::util::rng::Rng;
    use crate::workload::montage;

    fn setup(n_jobs: usize) -> (GeoSystem, Vec<crate::workload::job::JobSpec>) {
        let mut rng = Rng::new(71);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, 0.05);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        (sys.clone(), montage::generate(&w, &sites, &mut rng))
    }

    #[test]
    fn spark_finishes_everything_one_copy() {
        let (sys, jobs) = setup(8);
        let n_tasks: u64 = jobs.iter().map(|j| j.n_tasks() as u64).sum();
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut Spark::new());
        assert_eq!(res.finished_jobs, res.total_jobs);
        // plain spark restarts only failure-killed tasks
        assert!(res.copies_launched >= n_tasks);
        assert!(res.copies_launched <= n_tasks + res.copies_failed + n_tasks / 4);
    }

    #[test]
    fn speculative_spark_finishes_and_speculates() {
        let (sys, jobs) = setup(8);
        let res =
            Simulation::new(&sys, jobs, SimConfig::default()).run(&mut SpeculativeSpark::new());
        assert_eq!(res.finished_jobs, res.total_jobs);
    }

    #[test]
    fn speculation_not_worse_on_average() {
        let (sys, jobs) = setup(10);
        let plain =
            Simulation::new(&sys, jobs.clone(), SimConfig::default()).run(&mut Spark::new());
        let spec =
            Simulation::new(&sys, jobs, SimConfig::default()).run(&mut SpeculativeSpark::new());
        // speculation should not catastrophically regress (allow 60% slack —
        // the plant is stochastic and speculative copies can displace work
        // on a small testbed; the paper-level comparison lives in fig2)
        assert!(spec.avg_flowtime() <= plain.avg_flowtime() * 1.6);
    }
}
