//! Proposition 1 and Theorem 2 numeric checks.

use crate::dist::{Grid, Hist};
use crate::util::rng::Rng;

/// Check Proposition 1 on one family of copy-rate distributions: when
/// copies are added best-first (descending mean — PingAn greedily insures
/// the best available copy each round), `r(k)/k` must be non-increasing.
///
/// Returns the sequence of ratios; `Err` with the violating index if the
/// property fails beyond `tol`.
pub fn check_proposition1(hists: &[Hist], tol: f64) -> Result<Vec<f64>, usize> {
    assert!(!hists.is_empty());
    // best-first ordering by mean
    let mut order: Vec<usize> = (0..hists.len()).collect();
    order.sort_by(|&a, &b| hists[b].mean().partial_cmp(&hists[a].mean()).unwrap());
    let ratios: Vec<f64> = (1..=hists.len())
        .map(|k| {
            let refs: Vec<&Hist> = order[..k].iter().map(|&i| &hists[i]).collect();
            Hist::expected_max(&refs) / k as f64
        })
        .collect();
    match first_ratio_violation(&ratios, tol) {
        Some(k) => Err(k),
        None => Ok(ratios),
    }
}

/// The violation detector underneath [`check_proposition1`]: scan a
/// `ratios[k-1] = r(k)/k` sequence and return the 1-based `k` of the first
/// entry exceeding its predecessor by more than `tol`, if any.
pub fn first_ratio_violation(ratios: &[f64], tol: f64) -> Option<usize> {
    ratios.windows(2).position(|w| w[1] > w[0] + tol).map(|i| i + 2)
}

/// Random family generator for property checks.
pub fn random_family(rng: &mut Rng, n: usize, grid: &Grid) -> Vec<Hist> {
    (0..n)
        .map(|_| {
            let mean = rng.range_f64(1.0, 9.0);
            let std = rng.range_f64(0.2, 2.5);
            Hist::normal(grid, mean, std)
        })
        .collect()
}

/// Theorem 2's competitive-ratio expression with speed augmentation 1+ε:
/// `(α(1+ε) + C) / (αε² + (α−1)ε)` where α > 1/(1+ε) is the rate-floor
/// fraction and C the adversary's max copy count.
pub fn competitive_ratio(epsilon: f64, alpha: f64, c_max: f64) -> f64 {
    assert!(epsilon > 0.0 && epsilon < 1.0);
    assert!(
        alpha > 1.0 / (1.0 + epsilon),
        "alpha must exceed 1/(1+eps) for the bound to hold"
    );
    (alpha * (1.0 + epsilon) + c_max) / (alpha * epsilon * epsilon + (alpha - 1.0) * epsilon)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proposition1_holds_on_random_families() {
        let grid = Grid::uniform(0.0, 10.0, 96);
        let mut rng = Rng::new(101);
        for trial in 0..50 {
            let fam = random_family(&mut rng, 6, &grid);
            let ratios = check_proposition1(&fam, 1e-9)
                .unwrap_or_else(|k| panic!("trial {trial}: violated at k={k}"));
            assert_eq!(ratios.len(), 6);
            // r(1) is the best single mean
            let best = fam
                .iter()
                .map(|h| h.mean())
                .fold(f64::NEG_INFINITY, f64::max);
            assert!((ratios[0] - best).abs() < 1e-9);
        }
    }

    #[test]
    fn proposition1_catches_violations() {
        // A genuine violation fixture injects the ratio sequence directly
        // into the detector the end-to-end check runs on. (Composing real
        // hists to violate r(k)/k monotonicity requires adversarially
        // skewed families — Proposition 1 guarantees only r(k)/k <= r(1)
        // in general, and the copy-rate families the insurer scores behave
        // monotonically, as `proposition1_holds_on_random_families`
        // attests — so the detector is exercised on sequences.)
        assert_eq!(first_ratio_violation(&[5.0, 2.5, 3.0], 1e-9), Some(3));
        assert_eq!(first_ratio_violation(&[5.0, 6.0], 1e-9), Some(2));
        assert_eq!(first_ratio_violation(&[5.0, 2.5, 1.9], 1e-9), None);
        // tolerance gates the detector
        assert_eq!(first_ratio_violation(&[1.0, 1.0 + 1e-12], 1e-9), None);
        assert_eq!(first_ratio_violation(&[1.0, 1.1], 0.2), None);
        // and a legitimate family stays clean even at zero tolerance
        let grid = Grid::uniform(0.0, 10.0, 21); // step 0.5: 5.0 is on-grid
        let fam = vec![Hist::point(&grid, 5.0), Hist::point(&grid, 5.0)];
        let ratios = check_proposition1(&fam, 0.0).unwrap();
        assert!((ratios[0] - 5.0).abs() < 1e-9);
        assert!((ratios[1] - 2.5).abs() < 1e-9);
        // end-to-end Err plumbing: a negative tolerance demanding a
        // steeper decrease than the real 5.0 -> 2.5 reports k = 2
        assert_eq!(check_proposition1(&fam, -3.0), Err(2));
    }

    #[test]
    fn competitive_ratio_decreases_in_epsilon() {
        let alpha = 0.95;
        let mut prev = f64::INFINITY;
        for &eps in &[0.2, 0.4, 0.6, 0.8] {
            let r = competitive_ratio(eps, alpha, 4.0);
            assert!(r.is_finite() && r > 0.0);
            assert!(r < prev, "ratio must shrink as eps grows");
            prev = r;
        }
    }

    #[test]
    #[should_panic]
    fn competitive_ratio_rejects_small_alpha() {
        // alpha <= 1/(1+eps) invalidates Eq. (40)'s sign argument
        competitive_ratio(0.5, 0.6, 1.0);
    }

    #[test]
    fn ratio_matches_paper_order_of_magnitude() {
        // eps=0.6, alpha→1, C=4: bound should be a small constant factor
        let r = competitive_ratio(0.6, 0.999, 4.0);
        assert!(r > 1.0 && r < 20.0, "r={r}");
    }
}
