//! The dual-mode time core: orchestration of one simulation run.
//!
//! Since the cluster-sharding refactor this file is a *thin orchestrator*:
//! all per-cluster plant state (ledgers, failure gaps, AR(1) congestion)
//! lives in [`super::shard::EngineShards`], which both time cores advance
//! through a deterministic barrier between policy epochs (see
//! [`super::shard`] for the bit-identity contract). The two cores share
//! every mechanism (arrivals, failures, launches, completions):
//!
//! * **[`TimeModel::Dense`]** — the slotted loop: every slot the shards
//!   redraw the stochastic processes, then the policy is invoked and every
//!   alive copy advances one increment. [`Simulation::step`] *is* that
//!   engine's step.
//! * **[`TimeModel::EventSkip`]** — an event-queue core
//!   ([`super::events`]): copies progress at constant rate so the next
//!   completion is closed form, failures are sampled as geometric gaps
//!   and the AR(1) load advances in closed form over skipped slots
//!   ([`super::processes`]); `now` jumps straight to the earliest event.
//!   Statistically equivalent to `Dense` under paired seeds, and empty
//!   slots cost nothing.

use std::sync::Arc;
use std::time::Instant;

use crate::cluster::GeoSystem;
use crate::config::spec::{BandwidthModel, TimeModel};
use crate::metrics::flowstats::FlowStats;
use crate::obs::{Counters, CountersCell, SpanKind, Spans, SpansSnapshot};
use crate::perfmodel::PerfModel;
use crate::sched::{Action, Assignment, SchedView, Scheduler};
use crate::simulator::bandwidth::{
    egress_gate, ingress_gate, wan_gate, FairShare, IncrementalFairShare, Transfer,
};
use crate::simulator::events::{Event, ShardedEventQueue};
use crate::simulator::processes;
use crate::simulator::shard::EngineShards;
use crate::simulator::state::{CopyRt, JobRt, TaskState};
use crate::util::rng::Rng;
use crate::workload::job::JobSpec;
use crate::workload::source::{EagerSource, SourcePoll, WorkloadSource};

/// Engine knobs.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Hard wall on simulated slots (guards non-terminating policies).
    pub max_slots: u64,
    /// Grid resolution handed to the performance modeler.
    pub grid_bins: usize,
    pub seed: u64,
    /// Which time core drives the run (`Dense` is the default and the
    /// bit-reproducible reference; `EventSkip` jumps over empty slots).
    pub time_model: TimeModel,
    /// Thread budget (≥ 1) for intra-slot policy scoring, handed to the
    /// scheduler through `SchedView::score_threads` — the first
    /// concurrency *inside* one simulation cell (the sweep runner already
    /// parallelizes across cells; the two compose). PingAn shards each
    /// round's `ScoreBatch` across this many OS threads with bit-identical
    /// admissions at any value, so this knob only moves wall time.
    /// Defaults to the `PINGAN_SCORE_THREADS` env var, else 1.
    pub score_threads: usize,
    /// Thread budget (≥ 1) for advancing the engine's cluster shards
    /// between policy epochs — failure sampling, AR(1) congestion, and
    /// bulk copy-progress sync fan out across this many OS threads
    /// (`simulator::shard`). Action streams are bit-identical at any
    /// value (each cluster draws from its own RNG stream; merges are in
    /// shard order), so like `score_threads` this knob only moves wall
    /// time. Defaults to the `PINGAN_ENGINE_THREADS` env var, else 1.
    pub engine_threads: usize,
    /// Record wall-clock spans (Plane B of [`crate::obs`]): scheduling
    /// latency, shard advance, barrier wait. Deterministic counters
    /// (Plane A) are always kept — they are a handful of integer bumps —
    /// but span recording reads the clock on the hot path, so benches
    /// compare `telemetry` on/off to gate the overhead. Neither plane
    /// touches any RNG, so this flag cannot change results.
    pub telemetry: bool,
    /// Bounded-memory mode for million-job replays: drop the per-job
    /// `SimResult::flowtimes` Vec (the streaming [`FlowStats`] sketch is
    /// kept either way) and recycle the `JobRt` slab slots of finished
    /// jobs, so resident state is O(clusters + alive jobs) instead of
    /// O(total jobs). Statistics are folded in at job-completion time in
    /// *both* modes, so `SimResult::stats` is bit-identical whether this
    /// flag is on or off — it only trades the raw Vec (and exact
    /// percentiles) for bounded memory. Defaults to the
    /// `PINGAN_STREAM_METRICS` env var, else off.
    pub stream_metrics: bool,
    /// Bandwidth physics (`constant` | `shared`). Under `Constant` —
    /// the default, and the pre-contention reference — a copy's rate is
    /// its launch draw forever. Under `Shared` every remote stream is an
    /// active transfer in a max-min fair-share solver over cluster
    /// ingress/egress gates and WAN links
    /// ([`crate::simulator::bandwidth`]); rates are re-solved and applied
    /// at each policy-epoch barrier (serial phase only — the barrier-only
    /// re-rate contract in [`crate::simulator::shard`] keeps Action
    /// streams bit-identical at any `engine_threads`). An *environment*
    /// knob: it changes results, so paired constant-vs-shared sweep cells
    /// share their plant/workload seeds. Defaults to the
    /// `PINGAN_BANDWIDTH_MODEL` env var, else `Constant`.
    pub bandwidth_model: BandwidthModel,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            max_slots: 2_000_000,
            grid_bins: 64,
            seed: 99,
            time_model: TimeModel::Dense,
            score_threads: crate::config::spec::default_score_threads(),
            engine_threads: crate::config::spec::default_engine_threads(),
            telemetry: true,
            stream_metrics: crate::config::spec::default_stream_metrics(),
            bandwidth_model: crate::config::spec::default_bandwidth_model(),
        }
    }
}

/// The engine's handle on the fair-share solver (shared bandwidth model
/// only): the incremental backend plus the transfer-id → copy owner map.
/// All operations happen in serial engine phases (launch application,
/// copy teardown, the barrier re-rate) — never inside a shard advance.
struct BwPlane {
    solver: IncrementalFairShare,
    /// Transfer id → (job slab slot, task, copy index). Copy indices stay
    /// stable while any copy is alive: the engine only compacts a task's
    /// copy Vec when *all* its copies are dead.
    owners: std::collections::BTreeMap<u64, (usize, usize, usize)>,
    next_id: u64,
    /// WAN link gates registered so far (lazily, first transfer on the
    /// pair), so re-registration never clobbers a live solve.
    wan_gates: std::collections::BTreeSet<u64>,
}

impl BwPlane {
    fn new(system: &GeoSystem) -> BwPlane {
        let mut solver = IncrementalFairShare::new();
        let n = system.n();
        for (m, c) in system.clusters.iter().enumerate() {
            solver.set_gate(ingress_gate(m), c.ingress);
            solver.set_gate(egress_gate(n, m), c.egress);
        }
        BwPlane {
            solver,
            owners: std::collections::BTreeMap::new(),
            next_id: 0,
            wan_gates: std::collections::BTreeSet::new(),
        }
    }

    /// Retire a copy's transfer (no-op for local-only copies). Takes the
    /// copy by reference so call sites holding disjoint borrows of
    /// `Simulation::jobs` can release inline.
    fn release(&mut self, c: &CopyRt) {
        if let Some(id) = c.bw_id {
            self.solver.finish(id);
            self.owners.remove(&id);
        }
    }
}

/// Result of one run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub scheduler: String,
    /// Per-job flowtimes f_i - a_i (slots), in admission (= arrival)
    /// order; `NaN` for jobs alive when the run hit the wall. **Empty
    /// under [`SimConfig::stream_metrics`]** — the raw Vec is exactly the
    /// O(jobs) state that mode exists to shed; consumers needing only
    /// count/mean/CI/quantiles should read [`SimResult::stats`], which is
    /// populated identically in both modes.
    pub flowtimes: Vec<f64>,
    /// Streaming flowtime statistics, folded in at each job's completion
    /// slot: the accessor surface (`avg_flowtime`, `sum_flowtime`, p50/95/
    /// 99, CI) every emitter shares, available in O(1) memory even on
    /// million-job replays.
    pub stats: FlowStats,
    pub finished_jobs: usize,
    pub total_jobs: usize,
    /// Copies launched in total (resource-cost diagnostics).
    pub copies_launched: u64,
    /// Copies killed by cluster-level failures.
    pub copies_failed: u64,
    /// Slots simulated.
    pub slots: u64,
    /// Decision points the engine actually worked through: stepped slots
    /// under `Dense`, processed events (arrivals, completions, failures,
    /// policy wakes) under `EventSkip`. `events_processed / slots` is the
    /// skip efficiency — observable without a profiler.
    pub events_processed: u64,
    /// Plane-A telemetry: deterministic event counters (engine + policy,
    /// merged). Bit-identical at any thread count — safe to
    /// equality-check (see [`crate::obs`]).
    pub telemetry: Counters,
    /// Plane-B telemetry: wall-clock span histograms (scheduling
    /// latency, shard advance, barrier wait, scorer batches).
    /// Non-deterministic by construction — must stay out of
    /// equality-checked output, exactly like `wall_secs`.
    pub spans: SpansSnapshot,
}

impl SimResult {
    /// Mean flowtime over *finished* jobs (0.0 when none finished).
    /// Routed through [`SimResult::stats`] so every emitter agrees;
    /// before the streaming-metrics redesign this averaged the raw Vec
    /// and went `NaN` as soon as one job missed the wall.
    pub fn avg_flowtime(&self) -> f64 {
        self.stats.mean()
    }

    /// Sum of finished jobs' flowtimes (same finite-only convention as
    /// [`SimResult::avg_flowtime`]).
    pub fn sum_flowtime(&self) -> f64 {
        self.stats.sum()
    }

    /// Build a result carrying only flowtimes — tests and synthetic
    /// fixtures; every other field is zero/empty.
    pub fn synthetic(scheduler: &str, flowtimes: Vec<f64>) -> SimResult {
        let finished = flowtimes.iter().filter(|f| f.is_finite()).count();
        SimResult {
            scheduler: scheduler.to_string(),
            stats: FlowStats::from_flowtimes(&flowtimes),
            finished_jobs: finished,
            total_jobs: flowtimes.len(),
            flowtimes,
            copies_launched: 0,
            copies_failed: 0,
            slots: 0,
            events_processed: 0,
            telemetry: Counters::default(),
            spans: SpansSnapshot::default(),
        }
    }
}

/// One simulation: a plant, a workload, a policy.
pub struct Simulation<'a> {
    pub system: &'a GeoSystem,
    pub jobs: Vec<JobRt>,
    pub model: PerfModel,
    now: u64,
    /// The engine's *global* stream: launch-time draws only (copy power,
    /// WAN bandwidth), all made in the serial policy-application phase.
    /// Every cluster-local draw lives on that cluster's own stream inside
    /// [`EngineShards`] — the partition-independence half of the shard
    /// determinism contract.
    rng: Rng,
    cfg: SimConfig,
    /// Sharded per-cluster plant state: slot/gate ledgers, failure gaps,
    /// AR(1) congestion (the paper's premise that edges overload
    /// *persistently*: straggling is autocorrelated, not i.i.d.).
    shards: EngineShards,
    /// Alive (arrived, unfinished) job indices, maintained incrementally.
    alive: Vec<usize>,
    /// Lazy workload intake: jobs are pulled one at a time in arrival
    /// order and admitted when `now` reaches their slot, so the slab only
    /// ever holds admitted jobs (plus, under `stream_metrics`, recycled
    /// slots of finished ones).
    source: Box<dyn WorkloadSource + 'a>,
    /// The next job pulled but not yet admitted (one-spec lookahead —
    /// all the buffering lazy admission ever needs).
    pending: Option<JobSpec>,
    source_done: bool,
    /// `hint_total` captured at construction (accounting for truncated
    /// runs that never drained the source).
    hint_total: Option<usize>,
    /// Arrival slot of the last admitted job (ordering-contract check).
    last_arrival: u64,
    /// Slab slots of retired jobs, reusable for later admissions. Only
    /// populated under `cfg.stream_metrics`; LIFO pop keeps reuse
    /// deterministic.
    free_list: Vec<usize>,
    /// Jobs admitted / finished so far (the slab under-counts both once
    /// slots recycle).
    admitted: usize,
    finished: usize,
    /// Streaming flowtime statistics, fed at each job's completion slot
    /// (identically in both metric modes).
    stats: FlowStats,
    copies_launched: u64,
    copies_failed: u64,
    /// Decision points processed so far (see [`SimResult::events_processed`]).
    events_processed: u64,
    /// `now` at the previous policy invocation (drives `SchedView::elapsed`).
    last_policy_now: u64,
    /// Plane-A telemetry: deterministic engine counters (the policy keeps
    /// its own; `finish` merges the two).
    counters: Counters,
    /// Plane-B telemetry: shared wall-span histograms. The shards and the
    /// policy record into the same `Arc`, so one snapshot covers every
    /// kind. Only consulted when `cfg.telemetry` is set.
    spans: Arc<Spans>,
    /// Fair-share bandwidth plane (`Some` iff `cfg.bandwidth_model` is
    /// `Shared`): the incremental solver plus transfer ownership. Driven
    /// only from serial phases; rates land on copies in
    /// [`Simulation::apply_rerates`] at the policy-epoch barrier.
    bw: Option<BwPlane>,
    /// Optional live mirror of the Plane-A counters (`pingan serve`):
    /// when set, every policy epoch republishes the merged engine+policy
    /// counters into the cell so a concurrent stats reader sees them
    /// mid-run. `None` on every batch path — publishing never perturbs
    /// the simulation, only observes it.
    counters_cell: Option<Arc<CountersCell>>,
}

/// Fewest alive jobs worth fanning copy-progress bookkeeping out across
/// the engine threads; below this the spawn overhead dominates. Purely a
/// wall-time heuristic — the accumulate phase touches each copy
/// independently, so outputs are identical either way.
const MIN_JOBS_FOR_PARALLEL_PROGRESS: usize = 64;

impl<'a> Simulation<'a> {
    /// Eager-workload constructor: wraps `specs` in an [`EagerSource`]
    /// (stable-sorted by arrival) and runs the same lazy-admission core
    /// as [`Simulation::from_source`]. For arrival-ordered inputs — every
    /// generator in `workload::` — slab indices, Action streams and
    /// counters are bit-identical to the pre-redesign eager engine.
    pub fn new(system: &'a GeoSystem, specs: Vec<JobSpec>, cfg: SimConfig) -> Simulation<'a> {
        Simulation::from_source(system, EagerSource::new(specs), cfg)
    }

    /// Streaming constructor: jobs are pulled lazily from `source` in
    /// arrival order, so memory stays O(clusters + alive jobs) when the
    /// source itself is streaming (`GenSource`, `TraceSource`) and
    /// `cfg.stream_metrics` recycles retired slab slots.
    pub fn from_source(
        system: &'a GeoSystem,
        source: impl WorkloadSource + 'a,
        cfg: SimConfig,
    ) -> Simulation<'a> {
        let model = PerfModel::new(system, cfg.grid_bins);
        let mut shards = EngineShards::new(system, cfg.seed, cfg.engine_threads);
        let spans = Arc::new(Spans::new());
        if cfg.telemetry {
            shards.set_spans(spans.clone());
        }
        let source = Box::new(source);
        let hint_total = source.hint_total();
        let bw = match cfg.bandwidth_model {
            BandwidthModel::Constant => None,
            BandwidthModel::Shared => Some(BwPlane::new(system)),
        };
        Simulation {
            system,
            jobs: Vec::new(),
            model,
            now: 0,
            rng: Rng::new(cfg.seed),
            cfg,
            shards,
            alive: Vec::new(),
            source,
            pending: None,
            source_done: false,
            hint_total,
            last_arrival: 0,
            free_list: Vec::new(),
            admitted: 0,
            finished: 0,
            stats: FlowStats::new(),
            copies_launched: 0,
            copies_failed: 0,
            events_processed: 0,
            last_policy_now: 0,
            counters: Counters::default(),
            spans,
            bw,
            counters_cell: None,
        }
    }

    /// Share the run's wall-span sheet with a concurrent observer.
    /// [`Spans`] is interior-mutable behind the `Arc`, so `serve` can
    /// snapshot scheduling latency mid-run from another thread while the
    /// engine keeps recording.
    pub fn spans_handle(&self) -> Arc<Spans> {
        self.spans.clone()
    }

    /// Mirror the Plane-A counters into `cell` at every policy epoch (and
    /// once more at `finish`), for concurrent stats readers. Batch runs
    /// never call this; the deterministic counters themselves are
    /// untouched either way.
    pub fn publish_counters(&mut self, cell: Arc<CountersCell>) {
        self.counters_cell = Some(cell);
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    /// Jobs admitted from the source so far.
    pub fn admitted_jobs(&self) -> usize {
        self.admitted
    }

    /// Jobs fully finished so far (the slab under-counts this once
    /// `stream_metrics` recycles slots).
    pub fn finished_jobs(&self) -> usize {
        self.finished
    }

    /// Arrival slot of the next unadmitted job, pulling it from the
    /// source if needed — *without blocking*: a live source with nothing
    /// queued yet answers [`SourcePoll::Pending`], which leaves `pending`
    /// empty and the source open. `None` therefore means "no job visible
    /// right now", and only together with `source_done` does it mean
    /// "drained". Batch sources never answer `Pending`, so for them the
    /// two readings coincide exactly as before.
    fn peek_arrival(&mut self) -> Option<u64> {
        if self.pending.is_none() && !self.source_done {
            match self.source.poll_job(false) {
                SourcePoll::Job(spec) => self.pending = Some(spec),
                SourcePoll::Done => self.source_done = true,
                SourcePoll::Pending => {}
            }
        }
        self.pending.as_ref().map(|s| s.arrival)
    }

    /// Whether any job has yet to be admitted.
    fn arrivals_pending(&mut self) -> bool {
        self.peek_arrival().is_some()
    }

    /// Admit every pending job whose arrival slot has been reached,
    /// returning the slab slots assigned (in admission order — the
    /// event core grows its epoch table from them). Shared by both time
    /// cores; only `ev_arrivals` is counted here (the dense core charges
    /// one decision point per *slot*, the event core one per arrival —
    /// each adds its own).
    fn admit_pending(&mut self) -> Vec<usize> {
        let mut admitted = Vec::new();
        while let Some(at) = self.peek_arrival() {
            if at > self.now {
                break;
            }
            let spec = self.pending.take().expect("peeked");
            debug_assert!(
                spec.arrival >= self.last_arrival,
                "source yielded arrivals out of order ({} after {})",
                spec.arrival,
                self.last_arrival
            );
            self.last_arrival = spec.arrival;
            let mut rt = JobRt::new(spec);
            rt.arrived = true;
            let ji = match self.free_list.pop() {
                Some(slot) => {
                    self.jobs[slot] = rt;
                    slot
                }
                None => {
                    self.jobs.push(rt);
                    self.jobs.len() - 1
                }
            };
            self.alive.push(ji);
            self.admitted += 1;
            self.counters.ev_arrivals += 1;
            admitted.push(ji);
        }
        admitted
    }

    /// Copies launched so far (diagnostics for step-driven tests).
    pub fn copies_launched(&self) -> u64 {
        self.copies_launched
    }

    /// Copies killed by cluster failures so far.
    pub fn copies_failed(&self) -> u64 {
        self.copies_failed
    }

    /// Decision points processed so far (stepped slots or events).
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Run to completion (or `max_slots`) under `policy`, on the time
    /// core selected by [`SimConfig::time_model`].
    pub fn run(mut self, policy: &mut dyn Scheduler) -> SimResult {
        if self.cfg.telemetry {
            // one span sheet for the whole run: the policy's scorer batch
            // timings land next to the engine's scheduling/shard spans
            policy.attach_spans(self.spans.clone());
        }
        match self.cfg.time_model {
            TimeModel::Dense => self.run_dense(policy),
            TimeModel::EventSkip => self.run_events(policy),
        }
        self.finish(policy)
    }

    /// The slotted reference loop — exactly the pre-refactor `run`.
    fn run_dense(&mut self, policy: &mut dyn Scheduler) {
        while self.arrivals_pending() || !self.alive.is_empty() {
            if self.now >= self.cfg.max_slots {
                log::warn!(
                    "simulation hit max_slots={} with {} jobs alive",
                    self.cfg.max_slots,
                    self.alive.len()
                );
                break;
            }
            self.step(policy);
        }
    }

    /// Assemble the result (shared by both time cores). Finished jobs'
    /// statistics were already folded into `stats` at their completion
    /// slots; this accounts for the stragglers of truncated runs — jobs
    /// still resident but unfinished at the wall (recorded `NaN`, slab
    /// order, matching the eager path's Vec), plus jobs the source never
    /// admitted at all.
    fn finish(&mut self, policy: &dyn Scheduler) -> SimResult {
        for j in &self.jobs {
            if j.arrived && !j.is_done() {
                self.stats.record(f64::NAN);
            }
        }
        // Jobs never pulled out of the source: knowable exactly when the
        // source sized itself up front; otherwise only the one-job
        // lookahead is visible (an unsized trace cut off mid-run reports
        // admitted + 1, not the unknowable remainder).
        let unadmitted = match self.hint_total {
            Some(h) => h.saturating_sub(self.admitted),
            None => usize::from(self.pending.is_some()),
        };
        self.stats.record_unfinished(unadmitted as u64);
        let flowtimes: Vec<f64> = if self.cfg.stream_metrics {
            Vec::new()
        } else {
            self.jobs
                .iter()
                .map(|j| j.flowtime().map(|f| f as f64).unwrap_or(f64::NAN))
                .collect()
        };
        // fold the policy's Plane-A counters into the engine's
        let mut counters = self.counters.clone();
        if let Some(c) = policy.telemetry() {
            counters.merge(c);
        }
        if let Some(cell) = &self.counters_cell {
            cell.publish(&counters);
        }
        SimResult {
            scheduler: policy.name().to_string(),
            flowtimes,
            stats: std::mem::take(&mut self.stats),
            finished_jobs: self.finished,
            total_jobs: self.admitted + unadmitted,
            copies_launched: self.copies_launched,
            copies_failed: self.copies_failed,
            slots: self.now,
            events_processed: self.events_processed,
            telemetry: counters,
            spans: self.spans.snapshot(),
        }
    }

    /// The event-skip core: jump `now` to the earliest scheduled event,
    /// advance the stochastic processes over the gap in closed form, drain
    /// the slot's events in the dense engine's phase order, then invoke
    /// the policy once — *after* the slot's completions apply, so the
    /// policy at event-time t sees the state dense would first show it at
    /// t + 1 (dense schedules before its progress phase). The marginal
    /// per-slot processes are identical to the dense engine's (geometric
    /// failure gaps ≡ Bernoulli-per-slot; exact k-step AR(1) transitions),
    /// so paired-seed runs are statistically equivalent while only
    /// `events_processed` decision points — not `slots` — cost work.
    fn run_events(&mut self, policy: &mut dyn Scheduler) {
        let n = self.system.n();
        // cluster-local events live on per-shard queues; arrivals, copy
        // completions and policy wakes on the shared epoch heap
        let mut queue = ShardedEventQueue::new(self.shards.owner_table(), self.shards.n_shards());
        // One armed arrival event at a time (re-armed at the loop top once
        // the previous one drains), instead of the old
        // push-everything-up-front — O(1) queue space for arrivals and no
        // need to know the workload size. The job index is a placeholder:
        // admission pulls from the source, and with at most one arrival
        // event queued, its intra-rank tie-break key never matters (rank 0
        // still drains arrivals before every other kind at the same slot,
        // exactly like the eager core). Arming lives at the loop top —
        // not inside the Arrival drain — so a live source that answers
        // "no job yet" simply arms later, without stalling the queued
        // completions of jobs already in flight.
        let mut arrival_armed = false;
        // Copy-set epoch per task slot: bumping invalidates queued
        // completions. Grown at admission; a recycled slot's fresh epochs
        // start one past the old slot's maximum (the "epoch floor"), so a
        // stale completion aimed at the retired occupant can never match
        // the new one.
        let mut epochs: Vec<Vec<u64>> = Vec::new();
        // failure gaps + per-cluster obs_upto live inside the shards;
        // slots [0, load_upto) already absorbed into the AR(1) load
        let mut load_upto = 0u64;
        // dedupe caches: pending failure event per cluster / policy wake
        let mut fail_event_at: Vec<Option<u64>> = vec![None; n];
        let mut scheduled_wake: Option<u64> = None;

        while self.arrivals_pending() || !self.alive.is_empty() || !self.source_done {
            // (Re-)arm the single arrival placeholder the moment a pending
            // job is visible. For batch sources this is bit-identical to
            // the old arm-inside-the-drain: the next arrival is strictly
            // after the slot that admitted its predecessor, so the
            // `load_upto` clamp is the identity. A live job whose stamp
            // raced behind the already-absorbed frontier is clamped onto
            // it instead — slots below `load_upto` are closed.
            if !arrival_armed {
                if let Some(at) = self.peek_arrival() {
                    queue.push(at.max(load_upto), Event::Arrival { job: 0 });
                    arrival_armed = true;
                }
            }
            let Some(t) = queue.peek_time() else {
                if !self.source_done {
                    // Live intake, nothing in flight and nothing queued:
                    // the simulation's only possible next event is a new
                    // submission. Park on the source (CPU-free) until one
                    // lands or the intake closes.
                    match self.source.poll_job(true) {
                        SourcePoll::Job(spec) => self.pending = Some(spec),
                        SourcePoll::Done => self.source_done = true,
                        SourcePoll::Pending => {}
                    }
                    continue;
                }
                // Nothing can ever happen again: jobs alive but no copies
                // running, no arrivals pending, no wake requested. The
                // dense engine would spin empty slots to the wall.
                log::warn!(
                    "event queue drained with {} jobs alive (policy idle?)",
                    self.alive.len()
                );
                self.now = self.cfg.max_slots;
                break;
            };
            if t >= self.cfg.max_slots {
                log::warn!(
                    "simulation hit max_slots={} with {} jobs alive",
                    self.cfg.max_slots,
                    self.alive.len()
                );
                self.now = self.cfg.max_slots;
                break;
            }
            // ---- advance the skipped-slot processes to t ----
            // Idle gap: the dense engine fast-forwards without drawing —
            // the shards pause the failure process over the window
            // (geometric gaps are memoryless, so shifting the pending
            // failure is distributionally exact). Slot t itself is stepped,
            // exactly like dense steps the arrival slot it jumps to.
            // Per-shard work: idle shifts, k-step AR(1), and batch-firing
            // gap failures on empty clusters (occupied ones keep their
            // pending failure for the event at its exact slot); the
            // heartbeat observations merge back in cluster order.
            let idle = self.alive.is_empty();
            if idle {
                load_upto = load_upto.max(t);
            }
            let k = (t + 1).saturating_sub(load_upto);
            // slots strictly inside the jump never cost a decision point
            self.counters.slots_skipped += t.saturating_sub(self.now).saturating_sub(1);
            self.shards.advance_events_to(t, idle, k);
            self.counters.shard_merges += 1;
            load_upto = t + 1;
            for (m, span, fired) in self.shards.observations() {
                self.model.observe_slots(m, span, fired);
            }
            self.now = t;
            // lazy progress sync: constant rates make it exact
            self.sync_progress();
            // ---- drain every event scheduled for slot t ----
            let mut dirty: Vec<(usize, usize)> = Vec::new();
            let mut completions: Vec<(usize, usize)> = Vec::new();
            while let Some(ev) = queue.pop_at(t) {
                log::trace!("slot {t}: {} event", ev.kind());
                match ev {
                    Event::Arrival { .. } => {
                        // admit everything due at t (one decision point per
                        // job, like the one-event-per-job eager core); the
                        // next pending arrival re-arms at the loop top
                        // (strictly after t for batch sources:
                        // admit_pending drained everything ≤ t)
                        let admitted = self.admit_pending();
                        self.events_processed += admitted.len() as u64;
                        for &ji in &admitted {
                            let k = self.jobs[ji].tasks.len();
                            if ji < epochs.len() {
                                // recycled slot: floor above every epoch the
                                // old occupant's queued events could carry
                                let floor =
                                    epochs[ji].iter().copied().max().unwrap_or(0) + 1;
                                epochs[ji] = vec![floor; k];
                            } else {
                                debug_assert_eq!(ji, epochs.len());
                                epochs.push(vec![0u64; k]);
                            }
                        }
                        arrival_armed = false;
                    }
                    Event::ClusterFailure { cluster } => {
                        // valid only while the gap scalar still agrees
                        // (else the lazy walk or a fresher event owns it)
                        if self.shards.fail_next(cluster) != t {
                            continue;
                        }
                        let occupied = self.shards.is_occupied(cluster);
                        // The next gap is drawn from the failed cluster's
                        // own stream (event-drain order is global but
                        // serial, so no other cluster is perturbed).
                        self.shards.fire_failure(cluster);
                        self.model.observe_slots(cluster, 0, 1);
                        if !occupied {
                            // Nobody here to kill, but the gap was due and
                            // nothing else would advance it: fired as a
                            // heartbeat-only failure so the process never
                            // stalls (pure bookkeeping, not a decision).
                            continue;
                        }
                        self.kill_failed_copies(&[cluster], &mut dirty);
                        self.events_processed += 1;
                        self.counters.ev_failures += 1;
                    }
                    Event::CopyCompletion { job, task, epoch } => {
                        // The copy set changed since the push — or the slab
                        // slot was recycled entirely (the epoch floor makes
                        // a recycled occupant's epochs unmatchable, and the
                        // new job may have fewer tasks, hence the bounds
                        // check through `get`).
                        if epochs.get(job).and_then(|e| e.get(task)) != Some(&epoch) {
                            continue;
                        }
                        let rt = &self.jobs[job].tasks[task];
                        if rt.state != TaskState::Running || rt.alive_copies() == 0 {
                            continue;
                        }
                        // Re-validate against the *current* copy set: a
                        // failure earlier in this same slot may have killed
                        // the fastest copy before its epoch bump lands (the
                        // bump is applied at end of batch), pushing the true
                        // completion later.
                        let datasize = self.jobs[job].spec.tasks[task].datasize;
                        match rt.next_completion_slot(datasize) {
                            Some(tc) if tc <= t => {
                                completions.push((job, task));
                                self.events_processed += 1;
                            }
                            Some(_) => dirty.push((job, task)),
                            None => {}
                        }
                    }
                    Event::PolicyEpoch => {
                        if scheduled_wake == Some(t) {
                            scheduled_wake = None;
                            self.events_processed += 1;
                        }
                    }
                }
            }
            self.apply_completions(completions, policy);
            // ---- one policy epoch at the jumped-to instant ----
            let (n_actions, touched) = self.invoke_policy(policy);
            // Some emitted action bounced off the engine (slot caps, gate
            // clamps, unlucky draws): dense retries next slot with fresh
            // draws and an advanced load — mirror that with a 1-slot wake
            // (also for partial bounces; the landed siblings' completions
            // may be far away).
            let retry = touched.len() < n_actions;
            dirty.extend(touched);
            if retry && scheduled_wake.is_none_or(|s| self.now + 1 < s) {
                let w = self.now + 1;
                if w < self.cfg.max_slots {
                    queue.push(w, Event::PolicyEpoch);
                    scheduled_wake = Some(w);
                }
            }
            // ---- barrier re-rate (shared bandwidth model) ----
            // The slot's completions, failure kills and policy actions all
            // settled the transfer set, so one global fair-share solve
            // applies here — in the serial phase, after the shard merge —
            // and the re-rated tasks join `dirty` so their closed-form
            // completions re-queue through the epoch-bump machinery below.
            self.apply_rerates(Some(&mut dirty));
            // ---- re-predict completions for changed copy sets ----
            dirty.sort_unstable();
            dirty.dedup();
            for (ji, ti) in dirty {
                epochs[ji][ti] += 1;
                let rt = &self.jobs[ji].tasks[ti];
                if rt.state != TaskState::Running {
                    continue; // re-queued or done: no completion to predict
                }
                let datasize = self.jobs[ji].spec.tasks[ti].datasize;
                if let Some(tc) = rt.next_completion_slot(datasize) {
                    queue.push(
                        tc.max(t),
                        Event::CopyCompletion {
                            job: ji,
                            task: ti,
                            epoch: epochs[ji][ti],
                        },
                    );
                }
            }
            // ---- keep a failure event queued per occupied cluster ----
            for m in 0..n {
                if self.shards.is_occupied(m) {
                    let nf = self.shards.fail_next(m);
                    if nf != processes::NEVER && fail_event_at[m] != Some(nf) {
                        queue.push(nf, Event::ClusterFailure { cluster: m });
                        fail_event_at[m] = Some(nf);
                    }
                }
            }
            // ---- honor the scheduler's wake hint ----
            if let Some(w) = policy.next_wake(self.now) {
                let w = w.max(self.now + 1);
                if w < self.cfg.max_slots && scheduled_wake.is_none_or(|s| w < s) {
                    queue.push(w, Event::PolicyEpoch);
                    scheduled_wake = Some(w);
                }
            }
        }
        // Mirror dense's trailing `now += 1` after the final stepped slot,
        // so both cores report identical `slots` for an identical timeline
        // (the break paths — wall hit, drained queue — set `now` themselves).
        if self.alive.is_empty() && !self.arrivals_pending() && self.admitted > 0 {
            self.now += 1;
        }
    }

    /// Bring every alive copy's `processed` up to date with `now` (copies
    /// run at a piecewise-constant rate; the current segment's first slot
    /// counts one increment, and `progress_base` banks everything before
    /// it — under the constant bandwidth model the segment *is* the whole
    /// lifetime, making this the familiar constant-rate form). Each
    /// copy is written from its own closed form, so the sync fans out over
    /// the engine threads on big alive sets — order-free, hence identical
    /// at any thread count. (Running tasks exist only in arrived,
    /// unfinished jobs, so the chunked sweep over *all* jobs touches
    /// exactly the copies the serial alive-walk does.)
    fn sync_progress(&mut self) {
        let now = self.now;
        if self.shards.spawns() && self.alive.len() >= MIN_JOBS_FOR_PARALLEL_PROGRESS {
            let chunk = self.jobs.len().div_ceil(self.shards.threads());
            std::thread::scope(|scope| {
                for jobs in self.jobs.chunks_mut(chunk) {
                    scope.spawn(move || {
                        for job in jobs {
                            for t in job.tasks.iter_mut() {
                                if t.state != TaskState::Running {
                                    continue;
                                }
                                for c in t.copies.iter_mut().filter(|c| c.alive) {
                                    c.processed =
                                        c.progress_base + c.rate * (now + 1 - c.rate_since) as f64;
                                }
                            }
                        }
                    });
                }
            });
            return;
        }
        for &ji in &self.alive {
            for t in self.jobs[ji].tasks.iter_mut() {
                if t.state != TaskState::Running {
                    continue;
                }
                for c in t.copies.iter_mut().filter(|c| c.alive) {
                    c.processed = c.progress_base + c.rate * (now + 1 - c.rate_since) as f64;
                }
            }
        }
    }

    /// One time slot: arrivals → shard advance (congestion + failures) →
    /// schedule → progress. This is the dense engine's step (the
    /// event-skip core never calls it).
    pub fn step(&mut self, policy: &mut dyn Scheduler) {
        self.events_processed += 1;
        self.admit_pending();
        self.apply_failures();
        self.invoke_policy(policy);
        // barrier re-rate (shared bandwidth model): this slot's launches,
        // kills and failures settled the transfer set, so the fair-share
        // rates apply before the slot's progress increment
        self.apply_rerates(None);
        self.progress(policy);
        // fast-forward over idle gaps (no alive jobs, next arrival far away)
        self.now += 1;
        if self.alive.is_empty() {
            if let Some(at) = self.peek_arrival() {
                if at > self.now {
                    self.counters.slots_skipped += at - self.now;
                    self.now = at;
                }
            }
        }
    }

    /// One dense slot of the stochastic processes: the shards advance the
    /// AR(1) chains and flip the failure Bernoullis (each cluster on its
    /// own stream, fanned out over the engine threads), then the merged
    /// failed set — already in ascending cluster order — is observed and
    /// applied serially.
    fn apply_failures(&mut self) {
        let failed = self.shards.advance_dense_slot();
        self.counters.shard_merges += 1;
        self.counters.ev_failures += failed.len() as u64;
        let mut fi = 0usize;
        for m in 0..self.system.n() {
            let f = fi < failed.len() && failed[fi] == m;
            if f {
                fi += 1;
            }
            self.model.observe_slot(m, f);
        }
        if failed.is_empty() {
            return;
        }
        self.kill_failed_copies(&failed, &mut Vec::new());
    }

    /// Kill every alive copy sitting in a failed cluster (`failed` holds
    /// cluster indices); re-queue tasks that survived nowhere. Shared by
    /// the dense per-slot draw and the event-skip failure events; `dirty`
    /// collects the tasks whose copy set changed (the event core
    /// re-predicts their completions). Walks the alive set by index — no
    /// outstanding borrow of `self.alive` — and routes every teardown
    /// through [`EngineShards::release_copy`], the single ledger path.
    fn kill_failed_copies(&mut self, failed: &[usize], dirty: &mut Vec<(usize, usize)>) {
        for ai in 0..self.alive.len() {
            let ji = self.alive[ai];
            for ti in 0..self.jobs[ji].tasks.len() {
                let mut killed_any = false;
                let t = &mut self.jobs[ji].tasks[ti];
                for c in t.copies.iter_mut().filter(|c| c.alive) {
                    if failed.contains(&c.cluster) {
                        killed_any = true;
                        self.copies_failed += 1;
                        self.counters.copies_killed += 1;
                        if let Some(bw) = self.bw.as_mut() {
                            bw.release(c);
                        }
                        self.shards.release_copy(c);
                    }
                }
                if killed_any {
                    dirty.push((ji, ti));
                    if t.state == TaskState::Running && t.alive_copies() == 0 {
                        // the task survived nowhere: re-queue it
                        t.state = TaskState::Ready;
                        // progress is lost (copies restart from zero)
                        t.copies.retain(|c| c.alive);
                    }
                }
            }
        }
    }

    /// Build the scheduler's view, collect its actions and apply them.
    /// Returns how many actions the policy emitted plus the tasks whose
    /// copy set actually changed (the event-skip core re-predicts their
    /// completion events and retries all-rejected slots; the dense loop
    /// ignores both).
    fn invoke_policy(&mut self, policy: &mut dyn Scheduler) -> (usize, Vec<(usize, usize)>) {
        // Read-only facade over the shard set: PingAn and every baseline
        // see the same logical per-cluster view the monolithic engine gave
        // them, snapshotted at the barrier.
        let mut view = SchedView::over_shards(
            self.now,
            self.now.saturating_sub(self.last_policy_now),
            self.system,
            &self.model,
            &self.jobs,
            &self.alive,
            self.cfg.score_threads,
            self.cfg.bandwidth_model,
            &self.shards,
        );
        self.counters.policy_invocations += 1;
        let t0 = if self.cfg.telemetry {
            Some(Instant::now())
        } else {
            None
        };
        let actions = policy.schedule(&mut view);
        if let Some(t0) = t0 {
            self.spans.record(SpanKind::Sched, t0.elapsed());
        }
        self.last_policy_now = self.now;
        let n_actions = actions.len();
        let mut touched = Vec::new();
        for action in actions {
            match action {
                Action::Launch(a) => {
                    if self.launch_copy(a) {
                        touched.push((a.job, a.task));
                    }
                }
                Action::Kill { job, task, cluster } => {
                    if self.kill_copy(job, task, cluster) {
                        touched.push((job, task));
                    }
                }
            }
        }
        if let Some(cell) = &self.counters_cell {
            // live mirror for `pingan serve`: merged engine+policy view,
            // refreshed once per epoch (pure observation — the counters
            // the run reports are the plain fields, not the cell)
            let mut c = self.counters.clone();
            if let Some(pc) = policy.telemetry() {
                c.merge(pc);
            }
            cell.publish(&c);
        }
        (n_actions, touched)
    }

    /// Apply the fair-share solver's current rates to the copies they
    /// belong to — **the barrier-only re-rate**, and the only place copy
    /// rates ever change. No-op under the constant model. A changed rate
    /// checkpoints the copy's progress into a fresh closed-form segment
    /// (`progress_base`/`rate_since`) and bumps `rate_changes`; under the
    /// event-skip core the affected tasks additionally flow into `dirty`
    /// (counted as `rerate_invalidations`), reusing the copy-set epoch
    /// machinery to invalidate and re-queue their predicted completions.
    /// The dense core passes `None`: every slot re-checks completions
    /// anyway, so there are no predictions to invalidate.
    ///
    /// Segment start: the dense core re-rates *before* the slot's
    /// progress increment, so the new rate covers slot `now` for every
    /// copy. The event-skip core has already synced `processed` through
    /// the *end* of slot `now` at the old rate, so pre-existing copies
    /// start their new segment at `now + 1` — while copies launched this
    /// very slot (whose increment has not happened yet) start at `now`,
    /// matching dense's treatment of launch-slot progress.
    fn apply_rerates(&mut self, dirty: Option<&mut Vec<(usize, usize)>>) {
        let Some(bw) = self.bw.as_ref() else { return };
        let now = self.now;
        let event_skip = self.cfg.time_model == TimeModel::EventSkip;
        let mut touched: Vec<(usize, usize)> = Vec::new();
        for (id, new_rate) in bw.solver.rates() {
            let &(ji, ti, ci) = bw.owners.get(&id).expect("transfer without owner");
            let c = &mut self.jobs[ji].tasks[ti].copies[ci];
            debug_assert!(c.alive && c.bw_id == Some(id), "owner map out of sync");
            if c.rate.to_bits() == new_rate.to_bits() {
                continue;
            }
            c.progress_base = c.processed;
            c.rate_since = if event_skip && c.launched_at != now {
                now + 1
            } else {
                now
            };
            c.rate = new_rate;
            self.counters.rate_changes += 1;
            touched.push((ji, ti));
        }
        if let Some(dirty) = dirty {
            touched.sort_unstable();
            touched.dedup();
            self.counters.rerate_invalidations += touched.len() as u64;
            dirty.extend(touched);
        }
    }

    /// Validate and launch one copy (engine-enforced Eqs. 9–11). Returns
    /// whether the copy actually launched.
    fn launch_copy(&mut self, a: Assignment) -> bool {
        let Assignment { job, task, cluster } = a;
        if job >= self.jobs.len() || task >= self.jobs[job].tasks.len() {
            log::error!("policy referenced bogus task ({job},{task})");
            return false;
        }
        if self.shards.free(cluster) == 0 {
            return false; // slot cap (Eq. 9)
        }
        let (op, datasize) = {
            let spec = &self.jobs[job].spec.tasks[task];
            (spec.op, spec.datasize)
        };
        let _ = datasize;
        let t = &self.jobs[job].tasks[task];
        if !matches!(t.state, TaskState::Ready | TaskState::Running) {
            return false;
        }
        let sources = t.sources.clone();
        // true draws (on the engine's global stream — launches happen in
        // the serial policy phase), attenuated by the cluster's current
        // congestion
        let proc = self.system.clusters[cluster].draw_power(op.speed_skew(), &mut self.rng)
            / self.shards.load(cluster);
        let remote: Vec<usize> = sources.iter().copied().filter(|&s| s != cluster).collect();
        let trans = if sources.is_empty() {
            f64::INFINITY
        } else {
            let mut sum = 0.0;
            for &s in &sources {
                sum += self.system.draw_wan(s, cluster, &mut self.rng);
            }
            sum / sources.len() as f64
        };
        let mut rate = proc.min(trans).max(1e-6);
        // Gate bandwidth (Eqs. 10/11): the copy's remote stream is the
        // fraction of its rate fetched over the WAN. Gates are *physical
        // caps*: a stream that would exceed the remaining headroom is
        // clamped — the copy launches slower instead of being rejected
        // (rejecting would livelock policies whose only floor-admissible
        // cluster needs more than the gate's total capacity).
        let (ing_bw, eg_bw) = if remote.is_empty() {
            (0.0, Vec::new())
        } else {
            let remote_frac = remote.len() as f64 / sources.len() as f64;
            let want_stream = rate * remote_frac;
            let ing_head = (self.system.clusters[cluster].ingress
                - self.shards.ingress_used(cluster))
                .max(0.0);
            let eg_head = remote
                .iter()
                .map(|&s| {
                    (self.system.clusters[s].egress - self.shards.egress_used(s)).max(0.0)
                })
                .fold(f64::INFINITY, f64::min);
            let allowed = want_stream
                .min(ing_head)
                .min(eg_head * remote.len() as f64);
            // The stream may clamp against the gate's *capacity* (a physical
            // limit — launch slower) but not against *transient* congestion:
            // a copy squeezed below 20% of its feasible stream would crawl
            // uselessly while holding a slot, so reject and let the policy
            // retry once the gates drain.
            let ing_cap = self.system.clusters[cluster].ingress;
            let eg_cap = remote
                .iter()
                .map(|&s| self.system.clusters[s].egress)
                .fold(f64::INFINITY, f64::min);
            let cap_stream = want_stream.min(ing_cap).min(eg_cap * remote.len() as f64);
            if allowed < 0.2 * cap_stream {
                return false; // gates transiently full (Eqs. 10/11)
            }
            if allowed < want_stream {
                // the whole pipeline slows to the clamped stream
                rate = (rate * allowed / want_stream.max(1e-12)).max(1e-3);
            }
            let stream = allowed.max(0.0);
            let share = stream / remote.len() as f64;
            (stream, remote.iter().map(|&s| (s, share)).collect())
        };
        self.shards.occupy(cluster, ing_bw, &eg_bw);
        // Shared bandwidth model: copies with remote inputs become active
        // transfers in the fair-share solver. The launch `rate` is the
        // transfer's private ceiling (idle gates never speed a copy past
        // constant-model physics); gate weights mirror the reservation
        // split — the whole remote fraction on the destination ingress,
        // an even per-source share on each source egress and WAN link.
        // All solver work stays in this serial policy-application phase.
        let bw_id = match self.bw.as_mut() {
            Some(bw) if !eg_bw.is_empty() => {
                let id = bw.next_id;
                bw.next_id += 1;
                let n = self.system.n();
                let remote_frac = eg_bw.len() as f64 / sources.len() as f64;
                let per_source = remote_frac / eg_bw.len() as f64;
                let mut uses = Vec::with_capacity(1 + 2 * eg_bw.len());
                uses.push((ingress_gate(cluster), remote_frac));
                for &(s, _) in &eg_bw {
                    let wg = wan_gate(n, s, cluster);
                    if bw.wan_gates.insert(wg) {
                        bw.solver.set_gate(wg, self.system.wan_mean(s, cluster));
                    }
                    uses.push((egress_gate(n, s), per_source));
                    uses.push((wg, per_source));
                }
                bw.solver.start(Transfer::new(id, rate, uses));
                let copy_idx = self.jobs[job].tasks[task].copies.len();
                bw.owners.insert(id, (job, task, copy_idx));
                Some(id)
            }
            _ => None,
        };
        let t = &mut self.jobs[job].tasks[task];
        t.copies.push(CopyRt {
            cluster,
            rate,
            proc_speed: proc,
            trans_speed: if trans.is_finite() { trans } else { proc },
            processed: 0.0,
            launched_at: self.now,
            progress_base: 0.0,
            rate_since: self.now,
            bw_id,
            alive: true,
            ingress_bw: ing_bw,
            egress_bw: eg_bw,
        });
        t.state = TaskState::Running;
        self.copies_launched += 1;
        true
    }

    /// Kill one copy on a policy's request. Returns whether a copy died.
    fn kill_copy(&mut self, job: usize, task: usize, cluster: usize) -> bool {
        if job >= self.jobs.len() || task >= self.jobs[job].tasks.len() {
            return false;
        }
        let t = &mut self.jobs[job].tasks[task];
        if let Some(c) = t
            .copies
            .iter_mut()
            .find(|c| c.alive && c.cluster == cluster)
        {
            if let Some(bw) = self.bw.as_mut() {
                bw.release(c);
            }
            self.shards.release_copy(c);
            if t.alive_copies() == 0 && t.state == TaskState::Running {
                t.state = TaskState::Ready;
            }
            true
        } else {
            false
        }
    }

    /// Advance every alive copy by one slot; fire completions. Two phases
    /// since the sharding refactor: the accumulate (`processed += rate`)
    /// touches each copy independently, so it fans out over the engine
    /// threads on big alive sets; the completion scan stays serial in
    /// alive order, preserving the exact pre-split detection order at any
    /// thread count.
    fn progress(&mut self, policy: &mut dyn Scheduler) {
        if self.shards.spawns() && self.alive.len() >= MIN_JOBS_FOR_PARALLEL_PROGRESS {
            // Running tasks exist only in arrived, unfinished jobs, so the
            // chunked sweep over all jobs accumulates exactly the copies
            // the serial alive-walk would.
            let chunk = self.jobs.len().div_ceil(self.shards.threads());
            std::thread::scope(|scope| {
                for jobs in self.jobs.chunks_mut(chunk) {
                    scope.spawn(move || {
                        for job in jobs {
                            for t in job.tasks.iter_mut() {
                                if t.state != TaskState::Running {
                                    continue;
                                }
                                for c in t.copies.iter_mut().filter(|c| c.alive) {
                                    c.processed += c.rate;
                                }
                            }
                        }
                    });
                }
            });
        } else {
            for &ji in &self.alive {
                let job = &mut self.jobs[ji];
                for t in job.tasks.iter_mut() {
                    if t.state != TaskState::Running {
                        continue;
                    }
                    for c in t.copies.iter_mut().filter(|c| c.alive) {
                        c.processed += c.rate;
                    }
                }
            }
        }
        // completion scan: serial, in alive order
        let mut completions: Vec<(usize, usize)> = Vec::new();
        for &ji in &self.alive {
            let job = &self.jobs[ji];
            for (ti, t) in job.tasks.iter().enumerate() {
                if t.state != TaskState::Running {
                    continue;
                }
                let datasize = job.spec.tasks[ti].datasize;
                if t.copies.iter().any(|c| c.alive && c.processed >= datasize) {
                    completions.push((ji, ti));
                }
            }
        }
        self.apply_completions(completions, policy);
    }

    /// Fire detected completions and retire finished jobs — the shared
    /// tail of the dense progress phase and the event-skip batch.
    fn apply_completions(&mut self, completions: Vec<(usize, usize)>, policy: &mut dyn Scheduler) {
        for (ji, ti) in completions {
            self.complete_task(ji, ti);
            policy.on_task_done(ji, ti, self.now);
            if self.jobs[ji].is_done() {
                // the hook fires exactly once per job (only the final
                // task's completion flips `is_done`), in completion order
                // — deterministic, so policies may drop per-job state here
                policy.on_job_retired(ji);
                if self.cfg.stream_metrics {
                    // the slot becomes reusable for a *later* admission;
                    // arrivals precede completions within a slot in both
                    // cores, so a slot freed at t is never reused at t
                    self.free_list.push(ji);
                }
            }
        }
        // retire finished jobs from the alive set
        let jobs = &self.jobs;
        self.alive.retain(|&ji| !jobs[ji].is_done());
    }

    fn complete_task(&mut self, ji: usize, ti: usize) {
        // pick the winner (most processed; ties by rate)
        let (winner_cluster, winner_proc, winner_trans, sources) = {
            let t = &self.jobs[ji].tasks[ti];
            let datasize = self.jobs[ji].spec.tasks[ti].datasize;
            let (wi, w) = t
                .copies
                .iter()
                .enumerate()
                .filter(|(_, c)| c.alive)
                .max_by(|a, b| a.1.processed.partial_cmp(&b.1.processed).unwrap())
                .expect("completion without alive copy");
            // Plane-A insurance ledger: the premium is the slot-time the
            // losing copies occupied; the payout is how many slots the
            // winner beat the earliest-launched copy's own finish by.
            // Logical state only — no clock, no RNG.
            self.counters.ev_completions += 1;
            self.counters.copies_won += 1;
            for (ci, c) in t.copies.iter().enumerate().filter(|(_, c)| c.alive) {
                if ci == wi {
                    continue;
                }
                self.counters.copies_wasted += 1;
                self.counters.insurance_slots_spent +=
                    self.now.saturating_sub(c.launched_at) + 1;
            }
            if let Some(e) = t.copies.iter().filter(|c| c.alive).min_by_key(|c| c.launched_at)
            {
                if e.launched_at < w.launched_at && e.rate > 0.0 {
                    let remaining = (datasize - e.processed).max(0.0);
                    self.counters.flowtime_slots_saved += (remaining / e.rate).ceil() as u64;
                }
            }
            (w.cluster, w.proc_speed, w.trans_speed, t.sources.clone())
        };
        let op = self.jobs[ji].spec.tasks[ti].op;
        // report execution information (Fig 1b): processing + transfer speeds
        self.model.observe_proc(winner_cluster, op, winner_proc);
        for &s in &sources {
            if s != winner_cluster {
                self.model.observe_trans(s, winner_cluster, winner_trans);
            }
        }
        // free all copies
        {
            let t = &mut self.jobs[ji].tasks[ti];
            for c in t.copies.iter_mut().filter(|c| c.alive) {
                if let Some(bw) = self.bw.as_mut() {
                    bw.release(c);
                }
                self.shards.release_copy(c);
            }
            t.state = TaskState::Done;
            t.done_at = Some(self.now);
            t.output_cluster = Some(winner_cluster);
        }
        // propagate readiness (Eq. 8) and record intermediate data location
        let n_tasks = self.jobs[ji].tasks.len();
        for di in (ti + 1)..n_tasks {
            let depends = self.jobs[ji].spec.tasks[di].deps.contains(&ti);
            if !depends {
                continue;
            }
            let d = &mut self.jobs[ji].tasks[di];
            // input locations form a *set* (the paper's I_l^i): dedup so
            // wide fan-in tasks don't blow up the transfer-average math
            if !d.sources.contains(&winner_cluster) {
                d.sources.push(winner_cluster);
            }
            d.n_deps_left -= 1;
            if d.n_deps_left == 0 && d.state == TaskState::Blocked {
                d.state = TaskState::Ready;
                d.ready_at = Some(self.now);
            }
        }
        // job completion (Eq. 12): stamp it and fold the flowtime into
        // the streaming stats *now*, in completion order — the same fold
        // sequence whether stream_metrics later drops the slab entry or
        // not, which is what keeps the two modes' stats bit-identical
        if self.jobs[ji].tasks.iter().all(|t| t.state == TaskState::Done) {
            self.jobs[ji].done_at = Some(self.now);
            self.finished += 1;
            let flow = self.jobs[ji]
                .flowtime()
                .map(|f| f as f64)
                .unwrap_or(f64::NAN);
            self.stats.record(flow);
        }
    }

    /// Diagnostics for tests: current gate-usage invariant check.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (m, c) in self.system.clusters.iter().enumerate() {
            let used = c.slots - self.shards.free(m);
            let running: usize = self
                .jobs
                .iter()
                .flat_map(|j| &j.tasks)
                .flat_map(|t| &t.copies)
                .filter(|cp| cp.alive && cp.cluster == m)
                .count();
            if used != running {
                return Err(format!(
                    "cluster {m}: slot ledger {used} != alive copies {running}"
                ));
            }
            if self.shards.ingress_used(m) > c.ingress + 1e-6 {
                return Err(format!("cluster {m}: ingress oversubscribed"));
            }
            if self.shards.egress_used(m) > c.egress + 1e-6 {
                return Err(format!("cluster {m}: egress oversubscribed"));
            }
            // ledgers must equal the recomputed footprint of alive copies
            let ing_true: f64 = self
                .jobs
                .iter()
                .flat_map(|j| &j.tasks)
                .flat_map(|t| &t.copies)
                .filter(|cp| cp.alive && cp.cluster == m)
                .map(|cp| cp.ingress_bw)
                .sum();
            if (self.shards.ingress_used(m) - ing_true).abs() > 1e-6 {
                return Err(format!(
                    "cluster {m}: ingress ledger {} != recomputed {}",
                    self.shards.ingress_used(m),
                    ing_true
                ));
            }
            let eg_true: f64 = self
                .jobs
                .iter()
                .flat_map(|j| &j.tasks)
                .flat_map(|t| &t.copies)
                .filter(|cp| cp.alive)
                .flat_map(|cp| cp.egress_bw.iter())
                .filter(|(s, _)| *s == m)
                .map(|(_, bw)| bw)
                .sum();
            if (self.shards.egress_used(m) - eg_true).abs() > 1e-6 {
                return Err(format!(
                    "cluster {m}: egress ledger {} != recomputed {}",
                    self.shards.egress_used(m),
                    eg_true
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::workload::montage;

    /// Greedy one-copy policy used to exercise the engine.
    struct GreedyLocal;

    impl Scheduler for GreedyLocal {
        fn name(&self) -> &str {
            "greedy-local"
        }

        fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
            let mut out = Vec::new();
            for &ji in view.alive {
                for ti in view.ready_tasks(ji) {
                    let sources = view.jobs[ji].tasks[ti].sources.clone();
                    // best estimated cluster with a free slot
                    let op = view.jobs[ji].spec.tasks[ti].op;
                    let mut best: Option<(f64, usize)> = None;
                    for m in 0..view.system.n() {
                        if view.free_slots[m] == 0 {
                            continue;
                        }
                        let r = view.model.exp_rate1(&sources, m, op);
                        if best.map(|(b, _)| r > b).unwrap_or(true) {
                            best = Some((r, m));
                        }
                    }
                    if let Some((r, m)) = best {
                        if view.try_reserve_slot(m)
                            && view.try_reserve_bandwidth(&sources, m, r)
                        {
                            out.push(Action::Launch(Assignment {
                                job: ji,
                                task: ti,
                                cluster: m,
                            }));
                        }
                    }
                }
            }
            out
        }
    }

    fn small_setup(n_jobs: usize) -> (GeoSystem, Vec<crate::workload::job::JobSpec>) {
        let mut rng = Rng::new(41);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut wspec = WorkloadSpec::scaled(n_jobs, 0.05);
        wspec.datasize = (50.0, 400.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&wspec, &sites, &mut rng);
        (sys, jobs)
    }

    #[test]
    fn all_jobs_finish_under_greedy() {
        let (sys, jobs) = small_setup(12);
        let sim = Simulation::new(&sys, jobs, SimConfig::default());
        let res = sim.run(&mut GreedyLocal);
        assert_eq!(res.finished_jobs, res.total_jobs, "unfinished jobs");
        for f in &res.flowtimes {
            assert!(f.is_finite() && *f >= 0.0);
        }
        assert!(res.copies_launched > 0);
    }

    #[test]
    fn invariants_hold_mid_run() {
        let (sys, jobs) = small_setup(8);
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let mut policy = GreedyLocal;
        for _ in 0..200 {
            sim.step(&mut policy);
            sim.check_invariants().unwrap();
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (sys, jobs) = small_setup(6);
        let r1 = Simulation::new(&sys, jobs.clone(), SimConfig::default()).run(&mut GreedyLocal);
        let r2 = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut GreedyLocal);
        assert_eq!(r1.flowtimes, r2.flowtimes);
        assert_eq!(r1.copies_launched, r2.copies_launched);
    }

    fn event_cfg() -> SimConfig {
        let mut cfg = SimConfig::default();
        cfg.time_model = crate::config::spec::TimeModel::EventSkip;
        cfg
    }

    #[test]
    fn eventskip_finishes_everything_under_greedy() {
        let (sys, jobs) = small_setup(12);
        let res = Simulation::new(&sys, jobs, event_cfg()).run(&mut GreedyLocal);
        assert_eq!(res.finished_jobs, res.total_jobs, "unfinished jobs");
        for f in &res.flowtimes {
            assert!(f.is_finite() && *f >= 0.0);
        }
        assert!(res.copies_launched > 0);
        assert!(res.events_processed > 0);
    }

    #[test]
    fn eventskip_deterministic_given_seed() {
        let (sys, jobs) = small_setup(6);
        let r1 = Simulation::new(&sys, jobs.clone(), event_cfg()).run(&mut GreedyLocal);
        let r2 = Simulation::new(&sys, jobs, event_cfg()).run(&mut GreedyLocal);
        assert_eq!(r1.flowtimes, r2.flowtimes);
        assert_eq!(r1.copies_launched, r2.copies_launched);
        assert_eq!(r1.events_processed, r2.events_processed);
    }

    #[test]
    fn eventskip_survives_failures() {
        // cranked failure probabilities: the geometric-gap process must
        // kill copies and the re-queue path must still finish every job
        let mut rng = Rng::new(43);
        let mut spec = SystemSpec::small(5);
        for c in &mut spec.classes {
            c.unreach_p = (0.9, 0.95);
        }
        let sys = GeoSystem::generate(&spec, &mut rng);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let mut wspec = WorkloadSpec::scaled(12, 0.05);
        wspec.datasize = (800.0, 2000.0);
        let jobs = montage::generate(&wspec, &sites, &mut rng);
        let res = Simulation::new(&sys, jobs, event_cfg()).run(&mut GreedyLocal);
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(res.copies_failed > 0, "expected some failure kills");
    }

    #[test]
    fn eventskip_touches_fewer_decision_points_on_sparse_load() {
        // a sparse arrival stream: the event core must process far fewer
        // events than there are simulated slots
        let mut rng = Rng::new(44);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut wspec = WorkloadSpec::scaled(10, 0.004);
        wspec.datasize = (50.0, 300.0);
        wspec.size_classes = vec![(1.0, (2, 12))];
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&wspec, &sites, &mut rng);
        let dense = Simulation::new(&sys, jobs.clone(), SimConfig::default())
            .run(&mut GreedyLocal);
        let event = Simulation::new(&sys, jobs, event_cfg()).run(&mut GreedyLocal);
        assert_eq!(event.finished_jobs, event.total_jobs);
        assert!(
            event.events_processed * 2 < dense.slots,
            "event core processed {} events over {} dense slots",
            event.events_processed,
            dense.slots
        );
    }

    #[test]
    fn eventskip_idle_policy_terminates_without_progress() {
        struct Idle;
        impl Scheduler for Idle {
            fn name(&self) -> &str {
                "idle"
            }
            fn schedule(&mut self, _v: &mut SchedView<'_>) -> Vec<Action> {
                vec![]
            }
        }
        let (sys, jobs) = small_setup(2);
        let mut cfg = event_cfg();
        cfg.max_slots = 500;
        let res = Simulation::new(&sys, jobs, cfg).run(&mut Idle);
        assert_eq!(res.finished_jobs, 0);
        assert_eq!(res.slots, 500, "stuck runs report the wall, like dense");
    }

    #[test]
    fn dense_counts_one_decision_point_per_stepped_slot() {
        let (sys, jobs) = small_setup(6);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut GreedyLocal);
        assert!(res.events_processed > 0);
        // idle fast-forward can make `slots` exceed the stepped count,
        // never the other way around
        assert!(res.events_processed <= res.slots);
    }

    #[test]
    fn no_progress_without_policy_action() {
        struct Idle;
        impl Scheduler for Idle {
            fn name(&self) -> &str {
                "idle"
            }
            fn schedule(&mut self, _v: &mut SchedView<'_>) -> Vec<Action> {
                vec![]
            }
        }
        let (sys, jobs) = small_setup(2);
        let mut cfg = SimConfig::default();
        cfg.max_slots = 500;
        let res = Simulation::new(&sys, jobs, cfg).run(&mut Idle);
        assert_eq!(res.finished_jobs, 0);
    }

    #[test]
    fn failures_are_survivable() {
        // crank failure probabilities: jobs must still finish because the
        // engine re-queues orphaned tasks.
        let mut rng = Rng::new(43);
        let mut spec = SystemSpec::small(5);
        for c in &mut spec.classes {
            // Table-2 p is per ~20-slot task epoch; crank it so per-slot
            // failures are frequent enough to exercise the kill path
            c.unreach_p = (0.9, 0.95);
        }
        let sys = GeoSystem::generate(&spec, &mut rng);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let mut wspec = WorkloadSpec::scaled(12, 0.05);
        wspec.datasize = (800.0, 2000.0); // long tasks: real failure exposure
        let jobs = montage::generate(&wspec, &sites, &mut rng);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut GreedyLocal);
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(res.copies_failed > 0, "expected some failure kills");
    }

    #[test]
    fn score_threads_reach_every_policy_epoch() {
        struct SeesThreads {
            want: usize,
            epochs: usize,
        }
        impl Scheduler for SeesThreads {
            fn name(&self) -> &str {
                "sees-threads"
            }
            fn schedule(&mut self, v: &mut SchedView<'_>) -> Vec<Action> {
                assert_eq!(v.score_threads, self.want, "engine dropped the budget");
                self.epochs += 1;
                vec![]
            }
        }
        for time_model in crate::config::spec::TimeModel::ALL {
            let (sys, jobs) = small_setup(2);
            let mut cfg = SimConfig::default();
            cfg.max_slots = 40;
            cfg.time_model = time_model;
            cfg.score_threads = 3;
            let mut p = SeesThreads { want: 3, epochs: 0 };
            let _ = Simulation::new(&sys, jobs, cfg).run(&mut p);
            assert!(p.epochs > 0, "{time_model:?}: policy never invoked");
        }
    }

    #[test]
    fn engine_threads_are_invisible_to_results() {
        // the determinism contract at engine scope: identical SimResult
        // bits at any shard count, under both time cores
        for time_model in crate::config::spec::TimeModel::ALL {
            let mut results = Vec::new();
            for threads in [1usize, 2, 4] {
                let (sys, jobs) = small_setup(10);
                let mut cfg = SimConfig::default();
                cfg.time_model = time_model;
                cfg.engine_threads = threads;
                results.push((threads, Simulation::new(&sys, jobs, cfg).run(&mut GreedyLocal)));
            }
            let (_, base) = &results[0];
            assert_eq!(base.finished_jobs, base.total_jobs);
            for (threads, r) in &results[1..] {
                assert_eq!(
                    base.flowtimes.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    r.flowtimes.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    "{time_model:?} engine_threads={threads}: flowtimes diverged"
                );
                assert_eq!(base.copies_launched, r.copies_launched);
                assert_eq!(base.copies_failed, r.copies_failed);
                assert_eq!(base.slots, r.slots);
                assert_eq!(base.events_processed, r.events_processed);
                assert_eq!(
                    base.telemetry, r.telemetry,
                    "{time_model:?} engine_threads={threads}: Plane-A counters diverged"
                );
                assert_eq!(
                    base.stats, r.stats,
                    "{time_model:?} engine_threads={threads}: streaming stats diverged"
                );
            }
        }
    }

    #[test]
    fn shared_bandwidth_keeps_engine_threads_invisible() {
        // the shared solver couples transfers across shards through
        // common WAN gates; barrier-only re-rating must keep the
        // engine_threads contract intact under both time cores
        let mut total_rate_changes = 0u64;
        for time_model in crate::config::spec::TimeModel::ALL {
            let mut results = Vec::new();
            for threads in [1usize, 2, 4] {
                let (sys, jobs) = small_setup(10);
                let mut cfg = SimConfig::default();
                cfg.time_model = time_model;
                cfg.engine_threads = threads;
                cfg.bandwidth_model = BandwidthModel::Shared;
                results.push((threads, Simulation::new(&sys, jobs, cfg).run(&mut GreedyLocal)));
            }
            let (_, base) = &results[0];
            assert_eq!(base.finished_jobs, base.total_jobs, "{time_model:?}");
            total_rate_changes += base.telemetry.rate_changes;
            for (threads, r) in &results[1..] {
                assert_eq!(
                    base.flowtimes.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    r.flowtimes.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                    "{time_model:?} engine_threads={threads}: shared flowtimes diverged"
                );
                assert_eq!(base.copies_launched, r.copies_launched);
                assert_eq!(base.events_processed, r.events_processed);
                assert_eq!(
                    base.telemetry, r.telemetry,
                    "{time_model:?} engine_threads={threads}: shared counters diverged"
                );
            }
        }
        assert!(total_rate_changes > 0, "shared model never re-rated a copy");
    }

    #[test]
    fn shared_bandwidth_rerates_while_constant_never_does() {
        // constant runs keep the launch draw for a copy's whole life
        // (rate_changes == 0 exactly); the shared solver must engage and
        // — summed over both time cores, since a re-rate reshuffles the
        // launch draws of later epochs — never beat the uncontended
        // model on mean flowtime
        let mut total_constant = 0.0f64;
        let mut total_shared = 0.0f64;
        let mut shared_rate_changes = 0u64;
        for time_model in crate::config::spec::TimeModel::ALL {
            let (sys, jobs) = small_setup(12);
            let mut cfg = SimConfig::default();
            cfg.time_model = time_model;
            let constant =
                Simulation::new(&sys, jobs.clone(), cfg.clone()).run(&mut GreedyLocal);
            cfg.bandwidth_model = BandwidthModel::Shared;
            let shared = Simulation::new(&sys, jobs, cfg).run(&mut GreedyLocal);
            assert_eq!(constant.telemetry.rate_changes, 0, "{time_model:?}");
            assert_eq!(constant.telemetry.rerate_invalidations, 0, "{time_model:?}");
            assert_eq!(shared.finished_jobs, shared.total_jobs, "{time_model:?}");
            shared_rate_changes += shared.telemetry.rate_changes;
            total_constant +=
                constant.flowtimes.iter().sum::<f64>() / constant.flowtimes.len() as f64;
            total_shared +=
                shared.flowtimes.iter().sum::<f64>() / shared.flowtimes.len() as f64;
        }
        assert!(shared_rate_changes > 0, "contended WAN never triggered a re-rate");
        assert!(
            total_shared + 1e-6 >= total_constant,
            "shared ({total_shared}) beat constant ({total_constant}) in aggregate"
        );
    }

    #[test]
    fn shared_bandwidth_invariants_hold_mid_run() {
        // the slot/ingress/egress ledgers stay on launch-time
        // reservations — re-rates must not desync them
        for time_model in crate::config::spec::TimeModel::ALL {
            let (sys, jobs) = small_setup(8);
            let mut cfg = SimConfig::default();
            cfg.time_model = time_model;
            cfg.bandwidth_model = BandwidthModel::Shared;
            let mut sim = Simulation::new(&sys, jobs, cfg);
            let mut policy = GreedyLocal;
            for _ in 0..200 {
                sim.step(&mut policy);
                sim.check_invariants().unwrap();
            }
        }
    }

    #[test]
    fn telemetry_counters_track_the_run() {
        for time_model in crate::config::spec::TimeModel::ALL {
            let (sys, jobs) = small_setup(8);
            let mut cfg = SimConfig::default();
            cfg.time_model = time_model;
            let res = Simulation::new(&sys, jobs, cfg).run(&mut GreedyLocal);
            let c = &res.telemetry;
            assert_eq!(c.ev_arrivals, res.total_jobs as u64, "{time_model:?}");
            assert!(c.ev_completions > 0, "{time_model:?}: no completions counted");
            assert_eq!(c.copies_won, c.ev_completions, "one winner per completion");
            assert!(c.policy_invocations > 0);
            assert!(c.shard_merges > 0);
            // greedy launches one copy per task: no insurance, no waste
            assert_eq!(c.copies_wasted, 0, "{time_model:?}");
            assert_eq!(c.insurance_slots_spent, 0);
        }
    }

    #[test]
    fn telemetry_flag_only_moves_wall_spans() {
        // cfg.telemetry gates the clock reads (Plane B); Plane-A counters
        // and results must be bit-identical either way
        let (sys, jobs) = small_setup(6);
        let on = Simulation::new(&sys, jobs.clone(), SimConfig::default()).run(&mut GreedyLocal);
        let mut cfg = SimConfig::default();
        cfg.telemetry = false;
        let off = Simulation::new(&sys, jobs, cfg).run(&mut GreedyLocal);
        assert_eq!(on.flowtimes, off.flowtimes);
        assert_eq!(on.telemetry, off.telemetry);
        use crate::obs::SpanKind;
        let sched_on = on.spans.get(SpanKind::Sched).unwrap().count;
        let sched_off = off.spans.get(SpanKind::Sched).unwrap().count;
        assert!(sched_on > 0, "telemetry on: no sched spans recorded");
        assert_eq!(sched_off, 0, "telemetry off must not read the clock");
    }

    #[test]
    fn from_source_matches_eager_construction() {
        // the lazy-admission core behind from_source(EagerSource) must be
        // bit-identical to Simulation::new on arrival-ordered workloads,
        // under both time cores
        use crate::workload::source::EagerSource;
        for time_model in crate::config::spec::TimeModel::ALL {
            let (sys, jobs) = small_setup(10);
            let mut cfg = SimConfig::default();
            cfg.time_model = time_model;
            let a = Simulation::new(&sys, jobs.clone(), cfg.clone()).run(&mut GreedyLocal);
            let b = Simulation::from_source(&sys, EagerSource::new(jobs), cfg)
                .run(&mut GreedyLocal);
            assert_eq!(
                a.flowtimes.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                b.flowtimes.iter().map(|f| f.to_bits()).collect::<Vec<_>>(),
                "{time_model:?}: flowtimes diverged"
            );
            assert_eq!(a.stats, b.stats, "{time_model:?}");
            assert_eq!(a.telemetry, b.telemetry, "{time_model:?}");
            assert_eq!(a.slots, b.slots, "{time_model:?}");
            assert_eq!(a.events_processed, b.events_processed, "{time_model:?}");
            assert_eq!(a.total_jobs, b.total_jobs, "{time_model:?}");
        }
    }

    #[test]
    fn stream_metrics_mode_changes_memory_not_statistics() {
        // stream_metrics drops the Vec and recycles slab slots, but the
        // FlowStats fold happens at completion time in both modes — the
        // sketch, counters and scalar results must be bit-identical
        for time_model in crate::config::spec::TimeModel::ALL {
            let (sys, jobs) = small_setup(12);
            let mut cfg = SimConfig::default();
            cfg.time_model = time_model;
            let exact = Simulation::new(&sys, jobs.clone(), cfg.clone()).run(&mut GreedyLocal);
            cfg.stream_metrics = true;
            let streamed = Simulation::new(&sys, jobs, cfg).run(&mut GreedyLocal);
            assert!(streamed.flowtimes.is_empty(), "{time_model:?}: Vec kept");
            assert!(!exact.flowtimes.is_empty());
            assert_eq!(exact.stats, streamed.stats, "{time_model:?}");
            assert_eq!(exact.finished_jobs, streamed.finished_jobs);
            assert_eq!(exact.total_jobs, streamed.total_jobs);
            assert_eq!(exact.telemetry, streamed.telemetry, "{time_model:?}");
            assert_eq!(exact.slots, streamed.slots);
            assert_eq!(
                exact.avg_flowtime().to_bits(),
                streamed.avg_flowtime().to_bits(),
                "{time_model:?}: accessor surface diverged"
            );
        }
    }

    #[test]
    fn stream_metrics_recycles_slab_slots() {
        // drive the dense core by hand on a sparse arrival stream: jobs
        // finish before the next one arrives, so the slab must stay far
        // smaller than the total admitted count (slots get reused)
        let mut rng = Rng::new(41);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut wspec = WorkloadSpec::scaled(20, 0.005);
        wspec.datasize = (50.0, 300.0);
        wspec.size_classes = vec![(1.0, (2, 12))];
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&wspec, &sites, &mut rng);
        let total = jobs.len();
        let mut cfg = SimConfig::default();
        cfg.stream_metrics = true;
        let mut sim = Simulation::new(&sys, jobs, cfg);
        let mut policy = GreedyLocal;
        for _ in 0..50_000 {
            if sim.finished_jobs() == total {
                break;
            }
            sim.step(&mut policy);
            sim.check_invariants().unwrap();
        }
        assert_eq!(sim.finished_jobs(), total, "run did not finish");
        assert_eq!(sim.admitted_jobs(), total);
        assert!(
            sim.jobs.len() < total,
            "slab never recycled: {} slots for {} jobs",
            sim.jobs.len(),
            total
        );
    }

    #[test]
    fn bogus_actions_are_rejected() {
        struct Bogus;
        impl Scheduler for Bogus {
            fn name(&self) -> &str {
                "bogus"
            }
            fn schedule(&mut self, v: &mut SchedView<'_>) -> Vec<Action> {
                vec![
                    Action::Launch(Assignment {
                        job: 999,
                        task: 0,
                        cluster: 0,
                    }),
                    Action::Kill {
                        job: 999,
                        task: 9,
                        cluster: 0,
                    },
                    // valid-shaped launch onto a Blocked task must be dropped
                    Action::Launch(Assignment {
                        job: *v.alive.first().unwrap_or(&0),
                        task: usize::MAX - 1,
                        cluster: 0,
                    }),
                ]
            }
        }
        let (sys, jobs) = small_setup(2);
        let mut cfg = SimConfig::default();
        cfg.max_slots = 50;
        let mut sim = Simulation::new(&sys, jobs, cfg);
        let mut p = Bogus;
        for _ in 0..50 {
            sim.step(&mut p);
            sim.check_invariants().unwrap();
        }
    }

    #[test]
    fn channel_fed_run_drains_when_intake_closes() {
        // the serve drain contract: a live source fed from another thread
        // — including a mid-feed stall that leaves the engine idle with
        // jobs already in flight — must finish everything it was sent and
        // return cleanly the moment the last sender drops, with every
        // arrival accounted and no placeholder event left dangling
        let (sys, jobs) = small_setup(6);
        let n = jobs.len();
        let (tx, src) = crate::workload::source::channel();
        let feeder = std::thread::spawn(move || {
            for (i, job) in jobs.into_iter().enumerate() {
                tx.send(job).expect("engine closed intake early");
                if i == n / 2 {
                    // let the engine drain what it has and park on the
                    // blocking poll before the rest of the feed lands
                    std::thread::sleep(std::time::Duration::from_millis(25));
                }
            }
            // tx drops here: intake closes, the engine drains and returns
        });
        let res = Simulation::from_source(&sys, src, event_cfg()).run(&mut GreedyLocal);
        feeder.join().unwrap();
        assert_eq!(res.finished_jobs, n, "in-flight jobs lost at shutdown");
        assert_eq!(res.total_jobs, n);
        assert_eq!(res.telemetry.ev_arrivals, n as u64);
        assert_eq!(res.stats.unfinished(), 0);
    }

    #[test]
    fn source_ending_mid_epoch_accounts_the_shortfall() {
        // a source whose up-front hint promises more jobs than it ever
        // yields (a trace cut off mid-run): the engine must finish what it
        // got and report the shortfall as unfinished, not hang waiting
        struct Short {
            inner: EagerSource,
            hint: usize,
        }
        impl WorkloadSource for Short {
            fn next_job(&mut self) -> Option<JobSpec> {
                self.inner.next_job()
            }
            fn hint_total(&self) -> Option<usize> {
                Some(self.hint)
            }
        }
        let (sys, jobs) = small_setup(8);
        let hint = jobs.len();
        let yielded = hint - 3;
        let src = Short {
            inner: EagerSource::new(jobs.into_iter().take(yielded).collect()),
            hint,
        };
        let res = Simulation::from_source(&sys, src, event_cfg()).run(&mut GreedyLocal);
        assert_eq!(res.finished_jobs, yielded);
        assert_eq!(res.total_jobs, hint);
        assert_eq!(res.stats.unfinished(), 3);
        assert_eq!(res.telemetry.ev_arrivals, yielded as u64);
    }
}
