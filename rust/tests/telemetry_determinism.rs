//! Telemetry determinism: the zero-perturbation contract of `obs`.
//!
//! Plane A (the deterministic `Counters`) must be byte-identical at any
//! `score_threads` × `engine_threads` combination and at any sweep
//! runner thread count — counters join the equality-checked output, so
//! any drift is a test failure, not a tolerance. Plane B (wall-clock
//! spans) never appears in the compared output. And the decision trace
//! must be pure observation: running with a `TraceSink` attached may not
//! move one Action in the stream or one bit in the results.

use pingan::insurance::PingAn;
use pingan::obs::TraceSink;
use pingan::sched::{Action, Scheduler};
use pingan::simulator::{SimConfig, SimResult, Simulation, TimeModel};
use pingan::sweep::{self, Axis, Scenario, SweepSpec};

mod common {
    use pingan::cluster::GeoSystem;
    use pingan::config::spec::{SystemSpec, WorkloadSpec};
    use pingan::util::rng::Rng;
    use pingan::workload::job::JobSpec;
    use pingan::workload::montage;

    pub fn setup(
        n_clusters: usize,
        n_jobs: usize,
        lambda: f64,
        seed: u64,
    ) -> (GeoSystem, Vec<JobSpec>) {
        let mut rng = Rng::new(seed);
        let sys = GeoSystem::generate(&SystemSpec::small(n_clusters), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, lambda);
        w.datasize = (50.0, 500.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        (sys, jobs)
    }
}

/// Action-recording decorator that FORWARDS the telemetry hooks — unlike
/// the end-to-end suite's recorder, which leaves them at the trait
/// defaults. Forwarding matters here: a sink swallowed by a decorator
/// would make the trace trivially empty and the pin vacuous.
struct Recording<S> {
    inner: S,
    log: Vec<Action>,
}

impl<S: Scheduler> Scheduler for Recording<S> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn schedule(&mut self, view: &mut pingan::sched::SchedView<'_>) -> Vec<Action> {
        let actions = self.inner.schedule(view);
        self.log.extend(actions.iter().copied());
        actions
    }

    fn on_task_done(&mut self, job: usize, task: usize, now: u64) {
        self.inner.on_task_done(job, task, now)
    }

    fn next_wake(&mut self, now: u64) -> Option<u64> {
        self.inner.next_wake(now)
    }

    fn telemetry(&self) -> Option<&pingan::obs::Counters> {
        self.inner.telemetry()
    }

    fn attach_spans(&mut self, spans: std::sync::Arc<pingan::obs::Spans>) {
        self.inner.attach_spans(spans)
    }

    fn set_trace(&mut self, sink: TraceSink) {
        self.inner.set_trace(sink)
    }
}

fn run_pingan(
    lambda: f64,
    seed: u64,
    time_model: TimeModel,
    score_threads: usize,
    engine_threads: usize,
    trace: Option<TraceSink>,
) -> (Vec<Action>, SimResult) {
    let (sys, jobs) = common::setup(6, 10, lambda, 3000 + seed);
    let mut rec = Recording {
        inner: PingAn::with_epsilon(0.6),
        log: Vec::new(),
    };
    if let Some(sink) = trace {
        rec.set_trace(sink);
    }
    let mut cfg = SimConfig::default();
    cfg.seed = 0xAB ^ seed;
    cfg.time_model = time_model;
    cfg.score_threads = score_threads;
    cfg.engine_threads = engine_threads;
    let res = Simulation::new(&sys, jobs, cfg).run(&mut rec);
    (rec.log, res)
}

/// The tentpole acceptance pin: the counter block (struct equality AND
/// its JSON bytes) is invariant under every score × engine thread
/// combination, for both time cores, on the fixed-seed λ grid.
#[test]
fn counter_block_is_byte_identical_across_thread_counts() {
    for (lambda, seed) in [(0.05, 71u64), (0.10, 73), (0.15, 74)] {
        for time_model in TimeModel::ALL {
            let (base_log, base) = run_pingan(lambda, seed, time_model, 1, 1, None);
            assert_eq!(base.finished_jobs, base.total_jobs, "unfinished baseline");
            assert!(base.telemetry.insurer_rounds > 0, "insurer never ran");
            assert!(base.telemetry.rows_scored > 0, "no rows scored");
            let base_json = base.telemetry.to_json().to_string();
            for (st, et) in [(4, 1), (1, 4), (4, 4)] {
                let (log, res) = run_pingan(lambda, seed, time_model, st, et, None);
                let tag = format!("λ={lambda} seed={seed} {time_model:?} score={st} engine={et}");
                assert_eq!(log, base_log, "{tag}: action streams diverged");
                assert_eq!(res.telemetry, base.telemetry, "{tag}: counters diverged");
                assert_eq!(
                    res.telemetry.to_json().to_string(),
                    base_json,
                    "{tag}: counter JSON bytes diverged"
                );
                for (a, b) in res.flowtimes.iter().zip(&base.flowtimes) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{tag}: flowtime bits moved");
                }
            }
        }
    }
}

/// The zero-perturbation pin for the decision trace: re-run the pinned
/// Action streams with a live `TraceSink` attached. Identical actions,
/// identical result bits, identical counters — and a non-trivial trace
/// in which every record names an admit/reject reason.
#[test]
fn trace_sink_leaves_the_action_stream_pinned() {
    for (lambda, seed) in [(0.05, 71u64), (0.10, 73)] {
        for time_model in TimeModel::ALL {
            let (base_log, base) = run_pingan(lambda, seed, time_model, 1, 1, None);
            let (sink, buf) = TraceSink::in_memory();
            let (log, res) = run_pingan(lambda, seed, time_model, 1, 1, Some(sink));
            let tag = format!("λ={lambda} seed={seed} {time_model:?}");
            assert_eq!(log, base_log, "{tag}: tracing moved an action");
            assert_eq!(res.telemetry, base.telemetry, "{tag}: tracing moved a counter");
            assert_eq!(res.flowtimes.len(), base.flowtimes.len(), "{tag}");
            for (a, b) in res.flowtimes.iter().zip(&base.flowtimes) {
                assert_eq!(a.to_bits(), b.to_bits(), "{tag}: tracing moved a flowtime");
            }
            let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
            let lines: Vec<&str> = text.lines().collect();
            assert!(
                lines.len() as u64 >= base.telemetry.admissions,
                "{tag}: fewer trace records than admissions"
            );
            for line in &lines {
                let rec = pingan::util::jsonout::Json::parse(line)
                    .unwrap_or_else(|e| panic!("{tag}: bad trace line `{line}`: {e}"));
                assert!(
                    rec.get("reason").and_then(|r| r.as_str()).is_some(),
                    "{tag}: trace record without a reason: {line}"
                );
                for key in ["slot", "job", "task", "cluster"] {
                    assert!(rec.get(key).is_some(), "{tag}: record missing `{key}`");
                }
            }
        }
    }
}

fn smoke_spec() -> SweepSpec {
    let mut base = Scenario::default();
    base.n_clusters = 6;
    base.n_jobs = 10;
    base.slot_divisor = 10;
    SweepSpec::new(base)
        .axis(Axis::Lambda(vec![0.05, 0.1]))
        .axis(Axis::Scheduler(vec!["flutter".into(), "pingan".into()]))
        .reps(2)
        .seed(0xD5)
}

/// Sweep-level plane separation: per-cell counters ride in the
/// deterministic JSON and stay byte-identical across runner thread
/// counts; wall-span telemetry exists only in the full (wall-including)
/// emission.
#[test]
fn sweep_counters_are_byte_identical_across_runner_threads() {
    let spec = smoke_spec();
    let r1 = sweep::run_with(&spec, 1, None);
    let r4 = sweep::run_with(&spec, 4, None);
    assert!(r1
        .cells
        .iter()
        .all(|c| c.error.is_none() && c.finished == c.total));
    // CellResult equality now covers the telemetry counters
    assert_eq!(r1.cells, r4.cells);
    assert_eq!(r1.rows, r4.rows);
    let (j1, j4) = (r1.to_json_deterministic(), r4.to_json_deterministic());
    assert_eq!(j1.to_string(), j4.to_string(), "deterministic JSON diverged");
    let det = j1.to_string();
    assert!(det.contains("\"telemetry\""), "counters missing from JSON");
    assert!(
        !det.contains("telemetry_wall") && !det.contains("wall_secs"),
        "wall-clock leaked into deterministic JSON"
    );
    let full = r1.to_json().to_string();
    assert!(full.contains("telemetry_wall"), "full JSON lost the spans");
    // pingan cells must actually have admitted something for the
    // counter assertions above to be non-vacuous
    assert!(r1
        .cells
        .iter()
        .any(|c| c.scenario.scheduler == "pingan" && c.telemetry.admissions > 0));
}

/// A traced sweep must be outcome-identical to an untraced one, and the
/// shared sink must collect at least one reasoned record per admission.
#[test]
fn traced_sweep_matches_untraced_bit_for_bit() {
    let spec = smoke_spec();
    let base = sweep::run_with(&spec, 2, None);
    let (sink, buf) = TraceSink::in_memory();
    let traced = sweep::run_traced(&spec, 2, None, Some(&sink));
    sink.flush();
    assert_eq!(base.cells, traced.cells);
    assert_eq!(base.rows, traced.rows);
    assert_eq!(
        base.to_json_deterministic().to_string(),
        traced.to_json_deterministic().to_string()
    );
    let admissions: u64 = traced
        .cells
        .iter()
        .map(|c| c.telemetry.admissions)
        .sum();
    assert!(admissions > 0, "no pingan cell admitted a copy");
    let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert!(
        lines.len() as u64 >= admissions,
        "trace shorter than total admissions"
    );
    for line in &lines {
        assert!(line.contains("\"reason\""), "unreasoned record: {line}");
    }
}
