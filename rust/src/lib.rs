//! # PingAn — insurance-based job acceleration for geo-distributed analytics
//!
//! Reproduction of *"PingAn: An Insurance Scheme for Job Acceleration in
//! Geo-distributed Big Data Analytics System"* (Wang, Qian, Lu — 2018).
//!
//! PingAn speeds up geo-distributed data-analytics jobs by *insuring* tasks:
//! launching extra copies of a task in other clusters, chosen with an
//! efficiency-first / reliability-aware policy, so that cluster heterogeneity,
//! overload and cluster-level unreachability do not stall jobs.
//!
//! The crate is the Layer-3 (coordinator) of a three-layer stack:
//!
//! * **L3 (this crate)** — the PingAn insurer, the baseline schedulers, a
//!   slotted discrete-event geo-cluster simulator (the CloudSim substitute),
//!   and a mini Spark-on-Yarn testbed mode that executes real compute via
//!   PJRT-compiled XLA artifacts.
//! * **L2 (python/compile/model.py)** — JAX compute graphs (plan scoring and
//!   the analytics task payloads), AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the scoring
//!   hot-spot (bottleneck-composition + E\[max\] over copy sets).
//!
//! Python never runs on the request path: `make artifacts` lowers everything
//! once; the rust binary loads `artifacts/*.hlo.txt` through the PJRT C API.

pub mod analysis;
pub mod baselines;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod dist;
pub mod experiments;
pub mod insurance;
pub mod metrics;
pub mod perfmodel;
pub mod runtime;
pub mod sched;
pub mod simulator;
pub mod sparkyarn;
pub mod topology;
pub mod util;
pub mod workload;
