//! Substrate benches: histogram algebra, topology/workload generation and
//! raw engine throughput — the denominators of every experiment.
//!
//! Run: `cargo bench --bench bench_simulator`
//! (set PINGAN_BENCH_FAST=1 for a quick smoke pass)

use pingan::baselines::Flutter;
use pingan::bench_harness::Bench;
use pingan::cluster::GeoSystem;
use pingan::config::spec::{BandwidthModel, SystemSpec, TimeModel, WorkloadSpec};
use pingan::dist::{Grid, Hist};
use pingan::insurance::PingAn;
use pingan::simulator::bandwidth::{
    FairShare, IncrementalFairShare, ReferenceFairShare, Transfer,
};
use pingan::simulator::{SimConfig, Simulation};
use pingan::topology::Topology;
use pingan::util::jsonout::Json;
use pingan::util::rng::Rng;
use pingan::workload::montage;

/// Sparse fig7-style workload: PingAn over a low-λ Montage stream — long
/// idle-ish stretches between arrivals, exactly where the event-skip core
/// should touch a small fraction of the slots. Deterministic (fixed seed).
fn fig7_sparse_setup() -> (GeoSystem, Vec<pingan::workload::job::JobSpec>) {
    let mut rng = Rng::new(0xF165);
    let sys = GeoSystem::generate(&SystemSpec::small(8), &mut rng);
    let mut w = WorkloadSpec::scaled(16, 0.002);
    w.datasize = (100.0, 600.0);
    w.size_classes = vec![(1.0, (2, 30))];
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);
    (sys, jobs)
}

fn run_sparse(time_model: TimeModel) -> pingan::simulator::SimResult {
    let (sys, jobs) = fig7_sparse_setup();
    let mut cfg = SimConfig::default();
    cfg.time_model = time_model;
    Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6))
}

/// The same sparse run under the contended-WAN fair-share model: every
/// copy with remote inputs becomes an active transfer, re-rated at each
/// policy epoch. Deterministic (fixed seed).
fn run_sparse_shared(time_model: TimeModel) -> pingan::simulator::SimResult {
    let (sys, jobs) = fig7_sparse_setup();
    let mut cfg = SimConfig::default();
    cfg.time_model = time_model;
    cfg.bandwidth_model = BandwidthModel::Shared;
    Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6))
}

/// Contended fair-share churn: 32 disjoint bottleneck groups of 3 gates,
/// ramped to 320 concurrently-active transfers (10 per group), then 512
/// steady-state churn ops — each retires one transfer in a random group
/// and starts a replacement, holding the population at 320. A churn op
/// touches one group, so the incremental backend re-solves only that
/// component while the reference re-solves the world; CI gates the gap
/// (incremental ≤ 0.5× reference median). Returns Σ rates as a
/// deterministic checksum the two backends must agree on bit-for-bit.
fn run_bw<S: FairShare>(solver: &mut S) -> f64 {
    const GROUPS: u64 = 32;
    let mut rng = Rng::new(0xBA4D);
    for gate in 0..GROUPS * 3 {
        solver.set_gate(gate, 40.0 + gate as f64);
    }
    let mut next_id = 0u64;
    let mut live: Vec<Vec<u64>> = vec![Vec::new(); GROUPS as usize];
    for _ in 0..10 {
        for g in 0..GROUPS {
            let cap = rng.range_f64(2.0, 30.0);
            let w = rng.range_f64(0.25, 1.0);
            solver.start(Transfer::new(
                next_id,
                cap,
                [(g * 3, 1.0), (g * 3 + 1, w), (g * 3 + 2, 1.0 - w)],
            ));
            live[g as usize].push(next_id);
            next_id += 1;
        }
    }
    assert_eq!(solver.active(), 320, "ramp-up lost transfers");
    for _ in 0..512 {
        let g = rng.range_u64(0, GROUPS - 1);
        let slot = rng.range_usize(0, live[g as usize].len() - 1);
        let gone = live[g as usize].swap_remove(slot);
        solver.finish(gone);
        let cap = rng.range_f64(2.0, 30.0);
        let w = rng.range_f64(0.25, 1.0);
        solver.start(Transfer::new(
            next_id,
            cap,
            [(g * 3, 1.0), (g * 3 + 1, w), (g * 3 + 2, 1.0 - w)],
        ));
        live[g as usize].push(next_id);
        next_id += 1;
    }
    solver.rates().iter().map(|(_, r)| r).sum()
}

/// Wide-plant workload for the engine-sharding cases: 256 clusters — at 4
/// engine threads each shard owns exactly [`MIN_CLUSTERS_PER_SHARD`]
/// clusters, so the barrier really spawns — under a cheap policy, so the
/// per-cluster plant advance dominates. Deterministic (fixed seed);
/// shard1/shard4 results are bit-identical, only wall time differs.
fn run_sharded(engine_threads: usize) -> pingan::simulator::SimResult {
    let mut rng = Rng::new(0x54A2);
    let sys = GeoSystem::generate(&SystemSpec::small(256), &mut rng);
    let mut w = WorkloadSpec::scaled(6, 0.01);
    w.datasize = (100.0, 400.0);
    w.size_classes = vec![(1.0, (2, 20))];
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);
    let mut cfg = SimConfig::default();
    cfg.time_model = TimeModel::EventSkip;
    cfg.engine_threads = engine_threads;
    Simulation::new(&sys, jobs, cfg).run(&mut Flutter::new())
}

/// Streaming million-job replay: jobs flow from an incremental
/// [`pingan::workload::source::GenSource`] (never materialized as a Vec)
/// with `stream_metrics` shedding the per-job flowtime series, so
/// resident state is O(clusters + alive jobs) no matter how long the
/// trace. λ is kept well under the small plant's capacity so the alive
/// set stays small and the run terminates; event-skip makes the empty
/// slots free. Deterministic (fixed seed).
fn run_replay(n_jobs: usize) -> pingan::simulator::SimResult {
    let mut rng = Rng::new(0x1E9);
    let sys = GeoSystem::generate(&SystemSpec::small(8), &mut rng);
    let sites: Vec<usize> = (0..sys.n()).collect();
    let wseed = 0x1E9 ^ 0xABCD;
    let mut w = WorkloadSpec::scaled(n_jobs, 0.2);
    w.size_classes = vec![(1.0, (2, 8))];
    w.datasize = (50.0, 200.0);
    w.seed = wseed;
    let src = pingan::workload::source::GenSource::new(w, sites, wseed);
    let mut cfg = SimConfig::default();
    cfg.time_model = TimeModel::EventSkip;
    cfg.stream_metrics = true;
    // ~n/λ slots of simulated time; the default 2M wall would truncate
    cfg.max_slots = 20 * n_jobs.max(100_000) as u64;
    Simulation::from_source(&sys, src, cfg).run(&mut Flutter::new())
}

fn main() {
    let mut b = Bench::new("simulator");
    let fast = std::env::var("PINGAN_BENCH_FAST").ok().as_deref() == Some("1");

    // histogram algebra (the scoring inner loop)
    let grid = Grid::uniform(0.0, 400.0, 64);
    let h1 = Hist::normal(&grid, 120.0, 30.0);
    let h2 = Hist::normal(&grid, 90.0, 40.0);
    let h3 = Hist::normal(&grid, 150.0, 20.0);
    b.case("hist_min_compose_64bins", || {
        h1.min_compose(&h2).mean()
    });
    b.case("hist_expected_max_3x64bins", || {
        Hist::expected_max(&[&h1, &h2, &h3])
    });
    b.case("hist_normal_fit_64bins", || {
        Hist::normal(&grid, 100.0, 25.0).mean()
    });

    // generation
    b.case("topology_100_clusters", || {
        let mut rng = Rng::new(1);
        Topology::generate(100, 2, &mut rng).degree(0) as f64
    });
    b.case("geosystem_100_clusters", || {
        let mut rng = Rng::new(2);
        GeoSystem::generate(&SystemSpec::default(), &mut rng).total_slots() as f64
    });
    b.case("montage_100_jobs", || {
        let mut rng = Rng::new(3);
        let w = WorkloadSpec::scaled(100, 0.07);
        montage::generate(&w, &[0, 1, 2, 3], &mut rng).len() as f64
    });

    // engine throughput: one full small run under a cheap policy
    b.case("engine_run_12jobs_6clusters", || {
        let mut rng = Rng::new(4);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(12, 0.05);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut Flutter::new());
        res.slots as f64
    });

    // dual-mode time core on the sparse fig7-style workload: dense walks
    // every slot, event-skip only the events — same plant, same jobs
    b.case("sim_dense", || run_sparse(TimeModel::Dense).slots as f64);
    b.case("sim_eventskip", || {
        run_sparse(TimeModel::EventSkip).events_processed as f64
    });

    // telemetry overhead: the same sparse PingAn run with wall-span
    // clocks off vs on (plane-A counters are unconditional and an
    // integer bump deep inside already-hot paths; plane B adds two
    // Instant reads per insurer round plus shard/barrier timings). CI's
    // bench smoke gates `on` ≤ 1.05× `off` plus an absolute slack so
    // telemetry can never grow into a real cost silently.
    b.case("sim_telemetry_off", || {
        let (sys, jobs) = fig7_sparse_setup();
        let mut cfg = SimConfig::default();
        cfg.time_model = TimeModel::EventSkip;
        cfg.telemetry = false;
        let res = Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6));
        res.telemetry.admissions as f64
    });
    b.case("sim_telemetry_on", || {
        let (sys, jobs) = fig7_sparse_setup();
        let mut cfg = SimConfig::default();
        cfg.time_model = TimeModel::EventSkip;
        cfg.telemetry = true;
        let res = Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6));
        res.telemetry.admissions as f64
    });

    // contended fair-share solver under churn (≥256 concurrent
    // transfers): the reference re-solves every component per op, the
    // incremental backend only the touched bottleneck group. CI's bench
    // smoke gates incremental ≤ 0.5× reference median wall time.
    b.case("sim_bw_reference", || run_bw(&mut ReferenceFairShare::new()));
    b.case("sim_bw_incremental", || {
        run_bw(&mut IncrementalFairShare::new())
    });
    // and the two backends must agree bit-for-bit on the bench churn
    let ref_sum = run_bw(&mut ReferenceFairShare::new());
    let inc_sum = run_bw(&mut IncrementalFairShare::new());
    assert_eq!(
        ref_sum.to_bits(),
        inc_sum.to_bits(),
        "fair-share backends diverged on the bench churn: {ref_sum} vs {inc_sum}"
    );

    // cluster-sharded plant advance: serial vs 4 engine threads on a wide
    // plant (bit-identical results; CI's bench smoke gates shard4 wall
    // time ≤ 1.1× shard1 — sharding must never *cost* throughput)
    b.case("sim_shard1", || run_sharded(1).events_processed as f64);
    b.case("sim_shard4", || run_sharded(4).events_processed as f64);

    // streaming replay throughput: a long GenSource stream under
    // stream_metrics (the bounded-memory mode the `pingan replay` CLI and
    // the CI memory-ceiling leg exercise). Full mode replays a million
    // jobs per iteration; fast mode 50k so the smoke pass stays short.
    let replay_jobs = if fast { 50_000 } else { 1_000_000 };
    let replay_case = if fast { "sim_replay_50k" } else { "sim_replay_1m" };
    b.case(replay_case, || {
        let res = run_replay(replay_jobs);
        assert_eq!(
            res.finished_jobs, res.total_jobs,
            "replay bench left jobs unfinished (λ over capacity?)"
        );
        assert!(res.flowtimes.is_empty(), "stream_metrics kept the raw Vec");
        res.stats.p99()
    });

    // Deterministic skip-efficiency gate (no wall-clock flakiness): one
    // fixed-seed run per core; CI asserts eventskip events ≤ 25% of dense
    // slots from this line.
    let dense = run_sparse(TimeModel::Dense);
    let event = run_sparse(TimeModel::EventSkip);
    assert_eq!(
        dense.finished_jobs, dense.total_jobs,
        "dense run left jobs unfinished"
    );
    assert_eq!(
        event.finished_jobs, event.total_jobs,
        "event-skip run left jobs unfinished"
    );
    // the same deterministic gate under the shared bandwidth model: the
    // fair-share solver must not erode event-skip's advantage (CI asserts
    // shared eventskip events ≤ 25% of shared dense slots), and
    // contention can only slow transfers down, so mean flowtime is
    // monotone vs the paired constant-model run above.
    let shared_dense = run_sparse_shared(TimeModel::Dense);
    let shared_event = run_sparse_shared(TimeModel::EventSkip);
    assert_eq!(
        shared_dense.finished_jobs, shared_dense.total_jobs,
        "shared dense run left jobs unfinished"
    );
    assert_eq!(
        shared_event.finished_jobs, shared_event.total_jobs,
        "shared event-skip run left jobs unfinished"
    );
    // aggregated over both cores so a single run's post-divergence draw
    // luck cannot mask the systematic slowdown
    assert!(
        shared_dense.avg_flowtime() + shared_event.avg_flowtime() + 1e-6
            >= dense.avg_flowtime() + event.avg_flowtime(),
        "fair-sharing sped jobs up: shared {}+{} vs constant {}+{}",
        shared_dense.avg_flowtime(),
        shared_event.avg_flowtime(),
        dense.avg_flowtime(),
        event.avg_flowtime()
    );
    let mut j = Json::obj();
    j.set("suite", Json::str("simulator"))
        .set("dense_slots", Json::num(dense.slots as f64))
        .set("dense_events", Json::num(dense.events_processed as f64))
        .set("eventskip_slots", Json::num(event.slots as f64))
        .set("eventskip_events", Json::num(event.events_processed as f64))
        .set(
            "event_ratio",
            Json::num(event.events_processed as f64 / dense.slots.max(1) as f64),
        )
        .set("shared_dense_slots", Json::num(shared_dense.slots as f64))
        .set(
            "shared_eventskip_events",
            Json::num(shared_event.events_processed as f64),
        )
        .set(
            "shared_event_ratio",
            Json::num(
                shared_event.events_processed as f64 / shared_dense.slots.max(1) as f64,
            ),
        );
    println!("SIMGATE {}", j.to_string());
}
