//! Job, task and workload-trace model.
//!
//! * [`job`] — the DAG job model with precedence constraints (Eq. 8) and
//!   per-task input locations (the paper's `I_l^i` input-location sets).
//! * [`montage`] — Montage-workflow-shaped DAG generator used by the
//!   simulation experiments (Sec 6.1), with the Facebook-trace job-size mix.
//! * [`testbed`] — the Table-1 testbed mix (WordCount / Iterative ML /
//!   PageRank at 46/40/14% small/medium/large input sizes).
//! * [`arrivals`] — Poisson / exponential job arrival processes.

pub mod arrivals;
pub mod job;
pub mod montage;
pub mod testbed;

pub use job::{JobSpec, OpKind, TaskSpec};
