//! Insurer hot-path benches: the per-slot cost of Algorithm 1 as alive-job
//! count grows, plus the candidate-scoring kernel in isolation. This is the
//! L3 target of the §Perf pass: the insurer must not dominate slot time at
//! paper scale.
//!
//! Run: `cargo bench --bench bench_insurance`

use pingan::bench_harness::Bench;
use pingan::cluster::GeoSystem;
use pingan::config::spec::{SystemSpec, WorkloadSpec};
use pingan::dist::Hist;
use pingan::insurance::scoring::{
    assemble_score, existing_cdf_and_rate, score_candidates, score_candidates_cached,
};
use pingan::insurance::PingAn;
use pingan::perfmodel::PerfModel;
use pingan::runtime::{scorer, CpuScorer, RowInput, ScoreBatch, Scorer};
use pingan::simulator::{SimConfig, Simulation};
use pingan::util::rng::Rng;
use pingan::workload::job::OpKind;
use pingan::workload::montage;

/// One task's frozen per-slot scoring inputs (the insurer's cache layout).
struct TaskCase {
    datasize: f64,
    solo: Vec<(f64, Hist)>,
    proc: Vec<f64>,
    trans: Vec<f64>,
    existing_clusters: Vec<usize>,
}

fn main() {
    let mut b = Bench::new("insurance");

    // scoring kernel: 1 task × 30 candidate clusters
    let mut rng = Rng::new(21);
    let sys = GeoSystem::generate(
        &{
            let mut s = SystemSpec::default();
            s.n_clusters = 30;
            s
        },
        &mut rng,
    );
    let model = PerfModel::new(&sys, 64);
    let candidates: Vec<usize> = (0..sys.n()).collect();
    let existing = vec![model.rate_hist(&[0, 1], 2, OpKind::Map)];
    b.case("score_30_candidates_no_copies", || {
        score_candidates(&model, &[0, 1], OpKind::Map, 500.0, &[], &[], &candidates)
            .iter()
            .map(|s| s.rate)
            .sum()
    });
    b.case("score_30_candidates_1_copy", || {
        score_candidates(
            &model,
            &[0, 1],
            OpKind::Map,
            500.0,
            &existing,
            &[2],
            &candidates,
        )
        .iter()
        .map(|s| s.rate)
        .sum()
    });
    b.case("global_best_rate_30_clusters", || {
        model.global_best_rate(&[0, 1], OpKind::Map)
    });

    // The regression pair CI gates on: scoring B=8 tasks × K=30 candidate
    // clusters, each task holding 2 existing copies.
    //   insurance_scalar  — the pre-refactor try_insure flow: per-call
    //     cache clone + per-candidate Hist E[max] (which re-walks the
    //     existing copies' CDFs for every candidate).
    //   insurance_batched — the refactored flow: existing-CDF product
    //     hoisted once per task, one CpuScorer batch for all pairs, then
    //     CandidateScore assembly. Same numbers, bit for bit.
    {
        let n = sys.n();
        let grid = model.grid().clone();
        let v = grid.bins();
        let op = OpKind::Map;
        let make_tasks = |count: usize| -> Vec<TaskCase> {
            (0..count)
                .map(|i| {
                    let sources = vec![i % n, (3 * i + 1) % n];
                    let mut solo = Vec::with_capacity(n);
                    let mut proc = vec![0.0f64; n * v];
                    let mut trans = vec![0.0f64; n * v];
                    for m in 0..n {
                        let (p, t) = model.rate_components(&sources, m, op);
                        let t = t.expect("non-empty sources");
                        proc[m * v..(m + 1) * v].copy_from_slice(p.pmf());
                        trans[m * v..(m + 1) * v].copy_from_slice(t.pmf());
                        let h = p.min_compose(&t);
                        solo.push((h.mean(), h));
                    }
                    TaskCase {
                        datasize: 400.0 + 50.0 * i as f64,
                        solo,
                        proc,
                        trans,
                        existing_clusters: vec![(i + 2) % n, (i + 11) % n],
                    }
                })
                .collect()
        };
        let tasks = make_tasks(8);
        let candidates: Vec<usize> = (0..n).collect();
        b.case("insurance_scalar", || {
            let mut sink = 0.0;
            for t in &tasks {
                let solo = t.solo.clone();
                let existing: Vec<Hist> = t
                    .existing_clusters
                    .iter()
                    .map(|&m| solo[m].1.clone())
                    .collect();
                let refs: Vec<&Hist> = existing.iter().collect();
                sink += Hist::expected_max(&refs); // current_rate
                let scores = score_candidates_cached(
                    &model,
                    t.datasize,
                    &solo,
                    &existing,
                    &t.existing_clusters,
                    &candidates,
                );
                sink += scores.iter().map(|s| s.rate).sum::<f64>();
            }
            sink
        });
        let mut batch = ScoreBatch::new(0, 0, 0);
        b.case("insurance_batched", || {
            let mut sink = 0.0;
            batch.reset(tasks.len(), n, v);
            batch.values.copy_from_slice(grid.values());
            for (bi, t) in tasks.iter().enumerate() {
                let refs: Vec<&Hist> =
                    t.existing_clusters.iter().map(|&m| &t.solo[m].1).collect();
                let (cdf, current_rate) = existing_cdf_and_rate(&refs, grid.values());
                sink += current_rate;
                scorer::fill_row(&mut batch, bi, &t.proc, &t.trans, false, &cdf);
            }
            let rates = CpuScorer.score(&batch).expect("cpu scorer");
            for (bi, t) in tasks.iter().enumerate() {
                for (m, rate) in rates[bi * n..(bi + 1) * n].iter().enumerate() {
                    let s = assemble_score(
                        &model,
                        &t.existing_clusters,
                        m,
                        t.datasize,
                        t.solo[m].0,
                        Some(*rate),
                    );
                    sink += s.rate;
                }
            }
            sink
        });

        // Intra-cell parallelism gate: the same scoring work at B=96 rows
        // (a heavy round, well past MIN_ROWS_PER_SHARD so sharding truly
        // engages), through score_rows_sharded at 1/2/4 threads. CI's
        // bench-smoke requires all three insurance_par* cases and FAILS
        // if par4's median exceeds 1.1x par1's — sharding must never lose
        // at a realistic round size. (Output is bit-identical across the
        // three; the determinism suite pins that.)
        let par_tasks = make_tasks(96);
        // the frozen per-row inputs, hoisted once: the timed region is
        // what a warm scheduling round actually spends — shard fill +
        // kernel + row-order merge
        let rows_data: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)> = par_tasks
            .iter()
            .map(|t| {
                let refs: Vec<&Hist> =
                    t.existing_clusters.iter().map(|&m| &t.solo[m].1).collect();
                let (cdf, _) = existing_cdf_and_rate(&refs, grid.values());
                (t.proc.clone(), t.trans.clone(), cdf)
            })
            .collect();
        for threads in [1usize, 2, 4] {
            let mut scratch: Vec<ScoreBatch> = Vec::new();
            b.case(&format!("insurance_par{threads}"), || {
                let rows: Vec<RowInput<'_>> = rows_data
                    .iter()
                    .map(|(proc, trans, cdf)| RowInput {
                        proc,
                        trans,
                        proc_only: false,
                        existing_cdf: cdf,
                    })
                    .collect();
                let rates = scorer::score_rows_sharded(
                    &CpuScorer,
                    n,
                    v,
                    grid.values(),
                    &rows,
                    threads,
                    &mut scratch,
                )
                .expect("sharded scorer");
                rates.iter().sum()
            });
        }
    }

    // per-slot schedule() cost under load: steady-state step
    for &n_jobs in &[8usize, 24, 48] {
        let mut rng = Rng::new(33);
        let sys = GeoSystem::generate(&SystemSpec::small(12), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, 10.0); // all arrive ~immediately
        w.datasize = (300.0, 900.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        b.case(&format!("pingan_step_{n_jobs}_alive_jobs"), || {
            let mut sim = Simulation::new(&sys, jobs.clone(), SimConfig::default());
            let mut p = PingAn::with_epsilon(0.6);
            // warm 3 slots then measure 5 steady-state steps
            for _ in 0..8 {
                sim.step(&mut p);
            }
            sim.now() as f64
        });
    }

    // full run comparison: EFA vs JGA allocation cost
    {
        let mut rng = Rng::new(44);
        let sys = GeoSystem::generate(&SystemSpec::small(8), &mut rng);
        let mut w = WorkloadSpec::scaled(10, 0.05);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        for alloc in [
            pingan::config::spec::Allocation::Efa,
            pingan::config::spec::Allocation::Jga,
        ] {
            b.case(&format!("full_run_10jobs_{}", alloc.name()), || {
                let mut spec = pingan::config::spec::PingAnSpec::with_epsilon(0.6);
                spec.allocation = alloc;
                let res = Simulation::new(&sys, jobs.clone(), SimConfig::default())
                    .run(&mut PingAn::new(spec));
                res.slots as f64
            });
        }
    }
}
