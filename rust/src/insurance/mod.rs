//! The PingAn insurance algorithm (paper Sec 4, Algorithm 1).
//!
//! Per time slot:
//!
//! 1. Sort alive jobs by ascending *unprocessed datasize* of their current
//!    frontier; the first ⌈εN(t)⌉ jobs share the plant — each prior job is
//!    promised `h_i(t) = ⌊ΣM_k / εN(t)⌋` slots, every other job gets nothing.
//! 2. **Round 1 — efficiency-first**: at most one slot per waiting task, in
//!    job-priority order, on the cluster with the best estimated rate
//!    `E[r(1)]`, rejected when gates lack headroom or the rate is below
//!    `1/(1+ε)` of the task's global-optimal rate `E^O[r(1)]`.
//! 3. **Round 2 — reliability-aware**: running tasks sorted by ascending
//!    trouble-exemption probability `pro`; an extra copy goes to the
//!    cluster improving `pro` the most, subject to the same floors.
//! 4. **Rounds ≥3 — resource-saving**: a c-th copy is admitted only when
//!    `E^{c-1}[e] > (c+1)/c · E^{c}[e]` — it must save both time and the
//!    opportunity cost of the slot.
//!
//! The `Principle` (Fig 6a) swaps the round-1/round-2 criteria and the
//! `Allocation` (Fig 6b) switches EFA (rounds across jobs — the paper's)
//! against JGA (all rounds within a job before the next job).
//!
//! Candidate scoring is batched: each round's (task, candidate) pairs go
//! through one `runtime::ScoreBatch` and a pluggable `runtime::Scorer`
//! (`PingAnSpec::scorer`, `--scorer cpu|hlo|scalar`), with results cached
//! per slot. See [`pingan`]'s module docs for the frozen-state argument
//! and [`scoring`] for the shared numeric pieces.

pub mod pingan;
pub mod scoring;

pub use pingan::PingAn;
pub use scoring::{pro_with_candidate, CandidateScore};
