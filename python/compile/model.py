"""Layer-2 JAX compute graphs — the functions the AOT pipeline lowers.

Two families:

* ``score`` — the insurer's batched copy-placement scorer: bottleneck
  min-composition of per-candidate processing/transfer distributions
  followed by E[max] against the task's existing copies. Calls the L1
  Pallas kernels so both lower into one HLO module (the intermediate
  [B,K,V] pmf never leaves VMEM on a real TPU).
* the three testbed payloads (``wordcount`` / ``pagerank`` / ``logreg``)
  that the rust Spark-on-Yarn mode executes per task.

Python only ever runs at build time: `aot.py` lowers these once to
``artifacts/*.hlo.txt`` and the rust runtime loads them via PJRT.
"""

import jax.numpy as jnp

from compile.kernels import analytics, bottleneck, expmax


def score(proc_pmf, trans_pmf, existing_cdf, values):
    """[B,K,V] × [B,K,V] × [B,V] × [V] -> [B,K] expected max rates."""
    rate_pmf = bottleneck.bottleneck(proc_pmf, trans_pmf)
    return expmax.expmax(rate_pmf, existing_cdf, values)


def wordcount_payload(tokens, vocab: int):
    """[N] int32 token ids -> ([vocab] counts, checksum)."""
    hist = analytics.wordcount(tokens, vocab)
    return hist, jnp.sum(hist)


def pagerank_payload(ranks, adj, n_steps: int = 4):
    """Iterated PageRank steps (one task = a few supersteps)."""
    r = ranks
    for _ in range(n_steps):
        r = analytics.pagerank_step(r, adj)
    return r


def logreg_payload(x, y, w, n_steps: int = 4):
    """Iterated logistic-regression gradient steps."""
    for _ in range(n_steps):
        w = analytics.logreg_step(x, y, w)
    return w
