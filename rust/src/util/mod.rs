//! Small self-contained utilities.
//!
//! The build environment is offline and only a handful of vendored crates are
//! available, so the pieces a production scheduler would normally pull from
//! crates.io (deterministic RNG, summary statistics, CLI parsing, JSON
//! emission, aligned tables) are implemented here as first-class, tested
//! substrates.

pub mod cli;
pub mod jsonout;
pub mod knob;
pub mod rng;
pub mod shard;
pub mod stats;
pub mod table;

/// Clamp helper used across the config code (ranges in Table 2 are inclusive).
pub fn clampf(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clampf_bounds() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }
}
