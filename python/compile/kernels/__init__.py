"""Layer-1 Pallas kernels and their pure-jnp reference oracle.

All kernels are authored for ``interpret=True`` execution (the CPU PJRT
client cannot run Mosaic custom-calls); block shapes and dtypes are chosen
so the same kernels would tile cleanly into TPU VMEM (see DESIGN.md
"Hardware adaptation").
"""

from . import analytics, bottleneck, expmax, ref  # noqa: F401
