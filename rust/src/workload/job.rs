//! DAG job model.
//!
//! A job is a set of tasks with a partial order (Eq. 8 in the paper): a task
//! becomes *ready* when all its dependencies completed. Each task carries a
//! datasize `D_l^i` and an input-location set `I_l^i` — raw inputs sit in
//! clusters fixed at generation time; intermediate inputs materialize where
//! the producer task ran (the simulator rewrites those at runtime, mirroring
//! the OutputRecorder in Fig 1).

/// The operation a task performs. Used by the performance modeler to keep a
/// speed distribution *per operation* (the paper models one distribution per
/// RDD operation to remove task-type bias) and by the testbed mode to pick
/// the XLA payload to execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Scan/map over raw input (wordcount map, Montage projection).
    Map,
    /// Shuffle-heavy pairwise combination (joins, Montage overlaps).
    Shuffle,
    /// Aggregation (reduce, Montage mosaic add).
    Reduce,
    /// Iterative numeric step (logistic regression, PageRank iteration).
    Iterate,
}

impl OpKind {
    pub const ALL: [OpKind; 4] = [OpKind::Map, OpKind::Shuffle, OpKind::Reduce, OpKind::Iterate];

    pub fn index(&self) -> usize {
        match self {
            OpKind::Map => 0,
            OpKind::Shuffle => 1,
            OpKind::Reduce => 2,
            OpKind::Iterate => 3,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            OpKind::Map => "map",
            OpKind::Shuffle => "shuffle",
            OpKind::Reduce => "reduce",
            OpKind::Iterate => "iterate",
        }
    }

    /// Relative data-processing speed of this operation w.r.t. Map
    /// (ground-truth skew; the modeler has to *learn* it from logs).
    pub fn speed_skew(&self) -> f64 {
        match self {
            OpKind::Map => 1.0,
            OpKind::Shuffle => 0.7,
            OpKind::Reduce => 0.85,
            OpKind::Iterate => 0.55,
        }
    }
}

/// One task of a job.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    /// Index within the job.
    pub idx: usize,
    pub op: OpKind,
    /// Unprocessed datasize D_l^i (data units).
    pub datasize: f64,
    /// Indices (within the job) of tasks that must finish first.
    pub deps: Vec<usize>,
    /// Clusters holding this task's *raw* input partitions. Empty for tasks
    /// whose entire input is intermediate (rewritten at run time).
    pub input_locations: Vec<usize>,
}

/// A job: DAG of tasks plus arrival time.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: usize,
    pub name: String,
    /// Arrival time slot a_i.
    pub arrival: u64,
    pub tasks: Vec<TaskSpec>,
}

impl JobSpec {
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    pub fn total_datasize(&self) -> f64 {
        self.tasks.iter().map(|t| t.datasize).sum()
    }

    /// Tasks with no dependencies (the first stage).
    pub fn roots(&self) -> Vec<usize> {
        self.tasks
            .iter()
            .filter(|t| t.deps.is_empty())
            .map(|t| t.idx)
            .collect()
    }

    /// Validate DAG invariants: indices consistent, deps acyclic & earlier,
    /// datasizes positive. Generators guarantee deps point to lower indices
    /// (topological by construction); this checks it.
    pub fn validate(&self) -> Result<(), String> {
        for (i, t) in self.tasks.iter().enumerate() {
            if t.idx != i {
                return Err(format!("job {}: task {} has idx {}", self.id, i, t.idx));
            }
            if !(t.datasize > 0.0) {
                return Err(format!("job {}: task {} datasize <= 0", self.id, i));
            }
            for &d in &t.deps {
                if d >= i {
                    return Err(format!(
                        "job {}: task {} depends on non-earlier {}",
                        self.id, i, d
                    ));
                }
            }
        }
        if self.tasks.is_empty() {
            return Err(format!("job {} has no tasks", self.id));
        }
        Ok(())
    }

    /// Stage depth of every task (longest dependency chain length).
    pub fn depths(&self) -> Vec<usize> {
        let mut depth = vec![0usize; self.tasks.len()];
        for t in &self.tasks {
            depth[t.idx] = t
                .deps
                .iter()
                .map(|&d| depth[d] + 1)
                .max()
                .unwrap_or(0);
        }
        depth
    }

    /// Critical-path length in stages.
    pub fn critical_path(&self) -> usize {
        self.depths().into_iter().max().unwrap_or(0) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> JobSpec {
        JobSpec {
            id: 0,
            name: "diamond".into(),
            arrival: 0,
            tasks: vec![
                TaskSpec {
                    idx: 0,
                    op: OpKind::Map,
                    datasize: 10.0,
                    deps: vec![],
                    input_locations: vec![0],
                },
                TaskSpec {
                    idx: 1,
                    op: OpKind::Shuffle,
                    datasize: 5.0,
                    deps: vec![0],
                    input_locations: vec![],
                },
                TaskSpec {
                    idx: 2,
                    op: OpKind::Shuffle,
                    datasize: 5.0,
                    deps: vec![0],
                    input_locations: vec![],
                },
                TaskSpec {
                    idx: 3,
                    op: OpKind::Reduce,
                    datasize: 2.0,
                    deps: vec![1, 2],
                    input_locations: vec![],
                },
            ],
        }
    }

    #[test]
    fn validate_accepts_diamond() {
        assert!(diamond().validate().is_ok());
    }

    #[test]
    fn validate_rejects_forward_dep() {
        let mut j = diamond();
        j.tasks[1].deps = vec![3];
        assert!(j.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_datasize() {
        let mut j = diamond();
        j.tasks[0].datasize = 0.0;
        assert!(j.validate().is_err());
    }

    #[test]
    fn roots_and_depths() {
        let j = diamond();
        assert_eq!(j.roots(), vec![0]);
        assert_eq!(j.depths(), vec![0, 1, 1, 2]);
        assert_eq!(j.critical_path(), 3);
    }

    #[test]
    fn totals() {
        let j = diamond();
        assert_eq!(j.n_tasks(), 4);
        assert!((j.total_datasize() - 22.0).abs() < 1e-12);
    }

    #[test]
    fn op_skews_at_most_map() {
        for op in OpKind::ALL {
            assert!(op.speed_skew() <= 1.0 && op.speed_skew() > 0.0);
        }
    }
}
