//! PJRT client wrapper: HLO-text artifacts → compiled executables.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

use crate::config::toml::Doc;

/// Shapes recorded by `python/compile/aot.py` in `manifest.toml`.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub score_b: usize,
    pub score_k: usize,
    pub score_v: usize,
    pub wc_n: usize,
    pub wc_vocab: usize,
    pub pr_n: usize,
    pub lr_n: usize,
    pub lr_d: usize,
}

impl ArtifactSet {
    /// Read `manifest.toml` from an artifacts directory.
    pub fn discover<P: AsRef<Path>>(dir: P) -> Result<ArtifactSet> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?} — run `make artifacts` first"))?;
        let doc = Doc::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let need = |k: &str| -> Result<usize> {
            doc.get(k)
                .and_then(|v| v.as_i64())
                .map(|x| x as usize)
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        Ok(ArtifactSet {
            dir,
            score_b: need("score.b")?,
            score_k: need("score.k")?,
            score_v: need("score.v")?,
            wc_n: need("wordcount.n")?,
            wc_vocab: need("wordcount.vocab")?,
            pr_n: need("pagerank.n")?,
            lr_n: need("logreg.n")?,
            lr_d: need("logreg.d")?,
        })
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }
}

/// A PJRT CPU client plus compiled executables, one per artifact.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub artifacts: ArtifactSet,
}

impl Engine {
    /// Spin up the CPU PJRT client and discover artifacts.
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Engine> {
        let artifacts = ArtifactSet::discover(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Engine { client, artifacts })
    }

    /// Load + compile one artifact by name.
    pub fn compile(&self, name: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.artifacts.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))
    }
}

/// Execute a compiled module on f32 inputs, returning the flat f32 outputs
/// of the result tuple (AOT always lowers with `return_tuple=True`).
pub fn exec_f32(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<Vec<f32>>> {
    let result = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetch: {e:?}"))?;
    let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
    parts
        .into_iter()
        .map(|p| p.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}")))
        .collect()
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_ready() -> bool {
        Path::new("artifacts/manifest.toml").exists()
    }

    #[test]
    fn manifest_discovery() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let a = ArtifactSet::discover("artifacts").unwrap();
        assert_eq!(a.score_b, 32);
        assert_eq!(a.score_k, 8);
        assert_eq!(a.score_v, 64);
        assert!(a.hlo_path("score").exists());
    }

    #[test]
    fn missing_dir_is_actionable() {
        let err = ArtifactSet::discover("/nonexistent-dir").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn score_artifact_compiles_and_runs() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let eng = Engine::new("artifacts").unwrap();
        let exe = eng.compile("score").unwrap();
        let a = &eng.artifacts;
        let (b, k, v) = (a.score_b, a.score_k, a.score_v);
        // uniform pmfs, no existing copies, linear grid
        let pmf = vec![1.0f32 / v as f32; b * k * v];
        let exist = vec![1.0f32; b * v];
        let values: Vec<f32> = (0..v).map(|i| i as f32).collect();
        let out = exec_f32(
            &exe,
            &[
                literal_f32(&pmf, &[b as i64, k as i64, v as i64]).unwrap(),
                literal_f32(&pmf, &[b as i64, k as i64, v as i64]).unwrap(),
                literal_f32(&exist, &[b as i64, v as i64]).unwrap(),
                literal_f32(&values, &[v as i64]).unwrap(),
            ],
        )
        .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), b * k);
        // min of two uniforms skews low: mean below the grid midpoint
        let mid = (v - 1) as f32 / 2.0;
        for &r in &out[0] {
            assert!(r > 0.0 && r < mid, "rate {r} vs mid {mid}");
        }
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3], &[2]).is_err());
    }
}
