//! `sweep` — the declarative, parallel scenario-sweep subsystem.
//!
//! Every experiment in this crate is a grid of simulations: schedulers ×
//! arrival rates × ε × plant sizes × failure rates × workload mixes ×
//! seed replicas. This module is the single engine behind all of them:
//!
//! * **Spec layer** ([`Scenario`], [`SweepSpec`], [`Axis`]) — a scenario
//!   fully describes one cell; a sweep is a base scenario plus named axis
//!   value lists, expanded deterministically (row-major, replicas
//!   innermost) into the cell grid. Specs are built in code (builder
//!   style) or from a `[sweep]` TOML section ([`SweepSpec::from_doc`]).
//! * **Runner** ([`run`], [`run_with`]) — scoped worker threads pulling
//!   cells off a shared atomic queue, per-cell panic isolation, and a
//!   progress callback. Per-cell seeds are a pure function of the cell's
//!   coordinates, so results are bit-identical at any thread count and
//!   equal to a sequential loop over [`SweepSpec::cells`].
//! * **Reports** ([`CellResult`], [`ScenarioRow`], [`SweepReport`]) —
//!   per-replica-group mean/p50/p95/p99 flowtime, 95% confidence
//!   intervals across replicas, copy-cost accounting, and CSV / JSON /
//!   table emitters.
//!
//! The figure/table regenerators (`experiments`), the `pingan sweep` CLI
//! command, `benches/bench_sweep.rs`, and `examples/sweep_grid.rs` are
//! all thin constructions over this module.

pub mod axis;
pub mod report;
pub mod runner;
pub mod spec;

pub use axis::{Axis, WorkloadMix};
pub use report::{CellResult, ScenarioRow, SweepReport};
pub use runner::{default_threads, run, run_traced, run_with, Progress};
pub use spec::{make_scheduler, Scenario, SweepSpec, SCHEDULERS};
