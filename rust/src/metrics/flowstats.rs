//! Bounded-memory streaming flowtime statistics.
//!
//! [`FlowStats`] is the metrics half of the million-job replay redesign:
//! instead of holding every per-job flowtime in a `Vec<f64>` until the end
//! of the run, the engine folds each finished job into an online
//! accumulator — Welford mean/variance for the first two moments, plus a
//! log-linear histogram sketch (HDR-histogram shape) for p50/p95/p99 — so
//! a 10⁷-job cell carries a few KB of metric state instead of 80 MB.
//!
//! ## Determinism
//!
//! Everything here is pure integer/float arithmetic over the values fed
//! in, in feed order. The engine records completions in its deterministic
//! completion order, so `FlowStats` is bit-identical at any
//! `score_threads × engine_threads`, on either time core, and is safe to
//! equality-check and to emit into deterministic sweep JSON.
//!
//! ## Quantile tolerance (documented contract, pinned by proptest)
//!
//! The sketch buckets a value `v ≥ 0` by truncating to an integer and
//! splitting each power-of-two octave into 64 sub-buckets, so a bucket
//! containing `v` is at most `max(1, v/64)` wide. [`FlowStats::quantile`]
//! returns the upper edge of the bucket holding the *nearest-rank* order
//! statistic (clamped into the observed `[min, max]`). Against the exact
//! interpolated [`crate::util::stats::quantile_sorted`], whose result lies
//! between the two bracketing order statistics `lo ≤ hi`, the sketch value
//! `s` therefore satisfies
//!
//! ```text
//! lo - 1 ≤ s ≤ hi + hi/32 + 1
//! ```
//!
//! i.e. one sub-bucket (≈ 1.6% relative, widened to /32 for the truncation
//! slack) above, one absolute unit below. Flowtimes are integer slot
//! counts, so in practice the sketch lands within one sub-bucket of the
//! exact percentile. `tests/proptest_flowstats.rs` pins this bound on
//! random vectors.

use crate::util::stats::Welford;

/// Each power-of-two octave splits into `2^SUB_BITS` sub-buckets; this
/// bounds the sketch's relative quantile error at `2^-SUB_BITS ≈ 1.6%`.
const SUB_BITS: u32 = 6;
const SUB: u64 = 1 << SUB_BITS;

/// Largest value the sketch distinguishes; beyond this everything lands in
/// the top bucket (flowtimes are bounded by `max_slots`, far below this).
const CAP: u64 = 1 << 62;

/// Bucket index for a non-negative value: exact integer buckets below
/// `SUB`, then 64 log-linear sub-buckets per octave.
fn bucket_of(v: f64) -> usize {
    let u = if v <= 0.0 {
        0
    } else if v >= CAP as f64 {
        CAP - 1
    } else {
        v as u64
    };
    if u < SUB {
        u as usize
    } else {
        let octave = 63 - u64::from(u.leading_zeros());
        let sub = (u >> (octave - u64::from(SUB_BITS))) - SUB;
        ((octave - u64::from(SUB_BITS) + 1) * SUB + sub) as usize
    }
}

/// Exclusive upper edge of a bucket (the value [`FlowStats::quantile`]
/// reports before clamping into the observed range).
fn bucket_upper(index: usize) -> f64 {
    let i = index as u64;
    if i < SUB {
        (i + 1) as f64
    } else {
        let group = i / SUB; // ≥ 1
        let sub = i % SUB;
        let width = 1u64 << (group - 1);
        ((SUB + sub + 1).saturating_mul(width)) as f64
    }
}

/// Streaming flowtime statistics: count / mean / CI via Welford, p50/p95/
/// p99 via a log-linear histogram sketch, all in O(1) memory per run.
///
/// Non-finite records (the eager path's `NaN` markers for unfinished
/// jobs) are counted in [`FlowStats::total`] but excluded from every
/// moment and quantile — the same convention
/// `SimResult::avg_flowtime` has always used.
#[derive(Clone, Debug, PartialEq)]
pub struct FlowStats {
    welford: Welford,
    sum: f64,
    /// Jobs that never finished (recorded as `NaN`, or bulk-added for
    /// jobs a truncated run never admitted).
    unfinished: u64,
    /// Histogram counts, indexed by [`bucket_of`]; grown lazily to the
    /// highest bucket touched (≈ 4 KB for any realistic flowtime range).
    counts: Vec<u64>,
    min: f64,
    max: f64,
}

impl Default for FlowStats {
    fn default() -> Self {
        FlowStats {
            welford: Welford::new(),
            sum: 0.0,
            unfinished: 0,
            counts: Vec::new(),
            // infinities (not NaN) so empty sketches compare equal
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

impl FlowStats {
    pub fn new() -> FlowStats {
        FlowStats::default()
    }

    /// Build from an eager flowtime vector (NaN entries count as
    /// unfinished). Feed order is the vector order.
    pub fn from_flowtimes(xs: &[f64]) -> FlowStats {
        let mut s = FlowStats::new();
        for &x in xs {
            s.record(x);
        }
        s
    }

    /// Fold one job's flowtime in. Non-finite marks an unfinished job;
    /// negatives clamp to zero (flowtimes are non-negative by
    /// construction — the clamp only guards synthetic test inputs).
    pub fn record(&mut self, flow: f64) {
        if !flow.is_finite() {
            self.unfinished += 1;
            return;
        }
        let v = flow.max(0.0);
        self.welford.push(v);
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        let b = bucket_of(v);
        if b >= self.counts.len() {
            self.counts.resize(b + 1, 0);
        }
        self.counts[b] += 1;
    }

    /// Bulk-account `k` jobs that never finished (e.g. jobs a `max_slots`
    /// bailout never admitted from a streaming source).
    pub fn record_unfinished(&mut self, k: u64) {
        self.unfinished += k;
    }

    /// Finished (finite) jobs folded in.
    pub fn finished(&self) -> u64 {
        self.welford.count()
    }

    /// All jobs accounted for, finished or not.
    pub fn total(&self) -> u64 {
        self.welford.count() + self.unfinished
    }

    pub fn unfinished(&self) -> u64 {
        self.unfinished
    }

    /// Mean flowtime over finished jobs (0.0 when none — the historical
    /// `stats::mean(&[])` convention the emitters rely on).
    pub fn mean(&self) -> f64 {
        self.welford.mean()
    }

    /// Sum of finished flowtimes.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn std_dev(&self) -> f64 {
        self.welford.std_dev()
    }

    /// Half-width of the normal-approximation 95% CI on the mean.
    pub fn ci95(&self) -> f64 {
        let n = self.welford.count();
        if n < 2 {
            return 0.0;
        }
        1.96 * self.welford.std_dev() / (n as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.finished() == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.finished() == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Sketch quantile (see the module docs for the tolerance contract).
    /// `NaN` when no job finished, matching the exact path's convention
    /// for all-NaN cells.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.welford.count();
        if n == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 1.0) * (n - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_upper(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// (p50, p95, p99) in one call — the tuple shape
    /// `metrics::percentiles` has always returned.
    pub fn percentiles(&self) -> (f64, f64, f64) {
        (self.p50(), self.p95(), self.p99())
    }

    /// Pool another run's statistics in (replica aggregation in
    /// `sweep::report`). Histograms add; moments merge exactly (Chan's
    /// parallel Welford update). Deterministic given operand order.
    pub fn merge(&mut self, other: &FlowStats) {
        self.welford.merge(&other.welford);
        self.sum += other.sum;
        self.unfinished += other.unfinished;
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, &c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{mean, quantile_sorted};

    #[test]
    fn quantile_edges_are_pinned() {
        // the contract at the extremes, pinned so callers (the serve
        // stats path, sweep reports) can rely on it: q=0 lands on the
        // smallest value's bucket (upper edge, so within one bucket of
        // the exact min and never below it); q=1 returns exactly the
        // recorded max (the top bucket's upper edge clamps to it);
        // out-of-range q clamps into [0, 1] instead of panicking
        let mut s = FlowStats::new();
        for x in [4.0, 9.0, 25.0, 100.0, 3000.0] {
            s.record(x);
        }
        let lo = s.quantile(0.0);
        assert!(
            lo >= s.min() && lo <= s.min() * 1.02 + 1.0,
            "q=0 landed at {lo}, exact min was {}",
            s.min()
        );
        assert_eq!(s.quantile(1.0).to_bits(), s.max().to_bits());
        assert_eq!(s.quantile(-3.0).to_bits(), s.quantile(0.0).to_bits());
        assert_eq!(s.quantile(7.0).to_bits(), s.quantile(1.0).to_bits());
        // a single-sample sketch answers every quantile with that sample's
        // bucket (rank 0 at any q) — n=1 replay output depends on this
        let mut one = FlowStats::new();
        one.record(42.0);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(one.quantile(q).to_bits(), one.quantile(0.5).to_bits(), "q={q}");
        }
        assert!(one.quantile(0.5) >= 42.0 * (1.0 - 0.02));
        assert!(one.quantile(0.5) <= 42.0 * 1.02);
    }

    #[test]
    fn only_unfinished_sketch_is_nan_not_zero() {
        // a truncated run can finish nothing: the wall-cut straggler
        // (record(NaN)) and the never-admitted remainder
        // (record_unfinished) must leave quantiles/min/max NaN — the
        // all-NaN-cell convention — never a fabricated 0.0
        let mut s = FlowStats::new();
        s.record(f64::NAN);
        s.record_unfinished(3);
        assert_eq!(s.finished(), 0);
        assert_eq!(s.unfinished(), 4);
        assert_eq!(s.total(), 4);
        for q in [0.0, 0.5, 1.0] {
            assert!(s.quantile(q).is_nan(), "q={q} fabricated a value");
        }
        let (p50, p95, p99) = s.percentiles();
        assert!(p50.is_nan() && p95.is_nan() && p99.is_nan());
        assert!(s.min().is_nan() && s.max().is_nan());
        // mean keeps the historical stats::mean(&[]) convention (0.0),
        // and the CI on no samples is 0 — both pinned, not NaN
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.ci95(), 0.0);
    }

    #[test]
    fn buckets_are_contiguous_and_monotone() {
        let mut prev = 0usize;
        for u in 0..20_000u64 {
            let b = bucket_of(u as f64);
            assert!(b == prev || b == prev + 1, "gap at {u}: {prev} -> {b}");
            prev = b;
            // the bucket's upper edge bounds the value it holds
            assert!(bucket_upper(b) > u as f64, "upper({b}) <= {u}");
        }
        // sub-unit and negative inputs land in bucket 0
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(0.9), 0);
        assert_eq!(bucket_of(-3.0), 0);
    }

    #[test]
    fn relative_width_is_bounded() {
        for u in [100u64, 1000, 12_345, 1_000_000, 123_456_789] {
            let b = bucket_of(u as f64);
            let width = bucket_upper(b) - bucket_upper(b.saturating_sub(1));
            assert!(
                width <= (u as f64 / SUB as f64).max(1.0) + 1e-9,
                "bucket at {u} too wide: {width}"
            );
        }
    }

    #[test]
    fn moments_match_exact_and_skip_nan() {
        let xs = [10.0, 20.0, f64::NAN, 40.0];
        let s = FlowStats::from_flowtimes(&xs);
        assert_eq!(s.finished(), 3);
        assert_eq!(s.total(), 4);
        assert_eq!(s.unfinished(), 1);
        let finite = [10.0, 20.0, 40.0];
        assert!((s.mean() - mean(&finite)).abs() < 1e-12);
        assert!((s.sum() - 70.0).abs() < 1e-12);
        assert_eq!(s.min(), 10.0);
        assert_eq!(s.max(), 40.0);
    }

    #[test]
    fn empty_stats_are_well_defined_and_equal() {
        let a = FlowStats::new();
        let b = FlowStats::new();
        assert_eq!(a, b);
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.sum(), 0.0);
        assert!(a.p50().is_nan());
        assert!(a.min().is_nan());
    }

    #[test]
    fn single_value_quantiles_are_exact() {
        let s = FlowStats::from_flowtimes(&[137.0]);
        assert_eq!(s.p50(), 137.0);
        assert_eq!(s.p99(), 137.0);
    }

    #[test]
    fn quantiles_track_exact_within_documented_tolerance() {
        // integer slot counts, the real payload shape
        let mut xs: Vec<f64> = (0..1000).map(|i| ((i * i * 7919) % 100_000) as f64).collect();
        let s = FlowStats::from_flowtimes(&xs);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.5, 0.95, 0.99] {
            let pos = q * (xs.len() - 1) as f64;
            let lo = xs[pos.floor() as usize];
            let hi = xs[pos.ceil() as usize];
            let sk = s.quantile(q);
            assert!(
                sk >= lo - 1.0 && sk <= hi + hi / 32.0 + 1.0,
                "q={q}: sketch {sk} outside [{lo}, {hi}] tolerance"
            );
        }
        let exact = quantile_sorted(&xs, 0.5);
        assert!((s.p50() - exact).abs() <= exact / 32.0 + 1.0);
    }

    #[test]
    fn merge_equals_single_stream_pooling() {
        let a_xs: Vec<f64> = (0..500).map(|i| (i * 13 % 7000) as f64).collect();
        let b_xs: Vec<f64> = (0..300).map(|i| (i * 17 % 9000) as f64).collect();
        let mut merged = FlowStats::from_flowtimes(&a_xs);
        merged.record_unfinished(2);
        merged.merge(&FlowStats::from_flowtimes(&b_xs));
        let mut pooled_xs = a_xs.clone();
        pooled_xs.extend_from_slice(&b_xs);
        let pooled = FlowStats::from_flowtimes(&pooled_xs);
        assert_eq!(merged.finished(), pooled.finished());
        assert_eq!(merged.total(), pooled.total() + 2);
        assert!((merged.mean() - pooled.mean()).abs() < 1e-9);
        assert!((merged.sum() - pooled.sum()).abs() < 1e-6);
        // identical histograms → identical quantiles
        assert_eq!(merged.p50().to_bits(), pooled.p50().to_bits());
        assert_eq!(merged.p99().to_bits(), pooled.p99().to_bits());
    }

    #[test]
    fn feed_order_is_deterministic() {
        let xs: Vec<f64> = (0..200).map(|i| (i * 31 % 997) as f64).collect();
        let a = FlowStats::from_flowtimes(&xs);
        let b = FlowStats::from_flowtimes(&xs);
        assert_eq!(a, b);
        assert_eq!(a.mean().to_bits(), b.mean().to_bits());
    }
}
