"""Pallas kernel: E[max] over copy sets — the insurer's scoring hot-spot.

For a batch of B tasks and K candidate clusters each, given

* ``cand_pmf``     [B, K, V] — candidate copy execution-rate pmfs,
* ``existing_cdf`` [B, V]    — elementwise product of the CDFs of the
  copies the task already has (all-ones when none), and
* ``values``       [V]       — the shared grid bin centers,

compute ``rates[b, k] = E[max(existing_b, candidate_{b,k})]`` via the CDF
product (paper Eq. 13) and an expectation against the grid.

TPU shaping notes: the grid iterates over B (one task per program), the
whole [K, V] candidate block stays VMEM-resident (K·V·4 B ≈ 2 KiB at the
AOT shape 8×64 — far under the ~16 MiB VMEM budget, leaving headroom to
raise K·V by ~3 orders of magnitude), and both the cumulative sum and the
final contraction vectorize along the V lane dimension.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expmax_kernel(cand_ref, exist_ref, values_ref, out_ref):
    cand = cand_ref[...]  # [1, K, V]
    exist = exist_ref[...]  # [1, V]
    values = values_ref[...]  # [V]
    cand_cdf = jnp.cumsum(cand, axis=-1)
    combined = cand_cdf * exist[:, None, :]  # [1, K, V]
    shifted = jnp.concatenate(
        [jnp.zeros_like(combined[..., :1]), combined[..., :-1]], axis=-1
    )
    pmf = combined - shifted
    out_ref[...] = jnp.sum(pmf * values[None, None, :], axis=-1)


def expmax(cand_pmf, existing_cdf, values, *, interpret=True):
    """Batched E[max] scores: [B,K,V] × [B,V] × [V] -> [B,K]."""
    b, k, v = cand_pmf.shape
    return pl.pallas_call(
        _expmax_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, k, v), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, v), lambda i: (i, 0)),
            pl.BlockSpec((v,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), cand_pmf.dtype),
        interpret=interpret,
    )(cand_pmf, existing_cdf, values)
