"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and input distributions; assert_allclose against
ref.py is THE correctness signal for the scoring math (the rust fallback is
cross-checked against the same oracle via golden vectors in
test_golden.py / rust/tests/scorer_golden.rs).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import analytics, bottleneck, expmax, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand_pmf(rng, *shape):
    x = rng.random(shape).astype(np.float32) + 1e-3
    return x / x.sum(axis=-1, keepdims=True)


def rand_cdf(rng, b, v):
    """A valid CDF-product row: nondecreasing, ending at 1."""
    pmf = rand_pmf(rng, b, v)
    return np.cumsum(pmf, axis=-1).astype(np.float32)


@st.composite
def bkv(draw):
    b = draw(st.integers(1, 8))
    k = draw(st.integers(1, 8))
    v = draw(st.integers(2, 96))
    seed = draw(st.integers(0, 2**31 - 1))
    return b, k, v, seed


@given(bkv())
def test_expmax_matches_ref(args):
    b, k, v, seed = args
    rng = np.random.default_rng(seed)
    cand = rand_pmf(rng, b, k, v)
    exist = rand_cdf(rng, b, v)
    values = np.sort(rng.random(v).astype(np.float32))
    got = expmax.expmax(jnp.asarray(cand), jnp.asarray(exist), jnp.asarray(values))
    want = ref.expmax_ref(jnp.asarray(cand), jnp.asarray(exist), jnp.asarray(values))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@given(bkv())
def test_bottleneck_matches_ref(args):
    b, k, v, seed = args
    rng = np.random.default_rng(seed)
    p = rand_pmf(rng, b, k, v)
    t = rand_pmf(rng, b, k, v)
    got = bottleneck.bottleneck(jnp.asarray(p), jnp.asarray(t))
    want = ref.bottleneck_ref(jnp.asarray(p), jnp.asarray(t))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_expmax_no_existing_copies_is_plain_mean():
    """With existing_cdf == 1, E[max] reduces to the candidate's mean."""
    rng = np.random.default_rng(0)
    cand = rand_pmf(rng, 4, 3, 32)
    values = np.linspace(0.0, 10.0, 32).astype(np.float32)
    exist = np.ones((4, 32), np.float32)
    got = np.asarray(
        expmax.expmax(jnp.asarray(cand), jnp.asarray(exist), jnp.asarray(values))
    )
    want = np.einsum("bkv,v->bk", cand, values)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_expmax_monotone_in_existing():
    """A stronger existing copy set (stochastically larger) raises E[max]."""
    rng = np.random.default_rng(1)
    cand = rand_pmf(rng, 2, 2, 16)
    values = np.linspace(0.0, 1.0, 16).astype(np.float32)
    weak = np.ones((2, 16), np.float32)  # no copies
    pmf = rand_pmf(rng, 2, 16)
    strong = np.cumsum(pmf, axis=-1).astype(np.float32)  # some copy
    lo = np.asarray(expmax.expmax(jnp.asarray(cand), jnp.asarray(weak), jnp.asarray(values)))
    hi = np.asarray(expmax.expmax(jnp.asarray(cand), jnp.asarray(strong), jnp.asarray(values)))
    assert (hi >= lo - 1e-6).all()


def test_bottleneck_point_masses():
    """min of point masses at bins 3 and 7 is a point mass at bin 3."""
    v = 16
    p = np.zeros((1, 1, v), np.float32)
    t = np.zeros((1, 1, v), np.float32)
    p[0, 0, 3] = 1.0
    t[0, 0, 7] = 1.0
    got = np.asarray(bottleneck.bottleneck(jnp.asarray(p), jnp.asarray(t)))
    assert got[0, 0, 3] == pytest.approx(1.0)
    assert got.sum() == pytest.approx(1.0)


def test_score_composition_matches_ref():
    from compile import model

    rng = np.random.default_rng(2)
    p = rand_pmf(rng, 3, 4, 32)
    t = rand_pmf(rng, 3, 4, 32)
    exist = rand_cdf(rng, 3, 32)
    values = np.linspace(0.0, 5.0, 32).astype(np.float32)
    got = model.score(
        jnp.asarray(p), jnp.asarray(t), jnp.asarray(exist), jnp.asarray(values)
    )
    want = ref.score_ref(
        jnp.asarray(p), jnp.asarray(t), jnp.asarray(exist), jnp.asarray(values)
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


# ---- payload kernels ------------------------------------------------------


@given(st.integers(0, 2**31 - 1), st.sampled_from([512, 1024, 2048]))
def test_wordcount_matches_ref(seed, n):
    rng = np.random.default_rng(seed)
    vocab = 64
    toks = rng.integers(0, vocab, size=n).astype(np.int32)
    got = np.asarray(analytics.wordcount(jnp.asarray(toks), vocab))
    want = np.bincount(toks, minlength=vocab).astype(np.float32)
    np.testing.assert_allclose(got, want)
    assert got.sum() == n


@given(st.integers(0, 2**31 - 1))
def test_pagerank_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n = 32
    adj = (rng.random((n, n)) < 0.2).astype(np.float32)
    ranks = np.full(n, 1.0 / n, np.float32)
    got = np.asarray(analytics.pagerank_step(jnp.asarray(ranks), jnp.asarray(adj)))
    want = np.asarray(ref.pagerank_step_ref(jnp.asarray(ranks), jnp.asarray(adj)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert got.sum() == pytest.approx(1.0, abs=0.2)


@given(st.integers(0, 2**31 - 1))
def test_logreg_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n, d = 128, 8
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = (rng.random(n) < 0.5).astype(np.float32)
    w = rng.standard_normal(d).astype(np.float32) * 0.1
    got = np.asarray(
        analytics.logreg_step(jnp.asarray(x), jnp.asarray(y), jnp.asarray(w))
    )
    want = np.asarray(
        ref.logreg_step_ref(jnp.asarray(x), jnp.asarray(y), jnp.asarray(w))
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_logreg_reduces_loss():
    rng = np.random.default_rng(3)
    n, d = 256, 16
    w_true = rng.standard_normal(d).astype(np.float32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    y = (x @ w_true > 0).astype(np.float32)
    w = np.zeros(d, np.float32)

    def loss(w):
        logits = x @ w
        p = 1.0 / (1.0 + np.exp(-logits))
        eps = 1e-7
        return -(y * np.log(p + eps) + (1 - y) * np.log(1 - p + eps)).mean()

    l0 = loss(w)
    for _ in range(20):
        w = np.asarray(analytics.logreg_step(jnp.asarray(x), jnp.asarray(y), jnp.asarray(w)))
    assert loss(w) < l0
