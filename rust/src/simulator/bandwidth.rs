//! Max-min fair-share bandwidth solver over cluster gates and WAN links —
//! the contended-WAN physics behind
//! [`crate::config::spec::BandwidthModel::Shared`].
//!
//! ## Model
//!
//! A *transfer* is the remote input stream of one running copy. It
//! traverses a set of *gates* — capacity-limited resources — each with a
//! per-transfer weight: a transfer running at rate `r` consumes `w · r`
//! of every gate it uses. The engine maps a copy onto three gate kinds
//! (see [`ingress_gate`]/[`egress_gate`]/[`wan_gate`]): its destination
//! cluster's ingress gate, each remote source's egress gate, and the
//! per-pair WAN link between them. Every transfer additionally carries a
//! private rate ceiling `cap` (the copy's solo launch rate): idle gates
//! never make a copy *faster* than constant-rate physics would.
//!
//! Rates are the **max-min fair** allocation: raise every transfer's rate
//! uniformly; when a gate (or a private cap) saturates, freeze the
//! transfers through it and keep filling the rest — the classic
//! progressive-filling algorithm. The fixpoint is unique, so any correct
//! solver must produce the same rates; *bitwise* equality additionally
//! needs the same arithmetic in the same order, which is what the
//! component-wise canonical routine below pins down.
//!
//! ## Two interchangeable backends
//!
//! * [`ReferenceFairShare`] — on every start/finish, re-partition **all**
//!   active transfers into gate-connected components and re-solve each
//!   from scratch: O(active transfers) per event, trivially correct.
//! * [`IncrementalFairShare`] — keeps active transfers in balanced
//!   activity structures (`BTreeMap`/`BTreeSet` keyed by transfer and
//!   gate id): a start/finish costs O(log n) structure maintenance plus a
//!   re-solve of **only the affected bottleneck group** (the
//!   gate-connected component the changed transfer touches). Transfers in
//!   unrelated components keep their stored rates untouched.
//!
//! Bit-identity between the two is *by construction*, and proptest-pinned:
//! both backends call the same pure [`Registry::resolve`] routine —
//! components are discovered over the same ordered structures, members
//! are solved in ascending-id order, and an untouched component's stored
//! rates are exactly what a from-scratch resolve of that component
//! produces (same function, same inputs). Everything iterates B-tree
//! order, so results are independent of insertion history.
//!
//! The engine drives the incremental backend **only from serial phases**
//! (the policy-epoch barrier) — see the barrier-only re-rate contract in
//! [`crate::simulator`].

use std::collections::{BTreeMap, BTreeSet};

/// Identifier of one capacity-limited resource (gate or WAN link).
pub type GateId = u64;

/// Gate id of cluster `m`'s ingress gate (plant with any cluster count).
pub fn ingress_gate(m: usize) -> GateId {
    m as GateId
}

/// Gate id of cluster `m`'s egress gate in an `n`-cluster plant.
pub fn egress_gate(n: usize, m: usize) -> GateId {
    (n + m) as GateId
}

/// Gate id of the directed WAN link `src → dst` in an `n`-cluster plant.
pub fn wan_gate(n: usize, src: usize, dst: usize) -> GateId {
    (2 * n + src * n + dst) as GateId
}

/// One active transfer: a stable id, a private rate ceiling, and the
/// weighted gates it traverses.
#[derive(Clone, Debug)]
pub struct Transfer {
    pub id: u64,
    /// Private rate ceiling (> 0): the transfer never exceeds it, no
    /// matter how idle its gates are.
    pub cap: f64,
    /// `(gate, weight)` pairs, ascending by gate id, weights > 0, one
    /// entry per gate ([`Transfer::new`] canonicalizes).
    pub uses: Vec<(GateId, f64)>,
}

impl Transfer {
    /// Build a transfer, merging duplicate gates (weights add), dropping
    /// non-positive weights and sorting by gate id — the canonical form
    /// both solver backends require.
    pub fn new(id: u64, cap: f64, uses: impl IntoIterator<Item = (GateId, f64)>) -> Transfer {
        let mut merged: BTreeMap<GateId, f64> = BTreeMap::new();
        for (g, w) in uses {
            if w > 0.0 {
                *merged.entry(g).or_insert(0.0) += w;
            }
        }
        Transfer {
            id,
            cap: cap.max(0.0),
            uses: merged.into_iter().collect(),
        }
    }
}

/// Diagnostics of one resolve: progressive filling must saturate at least
/// one bottleneck (a gate or a private cap) per iteration — that is *why*
/// it terminates — and the fairness proptests assert it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SolveDiag {
    /// Progressive-filling iterations across the solved components.
    pub iterations: u64,
    /// Bottlenecks saturated (within tolerance) across those iterations.
    pub saturated: u64,
}

impl SolveDiag {
    fn absorb(&mut self, other: SolveDiag) {
        self.iterations += other.iterations;
        self.saturated += other.saturated;
    }
}

/// The common solver surface of the two backends.
pub trait FairShare {
    /// Declare (or resize) a gate's capacity. Gates must exist before a
    /// transfer uses them; resizing a gate with active members re-rates
    /// them.
    fn set_gate(&mut self, g: GateId, capacity: f64);
    /// Register a transfer and re-rate whatever it contends with.
    fn start(&mut self, t: Transfer);
    /// Remove a transfer and re-rate whatever it contended with.
    fn finish(&mut self, id: u64);
    /// Current fair rate of one active transfer.
    fn rate(&self, id: u64) -> f64;
    /// All `(id, rate)` pairs, ascending by id.
    fn rates(&self) -> Vec<(u64, f64)>;
    /// Number of active transfers.
    fn active(&self) -> usize;
    /// Diagnostics of the most recent resolve.
    fn last_diag(&self) -> SolveDiag;
    /// Check that no gate's capacity is exceeded by the current rates
    /// (up to float tolerance).
    fn check_capacities(&self) -> Result<(), String>;
}

/// Relative saturation tolerance: a gate is "full" (and its transfers
/// freeze) once its residual headroom is below this fraction of capacity.
const SAT_TOL: f64 = 1e-9;

/// Progressive filling over one gate-connected component. `members` must
/// be sorted ascending by id — the canonical order both backends feed —
/// and every gate a member uses must be present in `caps`. Pure: rates
/// are a function of `(members, caps)` only, which is the whole
/// bit-identity argument between the backends.
fn solve_component(members: &[&Transfer], caps: &BTreeMap<GateId, f64>) -> (Vec<f64>, SolveDiag) {
    let n = members.len();
    let mut rate = vec![0.0f64; n];
    let mut frozen = vec![false; n];
    let mut diag = SolveDiag::default();
    // capacities of the gates this component touches, ascending
    let mut gates: BTreeMap<GateId, f64> = BTreeMap::new();
    for t in members {
        for &(g, _) in &t.uses {
            let cap = *caps
                .get(&g)
                .unwrap_or_else(|| panic!("transfer {} uses unknown gate {g}", t.id));
            gates.entry(g).or_insert(cap);
        }
    }
    #[derive(Clone, Copy, PartialEq)]
    enum Limiter {
        None,
        Gate(GateId),
        Cap(usize),
    }
    let mut used: BTreeMap<GateId, f64> = BTreeMap::new();
    let mut wsum: BTreeMap<GateId, f64> = BTreeMap::new();
    while frozen.iter().any(|f| !f) {
        diag.iterations += 1;
        // recompute usage and unfrozen weight per gate from scratch, in
        // member order — a pure function of the current rates, so the
        // arithmetic never depends on how we got here
        used.clear();
        wsum.clear();
        for (i, t) in members.iter().enumerate() {
            for &(g, w) in &t.uses {
                *used.entry(g).or_insert(0.0) += w * rate[i];
                if !frozen[i] {
                    *wsum.entry(g).or_insert(0.0) += w;
                }
            }
        }
        // the uniform increment: min over gate headroom per unit of
        // active weight, and over unfrozen transfers' private headroom
        // (f64 min is exact, so scan order cannot change the value)
        let mut delta = f64::INFINITY;
        let mut limiter = Limiter::None;
        for (&g, &w) in &wsum {
            if w <= 0.0 {
                continue;
            }
            let head = (gates[&g] - used.get(&g).copied().unwrap_or(0.0)).max(0.0);
            let d = head / w;
            if d < delta {
                delta = d;
                limiter = Limiter::Gate(g);
            }
        }
        for (i, t) in members.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            let d = (t.cap - rate[i]).max(0.0);
            if d < delta {
                delta = d;
                limiter = Limiter::Cap(i);
            }
        }
        if limiter == Limiter::None {
            // every unfrozen transfer is gateless with an infinite cap —
            // impossible through Transfer::new, but never spin
            break;
        }
        for (i, r) in rate.iter_mut().enumerate() {
            if !frozen[i] {
                *r += delta;
            }
        }
        // freeze the limiter's transfers — the saturated-bottleneck step
        // that guarantees progress — plus anything now flush against its
        // cap or a full gate (tolerance absorbs float drift)
        match limiter {
            Limiter::Gate(g) => {
                for (i, t) in members.iter().enumerate() {
                    if !frozen[i] && t.uses.iter().any(|&(h, _)| h == g) {
                        frozen[i] = true;
                    }
                }
            }
            Limiter::Cap(i) => frozen[i] = true,
            Limiter::None => unreachable!(),
        }
        used.clear();
        for (i, t) in members.iter().enumerate() {
            for &(g, w) in &t.uses {
                *used.entry(g).or_insert(0.0) += w * rate[i];
            }
        }
        let saturated = match limiter {
            Limiter::Gate(g) => {
                used.get(&g).copied().unwrap_or(0.0)
                    >= gates[&g] - SAT_TOL * gates[&g].abs().max(1.0)
            }
            Limiter::Cap(i) => rate[i] >= members[i].cap - SAT_TOL * members[i].cap.max(1.0),
            Limiter::None => false,
        };
        if saturated {
            diag.saturated += 1;
        }
        for (i, t) in members.iter().enumerate() {
            if frozen[i] {
                continue;
            }
            let at_cap = rate[i] >= t.cap - SAT_TOL * t.cap.max(1.0);
            let gate_full = t.uses.iter().any(|&(g, _)| {
                used.get(&g).copied().unwrap_or(0.0) >= gates[&g] - SAT_TOL * gates[&g].abs().max(1.0)
            });
            if at_cap || gate_full {
                frozen[i] = true;
            }
        }
    }
    (rate, diag)
}

/// The shared activity structure: gate capacities, active transfers, the
/// gate → members index, and the current rates — all B-trees, so every
/// lookup/update is O(log n) and every iteration is in canonical
/// (ascending-id) order regardless of operation history.
#[derive(Default)]
struct Registry {
    caps: BTreeMap<GateId, f64>,
    transfers: BTreeMap<u64, Transfer>,
    members: BTreeMap<GateId, BTreeSet<u64>>,
    rates: BTreeMap<u64, f64>,
}

impl Registry {
    fn set_gate(&mut self, g: GateId, capacity: f64) {
        self.caps.insert(g, capacity.max(0.0));
    }

    fn insert(&mut self, t: Transfer) {
        assert!(
            !self.transfers.contains_key(&t.id),
            "duplicate transfer id {}",
            t.id
        );
        for &(g, _) in &t.uses {
            assert!(self.caps.contains_key(&g), "transfer {} uses unknown gate {g}", t.id);
            self.members.entry(g).or_default().insert(t.id);
        }
        self.rates.insert(t.id, 0.0);
        self.transfers.insert(t.id, t);
    }

    fn remove(&mut self, id: u64) -> Transfer {
        let t = self.transfers.remove(&id).expect("finish of unknown transfer");
        for &(g, _) in &t.uses {
            if let Some(m) = self.members.get_mut(&g) {
                m.remove(&id);
                if m.is_empty() {
                    self.members.remove(&g);
                }
            }
        }
        self.rates.remove(&id);
        t
    }

    /// Expand `seeds` into whole gate-connected components (of the
    /// *current* active set) and re-solve each with the canonical
    /// routine, storing the rates. Transfers unreachable from any seed
    /// are untouched. Components are visited in ascending seed order and
    /// solved independently — exactly what a full re-solve does, which is
    /// why a partial resolve over whole components is bit-identical to it.
    fn resolve(&mut self, seeds: &BTreeSet<u64>) -> SolveDiag {
        let mut diag = SolveDiag::default();
        let mut visited: BTreeSet<u64> = BTreeSet::new();
        for &seed in seeds {
            if visited.contains(&seed) || !self.transfers.contains_key(&seed) {
                continue;
            }
            // flood the component through the gate-membership index
            let mut comp: BTreeSet<u64> = BTreeSet::new();
            let mut stack = vec![seed];
            comp.insert(seed);
            while let Some(id) = stack.pop() {
                for &(g, _) in &self.transfers[&id].uses {
                    if let Some(m) = self.members.get(&g) {
                        for &o in m {
                            if comp.insert(o) {
                                stack.push(o);
                            }
                        }
                    }
                }
            }
            visited.extend(comp.iter().copied());
            let members: Vec<&Transfer> = comp.iter().map(|id| &self.transfers[id]).collect();
            let (rates, d) = solve_component(&members, &self.caps);
            diag.absorb(d);
            for (id, r) in comp.iter().zip(rates) {
                self.rates.insert(*id, r);
            }
        }
        diag
    }

    fn rates_vec(&self) -> Vec<(u64, f64)> {
        self.rates.iter().map(|(&id, &r)| (id, r)).collect()
    }

    fn check_capacities(&self) -> Result<(), String> {
        for (&g, members) in &self.members {
            let cap = *self.caps.get(&g).ok_or_else(|| format!("gate {g} has no capacity"))?;
            let mut load = 0.0;
            for id in members {
                let t = &self.transfers[id];
                let w = t
                    .uses
                    .iter()
                    .find(|(h, _)| *h == g)
                    .map(|(_, w)| *w)
                    .unwrap_or(0.0);
                load += w * self.rates[id];
            }
            if load > cap * (1.0 + 1e-9) + 1e-9 {
                return Err(format!("gate {g}: load {load} exceeds capacity {cap}"));
            }
        }
        for (id, t) in &self.transfers {
            if self.rates[id] > t.cap * (1.0 + 1e-9) + 1e-12 {
                return Err(format!(
                    "transfer {id}: rate {} exceeds private cap {}",
                    self.rates[id], t.cap
                ));
            }
        }
        Ok(())
    }
}

/// The from-scratch backend: every start/finish re-solves **all** active
/// transfers. O(active) per event — the correctness reference the
/// incremental backend is proptest-pinned against.
#[derive(Default)]
pub struct ReferenceFairShare {
    reg: Registry,
    last: SolveDiag,
}

impl ReferenceFairShare {
    pub fn new() -> ReferenceFairShare {
        ReferenceFairShare::default()
    }

    fn resolve_all(&mut self) {
        let seeds: BTreeSet<u64> = self.reg.transfers.keys().copied().collect();
        self.last = self.reg.resolve(&seeds);
    }
}

impl FairShare for ReferenceFairShare {
    fn set_gate(&mut self, g: GateId, capacity: f64) {
        self.reg.set_gate(g, capacity);
        if self.reg.members.contains_key(&g) {
            self.resolve_all();
        }
    }

    fn start(&mut self, t: Transfer) {
        self.reg.insert(t);
        self.resolve_all();
    }

    fn finish(&mut self, id: u64) {
        self.reg.remove(id);
        self.resolve_all();
    }

    fn rate(&self, id: u64) -> f64 {
        self.reg.rates[&id]
    }

    fn rates(&self) -> Vec<(u64, f64)> {
        self.reg.rates_vec()
    }

    fn active(&self) -> usize {
        self.reg.transfers.len()
    }

    fn last_diag(&self) -> SolveDiag {
        self.last
    }

    fn check_capacities(&self) -> Result<(), String> {
        self.reg.check_capacities()
    }
}

/// The incremental backend: a start/finish performs O(log n) activity-
/// structure maintenance, then re-solves only the gate-connected
/// component the change touches. Rates of unrelated components are not
/// even read. Bit-identical to [`ReferenceFairShare`] (see the module
/// docs for the argument; the proptests pin it).
#[derive(Default)]
pub struct IncrementalFairShare {
    reg: Registry,
    last: SolveDiag,
}

impl IncrementalFairShare {
    pub fn new() -> IncrementalFairShare {
        IncrementalFairShare::default()
    }
}

impl FairShare for IncrementalFairShare {
    fn set_gate(&mut self, g: GateId, capacity: f64) {
        self.reg.set_gate(g, capacity);
        if let Some(m) = self.reg.members.get(&g) {
            let seeds: BTreeSet<u64> = m.iter().copied().collect();
            self.last = self.reg.resolve(&seeds);
        }
    }

    fn start(&mut self, t: Transfer) {
        let id = t.id;
        self.reg.insert(t);
        // the new transfer connects (and possibly merges) every component
        // its gates touch; flooding from it covers exactly those
        let seeds: BTreeSet<u64> = BTreeSet::from([id]);
        self.last = self.reg.resolve(&seeds);
    }

    fn finish(&mut self, id: u64) {
        let t = self.reg.remove(id);
        // removal can split the old component — every former gate-peer
        // seeds the flood, and resolve() partitions what remains
        let mut seeds: BTreeSet<u64> = BTreeSet::new();
        for &(g, _) in &t.uses {
            if let Some(m) = self.reg.members.get(&g) {
                seeds.extend(m.iter().copied());
            }
        }
        self.last = self.reg.resolve(&seeds);
    }

    fn rate(&self, id: u64) -> f64 {
        self.reg.rates[&id]
    }

    fn rates(&self) -> Vec<(u64, f64)> {
        self.reg.rates_vec()
    }

    fn active(&self) -> usize {
        self.reg.transfers.len()
    }

    fn last_diag(&self) -> SolveDiag {
        self.last
    }

    fn check_capacities(&self) -> Result<(), String> {
        self.reg.check_capacities()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn t(id: u64, cap: f64, uses: &[(GateId, f64)]) -> Transfer {
        Transfer::new(id, cap, uses.iter().copied())
    }

    #[test]
    fn single_transfer_gets_min_of_cap_and_gates() {
        let mut s = ReferenceFairShare::new();
        s.set_gate(0, 10.0);
        s.set_gate(1, 4.0);
        s.start(t(7, 100.0, &[(0, 1.0), (1, 0.5)]));
        // gate 1 binds: 0.5 · r = 4 → r = 8
        assert!((s.rate(7) - 8.0).abs() < 1e-9);
        s.finish(7);
        s.start(t(8, 3.0, &[(0, 1.0), (1, 0.5)]));
        // the private cap binds below both gates
        assert!((s.rate(8) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn equal_sharers_split_a_gate_evenly() {
        let mut s = ReferenceFairShare::new();
        s.set_gate(0, 12.0);
        for id in 0..4 {
            s.start(t(id, 100.0, &[(0, 1.0)]));
        }
        for id in 0..4 {
            assert!((s.rate(id) - 3.0).abs() < 1e-9, "id {id}");
        }
        // one leaves: the rest re-rate to 4 each
        s.finish(2);
        for id in [0u64, 1, 3] {
            assert!((s.rate(id) - 4.0).abs() < 1e-9, "id {id}");
        }
    }

    #[test]
    fn capped_transfer_releases_headroom_to_sharers() {
        // classic max-min: one sharer is capped below the even split, the
        // others absorb what it leaves on the table
        let mut s = ReferenceFairShare::new();
        s.set_gate(0, 12.0);
        s.start(t(0, 2.0, &[(0, 1.0)]));
        s.start(t(1, 100.0, &[(0, 1.0)]));
        s.start(t(2, 100.0, &[(0, 1.0)]));
        assert!((s.rate(0) - 2.0).abs() < 1e-9);
        assert!((s.rate(1) - 5.0).abs() < 1e-9);
        assert!((s.rate(2) - 5.0).abs() < 1e-9);
        s.check_capacities().unwrap();
    }

    #[test]
    fn weights_scale_consumption() {
        // weight 2 consumes twice the gate per unit rate: fair *rates*
        // equalize until the heavy one's consumption saturates the gate
        let mut s = ReferenceFairShare::new();
        s.set_gate(0, 9.0);
        s.start(t(0, 100.0, &[(0, 2.0)]));
        s.start(t(1, 100.0, &[(0, 1.0)]));
        // uniform filling: both reach r with 3r = 9 → r = 3
        assert!((s.rate(0) - 3.0).abs() < 1e-9);
        assert!((s.rate(1) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn disjoint_components_do_not_interact() {
        let mut inc = IncrementalFairShare::new();
        inc.set_gate(0, 10.0);
        inc.set_gate(1, 6.0);
        inc.start(t(0, 100.0, &[(0, 1.0)]));
        inc.start(t(1, 100.0, &[(1, 1.0)]));
        let r0 = inc.rate(0).to_bits();
        // churn in component 1 must not even touch component 0's rate
        inc.start(t(2, 100.0, &[(1, 1.0)]));
        inc.finish(2);
        assert_eq!(inc.rate(0).to_bits(), r0);
        assert!((inc.rate(1) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn incremental_handles_component_splits() {
        // 0 —g0— 1 —g1— 2: removing the bridge transfer 1 splits the
        // component; both halves must re-rate to their solo allocations
        let mut inc = IncrementalFairShare::new();
        let mut re = ReferenceFairShare::new();
        for s in [&mut inc as &mut dyn FairShare, &mut re as &mut dyn FairShare] {
            s.set_gate(0, 8.0);
            s.set_gate(1, 4.0);
            s.start(t(0, 100.0, &[(0, 1.0)]));
            s.start(t(1, 100.0, &[(0, 1.0), (1, 1.0)]));
            s.start(t(2, 100.0, &[(1, 1.0)]));
            s.finish(1);
        }
        assert_eq!(inc.rates(), re.rates());
        assert!((inc.rate(0) - 8.0).abs() < 1e-9);
        assert!((inc.rate(2) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn zero_capacity_gate_pins_rates_to_zero() {
        let mut s = ReferenceFairShare::new();
        s.set_gate(0, 0.0);
        s.start(t(0, 5.0, &[(0, 1.0)]));
        assert_eq!(s.rate(0), 0.0);
        s.check_capacities().unwrap();
    }

    /// Drive both backends through one random start/finish interleaving,
    /// checking the satellite's three fairness invariants after every op.
    fn churn_both(seed: u64) {
        let mut rng = Rng::new(0xBA5E_0000 + seed);
        let n_gates = rng.range_usize(3, 14);
        let mut re = ReferenceFairShare::new();
        let mut inc = IncrementalFairShare::new();
        for g in 0..n_gates as u64 {
            let cap = rng.range_f64(1.0, 60.0);
            re.set_gate(g, cap);
            inc.set_gate(g, cap);
        }
        let mut next_id = 0u64;
        let mut active: Vec<u64> = Vec::new();
        for _op in 0..120 {
            let start = active.len() < 2 || (active.len() < 40 && rng.chance(0.6));
            if start {
                let n_uses = rng.range_usize(1, 4.min(n_gates));
                let mut uses = Vec::new();
                for _ in 0..n_uses {
                    uses.push((
                        rng.range_usize(0, n_gates - 1) as GateId,
                        rng.range_f64(0.1, 2.0),
                    ));
                }
                let tr = Transfer::new(next_id, rng.range_f64(0.5, 30.0), uses);
                next_id += 1;
                active.push(tr.id);
                re.start(tr.clone());
                inc.start(tr);
            } else {
                let victim = active.swap_remove(rng.range_usize(0, active.len() - 1));
                re.finish(victim);
                inc.finish(victim);
            }
            // (1) progressive filling saturated ≥ 1 bottleneck per iteration
            let d = re.last_diag();
            assert!(
                d.saturated >= d.iterations,
                "seed {seed}: {} iterations saturated only {} bottlenecks",
                d.iterations,
                d.saturated
            );
            // (2) no gate or private cap exceeded, in either backend
            re.check_capacities().unwrap_or_else(|e| panic!("seed {seed} (reference): {e}"));
            inc.check_capacities()
                .unwrap_or_else(|e| panic!("seed {seed} (incremental): {e}"));
            // (3) incremental == reference, bit for bit
            let rr = re.rates();
            let ri = inc.rates();
            assert_eq!(rr.len(), ri.len(), "seed {seed}: active sets diverged");
            for ((ida, ra), (idb, rb)) in rr.iter().zip(&ri) {
                assert_eq!(ida, idb, "seed {seed}: transfer ids diverged");
                assert_eq!(
                    ra.to_bits(),
                    rb.to_bits(),
                    "seed {seed}: transfer {ida} rates diverged ({ra} vs {rb})"
                );
            }
        }
    }

    #[test]
    fn prop_incremental_is_bit_identical_to_reference_under_churn() {
        const SEEDS: std::ops::Range<u64> = 0..12;
        for seed in SEEDS {
            churn_both(seed);
        }
    }
}
