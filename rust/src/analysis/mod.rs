//! Numeric verification of the paper's theory (Sec 4.2, Appendix A).
//!
//! The approximation bound itself is asymptotic; what we can check by
//! computation is (a) Proposition 1 — the diminishing-returns property
//! `r(a)/a >= r(b)/b` for best-first copy orderings — over randomized
//! distribution families, and (b) the competitive-ratio expression
//! `(α(1+ε)+C) / (αε² + (α−1)ε)` being finite and decreasing in ε on
//! (0,1) for α > 1/(1+ε), which Theorem 2 requires.

pub mod proposition;

pub use proposition::{check_proposition1, competitive_ratio, first_ratio_violation};
