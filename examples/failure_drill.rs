//! Failure drill: crank cluster-level unreachability far beyond Table 2 and
//! watch insurance keep jobs alive — the reliability story of the paper in
//! isolation. Compares PingAn's Eff-Reli against the reliability-blind
//! Eff-Eff variant and no-copy Flutter under a hostile plant.
//!
//! ```bash
//! cargo run --release --example failure_drill
//! ```

use pingan::baselines::Flutter;
use pingan::cluster::GeoSystem;
use pingan::config::spec::{PingAnSpec, Principle, SystemSpec, WorkloadSpec};
use pingan::insurance::PingAn;
use pingan::metrics;
use pingan::simulator::{SimConfig, Simulation};
use pingan::util::rng::Rng;
use pingan::workload::montage;

fn main() {
    // hostile plant: every class fails 5-10x more often than Table 2
    let mut spec = SystemSpec::small(10);
    for c in &mut spec.classes {
        c.unreach_p = (c.unreach_p.0 * 5.0, (c.unreach_p.1 * 5.0).min(0.6));
    }
    let mut rng = Rng::new(13);
    let system = GeoSystem::generate(&spec, &mut rng);
    let mut wspec = WorkloadSpec::scaled(30, 0.04);
    wspec.datasize = (100.0, 600.0);
    let sites: Vec<usize> = (0..system.n()).collect();
    let jobs = montage::generate(&wspec, &sites, &mut rng);

    println!("hostile plant: per-slot cluster unreachability up to 60%\n");
    let run = |name: &str, sched: &mut dyn pingan::sched::Scheduler| {
        let res = Simulation::new(&system, jobs.clone(), SimConfig::default()).run(sched);
        println!(
            "{:<28} avg flowtime {:>8.1} | copies {:>5} | failure-killed {:>5} ({:.0}% of copies)",
            name,
            metrics::avg_flowtime(&res),
            res.copies_launched,
            res.copies_failed,
            100.0 * res.copies_failed as f64 / res.copies_launched.max(1) as f64,
        );
        metrics::avg_flowtime(&res)
    };

    let flutter = run("flutter (no copies)", &mut Flutter::new());
    let mut eff_eff_spec = PingAnSpec::with_epsilon(0.6);
    eff_eff_spec.principle = Principle::EffEff;
    let eff_eff = run("pingan Eff-Eff (blind)", &mut PingAn::new(eff_eff_spec));
    let eff_reli = run("pingan Eff-Reli (paper)", &mut PingAn::with_epsilon(0.6));

    println!(
        "\nreliability-aware insurance vs flutter: {:.1}% faster; vs reliability-blind: {:.1}%",
        100.0 * (flutter - eff_reli) / flutter,
        100.0 * (eff_eff - eff_reli) / eff_eff,
    );
}
