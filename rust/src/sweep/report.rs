//! Sweep results: per-cell raw outcomes ([`CellResult`]), per-scenario
//! aggregate rows ([`ScenarioRow`]), and the CSV / JSON / table emitters.
//!
//! Wall-clock time is recorded per cell for the benches but deliberately
//! excluded from equality — two runs of the same spec compare equal
//! whenever their *simulated* outcomes match, which is what the
//! determinism tests assert across thread counts.

use super::spec::Scenario;
use crate::metrics::{self, FlowStats};
use crate::obs::{Counters, SpansSnapshot};
use crate::simulator::SimResult;
use crate::util::jsonout::Json;
use crate::util::stats;
use crate::util::table::{fnum, Table};

/// Raw outcome of one sweep cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    /// Position in the expanded grid (`SweepSpec::cells()` order).
    pub index: usize,
    pub scenario: Scenario,
    /// The environment seed this cell ran under.
    pub seed: u64,
    /// Per-job flowtimes (NaN = unfinished), empty when `error` is set
    /// **or** when the cell ran under `stream_metrics` (the sketch below
    /// is then the only per-cell statistic).
    pub flowtimes: Vec<f64>,
    /// Streaming moment/quantile sketch over the cell's flowtimes —
    /// populated identically with and without `stream_metrics`, so it is
    /// part of `==` like every other simulated outcome.
    pub stats: FlowStats,
    /// (p50, p95, p99) of the cell's finished-job flowtimes, computed
    /// once at construction — exact (sorted series) when the raw `Vec`
    /// was kept, sketch-derived under `stream_metrics` — and shared by
    /// every emitter instead of re-collecting and re-sorting per query.
    pub percentiles: (f64, f64, f64),
    pub finished: usize,
    pub total: usize,
    pub copies_launched: u64,
    pub copies_failed: u64,
    /// Simulated slots.
    pub slots: u64,
    /// Decision points the engine worked through (stepped slots under the
    /// dense core, processed events under event-skip) — skip efficiency
    /// is `events_processed / slots`, observable without a profiler.
    pub events_processed: u64,
    /// Why the cell produced no result (scheduler construction failure or
    /// a caught panic).
    pub error: Option<String>,
    /// Plane-A telemetry: the cell's deterministic counter block (engine
    /// events + insurer decisions). Part of `==` — two runs of the same
    /// spec must agree on every counter at any thread count.
    pub telemetry: Counters,
    /// Plane-B telemetry: wall-clock span percentiles. Host noise, so —
    /// like `wall_secs` — excluded from `==` and from the deterministic
    /// JSON variant.
    pub spans: SpansSnapshot,
    /// Host wall-clock seconds spent on this cell (excluded from `==`).
    pub wall_secs: f64,
}

impl PartialEq for CellResult {
    /// Equality over simulated outcome only — `wall_secs` is host noise.
    fn eq(&self, other: &CellResult) -> bool {
        self.index == other.index
            && self.scenario == other.scenario
            && self.seed == other.seed
            && same_series(&self.flowtimes, &other.flowtimes)
            && self.stats == other.stats
            && same_triple(self.percentiles, other.percentiles)
            && self.finished == other.finished
            && self.total == other.total
            && self.copies_launched == other.copies_launched
            && self.copies_failed == other.copies_failed
            && self.slots == other.slots
            && self.events_processed == other.events_processed
            && self.error == other.error
            && self.telemetry == other.telemetry
    }
}

/// Bitwise series equality (NaN == NaN, unlike `Vec<f64>`'s `==`).
fn same_series(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len()
        && a.iter()
            .zip(b)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Bitwise (p50, p95, p99) equality — NaN-safe like [`same_series`].
fn same_triple(a: (f64, f64, f64), b: (f64, f64, f64)) -> bool {
    a.0.to_bits() == b.0.to_bits()
        && a.1.to_bits() == b.1.to_bits()
        && a.2.to_bits() == b.2.to_bits()
}

impl CellResult {
    pub fn from_sim(
        index: usize,
        scenario: Scenario,
        seed: u64,
        sim: &SimResult,
        wall_secs: f64,
    ) -> CellResult {
        CellResult {
            index,
            scenario,
            seed,
            flowtimes: sim.flowtimes.clone(),
            stats: sim.stats.clone(),
            percentiles: metrics::flowtime_percentiles(sim),
            finished: sim.finished_jobs,
            total: sim.total_jobs,
            copies_launched: sim.copies_launched,
            copies_failed: sim.copies_failed,
            slots: sim.slots,
            events_processed: sim.events_processed,
            error: None,
            telemetry: sim.telemetry.clone(),
            spans: sim.spans.clone(),
            wall_secs,
        }
    }

    pub fn failed(
        index: usize,
        scenario: Scenario,
        seed: u64,
        error: String,
        wall_secs: f64,
    ) -> CellResult {
        CellResult {
            index,
            scenario,
            seed,
            flowtimes: Vec::new(),
            stats: FlowStats::default(),
            percentiles: (f64::NAN, f64::NAN, f64::NAN),
            finished: 0,
            total: 0,
            copies_launched: 0,
            copies_failed: 0,
            slots: 0,
            events_processed: 0,
            error: Some(error),
            telemetry: Counters::default(),
            spans: SpansSnapshot::default(),
            wall_secs,
        }
    }

    /// Mean flowtime over this cell's finished jobs (NaN when errored or
    /// nothing finished). Reads the [`FlowStats`] sketch, so it answers
    /// identically with and without `stream_metrics`.
    pub fn mean_flowtime(&self) -> f64 {
        if self.stats.finished() == 0 {
            f64::NAN
        } else {
            self.stats.mean()
        }
    }
}

/// One scenario group (all axes except `rep`) aggregated across replicas.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioRow {
    /// Representative scenario (`rep = 0`).
    pub scenario: Scenario,
    /// Replicas that ran without error.
    pub reps_ok: usize,
    /// Per-job flowtimes averaged across replicas (the paper's per-job
    /// ten-rep mean); NaN where a job finished in no replica. Empty when
    /// the group ran under `stream_metrics` — streamed cells keep no
    /// per-job series, so the row's statistics come from the pooled
    /// [`FlowStats`] sketch instead.
    pub flows: Vec<f64>,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// 95% confidence half-width of the mean across replica means
    /// (0 with fewer than two successful replicas).
    pub ci95: f64,
    /// Copies launched per job (copy-cost accounting, Sec 6.3).
    pub copies_per_job: f64,
    /// Fraction of launched copies killed by cluster failures.
    pub copy_fail_rate: f64,
    /// Jobs that finished in no replica (exact mode), or the total
    /// not-finished count summed across replicas (streamed mode, where
    /// per-job cross-replica matching is impossible without the series).
    pub unfinished: usize,
    /// Replicas that errored (panic or bad config).
    pub errors: usize,
    /// Plane-A counters summed across the group's successful replicas.
    pub telemetry: Counters,
}

/// A finished sweep: aggregate rows in grid order plus the raw cells.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepReport {
    pub base_seed: u64,
    pub rows: Vec<ScenarioRow>,
    pub cells: Vec<CellResult>,
}

impl SweepReport {
    /// Aggregate cells (grid order) into per-scenario rows. Groups keep
    /// first-appearance order, so rows mirror the declared grid.
    pub fn from_cells(base_seed: u64, cells: Vec<CellResult>) -> SweepReport {
        let mut groups: Vec<(Scenario, Vec<usize>)> = Vec::new();
        for (i, c) in cells.iter().enumerate() {
            let g = c.scenario.group();
            match groups.iter().position(|(k, _)| *k == g) {
                Some(p) => groups[p].1.push(i),
                None => groups.push((g, vec![i])),
            }
        }
        let rows = groups
            .into_iter()
            .map(|(scenario, members)| {
                let ok: Vec<&CellResult> = members
                    .iter()
                    .map(|&i| &cells[i])
                    .filter(|c| c.error.is_none())
                    .collect();
                let errors = members.len() - ok.len();
                // Streamed cells kept no raw series: pool their FlowStats
                // sketches (Welford merge) and read mean/quantiles off the
                // pooled sketch. `flows` stays empty and `unfinished`
                // becomes the pooled not-finished count summed over reps
                // (per-job cross-rep matching needs the raw series).
                let streamed = !ok.is_empty()
                    && ok
                        .iter()
                        .all(|c| c.flowtimes.is_empty() && c.stats.total() > 0);
                let (flows, mean, (p50, p95, p99), unfinished) = if streamed {
                    let mut pooled = FlowStats::default();
                    for c in &ok {
                        pooled.merge(&c.stats);
                    }
                    let (mean, pcts) = if pooled.finished() == 0 {
                        (f64::NAN, (f64::NAN, f64::NAN, f64::NAN))
                    } else {
                        (pooled.mean(), pooled.percentiles())
                    };
                    (Vec::new(), mean, pcts, pooled.unfinished() as usize)
                } else {
                    let series: Vec<&[f64]> =
                        ok.iter().map(|c| c.flowtimes.as_slice()).collect();
                    let flows = metrics::average_per_job(&series);
                    let finite: Vec<f64> =
                        flows.iter().copied().filter(|f| f.is_finite()).collect();
                    // no finished jobs at all -> NaN everywhere (JSON
                    // null), never a fabricated 0-slot flowtime
                    let (mean, pcts) = if finite.is_empty() {
                        (f64::NAN, (f64::NAN, f64::NAN, f64::NAN))
                    } else {
                        (stats::mean(&finite), metrics::percentiles(&flows))
                    };
                    let unfinished = flows.iter().filter(|f| !f.is_finite()).count();
                    (flows, mean, pcts, unfinished)
                };
                let rep_means: Vec<f64> = ok
                    .iter()
                    .map(|c| c.mean_flowtime())
                    .filter(|m| m.is_finite())
                    .collect();
                let ci95 = if rep_means.len() >= 2 {
                    let m = stats::mean(&rep_means);
                    let var = rep_means.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
                        / (rep_means.len() - 1) as f64;
                    1.96 * (var / rep_means.len() as f64).sqrt()
                } else {
                    0.0
                };
                let jobs: usize = ok.iter().map(|c| c.total).sum();
                let copies: u64 = ok.iter().map(|c| c.copies_launched).sum();
                let fails: u64 = ok.iter().map(|c| c.copies_failed).sum();
                let mut telemetry = Counters::default();
                for c in &ok {
                    telemetry.merge(&c.telemetry);
                }
                ScenarioRow {
                    scenario,
                    reps_ok: ok.len(),
                    unfinished,
                    flows,
                    mean,
                    p50,
                    p95,
                    p99,
                    ci95,
                    copies_per_job: if jobs > 0 { copies as f64 / jobs as f64 } else { 0.0 },
                    copy_fail_rate: if copies > 0 { fails as f64 / copies as f64 } else { 0.0 },
                    errors,
                    telemetry,
                }
            })
            .collect();
        SweepReport { base_seed, rows, cells }
    }

    /// CSV over aggregate rows; deterministic for a given spec at any
    /// thread count (no wall-clock columns). The Plane-A counter columns
    /// come from [`Counters::fields`], so CSV and JSON stay in sync with
    /// the counter set by construction.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "scheduler,lambda,epsilon,principle,allocation,clusters,jobs,failure_scale,mix,\
             reps_ok,errors,mean,p50,p95,p99,ci95,copies_per_job,copy_fail_rate,unfinished",
        );
        for (name, _) in Counters::default().fields() {
            out.push(',');
            out.push_str(name);
        }
        out.push('\n');
        for r in &self.rows {
            let s = &r.scenario;
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}",
                s.scheduler,
                s.lambda,
                s.epsilon,
                s.principle.name(),
                s.allocation.name(),
                s.n_clusters,
                s.n_jobs,
                s.failure_scale,
                s.mix.name(),
                r.reps_ok,
                r.errors,
                r.mean,
                r.p50,
                r.p95,
                r.p99,
                r.ci95,
                r.copies_per_job,
                r.copy_fail_rate,
                r.unfinished,
            ));
            for (_, v) in r.telemetry.fields() {
                out.push_str(&format!(",{v}"));
            }
            out.push('\n');
        }
        out
    }

    /// Full JSON report: aggregate rows plus per-cell outcomes including
    /// wall-clock seconds (the nondeterministic part lives only here).
    pub fn to_json(&self) -> Json {
        self.json_with(true)
    }

    /// The same report with host wall-clock excluded: for a given spec its
    /// serialized bytes are identical at any runner thread count and any
    /// `score_threads` budget — the determinism suite compares them
    /// byte-for-byte.
    pub fn to_json_deterministic(&self) -> Json {
        self.json_with(false)
    }

    fn json_with(&self, include_wall: bool) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let s = &r.scenario;
                let mut j = Json::obj();
                j.set("scheduler", Json::str(&s.scheduler))
                    .set("lambda", Json::num(s.lambda))
                    .set("epsilon", Json::num(s.epsilon))
                    .set("principle", Json::str(s.principle.name()))
                    .set("allocation", Json::str(s.allocation.name()))
                    .set("clusters", Json::num(s.n_clusters as f64))
                    .set("jobs", Json::num(s.n_jobs as f64))
                    .set("failure_scale", Json::num(s.failure_scale))
                    .set("mix", Json::str(s.mix.name()))
                    .set("reps_ok", Json::num(r.reps_ok as f64))
                    .set("errors", Json::num(r.errors as f64))
                    .set("mean", Json::num(r.mean))
                    .set("p50", Json::num(r.p50))
                    .set("p95", Json::num(r.p95))
                    .set("p99", Json::num(r.p99))
                    .set("ci95", Json::num(r.ci95))
                    .set("copies_per_job", Json::num(r.copies_per_job))
                    .set("copy_fail_rate", Json::num(r.copy_fail_rate))
                    .set("unfinished", Json::num(r.unfinished as f64))
                    .set("telemetry", r.telemetry.to_json());
                j
            })
            .collect();
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut j = Json::obj();
                j.set("index", Json::num(c.index as f64))
                    .set("label", Json::str(&c.scenario.label()))
                    .set("seed", Json::str(&c.seed.to_string()))
                    .set("mean", Json::num(c.mean_flowtime()))
                    .set("p50", Json::num(c.percentiles.0))
                    .set("p95", Json::num(c.percentiles.1))
                    .set("p99", Json::num(c.percentiles.2))
                    .set("finished", Json::num(c.finished as f64))
                    .set("total", Json::num(c.total as f64))
                    .set("copies_launched", Json::num(c.copies_launched as f64))
                    .set("slots", Json::num(c.slots as f64))
                    .set("events_processed", Json::num(c.events_processed as f64))
                    .set("telemetry", c.telemetry.to_json());
                if include_wall {
                    // Plane B rides with the other host-noise fields: the
                    // deterministic variant must stay byte-comparable
                    j.set("wall_secs", Json::num(c.wall_secs))
                        .set("telemetry_wall", c.spans.to_json());
                }
                if let Some(e) = &c.error {
                    j.set("error", Json::str(e));
                }
                j
            })
            .collect();
        let mut j = Json::obj();
        j.set("base_seed", Json::num(self.base_seed as f64))
            .set("rows", Json::Arr(rows))
            .set("cells", Json::Arr(cells));
        j
    }

    /// Human-readable summary table.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "sweep report (flowtimes in slots)",
            &[
                "scheduler", "lambda", "epsilon", "clusters", "fail×", "mix", "variant", "reps",
                "mean", "p50", "p95", "p99", "±ci95", "copies/job", "unfin", "err",
            ],
        );
        for r in &self.rows {
            let s = &r.scenario;
            t.row(&[
                s.scheduler.clone(),
                fnum(s.lambda, 3),
                fnum(s.epsilon, 2),
                s.n_clusters.to_string(),
                fnum(s.failure_scale, 1),
                s.mix.name().to_string(),
                format!("{}/{}", s.principle.name(), s.allocation.name()),
                r.reps_ok.to_string(),
                fnum(r.mean, 1),
                fnum(r.p50, 1),
                fnum(r.p95, 1),
                fnum(r.p99, 1),
                fnum(r.ci95, 1),
                fnum(r.copies_per_job, 2),
                r.unfinished.to_string(),
                r.errors.to_string(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(index: usize, scheduler: &str, rep: u64, flows: &[f64], wall: f64) -> CellResult {
        let mut s = Scenario::default();
        s.scheduler = scheduler.to_string();
        s.rep = rep;
        CellResult {
            index,
            scenario: s,
            seed: 1000 + rep,
            flowtimes: flows.to_vec(),
            stats: FlowStats::from_flowtimes(flows),
            percentiles: metrics::percentiles(flows),
            finished: flows.iter().filter(|f| f.is_finite()).count(),
            total: flows.len(),
            copies_launched: 4,
            copies_failed: 1,
            slots: 100,
            events_processed: 100,
            error: None,
            telemetry: Counters::default(),
            spans: SpansSnapshot::default(),
            wall_secs: wall,
        }
    }

    /// The same cell as [`cell`] but as `--stream-metrics` would emit it:
    /// sketch only, raw series dropped.
    fn streamed_cell(index: usize, scheduler: &str, rep: u64, flows: &[f64]) -> CellResult {
        let mut c = cell(index, scheduler, rep, flows, 0.1);
        c.flowtimes = Vec::new();
        c.percentiles = c.stats.percentiles();
        c
    }

    #[test]
    fn groups_replicas_and_averages_per_job() {
        let cells = vec![
            cell(0, "pingan", 0, &[10.0, 20.0], 0.5),
            cell(1, "pingan", 1, &[30.0, 40.0], 0.7),
            cell(2, "flutter", 0, &[50.0, 60.0], 0.2),
            cell(3, "flutter", 1, &[70.0, 80.0], 0.1),
        ];
        let rep = SweepReport::from_cells(7, cells);
        assert_eq!(rep.rows.len(), 2);
        assert_eq!(rep.rows[0].scenario.scheduler, "pingan");
        assert_eq!(rep.rows[0].reps_ok, 2);
        assert_eq!(rep.rows[0].flows, vec![20.0, 30.0]);
        assert!((rep.rows[0].mean - 25.0).abs() < 1e-12);
        assert!((rep.rows[0].copies_per_job - 8.0 / 4.0).abs() < 1e-12);
        assert!((rep.rows[0].copy_fail_rate - 0.25).abs() < 1e-12);
        assert!(rep.rows[0].ci95 > 0.0);
        assert_eq!(rep.rows[1].scenario.scheduler, "flutter");
    }

    #[test]
    fn errored_cells_counted_not_aggregated() {
        let ok = cell(0, "pingan", 0, &[10.0], 0.1);
        let mut bad = cell(1, "pingan", 1, &[], 0.1);
        bad.error = Some("boom".into());
        bad.finished = 0;
        bad.total = 0;
        let rep = SweepReport::from_cells(7, vec![ok, bad]);
        assert_eq!(rep.rows.len(), 1);
        assert_eq!(rep.rows[0].reps_ok, 1);
        assert_eq!(rep.rows[0].errors, 1);
        assert_eq!(rep.rows[0].mean, 10.0);
    }

    #[test]
    fn streamed_groups_aggregate_via_pooled_sketch() {
        let flows_a = [10.0, 20.0, 30.0, f64::NAN];
        let flows_b = [40.0, 50.0, 60.0, 70.0];
        let rep = SweepReport::from_cells(
            7,
            vec![
                streamed_cell(0, "pingan", 0, &flows_a),
                streamed_cell(1, "pingan", 1, &flows_b),
            ],
        );
        assert_eq!(rep.rows.len(), 1);
        let row = &rep.rows[0];
        assert!(row.flows.is_empty(), "streamed rows keep no series");
        // pooled mean over the 7 finished jobs
        let exact_mean = (10.0 + 20.0 + 30.0 + 40.0 + 50.0 + 60.0 + 70.0) / 7.0;
        assert!((row.mean - exact_mean).abs() < 1e-9, "mean={}", row.mean);
        assert!(row.p50 <= row.p95 && row.p95 <= row.p99);
        assert!(row.p50 > 0.0 && row.p99 <= 70.0 * (1.0 + 1.0 / 32.0) + 1.0);
        assert_eq!(row.unfinished, 1);
        assert_eq!(row.reps_ok, 2);
        // rows render/serialize without the raw series
        assert!(rep.to_csv().contains("\npingan,"));
        assert!(rep.to_json_deterministic().to_string().contains("\"mean\":"));
    }

    #[test]
    fn equality_ignores_wall_clock() {
        let a = cell(0, "pingan", 0, &[10.0, f64::NAN], 0.5);
        let b = cell(0, "pingan", 0, &[10.0, f64::NAN], 99.0);
        assert_eq!(a, b);
        let c = cell(0, "pingan", 0, &[11.0, f64::NAN], 0.5);
        assert_ne!(a, c);
    }

    #[test]
    fn equality_splits_the_telemetry_planes() {
        // Plane A (counters) joins equality; Plane B (wall spans) is host
        // noise like wall_secs and must not
        let a = cell(0, "pingan", 0, &[10.0], 0.5);
        let mut b = a.clone();
        b.telemetry.admissions = 7;
        assert_ne!(a, b);
        let mut c = a.clone();
        c.spans = SpansSnapshot {
            rows: vec![Default::default()],
        };
        assert_eq!(a, c);
    }

    #[test]
    fn rows_sum_replica_counters() {
        let mut x = cell(0, "pingan", 0, &[10.0], 0.1);
        x.telemetry.admissions = 3;
        x.telemetry.insurer_rounds = 2;
        let mut y = cell(1, "pingan", 1, &[20.0], 0.1);
        y.telemetry.admissions = 4;
        let rep = SweepReport::from_cells(7, vec![x, y]);
        assert_eq!(rep.rows[0].telemetry.admissions, 7);
        assert_eq!(rep.rows[0].telemetry.insurer_rounds, 2);
    }

    #[test]
    fn csv_and_json_emit_every_row() {
        let rep = SweepReport::from_cells(
            7,
            vec![
                cell(0, "pingan", 0, &[10.0, 20.0], 0.5),
                cell(1, "flutter", 0, &[30.0, 40.0], 0.5),
            ],
        );
        let csv = rep.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("scheduler,"));
        assert!(csv.contains("\npingan,"));
        assert!(csv.contains("\nflutter,"));
        // every Plane-A counter gets a CSV column, all lines same width
        let header_cols = csv.lines().next().unwrap().split(',').count();
        assert_eq!(
            header_cols,
            19 + Counters::default().fields().len(),
            "counter columns appended"
        );
        assert!(csv.lines().all(|l| l.split(',').count() == header_cols));
        assert!(csv.lines().next().unwrap().contains("admissions"));
        let json = rep.to_json().to_string();
        assert!(json.contains("\"rows\":["));
        assert!(json.contains("\"wall_secs\":"));
        assert!(json.contains("\"events_processed\":"));
        assert!(json.contains("\"telemetry\":"));
        assert!(json.contains("\"telemetry_wall\":"));
        // the deterministic variant drops ONLY the wall-clock plane —
        // counters stay, spans and wall_secs go
        let det = rep.to_json_deterministic().to_string();
        assert!(!det.contains("\"wall_secs\":"));
        assert!(!det.contains("\"telemetry_wall\":"));
        assert!(det.contains("\"telemetry\":"));
        assert!(det.contains("\"events_processed\":"));
        assert!(rep.render().contains("pingan"));
    }
}
