//! Quickstart: simulate a small geo-distributed plant under PingAn and
//! print what the insurer did.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pingan::baselines::Flutter;
use pingan::cluster::GeoSystem;
use pingan::config::spec::{SystemSpec, WorkloadSpec};
use pingan::insurance::PingAn;
use pingan::metrics;
use pingan::simulator::{SimConfig, Simulation};
use pingan::util::rng::Rng;
use pingan::workload::montage;

fn main() {
    // 1. a 12-cluster edge plant with Table-2 heterogeneity
    let mut rng = Rng::new(2024);
    let system = GeoSystem::generate(&SystemSpec::small(12), &mut rng);
    println!(
        "plant: {} clusters, {} slots total",
        system.n(),
        system.total_slots()
    );

    // 2. 40 Montage workflows arriving at λ=0.05, inputs scattered
    let mut wspec = WorkloadSpec::scaled(40, 0.05);
    wspec.datasize = (100.0, 800.0);
    let sites: Vec<usize> = (0..system.n()).collect();
    let jobs = montage::generate(&wspec, &sites, &mut rng);
    println!(
        "workload: {} jobs, {} tasks",
        jobs.len(),
        jobs.iter().map(|j| j.n_tasks()).sum::<usize>()
    );

    // 3. run PingAn (ε=0.6) and Flutter on the same workload
    let pingan_res = Simulation::new(&system, jobs.clone(), SimConfig::default())
        .run(&mut PingAn::with_epsilon(0.6));
    let flutter_res =
        Simulation::new(&system, jobs, SimConfig::default()).run(&mut Flutter::new());

    for res in [&flutter_res, &pingan_res] {
        println!(
            "{:<24} avg flowtime {:>8.1} slots | copies {:>5} | failure-killed {:>3}",
            res.scheduler,
            metrics::avg_flowtime(res),
            res.copies_launched,
            res.copies_failed,
        );
    }
    let gain = (metrics::avg_flowtime(&flutter_res) - metrics::avg_flowtime(&pingan_res))
        / metrics::avg_flowtime(&flutter_res);
    println!("pingan reduces average flowtime by {:.1}% vs flutter", 100.0 * gain);
}
