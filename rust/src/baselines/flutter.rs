//! Flutter (Hu, Li, Luo — INFOCOM'16): schedule each ready task on the
//! cluster minimizing its estimated completion time, stage by stage —
//! WAN-aware but heterogeneity-oblivious beyond mean rates, no copies.
//!
//! Flutter is the *reference* scheduler: Fig 5's reduction ratios are
//! computed against its flowtimes.

use crate::sched::{Action, Assignment, SchedView, Scheduler};

pub struct Flutter;

impl Flutter {
    pub fn new() -> Flutter {
        Flutter
    }

    /// Minimum estimated-finish-time placement for one task. Estimated
    /// finish = datasize / E[r(1)] on each cluster with a free slot.
    pub(crate) fn place(
        view: &mut SchedView<'_>,
        ji: usize,
        ti: usize,
        out: &mut Vec<Action>,
    ) -> bool {
        let sources = view.jobs[ji].tasks[ti].sources.clone();
        let spec = &view.jobs[ji].spec.tasks[ti];
        let (op, datasize) = (spec.op, spec.datasize);
        let mut best: Option<(f64, usize, f64)> = None; // (finish, cluster, rate)
        for m in 0..view.system.n() {
            if view.free_slots[m] == 0 {
                continue;
            }
            let r = view.model.exp_rate1(&sources, m, op).max(1e-9);
            let finish = datasize / r;
            if best.map(|(b, _, _)| finish < b).unwrap_or(true) {
                best = Some((finish, m, r));
            }
        }
        if let Some((_, m, r)) = best {
            if view.try_reserve_slot(m) {
                if view.try_reserve_bandwidth(&sources, m, r) {
                    out.push(Action::Launch(Assignment {
                        job: ji,
                        task: ti,
                        cluster: m,
                    }));
                    return true;
                }
                view.free_slots[m] += 1;
            }
        }
        false
    }
}

impl Default for Flutter {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Flutter {
    fn name(&self) -> &str {
        "flutter"
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        let mut out = Vec::new();
        // FIFO across jobs (Flutter optimizes stages, not job ordering)
        let mut order: Vec<usize> = view.alive.to_vec();
        order.sort_by_key(|&ji| view.jobs[ji].spec.arrival);
        for ji in order {
            for ti in view.ready_tasks(ji) {
                Flutter::place(view, ji, ti, &mut out);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::simulator::{SimConfig, Simulation};
    use crate::util::rng::Rng;
    use crate::workload::montage;

    #[test]
    fn flutter_completes_workload() {
        let mut rng = Rng::new(81);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(8, 0.05);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut Flutter::new());
        assert_eq!(res.finished_jobs, res.total_jobs);
    }
}
