"""Build-time compile path: L2 JAX graphs + L1 Pallas kernels + AOT lowering.

Never imported at request time — the rust binary only reads the HLO text
artifacts this package emits.
"""
