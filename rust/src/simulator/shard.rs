//! Cluster-sharded engine state: each [`EngineShard`] owns a contiguous
//! cluster range — its failure gaps, slot/ingress/egress ledgers and
//! per-cluster AR(1) congestion chains — and advances independently between
//! policy epochs. [`EngineShards`] is the set, plus the deterministic
//! barrier (`std::thread::scope` + shard-order merge) the engine syncs at
//! before every scheduler invocation.
//!
//! ## Determinism contract
//!
//! Action streams must be **bit-identical at any shard count**. Two
//! mechanisms carry that proof:
//!
//! 1. **One RNG stream per cluster.** Every stochastic draw a shard makes —
//!    the dense Bernoulli failure flip, the event-skip geometric gap, the
//!    AR(1) congestion gauss — comes from [`cluster_rng`]`(seed, m)`, a pure
//!    function of the run seed and the *global* cluster index. Grouping
//!    clusters into 1 or 16 shards cannot reorder draws within a stream,
//!    and streams never interact, so every cluster's trajectory is
//!    independent of the partition. (Launch-time draws — copy power, WAN
//!    bandwidth — stay on the engine's global stream: they happen in the
//!    serial policy-application phase, which no shard ever touches.)
//! 2. **Contiguous shard-order merge.** Shard boundaries come from
//!    [`crate::util::shard::shard_ranges`] (a pure function of `(n,
//!    threads)`), and every cross-shard read — failed-cluster lists,
//!    modeler observations, `SchedView` snapshots — concatenates shards in
//!    index order, which *is* global cluster order. No result ever depends
//!    on thread completion order.
//!
//! Whether shards advance on spawned scoped threads or inline on the
//! caller's thread is therefore a pure wall-time heuristic
//! ([`MIN_CLUSTERS_PER_SHARD`]); outputs are identical either way.
//!
//! **Barrier-only re-rate.** Under the shared bandwidth model
//! ([`crate::config::spec::BandwidthModel::Shared`]) a WAN link couples
//! transfers homed in *different* shards, so shards never touch copy
//! rates during an advance: the advance is exactly the constant-model
//! one, and the engine applies the global fair-share solve in the serial
//! phase at the policy-epoch barrier — after the merge, before the dirty
//! epoch bump. The ledgers below keep holding launch-time *reservations*
//! (admission control) in both models; the solver owns actual contention.

use crate::cluster::GeoSystem;
use crate::obs::{SpanKind, Spans};
use crate::simulator::processes::{self, FailureGaps};
use crate::simulator::state::CopyRt;
use crate::util::rng::{Rng, SplitMix64};
use crate::util::shard::shard_ranges;
use std::ops::Range;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Independent RNG stream of global cluster `m`: a pure function of
/// `(seed, m)`, mirroring `Rng::fork`'s stream mixing without mutating any
/// parent generator (a fork counter would make streams depend on fork
/// *order*, i.e. on the shard partition — exactly what must not happen).
pub fn cluster_rng(seed: u64, m: usize) -> Rng {
    let base = SplitMix64::new(seed).next_u64();
    Rng::new(base ^ ((m as u64) + 1).wrapping_mul(0xA24B_AED4_963E_E407))
}

/// Smallest per-shard cluster count worth an OS thread: a scoped
/// spawn/join costs tens of microseconds, comparable to advancing a few
/// hundred AR(1) chains. Purely a wall-time heuristic — the shard *state*
/// split is identical either way, so outputs never depend on it.
pub const MIN_CLUSTERS_PER_SHARD: usize = 64;

/// One shard: plant state of the contiguous cluster range it owns. All
/// vectors are local-indexed (`i = m - range.start`).
pub struct EngineShard {
    pub range: Range<usize>,
    /// Per-cluster draw streams (see [`cluster_rng`]).
    rngs: Vec<Rng>,
    /// AR(1) congestion factor per cluster (mean ~1).
    load: Vec<f64>,
    /// σ of the congestion target, precomputed from cluster scale.
    sigmas: Vec<f64>,
    /// Next-failure slots (event core) / Bernoulli p (both cores).
    fails: FailureGaps,
    /// Slots `[0, obs_upto)` already absorbed into the failure heartbeat
    /// (event core's lazy walk).
    obs_upto: Vec<u64>,
    /// Total slots per cluster (capacity, for occupancy checks).
    cap_slots: Vec<usize>,
    free_slots: Vec<usize>,
    ingress_used: Vec<f64>,
    egress_used: Vec<f64>,
    /// Scratch: global indices of clusters that failed this advance.
    failed: Vec<usize>,
    /// Scratch: `(global m, span, fired)` heartbeat observations of this
    /// advance, for the engine to hand the modeler in shard-merge order.
    observed: Vec<(usize, u64, u64)>,
}

impl EngineShard {
    fn new(system: &GeoSystem, seed: u64, range: Range<usize>) -> EngineShard {
        let mut rngs: Vec<Rng> = range.clone().map(|m| cluster_rng(seed, m)).collect();
        let fails = FailureGaps::for_range(system, range.clone(), &mut rngs);
        let clusters = &system.clusters[range.clone()];
        EngineShard {
            rngs,
            load: vec![1.0; range.len()],
            sigmas: clusters.iter().map(|c| processes::sigma_for(c.scale)).collect(),
            fails,
            obs_upto: vec![0u64; range.len()],
            cap_slots: clusters.iter().map(|c| c.slots).collect(),
            free_slots: clusters.iter().map(|c| c.slots).collect(),
            ingress_used: vec![0.0; range.len()],
            egress_used: vec![0.0; range.len()],
            failed: Vec::new(),
            observed: Vec::new(),
            range,
        }
    }

    /// One dense slot: per cluster, advance the AR(1) chain one step, then
    /// flip the failure Bernoulli — both from that cluster's own stream.
    /// Failed clusters land in `self.failed` (global indices, ascending).
    fn advance_dense(&mut self) {
        self.failed.clear();
        for i in 0..self.load.len() {
            processes::ar1_step(&mut self.load[i], self.sigmas[i], 1, &mut self.rngs[i]);
            if self.rngs[i].chance(self.fails.p(i)) {
                self.failed.push(self.range.start + i);
            }
        }
    }

    /// Event-skip advance to slot `t`: pause the failure process over idle
    /// windows, step the AR(1) chains over `k` skipped slots in closed
    /// form, and batch-fire gap failures on empty clusters (occupied ones
    /// keep their pending failure for its exact-slot event). Heartbeat
    /// observations accumulate in `self.observed` in cluster order.
    fn advance_events(&mut self, t: u64, idle: bool, k: u64) {
        self.observed.clear();
        for i in 0..self.load.len() {
            if idle {
                let skipped = t.saturating_sub(self.obs_upto[i]);
                self.fails.shift(i, skipped);
                self.obs_upto[i] = self.obs_upto[i].max(t);
            }
            if k > 0 {
                processes::ar1_step(&mut self.load[i], self.sigmas[i], k, &mut self.rngs[i]);
            }
            let span = (t + 1).saturating_sub(self.obs_upto[i]);
            if span == 0 {
                continue;
            }
            let mut fired = 0u64;
            if self.free_slots[i] == self.cap_slots[i] {
                while self.fails.next(i) <= t {
                    fired += 1;
                    self.fails.fire(i, &mut self.rngs[i]);
                }
            }
            self.observed.push((self.range.start + i, span, fired));
            self.obs_upto[i] = t + 1;
        }
    }
}

/// The shard set plus its deterministic barrier. Global-index accessors
/// route through the owner table; the advance entry points fan out over
/// `std::thread::scope` (or run inline — see [`MIN_CLUSTERS_PER_SHARD`])
/// and merge results in shard order.
pub struct EngineShards {
    shards: Vec<EngineShard>,
    /// Global cluster index → owning shard index.
    owner: Vec<usize>,
    threads: usize,
    /// Spawn heuristic, fixed at construction: threads > 1 and shards big
    /// enough to amortize a scoped spawn.
    spawn: bool,
    /// Plane-B telemetry: per-shard advance time + barrier wait land here
    /// when the engine attaches its span sheet (`SimConfig::telemetry`).
    /// `None` means no clock is ever read on the advance path. Recording
    /// is atomic (`&Spans` suffices), so shard threads need no `&mut`.
    spans: Option<Arc<Spans>>,
}

impl EngineShards {
    pub fn new(system: &GeoSystem, seed: u64, threads: usize) -> EngineShards {
        let n = system.n();
        let ranges = shard_ranges(n, threads.max(1));
        let mut owner = vec![0usize; n];
        for (si, r) in ranges.iter().enumerate() {
            for m in r.clone() {
                owner[m] = si;
            }
        }
        let shards: Vec<EngineShard> = ranges
            .into_iter()
            .map(|r| EngineShard::new(system, seed, r))
            .collect();
        let spawn = threads > 1
            && shards.len() > 1
            && shards.iter().all(|s| s.range.len() >= MIN_CLUSTERS_PER_SHARD);
        EngineShards {
            shards,
            owner,
            threads: threads.max(1),
            spawn,
            spans: None,
        }
    }

    /// Attach the engine's span sheet (enables wall-clock timing of the
    /// advance barriers — Plane B only, never any behavioral effect).
    pub fn set_spans(&mut self, spans: Arc<Spans>) {
        self.spans = Some(spans);
    }

    pub fn n(&self) -> usize {
        self.owner.len()
    }

    /// Configured engine thread budget (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Number of shards the cluster space is partitioned into.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Whether the advance barrier actually spawns OS threads (wall-time
    /// heuristic only; results are identical either way).
    pub fn spawns(&self) -> bool {
        self.spawn
    }

    /// Owner table for routing cluster-local events to per-shard queues.
    pub fn owner_table(&self) -> &[usize] {
        &self.owner
    }

    #[inline]
    fn local(&self, m: usize) -> (usize, usize) {
        let si = self.owner[m];
        (si, m - self.shards[si].range.start)
    }

    pub fn free(&self, m: usize) -> usize {
        let (si, i) = self.local(m);
        self.shards[si].free_slots[i]
    }

    /// Whether any copy currently occupies a slot of cluster `m`.
    pub fn is_occupied(&self, m: usize) -> bool {
        let (si, i) = self.local(m);
        self.shards[si].free_slots[i] < self.shards[si].cap_slots[i]
    }

    pub fn load(&self, m: usize) -> f64 {
        let (si, i) = self.local(m);
        self.shards[si].load[i]
    }

    pub fn ingress_used(&self, m: usize) -> f64 {
        let (si, i) = self.local(m);
        self.shards[si].ingress_used[i]
    }

    pub fn egress_used(&self, m: usize) -> f64 {
        let (si, i) = self.local(m);
        self.shards[si].egress_used[i]
    }

    /// Absolute slot of cluster `m`'s next pending failure (event core).
    pub fn fail_next(&self, m: usize) -> u64 {
        let (si, i) = self.local(m);
        self.shards[si].fails.next(i)
    }

    /// Fire cluster `m`'s pending failure and sample the next gap — from
    /// `m`'s own stream, so event-drain order (which is global and serial)
    /// never perturbs other clusters.
    pub fn fire_failure(&mut self, m: usize) {
        let (si, i) = self.local(m);
        let s = &mut self.shards[si];
        s.fails.fire(i, &mut s.rngs[i]);
    }

    /// Debit one slot plus gate bandwidth for a launching copy — the
    /// single resource-acquisition path (the mirror of [`Self::release_copy`]).
    /// Egress debits may land on other shards; launches happen in the
    /// serial policy-application phase, so `&mut self` is exclusive here.
    pub fn occupy(&mut self, cluster: usize, ingress_bw: f64, egress_bw: &[(usize, f64)]) {
        let (si, i) = self.local(cluster);
        self.shards[si].free_slots[i] -= 1;
        self.shards[si].ingress_used[i] += ingress_bw;
        for &(s, bw) in egress_bw {
            let (sj, j) = self.local(s);
            self.shards[sj].egress_used[j] += bw;
        }
    }

    /// Release one copy's slot and gate bandwidth back to the ledgers and
    /// mark it dead. The single teardown path — failures, policy kills and
    /// completions all go through here.
    pub fn release_copy(&mut self, c: &mut CopyRt) {
        c.alive = false;
        let (si, i) = self.local(c.cluster);
        self.shards[si].free_slots[i] += 1;
        self.shards[si].ingress_used[i] -= c.ingress_bw;
        for &(s, bw) in &c.egress_bw {
            let (sj, j) = self.local(s);
            self.shards[sj].egress_used[j] -= bw;
        }
    }

    /// Shared fan-out for both barriers: run `f` over every shard (scoped
    /// threads or inline), timing each shard's advance and — in spawn mode
    /// — the barrier's wait (whole-barrier time minus the slowest shard)
    /// when a span sheet is attached. Timing observes; it never orders.
    fn advance_all<F>(&mut self, f: F)
    where
        F: Fn(&mut EngineShard) + Send + Sync,
    {
        let spans = self.spans.clone();
        if self.spawn {
            let t0 = spans.as_ref().map(|_| Instant::now());
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .shards
                    .iter_mut()
                    .map(|shard| {
                        let f = &f;
                        let sp = spans.clone();
                        scope.spawn(move || {
                            let s0 = sp.as_ref().map(|_| Instant::now());
                            f(shard);
                            s0.map(|s0| s0.elapsed())
                        })
                    })
                    .collect();
                let mut slowest = Duration::ZERO;
                for h in handles {
                    if let Some(d) = h.join().expect("shard thread panicked") {
                        if let Some(sp) = &spans {
                            sp.record(SpanKind::ShardAdvance, d);
                        }
                        slowest = slowest.max(d);
                    }
                }
                if let (Some(sp), Some(t0)) = (&spans, t0) {
                    sp.record(SpanKind::BarrierWait, t0.elapsed().saturating_sub(slowest));
                }
            });
        } else {
            for shard in &mut self.shards {
                let s0 = spans.as_ref().map(|_| Instant::now());
                f(shard);
                if let (Some(sp), Some(s0)) = (&spans, s0) {
                    sp.record(SpanKind::ShardAdvance, s0.elapsed());
                }
            }
        }
    }

    /// Dense barrier: advance every shard one slot (AR(1) + failure flips)
    /// and merge the failed clusters in shard order — i.e. ascending global
    /// cluster order, exactly what the serial loop produced.
    pub fn advance_dense_slot(&mut self) -> Vec<usize> {
        self.advance_all(|shard| shard.advance_dense());
        let total: usize = self.shards.iter().map(|s| s.failed.len()).sum();
        let mut failed = Vec::with_capacity(total);
        for shard in &self.shards {
            failed.extend_from_slice(&shard.failed);
        }
        failed
    }

    /// Event-skip barrier: advance every shard to slot `t` (idle shifts,
    /// k-step AR(1), lazy gap walks). Read the merged heartbeat
    /// observations afterwards via [`Self::observations`].
    pub fn advance_events_to(&mut self, t: u64, idle: bool, k: u64) {
        self.advance_all(|shard| shard.advance_events(t, idle, k));
    }

    /// `(cluster, span, fired)` heartbeat observations of the last
    /// [`Self::advance_events_to`], in ascending cluster order.
    pub fn observations(&self) -> impl Iterator<Item = (usize, u64, u64)> + '_ {
        self.shards.iter().flat_map(|s| s.observed.iter().copied())
    }

    /// Snapshot of per-cluster free slots (for `SchedView`).
    pub fn snapshot_free_slots(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.n());
        for s in &self.shards {
            out.extend_from_slice(&s.free_slots);
        }
        out
    }

    /// Remaining ingress gate headroom per cluster.
    pub fn snapshot_ingress_free(&self, system: &GeoSystem) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n());
        for s in &self.shards {
            out.extend(
                s.ingress_used
                    .iter()
                    .zip(&system.clusters[s.range.clone()])
                    .map(|(used, c)| (c.ingress - used).max(0.0)),
            );
        }
        out
    }

    /// Remaining egress gate headroom per cluster.
    pub fn snapshot_egress_free(&self, system: &GeoSystem) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.n());
        for s in &self.shards {
            out.extend(
                s.egress_used
                    .iter()
                    .zip(&system.clusters[s.range.clone()])
                    .map(|(used, c)| (c.egress - used).max(0.0)),
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::SystemSpec;

    fn system(n: usize) -> GeoSystem {
        let mut rng = Rng::new(61);
        GeoSystem::generate(&SystemSpec::small(n), &mut rng)
    }

    #[test]
    fn cluster_rng_is_pure_and_distinct() {
        let mut a = cluster_rng(7, 3);
        let mut b = cluster_rng(7, 3);
        let mut c = cluster_rng(7, 4);
        let mut d = cluster_rng(8, 3);
        let (xa, xb, xc, xd) = (a.next_u64(), b.next_u64(), c.next_u64(), d.next_u64());
        assert_eq!(xa, xb, "same (seed, m) must give the same stream");
        assert_ne!(xa, xc, "streams differ across clusters");
        assert_ne!(xa, xd, "streams differ across seeds");
    }

    #[test]
    fn dense_advance_is_bit_identical_at_any_shard_count() {
        let sys = system(7);
        let mut one = EngineShards::new(&sys, 42, 1);
        let mut four = EngineShards::new(&sys, 42, 4);
        for slot in 0..200 {
            let f1 = one.advance_dense_slot();
            let f4 = four.advance_dense_slot();
            assert_eq!(f1, f4, "slot {slot}: failed sets diverge");
            for m in 0..sys.n() {
                assert_eq!(
                    one.load(m).to_bits(),
                    four.load(m).to_bits(),
                    "slot {slot} cluster {m}: load diverges"
                );
            }
        }
    }

    #[test]
    fn event_advance_is_bit_identical_at_any_shard_count() {
        let sys = system(7);
        let mut one = EngineShards::new(&sys, 43, 1);
        let mut three = EngineShards::new(&sys, 43, 3);
        // jump through an irregular slot sequence with idle stretches
        let mut load_upto = 0u64;
        for &(t, idle) in &[(0u64, false), (3, true), (4, false), (40, true), (41, false)] {
            let k = (t + 1).saturating_sub(load_upto);
            one.advance_events_to(t, idle, k);
            three.advance_events_to(t, idle, k);
            load_upto = t + 1;
            let o1: Vec<_> = one.observations().collect();
            let o3: Vec<_> = three.observations().collect();
            assert_eq!(o1, o3, "t={t}: observations diverge");
            for m in 0..sys.n() {
                assert_eq!(one.fail_next(m), three.fail_next(m), "t={t} cluster {m}");
                assert_eq!(
                    one.load(m).to_bits(),
                    three.load(m).to_bits(),
                    "t={t} cluster {m}: load diverges"
                );
            }
        }
    }

    #[test]
    fn occupy_and_release_round_trip() {
        let sys = system(6);
        let mut shards = EngineShards::new(&sys, 44, 2);
        let free0 = shards.snapshot_free_slots();
        let egress = vec![(0usize, 1.5f64), (5, 0.5)];
        shards.occupy(3, 2.0, &egress);
        assert_eq!(shards.free(3), free0[3] - 1);
        assert!(shards.is_occupied(3));
        assert_eq!(shards.ingress_used(3), 2.0);
        assert_eq!(shards.egress_used(0), 1.5);
        assert_eq!(shards.egress_used(5), 0.5);
        let mut copy = CopyRt {
            cluster: 3,
            rate: 1.0,
            proc_speed: 1.0,
            trans_speed: 1.0,
            processed: 0.0,
            launched_at: 0,
            progress_base: 0.0,
            rate_since: 0,
            bw_id: None,
            alive: true,
            ingress_bw: 2.0,
            egress_bw: egress,
        };
        shards.release_copy(&mut copy);
        assert!(!copy.alive);
        assert_eq!(shards.snapshot_free_slots(), free0);
        assert_eq!(shards.ingress_used(3), 0.0);
        assert_eq!(shards.egress_used(0), 0.0);
        assert_eq!(shards.egress_used(5), 0.0);
    }

    #[test]
    fn owner_table_matches_ranges() {
        let sys = system(9);
        let shards = EngineShards::new(&sys, 45, 4);
        for m in 0..sys.n() {
            let si = shards.owner_table()[m];
            assert!(shards.shards[si].range.contains(&m));
        }
        assert!(!shards.spawns(), "9 clusters are below the spawn threshold");
        assert_eq!(shards.threads(), 4);
    }
}
