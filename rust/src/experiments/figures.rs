//! Figure regenerators: each prints the same rows/series the paper reports
//! and returns the raw numbers for benches/tests.
//!
//! Every simulation figure is a declarative [`SweepSpec`] over the
//! parallel sweep runner; only the testbed figures (Fig 2/3) run the
//! Spark-on-Yarn path directly.

use super::{base_scenario, Scale, SIM_BASELINES};
use crate::baselines::{Spark, SpeculativeSpark};
use crate::config::spec::{Allocation, Principle};
use crate::insurance::PingAn;
use crate::metrics::cdf::{reduction_ratios, Cdf};
use crate::sparkyarn::{Testbed, TestbedConfig, TestbedResult};
use crate::sweep::{self, Axis, ScenarioRow, SweepSpec};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::{fnum, fpct, Table};
use crate::workload::testbed::{generate, TestbedSpec};

/// (λ, ε) pairs for light/medium/heavy load. λ follows Sec 6.2; ε is tuned
/// by *our* Fig-7 sweep at reproduction scale (the paper does the same via
/// its Sec-6.4 hint — their 0.8/0.6/0.2 values are specific to their
/// concurrency level N(t); at reduced scale ⌈εN⌉ degenerates for small ε,
/// and the measured optimum is 0.6/0.6/0.8 — see EXPERIMENTS.md).
pub const LOADS: [(&str, f64, f64); 3] = [
    ("light", 0.02, 0.6),
    ("medium", 0.07, 0.6),
    ("heavy", 0.15, 0.8),
];

// ---------------------------------------------------------------- fig 2/3

/// Fig 2 + Fig 3 share one testbed run set.
pub struct TestbedRuns {
    pub results: Vec<TestbedResult>,
}

/// Run the Sec-5 testbed comparison: PingAn (ε=0.6) vs Spark vs
/// speculative Spark on the Table-1 workload over 10 clusters.
pub fn run_testbed(n_jobs: usize, payload_every: usize) -> anyhow::Result<TestbedRuns> {
    let sys = crate::sparkyarn::testbed::testbed_system(42);
    let mut spec = TestbedSpec::default();
    spec.n_jobs = n_jobs;
    let sites: Vec<usize> = (0..sys.n()).collect();
    let mut rng = Rng::new(spec.seed);
    let jobs = generate(&spec, &sites, &mut rng);
    let mut cfg = TestbedConfig::default();
    cfg.payload_every = payload_every;
    let tb = Testbed::new(cfg)?;
    let mut results = Vec::new();
    let mut pingan = PingAn::with_epsilon(0.6);
    results.push(tb.run(&sys, jobs.clone(), &mut pingan));
    results.push(tb.run(&sys, jobs.clone(), &mut Spark::new()));
    results.push(tb.run(&sys, jobs, &mut SpeculativeSpark::new()));
    Ok(TestbedRuns { results })
}

/// Fig 2: average testbed flowtime per scheduler.
pub fn fig2(runs: &TestbedRuns) -> String {
    let mut t = Table::new(
        "Fig 2 — testbed average job flowtime (slots)",
        &["scheduler", "avg flowtime", "vs spark-spec", "payload execs", "payload errors"],
    );
    let spec_avg = avg(&runs.results[2].flowtimes);
    for r in &runs.results {
        let a = avg(&r.flowtimes);
        t.row(&[
            r.scheduler.clone(),
            fnum(a, 1),
            fpct((spec_avg - a) / spec_avg),
            r.payload_execs.to_string(),
            r.payload_errors.to_string(),
        ]);
    }
    t.render()
}

/// Fig 3: conditional flowtime CDFs (a: <500 s band, b: >300 s band),
/// sampled at fixed fractions of the observed range.
pub fn fig3(runs: &TestbedRuns) -> String {
    let mut out = String::new();
    let hi: f64 = runs
        .results
        .iter()
        .flat_map(|r| r.flowtimes.iter())
        .filter(|f| f.is_finite())
        .fold(0.0, |a: f64, &b| a.max(b));
    let windows = [("3a: short jobs", 0.0, 0.5 * hi), ("3b: long jobs", 0.3 * hi, hi)];
    for (label, lo, hi) in windows {
        let mut t = Table::new(
            &format!("Fig {label} — flowtime CDF on [{:.0},{:.0}]", lo, hi),
            &["scheduler", "p25", "p50", "p75", "p90", "n"],
        );
        for r in &runs.results {
            let c = Cdf::new(&r.flowtimes).restricted(lo, hi);
            t.row(&[
                r.scheduler.clone(),
                fnum(c.quantile(0.25), 1),
                fnum(c.quantile(0.5), 1),
                fnum(c.quantile(0.75), 1),
                fnum(c.quantile(0.9), 1),
                c.len().to_string(),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

// ------------------------------------------------------------------ fig 4

/// Fig 4 data: per (load, scheduler) average flowtime.
pub struct Fig4 {
    /// (load label, scheduler, avg flowtime)
    pub rows: Vec<(String, String, f64)>,
}

/// The paper's load points as a paired (λ, ε) sweep axis.
fn load_axis() -> Axis {
    Axis::Load(LOADS.iter().map(|&(_, l, e)| (l, e)).collect())
}

fn load_label(lambda: f64) -> String {
    LOADS
        .iter()
        .find(|&&(_, l, _)| l == lambda)
        .map(|&(name, _, _)| name.to_string())
        .unwrap_or_else(|| format!("λ={lambda}"))
}

pub fn run_fig4(scale: &Scale) -> Fig4 {
    let schedulers: Vec<String> = SIM_BASELINES
        .iter()
        .chain(&["pingan"])
        .map(|s| s.to_string())
        .collect();
    let spec = SweepSpec::new(base_scenario(scale))
        .axis(load_axis())
        .axis(Axis::Scheduler(schedulers))
        .reps(scale.reps);
    let report = sweep::run(&spec);
    let rows = report
        .rows
        .iter()
        .map(|r| {
            (
                load_label(r.scenario.lambda),
                r.scenario.scheduler.clone(),
                r.mean,
            )
        })
        .collect();
    Fig4 { rows }
}

pub fn fig4_table(f: &Fig4) -> String {
    let mut t = Table::new(
        "Fig 4 — avg job flowtime by load (slots)",
        &["load", "scheduler", "avg flowtime", "pingan vs best baseline"],
    );
    for (label, _, _) in LOADS {
        let in_load: Vec<&(String, String, f64)> =
            f.rows.iter().filter(|r| r.0 == label).collect();
        let pingan = in_load.iter().find(|r| r.1 == "pingan").map(|r| r.2);
        let best_base = in_load
            .iter()
            .filter(|r| r.1 != "pingan")
            .map(|r| r.2)
            .fold(f64::INFINITY, f64::min);
        for r in &in_load {
            let delta = if r.1 == "pingan" {
                fpct((best_base - pingan.unwrap()) / best_base)
            } else {
                String::new()
            };
            t.row(&[r.0.clone(), r.1.clone(), fnum(r.2, 1), delta]);
        }
    }
    t.render()
}

// ------------------------------------------------------------------ fig 5

/// Fig 5: flowtime CDFs and reduction-ratio-vs-Flutter CDFs per load.
///
/// One sweep covers every (load, scheduler) pair; per-job reduction
/// ratios are valid because policy variants share the environment seed
/// (see `sweep::spec` module docs).
pub fn fig5(scale: &Scale) -> String {
    let schedulers = ["flutter", "pingan", "flutter+mantri", "flutter+dolly"];
    let spec = SweepSpec::new(base_scenario(scale))
        .axis(load_axis())
        .axis(Axis::Scheduler(
            schedulers.iter().map(|s| s.to_string()).collect(),
        ))
        .reps(scale.reps);
    let report = sweep::run(&spec);
    let row_of = |lambda: f64, name: &str| -> &ScenarioRow {
        report
            .rows
            .iter()
            .find(|r| r.scenario.lambda == lambda && r.scenario.scheduler == name)
            .expect("sweep covers every (load, scheduler) pair")
    };
    let mut out = String::new();
    for (label, lambda, _eps) in LOADS {
        let flutter: &[f64] = &row_of(lambda, "flutter").flows;
        let series: Vec<(&str, &[f64])> = schedulers[1..]
            .iter()
            .map(|&n| (n, row_of(lambda, n).flows.as_slice()))
            .collect();
        let mut t = Table::new(
            &format!("Fig 5 ({label}, λ={lambda}) — flowtime quantiles (slots)"),
            &["scheduler", "p25", "p50", "p75", "p90"],
        );
        let q = |v: &[f64], q: f64| fnum(Cdf::new(v).quantile(q), 1);
        t.row(&[
            "flutter".into(),
            q(flutter, 0.25),
            q(flutter, 0.5),
            q(flutter, 0.75),
            q(flutter, 0.9),
        ]);
        for &(name, flows) in &series {
            t.row(&[
                name.to_string(),
                q(flows, 0.25),
                q(flows, 0.5),
                q(flows, 0.75),
                q(flows, 0.9),
            ]);
        }
        out.push_str(&t.render());
        let mut t2 = Table::new(
            &format!("Fig 5 ({label}) — flowtime reduction vs flutter"),
            &["scheduler", "p30 reduction", "median reduction", "% jobs slower"],
        );
        for &(name, flows) in &series {
            let rr = reduction_ratios(flutter, flows);
            let slower = rr.iter().filter(|&&x| x < 0.0).count() as f64
                / rr.len().max(1) as f64;
            t2.row(&[
                name.to_string(),
                fpct(stats::quantile(&rr, 0.30)),
                fpct(stats::quantile(&rr, 0.5)),
                fpct(slower),
            ]);
        }
        out.push_str(&t2.render());
        out.push('\n');
    }
    out
}

// ------------------------------------------------------------------ fig 6

/// The shared Fig-6 base: PingAn at λ=0.07, ε=0.6.
fn fig6_base(scale: &Scale) -> crate::sweep::Scenario {
    let mut base = base_scenario(scale);
    base.lambda = 0.07;
    base.epsilon = 0.6;
    base
}

/// Fig 6a data: avg flowtime per insuring principle at λ=0.07, ε=0.6.
pub fn run_fig6a(scale: &Scale) -> Vec<(String, f64)> {
    let spec = SweepSpec::new(fig6_base(scale))
        .axis(Axis::Principle(vec![
            Principle::EffReli,
            Principle::ReliEff,
            Principle::EffEff,
            Principle::ReliReli,
        ]))
        .reps(scale.reps);
    sweep::run(&spec)
        .rows
        .iter()
        .map(|r| (r.scenario.principle.name().to_string(), r.mean))
        .collect()
}

/// Fig 6b data: EFA vs JGA.
pub fn run_fig6b(scale: &Scale) -> Vec<(String, f64)> {
    let spec = SweepSpec::new(fig6_base(scale))
        .axis(Axis::Allocation(vec![Allocation::Efa, Allocation::Jga]))
        .reps(scale.reps);
    sweep::run(&spec)
        .rows
        .iter()
        .map(|r| (r.scenario.allocation.name().to_string(), r.mean))
        .collect()
}

/// Both Fig-6 ablation columns — the CLI's `fig6a`/`fig6b` arms print the
/// combined table from this one helper.
pub fn run_fig6(scale: &Scale) -> (Vec<(String, f64)>, Vec<(String, f64)>) {
    (run_fig6a(scale), run_fig6b(scale))
}

pub fn fig6_table(a_rows: &[(String, f64)], b_rows: &[(String, f64)]) -> String {
    let mut out = String::new();
    let mut t = Table::new(
        "Fig 6a — insuring-principle ablation (λ=0.07, ε=0.6)",
        &["principle", "avg flowtime", "vs Eff-Reli"],
    );
    let base = a_rows[0].1;
    for (name, v) in a_rows {
        t.row(&[name.clone(), fnum(*v, 1), fpct((v - base) / v.max(1e-9))]);
    }
    out.push_str(&t.render());
    let mut t2 = Table::new(
        "Fig 6b — allocation ablation",
        &["allocation", "avg flowtime", "vs EFA"],
    );
    let base = b_rows[0].1;
    for (name, v) in b_rows {
        t2.row(&[name.clone(), fnum(*v, 1), fpct((v - base) / v.max(1e-9))]);
    }
    out.push_str(&t2.render());
    out
}

// ------------------------------------------------------------------ fig 7

/// Fig 7: ε×λ sweep of average flowtime (λ outermost, as plotted).
pub fn run_fig7(scale: &Scale, lambdas: &[f64], epsilons: &[f64]) -> Vec<(f64, f64, f64)> {
    sweep::run(&fig7_spec(scale, lambdas, epsilons))
        .rows
        .iter()
        .map(|r| (r.scenario.lambda, r.scenario.epsilon, r.mean))
        .collect()
}

/// The Fig-7 grid as a sweep spec (shared with `benches/bench_sweep.rs`).
pub fn fig7_spec(scale: &Scale, lambdas: &[f64], epsilons: &[f64]) -> SweepSpec {
    SweepSpec::new(base_scenario(scale))
        .axis(Axis::Lambda(lambdas.to_vec()))
        .axis(Axis::Epsilon(epsilons.to_vec()))
        .reps(scale.reps)
}

pub fn fig7_table(rows: &[(f64, f64, f64)]) -> String {
    let mut t = Table::new(
        "Fig 7 — ε vs λ (avg job flowtime, slots; * = best ε per λ)",
        &["lambda", "epsilon", "avg flowtime", "best"],
    );
    let lambdas: Vec<f64> = {
        let mut ls: Vec<f64> = rows.iter().map(|r| r.0).collect();
        ls.dedup();
        ls
    };
    for &l in &lambdas {
        let best = rows
            .iter()
            .filter(|r| r.0 == l)
            .map(|r| r.2)
            .fold(f64::INFINITY, f64::min);
        for r in rows.iter().filter(|r| r.0 == l) {
            t.row(&[
                fnum(r.0, 2),
                fnum(r.1, 1),
                fnum(r.2, 1),
                if r.2 == best { "*".into() } else { String::new() },
            ]);
        }
    }
    t.render()
}

fn avg(xs: &[f64]) -> f64 {
    let v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    stats::mean(&v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_smoke() {
        let scale = Scale::smoke();
        let (a, b) = run_fig6(&scale);
        assert_eq!(a.len(), 4);
        assert_eq!(a[0].0, "Eff-Reli");
        assert_eq!(b.len(), 2);
        assert_eq!(b[0].0, "EFA");
        // Fig 6a's Eff-Reli/EFA cell and Fig 6b's EFA cell are the same
        // scenario — the sweep's seeding makes them bit-identical.
        assert_eq!(a[0].1.to_bits(), b[0].1.to_bits());
        let rendered = fig6_table(&a, &b);
        assert!(rendered.contains("Eff-Reli"));
        assert!(rendered.contains("JGA"));
    }

    #[test]
    fn fig7_smoke() {
        let scale = Scale::smoke();
        let rows = run_fig7(&scale, &[0.05], &[0.4, 0.8]);
        assert_eq!(rows.len(), 2);
        let t = fig7_table(&rows);
        assert!(t.contains('*'));
    }
}
