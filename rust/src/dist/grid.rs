//! The shared discretization of the rate axis.

/// A uniform grid of `bins` representative values spanning `[lo, hi]`
/// inclusive: `value(j) = lo + j * step` with `step = (hi - lo) / (bins - 1)`.
///
/// All histograms built on the same grid are algebra-compatible; mixing
/// grids is a programming error and panics in the [`Hist`](super::Hist)
/// operations. The inclusive-endpoint convention matches the batched
/// scorer's `values` tensor (`runtime::scorer`), so a `Hist` pmf can be
/// copied into a `ScoreBatch` row without resampling.
#[derive(Clone, Debug)]
pub struct Grid {
    lo: f64,
    hi: f64,
    step: f64,
    /// Shared so cloning a `Grid` (which every `Hist` holds) is a pointer
    /// bump, not a per-histogram allocation on the scoring hot path.
    centers: std::sync::Arc<Vec<f64>>,
}

impl Grid {
    /// `bins` evenly spaced values covering `[lo, hi]` inclusive.
    ///
    /// Panics unless `bins >= 2` and `lo < hi` are finite.
    pub fn uniform(lo: f64, hi: f64, bins: usize) -> Grid {
        assert!(bins >= 2, "grid needs at least 2 bins, got {bins}");
        assert!(
            lo.is_finite() && hi.is_finite() && lo < hi,
            "grid range must be finite and ordered, got [{lo}, {hi}]"
        );
        let step = (hi - lo) / (bins - 1) as f64;
        let centers = (0..bins).map(|j| lo + j as f64 * step).collect();
        Grid {
            lo,
            hi,
            step,
            centers: std::sync::Arc::new(centers),
        }
    }

    pub fn bins(&self) -> usize {
        self.centers.len()
    }

    pub fn lo(&self) -> f64 {
        self.lo
    }

    pub fn hi(&self) -> f64 {
        self.hi
    }

    /// Spacing between adjacent bin values.
    pub fn step(&self) -> f64 {
        self.step
    }

    /// The representative rate value of bin `j`.
    pub fn value(&self, j: usize) -> f64 {
        self.centers[j]
    }

    /// All bin values, ascending.
    pub fn values(&self) -> &[f64] {
        &self.centers
    }

    /// Index of the bin nearest to `v`, clamped to the grid. Non-finite
    /// inputs clamp to the lowest bin (pessimistic for rates).
    pub fn index_of(&self, v: f64) -> usize {
        if !v.is_finite() || v <= self.lo {
            return 0;
        }
        let j = ((v - self.lo) / self.step).round() as usize;
        j.min(self.centers.len() - 1)
    }

    /// Whether two grids carry identical discretizations (same range and
    /// bin count), i.e. their histograms compose.
    pub fn same_shape(&self, other: &Grid) -> bool {
        self.lo == other.lo && self.hi == other.hi && self.centers.len() == other.centers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_spans_inclusive_endpoints() {
        let g = Grid::uniform(0.0, 31.5, 64);
        assert_eq!(g.bins(), 64);
        assert_eq!(g.value(0), 0.0);
        assert!((g.value(63) - 31.5).abs() < 1e-12);
        // matches the scorer convention: value(j) = j * 0.5
        for j in 0..64 {
            assert!((g.value(j) - j as f64 * 0.5).abs() < 1e-12, "bin {j}");
        }
    }

    #[test]
    fn index_of_rounds_and_clamps() {
        let g = Grid::uniform(0.0, 10.0, 11); // step 1.0
        assert_eq!(g.index_of(-5.0), 0);
        assert_eq!(g.index_of(0.0), 0);
        assert_eq!(g.index_of(3.4), 3);
        assert_eq!(g.index_of(3.6), 4);
        assert_eq!(g.index_of(10.0), 10);
        assert_eq!(g.index_of(99.0), 10);
        assert_eq!(g.index_of(f64::NAN), 0);
        assert_eq!(g.index_of(f64::INFINITY), 0);
    }

    #[test]
    fn same_shape_discriminates() {
        let a = Grid::uniform(0.0, 10.0, 16);
        assert!(a.same_shape(&a.clone()));
        assert!(!a.same_shape(&Grid::uniform(0.0, 10.0, 32)));
        assert!(!a.same_shape(&Grid::uniform(0.0, 12.0, 16)));
    }

    #[test]
    #[should_panic]
    fn rejects_degenerate_range() {
        Grid::uniform(5.0, 5.0, 8);
    }
}
