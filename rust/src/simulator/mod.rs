//! Discrete-event simulator of the geo-distributed plant (the CloudSim
//! substitute — Sec 6.1), with a dual-mode time core.
//!
//! Semantics follow Sec 3.2/3.3:
//! * a copy of task ξ launched in cluster m runs at
//!   `min(V^P_m, mean over sources of V^T_{src,m})`, both drawn from the
//!   cluster's ground-truth distributions at launch;
//! * per-slot Bernoulli cluster-level unreachability kills every copy in
//!   the afflicted cluster;
//! * slot capacity M_k and gate bandwidths Ing_k / Eg_k (Eqs. 9–11) are
//!   enforced by the engine regardless of what a policy requests;
//! * a task completes when its fastest alive copy has processed D_l^i;
//!   sibling copies cancel and free their slots; completions propagate
//!   readiness through the DAG (Eq. 8) and the last task completes the job.
//!
//! ## Module layout
//!
//! * [`engine`] — orchestration: [`Simulation`] owns the plant state and
//!   runs either time core, selected by [`SimConfig::time_model`]
//!   ([`TimeModel::Dense`] = the slotted reference loop, bit-reproducible;
//!   [`TimeModel::EventSkip`] = jump-to-next-event).
//!   [`SimConfig::score_threads`] is the intra-cell parallelism budget:
//!   the engine hands it to the policy via `SchedView::score_threads`,
//!   and PingAn shards its per-round scoring batch across that many OS
//!   threads — bit-identical decisions at any value, on either time core
//!   (default: the `PINGAN_SCORE_THREADS` env var, else serial).
//! * [`events`] — the `BinaryHeap` event queue (`Arrival`,
//!   `CopyCompletion`, `ClusterFailure`, `PolicyEpoch`) with deterministic
//!   tie-breaking in the dense engine's within-slot phase order.
//! * [`processes`] — the per-slot stochastic processes in skippable form:
//!   geometric inter-failure gaps (same marginal Bernoulli-per-slot
//!   process) and exact k-step AR(1) congestion transitions.
//! * [`state`] — runtime job/task/copy state shared by both cores.

pub mod engine;
pub mod events;
pub mod processes;
pub mod state;

pub use crate::config::spec::TimeModel;
pub use engine::{SimConfig, SimResult, Simulation};
pub use events::{Event, EventQueue};
pub use state::{CopyRt, JobRt, TaskRt, TaskState};
