//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` binaries use [`Bench`] to run warmup + timed iterations
//! and print a stable `name  median  p10  p90  iters` row per case, plus
//! a machine-readable JSON line for EXPERIMENTS.md tooling.

use crate::util::jsonout::Json;
use crate::util::stats;
use std::time::Instant;

/// One benchmark suite.
pub struct Bench {
    suite: String,
    /// Target wall time per case (seconds).
    pub target_secs: f64,
    /// Minimum timed iterations.
    pub min_iters: usize,
    results: Vec<(String, f64)>,
}

impl Bench {
    pub fn new(suite: &str) -> Bench {
        // honor `PINGAN_BENCH_FAST=1` for CI-ish smoke runs
        let fast = std::env::var("PINGAN_BENCH_FAST").ok().as_deref() == Some("1");
        Bench {
            suite: suite.to_string(),
            target_secs: if fast { 0.2 } else { 1.0 },
            min_iters: if fast { 3 } else { 10 },
            results: Vec::new(),
        }
    }

    /// Run one case: `f` is called repeatedly; its return value is folded
    /// into a black-box sink so the optimizer cannot elide work.
    pub fn case<F: FnMut() -> f64>(&mut self, name: &str, mut f: F) -> f64 {
        // warmup: one call, also calibrates the iteration count
        let t0 = Instant::now();
        let mut sink = f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_secs / once).ceil() as usize).clamp(self.min_iters, 10_000);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            sink += f();
            samples.push(t.elapsed().as_secs_f64());
        }
        std::hint::black_box(sink);
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = stats::quantile_sorted(&samples, 0.5);
        let p10 = stats::quantile_sorted(&samples, 0.1);
        let p90 = stats::quantile_sorted(&samples, 0.9);
        println!(
            "{:<42} median {:>12}  p10 {:>12}  p90 {:>12}  iters {}",
            format!("{}::{}", self.suite, name),
            fmt_secs(median),
            fmt_secs(p10),
            fmt_secs(p90),
            iters
        );
        let mut j = Json::obj();
        j.set("suite", Json::str(&self.suite))
            .set("case", Json::str(name))
            .set("median_s", Json::num(median))
            .set("p10_s", Json::num(p10))
            .set("p90_s", Json::num(p90))
            .set("iters", Json::num(iters as f64));
        println!("BENCHJSON {}", j.to_string());
        self.results.push((name.to_string(), median));
        median
    }

    /// Medians recorded so far (for inter-case assertions in benches).
    pub fn medians(&self) -> &[(String, f64)] {
        &self.results
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3}us", s * 1e6)
    } else {
        format!("{:.1}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_runs_and_records() {
        std::env::set_var("PINGAN_BENCH_FAST", "1");
        let mut b = Bench::new("t");
        let med = b.case("noop-ish", || {
            let mut x = 0.0f64;
            for i in 0..100 {
                x += (i as f64).sqrt();
            }
            x
        });
        assert!(med >= 0.0);
        assert_eq!(b.medians().len(), 1);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.0).ends_with('s'));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("us"));
        assert!(fmt_secs(2e-9).ends_with("ns"));
    }
}
