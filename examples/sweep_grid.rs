//! Scenario-sweep walkthrough: a small λ×ε grid across two schedulers on
//! the parallel sweep runner, printed as the CSV report plus a best-ε
//! summary.
//!
//! ```bash
//! cargo run --release --example sweep_grid
//! ```

use pingan::sweep::{self, Axis, CellResult, Scenario, SweepSpec};

fn main() {
    let mut base = Scenario::default();
    base.n_clusters = 8;
    base.n_jobs = 16;
    base.slot_divisor = 10;
    let spec = SweepSpec::new(base)
        .axis(Axis::Scheduler(vec!["flutter".into(), "pingan".into()]))
        .axis(Axis::Lambda(vec![0.02, 0.07, 0.15]))
        .axis(Axis::Epsilon(vec![0.4, 0.8]))
        .reps(2)
        .seed(0x5EED);
    eprintln!(
        "sweeping {} cells on {} thread(s) ...",
        spec.n_cells(),
        sweep::default_threads(spec.n_cells())
    );
    let progress = |cell: &CellResult, done: usize, total: usize| {
        eprintln!("[{done}/{total}] {} ({:.2}s)", cell.scenario.label(), cell.wall_secs);
    };
    let report = sweep::run_with(&spec, 0, Some(&progress));

    print!("{}", report.to_csv());

    // ε-tuning readout: best ε per (scheduler=pingan, λ), Fig-7 style.
    println!("\nbest ε per λ (pingan):");
    for &lambda in &[0.02, 0.07, 0.15] {
        let best = report
            .rows
            .iter()
            .filter(|r| {
                r.scenario.scheduler == "pingan" && r.scenario.lambda == lambda && r.mean.is_finite()
            })
            .min_by(|a, b| a.mean.total_cmp(&b.mean));
        if let Some(r) = best {
            println!("  λ={lambda:<5} ε={} mean {:.1} ± {:.1}", r.scenario.epsilon, r.mean, r.ci95);
        }
    }
}
