"""Pure-jnp reference oracle for every kernel — the correctness ground
truth. pytest asserts kernel == ref under hypothesis-generated shapes, and
the rust scorer fallback is cross-checked against the same math bin-by-bin
(`rust/src/runtime/scorer.rs`).
"""

import jax.numpy as jnp


def bottleneck_ref(proc_pmf, trans_pmf):
    """Distribution of min(P, T) for independent P, T on a shared grid.

    P(min = v_j) = p_j * P(T > v_j) + t_j * P(P > v_j) + p_j * t_j.
    Shapes: [..., V] -> [..., V].
    """
    sf_p = exclusive_sf(proc_pmf)
    sf_t = exclusive_sf(trans_pmf)
    out = proc_pmf * sf_t + trans_pmf * sf_p + proc_pmf * trans_pmf
    total = jnp.sum(out, axis=-1, keepdims=True)
    return out / jnp.maximum(total, 1e-30)


def exclusive_sf(pmf):
    """P(X > v_j) per bin: suffix sum excluding bin j."""
    rev_cum = jnp.cumsum(pmf[..., ::-1], axis=-1)[..., ::-1]
    return rev_cum - pmf


def expmax_ref(cand_pmf, existing_cdf, values):
    """E[max(existing copies, candidate k)] for each candidate.

    cand_pmf:     [B, K, V] candidate execution-rate pmfs
    existing_cdf: [B, V]    product of the existing copies' CDFs
                            (all-ones row when the task has no copy yet)
    values:       [V]       grid bin centers
    returns:      [B, K]    expected max rate per candidate
    """
    cand_cdf = jnp.cumsum(cand_pmf, axis=-1)  # [B,K,V]
    combined = cand_cdf * existing_cdf[:, None, :]  # CDF product (Eq. 13)
    pmf = jnp.diff(combined, axis=-1, prepend=0.0)
    return jnp.einsum("bkv,v->bk", pmf, values)


def score_ref(proc_pmf, trans_pmf, existing_cdf, values):
    """Full scorer: bottleneck-compose then expected-max (the L2 graph)."""
    rate_pmf = bottleneck_ref(proc_pmf, trans_pmf)
    return expmax_ref(rate_pmf, existing_cdf, values)


def wordcount_ref(tokens, vocab):
    """Histogram of token ids: [N] int32 -> [vocab] f32 counts."""
    onehot = jnp.asarray(tokens[:, None] == jnp.arange(vocab)[None, :], jnp.float32)
    return jnp.sum(onehot, axis=0)


def pagerank_step_ref(ranks, adj, damping=0.85):
    """One PageRank power-iteration step with column-normalized adj."""
    deg = jnp.maximum(jnp.sum(adj, axis=1, keepdims=True), 1.0)
    contrib = (adj / deg).T @ ranks
    n = ranks.shape[0]
    return (1.0 - damping) / n + damping * contrib


def logreg_step_ref(x, y, w, lr=0.1):
    """One logistic-regression gradient step."""
    logits = x @ w
    p = 1.0 / (1.0 + jnp.exp(-logits))
    grad = x.T @ (p - y) / x.shape[0]
    return w - lr * grad
