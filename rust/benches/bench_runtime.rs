//! PJRT runtime benches: artifact compile latency, HLO-vs-CPU scorer
//! throughput and payload execution latency — the L1/L2 side of the §Perf
//! pass as observable from the rust hot path.
//!
//! Run: `make artifacts && cargo bench --bench bench_runtime`

use pingan::bench_harness::Bench;
use pingan::runtime::{CpuScorer, Engine, HloScorer, ScoreBatch, Scorer};
use pingan::util::rng::Rng;

fn rand_batch(seed: u64, b: usize, k: usize, v: usize) -> ScoreBatch {
    let mut rng = Rng::new(seed);
    let mut batch = ScoreBatch::new(b, k, v);
    batch.values = (0..v).map(|i| i as f64).collect();
    for x in batch.proc_pmf.iter_mut().chain(batch.trans_pmf.iter_mut()) {
        *x = rng.f64() + 1e-3;
    }
    for bi in 0..b {
        for ki in 0..k {
            let base = (bi * k + ki) * v;
            for pmf in [&mut batch.proc_pmf, &mut batch.trans_pmf] {
                let s: f64 = pmf[base..base + v].iter().sum();
                pmf[base..base + v].iter_mut().for_each(|e| *e /= s);
            }
        }
    }
    batch
}

fn main() {
    if !std::path::Path::new("artifacts/manifest.toml").exists() {
        eprintln!("bench_runtime requires artifacts: run `make artifacts`");
        return;
    }
    let mut b = Bench::new("runtime");

    let engine = Engine::new("artifacts").expect("engine");
    b.case("compile_score_artifact", || {
        engine.compile("score").map(|_| 1.0).unwrap_or(0.0)
    });

    let hlo = HloScorer::new(&engine).expect("scorer");
    let (bb, kk, vv) = hlo.shape();
    let batch = rand_batch(5, bb, kk, vv);
    b.case(&format!("hlo_score_{bb}x{kk}x{vv}"), || {
        hlo.score(&batch).unwrap().iter().sum::<f64>()
    });
    b.case(&format!("cpu_score_{bb}x{kk}x{vv}"), || {
        CpuScorer.score(&batch).unwrap().iter().sum::<f64>()
    });

    let payloads = pingan::runtime::payload::Payloads::new(&engine).expect("payloads");
    let mut rng = Rng::new(6);
    for app in pingan::workload::testbed::AppKind::ALL {
        // fork the rng per case for stable work
        let mut r = rng.fork(app.name().len() as u64);
        b.case(&format!("payload_{}", app.name()), || {
            payloads.run(app, &mut r).unwrap()
        });
    }
}
