//! Figure regenerators: each prints the same rows/series the paper reports
//! and returns the raw numbers for benches/tests.

use super::{run_averaged, sim_setup, Scale, SIM_BASELINES};
use crate::baselines::{Spark, SpeculativeSpark};
use crate::config::spec::{Allocation, PingAnSpec, Principle};
use crate::insurance::PingAn;
use crate::metrics::cdf::{reduction_ratios, Cdf};
use crate::sparkyarn::{Testbed, TestbedConfig, TestbedResult};
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::{fnum, fpct, Table};
use crate::workload::testbed::{generate, TestbedSpec};

/// (λ, ε) pairs for light/medium/heavy load. λ follows Sec 6.2; ε is tuned
/// by *our* Fig-7 sweep at reproduction scale (the paper does the same via
/// its Sec-6.4 hint — their 0.8/0.6/0.2 values are specific to their
/// concurrency level N(t); at reduced scale ⌈εN⌉ degenerates for small ε,
/// and the measured optimum is 0.6/0.6/0.8 — see EXPERIMENTS.md).
pub const LOADS: [(&str, f64, f64); 3] = [
    ("light", 0.02, 0.6),
    ("medium", 0.07, 0.6),
    ("heavy", 0.15, 0.8),
];

// ---------------------------------------------------------------- fig 2/3

/// Fig 2 + Fig 3 share one testbed run set.
pub struct TestbedRuns {
    pub results: Vec<TestbedResult>,
}

/// Run the Sec-5 testbed comparison: PingAn (ε=0.6) vs Spark vs
/// speculative Spark on the Table-1 workload over 10 clusters.
pub fn run_testbed(n_jobs: usize, payload_every: usize) -> anyhow::Result<TestbedRuns> {
    let sys = crate::sparkyarn::testbed::testbed_system(42);
    let mut spec = TestbedSpec::default();
    spec.n_jobs = n_jobs;
    let sites: Vec<usize> = (0..sys.n()).collect();
    let mut rng = Rng::new(spec.seed);
    let jobs = generate(&spec, &sites, &mut rng);
    let mut cfg = TestbedConfig::default();
    cfg.payload_every = payload_every;
    let tb = Testbed::new(cfg)?;
    let mut results = Vec::new();
    let mut pingan = PingAn::with_epsilon(0.6);
    results.push(tb.run(&sys, jobs.clone(), &mut pingan));
    results.push(tb.run(&sys, jobs.clone(), &mut Spark::new()));
    results.push(tb.run(&sys, jobs, &mut SpeculativeSpark::new()));
    Ok(TestbedRuns { results })
}

/// Fig 2: average testbed flowtime per scheduler.
pub fn fig2(runs: &TestbedRuns) -> String {
    let mut t = Table::new(
        "Fig 2 — testbed average job flowtime (slots)",
        &["scheduler", "avg flowtime", "vs spark-spec", "payload execs", "payload errors"],
    );
    let spec_avg = avg(&runs.results[2].flowtimes);
    for r in &runs.results {
        let a = avg(&r.flowtimes);
        t.row(&[
            r.scheduler.clone(),
            fnum(a, 1),
            fpct((spec_avg - a) / spec_avg),
            r.payload_execs.to_string(),
            r.payload_errors.to_string(),
        ]);
    }
    t.render()
}

/// Fig 3: conditional flowtime CDFs (a: <500 s band, b: >300 s band),
/// sampled at fixed fractions of the observed range.
pub fn fig3(runs: &TestbedRuns) -> String {
    let mut out = String::new();
    let hi: f64 = runs
        .results
        .iter()
        .flat_map(|r| r.flowtimes.iter())
        .filter(|f| f.is_finite())
        .fold(0.0, |a: f64, &b| a.max(b));
    let windows = [("3a: short jobs", 0.0, 0.5 * hi), ("3b: long jobs", 0.3 * hi, hi)];
    for (label, lo, hi) in windows {
        let mut t = Table::new(
            &format!("Fig {label} — flowtime CDF on [{:.0},{:.0}]", lo, hi),
            &["scheduler", "p25", "p50", "p75", "p90", "n"],
        );
        for r in &runs.results {
            let c = Cdf::new(&r.flowtimes).restricted(lo, hi);
            t.row(&[
                r.scheduler.clone(),
                fnum(c.quantile(0.25), 1),
                fnum(c.quantile(0.5), 1),
                fnum(c.quantile(0.75), 1),
                fnum(c.quantile(0.9), 1),
                c.len().to_string(),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

// ------------------------------------------------------------------ fig 4

/// Fig 4 data: per (load, scheduler) average flowtime.
pub struct Fig4 {
    /// (load label, scheduler, avg flowtime)
    pub rows: Vec<(String, String, f64)>,
}

pub fn run_fig4(scale: &Scale) -> Fig4 {
    let mut rows = Vec::new();
    for (label, lambda, eps) in LOADS {
        for name in SIM_BASELINES.iter().chain(&["pingan"]) {
            let flows = run_averaged(scale, lambda, name, eps);
            rows.push((label.to_string(), name.to_string(), avg(&flows)));
        }
    }
    Fig4 { rows }
}

pub fn fig4_table(f: &Fig4) -> String {
    let mut t = Table::new(
        "Fig 4 — avg job flowtime by load (slots)",
        &["load", "scheduler", "avg flowtime", "pingan vs best baseline"],
    );
    for (label, _, _) in LOADS {
        let in_load: Vec<&(String, String, f64)> =
            f.rows.iter().filter(|r| r.0 == label).collect();
        let pingan = in_load.iter().find(|r| r.1 == "pingan").map(|r| r.2);
        let best_base = in_load
            .iter()
            .filter(|r| r.1 != "pingan")
            .map(|r| r.2)
            .fold(f64::INFINITY, f64::min);
        for r in &in_load {
            let delta = if r.1 == "pingan" {
                fpct((best_base - pingan.unwrap()) / best_base)
            } else {
                String::new()
            };
            t.row(&[r.0.clone(), r.1.clone(), fnum(r.2, 1), delta]);
        }
    }
    t.render()
}

// ------------------------------------------------------------------ fig 5

/// Fig 5: flowtime CDFs and reduction-ratio-vs-Flutter CDFs per load.
pub fn fig5(scale: &Scale) -> String {
    let mut out = String::new();
    for (label, lambda, eps) in LOADS {
        let flutter = run_averaged(scale, lambda, "flutter", eps);
        let series: Vec<(&str, Vec<f64>)> = [
            ("pingan", eps),
            ("flutter+mantri", eps),
            ("flutter+dolly", eps),
        ]
        .iter()
        .map(|(n, e)| (*n, run_averaged(scale, lambda, n, *e)))
        .collect();
        let mut t = Table::new(
            &format!("Fig 5 ({label}, λ={lambda}) — flowtime quantiles (slots)"),
            &["scheduler", "p25", "p50", "p75", "p90"],
        );
        let q = |v: &[f64], q: f64| fnum(Cdf::new(v).quantile(q), 1);
        t.row(&[
            "flutter".into(),
            q(&flutter, 0.25),
            q(&flutter, 0.5),
            q(&flutter, 0.75),
            q(&flutter, 0.9),
        ]);
        for (name, flows) in &series {
            t.row(&[
                name.to_string(),
                q(flows, 0.25),
                q(flows, 0.5),
                q(flows, 0.75),
                q(flows, 0.9),
            ]);
        }
        out.push_str(&t.render());
        let mut t2 = Table::new(
            &format!("Fig 5 ({label}) — flowtime reduction vs flutter"),
            &["scheduler", "p30 reduction", "median reduction", "% jobs slower"],
        );
        for (name, flows) in &series {
            let rr = reduction_ratios(&flutter, flows);
            let slower = rr.iter().filter(|&&x| x < 0.0).count() as f64
                / rr.len().max(1) as f64;
            t2.row(&[
                name.to_string(),
                fpct(stats::quantile(&rr, 0.30)),
                fpct(stats::quantile(&rr, 0.5)),
                fpct(slower),
            ]);
        }
        out.push_str(&t2.render());
        out.push('\n');
    }
    out
}

// ------------------------------------------------------------------ fig 6

/// Fig 6a data: avg flowtime per insuring principle at λ=0.07, ε=0.6.
pub fn run_fig6a(scale: &Scale) -> Vec<(String, f64)> {
    let lambda = 0.07;
    [
        Principle::EffReli,
        Principle::ReliEff,
        Principle::EffEff,
        Principle::ReliReli,
    ]
    .iter()
    .map(|&p| {
        let flows = run_variant(scale, lambda, p, Allocation::Efa);
        (p.name().to_string(), avg(&flows))
    })
    .collect()
}

/// Fig 6b data: EFA vs JGA.
pub fn run_fig6b(scale: &Scale) -> Vec<(String, f64)> {
    let lambda = 0.07;
    [Allocation::Efa, Allocation::Jga]
        .iter()
        .map(|&a| {
            let flows = run_variant(scale, lambda, Principle::EffReli, a);
            (a.name().to_string(), avg(&flows))
        })
        .collect()
}

fn run_variant(scale: &Scale, lambda: f64, p: Principle, a: Allocation) -> Vec<f64> {
    let results: Vec<crate::simulator::SimResult> = (0..scale.reps)
        .map(|rep| {
            let (sys, jobs) = sim_setup(scale, lambda, rep);
            let mut spec = PingAnSpec::with_epsilon(0.6);
            spec.principle = p;
            spec.allocation = a;
            let mut cfg = crate::simulator::SimConfig::default();
            cfg.seed = 0xC0FFEE ^ rep;
            crate::simulator::Simulation::new(&sys, jobs, cfg).run(&mut PingAn::new(spec))
        })
        .collect();
    super::averaged_flowtimes(&results)
}

pub fn fig6_table(a_rows: &[(String, f64)], b_rows: &[(String, f64)]) -> String {
    let mut out = String::new();
    let mut t = Table::new(
        "Fig 6a — insuring-principle ablation (λ=0.07, ε=0.6)",
        &["principle", "avg flowtime", "vs Eff-Reli"],
    );
    let base = a_rows[0].1;
    for (name, v) in a_rows {
        t.row(&[name.clone(), fnum(*v, 1), fpct((v - base) / v.max(1e-9))]);
    }
    out.push_str(&t.render());
    let mut t2 = Table::new(
        "Fig 6b — allocation ablation",
        &["allocation", "avg flowtime", "vs EFA"],
    );
    let base = b_rows[0].1;
    for (name, v) in b_rows {
        t2.row(&[name.clone(), fnum(*v, 1), fpct((v - base) / v.max(1e-9))]);
    }
    out.push_str(&t2.render());
    out
}

// ------------------------------------------------------------------ fig 7

/// Fig 7: ε×λ sweep of average flowtime.
pub fn run_fig7(scale: &Scale, lambdas: &[f64], epsilons: &[f64]) -> Vec<(f64, f64, f64)> {
    let mut out = Vec::new();
    for &lambda in lambdas {
        for &eps in epsilons {
            let flows = run_averaged(scale, lambda, "pingan", eps);
            out.push((lambda, eps, avg(&flows)));
        }
    }
    out
}

pub fn fig7_table(rows: &[(f64, f64, f64)]) -> String {
    let mut t = Table::new(
        "Fig 7 — ε vs λ (avg job flowtime, slots; * = best ε per λ)",
        &["lambda", "epsilon", "avg flowtime", "best"],
    );
    let lambdas: Vec<f64> = {
        let mut ls: Vec<f64> = rows.iter().map(|r| r.0).collect();
        ls.dedup();
        ls
    };
    for &l in &lambdas {
        let best = rows
            .iter()
            .filter(|r| r.0 == l)
            .map(|r| r.2)
            .fold(f64::INFINITY, f64::min);
        for r in rows.iter().filter(|r| r.0 == l) {
            t.row(&[
                fnum(r.0, 2),
                fnum(r.1, 1),
                fnum(r.2, 1),
                if r.2 == best { "*".into() } else { String::new() },
            ]);
        }
    }
    t.render()
}

fn avg(xs: &[f64]) -> f64 {
    let v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    stats::mean(&v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_smoke() {
        let scale = Scale::smoke();
        let a = run_fig6a(&scale);
        assert_eq!(a.len(), 4);
        let b = run_fig6b(&scale);
        assert_eq!(b.len(), 2);
        let rendered = fig6_table(&a, &b);
        assert!(rendered.contains("Eff-Reli"));
        assert!(rendered.contains("JGA"));
    }

    #[test]
    fn fig7_smoke() {
        let scale = Scale::smoke();
        let rows = run_fig7(&scale, &[0.05], &[0.4, 0.8]);
        assert_eq!(rows.len(), 2);
        let t = fig7_table(&rows);
        assert!(t.contains('*'));
    }
}
