//! Iridium (Pu et al. — SIGCOMM'15): place tasks to minimize WAN transfer —
//! each task runs where most of its input already sits, falling back to the
//! best-connected cluster. No copies, no heterogeneity awareness.

use crate::sched::{Action, Assignment, SchedView, Scheduler};
use std::collections::HashMap;

pub struct Iridium;

impl Iridium {
    pub fn new() -> Iridium {
        Iridium
    }
}

impl Default for Iridium {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for Iridium {
    fn name(&self) -> &str {
        "iridium"
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        let mut out = Vec::new();
        let mut order: Vec<usize> = view.alive.to_vec();
        order.sort_by_key(|&ji| view.jobs[ji].spec.arrival);
        for ji in order {
            for ti in view.ready_tasks(ji) {
                let sources = view.jobs[ji].tasks[ti].sources.clone();
                let op = view.jobs[ji].spec.tasks[ti].op;
                // rank clusters by input-partition count held
                let mut held: HashMap<usize, usize> = HashMap::new();
                for &s in &sources {
                    *held.entry(s).or_insert(0) += 1;
                }
                let mut ranked: Vec<(usize, usize)> =
                    held.into_iter().map(|(m, c)| (c, m)).collect();
                ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
                // candidate order: data-holding clusters first, then the
                // rest by mean bandwidth from the dominant source — and
                // fall through on slot/bandwidth rejection (a single pinned
                // choice can livelock behind a permanently tight gate)
                let dom = ranked.first().map(|(_, m)| *m);
                let mut order: Vec<usize> = ranked.iter().map(|(_, m)| *m).collect();
                let mut rest: Vec<(f64, usize)> = (0..view.system.n())
                    .filter(|m| !order.contains(m))
                    .map(|m| {
                        let bw = dom.map(|d| view.system.wan_mean(d, m)).unwrap_or(1.0);
                        (bw, m)
                    })
                    .collect();
                rest.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
                order.extend(rest.into_iter().map(|(_, m)| m));
                for m in order {
                    if view.free_slots[m] == 0 {
                        continue;
                    }
                    let est = view.model.exp_rate1(&sources, m, op);
                    if view.try_reserve_slot(m) {
                        if view.try_reserve_bandwidth(&sources, m, est) {
                            out.push(Action::Launch(Assignment {
                                job: ji,
                                task: ti,
                                cluster: m,
                            }));
                            break;
                        }
                        view.free_slots[m] += 1;
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::simulator::{SimConfig, Simulation};
    use crate::util::rng::Rng;
    use crate::workload::montage;

    #[test]
    fn iridium_completes_workload() {
        let mut rng = Rng::new(82);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(8, 0.05);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut Iridium::new());
        assert_eq!(res.finished_jobs, res.total_jobs);
    }
}
