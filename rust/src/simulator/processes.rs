//! The two per-slot stochastic processes of the plant, lifted into
//! *skippable* form for the event-skip time core.
//!
//! * **Cluster failures** — the dense engine draws Bernoulli(p_m) per
//!   cluster per slot. [`FailureGaps`] samples the same marginal process
//!   as geometric inter-failure gaps (`P(G = g) = (1-p)^(g-1) p`), so an
//!   event-driven engine knows the *next* failure slot of every cluster
//!   without touching the slots in between. Geometric gaps are memoryless,
//!   which is what makes pausing the process over idle windows
//!   ([`FailureGaps::shift`]) distributionally exact.
//! * **AR(1) congestion load** — the dense engine advances
//!   `x ← clamp(φ·x + w·T)` once per slot with lognormal targets
//!   `T = exp(σ·N(0,1))`. [`ar1_advance`] steps the same recursion either
//!   exactly (k = 1, bit-identical to the dense engine's draw) or in
//!   closed form over k skipped slots: the k-step transition has mean
//!   `φ^k·x + w·(1-φ^k)/(1-φ)·E[T]` and variance
//!   `w²·Var[T]·(1-φ^{2k})/(1-φ²)`, approximated as normal (CLT over the
//!   k independent target draws) and clamped once.

use crate::cluster::{GeoSystem, FAILURE_EPOCH_SLOTS};
use crate::topology::ClusterScale;
use crate::util::rng::Rng;
use std::ops::Range;

/// AR(1) smoothing factor of the congestion process (the pre-refactor
/// engine's literal 0.95 — same f64 bits, so the k = 1 path reproduces
/// the dense arithmetic exactly).
pub const AR1_PHI: f64 = 0.95;
/// Innovation weight (a separate constant, not `1.0 - AR1_PHI`, which
/// differs in the last bit from the literal 0.05 the engine always used).
pub const AR1_WEIGHT: f64 = 0.05;
/// Clamp range of the congestion factor.
pub const LOAD_MIN: f64 = 0.25;
pub const LOAD_MAX: f64 = 4.0;

/// Per-scale lognormal σ of the congestion target: smaller clusters swing
/// harder (Table-2 scale classes; the paper's motivation is that *edges*
/// overload).
pub fn sigma_for(scale: ClusterScale) -> f64 {
    match scale {
        ClusterScale::Large => 0.25,
        ClusterScale::Medium => 0.5,
        ClusterScale::Small => 0.8,
    }
}

/// One geometric inter-failure gap on {1, 2, ...} with per-slot hit
/// probability `p` (inverse-CDF sampling). `None` means "never" (p ≤ 0).
pub fn geometric_gap(p: f64, rng: &mut Rng) -> Option<u64> {
    if p <= 0.0 {
        return None;
    }
    if p >= 1.0 {
        return Some(1);
    }
    let u = rng.f64();
    // G = ⌈ln(1-U) / ln(1-p)⌉: P(G = g) = (1-p)^(g-1)·p exactly.
    let g = ((1.0 - u).ln() / (1.0 - p).ln()).ceil();
    Some((g as u64).max(1))
}

/// Sentinel for "this cluster never fails".
pub const NEVER: u64 = u64::MAX;

/// Next-failure slots per cluster, maintained as sampled geometric gaps.
/// The marginal per-slot process is exactly the dense engine's Bernoulli
/// draw (see the proptest in `tests/proptest_invariants.rs`).
pub struct FailureGaps {
    p: Vec<f64>,
    next: Vec<u64>,
}

impl FailureGaps {
    /// Sample the initial gap of every cluster; slot 0 itself can fail
    /// (gap G ≥ 1 maps to first failure at slot G-1, so slot 0 fails with
    /// probability p, matching the dense engine's draw at `now = 0`).
    pub fn new(system: &GeoSystem, rng: &mut Rng) -> FailureGaps {
        let p: Vec<f64> = system
            .clusters
            .iter()
            .map(|c| c.unreach_p / FAILURE_EPOCH_SLOTS)
            .collect();
        let next = p
            .iter()
            .map(|&p| match geometric_gap(p, rng) {
                Some(g) => g - 1,
                None => NEVER,
            })
            .collect();
        FailureGaps { p, next }
    }

    /// [`FailureGaps::new`] restricted to the clusters of one engine shard:
    /// index `i` addresses global cluster `range.start + i`, and cluster `i`
    /// draws its initial gap from *its own* stream `rngs[i]` (the
    /// RNG-stream-per-cluster discipline that makes the sharded walk
    /// independent of the shard count — see `simulator::shard`).
    pub fn for_range(system: &GeoSystem, range: Range<usize>, rngs: &mut [Rng]) -> FailureGaps {
        debug_assert_eq!(range.len(), rngs.len());
        let p: Vec<f64> = system.clusters[range]
            .iter()
            .map(|c| c.unreach_p / FAILURE_EPOCH_SLOTS)
            .collect();
        let next = p
            .iter()
            .zip(rngs.iter_mut())
            .map(|(&p, rng)| match geometric_gap(p, rng) {
                Some(g) => g - 1,
                None => NEVER,
            })
            .collect();
        FailureGaps { p, next }
    }

    /// Absolute slot of cluster `m`'s next failure ([`NEVER`] if none).
    pub fn next(&self, m: usize) -> u64 {
        self.next[m]
    }

    /// Per-slot failure probability of cluster `m` (the dense engine's
    /// Bernoulli parameter — shards draw against it directly).
    pub fn p(&self, m: usize) -> f64 {
        self.p[m]
    }

    /// Record that `m`'s pending failure fired; sample the next gap.
    pub fn fire(&mut self, m: usize, rng: &mut Rng) {
        self.next[m] = match geometric_gap(self.p[m], rng) {
            Some(g) => self.next[m].saturating_add(g),
            None => NEVER,
        };
    }

    /// Pause the process over an idle window: push `m`'s pending failure
    /// `by` slots into the future. Distributionally exact — geometric
    /// gaps are memoryless — and mirrors the dense engine, which draws no
    /// failures during its idle fast-forward.
    pub fn shift(&mut self, m: usize, by: u64) {
        if self.next[m] != NEVER {
            self.next[m] = self.next[m].saturating_add(by);
        }
    }
}

/// Advance the per-cluster AR(1) congestion loads over `k` slots.
///
/// `k = 1` replays the dense engine's per-slot update literally (same
/// constants, same operation order, one `gauss` draw per cluster), so the
/// dense path stays bit-identical. `k ≥ 2` applies the exact k-step
/// transition moments with a single normal draw per cluster.
pub fn ar1_advance(load: &mut [f64], sigmas: &[f64], k: u64, rng: &mut Rng) {
    debug_assert_eq!(load.len(), sigmas.len());
    for m in 0..load.len() {
        ar1_step(&mut load[m], sigmas[m], k, rng);
    }
}

/// One cluster's AR(1) advance over `k` slots — the scalar core of
/// [`ar1_advance`] (which is the same loop against one shared stream).
/// Engine shards call this per cluster against that cluster's own RNG
/// stream, so the draw sequence of each chain is independent of how
/// clusters are grouped into shards. Exactly one `gauss` draw when k ≥ 1.
pub fn ar1_step(load: &mut f64, sigma: f64, k: u64, rng: &mut Rng) {
    if k == 0 {
        return;
    }
    if k == 1 {
        let target = (sigma * rng.gauss()).exp();
        *load = (AR1_PHI * *load + AR1_WEIGHT * target).clamp(LOAD_MIN, LOAD_MAX);
        return;
    }
    let s2 = sigma * sigma;
    // lognormal target moments: T = exp(σ·N(0,1))
    let mean_t = (0.5 * s2).exp();
    let var_t = (s2.exp() - 1.0) * s2.exp();
    let phi_k = AR1_PHI.powf(k as f64);
    let mean = phi_k * *load + AR1_WEIGHT * (1.0 - phi_k) / (1.0 - AR1_PHI) * mean_t;
    let var = AR1_WEIGHT * AR1_WEIGHT * var_t * (1.0 - AR1_PHI.powf(2.0 * k as f64))
        / (1.0 - AR1_PHI * AR1_PHI);
    *load = (mean + var.sqrt() * rng.gauss()).clamp(LOAD_MIN, LOAD_MAX);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::SystemSpec;

    #[test]
    fn geometric_gap_mean_tracks_inverse_p() {
        let mut rng = Rng::new(101);
        for &p in &[0.01, 0.05, 0.2] {
            let n = 20_000;
            let mean: f64 = (0..n)
                .map(|_| geometric_gap(p, &mut rng).unwrap() as f64)
                .sum::<f64>()
                / n as f64;
            let want = 1.0 / p;
            assert!(
                (mean - want).abs() < 0.05 * want,
                "p={p}: mean {mean} vs {want}"
            );
        }
    }

    #[test]
    fn geometric_gap_degenerate_probs() {
        let mut rng = Rng::new(102);
        assert_eq!(geometric_gap(0.0, &mut rng), None);
        assert_eq!(geometric_gap(-1.0, &mut rng), None);
        assert_eq!(geometric_gap(1.0, &mut rng), Some(1));
        for _ in 0..100 {
            assert!(geometric_gap(0.5, &mut rng).unwrap() >= 1);
        }
    }

    #[test]
    fn failure_gaps_advance_and_shift() {
        let mut rng = Rng::new(103);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut gaps = FailureGaps::new(&sys, &mut rng);
        for m in 0..sys.n() {
            let t0 = gaps.next(m);
            assert!(t0 < NEVER, "Table-2 probabilities are all positive");
            gaps.fire(m, &mut rng);
            assert!(gaps.next(m) > t0, "gaps are at least one slot");
            let t1 = gaps.next(m);
            gaps.shift(m, 100);
            assert_eq!(gaps.next(m), t1 + 100);
        }
    }

    #[test]
    fn ar1_k1_matches_dense_update_bitwise() {
        // the dense engine's literal update, replayed side by side
        let sigmas = [0.25, 0.5, 0.8];
        let mut a = [1.0f64, 1.3, 0.7];
        let mut b = a;
        let mut rng_a = Rng::new(104);
        let mut rng_b = Rng::new(104);
        for _ in 0..50 {
            ar1_advance(&mut a, &sigmas, 1, &mut rng_a);
            for m in 0..b.len() {
                let target = (sigmas[m] * rng_b.gauss()).exp();
                b[m] = (0.95 * b[m] + 0.05 * target).clamp(0.25, 4.0);
            }
            for m in 0..a.len() {
                assert_eq!(a[m].to_bits(), b[m].to_bits(), "cluster {m}");
            }
        }
    }

    #[test]
    fn ar1_closed_form_matches_iterated_moments() {
        // advance many chains 40 slots both ways; means/stds must agree
        let sigmas = [0.5f64];
        let k = 40u64;
        let n = 4000;
        let mut rng = Rng::new(105);
        let (mut sum_i, mut sq_i, mut sum_c, mut sq_c) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let mut it = [1.0f64];
            for _ in 0..k {
                ar1_advance(&mut it, &sigmas, 1, &mut rng);
            }
            sum_i += it[0];
            sq_i += it[0] * it[0];
            let mut cf = [1.0f64];
            ar1_advance(&mut cf, &sigmas, k, &mut rng);
            sum_c += cf[0];
            sq_c += cf[0] * cf[0];
        }
        let (m_i, m_c) = (sum_i / n as f64, sum_c / n as f64);
        let v_i = sq_i / n as f64 - m_i * m_i;
        let v_c = sq_c / n as f64 - m_c * m_c;
        assert!((m_i - m_c).abs() < 0.03, "means {m_i} vs {m_c}");
        assert!(
            (v_i.sqrt() - v_c.sqrt()).abs() < 0.05,
            "stds {} vs {}",
            v_i.sqrt(),
            v_c.sqrt()
        );
    }

    #[test]
    fn for_range_is_invariant_under_range_splits() {
        // per-cluster streams: splitting 0..n into sub-ranges must draw the
        // exact same initial gaps, because each cluster samples only from
        // its own rng
        let mut rng = Rng::new(107);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let n = sys.n();
        let mk_rngs = || (0..n).map(|m| Rng::new(900 + m as u64)).collect::<Vec<_>>();
        let mut whole_rngs = mk_rngs();
        let whole = FailureGaps::for_range(&sys, 0..n, &mut whole_rngs);
        let mut split_rngs = mk_rngs();
        let (lo, hi) = split_rngs.split_at_mut(3);
        let left = FailureGaps::for_range(&sys, 0..3, lo);
        let right = FailureGaps::for_range(&sys, 3..n, hi);
        for m in 0..n {
            let got = if m < 3 { left.next(m) } else { right.next(m - 3) };
            assert_eq!(got, whole.next(m), "cluster {m}");
            let p = if m < 3 { left.p(m) } else { right.p(m - 3) };
            assert_eq!(p.to_bits(), whole.p(m).to_bits(), "cluster {m} p");
        }
    }

    #[test]
    fn ar1_zero_slots_is_a_noop() {
        let sigmas = [0.5f64];
        let mut x = [1.5f64];
        let mut rng = Rng::new(106);
        ar1_advance(&mut x, &sigmas, 0, &mut rng);
        assert_eq!(x[0], 1.5);
    }
}
