//! Scoring/execution runtime. The default build is hermetic (pure rust);
//! the `pjrt` cargo feature adds the XLA/PJRT artifact path.
//!
//! * [`scorer`] — the insurer's batched copy-placement scorer. The
//!   always-available [`scorer::CpuScorer`] mirrors the `dist::Hist`
//!   algebra exactly (tests assert they agree bin-for-bin). With `pjrt`
//!   enabled, [`scorer::HloScorer`] runs the compiled `score` artifact
//!   (L1 Pallas + L2 JAX math) instead. [`scorer::score_rows_sharded`]
//!   shards a round's rows across a thread pool with bit-identical
//!   output at any thread count (`SimConfig::score_threads`).
//! * [`pjrt`] *(feature `pjrt`)* — artifact discovery
//!   (`artifacts/manifest.toml`), HLO-text loading, compilation on the CPU
//!   PJRT client, typed execution helpers. Python never runs here:
//!   `make artifacts` lowers everything once, ahead of time.
//! * [`payload`] *(feature `pjrt`)* — the testbed task payloads
//!   (wordcount / pagerank / logreg) used by the Spark-on-Yarn mode to run
//!   real compute per task.

#[cfg(feature = "pjrt")]
pub mod payload;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod scorer;

#[cfg(feature = "pjrt")]
pub use pjrt::{ArtifactSet, Engine};
#[cfg(feature = "pjrt")]
pub use scorer::HloScorer;
pub use scorer::{CpuScorer, RowInput, ScoreBatch, Scorer};
