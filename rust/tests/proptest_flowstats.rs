//! Property pins for the [`pingan::metrics::FlowStats`] streaming sketch
//! (an in-tree proptest: seeds sweep a generator; any failure prints the
//! violating seed). The module docs of `metrics::flowstats` document the
//! quantile tolerance contract; this file is the pin referenced there.
//!
//! Properties covered:
//! * sketch quantiles land within the documented band of the exact
//!   bracketing order statistics: `lo - 1 <= s <= hi + hi/32 + 1`
//! * count / mean / sum / min / max are *exact* (not sketched), with the
//!   NaN-means-unfinished convention
//! * merging arbitrary splits of a stream is bit-identical to feeding it
//!   as one stream (histograms add; moments pool within fp tolerance)
//! * feeding the same values in a different order moves no quantile bit
//!   (the histogram is order-free; only moments are order-sensitive, and
//!   those stay within fp-accumulation tolerance)

use pingan::metrics::FlowStats;
use pingan::util::rng::Rng;
use pingan::util::stats;

const SEEDS: std::ops::Range<u64> = 0..16;
const QS: [f64; 4] = [0.5, 0.9, 0.95, 0.99];

/// One random flowtime series: integer slot counts (the real payload
/// shape) from a mix of uniform and heavy-tail draws, with occasional
/// NaN unfinished markers.
fn random_series(rng: &mut Rng) -> Vec<f64> {
    let n = rng.range_usize(1, 3000);
    let scale = rng.range_f64(5.0, 50_000.0);
    let nan_p = if rng.chance(0.5) { rng.range_f64(0.0, 0.15) } else { 0.0 };
    (0..n)
        .map(|_| {
            if rng.chance(nan_p) {
                f64::NAN
            } else if rng.chance(0.3) {
                // heavy tail: exponential, truncated to integer slots
                (rng.exponential(1.0 / scale)).floor().min(1e12)
            } else {
                rng.range_f64(0.0, scale).floor()
            }
        })
        .collect()
}

/// The documented tolerance band around the exact nearest-rank bracket.
fn assert_in_band(seed: u64, q: f64, sorted_finite: &[f64], sketch: f64) {
    let pos = q * (sorted_finite.len() - 1) as f64;
    let lo = sorted_finite[pos.floor() as usize];
    let hi = sorted_finite[pos.ceil() as usize];
    assert!(
        sketch >= lo - 1.0 && sketch <= hi + hi / 32.0 + 1.0,
        "seed {seed} q={q}: sketch {sketch} outside [{lo}, {hi}] band"
    );
}

#[test]
fn prop_quantiles_stay_within_documented_tolerance() {
    for seed in SEEDS {
        let mut rng = Rng::new(0xF10_0 + seed);
        let xs = random_series(&mut rng);
        let s = FlowStats::from_flowtimes(&xs);
        let mut finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        finite.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if finite.is_empty() {
            assert!(s.p50().is_nan(), "seed {seed}: all-NaN series must sketch NaN");
            continue;
        }
        for q in QS {
            assert_in_band(seed, q, &finite, s.quantile(q));
        }
        // interpolated-exact comparison too, at the same documented slack
        let exact = stats::quantile_sorted(&finite, 0.5);
        assert!(
            (s.p50() - exact).abs() <= exact / 32.0 + 2.0,
            "seed {seed}: p50 sketch {} vs exact {exact}",
            s.p50()
        );
    }
}

#[test]
fn prop_moments_and_counts_are_exact() {
    for seed in SEEDS {
        let mut rng = Rng::new(0xF20_0 + seed);
        let xs = random_series(&mut rng);
        let s = FlowStats::from_flowtimes(&xs);
        let finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
        assert_eq!(s.finished(), finite.len() as u64, "seed {seed}");
        assert_eq!(s.total(), xs.len() as u64, "seed {seed}");
        assert_eq!(
            s.unfinished(),
            (xs.len() - finite.len()) as u64,
            "seed {seed}"
        );
        if finite.is_empty() {
            assert!(s.min().is_nan() && s.max().is_nan(), "seed {seed}");
            continue;
        }
        let sum: f64 = finite.iter().sum();
        let rel = sum.abs().max(1.0);
        assert!(
            (s.sum() - sum).abs() <= 1e-9 * rel,
            "seed {seed}: sum {} vs {sum}",
            s.sum()
        );
        assert!(
            (s.mean() - stats::mean(&finite)).abs() <= 1e-9 * s.mean().abs().max(1.0),
            "seed {seed}: mean {} vs {}",
            s.mean(),
            stats::mean(&finite)
        );
        let lo = finite.iter().copied().fold(f64::INFINITY, f64::min);
        let hi = finite.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(s.min().to_bits(), lo.to_bits(), "seed {seed}");
        assert_eq!(s.max().to_bits(), hi.to_bits(), "seed {seed}");
    }
}

#[test]
fn prop_merge_of_any_split_matches_the_single_stream() {
    for seed in SEEDS {
        let mut rng = Rng::new(0xF30_0 + seed);
        let xs = random_series(&mut rng);
        let whole = FlowStats::from_flowtimes(&xs);
        // split at a random point into 1-3 chunks and merge
        let mut merged = FlowStats::new();
        let mut rest: &[f64] = &xs;
        while !rest.is_empty() {
            let take = rng.range_usize(1, rest.len() + 1).min(rest.len());
            merged.merge(&FlowStats::from_flowtimes(&rest[..take]));
            rest = &rest[take..];
        }
        assert_eq!(merged.finished(), whole.finished(), "seed {seed}");
        assert_eq!(merged.total(), whole.total(), "seed {seed}");
        // histograms add exactly → every quantile is bit-identical
        for q in QS {
            assert_eq!(
                merged.quantile(q).to_bits(),
                whole.quantile(q).to_bits(),
                "seed {seed} q={q}: merged quantile moved"
            );
        }
        // moments pool via Chan's update: equal within fp tolerance
        assert!(
            (merged.mean() - whole.mean()).abs() <= 1e-9 * whole.mean().abs().max(1.0),
            "seed {seed}: merged mean {} vs {}",
            merged.mean(),
            whole.mean()
        );
    }
}

#[test]
fn prop_quantiles_are_order_free() {
    for seed in SEEDS {
        let mut rng = Rng::new(0xF40_0 + seed);
        let xs = random_series(&mut rng);
        let fwd = FlowStats::from_flowtimes(&xs);
        let rev: Vec<f64> = xs.iter().rev().copied().collect();
        let bwd = FlowStats::from_flowtimes(&rev);
        for q in QS {
            assert_eq!(
                fwd.quantile(q).to_bits(),
                bwd.quantile(q).to_bits(),
                "seed {seed} q={q}: feed order moved a quantile bit"
            );
        }
        assert_eq!(fwd.finished(), bwd.finished(), "seed {seed}");
    }
}
