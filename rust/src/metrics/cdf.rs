//! Empirical CDF series — the paper plots CDFs of job flowtimes (Fig 3/5)
//! and of per-job flowtime *reduction ratios* relative to Flutter (Fig 5
//! b/d/f).

use crate::util::stats;

/// An empirical CDF that can be sampled at fixed points for plotting.
#[derive(Clone, Debug)]
pub struct Cdf {
    sorted: Vec<f64>,
}

impl Cdf {
    pub fn new(samples: &[f64]) -> Cdf {
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Cdf { sorted }
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    /// P(X <= x).
    pub fn at(&self, x: f64) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        // binary search for upper bound
        let mut lo = 0usize;
        let mut hi = self.sorted.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.sorted[mid] <= x {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as f64 / self.sorted.len() as f64
    }

    /// Inverse CDF (quantile).
    pub fn quantile(&self, q: f64) -> f64 {
        stats::quantile_sorted(&self.sorted, q)
    }

    /// Evaluate at `n` evenly spaced points over [lo, hi] — a plot series.
    pub fn series(&self, lo: f64, hi: f64, n: usize) -> Vec<(f64, f64)> {
        assert!(n >= 2 && hi > lo);
        let step = (hi - lo) / (n - 1) as f64;
        (0..n)
            .map(|i| {
                let x = lo + step * i as f64;
                (x, self.at(x))
            })
            .collect()
    }

    /// Restrict to samples inside [lo, hi] — Fig 3a/3b plot conditional
    /// CDFs ("jobs with <500 s flowtime", "jobs with >300 s").
    pub fn restricted(&self, lo: f64, hi: f64) -> Cdf {
        Cdf {
            sorted: self
                .sorted
                .iter()
                .copied()
                .filter(|&x| x >= lo && x <= hi)
                .collect(),
        }
    }
}

/// Per-job flowtime reduction ratio vs a reference run:
/// `(ref_i - x_i) / ref_i` — positive when `x` is faster (Fig 5 b/d/f).
/// Jobs unfinished in either run are skipped.
pub fn reduction_ratios(reference: &[f64], xs: &[f64]) -> Vec<f64> {
    assert_eq!(reference.len(), xs.len(), "job sets must match");
    reference
        .iter()
        .zip(xs)
        .filter(|(r, x)| r.is_finite() && x.is_finite() && **r > 0.0)
        .map(|(r, x)| (r - x) / r)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdf_basic() {
        let c = Cdf::new(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.at(0.0), 0.0);
        assert_eq!(c.at(2.0), 0.5);
        assert_eq!(c.at(10.0), 1.0);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn cdf_skips_nan() {
        let c = Cdf::new(&[1.0, f64::NAN, 3.0]);
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn series_monotone() {
        let c = Cdf::new(&[5.0, 10.0, 20.0, 40.0]);
        let s = c.series(0.0, 50.0, 11);
        for w in s.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(s[0].1, 0.0);
        assert_eq!(s[10].1, 1.0);
    }

    #[test]
    fn restricted_window() {
        let c = Cdf::new(&[100.0, 250.0, 600.0]);
        let r = c.restricted(0.0, 500.0);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn reduction_ratio_math() {
        let base = [100.0, 200.0, f64::NAN];
        let fast = [50.0, 100.0, 10.0];
        let r = reduction_ratios(&base, &fast);
        assert_eq!(r, vec![0.5, 0.5]);
        // slower job -> negative reduction (Dolly's "63.4% of jobs longer")
        let slow = [150.0, 100.0, f64::NAN];
        let r = reduction_ratios(&base, &slow);
        assert_eq!(r[0], -0.5);
    }

    #[test]
    fn quantile_inverse() {
        let c = Cdf::new(&[10.0, 20.0, 30.0]);
        assert_eq!(c.quantile(0.0), 10.0);
        assert_eq!(c.quantile(1.0), 30.0);
    }
}
