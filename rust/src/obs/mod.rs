//! Zero-perturbation telemetry: deterministic counters, wall-clock spans,
//! and the insurance decision trace.
//!
//! # The two-plane contract
//!
//! Everything this module records lives on exactly one of two planes, and
//! the planes never mix:
//!
//! * **Plane A — deterministic counters** ([`Counters`]). Plain `u64`
//!   event counts bumped on the simulation's *logical* timeline: insurer
//!   rounds, rows scored, admissions and rejections by reason, copies
//!   won/killed/wasted, insurance slots spent vs flowtime slots saved,
//!   engine events by type, slots skipped, shard merges. Counting touches
//!   **no RNG and no clock**, so the numbers are a pure function of
//!   (workload, seed, time model) — bit-identical at any
//!   `score_threads` × `engine_threads` combination. Plane-A data **may
//!   appear in equality-checked output**: it participates in
//!   `CellResult` equality and in `to_json_deterministic()`.
//!
//! * **Plane B — wall-clock spans** ([`Spans`]). Nanosecond timings of
//!   real work (per-round scheduling latency, per-shard advance time,
//!   barrier wait, scorer batch fill/exec) folded into lock-free log2
//!   bucket histograms. Plane-B data is **quarantined exactly like
//!   `wall_secs`**: it must never enter equality checks or the
//!   deterministic JSON variant, only human-facing / non-deterministic
//!   sections (`telemetry_wall` in `pingan simulate --json`, the
//!   `include_wall` sweep JSON).
//!
//! The rule for adding a metric: if reading a clock (or anything else
//! non-reproducible) is needed to produce it, it is Plane B. If it can
//! be bumped from logical state alone, it is Plane A. Nothing in this
//! module draws from any RNG stream, so instrumented and
//! un-instrumented runs make identical decisions ("zero perturbation").
//!
//! [`TraceSink`] is the third surface: an opt-in JSONL stream of
//! per-decision records (`--trace-file`). It only *observes* Plane-A
//! state, so enabling it cannot perturb the Action stream either — the
//! end-to-end pins re-run with a sink attached to prove it.

use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::util::jsonout::Json;

/// Plane A: deterministic event counters.
///
/// Every field is a logical-event count — no clocks, no RNG — so a
/// `Counters` value is bit-identical across thread counts and safe to
/// equality-check. `merge` is fieldwise addition (used when the engine
/// folds the policy's counters into its own, and when aggregating).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Counters {
    // --- insurer (PingAn) plane ---
    /// Scoring/admission rounds the insurer ran (one per `run_round`).
    pub insurer_rounds: u64,
    /// (task, candidate) rows pushed through the batched scorer.
    pub rows_scored: u64,
    /// Insurance copies admitted (one per emitted `Launch`).
    pub admissions: u64,
    /// Candidates dropped by the ε rate floor (score threshold).
    pub rej_rate_floor: u64,
    /// Candidates rejected by the resource-saving (budget) test.
    pub rej_saving: u64,
    /// Candidates rejected by the slot ledger (no free slot).
    pub rej_slot: u64,
    /// Candidates rejected by the bandwidth ledger.
    pub rej_bw: u64,
    // --- engine plane ---
    /// Job arrivals admitted into the alive set.
    pub ev_arrivals: u64,
    /// Cluster failures that actually fired (killed ≥ 0 copies).
    pub ev_failures: u64,
    /// Task completions (first copy past its datasize).
    pub ev_completions: u64,
    /// Scheduler invocations (policy epochs worked).
    pub policy_invocations: u64,
    /// Slots the time core skipped without work (idle fast-forward /
    /// event-skip jumps).
    pub slots_skipped: u64,
    /// Shard-merge barriers executed (plant advances joined in shard
    /// order).
    pub shard_merges: u64,
    /// Copies that won their task (one per completion).
    pub copies_won: u64,
    /// Alive copies released un-won at a completion (insurance that
    /// lost the race).
    pub copies_wasted: u64,
    /// Copies killed by cluster failures.
    pub copies_killed: u64,
    /// Slot-time (in slots) spent by non-winning copies: the premium.
    pub insurance_slots_spent: u64,
    /// Slots of flowtime saved when a later-launched copy beat the
    /// earliest one: the payout.
    pub flowtime_slots_saved: u64,
    /// Copy rate changes applied by the fair-share solver at the policy-
    /// epoch barrier (shared bandwidth model; 0 under `constant`). How
    /// much contention churn the policy's copy placement induces.
    pub rate_changes: u64,
    /// Tasks whose predicted completion was invalidated (epoch-bumped and
    /// re-queued) by a barrier re-rate — event-skip core only; the dense
    /// core re-checks completions every slot, so it has no predictions to
    /// invalidate and keeps this at 0.
    pub rerate_invalidations: u64,
}

macro_rules! for_each_counter {
    ($self:ident, $other:ident, $f:expr) => {{
        let mut f = $f;
        f(&mut $self.insurer_rounds, $other.insurer_rounds);
        f(&mut $self.rows_scored, $other.rows_scored);
        f(&mut $self.admissions, $other.admissions);
        f(&mut $self.rej_rate_floor, $other.rej_rate_floor);
        f(&mut $self.rej_saving, $other.rej_saving);
        f(&mut $self.rej_slot, $other.rej_slot);
        f(&mut $self.rej_bw, $other.rej_bw);
        f(&mut $self.ev_arrivals, $other.ev_arrivals);
        f(&mut $self.ev_failures, $other.ev_failures);
        f(&mut $self.ev_completions, $other.ev_completions);
        f(&mut $self.policy_invocations, $other.policy_invocations);
        f(&mut $self.slots_skipped, $other.slots_skipped);
        f(&mut $self.shard_merges, $other.shard_merges);
        f(&mut $self.copies_won, $other.copies_won);
        f(&mut $self.copies_wasted, $other.copies_wasted);
        f(&mut $self.copies_killed, $other.copies_killed);
        f(&mut $self.insurance_slots_spent, $other.insurance_slots_spent);
        f(&mut $self.flowtime_slots_saved, $other.flowtime_slots_saved);
        f(&mut $self.rate_changes, $other.rate_changes);
        f(&mut $self.rerate_invalidations, $other.rerate_invalidations);
    }};
}

impl Counters {
    /// Fieldwise `self += other`.
    pub fn merge(&mut self, other: &Counters) {
        for_each_counter!(self, other, |a: &mut u64, b: u64| *a += b);
    }

    /// Total rejections across all four reasons.
    pub fn rejections(&self) -> u64 {
        self.rej_rate_floor + self.rej_saving + self.rej_slot + self.rej_bw
    }

    /// Stable `(name, value)` view, in declaration order. Drives both
    /// JSON emission and the CSV columns so they can never disagree.
    pub fn fields(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("insurer_rounds", self.insurer_rounds),
            ("rows_scored", self.rows_scored),
            ("admissions", self.admissions),
            ("rej_rate_floor", self.rej_rate_floor),
            ("rej_saving", self.rej_saving),
            ("rej_slot", self.rej_slot),
            ("rej_bw", self.rej_bw),
            ("ev_arrivals", self.ev_arrivals),
            ("ev_failures", self.ev_failures),
            ("ev_completions", self.ev_completions),
            ("policy_invocations", self.policy_invocations),
            ("slots_skipped", self.slots_skipped),
            ("shard_merges", self.shard_merges),
            ("copies_won", self.copies_won),
            ("copies_wasted", self.copies_wasted),
            ("copies_killed", self.copies_killed),
            ("insurance_slots_spent", self.insurance_slots_spent),
            ("flowtime_slots_saved", self.flowtime_slots_saved),
            ("rate_changes", self.rate_changes),
            ("rerate_invalidations", self.rerate_invalidations),
        ]
    }

    /// Plane-A JSON: a flat object, keys in declaration order (the
    /// `Json` emitter sorts keys anyway, so bytes are deterministic).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for (name, v) in self.fields() {
            j.set(name, Json::num(v as f64));
        }
        j
    }
}

/// Shared live mirror of a [`Counters`] value, for concurrent readers.
///
/// `pingan serve` answers `/stats` from another thread while the engine
/// runs; the engine republishes its merged Plane-A counters into the
/// cell at every policy epoch ([`publish`](CountersCell::publish)) and a
/// reader reconstructs a plain [`Counters`] at any moment with
/// [`load`](CountersCell::load). One atomic slot per counter field, in
/// [`Counters::fields`] order; `Relaxed` everywhere — a reader may see a
/// mid-epoch mix of old and new fields, which is fine for monitoring
/// output (the cell never feeds back into the simulation, so Plane-A
/// determinism is untouched).
pub struct CountersCell {
    slots: Vec<AtomicU64>,
}

impl CountersCell {
    pub fn new() -> CountersCell {
        let n = Counters::default().fields().len();
        CountersCell {
            slots: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Overwrite every slot from `c` (writer side: the engine).
    pub fn publish(&self, c: &Counters) {
        for (i, (_, v)) in c.fields().into_iter().enumerate() {
            self.slots[i].store(v, Ordering::Relaxed);
        }
    }

    /// Reconstruct the last published [`Counters`] (reader side).
    pub fn load(&self) -> Counters {
        let mut c = Counters::default();
        let zero = Counters::default();
        let mut i = 0usize;
        for_each_counter!(c, zero, |a: &mut u64, _b: u64| {
            *a = self.slots[i].load(Ordering::Relaxed);
            i += 1;
        });
        c
    }
}

impl Default for CountersCell {
    fn default() -> Self {
        CountersCell::new()
    }
}

/// Wall-span kinds. One histogram per kind inside [`Spans`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// One `Scheduler::schedule` call (decision latency — the metric
    /// `pingan serve` will report as rounds/sec + p50/p99).
    Sched = 0,
    /// One shard's plant advance inside the merge barrier.
    ShardAdvance = 1,
    /// Whole-barrier time minus the slowest shard: time spent waiting.
    BarrierWait = 2,
    /// Building a round's `ScoreBatch` rows (fill).
    BatchFill = 3,
    /// Executing the batch through the scorer backend (exec).
    BatchExec = 4,
}

impl SpanKind {
    pub const ALL: [SpanKind; 5] = [
        SpanKind::Sched,
        SpanKind::ShardAdvance,
        SpanKind::BarrierWait,
        SpanKind::BatchFill,
        SpanKind::BatchExec,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Sched => "sched",
            SpanKind::ShardAdvance => "shard_advance",
            SpanKind::BarrierWait => "barrier_wait",
            SpanKind::BatchFill => "batch_fill",
            SpanKind::BatchExec => "batch_exec",
        }
    }
}

const N_KINDS: usize = 5;
/// log2-ns buckets; bucket 47 holds everything ≥ 2^46 ns (~19.5 h).
const N_BUCKETS: usize = 48;

/// Plane B: lock-free wall-clock span histograms.
///
/// Interior-mutable (`AtomicU64`, `Relaxed`) so shard threads can record
/// through a shared `&Spans` without coordination; recording order never
/// matters because only bucket *counts* are kept. Everything derived
/// from this type is non-deterministic by construction and must stay
/// out of equality-checked output — see the module docs.
pub struct Spans {
    buckets: [[AtomicU64; N_BUCKETS]; N_KINDS],
    total_ns: [AtomicU64; N_KINDS],
    max_ns: [AtomicU64; N_KINDS],
}

impl Spans {
    pub fn new() -> Self {
        Spans {
            buckets: std::array::from_fn(|_| std::array::from_fn(|_| AtomicU64::new(0))),
            total_ns: std::array::from_fn(|_| AtomicU64::new(0)),
            max_ns: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Fold one measured duration into `kind`'s histogram.
    pub fn record(&self, kind: SpanKind, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let k = kind as usize;
        let b = (64 - ns.leading_zeros()).min(N_BUCKETS as u32 - 1) as usize;
        self.buckets[k][b].fetch_add(1, Ordering::Relaxed);
        self.total_ns[k].fetch_add(ns, Ordering::Relaxed);
        self.max_ns[k].fetch_max(ns, Ordering::Relaxed);
    }

    /// Freeze the histograms into plain numbers (percentiles are
    /// bucket-interpolated, i.e. accurate to roughly a factor of √2).
    pub fn snapshot(&self) -> SpansSnapshot {
        let mut rows = Vec::with_capacity(N_KINDS);
        for kind in SpanKind::ALL {
            let k = kind as usize;
            let counts: Vec<u64> = self.buckets[k]
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect();
            let count: u64 = counts.iter().sum();
            let max_ns = self.max_ns[k].load(Ordering::Relaxed);
            let pct = |q: f64| -> f64 {
                if count == 0 {
                    return 0.0;
                }
                let target = ((q * count as f64).ceil() as u64).max(1);
                let mut seen = 0u64;
                for (b, &c) in counts.iter().enumerate() {
                    seen += c;
                    if seen >= target {
                        // midpoint of [2^(b-1), 2^b), clamped by the max
                        let mid = if b == 0 { 0.0 } else { 1.5 * f64::powi(2.0, b as i32 - 1) };
                        return mid.min(max_ns as f64) / 1e9;
                    }
                }
                max_ns as f64 / 1e9
            };
            rows.push(SpanStats {
                kind: kind.name(),
                count,
                total_secs: self.total_ns[k].load(Ordering::Relaxed) as f64 / 1e9,
                p50_secs: pct(0.50),
                p99_secs: pct(0.99),
                max_secs: max_ns as f64 / 1e9,
            });
        }
        SpansSnapshot { rows }
    }
}

impl Default for Spans {
    fn default() -> Self {
        Spans::new()
    }
}

/// One frozen span histogram (Plane B — never equality-checked).
#[derive(Clone, Debug, Default)]
pub struct SpanStats {
    pub kind: &'static str,
    pub count: u64,
    pub total_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
    pub max_secs: f64,
}

/// Frozen Plane-B snapshot: one [`SpanStats`] row per [`SpanKind`].
#[derive(Clone, Debug, Default)]
pub struct SpansSnapshot {
    pub rows: Vec<SpanStats>,
}

impl SpansSnapshot {
    pub fn get(&self, kind: SpanKind) -> Option<&SpanStats> {
        self.rows.iter().find(|r| r.kind == kind.name())
    }

    /// Plane-B JSON. Must only ever be placed in non-deterministic
    /// sections (`telemetry_wall`, `include_wall` sweep output).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for r in &self.rows {
            let mut row = Json::obj();
            row.set("count", Json::num(r.count as f64))
                .set("total_secs", Json::num(r.total_secs))
                .set("p50_secs", Json::num(r.p50_secs))
                .set("p99_secs", Json::num(r.p99_secs))
                .set("max_secs", Json::num(r.max_secs));
            j.set(r.kind, row);
        }
        j
    }
}

/// `Write` adapter over a shared byte buffer (for in-memory trace
/// capture in tests).
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// Opt-in JSONL stream of per-decision records (`--trace-file`).
///
/// Cloneable and `Send` — one sink can be shared by every cell of a
/// sweep (lines interleave whole, never torn, because each `emit` holds
/// the lock for exactly one line). Emitting only *reads* Plane-A state,
/// so an attached sink cannot change any decision.
#[derive(Clone)]
pub struct TraceSink {
    inner: Arc<Mutex<Box<dyn Write + Send>>>,
}

impl TraceSink {
    /// Trace to a file (created/truncated), buffered.
    pub fn to_file(path: &str) -> std::io::Result<TraceSink> {
        let f = std::fs::File::create(path)?;
        Ok(TraceSink {
            inner: Arc::new(Mutex::new(Box::new(std::io::BufWriter::new(f)))),
        })
    }

    /// Trace into memory; the returned buffer can be inspected after
    /// the run (tests use this to assert on the stream).
    pub fn in_memory() -> (TraceSink, Arc<Mutex<Vec<u8>>>) {
        let buf = Arc::new(Mutex::new(Vec::new()));
        let sink = TraceSink {
            inner: Arc::new(Mutex::new(Box::new(SharedBuf(buf.clone())))),
        };
        (sink, buf)
    }

    /// Write one record as a single JSONL line.
    pub fn emit(&self, record: &Json) {
        let mut w = self.inner.lock().unwrap();
        let _ = writeln!(w, "{}", record.to_string());
    }

    /// Flush buffered lines (call once at end of run).
    pub fn flush(&self) {
        let _ = self.inner.lock().unwrap().flush();
    }
}

/// One per-decision trace record, flattened to JSON by [`TraceSink`].
/// `reason` ∈ {`rate-floor`, `saving`, `slot`, `bw`, `admit`}.
pub struct TraceRecord<'a> {
    pub slot: u64,
    pub job: usize,
    pub task: usize,
    pub cluster: usize,
    pub solo_rate: f64,
    pub rate: f64,
    pub pro: f64,
    pub reason: &'a str,
}

impl TraceRecord<'_> {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("slot", Json::num(self.slot as f64))
            .set("job", Json::num(self.job as f64))
            .set("task", Json::num(self.task as f64))
            .set("cluster", Json::num(self.cluster as f64))
            .set("solo_rate", Json::num(self.solo_rate))
            .set("rate", Json::num(self.rate))
            .set("pro", Json::num(self.pro))
            .set("reason", Json::str(self.reason));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_merge_is_fieldwise_addition() {
        let mut a = Counters {
            admissions: 2,
            rej_bw: 1,
            ..Counters::default()
        };
        let b = Counters {
            admissions: 3,
            copies_won: 7,
            ..Counters::default()
        };
        a.merge(&b);
        assert_eq!(a.admissions, 5);
        assert_eq!(a.rej_bw, 1);
        assert_eq!(a.copies_won, 7);
        assert_eq!(a.rejections(), 1);
    }

    #[test]
    fn counters_fields_cover_every_counter_once() {
        let fields = Counters::default().fields();
        assert_eq!(fields.len(), 20);
        let mut names: Vec<_> = fields.iter().map(|(n, _)| *n).collect();
        names.dedup();
        assert_eq!(names.len(), 20, "duplicate counter name");
        // fields() reads the same values to_json writes
        let c = Counters {
            insurer_rounds: 4,
            flowtime_slots_saved: 9,
            ..Counters::default()
        };
        let j = c.to_json().to_string();
        assert!(j.contains("\"insurer_rounds\":4"));
        assert!(j.contains("\"flowtime_slots_saved\":9"));
    }

    #[test]
    fn counters_cell_roundtrips_every_field() {
        // publish → load must be the identity on all 20 fields (the cell
        // stores in fields() order and loads in macro order — this test
        // is the guard that the two orders agree)
        let mut c = Counters::default();
        for (i, (_, _)) in Counters::default().fields().into_iter().enumerate() {
            // give every field a distinct value via merge of a one-hot
            let mut one = Counters::default();
            let mut j = 0usize;
            let zero = Counters::default();
            for_each_counter!(one, zero, |a: &mut u64, _b: u64| {
                if j == i {
                    *a = (i as u64 + 1) * 10;
                }
                j += 1;
            });
            c.merge(&one);
        }
        let cell = CountersCell::new();
        cell.publish(&c);
        assert_eq!(cell.load(), c);
        assert_eq!(cell.load().fields(), c.fields());
        // republish overwrites rather than accumulates
        cell.publish(&c);
        assert_eq!(cell.load(), c);
    }

    #[test]
    fn spans_snapshot_orders_percentiles() {
        let s = Spans::new();
        for us in [1u64, 2, 4, 8, 1000] {
            s.record(SpanKind::Sched, Duration::from_micros(us));
        }
        let snap = s.snapshot();
        let row = snap.get(SpanKind::Sched).unwrap();
        assert_eq!(row.count, 5);
        assert!(row.total_secs > 0.0);
        assert!(row.p50_secs <= row.p99_secs);
        assert!(row.p99_secs <= row.max_secs + 1e-12);
        assert_eq!(snap.get(SpanKind::BatchExec).unwrap().count, 0);
    }

    #[test]
    fn trace_sink_emits_one_line_per_record() {
        let (sink, buf) = TraceSink::in_memory();
        for reason in ["rate-floor", "admit"] {
            sink.emit(
                &TraceRecord {
                    slot: 3,
                    job: 1,
                    task: 0,
                    cluster: 2,
                    solo_rate: 0.5,
                    rate: 0.75,
                    pro: 0.9,
                    reason,
                }
                .to_json(),
            );
        }
        sink.flush();
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains("\"reason\":\"rate-floor\""));
        assert!(lines[1].contains("\"reason\":\"admit\""));
    }
}
