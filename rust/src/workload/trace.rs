//! Arrival-trace workload source (`pingan replay --trace <file>`).
//!
//! Parses an Azure-Functions-style arrival trace — one job per line, CSV
//! or JSONL — into a [`WorkloadSource`] that streams [`JobSpec`]s without
//! ever materializing the whole trace. The trace supplies *when* jobs
//! arrive (and optionally how big they are); the Montage DAG generator
//! supplies each job's internal shape, seeded deterministically per job
//! id so replays are bit-reproducible regardless of how the file is
//! chunked or how far a truncated run got.
//!
//! ## File format
//!
//! Blank lines and lines starting with `#` are skipped. The first data
//! line picks the dialect:
//!
//! * **CSV** — a header row naming columns, then one row per job.
//!   Required column: `arrival` (u64 slot). Optional: `tasks` (task
//!   count; drawn from the Facebook size mix when absent), `datasize`
//!   (per-job total MB, overriding the spec's range), `name`.
//!
//!   ```text
//!   # slots are 1s; trace covers 10 minutes
//!   arrival,tasks,datasize,name
//!   0,40,800,etl-hourly
//!   12,,,adhoc
//!   ```
//!
//!   Empty fields fall back to the generator. Comments are whole-line
//!   only (`#` must be the first non-blank character).
//!
//! * **JSONL** — first data line starts with `{`; one JSON object per
//!   line with the same keys: `{"arrival": 12, "tasks": 40,
//!   "datasize": 800.0, "name": "etl"}`. This is also the `pingan serve`
//!   submission wire format ([`parse_jsonl_row`]).
//!
//! ## Error discipline
//!
//! Arrivals must be nondecreasing (the [`WorkloadSource`] ordering
//! contract). Every malformed-input condition — bad header, bad field,
//! bad JSON, unsorted arrivals, a mid-read I/O error — surfaces as a
//! [`TraceError`] from the fallible API ([`TraceSource::try_next_job`],
//! [`parse_jsonl_row`]). The [`WorkloadSource`] impl used by
//! `pingan replay` panics with the error's exact message — a broken
//! trace should abort a batch replay loudly, not silently skew results —
//! while `pingan serve` maps the same error to a per-submission error
//! response and keeps running. The panic text is pinned byte-for-byte by
//! tests below.
//!
//! ## Determinism
//!
//! Job `k`'s DAG is drawn from `Rng::new(splitmix(seed ^ k·φ64))` — a
//! fresh, id-keyed stream per job — so a job's shape depends only on
//! `(seed, id, its own trace row)`, never on read order or on how many
//! jobs preceded it. [`JobBuilder`] owns that materialization step and is
//! shared by the file reader and the live `serve` intake.

use std::fmt;
use std::fs::File;
use std::io::{self, BufRead, BufReader};

use super::job::JobSpec;
use super::montage;
use super::source::WorkloadSource;
use crate::config::spec::WorkloadSpec;
use crate::util::jsonout::Json;
use crate::util::rng::{Rng, SplitMix64};

const PHI64: u64 = 0x9E37_79B9_7F4A_7C15;

/// A malformed-trace condition: one human-readable message carrying the
/// line number, formatted exactly like the panic text the replay path
/// aborts with (so wrapping it with `panic!("{err}")` is byte-identical
/// to the historical behavior).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceError {
    msg: String,
}

impl TraceError {
    fn new(msg: String) -> TraceError {
        TraceError { msg }
    }

    /// The full message (what `Display` prints).
    pub fn message(&self) -> &str {
        &self.msg
    }
}

impl fmt::Display for TraceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for TraceError {}

#[derive(Clone, Copy, PartialEq)]
enum Dialect {
    /// Not yet determined (no data line seen).
    Unknown,
    Csv,
    Jsonl,
}

/// Column layout of a CSV trace (indices into the split row).
struct CsvCols {
    arrival: usize,
    tasks: Option<usize>,
    datasize: Option<usize>,
    name: Option<usize>,
    width: usize,
}

/// One parsed trace row, dialect-independent. Public because the
/// `serve` intake parses rows off the wire ([`parse_jsonl_row`]) and
/// materializes them itself through a [`JobBuilder`].
pub struct Row {
    pub arrival: u64,
    pub tasks: Option<usize>,
    pub datasize: Option<f64>,
    pub name: Option<String>,
}

/// Id-keyed job materializer: turns parsed [`Row`]s into full Montage
/// DAG jobs. Job `k`'s RNG stream depends only on `(seed, k)`, so the
/// DAG a row produces is independent of what was submitted before it —
/// the property that makes truncated replays and live submissions
/// reproducible. Shared by [`TraceSource`] and the `pingan serve` intake.
pub struct JobBuilder {
    /// Shape parameters for the generated DAG bodies (size mix, datasize
    /// range for rows without an override).
    spec: WorkloadSpec,
    sites: Vec<usize>,
    seed: u64,
    next_id: usize,
}

impl JobBuilder {
    /// `spec` shapes the generated DAGs; `sites` are the clusters raw
    /// inputs scatter over; `seed` keys the per-job RNG streams.
    pub fn new(spec: WorkloadSpec, sites: Vec<usize>, seed: u64) -> JobBuilder {
        assert!(!sites.is_empty(), "need input sites");
        JobBuilder {
            spec,
            sites,
            seed,
            next_id: 0,
        }
    }

    /// Jobs materialized so far (the next job's id).
    pub fn next_id(&self) -> usize {
        self.next_id
    }

    /// Materialize one row into a full DAG job with an id-keyed RNG.
    pub fn build(&mut self, row: Row) -> JobSpec {
        let id = self.next_id;
        self.next_id += 1;
        let mut rng =
            Rng::new(SplitMix64::new(self.seed ^ (id as u64).wrapping_mul(PHI64)).next_u64());
        let n_tasks = row
            .tasks
            .unwrap_or_else(|| montage::draw_size(&self.spec, &mut rng));
        let spec = match row.datasize {
            // pin the job's total datasize: montage_dag draws from
            // (lo, hi), so a degenerate range fixes the draw
            Some(d) => {
                let mut s = self.spec.clone();
                s.datasize = (d, d);
                s
            }
            None => self.spec.clone(),
        };
        let mut job = montage::montage_dag(id, row.arrival, n_tasks, &spec, &self.sites, &mut rng);
        if let Some(name) = row.name {
            job.name = name;
        }
        debug_assert!(job.validate().is_ok());
        job
    }
}

/// Parse one JSONL object row (`{"arrival": 12, "tasks": 40, ...}`).
/// `line_no` only shapes the error message. This is the single row
/// grammar shared by JSONL trace files and `pingan serve` submissions.
pub fn parse_jsonl_row(line: &str, line_no: usize) -> Result<Row, TraceError> {
    let v = Json::parse(line)
        .map_err(|e| TraceError::new(format!("trace: line {line_no}: bad JSON: {e}")))?;
    let num = |k: &str| v.get(k).and_then(|x| x.as_num());
    let arrival = num("arrival").ok_or_else(|| {
        TraceError::new(format!(
            "trace: line {line_no}: JSONL object needs a numeric `arrival`"
        ))
    })? as u64;
    Ok(Row {
        arrival,
        tasks: num("tasks").map(|t| t as usize),
        datasize: num("datasize"),
        name: v
            .get("name")
            .and_then(|x| x.as_str())
            .map(|s| s.to_string()),
    })
}

/// Streaming trace reader: one `BufRead` line cursor plus O(1) parser
/// state — resident size is independent of trace length.
pub struct TraceSource {
    reader: Box<dyn BufRead>,
    builder: JobBuilder,
    dialect: Dialect,
    cols: Option<CsvCols>,
    line_no: usize,
    last_arrival: u64,
}

impl TraceSource {
    /// Open a trace file. `spec` shapes the generated DAGs; `sites` are
    /// the clusters raw inputs scatter over; `seed` keys the per-job RNG
    /// streams.
    pub fn open(
        path: &str,
        spec: WorkloadSpec,
        sites: Vec<usize>,
        seed: u64,
    ) -> io::Result<TraceSource> {
        let f = File::open(path)?;
        Ok(TraceSource::from_reader(
            Box::new(BufReader::new(f)),
            spec,
            sites,
            seed,
        ))
    }

    /// Build from any line source (tests use `io::Cursor`).
    pub fn from_reader(
        reader: Box<dyn BufRead>,
        spec: WorkloadSpec,
        sites: Vec<usize>,
        seed: u64,
    ) -> TraceSource {
        TraceSource {
            reader,
            builder: JobBuilder::new(spec, sites, seed),
            dialect: Dialect::Unknown,
            cols: None,
            line_no: 0,
            last_arrival: 0,
        }
    }

    /// Next meaningful line (skipping blanks and `#` comments), or
    /// `Ok(None)` at EOF. A mid-read I/O error — a vanishing trace file —
    /// is a [`TraceError`] like any malformed row.
    fn next_line(&mut self) -> Result<Option<String>, TraceError> {
        loop {
            let mut buf = String::new();
            let n = self.reader.read_line(&mut buf).map_err(|e| {
                TraceError::new(format!("trace: read error at line {}: {e}", self.line_no + 1))
            })?;
            if n == 0 {
                return Ok(None);
            }
            self.line_no += 1;
            let t = buf.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            return Ok(Some(t.to_string()));
        }
    }

    fn parse_csv_header(&mut self, line: &str) -> Result<(), TraceError> {
        let names: Vec<String> = line
            .split(',')
            .map(|s| s.trim().to_ascii_lowercase())
            .collect();
        let find = |k: &str| names.iter().position(|n| n == k);
        let arrival = find("arrival").ok_or_else(|| {
            TraceError::new(format!(
                "trace: line {}: CSV header must name an `arrival` column (got `{line}`)",
                self.line_no
            ))
        })?;
        self.cols = Some(CsvCols {
            arrival,
            tasks: find("tasks"),
            datasize: find("datasize"),
            name: find("name"),
            width: names.len(),
        });
        Ok(())
    }

    fn parse_csv_row(&self, line: &str) -> Result<Row, TraceError> {
        let cols = self.cols.as_ref().expect("header parsed first");
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if fields.len() > cols.width {
            return Err(TraceError::new(format!(
                "trace: line {}: {} fields but header has {}",
                self.line_no,
                fields.len(),
                cols.width
            )));
        }
        let get = |i: usize| -> Option<&str> {
            fields
                .get(i)
                .copied()
                .filter(|s| !s.is_empty())
                .map(|s| s.trim_matches('"'))
        };
        let arrival = get(cols.arrival)
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| {
                TraceError::new(format!(
                    "trace: line {}: bad or missing arrival in `{line}`",
                    self.line_no
                ))
            })?;
        let parse_num = |s: &str, what: &str| -> Result<f64, TraceError> {
            s.parse::<f64>().map_err(|_| {
                TraceError::new(format!("trace: line {}: bad {what} `{s}`", self.line_no))
            })
        };
        Ok(Row {
            arrival,
            tasks: cols
                .tasks
                .and_then(get)
                .map(|s| parse_num(s, "tasks"))
                .transpose()?
                .map(|t| t as usize),
            datasize: cols
                .datasize
                .and_then(get)
                .map(|s| parse_num(s, "datasize"))
                .transpose()?,
            name: cols.name.and_then(get).map(|s| s.to_string()),
        })
    }

    /// Fallible pull: the next job, `Ok(None)` at EOF, or a
    /// [`TraceError`] on any malformed row. The [`WorkloadSource`] impl
    /// wraps this with the batch path's loud panic; callers that must
    /// survive bad input (`pingan serve`) use this directly.
    pub fn try_next_job(&mut self) -> Result<Option<JobSpec>, TraceError> {
        let Some(line) = self.next_line()? else {
            return Ok(None);
        };
        let row = match self.dialect {
            Dialect::Unknown => {
                if line.starts_with('{') {
                    self.dialect = Dialect::Jsonl;
                    parse_jsonl_row(&line, self.line_no)?
                } else {
                    self.dialect = Dialect::Csv;
                    self.parse_csv_header(&line)?;
                    let Some(data) = self.next_line()? else {
                        return Ok(None);
                    };
                    self.parse_csv_row(&data)?
                }
            }
            Dialect::Csv => self.parse_csv_row(&line)?,
            Dialect::Jsonl => parse_jsonl_row(&line, self.line_no)?,
        };
        if row.arrival < self.last_arrival {
            return Err(TraceError::new(format!(
                "trace: line {}: arrival {} goes backwards (previous {}) — traces must be sorted",
                self.line_no, row.arrival, self.last_arrival
            )));
        }
        self.last_arrival = row.arrival;
        Ok(Some(self.builder.build(row)))
    }
}

impl WorkloadSource for TraceSource {
    /// The batch-replay pull: panics on malformed input with the
    /// [`TraceError`] message verbatim (byte-identical to the historical
    /// panic text — pinned by tests).
    fn next_job(&mut self) -> Option<JobSpec> {
        match self.try_next_job() {
            Ok(job) => job,
            Err(e) => panic!("{e}"),
        }
    }

    /// Traces are streamed; the total is unknown until EOF.
    fn hint_total(&self) -> Option<usize> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::source::collect;
    use std::io::Cursor;

    fn src(text: &str) -> TraceSource {
        TraceSource::from_reader(
            Box::new(Cursor::new(text.to_string())),
            WorkloadSpec::scaled(10, 0.07),
            vec![0, 1, 2],
            4242,
        )
    }

    #[test]
    fn csv_with_all_columns() {
        let jobs = collect(&mut src(
            "# a comment\n\narrival,tasks,datasize,name\n0,10,500,etl\n7,20,,\n7,,,adhoc\n",
        ));
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].arrival, 0);
        assert_eq!(jobs[0].n_tasks(), 10);
        assert_eq!(jobs[0].name, "etl");
        // datasize=500 pins the projection layer's total input
        let proj: f64 = jobs[0]
            .tasks
            .iter()
            .filter(|t| t.deps.is_empty())
            .map(|t| t.datasize)
            .sum();
        assert!(proj > 250.0 && proj < 750.0, "proj={proj}");
        assert_eq!(jobs[1].arrival, 7);
        assert_eq!(jobs[1].n_tasks(), 20);
        assert_eq!(jobs[1].name, "montage-1"); // generator default
        assert_eq!(jobs[2].name, "adhoc"); // tasks drawn from mix
        for j in &jobs {
            j.validate().unwrap();
        }
    }

    #[test]
    fn jsonl_dialect() {
        let jobs = collect(&mut src(
            "{\"arrival\": 3, \"tasks\": 5, \"name\": \"a\"}\n{\"arrival\": 9, \"datasize\": 100.0}\n",
        ));
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].arrival, 3);
        assert_eq!(jobs[0].n_tasks(), 5);
        assert_eq!(jobs[0].name, "a");
        assert_eq!(jobs[1].arrival, 9);
    }

    #[test]
    fn hint_total_is_unknown() {
        assert_eq!(src("arrival\n0\n").hint_total(), None);
    }

    #[test]
    fn per_job_seeding_is_read_order_independent() {
        // the same row at the same id yields the same DAG even when the
        // preceding rows change shape (different draws)
        let a = collect(&mut src("arrival,tasks\n0,3\n5,\n9,7\n"));
        let b = collect(&mut src("arrival,tasks\n0,9\n5,\n9,7\n"));
        assert_eq!(a[2].n_tasks(), b[2].n_tasks());
        let da: f64 = a[2].total_datasize();
        let db: f64 = b[2].total_datasize();
        assert_eq!(da.to_bits(), db.to_bits());
        // ...and the middle job (tasks unspecified) is also stable
        assert_eq!(a[1].n_tasks(), b[1].n_tasks());
    }

    #[test]
    fn job_builder_matches_trace_source_materialization() {
        // a TraceSource job and a JobBuilder job built from the same row
        // at the same (seed, id) are the same job
        let jobs = collect(&mut src("{\"arrival\": 3, \"tasks\": 5, \"name\": \"a\"}\n"));
        let mut b = JobBuilder::new(WorkloadSpec::scaled(10, 0.07), vec![0, 1, 2], 4242);
        assert_eq!(b.next_id(), 0);
        let built = b.build(Row {
            arrival: 3,
            tasks: Some(5),
            datasize: None,
            name: Some("a".into()),
        });
        assert_eq!(b.next_id(), 1);
        assert_eq!(built.id, jobs[0].id);
        assert_eq!(built.name, jobs[0].name);
        assert_eq!(built.n_tasks(), jobs[0].n_tasks());
        assert_eq!(
            built.total_datasize().to_bits(),
            jobs[0].total_datasize().to_bits()
        );
    }

    #[test]
    #[should_panic(expected = "goes backwards")]
    fn unsorted_trace_panics() {
        collect(&mut src("arrival\n9\n3\n"));
    }

    #[test]
    #[should_panic(expected = "arrival")]
    fn csv_without_arrival_column_panics() {
        collect(&mut src("tasks,name\n3,x\n"));
    }

    #[test]
    #[should_panic(expected = "bad JSON")]
    fn malformed_jsonl_panics() {
        collect(&mut src("{\"arrival\": 1}\n{nope\n"));
    }

    #[test]
    fn error_messages_are_pinned_byte_for_byte() {
        // the replay path panics with exactly these strings (the
        // WorkloadSource impl forwards the Display text verbatim), so
        // pinning the fallible API pins the abort text too
        let mut s = src("arrival\n9\n3\n");
        assert!(matches!(s.try_next_job(), Ok(Some(_))));
        assert_eq!(
            s.try_next_job().unwrap_err().to_string(),
            "trace: line 3: arrival 3 goes backwards (previous 9) — traces must be sorted"
        );
        assert_eq!(
            src("tasks,name\n3,x\n").try_next_job().unwrap_err().to_string(),
            "trace: line 1: CSV header must name an `arrival` column (got `tasks,name`)"
        );
        assert_eq!(
            src("arrival\nxyz\n").try_next_job().unwrap_err().to_string(),
            "trace: line 2: bad or missing arrival in `xyz`"
        );
        assert_eq!(
            src("arrival,tasks\n0,zz\n").try_next_job().unwrap_err().to_string(),
            "trace: line 2: bad tasks `zz`"
        );
        assert_eq!(
            src("arrival,tasks,datasize\n0,1,huge\n")
                .try_next_job()
                .unwrap_err()
                .to_string(),
            "trace: line 2: bad datasize `huge`"
        );
        assert_eq!(
            src("arrival,tasks\n0,1,9,9\n").try_next_job().unwrap_err().to_string(),
            "trace: line 2: 4 fields but header has 2"
        );
        assert_eq!(
            src("{\"tasks\": 3}\n").try_next_job().unwrap_err().to_string(),
            "trace: line 1: JSONL object needs a numeric `arrival`"
        );
        let e = src("{nope\n").try_next_job().unwrap_err();
        assert!(e.message().starts_with("trace: line 1: bad JSON: "), "{e}");
    }

    #[test]
    fn jsonl_row_parser_is_reusable_standalone() {
        let row = parse_jsonl_row("{\"arrival\": 7, \"datasize\": 12.5}", 42).unwrap();
        assert_eq!(row.arrival, 7);
        assert_eq!(row.tasks, None);
        assert_eq!(row.datasize, Some(12.5));
        assert!(row.name.is_none());
        let e = parse_jsonl_row("not json", 42).unwrap_err();
        assert!(e.to_string().starts_with("trace: line 42: "), "{e}");
    }
}
