//! `pingan` — the launcher.
//!
//! ```text
//! pingan table t1|t2                        regenerate a paper table
//! pingan figure fig2|fig3|fig4|fig5|fig6a|fig6b|fig7   regenerate a figure
//! pingan sweep [axis flags]                 parallel scenario sweep
//! pingan simulate [--scheduler S] [--lambda L] [--epsilon E] [--jobs N]
//! pingan replay (--trace FILE | --synthetic N)         streaming replay
//! pingan serve [--listen ADDR] [--drive TRACE]         live job-intake service
//! pingan testbed  [--jobs N] [--payload-every K]       Sec-5 testbed run
//! pingan validate                            artifact + scorer self-check
//! pingan bench-append <artifact>             append a CI bench entry to BENCH_sim.json
//! ```
//!
//! Common options: `--scale smoke|default|paper`, `--seed`, `--json`,
//! `--log-level SPEC` (also `PINGAN_LOG` / `RUST_LOG`), and — on
//! `simulate`/`sweep` — `--trace-file PATH` for the per-decision
//! insurance JSONL trace.

use pingan::experiments::{figures, tables, Scale};
use pingan::obs::TraceSink;
use pingan::sched::Scheduler;
use pingan::sweep::{Axis, Scenario, SweepSpec, WorkloadMix};
use pingan::util::cli::Args;
use pingan::util::jsonout::Json;

fn main() {
    // parse first so `--log-level` can shape the logger install
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => die(&e),
    };
    if let Err(e) = init_logging(args.get("log-level")) {
        die(&e);
    }
    let result = match args.command.as_deref() {
        Some("table") => cmd_table(&args),
        Some("figure") => cmd_figure(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("replay") => cmd_replay(&args),
        Some("serve") => cmd_serve(&args),
        Some("testbed") => cmd_testbed(&args),
        Some("validate") => cmd_validate(&args),
        Some("bench-append") => cmd_bench_append(&args),
        Some("debug-sim") => cmd_debug_sim(&args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => Err(format!("unknown command `{other}`\n{HELP}")),
    };
    if let Err(e) = result {
        die(&e);
    }
}

const HELP: &str = "\
pingan — insurance-based job acceleration for geo-distributed analytics

USAGE:
  pingan table <t1|t2> [--jobs N] [--clusters N] [--seed S]
  pingan figure <fig2|fig3|fig4|fig5|fig6a|fig6b|fig7> [--scale smoke|default|paper]
  pingan sweep [--schedulers A,B] [--lambdas ..] [--epsilons ..]
               [--cluster-counts ..] [--failure-scales ..] [--mixes ..]
               [--scorer cpu|hlo|scalar] [--time-model dense|event-skip]
               [--time-models A,B] [--score-threads N]
               [--score-thread-counts A,B] [--engine-threads N]
               [--engine-thread-counts A,B] [--bandwidth-model constant|shared]
               [--bandwidth-models A,B] [--threads N] [--reps N]
               [--seed S] [--config FILE] [--csv|--json] [--quiet]
               [--trace-file PATH] [--trace FILE] [--stream-metrics]
  pingan simulate [--scheduler S] [--lambda L] [--epsilon E] [--jobs N] [--clusters N]
                  [--scorer cpu|hlo|scalar] [--time-model dense|event-skip]
                  [--score-threads N] [--engine-threads N]
                  [--bandwidth-model constant|shared] [--json]
                  [--trace-file PATH] [--no-telemetry] [--stream-metrics]
  pingan replay (--trace FILE | --synthetic N) [--scheduler S] [--lambda L]
                [--epsilon E] [--clusters N] [--seed S] [--scale smoke|default|paper]
                [--scorer cpu|hlo|scalar] [--time-model dense|event-skip]
                [--score-threads N] [--engine-threads N]
                [--bandwidth-model constant|shared] [--stream-metrics]
                [--max-slots N] [--json]
  pingan serve [--listen HOST:PORT] [--drive TRACE.jsonl] [--scheduler S]
               [--lambda L] [--epsilon E] [--clusters N] [--seed S]
               [--scale smoke|default|paper] [--scorer cpu|hlo|scalar]
               [--score-threads N] [--engine-threads N]
               [--bandwidth-model constant|shared] [--max-slots N]
  pingan testbed [--jobs N] [--payload-every K]
  pingan validate
  pingan bench-append <artifact.json> [--history FILE] [--dry-run]

Every command accepts `--log-level SPEC` with env_logger-style module
filtering (`warn,pingan::insurance=debug`); the `PINGAN_LOG` then
`RUST_LOG` env vars are consulted when the flag is absent (default:
warn).

`sweep` expands the named axes into a deterministic scenario grid and
runs it on a work-stealing thread pool (--threads 0 = all cores);
results are identical at any thread count. Axis flags take
comma-separated values; --config reads a [sweep] TOML section instead.
Mixes: montage, small-jobs, large-jobs, testbed.

`--scorer` picks the insurer's batched scoring backend: `cpu` (default;
bit-identical to the scalar histogram algebra), `hlo` (compiled XLA
artifact via PJRT — needs `--features pjrt` and `make artifacts`; f32,
so admissions can differ within ~1e-3), or `scalar` (the per-candidate
reference path, for agreement checks).

`--time-model` picks the simulator's time core: `dense` (default; the
slotted reference engine, bit-reproducible) or `event-skip` (jump to the
next arrival/completion/failure/wake event; statistically equivalent
under paired seeds and far cheaper on sparse workloads). The
`events_processed` counter in `--json` output reports how many decision
points the run actually worked through vs `slots` simulated;
`--time-models dense,event-skip` sweeps both as an axis.

`--score-threads` shards the insurer's per-round scoring batch across N
OS threads *inside* each simulation (intra-cell parallelism; it composes
with the sweep runner's `--threads` across cells). Admissions are
bit-identical at any value — the knob only moves wall time — and
`--score-thread-counts 1,4` sweeps it as an axis to prove it. The
default comes from the PINGAN_SCORE_THREADS env var (else 1, serial).

`--engine-threads` shards the simulator's per-cluster plant state
(failure gaps, slot/bandwidth ledgers, congestion chains) across N OS
threads, syncing at a deterministic barrier before every scheduler
invocation. Action streams and results are bit-identical at any value
under both time cores — each cluster owns its own RNG stream, so the
shard partition cannot reorder draws — and `--engine-thread-counts 1,4`
sweeps it as an axis to prove it. The default comes from the
PINGAN_ENGINE_THREADS env var (else 1, serial).

`--bandwidth-model` (simulate, replay, sweep — also the
PINGAN_BANDWIDTH_MODEL env var and the `bandwidth_model` TOML key) picks
the WAN transfer model: `constant` (default; each copy keeps the rate
drawn at launch) or `shared` (active transfers max-min fair-share the
cluster ingress/egress gates and per-pair WAN links, re-rated once per
policy epoch at the barrier — an incremental solver proptest-pinned
bit-identical to the progressive-filling reference). `shared` changes
results (contention can only slow transfers down) but is excluded from
cell seeds so a shared cell and its constant twin face the identical
plant and job set; `--bandwidth-models constant,shared` sweeps both as a
paired axis. Results stay bit-identical at any --engine-threads value in
both models.

`replay` streams a workload through the engine without materializing it:
`--trace FILE` reads an Azure-Functions-style arrival trace (CSV with an
`arrival` header column — optional `tasks`, `datasize`, `name` — or
JSONL objects with the same keys; blank lines and `#` comment lines are
skipped, arrivals must be nondecreasing; see examples/trace_small.csv),
while `--synthetic N` streams N generated Montage jobs, bit-identical to
the batch generator at the same seed. Each trace row's DAG is drawn from
a per-job-id RNG stream, so replays are reproducible regardless of how
far a truncated run got. `--max-slots` bounds the simulated horizon
(unfinished jobs are counted, never fabricated). `sweep` accepts the
same trace via `--trace` (or the `trace` key of a `[sweep]` TOML
section): every cell then replays the file instead of generating jobs.

`serve` is the online half of the online algorithm: a long-lived
service that accepts job submissions over TCP (default 127.0.0.1:7411;
port 0 picks a free port, announced as a `{\"event\":\"serving\"}` stdout
line), admits and places them through the same insurer against a live
engine, and reports its own decision latency. One line in, one line
out: a JSONL trace row submits a job (response `{\"ok\":true,\"id\":N}`,
or `{\"ok\":false,\"error\":...}` on a malformed row — the same error text
`replay` aborts with, but the server keeps running); the literal line
`/stats` returns live statistics (rounds/sec and p50/p99/max scheduling
latency from the wall-span histograms, submissions, engine admissions/
completions and the insurer's admission/rejection counters); `/shutdown`
— or SIGTERM/SIGINT — drains gracefully: in-flight jobs finish, final
stats print to stdout, exit 0. `--drive TRACE.jsonl` self-drives: the
server replays the trace against its own listener at full socket speed,
prints the resulting `/stats` line plus a `drive_done` summary, and
shuts down (the CI smoke leg). Submissions are paced onto the virtual
clock at 1 slot ≈ 1 ms of uptime; `serve` requires `--time-model
event-skip` and always streams metrics. Everything `/stats` reports is
monitoring-plane output under the two-plane telemetry rule: Plane-A
counters arrive through a live mirror republished each policy epoch,
Plane-B wall spans stay quarantined from deterministic output — batch
`replay` results are byte-identical with `serve` compiled in or out.

`--stream-metrics` (simulate, replay, sweep — also the
PINGAN_STREAM_METRICS env var and the `stream_metrics` TOML key) drops
the per-job flowtime vector and keeps only a constant-size streaming
sketch (count/mean/CI exact; p50/p95/p99 within ~1.6% relative error),
letting the engine recycle finished jobs' slots: resident state becomes
O(clusters + alive jobs) instead of O(total jobs), which is what makes
million-job replays fit in CI memory. The sketch is fed identically with
the flag off, so every scalar statistic it reports is bit-identical in
both modes; only exact whole-series outputs (per-job CDFs, per-job
cross-replica averaging) need the flag off.

Telemetry: every run keeps deterministic decision counters (admissions,
per-guard rejections, event/copy accounting) that land in `--json`
output as a `telemetry` block and as per-cell columns in sweep CSV/JSON;
they are bit-identical at any thread count. Wall-clock span histograms
are quarantined in `telemetry_wall` next to `wall_secs` and never enter
deterministic output. `--trace-file PATH` additionally streams one JSONL
record per insurance decision (slot, job, task, candidate cluster, score
components, admit/reject reason); in a sweep all cells share the file,
so lines interleave across cells but each line is atomic.
`--no-telemetry` (simulate) skips the wall-span clock reads for
overhead measurements; counters stay on.

`bench-append` merges a CI `BENCH_sim.json` artifact (the `benchjson`
artifact from a green main run) into the repo-tracked history file:
schema-validated, append-only, duplicate commits rejected. `--dry-run`
validates without writing.
";

fn die(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(1)
}

fn scale_of(args: &Args) -> Result<Scale, String> {
    Ok(match args.get_or("scale", "default") {
        "smoke" => Scale::smoke(),
        "default" => Scale::default_repro(),
        "paper" => Scale::paper(),
        other => return Err(format!("unknown --scale `{other}`")),
    })
}

fn cmd_table(args: &Args) -> Result<(), String> {
    let seed = args.get_u64("seed", 7)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("t1") => {
            let jobs = args.get_usize("jobs", 88)?;
            print!("{}", tables::table1(jobs, seed));
            Ok(())
        }
        Some("t2") => {
            let clusters = args.get_usize("clusters", 100)?;
            print!("{}", tables::table2(clusters, seed));
            Ok(())
        }
        other => Err(format!("expected t1|t2, got {other:?}")),
    }
}

fn cmd_figure(args: &Args) -> Result<(), String> {
    let scale = scale_of(args)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("fig2") | Some("fig3") => {
            let n_jobs = args.get_usize("jobs", 88)?;
            let every = args.get_usize("payload-every", 10)?;
            let runs = figures::run_testbed(n_jobs, every).map_err(|e| format!("{e:#}"))?;
            if args.positional[0] == "fig2" {
                print!("{}", figures::fig2(&runs));
            } else {
                print!("{}", figures::fig3(&runs));
            }
            Ok(())
        }
        Some("fig4") => {
            let f = figures::run_fig4(&scale);
            print!("{}", figures::fig4_table(&f));
            Ok(())
        }
        Some("fig5") => {
            print!("{}", figures::fig5(&scale));
            Ok(())
        }
        Some("fig6a") | Some("fig6b") => {
            let (a, b) = figures::run_fig6(&scale);
            print!("{}", figures::fig6_table(&a, &b));
            Ok(())
        }
        Some("fig7") => {
            let lambdas = args.get_f64_list("lambdas", &[0.02, 0.05, 0.07, 0.11, 0.15])?;
            let epsilons = args.get_f64_list("epsilons", &[0.2, 0.4, 0.6, 0.8])?;
            let rows = figures::run_fig7(&scale, &lambdas, &epsilons);
            print!("{}", figures::fig7_table(&rows));
            Ok(())
        }
        other => Err(format!(
            "expected fig2|fig3|fig4|fig5|fig6a|fig6b|fig7, got {other:?}"
        )),
    }
}

/// `pingan sweep`: expand axis flags (or a `[sweep]` TOML section) into a
/// scenario grid and run it on the parallel sweep runner.
fn cmd_sweep(args: &Args) -> Result<(), String> {
    args.expect_known(&[
        "scale", "jobs", "scheduler", "schedulers", "lambdas", "epsilons", "cluster-counts",
        "failure-scales", "mixes", "scorer", "time-model", "time-models", "score-threads",
        "score-thread-counts", "engine-threads", "engine-thread-counts", "bandwidth-model",
        "bandwidth-models", "reps", "threads", "seed", "config", "json", "csv", "quiet",
        "trace-file", "trace", "stream-metrics", "log-level",
    ])?;
    let scale = scale_of(args)?;
    let spec = if let Some(path) = args.get("config") {
        // --config replaces the flag-built grid; a flag that would be
        // silently ignored is an error, not a surprise
        for conflicting in [
            "scale", "jobs", "scheduler", "schedulers", "lambdas", "epsilons", "cluster-counts",
            "failure-scales", "mixes", "scorer", "time-model", "time-models", "score-threads",
            "score-thread-counts", "engine-threads", "engine-thread-counts", "bandwidth-model",
            "bandwidth-models", "reps", "trace", "stream-metrics",
        ] {
            if args.get(conflicting).is_some() {
                return Err(format!(
                    "--config defines the whole sweep; drop --{conflicting} (or set it in the [sweep] section)"
                ));
            }
        }
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let doc = pingan::config::toml::Doc::parse(&text)?;
        let mut spec = SweepSpec::from_doc(&doc)?;
        spec.base_seed = args.get_u64("seed", spec.base_seed)?;
        spec
    } else {
        let mut base = Scenario::default();
        base.n_clusters = scale.n_clusters;
        base.n_jobs = args.get_usize("jobs", scale.n_jobs)?;
        base.slot_divisor = scale.slot_divisor;
        if let Some(s) = args.get("scheduler") {
            base.scheduler = s.to_string();
        }
        base.scorer = pingan::config::spec::ScorerKind::parse(args.get_or("scorer", "cpu"))?;
        base.time_model =
            pingan::config::spec::TimeModel::parse(args.get_or("time-model", "dense"))?;
        base.score_threads = args.get_usize("score-threads", base.score_threads)?.max(1);
        base.engine_threads = args
            .get_usize("engine-threads", base.engine_threads)?
            .max(1);
        base.bandwidth_model = pingan::config::spec::BandwidthModel::parse(
            args.get_or("bandwidth-model", base.bandwidth_model.name()),
        )?;
        if let Some(t) = args.get("trace") {
            base.trace = Some(t.to_string());
        }
        base.stream_metrics = base.stream_metrics || args.flag("stream-metrics");
        let schedulers: Vec<String> = match args.get("schedulers") {
            Some(s) => s.split(',').map(|x| x.trim().to_string()).collect(),
            None => vec![base.scheduler.clone()],
        };
        let mixes: Vec<WorkloadMix> = match args.get("mixes") {
            Some(s) => s
                .split(',')
                .map(|x| WorkloadMix::parse(x.trim()))
                .collect::<Result<_, _>>()?,
            None => vec![base.mix],
        };
        let time_models: Vec<pingan::config::spec::TimeModel> = match args.get("time-models") {
            Some(s) => s
                .split(',')
                .map(|x| pingan::config::spec::TimeModel::parse(x.trim()))
                .collect::<Result<_, _>>()?,
            None => vec![base.time_model],
        };
        let bandwidth_models: Vec<pingan::config::spec::BandwidthModel> =
            match args.get("bandwidth-models") {
                Some(s) => s
                    .split(',')
                    .map(|x| pingan::config::spec::BandwidthModel::parse(x.trim()))
                    .collect::<Result<_, _>>()?,
                None => vec![base.bandwidth_model],
            };
        let lambdas = args.get_f64_list("lambdas", &[base.lambda])?;
        let epsilons = args.get_f64_list("epsilons", &[base.epsilon])?;
        let cluster_counts = args.get_f64_list("cluster-counts", &[base.n_clusters as f64])?;
        let failure_scales = args.get_f64_list("failure-scales", &[base.failure_scale])?;
        let score_thread_counts =
            args.get_f64_list("score-thread-counts", &[base.score_threads as f64])?;
        let engine_thread_counts =
            args.get_f64_list("engine-thread-counts", &[base.engine_threads as f64])?;
        SweepSpec::new(base)
            .axis(Axis::Scheduler(schedulers))
            .axis(Axis::Lambda(lambdas))
            .axis(Axis::Epsilon(epsilons))
            .axis(Axis::Clusters(
                cluster_counts.iter().map(|&x| x as usize).collect(),
            ))
            .axis(Axis::FailureScale(failure_scales))
            .axis(Axis::Mix(mixes))
            .axis(Axis::TimeModel(time_models))
            .axis(Axis::ScoreThreads(
                score_thread_counts.iter().map(|&x| (x as usize).max(1)).collect(),
            ))
            .axis(Axis::EngineThreads(
                engine_thread_counts.iter().map(|&x| (x as usize).max(1)).collect(),
            ))
            .axis(Axis::BandwidthModel(bandwidth_models))
            .reps(args.get_u64("reps", scale.reps)?)
            .seed(args.get_u64("seed", 0x5EED)?)
    };
    let threads = args.get_usize("threads", 0)?;
    let quiet = args.flag("quiet");
    let progress = |cell: &pingan::sweep::CellResult, done: usize, total: usize| {
        if !quiet {
            let status = match &cell.error {
                Some(e) => format!("ERROR {e}"),
                None => format!("mean {:.1}", cell.mean_flowtime()),
            };
            eprintln!(
                "[{done}/{total}] {} — {status} ({:.2}s)",
                cell.scenario.label(),
                cell.wall_secs
            );
        }
    };
    eprintln!(
        "sweeping {} cells on {} thread(s) ...",
        spec.n_cells(),
        if threads == 0 {
            pingan::sweep::default_threads(spec.n_cells())
        } else {
            threads
        }
    );
    let sink = trace_sink(args)?;
    let report = pingan::sweep::run_traced(&spec, threads, Some(&progress), sink.as_ref());
    if let Some(s) = &sink {
        s.flush();
    }
    if args.flag("json") {
        println!("{}", report.to_json().to_string());
    } else if args.flag("csv") {
        print!("{}", report.to_csv());
    } else {
        print!("{}", report.render());
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let mut scale = scale_of(args)?;
    scale.n_jobs = args.get_usize("jobs", scale.n_jobs)?;
    scale.n_clusters = args.get_usize("clusters", scale.n_clusters)?;
    let lambda = args.get_f64("lambda", 0.07)?;
    let epsilon = args.get_f64(
        "epsilon",
        pingan::config::spec::PingAnSpec::epsilon_hint(lambda),
    )?;
    let name = args.get_or("scheduler", "pingan").to_string();
    let rep = args.get_u64("seed", 0)?;
    let (sys, jobs) = pingan::experiments::sim_setup(&scale, lambda, rep);
    let mut cfg = pingan::simulator::SimConfig::default();
    cfg.seed = 0xC0FFEE ^ rep;
    cfg.max_slots = args.get_u64("max-slots", cfg.max_slots)?;
    cfg.time_model = pingan::config::spec::TimeModel::parse(args.get_or("time-model", "dense"))?;
    cfg.score_threads = args.get_usize("score-threads", cfg.score_threads)?.max(1);
    cfg.engine_threads = args
        .get_usize("engine-threads", cfg.engine_threads)?
        .max(1);
    cfg.bandwidth_model = pingan::config::spec::BandwidthModel::parse(
        args.get_or("bandwidth-model", cfg.bandwidth_model.name()),
    )?;
    // counters (plane A) are always on; this only skips wall-span clocks
    cfg.telemetry = !args.flag("no-telemetry");
    cfg.stream_metrics = cfg.stream_metrics || args.flag("stream-metrics");
    let time_model = cfg.time_model;
    let scorer = pingan::config::spec::ScorerKind::parse(args.get_or("scorer", "cpu"))?;
    let mut sched = pingan::sweep::make_scheduler(
        &name,
        epsilon,
        pingan::config::spec::Principle::EffReli,
        pingan::config::spec::Allocation::Efa,
        scorer,
    )?;
    let sink = trace_sink(args)?;
    if let Some(s) = &sink {
        sched.set_trace(s.clone());
    }
    let res = pingan::simulator::Simulation::new(&sys, jobs, cfg).run(sched.as_mut());
    if let Some(s) = &sink {
        s.flush();
    }
    let avg = res.avg_flowtime();
    let (p50, p95, p99) = pingan::metrics::flowtime_percentiles(&res);
    if args.flag("json") {
        let mut j = Json::obj();
        j.set("scheduler", Json::str(&res.scheduler))
            .set("lambda", Json::num(lambda))
            .set("epsilon", Json::num(epsilon))
            .set("jobs", Json::num(res.total_jobs as f64))
            .set("finished", Json::num(res.finished_jobs as f64))
            .set("avg_flowtime", Json::num(avg))
            .set("p50_flowtime", Json::num(p50))
            .set("p95_flowtime", Json::num(p95))
            .set("p99_flowtime", Json::num(p99))
            .set("sum_flowtime", Json::num(res.sum_flowtime()))
            .set("copies_launched", Json::num(res.copies_launched as f64))
            .set("copies_failed", Json::num(res.copies_failed as f64))
            .set("slots", Json::num(res.slots as f64))
            .set("time_model", Json::str(time_model.name()))
            .set("events_processed", Json::num(res.events_processed as f64))
            // plane A: deterministic counters — byte-identical at any
            // score/engine thread count, safe to diff across runs
            .set("telemetry", res.telemetry.to_json())
            // plane B: wall-clock span histograms — host noise, kept in
            // a clearly separate key like wall_secs in sweep output
            .set("telemetry_wall", res.spans.to_json());
        println!("{}", j.to_string());
    } else {
        println!(
            "{}: {} jobs (λ={lambda}, ε={epsilon}) avg flowtime {:.1} slots (p50 {:.1}, p95 {:.1}, p99 {:.1}), {} copies ({} failure-killed), {} slots simulated ({} decision points, {})",
            res.scheduler, res.total_jobs, avg, p50, p95, p99, res.copies_launched, res.copies_failed, res.slots, res.events_processed, time_model.name()
        );
    }
    Ok(())
}

/// `pingan replay`: stream a workload through the engine without ever
/// materializing it — an external arrival trace (`--trace FILE`) or the
/// incremental Montage generator (`--synthetic N`, bit-identical to the
/// batch path at the same coordinates). With `--stream-metrics` resident
/// state is O(clusters + alive jobs), which is how the CI leg replays a
/// million jobs under a memory ceiling. All output is deterministic in
/// the flags — the CI leg byte-compares two runs' `--json`.
fn cmd_replay(args: &Args) -> Result<(), String> {
    args.expect_known(&[
        "trace", "synthetic", "scheduler", "scale", "lambda", "epsilon", "clusters", "seed",
        "scorer", "time-model", "score-threads", "engine-threads", "bandwidth-model",
        "stream-metrics", "max-slots", "json", "log-level",
    ])?;
    let scale = scale_of(args)?;
    let mut scen = Scenario::default();
    scen.scheduler = args.get_or("scheduler", "pingan").to_string();
    scen.lambda = args.get_f64("lambda", scen.lambda)?;
    scen.epsilon = args.get_f64(
        "epsilon",
        pingan::config::spec::PingAnSpec::epsilon_hint(scen.lambda),
    )?;
    scen.n_clusters = args.get_usize("clusters", scale.n_clusters)?;
    scen.slot_divisor = scale.slot_divisor;
    scen.rep = args.get_u64("seed", 0)?;
    scen.scorer = pingan::config::spec::ScorerKind::parse(args.get_or("scorer", "cpu"))?;
    scen.time_model =
        pingan::config::spec::TimeModel::parse(args.get_or("time-model", "dense"))?;
    scen.score_threads = args.get_usize("score-threads", scen.score_threads)?.max(1);
    scen.engine_threads = args
        .get_usize("engine-threads", scen.engine_threads)?
        .max(1);
    scen.bandwidth_model = pingan::config::spec::BandwidthModel::parse(
        args.get_or("bandwidth-model", scen.bandwidth_model.name()),
    )?;
    scen.stream_metrics = scen.stream_metrics || args.flag("stream-metrics");
    let synthetic = args.get_usize("synthetic", 0)?;
    if args.get("trace").is_none() && synthetic == 0 {
        return Err("replay needs --trace FILE or --synthetic N".into());
    }
    if synthetic > 0 {
        // n_jobs feeds the env seed, so set it before deriving anything
        scen.n_jobs = synthetic;
    }
    let mut cfg = pingan::simulator::SimConfig::default();
    cfg.seed = scen.env_seed(0x5EED) ^ 0xC0FFEE;
    cfg.time_model = scen.time_model;
    cfg.score_threads = scen.score_threads;
    cfg.engine_threads = scen.engine_threads;
    cfg.bandwidth_model = scen.bandwidth_model;
    cfg.stream_metrics = scen.stream_metrics;
    cfg.max_slots = args.get_u64("max-slots", cfg.max_slots)?;
    let time_model = cfg.time_model;
    let streamed = cfg.stream_metrics;
    let mut sched = scen.make_scheduler()?;
    let res = if let Some(path) = args.get("trace") {
        let (sys, src) = scen.build_trace_source(0x5EED, path)?;
        pingan::simulator::Simulation::from_source(&sys, src, cfg).run(sched.as_mut())
    } else {
        // the streaming twin of the sweep's generated environment: same
        // plant, same workload seed chain, one job resident at a time
        let seed = scen.env_seed(0x5EED);
        let mut rng = pingan::util::rng::Rng::new(seed);
        let sys = pingan::cluster::GeoSystem::generate(&scen.system_spec(seed), &mut rng);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let wseed = seed ^ 0xABCD;
        let effective_lambda = scen.lambda / scen.slot_divisor.max(1) as f64;
        let mut w = pingan::config::spec::WorkloadSpec::scaled(synthetic, effective_lambda);
        w.seed = wseed;
        let src = pingan::workload::source::GenSource::new(w, sites, wseed);
        pingan::simulator::Simulation::from_source(&sys, src, cfg).run(sched.as_mut())
    };
    let (p50, p95, p99) = pingan::metrics::flowtime_percentiles(&res);
    if args.flag("json") {
        let mut j = Json::obj();
        j.set("scheduler", Json::str(&res.scheduler))
            .set("jobs", Json::num(res.total_jobs as f64))
            .set("finished", Json::num(res.finished_jobs as f64))
            .set("unfinished", Json::num(res.stats.unfinished() as f64))
            .set("avg_flowtime", Json::num(res.avg_flowtime()))
            .set("ci95_flowtime", Json::num(res.stats.ci95()))
            .set("p50_flowtime", Json::num(p50))
            .set("p95_flowtime", Json::num(p95))
            .set("p99_flowtime", Json::num(p99))
            .set("min_flowtime", Json::num(res.stats.min()))
            .set("max_flowtime", Json::num(res.stats.max()))
            .set("copies_launched", Json::num(res.copies_launched as f64))
            .set("copies_failed", Json::num(res.copies_failed as f64))
            .set("slots", Json::num(res.slots as f64))
            .set("events_processed", Json::num(res.events_processed as f64))
            .set("time_model", Json::str(time_model.name()))
            .set("stream_metrics", Json::Bool(streamed))
            .set("telemetry", res.telemetry.to_json());
        println!("{}", j.to_string());
    } else {
        println!(
            "{}: replayed {} jobs ({} finished), avg flowtime {:.1} slots (p50 {:.1}, p95 {:.1}, p99 {:.1}), {} copies, {} slots simulated ({} decision points, {}{})",
            res.scheduler,
            res.total_jobs,
            res.finished_jobs,
            res.avg_flowtime(),
            p50,
            p95,
            p99,
            res.copies_launched,
            res.slots,
            res.events_processed,
            time_model.name(),
            if streamed { ", streamed metrics" } else { "" },
        );
    }
    Ok(())
}

/// `pingan serve`: the live job-intake service. Flag surface and seed
/// chain mirror `cmd_replay` — a serve session at given scenario
/// coordinates faces the identical plant, scheduler and engine config a
/// batch replay of them would — with the workload arriving over a
/// socket instead of a file. `--time-model` defaults to (and must
/// resolve to) `event-skip`; metrics always stream, since a long-lived
/// intake cannot grow per-job state without bound.
fn cmd_serve(args: &Args) -> Result<(), String> {
    args.expect_known(&[
        "listen", "drive", "scheduler", "scale", "lambda", "epsilon", "clusters", "seed",
        "scorer", "time-model", "score-threads", "engine-threads", "bandwidth-model",
        "max-slots", "log-level",
    ])?;
    let scale = scale_of(args)?;
    let mut scen = Scenario::default();
    scen.scheduler = args.get_or("scheduler", "pingan").to_string();
    scen.lambda = args.get_f64("lambda", scen.lambda)?;
    scen.epsilon = args.get_f64(
        "epsilon",
        pingan::config::spec::PingAnSpec::epsilon_hint(scen.lambda),
    )?;
    scen.n_clusters = args.get_usize("clusters", scale.n_clusters)?;
    scen.slot_divisor = scale.slot_divisor;
    scen.rep = args.get_u64("seed", 0)?;
    scen.scorer = pingan::config::spec::ScorerKind::parse(args.get_or("scorer", "cpu"))?;
    scen.time_model =
        pingan::config::spec::TimeModel::parse(args.get_or("time-model", "event-skip"))?;
    scen.score_threads = args.get_usize("score-threads", scen.score_threads)?.max(1);
    scen.engine_threads = args
        .get_usize("engine-threads", scen.engine_threads)?
        .max(1);
    scen.bandwidth_model = pingan::config::spec::BandwidthModel::parse(
        args.get_or("bandwidth-model", scen.bandwidth_model.name()),
    )?;
    scen.stream_metrics = true;
    let mut cfg = pingan::simulator::SimConfig::default();
    cfg.seed = scen.env_seed(0x5EED) ^ 0xC0FFEE;
    cfg.time_model = scen.time_model;
    cfg.score_threads = scen.score_threads;
    cfg.engine_threads = scen.engine_threads;
    cfg.bandwidth_model = scen.bandwidth_model;
    cfg.stream_metrics = true;
    // the service horizon: unbounded in practice unless the operator
    // caps it (1 slot ≈ 1 ms, so the default outlives any real session)
    cfg.max_slots = args.get_u64("max-slots", u64::MAX / 4)?;
    pingan::serve::run(pingan::serve::ServeOpts {
        listen: args.get_or("listen", "127.0.0.1:7411").to_string(),
        drive: args.get("drive").map(|s| s.to_string()),
        scenario: scen,
        cfg,
    })
}

fn cmd_testbed(args: &Args) -> Result<(), String> {
    let n_jobs = args.get_usize("jobs", 88)?;
    let every = args.get_usize("payload-every", 10)?;
    let runs = figures::run_testbed(n_jobs, every).map_err(|e| format!("{e:#}"))?;
    print!("{}", figures::fig2(&runs));
    print!("{}", figures::fig3(&runs));
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_validate(_args: &Args) -> Result<(), String> {
    use pingan::runtime::{CpuScorer, Engine, HloScorer, ScoreBatch, Scorer};
    println!("checking artifacts + PJRT + scorer agreement ...");
    let engine = Engine::new("artifacts").map_err(|e| format!("{e:#}"))?;
    let hlo = HloScorer::new(&engine).map_err(|e| format!("{e:#}"))?;
    let (b, k, v) = hlo.shape();
    let mut batch = ScoreBatch::new(b, k, v);
    batch.values = (0..v).map(|i| i as f64).collect();
    let mut rng = pingan::util::rng::Rng::new(1);
    for i in 0..batch.proc_pmf.len() {
        batch.proc_pmf[i] = rng.f64();
        batch.trans_pmf[i] = rng.f64();
    }
    // normalize rows
    for bi in 0..b {
        for ki in 0..k {
            let base = (bi * k + ki) * v;
            for pmf in [&mut batch.proc_pmf, &mut batch.trans_pmf] {
                let s: f64 = pmf[base..base + v].iter().sum();
                pmf[base..base + v].iter_mut().for_each(|x| *x /= s);
            }
        }
    }
    let a = hlo.score(&batch).map_err(|e| format!("{e:#}"))?;
    let c = CpuScorer.score(&batch).map_err(|e| format!("{e:#}"))?;
    let max_err = a
        .iter()
        .zip(&c)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max);
    println!("score artifact: [{b}x{k}x{v}], max |hlo - cpu| = {max_err:.2e}");
    if max_err > 1e-3 {
        return Err(format!("scorer mismatch {max_err}"));
    }
    let payloads =
        pingan::runtime::payload::Payloads::new(&engine).map_err(|e| format!("{e:#}"))?;
    let mut prng = pingan::util::rng::Rng::new(2);
    for app in pingan::workload::testbed::AppKind::ALL {
        let digest = payloads.run(app, &mut prng).map_err(|e| format!("{e:#}"))?;
        println!("payload {:<10} ok (digest {digest:.3})", app.name());
    }
    println!("validate: all green");
    Ok(())
}

/// Hermetic build: no PJRT, so validate the always-on backend instead —
/// the batched CPU scorer against the `dist::Hist` reference algebra.
#[cfg(not(feature = "pjrt"))]
fn cmd_validate(_args: &Args) -> Result<(), String> {
    use pingan::dist::{Grid, Hist};
    use pingan::runtime::{CpuScorer, ScoreBatch, Scorer};
    println!("checking CPU scorer vs dist::Hist algebra (built without `pjrt`) ...");
    let (b, k, v) = (4usize, 4usize, 64usize);
    let mut batch = ScoreBatch::new(b, k, v);
    batch.values = (0..v).map(|i| i as f64 * 0.5).collect();
    let mut rng = pingan::util::rng::Rng::new(1);
    for i in 0..batch.proc_pmf.len() {
        batch.proc_pmf[i] = rng.f64() + 1e-3;
        batch.trans_pmf[i] = rng.f64() + 1e-3;
    }
    // normalize rows
    for bi in 0..b {
        for ki in 0..k {
            let base = (bi * k + ki) * v;
            for pmf in [&mut batch.proc_pmf, &mut batch.trans_pmf] {
                let s: f64 = pmf[base..base + v].iter().sum();
                pmf[base..base + v].iter_mut().for_each(|x| *x /= s);
            }
        }
    }
    let got = CpuScorer.score(&batch).map_err(|e| format!("{e:#}"))?;
    // no existing copies (cdf = 1), so each score is E[min(proc, trans)]
    let grid = Grid::uniform(0.0, (v - 1) as f64 * 0.5, v);
    let mut max_err = 0.0f64;
    for bi in 0..b {
        for ki in 0..k {
            let base = (bi * k + ki) * v;
            let hp = Hist::from_pmf(&grid, &batch.proc_pmf[base..base + v]);
            let ht = Hist::from_pmf(&grid, &batch.trans_pmf[base..base + v]);
            let want = hp.min_compose(&ht).mean();
            max_err = max_err.max((got[bi * k + ki] - want).abs());
        }
    }
    println!("cpu scorer: [{b}x{k}x{v}], max |cpu - hist| = {max_err:.2e}");
    if max_err > 1e-3 {
        return Err(format!("cpu scorer disagrees with hist algebra: {max_err}"));
    }
    println!("validate: cpu backend green; rebuild with `--features pjrt` for artifact checks");
    Ok(())
}

/// `pingan bench-append`: merge a CI bench artifact (the `benchjson`
/// artifact's BENCH_sim.json, `{"commit": sha, "cases": [...]}`) into
/// the repo-tracked perf history. Append-only: the entry is
/// schema-validated, a commit that is already recorded is an error, and
/// past entries are never rewritten — only the `history` array grows.
/// `--dry-run` validates and reports without writing.
fn cmd_bench_append(args: &Args) -> Result<(), String> {
    args.expect_known(&["history", "dry-run", "log-level"])?;
    let artifact_path = args
        .positional
        .first()
        .ok_or("usage: pingan bench-append <artifact.json> [--history FILE] [--dry-run]")?;
    let history_path = args.get_or("history", "BENCH_sim.json");
    let artifact_text =
        std::fs::read_to_string(artifact_path).map_err(|e| format!("{artifact_path}: {e}"))?;
    let artifact = Json::parse(&artifact_text).map_err(|e| format!("{artifact_path}: {e}"))?;
    let commit = artifact
        .get("commit")
        .and_then(Json::as_str)
        .filter(|c| !c.is_empty() && *c != "unknown")
        .ok_or_else(|| format!("{artifact_path}: entry needs a non-empty `commit` sha"))?
        .to_string();
    let cases = artifact
        .get("cases")
        .and_then(Json::as_arr)
        .filter(|cs| !cs.is_empty())
        .ok_or_else(|| format!("{artifact_path}: entry needs a non-empty `cases` array"))?;
    for (i, case) in cases.iter().enumerate() {
        for key in ["suite", "case"] {
            if case.get(key).and_then(Json::as_str).is_none() {
                return Err(format!(
                    "{artifact_path}: cases[{i}] has no string `{key}` field"
                ));
            }
        }
    }
    let history_text =
        std::fs::read_to_string(history_path).map_err(|e| format!("{history_path}: {e}"))?;
    let mut doc = Json::parse(&history_text).map_err(|e| format!("{history_path}: {e}"))?;
    let existing = doc
        .get("history")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{history_path}: no `history` array — wrong file?"))?;
    if existing
        .iter()
        .any(|e| e.get("commit").and_then(Json::as_str) == Some(commit.as_str()))
    {
        return Err(format!(
            "{history_path}: commit {commit} is already recorded; history is append-only"
        ));
    }
    let mut entry = Json::obj();
    entry
        .set("commit", Json::str(&commit))
        .set("cases", Json::Arr(cases.to_vec()));
    let n_cases = cases.len();
    let mut new_hist = existing.to_vec();
    new_hist.push(entry);
    let n_entries = new_hist.len();
    doc.set("history", Json::Arr(new_hist));
    if args.flag("dry-run") {
        println!(
            "dry-run: would append commit {commit} ({n_cases} cases) to {history_path} as entry {n_entries}"
        );
        return Ok(());
    }
    std::fs::write(history_path, doc.to_pretty()).map_err(|e| format!("{history_path}: {e}"))?;
    println!("appended commit {commit} ({n_cases} cases) to {history_path} ({n_entries} entries)");
    Ok(())
}

/// Minimal env_logger substitute with module-path filtering.
///
/// The filter spec (env_logger syntax, e.g. `warn,pingan::insurance=debug`)
/// is taken from, in precedence order: the `--log-level` flag, then the
/// `PINGAN_LOG` env var, then `RUST_LOG`, defaulting to `warn`. Records
/// print to stderr as `[LEVEL module::path] message`.
fn init_logging(cli_spec: Option<&str>) -> Result<(), String> {
    struct L;
    impl log::Log for L {
        fn enabled(&self, m: &log::Metadata) -> bool {
            m.level() <= log::max_level()
        }
        fn log(&self, r: &log::Record) {
            if self.enabled(r.metadata()) {
                eprintln!("[{} {}] {}", r.level(), r.target(), r.args());
            }
        }
        fn flush(&self) {}
    }
    static LOGGER: L = L;
    // the explicit flag hard-errors on a typo; a malformed env var
    // (possibly set for some other tool) just warns and falls back
    let filters = if let Some(spec) = cli_spec {
        log::Filters::parse(spec).map_err(|e| format!("--log-level: {e}"))?
    } else {
        let env_spec = std::env::var("PINGAN_LOG")
            .or_else(|_| std::env::var("RUST_LOG"))
            .unwrap_or_else(|_| "warn".to_string());
        log::Filters::parse(&env_spec).unwrap_or_else(|e| {
            eprintln!("warning: ignoring log filter `{env_spec}`: {e}");
            log::Filters::uniform(log::LevelFilter::Warn)
        })
    };
    let _ = log::set_logger(&LOGGER);
    let _ = log::set_filters(filters);
    Ok(())
}

/// Build the optional `--trace-file` decision-trace sink.
fn trace_sink(args: &Args) -> Result<Option<TraceSink>, String> {
    match args.get("trace-file") {
        None => Ok(None),
        Some(path) => TraceSink::to_file(path)
            .map(Some)
            .map_err(|e| format!("--trace-file {path}: {e}")),
    }
}

// Hidden diagnostic: step a small sim and dump per-job state.
// `pingan debug-sim --jobs N --clusters N --seed S --steps K`
#[allow(dead_code)]
fn cmd_debug_sim(args: &Args) -> Result<(), String> {
    let mut scale = scale_of(args)?;
    scale.n_jobs = args.get_usize("jobs", 6)?;
    scale.n_clusters = args.get_usize("clusters", 6)?;
    let lambda = args.get_f64("lambda", 0.07)?;
    let rep = args.get_u64("seed", 1)?;
    let steps = args.get_u64("steps", 300)?;
    let (sys, jobs) = pingan::experiments::sim_setup(&scale, lambda, rep);
    println!("total slots: {}", sys.total_slots());
    let mut cfg = pingan::simulator::SimConfig::default();
    cfg.seed = 0xC0FFEE ^ rep;
    let mut sim = pingan::simulator::Simulation::new(&sys, jobs, cfg);
    let mut sched = pingan::experiments::make_scheduler("pingan", 0.6);
    for step in 0..steps {
        sim.step(sched.as_mut());
        if let Err(e) = sim.check_invariants() {
            println!("INVARIANT VIOLATION at step {step}: {e}");
            return Ok(());
        }
        if step % 50 == 0 || step == steps - 1 {
            let now = sim.now();
            print!("t={now}: ");
            for (ji, j) in sim.jobs.iter().enumerate() {
                let running: usize = j.tasks.iter().map(|t| t.alive_copies()).sum();
                let ready = j
                    .tasks
                    .iter()
                    .filter(|t| t.state == pingan::simulator::TaskState::Ready)
                    .count();
                print!(
                    "[j{ji} done {}/{} run {running} rdy {ready}] ",
                    j.n_done(),
                    j.tasks.len()
                );
            }
            // sample a running copy
            if let Some((d, c)) = sim.jobs.iter().flat_map(|j| {
                j.spec.tasks.iter().zip(&j.tasks).flat_map(|(sp, t)| {
                    t.copies.iter().filter(|c| c.alive).map(move |c| (sp.datasize, c))
                })
            }).next() {
                print!("| sample copy rate {:.4} processed {:.1}/{:.0}", c.rate, c.processed, d);
            }
            println!();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Args {
        Args::parse(argv.iter().map(|s| s.to_string())).expect("argv shape is valid")
    }

    #[test]
    fn every_replay_flag_rejects_garbage_without_backtrace() {
        // satellite contract: a typo'd value on any value-taking flag
        // dies with an error that names the flag (or echoes the value),
        // never a panic/backtrace — and never a silent fallback
        let cases: &[(&str, &str)] = &[
            ("--trace", "/definitely/not/here.jsonl"),
            ("--synthetic", "lots"),
            ("--scheduler", "bogus-policy"),
            ("--scale", "enormous"),
            ("--lambda", "fast"),
            ("--epsilon", "half"),
            ("--clusters", "3.5"),
            ("--seed", "s33d"),
            ("--scorer", "quantum"),
            ("--time-model", "warp"),
            ("--score-threads", "lots"),
            ("--engine-threads", "-2"),
            ("--bandwidth-model", "infinite"),
            ("--max-slots", "forever"),
        ];
        for (flag, garbage) in cases {
            let args = parse(&["replay", "--synthetic", "4", flag, garbage]);
            let err = cmd_replay(&args).expect_err(&format!("{flag} {garbage} was accepted"));
            let name = flag.trim_start_matches("--");
            assert!(
                err.contains(name) || err.contains(garbage),
                "{flag}: error `{err}` names neither the flag nor the value"
            );
        }
        // and an unknown flag is a typo, not an ignored option
        let args = parse(&["replay", "--synthetic", "4", "--sychedule", "x"]);
        assert!(cmd_replay(&args).unwrap_err().contains("--sychedule"));
    }

    #[test]
    fn serve_flags_reject_garbage_before_binding_anything() {
        // every case errors in the parse layer (or serve's time-model
        // gate), before a listener could bind — safe to run in parallel
        let cases: &[(&str, &str)] = &[
            ("--scale", "galactic"),
            ("--lambda", "many"),
            ("--epsilon", "tiny"),
            ("--clusters", "few"),
            ("--seed", "abc"),
            ("--scorer", "gpu"),
            ("--time-model", "warp"),
            ("--score-threads", "lots"),
            ("--engine-threads", "zero"),
            ("--bandwidth-model", "free"),
            ("--max-slots", "infinity"),
            ("--unknown-flag", "x"),
        ];
        for (flag, garbage) in cases {
            let args = parse(&["serve", flag, garbage]);
            assert!(cmd_serve(&args).is_err(), "{flag} {garbage} was accepted");
        }
        // the dense core is refused up front with an explanation
        let args = parse(&["serve", "--time-model", "dense"]);
        let err = cmd_serve(&args).unwrap_err();
        assert!(err.contains("event-skip"), "unhelpful dense refusal: {err}");
    }
}
