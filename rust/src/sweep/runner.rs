//! The parallel sweep runner: scoped worker threads pulling cells off a
//! shared atomic queue (idle workers steal the next unclaimed cell), with
//! per-cell panic isolation and a progress callback.
//!
//! Determinism does not depend on the thread count: each cell's seeds are
//! a pure function of its coordinates (`Scenario::env_seed`), results are
//! written into the cell's grid slot, and aggregation reads the slots in
//! grid order — so `run_with(spec, 1, ..)`, `run_with(spec, 8, ..)` and a
//! sequential loop over `spec.cells()` all produce the same report.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::report::{CellResult, SweepReport};
use super::spec::SweepSpec;

/// Progress callback: `(just-finished cell, cells done, cells total)`.
/// Called from worker threads — it must be `Sync` and should be quick.
pub type Progress<'a> = &'a (dyn Fn(&CellResult, usize, usize) + Sync);

/// Worker count for `threads = 0`: the machine's parallelism, capped at
/// the cell count.
pub fn default_threads(n_cells: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, n_cells.max(1))
}

/// Run a sweep with auto-sized worker count and no progress reporting.
pub fn run(spec: &SweepSpec) -> SweepReport {
    run_with(spec, 0, None)
}

/// Run a sweep on `threads` workers (`0` = auto). A cell that panics or
/// fails to construct its scheduler is recorded as an errored
/// [`CellResult`]; it never takes down the sweep or its siblings.
pub fn run_with(spec: &SweepSpec, threads: usize, progress: Option<Progress>) -> SweepReport {
    run_traced(spec, threads, progress, None)
}

/// [`run_with`] plus an optional shared decision-trace sink
/// (`--trace-file`): every cell's scheduler gets a clone of the sink, so
/// records from concurrently-running cells interleave in the output —
/// each JSONL *line* is atomic (the sink locks per record), but line
/// order across cells is host-scheduling noise. The simulated outcomes
/// remain bit-identical with or without the sink; only the trace itself
/// is unordered.
pub fn run_traced(
    spec: &SweepSpec,
    threads: usize,
    progress: Option<Progress>,
    trace: Option<&crate::obs::TraceSink>,
) -> SweepReport {
    let cells = spec.cells();
    let n = cells.len();
    let threads = if threads == 0 {
        default_threads(n)
    } else {
        threads.clamp(1, n.max(1))
    };
    let next = AtomicUsize::new(0);
    let done = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<CellResult>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let cell = &cells[i];
                let seed = cell.env_seed(spec.base_seed);
                let t0 = Instant::now();
                let outcome =
                    catch_unwind(AssertUnwindSafe(|| cell.run_traced(spec.base_seed, trace)));
                let wall_secs = t0.elapsed().as_secs_f64();
                let result = match outcome {
                    Ok(Ok(sim)) => CellResult::from_sim(i, cell.clone(), seed, &sim, wall_secs),
                    Ok(Err(e)) => CellResult::failed(i, cell.clone(), seed, e, wall_secs),
                    Err(payload) => {
                        let msg = panic_message(payload.as_ref());
                        CellResult::failed(i, cell.clone(), seed, msg, wall_secs)
                    }
                };
                let finished = done.fetch_add(1, Ordering::Relaxed) + 1;
                if let Some(report) = progress {
                    report(&result, finished, n);
                }
                *slots[i].lock().unwrap() = Some(result);
            });
        }
    });
    let results: Vec<CellResult> = slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("every claimed cell stores a result")
        })
        .collect();
    SweepReport::from_cells(spec.base_seed, results)
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        format!("cell panicked: {s}")
    } else if let Some(s) = payload.downcast_ref::<String>() {
        format!("cell panicked: {s}")
    } else {
        "cell panicked".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::{Axis, Scenario};

    fn tiny_spec() -> SweepSpec {
        let mut base = Scenario::default();
        base.n_clusters = 6;
        base.n_jobs = 8;
        base.slot_divisor = 10;
        SweepSpec::new(base)
            .axis(Axis::Scheduler(vec!["flutter".into(), "pingan".into()]))
            .seed(0xD5)
    }

    #[test]
    fn runs_every_cell_once() {
        let spec = tiny_spec();
        let report = run_with(&spec, 2, None);
        assert_eq!(report.cells.len(), spec.n_cells());
        for (i, c) in report.cells.iter().enumerate() {
            assert_eq!(c.index, i);
            assert!(c.error.is_none(), "{:?}", c.error);
            assert!(c.wall_secs >= 0.0);
            assert_eq!(c.finished, c.total);
        }
    }

    #[test]
    fn progress_reaches_total() {
        let spec = tiny_spec();
        let seen = AtomicUsize::new(0);
        let max_done = AtomicUsize::new(0);
        run_with(
            &spec,
            2,
            Some(&|_cell, done, total| {
                seen.fetch_add(1, Ordering::Relaxed);
                max_done.fetch_max(done, Ordering::Relaxed);
                assert_eq!(total, 2);
            }),
        );
        assert_eq!(seen.load(Ordering::Relaxed), 2);
        assert_eq!(max_done.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn bad_cell_is_isolated() {
        let mut base = Scenario::default();
        base.n_clusters = 6;
        base.n_jobs = 8;
        base.slot_divisor = 10;
        // ε=1.5 fails PingAnSpec validation inside the cell
        let spec = SweepSpec::new(base)
            .axis(Axis::Scheduler(vec!["pingan".into(), "flutter".into()]))
            .axis(Axis::Epsilon(vec![1.5]));
        let report = run_with(&spec, 2, None);
        assert_eq!(report.cells.len(), 2);
        assert!(report.cells[0].error.is_some());
        assert!(report.cells[1].error.is_none(), "flutter ignores ε");
        assert_eq!(report.rows.len(), 2);
        assert_eq!(report.rows[0].errors, 1);
    }
}
