//! Copy-placement scoring: the numeric hot path of the insurer.
//!
//! Everything here is expressed over the performance modeler's histogram
//! estimates. The same math — bottleneck min-composition followed by
//! E\[max\] over the copy set — is what the L1 Pallas kernel computes in
//! batch; `runtime::scorer` can replace the inner loop with the compiled
//! artifact and is cross-checked against this implementation.

use crate::dist::Hist;
use crate::perfmodel::PerfModel;
use crate::workload::job::OpKind;

/// Score of one candidate cluster for one task.
#[derive(Clone, Debug)]
pub struct CandidateScore {
    pub cluster: usize,
    /// E[r(x+1)] if the copy lands here (x = existing copies).
    pub rate: f64,
    /// E[r(1)] of this copy alone (floor checks use the solo rate).
    pub solo_rate: f64,
    /// pro after adding the copy.
    pub pro: f64,
}

/// Evaluate every cluster in `candidates` for a task with `existing` copy
/// rate-hists in `existing_clusters`. Returns scores aligned to input.
#[allow(clippy::too_many_arguments)]
pub fn score_candidates(
    model: &PerfModel,
    sources: &[usize],
    op: OpKind,
    datasize: f64,
    existing: &[Hist],
    existing_clusters: &[usize],
    candidates: &[usize],
) -> Vec<CandidateScore> {
    candidates
        .iter()
        .map(|&m| {
            let cand = model.rate_hist(sources, m, op);
            let solo = cand.mean();
            let rate = if existing.is_empty() {
                solo
            } else {
                model.exp_rate_with(existing, &cand)
            };
            let pro = pro_with_candidate(model, existing_clusters, m, datasize, rate);
            CandidateScore {
                cluster: m,
                rate,
                solo_rate: solo,
                pro,
            }
        })
        .collect()
}

/// Like [`score_candidates`] but over precomputed per-cluster (solo rate,
/// rate hist) pairs — the insurer's per-slot cache path.
pub fn score_candidates_cached(
    model: &PerfModel,
    datasize: f64,
    solo: &[(f64, Hist)],
    existing: &[Hist],
    existing_clusters: &[usize],
    candidates: &[usize],
) -> Vec<CandidateScore> {
    candidates
        .iter()
        .map(|&m| {
            let (solo_rate, cand) = &solo[m];
            let rate = if existing.is_empty() {
                *solo_rate
            } else {
                model.exp_rate_with(existing, cand)
            };
            let pro = pro_with_candidate(model, existing_clusters, m, datasize, rate);
            CandidateScore {
                cluster: m,
                rate,
                solo_rate: *solo_rate,
                pro,
            }
        })
        .collect()
}

/// `pro` of the task if a copy is added in `candidate` (Sec 3.2: per-slot
/// survival is `1 - Π p_m` over distinct copy clusters).
pub fn pro_with_candidate(
    model: &PerfModel,
    existing_clusters: &[usize],
    candidate: usize,
    datasize: f64,
    rate: f64,
) -> f64 {
    let mut cs: Vec<usize> = existing_clusters.to_vec();
    cs.push(candidate);
    model.pro(&cs, datasize, rate)
}

/// The round-1 rate floor (Sec 4.1): a slot is acceptable only when the
/// copy's expected rate is at least `1/(1+ε)` of the task's global optimum.
pub fn passes_rate_floor(solo_rate: f64, global_best: f64, epsilon: f64) -> bool {
    solo_rate + 1e-12 >= global_best / (1.0 + epsilon)
}

/// The resource-saving admission rule for the c-th copy (c >= 2 extra):
/// `E^{c-1}[e] > (c+1)/c · E^{c}[e]`.
pub fn resource_saving_ok(datasize: f64, rate_before: f64, rate_after: f64, c: usize) -> bool {
    if rate_before <= 0.0 || rate_after <= 0.0 {
        return false;
    }
    let e_before = datasize / rate_before;
    let e_after = datasize / rate_after;
    e_before > (c as f64 + 1.0) / (c as f64) * e_after
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::SystemSpec;
    use crate::util::rng::Rng;

    fn model() -> PerfModel {
        let mut rng = Rng::new(51);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        PerfModel::new(&sys, 64)
    }

    #[test]
    fn rate_floor_boundary() {
        assert!(passes_rate_floor(10.0, 16.0, 0.6)); // 16/1.6 = 10
        assert!(!passes_rate_floor(9.9, 16.0, 0.6));
        assert!(passes_rate_floor(5.0, 5.0, 0.2));
    }

    #[test]
    fn resource_saving_rule() {
        // c=2: requires e1 > 1.5 e2 -> rate_after > 1.5 rate_before
        assert!(resource_saving_ok(100.0, 1.0, 1.6, 2));
        assert!(!resource_saving_ok(100.0, 1.0, 1.4, 2));
        // c=3: requires e2 > (4/3) e3
        assert!(resource_saving_ok(100.0, 1.0, 1.4, 3));
        assert!(!resource_saving_ok(100.0, 1.0, 1.2, 3));
        assert!(!resource_saving_ok(100.0, 0.0, 1.0, 2));
    }

    #[test]
    fn scores_cover_candidates_and_improve_with_copies() {
        let pm = model();
        let sources = vec![1usize];
        let op = OpKind::Map;
        let scores = score_candidates(&pm, &sources, op, 500.0, &[], &[], &[0, 2, 3]);
        assert_eq!(scores.len(), 3);
        for s in &scores {
            assert!(s.rate > 0.0 && s.pro > 0.0 && s.pro <= 1.0);
            assert!((s.rate - s.solo_rate).abs() < 1e-9, "no existing copies");
        }
        // now with an existing copy: combined rate >= solo of candidate
        let existing = vec![pm.rate_hist(&sources, 0, op)];
        let with = score_candidates(&pm, &sources, op, 500.0, &existing, &[0], &[2]);
        assert!(with[0].rate >= with[0].solo_rate - 1e-9);
    }

    #[test]
    fn pro_candidate_dedups_cluster() {
        let pm = model();
        let a = pro_with_candidate(&pm, &[0], 0, 100.0, 5.0);
        let b = pm.pro(&[0], 100.0, 5.0);
        assert!((a - b).abs() < 1e-12);
    }
}
