//! API-compatible shim for the subset of the `log` facade crate this
//! repository uses: the five leveled macros, the [`Log`] trait, a global
//! logger installed once with [`set_logger`], and a process-wide
//! [`LevelFilter`] read by [`max_level`].
//!
//! Like `util::cli` (clap) and `bench_harness` (criterion), this exists
//! because registry crates are unavailable offline; keeping the dependency
//! graph path-only also lets `Cargo.lock` be exact without checksums. The
//! surface mirrors `log` 0.4 so swapping the real crate back in is a
//! one-line `Cargo.toml` change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity of one record, ordered from terse to chatty.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl Level {
    fn as_usize(self) -> usize {
        self as usize
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// The global verbosity ceiling. `Off` disables everything.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

impl LevelFilter {
    fn as_usize(self) -> usize {
        self as usize
    }

    fn from_usize(u: usize) -> LevelFilter {
        match u {
            0 => LevelFilter::Off,
            1 => LevelFilter::Error,
            2 => LevelFilter::Warn,
            3 => LevelFilter::Info,
            4 => LevelFilter::Debug,
            _ => LevelFilter::Trace,
        }
    }
}

// A record passes when its level is at or below the filter: the orderings
// between Level and LevelFilter mirror the real facade's cross impls.
impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        self.as_usize() == other.as_usize()
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        Some(self.as_usize().cmp(&other.as_usize()))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        self.as_usize() == other.as_usize()
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        Some(self.as_usize().cmp(&other.as_usize()))
    }
}

/// Metadata about one record (its level; targets are unused here).
#[derive(Clone, Copy, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus the pre-formatted arguments.
#[derive(Clone, Copy, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging sink. Installed once per process via [`set_logger`].
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Returned when [`set_logger`] is called twice.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

pub fn set_max_level(level: LevelFilter) {
    MAX_LEVEL.store(level.as_usize(), Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    LevelFilter::from_usize(MAX_LEVEL.load(Ordering::Relaxed))
}

/// Parse one level name (`env_logger` spelling, case-insensitive).
pub fn parse_level(s: &str) -> Result<LevelFilter, String> {
    Ok(match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "info" => LevelFilter::Info,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        other => return Err(format!("unknown log level `{other}`")),
    })
}

/// A default verbosity plus per-module-path overrides, in `env_logger`'s
/// directive syntax: `"warn,pingan::insurance=debug"` means warn
/// everywhere except the `pingan::insurance` subtree at debug. Matching
/// is by module-path prefix on `::` boundaries; the longest matching
/// prefix wins.
#[derive(Clone, Debug, PartialEq)]
pub struct Filters {
    pub default: LevelFilter,
    /// `(module path prefix, level)` overrides, any order.
    pub modules: Vec<(String, LevelFilter)>,
}

impl Filters {
    /// Everything at one level, no overrides.
    pub fn uniform(default: LevelFilter) -> Filters {
        Filters {
            default,
            modules: Vec::new(),
        }
    }

    /// Parse a comma-separated directive list. A bare level sets the
    /// default; `path=level` adds an override. Empty items are ignored
    /// (so trailing commas are harmless); an empty spec is all-off.
    pub fn parse(spec: &str) -> Result<Filters, String> {
        let mut f = Filters::uniform(LevelFilter::Off);
        for item in spec.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            match item.split_once('=') {
                Some((path, level)) => {
                    let path = path.trim();
                    if path.is_empty() {
                        return Err(format!("empty module path in `{item}`"));
                    }
                    f.modules.push((path.to_string(), parse_level(level.trim())?));
                }
                None => f.default = parse_level(item)?,
            }
        }
        Ok(f)
    }

    /// The level governing `target`: the longest module-prefix override,
    /// or the default when none matches. `pingan::insurance` matches
    /// itself and `pingan::insurance::pingan`, never `pingan::insurancex`.
    pub fn level_for(&self, target: &str) -> LevelFilter {
        let mut best: Option<(usize, LevelFilter)> = None;
        for (path, level) in &self.modules {
            let matches = target == path
                || (target.starts_with(path.as_str()) && target[path.len()..].starts_with("::"));
            if matches && best.map_or(true, |(len, _)| path.len() > len) {
                best = Some((path.len(), *level));
            }
        }
        best.map_or(self.default, |(_, l)| l)
    }

    /// The loosest level any directive allows — what [`set_filters`]
    /// raises the global [`max_level`] ceiling to, so per-module records
    /// above the default still reach the module check.
    pub fn ceiling(&self) -> LevelFilter {
        self.modules
            .iter()
            .map(|(_, l)| *l)
            .fold(self.default, |a, b| if b > a { b } else { a })
    }
}

static FILTERS: OnceLock<Filters> = OnceLock::new();

/// Install per-module filters (once per process, like [`set_logger`]) and
/// raise the global ceiling to their loosest level. Records then pass
/// when at or below `filters.level_for(module_path)`.
pub fn set_filters(filters: Filters) -> Result<(), SetLoggerError> {
    let ceiling = filters.ceiling();
    FILTERS.set(filters).map_err(|_| SetLoggerError(()))?;
    set_max_level(ceiling);
    Ok(())
}

/// Macro plumbing: filter, then dispatch to the installed logger. Public
/// so the exported macros can reach it via `$crate`; not a stable API.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: fmt::Arguments) {
    if level.as_usize() > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(filters) = FILTERS.get() {
        if level > filters.level_for(target) {
            return;
        }
    }
    if let Some(logger) = LOGGER.get() {
        let metadata = Metadata { level, target };
        if logger.enabled(&metadata) {
            logger.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Error, module_path!(), ::std::format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Warn, module_path!(), ::std::format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Info, module_path!(), ::std::format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Debug, module_path!(), ::std::format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Trace, module_path!(), ::std::format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= max_level()
        }
        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                HITS.fetch_add(1, Ordering::Relaxed);
                // exercise the accessors the way main.rs's logger does
                let _ = format!("[{}] {}", record.level(), record.args());
            }
        }
        fn flush(&self) {}
    }

    #[test]
    fn filter_orders_and_dispatch() {
        assert!(Level::Error < Level::Trace);
        assert!(Level::Warn <= LevelFilter::Warn);
        assert!(Level::Debug > LevelFilter::Warn);
        assert!(LevelFilter::Info >= Level::Info);

        static COUNTER: Counter = Counter;
        let _ = set_logger(&COUNTER);
        set_max_level(LevelFilter::Warn);
        let before = HITS.load(Ordering::Relaxed);
        crate::warn!("shown {}", 1);
        crate::debug!("suppressed");
        assert_eq!(HITS.load(Ordering::Relaxed), before + 1);
        // second install is rejected, not a panic
        assert!(set_logger(&COUNTER).is_err());
        set_max_level(LevelFilter::Debug);
        crate::debug!("now shown");
        assert_eq!(HITS.load(Ordering::Relaxed), before + 2);
    }

    #[test]
    fn filters_parse_env_logger_syntax() {
        let f = Filters::parse("warn,pingan::insurance=debug,pingan::simulator=trace,").unwrap();
        assert_eq!(f.default, LevelFilter::Warn);
        assert_eq!(f.modules.len(), 2);
        assert_eq!(f.ceiling(), LevelFilter::Trace);
        assert_eq!(Filters::parse("").unwrap(), Filters::uniform(LevelFilter::Off));
        assert_eq!(Filters::parse("INFO").unwrap().default, LevelFilter::Info);
        assert!(Filters::parse("verbose").is_err());
        assert!(Filters::parse("=debug").is_err());
        assert!(Filters::parse("a::b=loud").is_err());
    }

    #[test]
    fn longest_module_prefix_wins_on_path_boundaries() {
        let f = Filters::parse("warn,pingan=info,pingan::insurance=debug").unwrap();
        assert_eq!(f.level_for("other::module"), LevelFilter::Warn);
        assert_eq!(f.level_for("pingan"), LevelFilter::Info);
        assert_eq!(f.level_for("pingan::sweep"), LevelFilter::Info);
        assert_eq!(f.level_for("pingan::insurance"), LevelFilter::Debug);
        assert_eq!(f.level_for("pingan::insurance::pingan"), LevelFilter::Debug);
        // a prefix must end on a `::` boundary, not mid-identifier
        assert_eq!(f.level_for("pingan::insurancex"), LevelFilter::Info);
        assert_eq!(f.level_for("pinganx"), LevelFilter::Warn);
    }
}
