//! Insurer hot-path benches: the per-slot cost of Algorithm 1 as alive-job
//! count grows, plus the candidate-scoring kernel in isolation. This is the
//! L3 target of the §Perf pass: the insurer must not dominate slot time at
//! paper scale.
//!
//! Run: `cargo bench --bench bench_insurance`

use pingan::bench_harness::Bench;
use pingan::cluster::GeoSystem;
use pingan::config::spec::{SystemSpec, WorkloadSpec};
use pingan::insurance::scoring::score_candidates;
use pingan::insurance::PingAn;
use pingan::perfmodel::PerfModel;
use pingan::simulator::{SimConfig, Simulation};
use pingan::util::rng::Rng;
use pingan::workload::job::OpKind;
use pingan::workload::montage;

fn main() {
    let mut b = Bench::new("insurance");

    // scoring kernel: 1 task × 30 candidate clusters
    let mut rng = Rng::new(21);
    let sys = GeoSystem::generate(
        &{
            let mut s = SystemSpec::default();
            s.n_clusters = 30;
            s
        },
        &mut rng,
    );
    let model = PerfModel::new(&sys, 64);
    let candidates: Vec<usize> = (0..sys.n()).collect();
    let existing = vec![model.rate_hist(&[0, 1], 2, OpKind::Map)];
    b.case("score_30_candidates_no_copies", || {
        score_candidates(&model, &[0, 1], OpKind::Map, 500.0, &[], &[], &candidates)
            .iter()
            .map(|s| s.rate)
            .sum()
    });
    b.case("score_30_candidates_1_copy", || {
        score_candidates(
            &model,
            &[0, 1],
            OpKind::Map,
            500.0,
            &existing,
            &[2],
            &candidates,
        )
        .iter()
        .map(|s| s.rate)
        .sum()
    });
    b.case("global_best_rate_30_clusters", || {
        model.global_best_rate(&[0, 1], OpKind::Map)
    });

    // per-slot schedule() cost under load: steady-state step
    for &n_jobs in &[8usize, 24, 48] {
        let mut rng = Rng::new(33);
        let sys = GeoSystem::generate(&SystemSpec::small(12), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, 10.0); // all arrive ~immediately
        w.datasize = (300.0, 900.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        b.case(&format!("pingan_step_{n_jobs}_alive_jobs"), || {
            let mut sim = Simulation::new(&sys, jobs.clone(), SimConfig::default());
            let mut p = PingAn::with_epsilon(0.6);
            // warm 3 slots then measure 5 steady-state steps
            for _ in 0..8 {
                sim.step(&mut p);
            }
            sim.now() as f64
        });
    }

    // full run comparison: EFA vs JGA allocation cost
    {
        let mut rng = Rng::new(44);
        let sys = GeoSystem::generate(&SystemSpec::small(8), &mut rng);
        let mut w = WorkloadSpec::scaled(10, 0.05);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        for alloc in [
            pingan::config::spec::Allocation::Efa,
            pingan::config::spec::Allocation::Jga,
        ] {
            b.case(&format!("full_run_10jobs_{}", alloc.name()), || {
                let mut spec = pingan::config::spec::PingAnSpec::with_epsilon(0.6);
                spec.allocation = alloc;
                let res = Simulation::new(&sys, jobs.clone(), SimConfig::default())
                    .run(&mut PingAn::new(spec));
                res.slots as f64
            });
        }
    }
}
