//! Pull-based workload intake.
//!
//! [`WorkloadSource`] is the intake half of the million-job replay
//! redesign: instead of materializing every [`JobSpec`] up front in a
//! `Vec` (O(jobs × tasks) memory before the first slot simulates), the
//! engine pulls jobs one at a time **in nondecreasing arrival order** and
//! admits each lazily when simulated time reaches its arrival slot.
//! Combined with slab recycling (`SimConfig::stream_metrics`), resident
//! state is O(clusters + alive jobs) regardless of trace length.
//!
//! Implementors:
//!
//! * [`EagerSource`] — wraps an existing `Vec<JobSpec>`; the adapter every
//!   pre-redesign call site routes through, bit-identical to the old
//!   eager path for the repo's generators (whose output is already in
//!   arrival order).
//! * [`GenSource`] — generates the Montage workload *incrementally*,
//!   replicating [`montage::generate`]'s RNG draw sequence job by job, so
//!   a 10⁶-job synthetic replay never holds more than one spec at a time.
//! * [`crate::workload::trace::TraceSource`] — parses an
//!   Azure-Functions-style CSV/JSONL arrival trace from disk.
//!
//! ## Ordering contract
//!
//! `next_job` must yield arrivals nondecreasing in `JobSpec::arrival`;
//! the engine assigns slab indices in pull order, debug-asserts
//! monotonicity, and panics (with the offending ids) in release builds
//! only inside `TraceSource`, where the data is externally supplied.

use super::job::JobSpec;
use super::montage;
use crate::config::spec::WorkloadSpec;
use crate::util::rng::Rng;

/// A pull-based stream of jobs in nondecreasing arrival order.
pub trait WorkloadSource {
    /// The next job, or `None` when the workload is exhausted.
    fn next_job(&mut self) -> Option<JobSpec>;

    /// Total job count when known up front (progress reporting and
    /// `SimResult::total_jobs` accounting for truncated runs); `None`
    /// for open-ended sources such as unsized traces.
    fn hint_total(&self) -> Option<usize>;
}

/// Adapter over an already-materialized workload `Vec`.
///
/// Jobs are yielded stable-sorted by arrival — for the repo's generators
/// (montage, testbed), whose output is already nondecreasing, this is the
/// identity permutation, so slab indices and hence Action streams match
/// the pre-redesign eager path bit for bit.
pub struct EagerSource {
    jobs: std::vec::IntoIter<JobSpec>,
    total: usize,
}

impl EagerSource {
    pub fn new(mut specs: Vec<JobSpec>) -> EagerSource {
        // stable: equal arrivals keep their original relative order,
        // matching the legacy engine's stable `sort_by_key` on arrival
        specs.sort_by_key(|j| j.arrival);
        let total = specs.len();
        EagerSource {
            jobs: specs.into_iter(),
            total,
        }
    }
}

impl WorkloadSource for EagerSource {
    fn next_job(&mut self) -> Option<JobSpec> {
        self.jobs.next()
    }

    fn hint_total(&self) -> Option<usize> {
        Some(self.total)
    }
}

/// Incremental Montage generator: the streaming twin of
/// [`montage::generate`].
///
/// Holds the same single [`Rng`] the batch generator uses and interleaves
/// the arrival-gap and DAG-body draws identically, so for any
/// `(spec, sites, seed)` the k-th job it yields is bit-identical to
/// `generate(...)[k]` — pinned by a test below — while never holding more
/// than the job being built.
pub struct GenSource {
    spec: WorkloadSpec,
    sites: Vec<usize>,
    rng: Rng,
    next_id: usize,
    t: f64,
}

impl GenSource {
    /// `seed` is the workload seed the batch path would have built its
    /// `Rng` from (the caller applies any env-seed mixing first).
    pub fn new(spec: WorkloadSpec, sites: Vec<usize>, seed: u64) -> GenSource {
        assert!(!sites.is_empty(), "need input sites");
        GenSource {
            spec,
            sites,
            rng: Rng::new(seed),
            next_id: 0,
            t: 0.0,
        }
    }
}

impl WorkloadSource for GenSource {
    fn next_job(&mut self) -> Option<JobSpec> {
        if self.next_id >= self.spec.n_jobs {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        // exact draw order of montage::generate's loop body
        self.t += self.rng.exponential(self.spec.lambda);
        let n_tasks = montage::draw_size(&self.spec, &mut self.rng);
        let job = montage::montage_dag(
            id,
            self.t as u64,
            n_tasks,
            &self.spec,
            &self.sites,
            &mut self.rng,
        );
        debug_assert!(job.validate().is_ok());
        Some(job)
    }

    fn hint_total(&self) -> Option<usize> {
        Some(self.spec.n_jobs)
    }
}

/// Drain a source into a `Vec` (tests and the few call sites that truly
/// need the whole workload, e.g. workload-summary analysis).
pub fn collect(source: &mut dyn WorkloadSource) -> Vec<JobSpec> {
    let mut out = Vec::with_capacity(source.hint_total().unwrap_or(0));
    while let Some(j) = source.next_job() {
        out.push(j);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn same_job(a: &JobSpec, b: &JobSpec) -> bool {
        a.id == b.id
            && a.name == b.name
            && a.arrival == b.arrival
            && a.n_tasks() == b.n_tasks()
            && a.total_datasize().to_bits() == b.total_datasize().to_bits()
            && a.tasks.iter().zip(&b.tasks).all(|(x, y)| {
                x.idx == y.idx
                    && x.op == y.op
                    && x.datasize.to_bits() == y.datasize.to_bits()
                    && x.deps == y.deps
                    && x.input_locations == y.input_locations
            })
    }

    #[test]
    fn eager_source_sorts_stably_and_hints_total() {
        let mk = |id: usize, arrival: u64| JobSpec {
            id,
            name: format!("j{id}"),
            arrival,
            tasks: vec![crate::workload::TaskSpec {
                idx: 0,
                op: crate::workload::OpKind::Map,
                datasize: 1.0,
                deps: vec![],
                input_locations: vec![0],
            }],
        };
        let mut src = EagerSource::new(vec![mk(0, 5), mk(1, 2), mk(2, 5), mk(3, 1)]);
        assert_eq!(src.hint_total(), Some(4));
        let order: Vec<(usize, u64)> = std::iter::from_fn(|| src.next_job())
            .map(|j| (j.id, j.arrival))
            .collect();
        // sorted by arrival; ids 0 and 2 (equal arrivals) keep input order
        assert_eq!(order, vec![(3, 1), (1, 2), (0, 5), (2, 5)]);
        assert_eq!(src.next_job().map(|j| j.id), None);
    }

    #[test]
    fn gen_source_is_bit_identical_to_batch_generate() {
        let spec = WorkloadSpec::scaled(60, 0.07);
        let sites = vec![0usize, 1, 2, 3];
        let batch = montage::generate(&spec, &sites, &mut Rng::new(909));
        let mut src = GenSource::new(spec, sites, 909);
        assert_eq!(src.hint_total(), Some(60));
        let streamed = collect(&mut src);
        assert_eq!(streamed.len(), batch.len());
        for (a, b) in streamed.iter().zip(&batch) {
            assert!(same_job(a, b), "job {} diverged", a.id);
        }
    }

    #[test]
    fn gen_source_arrivals_are_nondecreasing() {
        let mut src = GenSource::new(WorkloadSpec::scaled(200, 0.1), vec![0, 1], 7);
        let mut prev = 0u64;
        while let Some(j) = src.next_job() {
            assert!(j.arrival >= prev);
            prev = j.arrival;
        }
    }
}
