//! The slotted discrete-event engine.

use crate::cluster::GeoSystem;
use crate::perfmodel::PerfModel;
use crate::sched::{Action, Assignment, SchedView, Scheduler};
use crate::simulator::state::{CopyRt, JobRt, TaskState};
use crate::util::rng::Rng;
use crate::workload::job::JobSpec;

/// Engine knobs.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Hard wall on simulated slots (guards non-terminating policies).
    pub max_slots: u64,
    /// Grid resolution handed to the performance modeler.
    pub grid_bins: usize,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            max_slots: 2_000_000,
            grid_bins: 64,
            seed: 99,
        }
    }
}

/// Result of one run.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub scheduler: String,
    /// Per-job flowtimes f_i - a_i (slots), indexed like the input jobs.
    pub flowtimes: Vec<f64>,
    pub finished_jobs: usize,
    pub total_jobs: usize,
    /// Copies launched in total (resource-cost diagnostics).
    pub copies_launched: u64,
    /// Copies killed by cluster-level failures.
    pub copies_failed: u64,
    /// Slots simulated.
    pub slots: u64,
}

impl SimResult {
    pub fn avg_flowtime(&self) -> f64 {
        crate::util::stats::mean(&self.flowtimes)
    }

    pub fn sum_flowtime(&self) -> f64 {
        self.flowtimes.iter().sum()
    }
}

/// One simulation: a plant, a workload, a policy.
pub struct Simulation<'a> {
    pub system: &'a GeoSystem,
    pub jobs: Vec<JobRt>,
    pub model: PerfModel,
    now: u64,
    rng: Rng,
    cfg: SimConfig,
    /// Free slots per cluster (updated incrementally).
    free_slots: Vec<usize>,
    /// Occupied gate bandwidth per cluster this instant.
    ingress_used: Vec<f64>,
    egress_used: Vec<f64>,
    /// Alive (arrived, unfinished) job indices, maintained incrementally.
    alive: Vec<usize>,
    next_arrival_idx: usize,
    /// Arrival order (jobs sorted by arrival slot).
    arrival_order: Vec<usize>,
    copies_launched: u64,
    copies_failed: u64,
    /// Per-cluster congestion factor (AR(1), mean ~1). Models the paper's
    /// premise that edges overload *persistently* under dynamic user access
    /// patterns: a copy launched into an overloaded cluster is slow, and a
    /// restart there stays slow — straggling is autocorrelated, not i.i.d.
    load: Vec<f64>,
}

impl<'a> Simulation<'a> {
    pub fn new(system: &'a GeoSystem, specs: Vec<JobSpec>, cfg: SimConfig) -> Simulation<'a> {
        let model = PerfModel::new(system, cfg.grid_bins);
        let jobs: Vec<JobRt> = specs.into_iter().map(JobRt::new).collect();
        let mut arrival_order: Vec<usize> = (0..jobs.len()).collect();
        arrival_order.sort_by_key(|&i| jobs[i].spec.arrival);
        let free_slots = system.clusters.iter().map(|c| c.slots).collect();
        let n = system.n();
        Simulation {
            system,
            jobs,
            model,
            now: 0,
            rng: Rng::new(cfg.seed),
            cfg,
            free_slots,
            ingress_used: vec![0.0; n],
            egress_used: vec![0.0; n],
            alive: Vec::new(),
            next_arrival_idx: 0,
            arrival_order,
            copies_launched: 0,
            copies_failed: 0,
            load: vec![1.0; n],
        }
    }

    /// AR(1) congestion update: smaller clusters swing harder (Table-2
    /// scale classes; the paper's motivation is that *edges* overload).
    fn update_load(&mut self) {
        for m in 0..self.load.len() {
            let sigma = match self.system.clusters[m].scale {
                crate::topology::ClusterScale::Large => 0.25,
                crate::topology::ClusterScale::Medium => 0.5,
                crate::topology::ClusterScale::Small => 0.8,
            };
            let target = (sigma * self.rng.gauss()).exp();
            self.load[m] = (0.95 * self.load[m] + 0.05 * target).clamp(0.25, 4.0);
        }
    }

    pub fn now(&self) -> u64 {
        self.now
    }

    /// Run to completion (or `max_slots`) under `policy`.
    pub fn run(mut self, policy: &mut dyn Scheduler) -> SimResult {
        while self.next_arrival_idx < self.arrival_order.len() || !self.alive.is_empty() {
            if self.now >= self.cfg.max_slots {
                log::warn!(
                    "simulation hit max_slots={} with {} jobs alive",
                    self.cfg.max_slots,
                    self.alive.len()
                );
                break;
            }
            self.step(policy);
        }
        let flowtimes: Vec<f64> = self
            .jobs
            .iter()
            .map(|j| j.flowtime().map(|f| f as f64).unwrap_or(f64::NAN))
            .collect();
        let finished = self.jobs.iter().filter(|j| j.is_done()).count();
        SimResult {
            scheduler: policy.name().to_string(),
            flowtimes,
            finished_jobs: finished,
            total_jobs: self.jobs.len(),
            copies_launched: self.copies_launched,
            copies_failed: self.copies_failed,
            slots: self.now,
        }
    }

    /// One time slot: arrivals → failures → schedule → progress.
    pub fn step(&mut self, policy: &mut dyn Scheduler) {
        self.admit_arrivals();
        self.update_load();
        self.apply_failures();
        self.invoke_policy(policy);
        self.progress(policy);
        // fast-forward over idle gaps (no alive jobs, next arrival far away)
        self.now += 1;
        if self.alive.is_empty() {
            if let Some(&next) = self.arrival_order.get(self.next_arrival_idx) {
                let at = self.jobs[next].spec.arrival;
                if at > self.now {
                    self.now = at;
                }
            }
        }
    }

    fn admit_arrivals(&mut self) {
        while self.next_arrival_idx < self.arrival_order.len() {
            let j = self.arrival_order[self.next_arrival_idx];
            if self.jobs[j].spec.arrival > self.now {
                break;
            }
            self.jobs[j].arrived = true;
            self.alive.push(j);
            self.next_arrival_idx += 1;
        }
    }

    fn apply_failures(&mut self) {
        let failures = self.system.draw_failures(&mut self.rng);
        for (m, &failed) in failures.iter().enumerate() {
            self.model.observe_slot(m, failed);
        }
        let mut any = false;
        for &f in &failures {
            any |= f;
        }
        if !any {
            return;
        }
        for &ji in &self.alive.clone() {
            for ti in 0..self.jobs[ji].tasks.len() {
                let mut killed_any = false;
                {
                    let t = &mut self.jobs[ji].tasks[ti];
                    for c in t.copies.iter_mut().filter(|c| c.alive) {
                        if failures[c.cluster] {
                            c.alive = false;
                            killed_any = true;
                            self.copies_failed += 1;
                            self.free_slots[c.cluster] += 1;
                            self.ingress_used[c.cluster] -= c.ingress_bw;
                            for (s, bw) in &c.egress_bw {
                                self.egress_used[*s] -= bw;
                            }
                        }
                    }
                }
                if killed_any {
                    let t = &mut self.jobs[ji].tasks[ti];
                    if t.state == TaskState::Running && t.alive_copies() == 0 {
                        // the task survived nowhere: re-queue it
                        t.state = TaskState::Ready;
                        // progress is lost (copies restart from zero)
                        t.copies.retain(|c| c.alive);
                    }
                }
            }
        }
    }

    fn invoke_policy(&mut self, policy: &mut dyn Scheduler) {
        // Build the view with current headroom.
        let mut view = SchedView {
            now: self.now,
            system: self.system,
            model: &self.model,
            jobs: &self.jobs,
            alive: &self.alive,
            free_slots: self.free_slots.clone(),
            ingress_free: self
                .system
                .clusters
                .iter()
                .enumerate()
                .map(|(m, c)| (c.ingress - self.ingress_used[m]).max(0.0))
                .collect(),
            egress_free: self
                .system
                .clusters
                .iter()
                .enumerate()
                .map(|(m, c)| (c.egress - self.egress_used[m]).max(0.0))
                .collect(),
        };
        let actions = policy.schedule(&mut view);
        for action in actions {
            match action {
                Action::Launch(a) => self.launch_copy(a),
                Action::Kill { job, task, cluster } => self.kill_copy(job, task, cluster),
            }
        }
    }

    /// Validate and launch one copy (engine-enforced Eqs. 9–11).
    fn launch_copy(&mut self, a: Assignment) {
        let Assignment { job, task, cluster } = a;
        if job >= self.jobs.len() || task >= self.jobs[job].tasks.len() {
            log::error!("policy referenced bogus task ({job},{task})");
            return;
        }
        if self.free_slots[cluster] == 0 {
            return; // slot cap (Eq. 9)
        }
        let (op, datasize) = {
            let spec = &self.jobs[job].spec.tasks[task];
            (spec.op, spec.datasize)
        };
        let _ = datasize;
        let t = &self.jobs[job].tasks[task];
        if !matches!(t.state, TaskState::Ready | TaskState::Running) {
            return;
        }
        let sources = t.sources.clone();
        // true draws, attenuated by the cluster's current congestion
        let proc = self.system.clusters[cluster].draw_power(op.speed_skew(), &mut self.rng)
            / self.load[cluster];
        let remote: Vec<usize> = sources.iter().copied().filter(|&s| s != cluster).collect();
        let trans = if sources.is_empty() {
            f64::INFINITY
        } else {
            let mut sum = 0.0;
            for &s in &sources {
                sum += self.system.draw_wan(s, cluster, &mut self.rng);
            }
            sum / sources.len() as f64
        };
        let mut rate = proc.min(trans).max(1e-6);
        // Gate bandwidth (Eqs. 10/11): the copy's remote stream is the
        // fraction of its rate fetched over the WAN. Gates are *physical
        // caps*: a stream that would exceed the remaining headroom is
        // clamped — the copy launches slower instead of being rejected
        // (rejecting would livelock policies whose only floor-admissible
        // cluster needs more than the gate's total capacity).
        let (ing_bw, eg_bw) = if remote.is_empty() {
            (0.0, Vec::new())
        } else {
            let remote_frac = remote.len() as f64 / sources.len() as f64;
            let want_stream = rate * remote_frac;
            let ing_head = (self.system.clusters[cluster].ingress
                - self.ingress_used[cluster])
                .max(0.0);
            let eg_head = remote
                .iter()
                .map(|&s| (self.system.clusters[s].egress - self.egress_used[s]).max(0.0))
                .fold(f64::INFINITY, f64::min);
            let allowed = want_stream
                .min(ing_head)
                .min(eg_head * remote.len() as f64);
            // The stream may clamp against the gate's *capacity* (a physical
            // limit — launch slower) but not against *transient* congestion:
            // a copy squeezed below 20% of its feasible stream would crawl
            // uselessly while holding a slot, so reject and let the policy
            // retry once the gates drain.
            let ing_cap = self.system.clusters[cluster].ingress;
            let eg_cap = remote
                .iter()
                .map(|&s| self.system.clusters[s].egress)
                .fold(f64::INFINITY, f64::min);
            let cap_stream = want_stream.min(ing_cap).min(eg_cap * remote.len() as f64);
            if allowed < 0.2 * cap_stream {
                return; // gates transiently full (Eqs. 10/11)
            }
            if allowed < want_stream {
                // the whole pipeline slows to the clamped stream
                rate = (rate * allowed / want_stream.max(1e-12)).max(1e-3);
            }
            let stream = allowed.max(0.0);
            let share = stream / remote.len() as f64;
            (stream, remote.iter().map(|&s| (s, share)).collect())
        };
        self.free_slots[cluster] -= 1;
        self.ingress_used[cluster] += ing_bw;
        for (s, bw) in &eg_bw {
            self.egress_used[*s] += bw;
        }
        let t = &mut self.jobs[job].tasks[task];
        t.copies.push(CopyRt {
            cluster,
            rate,
            proc_speed: proc,
            trans_speed: if trans.is_finite() { trans } else { proc },
            processed: 0.0,
            launched_at: self.now,
            alive: true,
            ingress_bw: ing_bw,
            egress_bw: eg_bw,
        });
        t.state = TaskState::Running;
        self.copies_launched += 1;
    }

    fn kill_copy(&mut self, job: usize, task: usize, cluster: usize) {
        if job >= self.jobs.len() || task >= self.jobs[job].tasks.len() {
            return;
        }
        let t = &mut self.jobs[job].tasks[task];
        if let Some(c) = t
            .copies
            .iter_mut()
            .find(|c| c.alive && c.cluster == cluster)
        {
            c.alive = false;
            self.free_slots[cluster] += 1;
            self.ingress_used[cluster] -= c.ingress_bw;
            for (s, bw) in &c.egress_bw {
                self.egress_used[*s] -= bw;
            }
            if t.alive_copies() == 0 && t.state == TaskState::Running {
                t.state = TaskState::Ready;
            }
        }
    }

    /// Advance every alive copy by one slot; fire completions.
    fn progress(&mut self, policy: &mut dyn Scheduler) {
        let mut completions: Vec<(usize, usize)> = Vec::new();
        for &ji in &self.alive {
            let job = &mut self.jobs[ji];
            for (ti, t) in job.tasks.iter_mut().enumerate() {
                if t.state != TaskState::Running {
                    continue;
                }
                let datasize = job.spec.tasks[ti].datasize;
                let mut done = false;
                for c in t.copies.iter_mut().filter(|c| c.alive) {
                    c.processed += c.rate;
                    if c.processed >= datasize {
                        done = true;
                    }
                }
                if done {
                    completions.push((ji, ti));
                }
            }
        }
        for (ji, ti) in completions {
            self.complete_task(ji, ti);
            policy.on_task_done(ji, ti, self.now);
        }
        // retire finished jobs from the alive set
        let jobs = &self.jobs;
        self.alive.retain(|&ji| !jobs[ji].is_done());
    }

    fn complete_task(&mut self, ji: usize, ti: usize) {
        // pick the winner (most processed; ties by rate)
        let (winner_cluster, winner_proc, winner_trans, sources) = {
            let t = &self.jobs[ji].tasks[ti];
            let w = t
                .copies
                .iter()
                .filter(|c| c.alive)
                .max_by(|a, b| a.processed.partial_cmp(&b.processed).unwrap())
                .expect("completion without alive copy");
            (w.cluster, w.proc_speed, w.trans_speed, t.sources.clone())
        };
        let op = self.jobs[ji].spec.tasks[ti].op;
        // report execution information (Fig 1b): processing + transfer speeds
        self.model.observe_proc(winner_cluster, op, winner_proc);
        for &s in &sources {
            if s != winner_cluster {
                self.model.observe_trans(s, winner_cluster, winner_trans);
            }
        }
        // free all copies
        {
            let t = &mut self.jobs[ji].tasks[ti];
            for c in t.copies.iter_mut().filter(|c| c.alive) {
                c.alive = false;
                self.free_slots[c.cluster] += 1;
                self.ingress_used[c.cluster] -= c.ingress_bw;
                for (s, bw) in &c.egress_bw {
                    self.egress_used[*s] -= bw;
                }
            }
            t.state = TaskState::Done;
            t.done_at = Some(self.now);
            t.output_cluster = Some(winner_cluster);
        }
        // propagate readiness (Eq. 8) and record intermediate data location
        let n_tasks = self.jobs[ji].tasks.len();
        for di in (ti + 1)..n_tasks {
            let depends = self.jobs[ji].spec.tasks[di].deps.contains(&ti);
            if !depends {
                continue;
            }
            let d = &mut self.jobs[ji].tasks[di];
            // input locations form a *set* (the paper's I_l^i): dedup so
            // wide fan-in tasks don't blow up the transfer-average math
            if !d.sources.contains(&winner_cluster) {
                d.sources.push(winner_cluster);
            }
            d.n_deps_left -= 1;
            if d.n_deps_left == 0 && d.state == TaskState::Blocked {
                d.state = TaskState::Ready;
                d.ready_at = Some(self.now);
            }
        }
        // job completion (Eq. 12)
        if self.jobs[ji].tasks.iter().all(|t| t.state == TaskState::Done) {
            self.jobs[ji].done_at = Some(self.now);
        }
    }

    /// Diagnostics for tests: current gate-usage invariant check.
    pub fn check_invariants(&self) -> Result<(), String> {
        for (m, c) in self.system.clusters.iter().enumerate() {
            let used = c.slots - self.free_slots[m];
            let running: usize = self
                .jobs
                .iter()
                .flat_map(|j| &j.tasks)
                .flat_map(|t| &t.copies)
                .filter(|cp| cp.alive && cp.cluster == m)
                .count();
            if used != running {
                return Err(format!(
                    "cluster {m}: slot ledger {used} != alive copies {running}"
                ));
            }
            if self.ingress_used[m] > c.ingress + 1e-6 {
                return Err(format!("cluster {m}: ingress oversubscribed"));
            }
            if self.egress_used[m] > c.egress + 1e-6 {
                return Err(format!("cluster {m}: egress oversubscribed"));
            }
            // ledgers must equal the recomputed footprint of alive copies
            let ing_true: f64 = self
                .jobs
                .iter()
                .flat_map(|j| &j.tasks)
                .flat_map(|t| &t.copies)
                .filter(|cp| cp.alive && cp.cluster == m)
                .map(|cp| cp.ingress_bw)
                .sum();
            if (self.ingress_used[m] - ing_true).abs() > 1e-6 {
                return Err(format!(
                    "cluster {m}: ingress ledger {} != recomputed {}",
                    self.ingress_used[m], ing_true
                ));
            }
            let eg_true: f64 = self
                .jobs
                .iter()
                .flat_map(|j| &j.tasks)
                .flat_map(|t| &t.copies)
                .filter(|cp| cp.alive)
                .flat_map(|cp| cp.egress_bw.iter())
                .filter(|(s, _)| *s == m)
                .map(|(_, bw)| bw)
                .sum();
            if (self.egress_used[m] - eg_true).abs() > 1e-6 {
                return Err(format!(
                    "cluster {m}: egress ledger {} != recomputed {}",
                    self.egress_used[m], eg_true
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::{SystemSpec, WorkloadSpec};
    use crate::workload::montage;

    /// Greedy one-copy policy used to exercise the engine.
    struct GreedyLocal;

    impl Scheduler for GreedyLocal {
        fn name(&self) -> &str {
            "greedy-local"
        }

        fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
            let mut out = Vec::new();
            for &ji in view.alive {
                for ti in view.ready_tasks(ji) {
                    let sources = view.jobs[ji].tasks[ti].sources.clone();
                    // best estimated cluster with a free slot
                    let op = view.jobs[ji].spec.tasks[ti].op;
                    let mut best: Option<(f64, usize)> = None;
                    for m in 0..view.system.n() {
                        if view.free_slots[m] == 0 {
                            continue;
                        }
                        let r = view.model.exp_rate1(&sources, m, op);
                        if best.map(|(b, _)| r > b).unwrap_or(true) {
                            best = Some((r, m));
                        }
                    }
                    if let Some((r, m)) = best {
                        if view.try_reserve_slot(m)
                            && view.try_reserve_bandwidth(&sources, m, r)
                        {
                            out.push(Action::Launch(Assignment {
                                job: ji,
                                task: ti,
                                cluster: m,
                            }));
                        }
                    }
                }
            }
            out
        }
    }

    fn small_setup(n_jobs: usize) -> (GeoSystem, Vec<crate::workload::job::JobSpec>) {
        let mut rng = Rng::new(41);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut wspec = WorkloadSpec::scaled(n_jobs, 0.05);
        wspec.datasize = (50.0, 400.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&wspec, &sites, &mut rng);
        (sys, jobs)
    }

    #[test]
    fn all_jobs_finish_under_greedy() {
        let (sys, jobs) = small_setup(12);
        let sim = Simulation::new(&sys, jobs, SimConfig::default());
        let res = sim.run(&mut GreedyLocal);
        assert_eq!(res.finished_jobs, res.total_jobs, "unfinished jobs");
        for f in &res.flowtimes {
            assert!(f.is_finite() && *f >= 0.0);
        }
        assert!(res.copies_launched > 0);
    }

    #[test]
    fn invariants_hold_mid_run() {
        let (sys, jobs) = small_setup(8);
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let mut policy = GreedyLocal;
        for _ in 0..200 {
            sim.step(&mut policy);
            sim.check_invariants().unwrap();
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (sys, jobs) = small_setup(6);
        let r1 = Simulation::new(&sys, jobs.clone(), SimConfig::default()).run(&mut GreedyLocal);
        let r2 = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut GreedyLocal);
        assert_eq!(r1.flowtimes, r2.flowtimes);
        assert_eq!(r1.copies_launched, r2.copies_launched);
    }

    #[test]
    fn no_progress_without_policy_action() {
        struct Idle;
        impl Scheduler for Idle {
            fn name(&self) -> &str {
                "idle"
            }
            fn schedule(&mut self, _v: &mut SchedView<'_>) -> Vec<Action> {
                vec![]
            }
        }
        let (sys, jobs) = small_setup(2);
        let mut cfg = SimConfig::default();
        cfg.max_slots = 500;
        let res = Simulation::new(&sys, jobs, cfg).run(&mut Idle);
        assert_eq!(res.finished_jobs, 0);
    }

    #[test]
    fn failures_are_survivable() {
        // crank failure probabilities: jobs must still finish because the
        // engine re-queues orphaned tasks.
        let mut rng = Rng::new(43);
        let mut spec = SystemSpec::small(5);
        for c in &mut spec.classes {
            // Table-2 p is per ~20-slot task epoch; crank it so per-slot
            // failures are frequent enough to exercise the kill path
            c.unreach_p = (0.9, 0.95);
        }
        let sys = GeoSystem::generate(&spec, &mut rng);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let mut wspec = WorkloadSpec::scaled(12, 0.05);
        wspec.datasize = (800.0, 2000.0); // long tasks: real failure exposure
        let jobs = montage::generate(&wspec, &sites, &mut rng);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut GreedyLocal);
        assert_eq!(res.finished_jobs, res.total_jobs);
        assert!(res.copies_failed > 0, "expected some failure kills");
    }

    #[test]
    fn bogus_actions_are_rejected() {
        struct Bogus;
        impl Scheduler for Bogus {
            fn name(&self) -> &str {
                "bogus"
            }
            fn schedule(&mut self, v: &mut SchedView<'_>) -> Vec<Action> {
                vec![
                    Action::Launch(Assignment {
                        job: 999,
                        task: 0,
                        cluster: 0,
                    }),
                    Action::Kill {
                        job: 999,
                        task: 9,
                        cluster: 0,
                    },
                    // valid-shaped launch onto a Blocked task must be dropped
                    Action::Launch(Assignment {
                        job: *v.alive.first().unwrap_or(&0),
                        task: usize::MAX - 1,
                        cluster: 0,
                    }),
                ]
            }
        }
        let (sys, jobs) = small_setup(2);
        let mut cfg = SimConfig::default();
        cfg.max_slots = 50;
        let mut sim = Simulation::new(&sys, jobs, cfg);
        let mut p = Bogus;
        for _ in 0..50 {
            sim.step(&mut p);
            sim.check_invariants().unwrap();
        }
    }
}
