//! ε-tuning walkthrough (the Sec 6.4 experiment at example scale): sweep
//! ε against arrival rate λ and print the best ε per load, next to the
//! paper's hint table.
//!
//! ```bash
//! cargo run --release --example epsilon_tuning
//! ```

use pingan::config::spec::PingAnSpec;
use pingan::experiments::{figures, Scale};

fn main() {
    let scale = Scale::smoke();
    let lambdas = [0.02, 0.07, 0.15];
    let epsilons = [0.2, 0.4, 0.6, 0.8];
    println!(
        "sweeping ε over λ ({} jobs, {} clusters, {} rep(s))\n",
        scale.n_jobs, scale.n_clusters, scale.reps
    );
    let rows = figures::run_fig7(&scale, &lambdas, &epsilons);
    print!("{}", figures::fig7_table(&rows));

    println!("\npaper's hint (Sec 6.4) vs this run:");
    for &l in &lambdas {
        let best = rows
            .iter()
            .filter(|r| r.0 == l)
            .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        println!(
            "  λ={:<5} paper ε={:<4} measured best ε={}",
            l,
            PingAnSpec::epsilon_hint(l),
            best.1
        );
    }
}
