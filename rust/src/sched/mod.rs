//! Scheduler interface shared by PingAn and every baseline.
//!
//! At each *policy epoch* the engine hands the active scheduler a
//! [`SchedView`] — alive jobs, task states, per-cluster free slots,
//! gate-bandwidth headroom and the performance modeler's estimates — and
//! receives a list of [`Action`]s: copy launches (insurances) and copy
//! kills (speculative restarts). The engine validates every action against
//! Eqs. (9)–(11) before applying it, so a buggy policy cannot
//! oversubscribe the plant.
//!
//! ## Epoch-driven invocation
//!
//! Under the dense time core a policy epoch is every simulated slot.
//! Under the event-skip core epochs fire only when something changed — an
//! arrival, a completion, a failure — so `now` *jumps* between
//! invocations ([`SchedView::elapsed`] reports by how much). Policies
//! must therefore derive decisions from absolute state (task ages,
//! progress, ledgers), never from invocation counts. A policy whose value
//! depends on time passing with no event in between (progress monitors,
//! delay scheduling) returns its next deadline from
//! [`Scheduler::next_wake`] and gets a `PolicyEpoch` event there.

use crate::cluster::GeoSystem;
use crate::config::spec::BandwidthModel;
use crate::perfmodel::PerfModel;
use crate::simulator::shard::EngineShards;
use crate::simulator::state::{JobRt, TaskState};

/// Launch a (possibly extra) copy of `task` of `job` in `cluster`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    pub job: usize,
    pub task: usize,
    pub cluster: usize,
}

/// An action a scheduler may request this slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    Launch(Assignment),
    /// Kill the copy of (`job`,`task`) running in `cluster` (speculative
    /// restart mechanisms such as Mantri).
    Kill {
        job: usize,
        task: usize,
        cluster: usize,
    },
}

/// Everything a policy may look at, plus a ledger for intra-slot accounting.
pub struct SchedView<'a> {
    pub now: u64,
    /// Slots since the previous policy invocation: 0 on the first and on
    /// repeated same-slot epochs, 1 between consecutive dense slots, and
    /// arbitrarily large across jumps (dense idle fast-forward or
    /// event-skip). Interval-style logic ("every k slots") must reason
    /// over this — or over absolute `now` — rather than count invocations.
    pub elapsed: u64,
    pub system: &'a GeoSystem,
    pub model: &'a PerfModel,
    pub jobs: &'a [JobRt],
    /// Indices of alive (arrived, not finished) jobs.
    pub alive: &'a [usize],
    /// Thread budget (≥ 1) the policy may spend on intra-epoch scoring —
    /// `SimConfig::score_threads`, plumbed through by the engine. PingAn
    /// shards its per-round `ScoreBatch` across this many OS threads.
    /// Contract: decisions must be bit-identical at any value; only wall
    /// time may change (the determinism suite sweeps it to prove that).
    pub score_threads: usize,
    /// Which bandwidth physics the run uses. Under
    /// [`BandwidthModel::Shared`] a copy's `rate` is the fair-share
    /// solver's *current* allocation (see [`Self::task_rate`]), re-rated
    /// at every policy-epoch barrier; under `Constant` it is the launch
    /// draw, forever.
    pub bandwidth_model: BandwidthModel,
    /// Free slots per cluster after currently-running copies.
    pub free_slots: Vec<usize>,
    /// Remaining ingress gate bandwidth per cluster this slot.
    pub ingress_free: Vec<f64>,
    /// Remaining egress gate bandwidth per cluster.
    pub egress_free: Vec<f64>,
}

impl<'a> SchedView<'a> {
    /// Read-only facade over the engine's cluster shards: snapshot the
    /// per-cluster free slots and gate headroom out of the shard ledgers
    /// (merged in cluster order) into the owned working vectors the
    /// `try_reserve_*` accounting mutates. Policies see the exact logical
    /// view the monolithic engine built, at any shard count.
    #[allow(clippy::too_many_arguments)]
    pub fn over_shards(
        now: u64,
        elapsed: u64,
        system: &'a GeoSystem,
        model: &'a PerfModel,
        jobs: &'a [JobRt],
        alive: &'a [usize],
        score_threads: usize,
        bandwidth_model: BandwidthModel,
        shards: &EngineShards,
    ) -> SchedView<'a> {
        SchedView {
            now,
            elapsed,
            system,
            model,
            jobs,
            alive,
            score_threads: score_threads.max(1),
            bandwidth_model,
            free_slots: shards.snapshot_free_slots(),
            ingress_free: shards.snapshot_ingress_free(system),
            egress_free: shards.snapshot_egress_free(system),
        }
    }

    /// Total free slots across the plant.
    pub fn total_free(&self) -> usize {
        self.free_slots.iter().sum()
    }

    /// Ready (runnable, no alive copy) tasks of a job.
    pub fn ready_tasks(&self, job: usize) -> Vec<usize> {
        self.jobs[job]
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.state == TaskState::Ready && t.alive_copies() == 0)
            .map(|(i, _)| i)
            .collect()
    }

    /// Tasks currently running (with at least one alive copy).
    pub fn running_tasks(&self, job: usize) -> Vec<usize> {
        self.jobs[job]
            .tasks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.state == TaskState::Running)
            .map(|(i, _)| i)
            .collect()
    }

    /// Unprocessed datasize of a job's current frontier (the paper's job
    /// priority key: jobs are ordered by least unprocessed data).
    pub fn unprocessed(&self, job: usize) -> f64 {
        self.jobs[job].unprocessed()
    }

    /// Fastest *current* rate among a task's alive copies, or `None` when
    /// none is alive. Under the shared bandwidth model this is the
    /// fair-share allocation as of the last epoch barrier — the rate
    /// visibility policies need to tell a contention-starved copy from a
    /// genuinely slow one before killing or re-insuring it.
    pub fn task_rate(&self, job: usize, task: usize) -> Option<f64> {
        self.jobs[job].tasks[task]
            .copies
            .iter()
            .filter(|c| c.alive)
            .map(|c| c.rate)
            .fold(None, |acc, r| Some(acc.map_or(r, |a: f64| a.max(r))))
    }

    /// The bandwidth a copy would occupy: the remote fraction of its
    /// estimated rate on the ingress of the running cluster, split over
    /// remote sources' egress. Returns (ingress_need, per-source egress).
    pub fn bandwidth_need(
        &self,
        sources: &[usize],
        cluster: usize,
        est_rate: f64,
    ) -> (f64, Vec<(usize, f64)>) {
        let remote: Vec<usize> = sources
            .iter()
            .copied()
            .filter(|&s| s != cluster)
            .collect();
        if remote.is_empty() || sources.is_empty() {
            return (0.0, vec![]);
        }
        let stream = est_rate * remote.len() as f64 / sources.len() as f64;
        let share = stream / remote.len() as f64;
        (stream, remote.into_iter().map(|s| (s, share)).collect())
    }

    /// Minimum fraction of the desired stream that must fit for a copy to
    /// be worth launching; below this the clamped copy would crawl.
    pub const MIN_STREAM_FRACTION: f64 = 0.25;

    /// Check Eqs. (10)/(11) headroom for a prospective copy. Gates clamp
    /// rather than reject (mirroring the engine): the reservation succeeds
    /// when at least [`Self::MIN_STREAM_FRACTION`] of the stream fits, and
    /// debits the clamped amount. *Essential* (first) copies use this —
    /// they must land somewhere or the task livelocks.
    pub fn try_reserve_bandwidth(
        &mut self,
        sources: &[usize],
        cluster: usize,
        est_rate: f64,
    ) -> bool {
        self.try_reserve_bandwidth_min(sources, cluster, est_rate, Self::MIN_STREAM_FRACTION)
    }

    /// Reservation for *extra* (insurance/speculation/clone) copies: they
    /// must fit entirely (`min_fraction = 1.0`) — a clamped extra copy
    /// crawls uselessly while starving other tasks' primary streams.
    pub fn try_reserve_bandwidth_full(
        &mut self,
        sources: &[usize],
        cluster: usize,
        est_rate: f64,
    ) -> bool {
        self.try_reserve_bandwidth_min(sources, cluster, est_rate, 0.999)
    }

    /// Core reservation with an explicit minimum-fit fraction.
    pub fn try_reserve_bandwidth_min(
        &mut self,
        sources: &[usize],
        cluster: usize,
        est_rate: f64,
        min_fraction: f64,
    ) -> bool {
        let (ing, egs) = self.bandwidth_need(sources, cluster, est_rate);
        if ing == 0.0 {
            return true;
        }
        let mut feasible: f64 = (self.ingress_free[cluster] / ing).min(1.0);
        for (s, need) in &egs {
            feasible = feasible.min(self.egress_free[*s] / need);
        }
        if feasible < min_fraction {
            return false;
        }
        self.ingress_free[cluster] = (self.ingress_free[cluster] - feasible * ing).max(0.0);
        for (s, need) in egs {
            self.egress_free[s] = (self.egress_free[s] - feasible * need).max(0.0);
        }
        true
    }

    /// Debit one slot in `cluster`; false if none free.
    pub fn try_reserve_slot(&mut self, cluster: usize) -> bool {
        if self.free_slots[cluster] == 0 {
            return false;
        }
        self.free_slots[cluster] -= 1;
        true
    }
}

/// A scheduling policy. One instance drives one simulation run.
pub trait Scheduler {
    fn name(&self) -> &str;

    /// Called once per policy epoch (every slot under the dense core;
    /// every event under event-skip). Returns the actions to apply.
    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action>;

    /// Notification: task (job, task) completed at `now`. Policies with
    /// internal progress trackers (Mantri, speculation) use this.
    fn on_task_done(&mut self, _job: usize, _task: usize, _now: u64) {}

    /// Notification: `job` (a slab index) finished all tasks and is being
    /// retired. Fired exactly once per job, in completion order, right
    /// after the final `on_task_done`. Policies keeping per-job maps
    /// (delay-scheduling first-seen stamps, speculation duration samples)
    /// must drop that job's entries here — under `stream_metrics` the
    /// engine recycles slab indices, so stale entries would both leak
    /// memory on million-job replays *and* corrupt the recycled job's
    /// state. Default: nothing retained, nothing to drop.
    fn on_job_retired(&mut self, _job: usize) {}

    /// Wake hint for the event-skip core, asked right after `schedule`:
    /// the absolute slot at which the policy wants an extra epoch even if
    /// no event fires before then (progress monitors, locality delays).
    /// `None` (the default) means event-driven epochs suffice. Times in
    /// the past are clamped to `now + 1`; the dense core ignores this.
    fn next_wake(&mut self, _now: u64) -> Option<u64> {
        None
    }

    /// Plane-A telemetry: the policy's deterministic decision counters
    /// (rounds, rows scored, admissions/rejections by reason). The engine
    /// merges them into [`crate::simulator::SimResult::telemetry`] at end
    /// of run. `None` (the default) means the policy keeps no counters.
    fn telemetry(&self) -> Option<&crate::obs::Counters> {
        None
    }

    /// Plane-B telemetry: the engine hands its shared span histograms to
    /// the policy at run start so scorer batch fill/exec timings land in
    /// the same wall-clock snapshot. Default: drop them (no spans kept).
    fn attach_spans(&mut self, _spans: std::sync::Arc<crate::obs::Spans>) {}

    /// Attach an opt-in per-decision trace sink (`--trace-file`). The
    /// sink only observes decisions already made — attaching one must
    /// never change the Action stream. Default: ignore it.
    fn set_trace(&mut self, _sink: crate::obs::TraceSink) {}
}

/// Boxed schedulers forward the whole trait, hooks included — decorators
/// wrapping a factory-built `Box<dyn Scheduler>` must not silently drop
/// `next_wake`/`on_task_done`.
impl Scheduler for Box<dyn Scheduler + '_> {
    fn name(&self) -> &str {
        (**self).name()
    }

    fn schedule(&mut self, view: &mut SchedView<'_>) -> Vec<Action> {
        (**self).schedule(view)
    }

    fn on_task_done(&mut self, job: usize, task: usize, now: u64) {
        (**self).on_task_done(job, task, now)
    }

    fn on_job_retired(&mut self, job: usize) {
        (**self).on_job_retired(job)
    }

    fn next_wake(&mut self, now: u64) -> Option<u64> {
        (**self).next_wake(now)
    }

    fn telemetry(&self) -> Option<&crate::obs::Counters> {
        (**self).telemetry()
    }

    fn attach_spans(&mut self, spans: std::sync::Arc<crate::obs::Spans>) {
        (**self).attach_spans(spans)
    }

    fn set_trace(&mut self, sink: crate::obs::TraceSink) {
        (**self).set_trace(sink)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_need_local_is_free() {
        // A synthetic view is cumbersome to build here; bandwidth_need is
        // pure arithmetic so we exercise it through a tiny helper struct in
        // the simulator integration tests. Here: the remote-split math.
        let remote = [0usize, 1, 2];
        let est = 9.0;
        let share = est / remote.len() as f64;
        assert!((share - 3.0).abs() < 1e-12);
    }
}
