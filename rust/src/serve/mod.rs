//! `pingan serve` — the online half of the online algorithm.
//!
//! Long-lived service mode: a TCP listener accepts newline-delimited
//! JSON job submissions (the same row grammar as JSONL traces — see
//! [`crate::workload::trace::parse_jsonl_row`]), materializes each row
//! into a DAG job through the id-keyed [`JobBuilder`], and feeds it to a
//! live engine over a [`ChannelSource`](crate::workload::ChannelSource).
//! The engine runs on its own thread against the same plant, scheduler
//! and insurer a `pingan replay` of the identical scenario would use;
//! only the intake differs.
//!
//! # Wire protocol
//!
//! One line in, one line out, per connection:
//!
//! * a JSON object row (`{"arrival":12,"tasks":40,...}`) → submission.
//!   Response `{"ok":true,"id":N,"arrival":A}`, or
//!   `{"ok":false,"error":"trace: line ...: ..."}` on a malformed row —
//!   the same [`TraceError`](crate::workload::TraceError) text `replay`
//!   would panic with, demoted to a per-submission error. The server
//!   keeps running either way.
//! * the literal line `/stats` → one JSON line of live statistics
//!   (`"event":"stats"`), answered mid-run without pausing the engine.
//! * the literal line `/shutdown` → graceful drain: intake closes, jobs
//!   already in flight finish, final statistics print to stdout, exit 0.
//!   `SIGINT`/`SIGTERM` trigger the identical sequence.
//!
//! # Time, and what the latency numbers mean
//!
//! The engine still runs in *virtual* slot time; serve paces it against
//! the wall by stamping each submission's arrival as
//! `max(row.arrival, elapsed_ms)` (1 slot ≈ 1 ms — an approximate
//! pacer, not a hard real-time claim). The first-class online metric is
//! instead the server's own **decision latency**: every scheduler
//! invocation is timed into the shared [`SpanKind::Sched`] histogram,
//! and `/stats` reports live p50/p99/max plus rounds/sec from it.
//!
//! # The two-plane rule, observed
//!
//! Everything `/stats` reports is *monitoring-plane* output. Plane-A
//! counters reach it through an [`CountersCell`] mirror the engine
//! republishes at each policy epoch — the counters the simulation
//! itself reports stay plain fields, untouched. Plane-B wall spans were
//! already quarantined from deterministic output; serve is their first
//! live consumer. Nothing the stats path reads ever feeds back into a
//! scheduling decision.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::spec::{TimeModel, WorkloadSpec};
use crate::obs::{CountersCell, SpanKind, Spans};
use crate::simulator::{SimConfig, Simulation};
use crate::sweep::Scenario;
use crate::util::jsonout::Json;
use crate::util::rng::Rng;
use crate::workload::source::{self, JobSender};
use crate::workload::trace::{parse_jsonl_row, JobBuilder};

/// Signal plumbing: `SIGINT`/`SIGTERM` flip one process-wide flag the
/// accept loop polls, turning both into the same graceful drain as a
/// `/shutdown` line. Declared against libc's `signal(2)` directly — the
/// one C call this crate makes — with a typed handler so no function
/// pointer is ever cast through an integer.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static STOP: AtomicBool = AtomicBool::new(false);

    type SigHandler = extern "C" fn(i32);

    extern "C" {
        fn signal(signum: i32, handler: SigHandler) -> isize;
    }

    extern "C" fn on_signal(_signum: i32) {
        // async-signal-safe: one atomic store, nothing else
        STOP.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            let _ = signal(SIGINT, on_signal);
            let _ = signal(SIGTERM, on_signal);
        }
    }

    pub fn stop_requested() -> bool {
        STOP.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install() {}
    pub fn stop_requested() -> bool {
        false
    }
}

/// Everything `pingan serve` needs beyond the listener address comes as
/// a fully-resolved [`Scenario`] plus the engine config — the same pair
/// `pingan replay` resolves from its flags, so a serve session and a
/// replay of the same coordinates face the identical plant and policy.
pub struct ServeOpts {
    /// `host:port` to bind (port 0 picks a free one; the bound address
    /// is announced on stdout as a `{"event":"serving",...}` line).
    pub listen: String,
    /// Self-drive mode: replay this JSONL trace against our own
    /// listener at full speed, print the resulting `/stats` line, then
    /// shut down. The serve-smoke CI leg runs exactly this.
    pub drive: Option<String>,
    pub scenario: Scenario,
    pub cfg: SimConfig,
}

/// What the engine thread hands back after the drain.
struct EngineReport {
    finished: usize,
    total: usize,
    slots: u64,
    events: u64,
}

/// State shared between connection handlers, the accept loop, and the
/// stats path. Handlers never own a [`JobSender`] clone — every send
/// goes through the mutex — so taking the one sender out is all a
/// graceful drain needs to close the intake.
struct Shared {
    intake: Mutex<Option<JobSender>>,
    builder: Mutex<JobBuilder>,
    submitted: AtomicU64,
    parse_errors: AtomicU64,
    stop: AtomicBool,
    start: Instant,
    spans: Arc<Spans>,
    cell: Arc<CountersCell>,
}

impl Shared {
    fn should_stop(&self) -> bool {
        self.stop.load(Ordering::SeqCst) || sig::stop_requested()
    }

    /// Live statistics as one JSON object. All monitoring-plane: the
    /// counters come from the engine's republished mirror, the latency
    /// percentiles from the shared Plane-B span sheet.
    fn stats_json(&self, event: &str) -> Json {
        let c = self.cell.load();
        let snap = self.spans.snapshot();
        let uptime = self.start.elapsed().as_secs_f64();
        let invocations = c.policy_invocations as f64;
        let mut j = Json::obj();
        j.set("event", Json::str(event))
            .set("ok", Json::Bool(true))
            .set("uptime_secs", Json::num(uptime))
            .set(
                "submitted",
                Json::num(self.submitted.load(Ordering::Relaxed) as f64),
            )
            .set(
                "parse_errors",
                Json::num(self.parse_errors.load(Ordering::Relaxed) as f64),
            )
            // jobs admitted into the engine's alive set
            .set("admissions", Json::num(c.ev_arrivals as f64))
            .set("completions", Json::num(c.ev_completions as f64))
            // the insurer's own admission/rejection ledger
            .set("insurer_admissions", Json::num(c.admissions as f64))
            .set("rejections", Json::num(c.rejections() as f64))
            .set("policy_invocations", Json::num(invocations));
        if let Some(sched) = snap.get(SpanKind::Sched) {
            let per_sec = if uptime > 0.0 {
                sched.count as f64 / uptime
            } else {
                0.0
            };
            j.set("rounds", Json::num(sched.count as f64))
                .set("rounds_per_sec", Json::num(per_sec))
                .set("sched_p50_ms", Json::num(sched.p50_secs * 1e3))
                .set("sched_p99_ms", Json::num(sched.p99_secs * 1e3))
                .set("sched_max_ms", Json::num(sched.max_secs * 1e3));
        }
        j
    }

    /// Process one protocol line; the returned string is the response
    /// line (without the newline).
    fn dispatch(&self, line: &str, line_no: usize) -> String {
        match line {
            "/stats" => self.stats_json("stats").to_string(),
            "/shutdown" => {
                self.stop.store(true, Ordering::SeqCst);
                let mut j = Json::obj();
                j.set("event", Json::str("shutdown_requested"))
                    .set("ok", Json::Bool(true));
                j.to_string()
            }
            row => match parse_jsonl_row(row, line_no) {
                Ok(mut row) => {
                    // the wall-clock pacer: a stamp in the past is
                    // clamped onto "now" (1 slot ≈ 1 ms of uptime)
                    let elapsed = self.start.elapsed().as_millis() as u64;
                    row.arrival = row.arrival.max(elapsed);
                    let job = self.builder.lock().unwrap().build(row);
                    let (id, arrival) = (job.id, job.arrival);
                    let sent = match self.intake.lock().unwrap().as_ref() {
                        Some(tx) => tx.send(job),
                        None => Err("engine intake closed"),
                    };
                    let mut j = Json::obj();
                    match sent {
                        Ok(()) => {
                            self.submitted.fetch_add(1, Ordering::Relaxed);
                            j.set("ok", Json::Bool(true))
                                .set("id", Json::num(id as f64))
                                .set("arrival", Json::num(arrival as f64));
                        }
                        Err(e) => {
                            j.set("ok", Json::Bool(false)).set("error", Json::str(e));
                        }
                    }
                    j.to_string()
                }
                Err(e) => {
                    self.parse_errors.fetch_add(1, Ordering::Relaxed);
                    let mut j = Json::obj();
                    j.set("ok", Json::Bool(false))
                        .set("error", Json::str(e.message()));
                    j.to_string()
                }
            },
        }
    }
}

/// One connection's session loop: read lines, answer lines. The read
/// timeout (200 ms) only exists so an idle connection notices shutdown;
/// a partially-received line survives timeouts intact because the
/// buffer is cleared strictly after a full line is processed.
fn handle_conn(stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let _ = stream.set_nodelay(true);
    let mut out = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut line_no = 0usize;
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => break, // client closed its write half
            Ok(_) => {
                let t = line.trim().to_string();
                line.clear();
                if !(t.is_empty() || t.starts_with('#')) {
                    line_no += 1;
                    let resp = shared.dispatch(&t, line_no);
                    if writeln!(out, "{resp}").is_err() {
                        break;
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
            Err(_) => break,
        }
        if shared.should_stop() {
            break;
        }
    }
}

/// The self-drive client: one connection, a writer (this thread) firing
/// every trace line as fast as the socket accepts them, and a reader
/// thread draining responses concurrently so neither side's TCP buffer
/// can deadlock the other. Returns `(jobs_sent, ok, errors)`.
fn drive(addr: SocketAddr, path: &str) -> Result<(u64, u64, u64), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("drive: trace `{path}`: {e}"))?;
    let mut rows: Vec<&str> = Vec::new();
    for l in text.lines() {
        let t = l.trim();
        if t.is_empty() || t.starts_with('#') {
            continue;
        }
        if !t.starts_with('{') {
            return Err(format!(
                "drive: trace `{path}` is not JSONL (line does not start with `{{`) — \
                 `--drive` submits raw lines over the wire, so CSV traces must be \
                 converted to JSONL first"
            ));
        }
        rows.push(t);
    }
    let stream = TcpStream::connect(addr).map_err(|e| format!("drive: connect {addr}: {e}"))?;
    let _ = stream.set_nodelay(true);
    let read_half = stream
        .try_clone()
        .map_err(|e| format!("drive: clone stream: {e}"))?;
    let reader = std::thread::spawn(move || -> (u64, u64, Option<String>) {
        let (mut ok, mut errs) = (0u64, 0u64);
        let mut stats: Option<String> = None;
        let mut br = BufReader::new(read_half);
        let mut line = String::new();
        loop {
            line.clear();
            match br.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {
                    let t = line.trim();
                    if t.contains("\"event\":\"stats\"") {
                        stats = Some(t.to_string());
                    } else if t.contains("\"event\":") {
                        // shutdown ack: not a submission response
                    } else if t.contains("\"ok\":false") {
                        errs += 1;
                    } else if t.contains("\"ok\":true") {
                        ok += 1;
                    }
                }
            }
        }
        (ok, errs, stats)
    });
    let n = rows.len() as u64;
    let mut w = &stream;
    for row in rows {
        writeln!(w, "{row}").map_err(|e| format!("drive: send: {e}"))?;
    }
    writeln!(w, "/stats").map_err(|e| format!("drive: send: {e}"))?;
    writeln!(w, "/shutdown").map_err(|e| format!("drive: send: {e}"))?;
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let (ok, errs, stats) = reader
        .join()
        .map_err(|_| "drive: response reader panicked".to_string())?;
    if let Some(s) = stats {
        println!("{s}");
    }
    let mut j = Json::obj();
    j.set("event", Json::str("drive_done"))
        .set("jobs", Json::num(n as f64))
        .set("responses_ok", Json::num(ok as f64))
        .set("responses_err", Json::num(errs as f64));
    println!("{}", j.to_string());
    Ok((n, ok, errs))
}

/// Run the service until `/shutdown`, `SIGTERM`/`SIGINT`, or the end of
/// a `--drive` session, then drain the engine and print final
/// statistics. The error path is reserved for startup problems and a
/// failed drive; protocol-level garbage never takes the server down.
pub fn run(opts: ServeOpts) -> Result<(), String> {
    if opts.cfg.time_model != TimeModel::EventSkip {
        let msg = "serve requires --time-model event-skip: the dense core treats an idle \
                   live intake as a drained workload and would exit before the first job";
        return Err(msg.to_string());
    }
    sig::install();
    let listener = TcpListener::bind(&opts.listen)
        .map_err(|e| format!("serve: bind {}: {e}", opts.listen))?;
    listener.set_nonblocking(true).map_err(|e| format!("serve: {e}"))?;
    let addr = listener.local_addr().map_err(|e| format!("serve: {e}"))?;

    // The environment chain is build_trace_source's, verbatim: a serve
    // session at given scenario coordinates faces the identical plant,
    // per-job DAG shaping and engine seed as `pingan replay` would.
    let scen = opts.scenario;
    let seed = scen.env_seed(0x5EED);
    let mut rng = Rng::new(seed);
    let sys = crate::cluster::GeoSystem::generate(&scen.system_spec(seed), &mut rng);
    let sites: Vec<usize> = (0..sys.n()).collect();
    let wseed = seed ^ 0xABCD;
    let effective_lambda = scen.lambda / scen.slot_divisor.max(1) as f64;
    let mut w = WorkloadSpec::scaled(scen.n_jobs, effective_lambda);
    w.seed = wseed;
    scen.mix.apply(&mut w);
    let builder = JobBuilder::new(w, sites, wseed);

    let (tx_job, src) = source::channel();
    let cell = Arc::new(CountersCell::new());
    let (tx_spans, rx_spans) = mpsc::channel::<Arc<Spans>>();
    let engine_cfg = opts.cfg;
    let engine_cell = cell.clone();
    let engine_scen = scen.clone();
    let engine = std::thread::spawn(move || -> Result<EngineReport, String> {
        // the plant moved into (and dies with) the engine thread
        let mut sched = engine_scen.make_scheduler()?;
        let mut sim = Simulation::from_source(&sys, src, engine_cfg);
        sim.publish_counters(engine_cell);
        let _ = tx_spans.send(sim.spans_handle());
        let res = sim.run(sched.as_mut());
        Ok(EngineReport {
            finished: res.finished_jobs,
            total: res.total_jobs,
            slots: res.slots,
            events: res.events_processed,
        })
    });
    let spans = match rx_spans.recv() {
        Ok(s) => s,
        // the engine died before its first heartbeat (bad scheduler
        // name, ...): surface its error instead of a channel error
        Err(_) => {
            return match engine.join() {
                Ok(Err(e)) => Err(e),
                Ok(Ok(_)) => Err("serve: engine exited before startup".into()),
                Err(_) => Err("serve: engine thread panicked during startup".into()),
            };
        }
    };
    let shared = Arc::new(Shared {
        intake: Mutex::new(Some(tx_job)),
        builder: Mutex::new(builder),
        submitted: AtomicU64::new(0),
        parse_errors: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        start: Instant::now(),
        spans,
        cell,
    });
    let mut j = Json::obj();
    j.set("event", Json::str("serving"))
        .set("addr", Json::str(&addr.to_string()))
        .set("scheduler", Json::str(&scen.scheduler));
    println!("{}", j.to_string());
    let _ = std::io::stdout().flush();

    let mut driver = opts
        .drive
        .map(|path| std::thread::spawn(move || drive(addr, &path)));
    let mut drive_error: Option<String> = None;
    let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();

    while !shared.should_stop() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let sh = shared.clone();
                handlers.push(std::thread::spawn(move || handle_conn(stream, &sh)));
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
        if driver.as_ref().is_some_and(|h| h.is_finished()) {
            let h = driver.take().expect("checked");
            match h.join() {
                Ok(Ok(_)) => {} // the drive's own /shutdown stops the loop
                Ok(Err(e)) => {
                    drive_error = Some(e);
                    shared.stop.store(true, Ordering::SeqCst);
                }
                Err(_) => {
                    drive_error = Some("drive thread panicked".into());
                    shared.stop.store(true, Ordering::SeqCst);
                }
            }
        }
    }

    // ---- graceful drain ----
    // Dropping the one JobSender closes the intake; the engine finishes
    // every job already in flight, accounts the rest, and returns. The
    // handlers notice the stop flag within one read timeout.
    shared.stop.store(true, Ordering::SeqCst);
    drop(shared.intake.lock().unwrap().take());
    let report = engine
        .join()
        .map_err(|_| "serve: engine thread panicked".to_string())??;
    if let Some(h) = driver.take() {
        let _ = h.join();
    }
    for h in handlers {
        let _ = h.join();
    }
    let mut j = shared.stats_json("shutdown");
    j.set("finished", Json::num(report.finished as f64))
        .set("total_jobs", Json::num(report.total as f64))
        .set("slots", Json::num(report.slots as f64))
        .set("events_processed", Json::num(report.events as f64));
    println!("{}", j.to_string());
    match drive_error {
        Some(e) => Err(e),
        None => Ok(()),
    }
}
