//! Batched copy-placement scoring with interchangeable backends.
//!
//! The insurer needs, for B (task, candidate-set) pairs at once,
//! `E[max(existing copies, candidate_k)]` where each candidate's rate
//! distribution is the bottleneck `min(proc, trans)` of two histograms.
//!
//! * [`CpuScorer`] — pure rust, exactly the `dist::Hist` algebra.
//! * [`HloScorer`] *(feature `pjrt`)* — the compiled `score` artifact
//!   (L1 Pallas + L2 JAX), executed through PJRT. Batches are padded to
//!   the artifact's fixed [B, K, V] shape.
//!
//! The in-module tests and `tests/proptest_invariants.rs` assert the
//! backends agree to f32 tolerance, which transitively ties the rust hot
//! path to the pytest oracle (`python/compile/kernels/ref.py`).

use anyhow::Result;

/// One batch of scoring work: B tasks × K candidates on a V-bin grid.
#[derive(Clone, Debug)]
pub struct ScoreBatch {
    pub b: usize,
    pub k: usize,
    pub v: usize,
    /// [B*K*V] processing-speed pmfs.
    pub proc_pmf: Vec<f32>,
    /// [B*K*V] transfer-bandwidth pmfs.
    pub trans_pmf: Vec<f32>,
    /// [B*V] product of existing copies' CDFs (ones when no copies).
    pub existing_cdf: Vec<f32>,
    /// [V] grid centers.
    pub values: Vec<f32>,
}

impl ScoreBatch {
    pub fn new(b: usize, k: usize, v: usize) -> ScoreBatch {
        ScoreBatch {
            b,
            k,
            v,
            proc_pmf: vec![0.0; b * k * v],
            trans_pmf: vec![0.0; b * k * v],
            existing_cdf: vec![1.0; b * v],
            values: vec![0.0; v],
        }
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.proc_pmf.len() == self.b * self.k * self.v, "proc shape");
        anyhow::ensure!(self.trans_pmf.len() == self.b * self.k * self.v, "trans shape");
        anyhow::ensure!(self.existing_cdf.len() == self.b * self.v, "cdf shape");
        anyhow::ensure!(self.values.len() == self.v, "values shape");
        Ok(())
    }
}

/// A scoring backend: returns [B*K] expected max rates.
pub trait Scorer {
    fn name(&self) -> &str;
    fn score(&self, batch: &ScoreBatch) -> Result<Vec<f32>>;
}

/// Pure-rust backend (also the fallback when artifacts are absent).
pub struct CpuScorer;

impl Scorer for CpuScorer {
    fn name(&self) -> &str {
        "cpu"
    }

    fn score(&self, batch: &ScoreBatch) -> Result<Vec<f32>> {
        batch.validate()?;
        let (b, k, v) = (batch.b, batch.k, batch.v);
        let mut out = vec![0.0f32; b * k];
        let mut min_pmf = vec![0.0f32; v];
        for bi in 0..b {
            let exist = &batch.existing_cdf[bi * v..(bi + 1) * v];
            for ki in 0..k {
                let base = (bi * k + ki) * v;
                let p = &batch.proc_pmf[base..base + v];
                let t = &batch.trans_pmf[base..base + v];
                // bottleneck: pmf of min(P, T)
                let mut sf_p = 0.0f32; // P(P > v_j), built backwards
                let mut sf_t = 0.0f32;
                for j in (0..v).rev() {
                    min_pmf[j] = p[j] * sf_t + t[j] * sf_p + p[j] * t[j];
                    sf_p += p[j];
                    sf_t += t[j];
                }
                let total: f32 = min_pmf.iter().sum();
                let norm = if total > 1e-30 { 1.0 / total } else { 0.0 };
                // E[max]: CDF product against existing, then expectation
                let mut cdf = 0.0f32;
                let mut prev = 0.0f32;
                let mut e = 0.0f32;
                for j in 0..v {
                    cdf += min_pmf[j] * norm;
                    let combined = cdf * exist[j];
                    e += batch.values[j] * (combined - prev);
                    prev = combined;
                }
                out[bi * k + ki] = e;
            }
        }
        Ok(out)
    }
}

/// PJRT backend running the compiled `score` artifact.
#[cfg(feature = "pjrt")]
pub struct HloScorer {
    exe: xla::PjRtLoadedExecutable,
    b: usize,
    k: usize,
    v: usize,
}

#[cfg(feature = "pjrt")]
impl HloScorer {
    /// Compile the `score` artifact from an [`super::Engine`].
    pub fn new(engine: &super::Engine) -> Result<HloScorer> {
        let a = &engine.artifacts;
        Ok(HloScorer {
            exe: engine.compile("score")?,
            b: a.score_b,
            k: a.score_k,
            v: a.score_v,
        })
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.b, self.k, self.v)
    }

    /// Pad `batch` into the artifact's fixed shape (grid V must match).
    fn pad(&self, batch: &ScoreBatch) -> Result<ScoreBatch> {
        anyhow::ensure!(
            batch.v == self.v,
            "grid bins {} != artifact V {}",
            batch.v,
            self.v
        );
        anyhow::ensure!(
            batch.b <= self.b && batch.k <= self.k,
            "batch {}x{} exceeds artifact {}x{}",
            batch.b,
            batch.k,
            self.b,
            self.k
        );
        let mut padded = ScoreBatch::new(self.b, self.k, self.v);
        padded.values.copy_from_slice(&batch.values);
        for bi in 0..batch.b {
            for ki in 0..batch.k {
                let src = (bi * batch.k + ki) * batch.v;
                let dst = (bi * self.k + ki) * self.v;
                padded.proc_pmf[dst..dst + self.v]
                    .copy_from_slice(&batch.proc_pmf[src..src + batch.v]);
                padded.trans_pmf[dst..dst + self.v]
                    .copy_from_slice(&batch.trans_pmf[src..src + batch.v]);
            }
            let src = bi * batch.v;
            let dst = bi * self.v;
            padded.existing_cdf[dst..dst + self.v]
                .copy_from_slice(&batch.existing_cdf[src..src + batch.v]);
        }
        Ok(padded)
    }
}

#[cfg(feature = "pjrt")]
impl Scorer for HloScorer {
    fn name(&self) -> &str {
        "hlo"
    }

    fn score(&self, batch: &ScoreBatch) -> Result<Vec<f32>> {
        batch.validate()?;
        let padded = self.pad(batch)?;
        let (b, k, v) = (self.b as i64, self.k as i64, self.v as i64);
        let outs = super::pjrt::exec_f32(
            &self.exe,
            &[
                super::pjrt::literal_f32(&padded.proc_pmf, &[b, k, v])?,
                super::pjrt::literal_f32(&padded.trans_pmf, &[b, k, v])?,
                super::pjrt::literal_f32(&padded.existing_cdf, &[b, v])?,
                super::pjrt::literal_f32(&padded.values, &[v])?,
            ],
        )?;
        // unpad to the caller's [batch.b x batch.k]
        let full = &outs[0];
        let mut out = vec![0.0f32; batch.b * batch.k];
        for bi in 0..batch.b {
            for ki in 0..batch.k {
                out[bi * batch.k + ki] = full[bi * self.k + ki];
            }
        }
        Ok(out)
    }
}

/// Fill a [`ScoreBatch`] row from `dist::Hist` pairs — the bridge between
/// the insurer's histogram world and the flat tensors.
pub fn fill_row(
    batch: &mut ScoreBatch,
    bi: usize,
    candidates: &[(Vec<f32>, Vec<f32>)], // (proc pmf, trans pmf) per k
    existing_cdf: &[f32],
) {
    let (k, v) = (batch.k, batch.v);
    assert!(candidates.len() <= k);
    for (ki, (p, t)) in candidates.iter().enumerate() {
        let base = (bi * k + ki) * v;
        batch.proc_pmf[base..base + v].copy_from_slice(p);
        batch.trans_pmf[base..base + v].copy_from_slice(t);
    }
    batch.existing_cdf[bi * v..(bi + 1) * v].copy_from_slice(existing_cdf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_pmf(rng: &mut Rng, v: usize) -> Vec<f32> {
        let mut x: Vec<f32> = (0..v).map(|_| rng.f64() as f32 + 1e-3).collect();
        let s: f32 = x.iter().sum();
        x.iter_mut().for_each(|e| *e /= s);
        x
    }

    fn rand_batch(seed: u64, b: usize, k: usize, v: usize) -> ScoreBatch {
        let mut rng = Rng::new(seed);
        let mut batch = ScoreBatch::new(b, k, v);
        batch.values = (0..v).map(|i| i as f32 * 0.5).collect();
        for bi in 0..b {
            let pmf = rand_pmf(&mut rng, v);
            let mut cdf = Vec::with_capacity(v);
            let mut acc = 0.0f32;
            for &p in &pmf {
                acc += p;
                cdf.push(acc.min(1.0));
            }
            let cands: Vec<(Vec<f32>, Vec<f32>)> = (0..k)
                .map(|_| (rand_pmf(&mut rng, v), rand_pmf(&mut rng, v)))
                .collect();
            fill_row(&mut batch, bi, &cands, &cdf);
        }
        batch
    }

    #[test]
    fn cpu_scorer_matches_hist_algebra() {
        use crate::dist::{Grid, Hist};
        let v = 64;
        let batch = rand_batch(7, 2, 3, v);
        let cpu = CpuScorer.score(&batch).unwrap();
        // cross-check row (0,0) against dist::Hist
        let grid = Grid::uniform(0.0, (v - 1) as f64 * 0.5, v);
        for bi in 0..2 {
            for ki in 0..3 {
                let base = (bi * 3 + ki) * v;
                let p: Vec<f64> = batch.proc_pmf[base..base + v].iter().map(|&x| x as f64).collect();
                let t: Vec<f64> = batch.trans_pmf[base..base + v].iter().map(|&x| x as f64).collect();
                let hp = pmf_to_hist(&grid, &p);
                let ht = pmf_to_hist(&grid, &t);
                let hmin = hp.min_compose(&ht);
                // existing cdf -> hist
                let ex: Vec<f64> = batch.existing_cdf[bi * v..(bi + 1) * v]
                    .iter()
                    .map(|&x| x as f64)
                    .collect();
                let mut ex_pmf = vec![0.0; v];
                let mut prev = 0.0;
                for j in 0..v {
                    ex_pmf[j] = (ex[j] - prev).max(0.0);
                    prev = ex[j];
                }
                let hex = pmf_to_hist(&grid, &ex_pmf);
                let want = Hist::expected_max(&[&hmin, &hex]);
                let got = cpu[bi * 3 + ki] as f64;
                assert!(
                    (got - want).abs() < 1e-3 * want.max(1.0),
                    "({bi},{ki}): got {got} want {want}"
                );
            }
        }
    }

    fn pmf_to_hist(grid: &crate::dist::Grid, pmf: &[f64]) -> crate::dist::Hist {
        crate::dist::Hist::from_pmf(grid, pmf)
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn hlo_and_cpu_agree() {
        if !std::path::Path::new("artifacts/manifest.toml").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let engine = crate::runtime::Engine::new("artifacts").unwrap();
        let hlo = HloScorer::new(&engine).unwrap();
        let (b, k, v) = hlo.shape();
        let batch = rand_batch(11, b, k, v);
        let got_hlo = hlo.score(&batch).unwrap();
        let got_cpu = CpuScorer.score(&batch).unwrap();
        assert_eq!(got_hlo.len(), got_cpu.len());
        for (i, (a, c)) in got_hlo.iter().zip(&got_cpu).enumerate() {
            assert!(
                (a - c).abs() < 1e-3 * c.abs().max(1.0),
                "idx {i}: hlo {a} vs cpu {c}"
            );
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn hlo_pads_partial_batches() {
        if !std::path::Path::new("artifacts/manifest.toml").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let engine = crate::runtime::Engine::new("artifacts").unwrap();
        let hlo = HloScorer::new(&engine).unwrap();
        let (_, _, v) = hlo.shape();
        let batch = rand_batch(13, 3, 2, v); // smaller than artifact shape
        let got_hlo = hlo.score(&batch).unwrap();
        let got_cpu = CpuScorer.score(&batch).unwrap();
        for (a, c) in got_hlo.iter().zip(&got_cpu) {
            assert!((a - c).abs() < 1e-3 * c.abs().max(1.0));
        }
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let mut b = ScoreBatch::new(2, 2, 8);
        b.values.pop();
        assert!(b.validate().is_err());
    }
}
