//! Typed configuration: Table-2 cluster parameter ranges, workload specs,
//! and PingAn algorithm parameters, with TOML overrides.
//!
//! Units follow the paper: VM power in MIPS-like "data units per time slot",
//! WAN bandwidth in kb/s scaled to the same data unit, datasize in MB.

use super::toml::Doc;
use crate::util::knob;

/// Parameter ranges for one cluster scale class (one row of Table 2).
#[derive(Clone, Debug)]
pub struct ScaleClass {
    pub name: &'static str,
    /// Fraction of clusters in this class.
    pub proportion: f64,
    /// VM (slot) count range, inclusive.
    pub vm_count: (u64, u64),
    /// Ratio of gate (egress/ingress) bandwidth to the sum of VM external bw.
    pub gate_ratio: (f64, f64),
    /// Mean VM power (data units / slot) range.
    pub power_mean: (f64, f64),
    /// Relative standard deviation of VM power.
    pub power_rsd: (f64, f64),
    /// Cluster-level unreachability probability per time slot.
    pub unreach_p: (f64, f64),
}

/// Full system spec (Table 2 defaults).
#[derive(Clone, Debug)]
pub struct SystemSpec {
    pub n_clusters: usize,
    pub classes: Vec<ScaleClass>,
    /// WAN bandwidth mean range (shared by all pairs; kb/s in the paper).
    pub wan_mean: (f64, f64),
    /// WAN bandwidth RSD range.
    pub wan_rsd: (f64, f64),
    /// Per-VM external bandwidth used to derive gate capacity.
    pub vm_ext_bw: f64,
    /// Value-grid resolution for the performance modeler.
    pub grid_bins: usize,
    pub seed: u64,
}

impl Default for SystemSpec {
    fn default() -> Self {
        SystemSpec {
            n_clusters: 100,
            classes: vec![
                ScaleClass {
                    name: "large",
                    proportion: 0.05,
                    vm_count: (500, 1500),
                    gate_ratio: (0.55, 0.75),
                    power_mean: (174.0, 355.0),
                    power_rsd: (0.25, 0.6),
                    unreach_p: (0.002, 0.011),
                },
                ScaleClass {
                    name: "medium",
                    proportion: 0.20,
                    vm_count: (50, 500),
                    gate_ratio: (0.65, 0.85),
                    power_mean: (128.0, 241.0),
                    power_rsd: (0.55, 0.85),
                    unreach_p: (0.02, 0.2),
                },
                ScaleClass {
                    name: "small",
                    proportion: 0.75,
                    vm_count: (10, 50),
                    gate_ratio: (0.75, 0.95),
                    power_mean: (68.0, 179.0),
                    power_rsd: (0.35, 0.75),
                    unreach_p: (0.05, 0.5),
                },
            ],
            wan_mean: (64.0, 256.0),
            wan_rsd: (0.2, 0.5),
            vm_ext_bw: 96.0,
            grid_bins: 64,
            seed: 20180001,
        }
    }
}

impl SystemSpec {
    /// Scaled-down spec for fast tests/benches: same shape, fewer clusters,
    /// smaller VM counts.
    pub fn small(n_clusters: usize) -> SystemSpec {
        let mut s = SystemSpec::default();
        s.n_clusters = n_clusters;
        for c in &mut s.classes {
            c.vm_count = (c.vm_count.0 / 10 + 1, c.vm_count.1 / 10 + 1);
        }
        s
    }

    /// Apply TOML overrides under `[system]`.
    pub fn from_doc(doc: &Doc) -> Result<SystemSpec, String> {
        let mut s = SystemSpec::default();
        s.n_clusters = doc.get_usize("system.clusters", s.n_clusters)?;
        s.grid_bins = doc.get_usize("system.grid_bins", s.grid_bins)?;
        s.seed = doc.get_f64("system.seed", s.seed as f64)? as u64;
        s.wan_mean.0 = doc.get_f64("system.wan_mean_lo", s.wan_mean.0)?;
        s.wan_mean.1 = doc.get_f64("system.wan_mean_hi", s.wan_mean.1)?;
        s.vm_ext_bw = doc.get_f64("system.vm_ext_bw", s.vm_ext_bw)?;
        if s.n_clusters == 0 {
            return Err("system.clusters must be > 0".into());
        }
        Ok(s)
    }
}

/// Workload spec for the simulation experiments (Sec 6.1).
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    /// Number of workflows (paper: 2000 Montage workflows).
    pub n_jobs: usize,
    /// Poisson arrival-rate parameter λ (jobs per time slot).
    pub lambda: f64,
    /// Facebook trace mix: (fraction, task-count range) per class.
    pub size_classes: Vec<(f64, (usize, usize))>,
    /// Per-task input datasize range (MB-equivalent data units).
    pub datasize: (f64, f64),
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            n_jobs: 2000,
            lambda: 0.07,
            // 89% small (1-150 tasks), 8% medium (151-500), 3% large (>500).
            size_classes: vec![
                (0.89, (1, 150)),
                (0.08, (151, 500)),
                (0.03, (501, 900)),
            ],
            datasize: (100.0, 4000.0),
            seed: 77,
        }
    }
}

impl WorkloadSpec {
    pub fn scaled(n_jobs: usize, lambda: f64) -> WorkloadSpec {
        let mut w = WorkloadSpec::default();
        w.n_jobs = n_jobs;
        w.lambda = lambda;
        w
    }

    pub fn from_doc(doc: &Doc) -> Result<WorkloadSpec, String> {
        let mut w = WorkloadSpec::default();
        w.n_jobs = doc.get_usize("workload.jobs", w.n_jobs)?;
        w.lambda = doc.get_f64("workload.lambda", w.lambda)?;
        w.seed = doc.get_f64("workload.seed", w.seed as f64)? as u64;
        if !(w.lambda > 0.0) {
            return Err("workload.lambda must be > 0".into());
        }
        Ok(w)
    }
}

/// PingAn algorithm parameters (Sec 4.1).
#[derive(Clone, Debug)]
pub struct PingAnSpec {
    /// ε ∈ (0,1): fraction of alive jobs sharing slots; also sets the rate
    /// floor 1/(1+ε) and the speed augmentation in the analysis.
    pub epsilon: f64,
    /// Hard cap on copies per task (rounds are self-limiting via the
    /// resource-saving rule; the cap is a safety net).
    pub max_copies: usize,
    /// Insuring-principle order for rounds 1 and 2 (ablation, Fig 6a).
    pub principle: Principle,
    /// Cross-job allocation discipline in round 1 (ablation, Fig 6b).
    pub allocation: Allocation,
    /// Backend scoring candidate batches in the insurer's hot path.
    pub scorer: ScorerKind,
}

/// Which backend `PingAn::schedule` scores candidate batches with
/// (`--scorer` on the CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScorerKind {
    /// Batched pure-rust kernel — bit-identical to the `dist::Hist`
    /// algebra, and the default.
    Cpu,
    /// Compiled XLA `score` artifact through PJRT (needs the `pjrt` cargo
    /// feature and `make artifacts`). Scores in f32: agrees with `Cpu`
    /// only to ~1e-3 relative, so knife-edge admissions may differ.
    Hlo,
    /// Per-candidate scalar reference (the pre-batching hot path), kept
    /// for agreement tests and as the bench baseline.
    Scalar,
}

impl ScorerKind {
    pub fn name(&self) -> &'static str {
        match self {
            ScorerKind::Cpu => "cpu",
            ScorerKind::Hlo => "hlo",
            ScorerKind::Scalar => "scalar",
        }
    }

    pub fn parse(s: &str) -> Result<ScorerKind, String> {
        match s.to_ascii_lowercase().as_str() {
            "cpu" => Ok(ScorerKind::Cpu),
            "hlo" => Ok(ScorerKind::Hlo),
            "scalar" => Ok(ScorerKind::Scalar),
            _ => Err(format!("unknown scorer `{s}` (expected cpu|hlo|scalar)")),
        }
    }
}

/// Which time core the simulator runs on (`--time-model` on the CLI).
///
/// `Dense` is the original slotted engine: every simulated slot redraws
/// the stochastic processes and re-invokes the scheduler — O(slots ×
/// copies) regardless of activity, but bit-reproducible against the
/// pre-refactor engine (same RNG draw order, same `Action` streams).
/// `EventSkip` jumps straight to the next event (arrival, copy
/// completion, cluster failure, policy wake) and advances the per-slot
/// processes in closed form over the skipped gap: statistically
/// equivalent under paired seeds, and it touches a small fraction of the
/// slots on sparse workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TimeModel {
    /// The slotted reference engine (default).
    #[default]
    Dense,
    /// The event-queue time core.
    EventSkip,
}

impl TimeModel {
    pub fn name(&self) -> &'static str {
        match self {
            TimeModel::Dense => "dense",
            TimeModel::EventSkip => "event-skip",
        }
    }

    pub fn parse(s: &str) -> Result<TimeModel, String> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Ok(TimeModel::Dense),
            "event-skip" | "eventskip" | "event_skip" | "events" => Ok(TimeModel::EventSkip),
            _ => Err(format!(
                "unknown time model `{s}` (expected dense|event-skip)"
            )),
        }
    }

    /// Both cores (note: the time model is a knob of the *runner*, not of
    /// the environment — it is never folded into cell seeds).
    pub const ALL: [TimeModel; 2] = [TimeModel::Dense, TimeModel::EventSkip];
}

/// How WAN transfers share bandwidth (`--bandwidth-model` on the CLI).
///
/// `Constant` is the original physics: every copy's transfer rate is
/// fixed at launch (its solo rate clamped by the gate-headroom admission
/// check) and never changes while the copy runs — launching an insurance
/// copy can never slow its neighbours down. `Shared` replaces that with a
/// max-min fair-share solve over cluster ingress/egress gates and
/// per-pair WAN links ([`crate::simulator::bandwidth`]): every copy
/// start/finish re-rates the transfers that share a bottleneck, so an
/// insurance copy has a *cost*, which is the contention the paper's
/// gain-vs-resource argument assumes.
///
/// Unlike [`TimeModel`], this is a knob of the *environment*, not of the
/// runner — it changes the physics and therefore the results. It is still
/// kept **out** of the sweep cell seeds so that a paired
/// constant-vs-shared sweep runs both models against the identical plant
/// and job stream; the non-default value is tagged in cell labels
/// instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BandwidthModel {
    /// Launch-time rates, frozen for the copy's lifetime (default).
    #[default]
    Constant,
    /// Max-min fair sharing over gates and WAN links, re-rated at every
    /// copy start/finish (at the policy-epoch barrier only — see
    /// `simulator/mod.rs`).
    Shared,
}

impl BandwidthModel {
    pub fn name(&self) -> &'static str {
        match self {
            BandwidthModel::Constant => "constant",
            BandwidthModel::Shared => "shared",
        }
    }

    pub fn parse(s: &str) -> Result<BandwidthModel, String> {
        match s.to_ascii_lowercase().as_str() {
            "constant" | "const" | "fixed" => Ok(BandwidthModel::Constant),
            "shared" | "fair" | "fairshare" | "fair-share" => Ok(BandwidthModel::Shared),
            _ => Err(format!(
                "unknown bandwidth model `{s}` (expected constant|shared)"
            )),
        }
    }

    pub const ALL: [BandwidthModel; 2] = [BandwidthModel::Constant, BandwidthModel::Shared];
}

/// Parse an intra-cell scoring thread budget (`SimConfig::score_threads`,
/// CLI `--score-threads`). Absent or empty means 1 (serial); garbage is
/// an `Err` naming the flag — CLI typos die with a one-line error, never
/// a backtrace and never a silent fallback. (A thin wrapper over
/// [`crate::util::knob::try_knob`], kept for its call sites and pinned
/// tests; the *env* default stays total — see [`default_score_threads`].)
pub fn parse_score_threads(s: Option<&str>) -> Result<usize, String> {
    Ok(knob::try_knob("--score-threads", s, knob::thread_count)?.unwrap_or(1))
}

/// Process-wide default for `SimConfig::score_threads`: the
/// `PINGAN_SCORE_THREADS` environment variable (CI's test-threads matrix
/// leg sets it to 4 to run the whole tier-1 suite sharded), else 1.
/// Safe as a *default* precisely because sharded scoring is bit-identical
/// to serial scoring — every fixed-seed pin in the suite must pass
/// unchanged at any value.
pub fn default_score_threads() -> usize {
    knob::env_knob("PINGAN_SCORE_THREADS", knob::thread_count, 1)
}

/// Parse an engine shard-thread budget (`SimConfig::engine_threads`,
/// CLI `--engine-threads`). Same contract as [`parse_score_threads`]:
/// absent or empty means 1, garbage is an `Err` naming the flag.
pub fn parse_engine_threads(s: Option<&str>) -> Result<usize, String> {
    Ok(knob::try_knob("--engine-threads", s, knob::thread_count)?.unwrap_or(1))
}

/// Process-wide default for `SimConfig::engine_threads`: the
/// `PINGAN_ENGINE_THREADS` environment variable (CI's engine-threads
/// matrix leg sets it to 4 to run the whole tier-1 suite on sharded
/// engines), else 1. Safe as a *default* precisely because the sharded
/// engine is bit-identical to the serial one — every fixed-seed pin in
/// the suite must pass unchanged at any value.
pub fn default_engine_threads() -> usize {
    knob::env_knob("PINGAN_ENGINE_THREADS", knob::thread_count, 1)
}

/// Parse the bounded-memory metrics switch (`SimConfig::stream_metrics`,
/// CLI `--stream-metrics`, sweep key `stream_metrics`). Accepts the
/// spellings [`knob::switch`] does; absent or empty means the default,
/// `false` (keep the exact per-job flowtime `Vec`); anything else is an
/// `Err` naming the flag — the same CLI discipline as the thread knobs.
pub fn parse_stream_metrics(s: Option<&str>) -> Result<bool, String> {
    Ok(knob::try_knob("--stream-metrics", s, knob::switch)?.unwrap_or(false))
}

/// Process-wide default for `SimConfig::stream_metrics`: the
/// `PINGAN_STREAM_METRICS` environment variable (CI's million-job replay
/// leg sets it), else `false`.
pub fn default_stream_metrics() -> bool {
    knob::env_knob("PINGAN_STREAM_METRICS", knob::switch, false)
}

/// Process-wide default for `SimConfig::bandwidth_model`: the
/// `PINGAN_BANDWIDTH_MODEL` environment variable, else
/// [`BandwidthModel::Constant`]. Unlike the thread knobs this changes
/// results, so CI never sets it for the tier-1 suite — it exists so a
/// whole experiment batch can be flipped to contended physics without
/// editing every invocation.
pub fn default_bandwidth_model() -> BandwidthModel {
    knob::env_knob(
        "PINGAN_BANDWIDTH_MODEL",
        |s| BandwidthModel::parse(s).ok(),
        BandwidthModel::Constant,
    )
}

/// Which criterion each of the first two insurance rounds optimizes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Principle {
    /// Round 1 efficiency-first, round 2 reliability-aware (the paper's).
    EffReli,
    /// Swapped (Fig 6a ablation).
    ReliEff,
    /// Efficiency in both rounds.
    EffEff,
    /// Reliability in both rounds.
    ReliReli,
}

impl Principle {
    pub fn name(&self) -> &'static str {
        match self {
            Principle::EffReli => "Eff-Reli",
            Principle::ReliEff => "Reli-Eff",
            Principle::EffEff => "Eff-Eff",
            Principle::ReliReli => "Reli-Reli",
        }
    }

    pub fn parse(s: &str) -> Result<Principle, String> {
        match s.to_ascii_lowercase().as_str() {
            "eff-reli" | "effreli" => Ok(Principle::EffReli),
            "reli-eff" | "relieff" => Ok(Principle::ReliEff),
            "eff-eff" | "effeff" => Ok(Principle::EffEff),
            "reli-reli" | "relireli" => Ok(Principle::ReliReli),
            _ => Err(format!("unknown principle `{s}`")),
        }
    }
}

/// Cross-job slot allocation in round 1 (Sec 4.1, EFA vs JGA).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Allocation {
    /// Efficient-First Allocation: essential copies for all prior jobs
    /// first, extra copies only in later rounds (the paper's).
    Efa,
    /// Job-Greedy Allocation: each job takes essential + extra copies
    /// before the next job is served.
    Jga,
}

impl Allocation {
    pub fn name(&self) -> &'static str {
        match self {
            Allocation::Efa => "EFA",
            Allocation::Jga => "JGA",
        }
    }
}

impl Default for PingAnSpec {
    fn default() -> Self {
        PingAnSpec {
            epsilon: 0.6,
            max_copies: 4,
            principle: Principle::EffReli,
            allocation: Allocation::Efa,
            scorer: ScorerKind::Cpu,
        }
    }
}

impl PingAnSpec {
    pub fn with_epsilon(epsilon: f64) -> PingAnSpec {
        let mut p = PingAnSpec::default();
        p.epsilon = epsilon;
        p
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.epsilon > 0.0 && self.epsilon < 1.0) {
            return Err(format!("epsilon must be in (0,1), got {}", self.epsilon));
        }
        if self.max_copies == 0 {
            return Err("max_copies must be >= 1".into());
        }
        if self.scorer == ScorerKind::Hlo && !cfg!(feature = "pjrt") {
            return Err("scorer `hlo` needs a build with `--features pjrt`".into());
        }
        Ok(())
    }

    /// The paper's ε-selection hint (Sec 6.4): pick ε by load λ.
    pub fn epsilon_hint(lambda: f64) -> f64 {
        if lambda <= 0.03 {
            0.8
        } else if lambda <= 0.09 {
            0.6
        } else if lambda <= 0.13 {
            0.4
        } else {
            0.2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table2() {
        let s = SystemSpec::default();
        assert_eq!(s.n_clusters, 100);
        assert_eq!(s.classes.len(), 3);
        let total: f64 = s.classes.iter().map(|c| c.proportion).sum();
        assert!((total - 1.0).abs() < 1e-12);
        assert_eq!(s.classes[0].vm_count, (500, 1500));
        assert_eq!(s.classes[2].unreach_p, (0.05, 0.5));
    }

    #[test]
    fn overrides_from_toml() {
        let doc = Doc::parse("[system]\nclusters = 10\ngrid_bins = 32").unwrap();
        let s = SystemSpec::from_doc(&doc).unwrap();
        assert_eq!(s.n_clusters, 10);
        assert_eq!(s.grid_bins, 32);
    }

    #[test]
    fn zero_clusters_rejected() {
        let doc = Doc::parse("[system]\nclusters = 0").unwrap();
        assert!(SystemSpec::from_doc(&doc).is_err());
    }

    #[test]
    fn workload_default_mix() {
        let w = WorkloadSpec::default();
        let total: f64 = w.size_classes.iter().map(|c| c.0).sum();
        assert!((total - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pingan_validation() {
        assert!(PingAnSpec::with_epsilon(0.6).validate().is_ok());
        assert!(PingAnSpec::with_epsilon(0.0).validate().is_err());
        assert!(PingAnSpec::with_epsilon(1.0).validate().is_err());
    }

    #[test]
    fn epsilon_hint_follows_paper() {
        assert_eq!(PingAnSpec::epsilon_hint(0.02), 0.8);
        assert_eq!(PingAnSpec::epsilon_hint(0.05), 0.6);
        assert_eq!(PingAnSpec::epsilon_hint(0.07), 0.6);
        assert_eq!(PingAnSpec::epsilon_hint(0.11), 0.4);
        assert_eq!(PingAnSpec::epsilon_hint(0.15), 0.2);
    }

    #[test]
    fn scorer_parse_roundtrip_and_gate() {
        for k in [ScorerKind::Cpu, ScorerKind::Hlo, ScorerKind::Scalar] {
            assert_eq!(ScorerKind::parse(k.name()).unwrap(), k);
        }
        assert!(ScorerKind::parse("gpu").is_err());
        let mut spec = PingAnSpec::default();
        assert_eq!(spec.scorer, ScorerKind::Cpu);
        spec.scorer = ScorerKind::Hlo;
        // without the pjrt feature the hlo scorer is a validation error
        assert_eq!(spec.validate().is_ok(), cfg!(feature = "pjrt"));
    }

    #[test]
    fn score_threads_parse_defaults_to_serial_and_names_the_flag() {
        assert_eq!(parse_score_threads(None), Ok(1));
        assert_eq!(parse_score_threads(Some("4")), Ok(4));
        assert_eq!(parse_score_threads(Some(" 2 ")), Ok(2));
        assert_eq!(parse_score_threads(Some("")), Ok(1));
        for garbage in ["0", "-3", "lots", "4.5"] {
            let e = parse_score_threads(Some(garbage)).unwrap_err();
            assert!(e.starts_with("--score-threads:"), "{e}");
            assert!(e.contains(garbage), "{e}");
        }
        // the env-backed default always yields a usable budget
        assert!(default_score_threads() >= 1);
    }

    #[test]
    fn engine_threads_parse_defaults_to_serial_and_names_the_flag() {
        assert_eq!(parse_engine_threads(None), Ok(1));
        assert_eq!(parse_engine_threads(Some("4")), Ok(4));
        assert_eq!(parse_engine_threads(Some(" 2 ")), Ok(2));
        assert_eq!(parse_engine_threads(Some("")), Ok(1));
        for garbage in ["0", "-3", "lots"] {
            let e = parse_engine_threads(Some(garbage)).unwrap_err();
            assert!(e.starts_with("--engine-threads:"), "{e}");
        }
        assert!(default_engine_threads() >= 1);
    }

    #[test]
    fn stream_metrics_parse_defaults_off_and_names_the_flag() {
        assert_eq!(parse_stream_metrics(None), Ok(false));
        assert_eq!(parse_stream_metrics(Some("1")), Ok(true));
        assert_eq!(parse_stream_metrics(Some("true")), Ok(true));
        assert_eq!(parse_stream_metrics(Some(" on ")), Ok(true));
        assert_eq!(parse_stream_metrics(Some("0")), Ok(false));
        assert_eq!(parse_stream_metrics(Some("off")), Ok(false));
        assert_eq!(parse_stream_metrics(Some("")), Ok(false));
        let e = parse_stream_metrics(Some("maybe")).unwrap_err();
        assert!(e.starts_with("--stream-metrics:"), "{e}");
    }

    #[test]
    fn time_model_parse_roundtrip() {
        for t in TimeModel::ALL {
            assert_eq!(TimeModel::parse(t.name()).unwrap(), t);
        }
        assert_eq!(TimeModel::parse("eventskip").unwrap(), TimeModel::EventSkip);
        assert_eq!(TimeModel::default(), TimeModel::Dense);
        assert!(TimeModel::parse("warp").is_err());
    }

    #[test]
    fn bandwidth_model_parse_roundtrip() {
        for b in BandwidthModel::ALL {
            assert_eq!(BandwidthModel::parse(b.name()).unwrap(), b);
        }
        assert_eq!(
            BandwidthModel::parse("fair-share").unwrap(),
            BandwidthModel::Shared
        );
        assert_eq!(BandwidthModel::default(), BandwidthModel::Constant);
        assert!(BandwidthModel::parse("infinite").is_err());
        assert!(matches!(
            default_bandwidth_model(),
            BandwidthModel::Constant | BandwidthModel::Shared
        ));
    }

    #[test]
    fn principle_parse_roundtrip() {
        for p in [
            Principle::EffReli,
            Principle::ReliEff,
            Principle::EffEff,
            Principle::ReliReli,
        ] {
            assert_eq!(Principle::parse(p.name()).unwrap(), p);
        }
        assert!(Principle::parse("bogus").is_err());
    }
}
