//! One bench per paper table/figure (DESIGN.md experiment index): each case
//! regenerates the experiment end-to-end at smoke scale and reports its
//! wall time. `pingan figure <id> --scale default|paper` produces the full
//! numbers; these benches keep the regeneration paths healthy and timed.
//!
//! Run: `cargo bench --bench bench_figures`

use pingan::bench_harness::Bench;
use pingan::experiments::{figures, tables, Scale};

fn main() {
    let mut b = Bench::new("figures");
    let scale = Scale::smoke();

    b.case("table1_workload_constitution", || {
        tables::table1(88, 7).len() as f64
    });
    b.case("table2_cluster_parameters", || {
        tables::table2(100, 7).len() as f64
    });
    b.case("fig4_load_comparison", || {
        let f = figures::run_fig4(&scale);
        figures::fig4_table(&f).len() as f64
    });
    b.case("fig5_cdf_and_reduction", || figures::fig5(&scale).len() as f64);
    b.case("fig6a_principle_ablation", || {
        figures::run_fig6a(&scale)[0].1
    });
    b.case("fig6b_allocation_ablation", || {
        figures::run_fig6b(&scale)[0].1
    });
    b.case("fig7_epsilon_lambda_cell", || {
        figures::run_fig7(&scale, &[0.07], &[0.6])[0].2
    });
    // fig2/fig3 (testbed with real payloads) only when artifacts exist
    if std::path::Path::new("artifacts/manifest.toml").exists() {
        b.case("fig2_fig3_testbed_16jobs", || {
            let runs = figures::run_testbed(16, 10).expect("testbed");
            figures::fig2(&runs).len() as f64
        });
    } else {
        eprintln!("skipping fig2/fig3 bench: run `make artifacts`");
    }
}
