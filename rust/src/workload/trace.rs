//! Arrival-trace workload source (`pingan replay --trace <file>`).
//!
//! Parses an Azure-Functions-style arrival trace — one job per line, CSV
//! or JSONL — into a [`WorkloadSource`] that streams [`JobSpec`]s without
//! ever materializing the whole trace. The trace supplies *when* jobs
//! arrive (and optionally how big they are); the Montage DAG generator
//! supplies each job's internal shape, seeded deterministically per job
//! id so replays are bit-reproducible regardless of how the file is
//! chunked or how far a truncated run got.
//!
//! ## File format
//!
//! Blank lines and lines starting with `#` are skipped. The first data
//! line picks the dialect:
//!
//! * **CSV** — a header row naming columns, then one row per job.
//!   Required column: `arrival` (u64 slot). Optional: `tasks` (task
//!   count; drawn from the Facebook size mix when absent), `datasize`
//!   (per-job total MB, overriding the spec's range), `name`.
//!
//!   ```text
//!   # slots are 1s; trace covers 10 minutes
//!   arrival,tasks,datasize,name
//!   0,40,800,etl-hourly
//!   12,,,adhoc
//!   ```
//!
//!   Empty fields fall back to the generator. Comments are whole-line
//!   only (`#` must be the first non-blank character).
//!
//! * **JSONL** — first data line starts with `{`; one JSON object per
//!   line with the same keys: `{"arrival": 12, "tasks": 40,
//!   "datasize": 800.0, "name": "etl"}`.
//!
//! Arrivals must be nondecreasing (the [`WorkloadSource`] ordering
//! contract); the parser panics with the line number on violations or
//! malformed rows — a broken trace should abort the replay loudly, not
//! silently skew results.
//!
//! ## Determinism
//!
//! Job `k`'s DAG is drawn from `Rng::new(splitmix(seed ^ k·φ64))` — a
//! fresh, id-keyed stream per job — so a job's shape depends only on
//! `(seed, id, its own trace row)`, never on read order or on how many
//! jobs preceded it.

use std::fs::File;
use std::io::{self, BufRead, BufReader};

use super::job::JobSpec;
use super::montage;
use super::source::WorkloadSource;
use crate::config::spec::WorkloadSpec;
use crate::util::jsonout::Json;
use crate::util::rng::{Rng, SplitMix64};

const PHI64: u64 = 0x9E37_79B9_7F4A_7C15;

#[derive(Clone, Copy, PartialEq)]
enum Dialect {
    /// Not yet determined (no data line seen).
    Unknown,
    Csv,
    Jsonl,
}

/// Column layout of a CSV trace (indices into the split row).
struct CsvCols {
    arrival: usize,
    tasks: Option<usize>,
    datasize: Option<usize>,
    name: Option<usize>,
    width: usize,
}

/// One parsed trace row, dialect-independent.
struct Row {
    arrival: u64,
    tasks: Option<usize>,
    datasize: Option<f64>,
    name: Option<String>,
}

/// Streaming trace reader: one `BufRead` line cursor plus O(1) parser
/// state — resident size is independent of trace length.
pub struct TraceSource {
    reader: Box<dyn BufRead>,
    /// Shape parameters for the generated DAG bodies (size mix, datasize
    /// range for rows without an override).
    spec: WorkloadSpec,
    sites: Vec<usize>,
    seed: u64,
    dialect: Dialect,
    cols: Option<CsvCols>,
    next_id: usize,
    line_no: usize,
    last_arrival: u64,
}

impl TraceSource {
    /// Open a trace file. `spec` shapes the generated DAGs; `sites` are
    /// the clusters raw inputs scatter over; `seed` keys the per-job RNG
    /// streams.
    pub fn open(
        path: &str,
        spec: WorkloadSpec,
        sites: Vec<usize>,
        seed: u64,
    ) -> io::Result<TraceSource> {
        let f = File::open(path)?;
        Ok(TraceSource::from_reader(
            Box::new(BufReader::new(f)),
            spec,
            sites,
            seed,
        ))
    }

    /// Build from any line source (tests use `io::Cursor`).
    pub fn from_reader(
        reader: Box<dyn BufRead>,
        spec: WorkloadSpec,
        sites: Vec<usize>,
        seed: u64,
    ) -> TraceSource {
        assert!(!sites.is_empty(), "need input sites");
        TraceSource {
            reader,
            spec,
            sites,
            seed,
            dialect: Dialect::Unknown,
            cols: None,
            next_id: 0,
            line_no: 0,
            last_arrival: 0,
        }
    }

    /// Next meaningful line (skipping blanks and `#` comments), or `None`
    /// at EOF. Panics on I/O errors — a vanishing trace file mid-replay
    /// is not a recoverable condition.
    fn next_line(&mut self) -> Option<String> {
        loop {
            let mut buf = String::new();
            let n = self
                .reader
                .read_line(&mut buf)
                .unwrap_or_else(|e| panic!("trace: read error at line {}: {e}", self.line_no + 1));
            if n == 0 {
                return None;
            }
            self.line_no += 1;
            let t = buf.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            return Some(t.to_string());
        }
    }

    fn parse_csv_header(&mut self, line: &str) {
        let names: Vec<String> = line
            .split(',')
            .map(|s| s.trim().to_ascii_lowercase())
            .collect();
        let find = |k: &str| names.iter().position(|n| n == k);
        let arrival = find("arrival").unwrap_or_else(|| {
            panic!(
                "trace: line {}: CSV header must name an `arrival` column (got `{line}`)",
                self.line_no
            )
        });
        self.cols = Some(CsvCols {
            arrival,
            tasks: find("tasks"),
            datasize: find("datasize"),
            name: find("name"),
            width: names.len(),
        });
    }

    fn parse_csv_row(&self, line: &str) -> Row {
        let cols = self.cols.as_ref().expect("header parsed first");
        let fields: Vec<&str> = line.split(',').map(|s| s.trim()).collect();
        if fields.len() > cols.width {
            panic!(
                "trace: line {}: {} fields but header has {}",
                self.line_no,
                fields.len(),
                cols.width
            );
        }
        let get = |i: usize| -> Option<&str> {
            fields
                .get(i)
                .copied()
                .filter(|s| !s.is_empty())
                .map(|s| s.trim_matches('"'))
        };
        let arrival = get(cols.arrival)
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or_else(|| {
                panic!("trace: line {}: bad or missing arrival in `{line}`", self.line_no)
            });
        let parse_or_die = |s: &str, what: &str| -> f64 {
            s.parse::<f64>().unwrap_or_else(|_| {
                panic!("trace: line {}: bad {what} `{s}`", self.line_no)
            })
        };
        Row {
            arrival,
            tasks: cols
                .tasks
                .and_then(get)
                .map(|s| parse_or_die(s, "tasks") as usize),
            datasize: cols.datasize.and_then(get).map(|s| parse_or_die(s, "datasize")),
            name: cols.name.and_then(get).map(|s| s.to_string()),
        }
    }

    fn parse_jsonl_row(&self, line: &str) -> Row {
        let v = Json::parse(line)
            .unwrap_or_else(|e| panic!("trace: line {}: bad JSON: {e}", self.line_no));
        let num = |k: &str| v.get(k).and_then(|x| x.as_num());
        let arrival = num("arrival").unwrap_or_else(|| {
            panic!("trace: line {}: JSONL object needs a numeric `arrival`", self.line_no)
        }) as u64;
        Row {
            arrival,
            tasks: num("tasks").map(|t| t as usize),
            datasize: num("datasize"),
            name: v
                .get("name")
                .and_then(|x| x.as_str())
                .map(|s| s.to_string()),
        }
    }

    /// Materialize one trace row into a full DAG job with an id-keyed RNG.
    fn build_job(&mut self, row: Row) -> JobSpec {
        let id = self.next_id;
        self.next_id += 1;
        let mut rng = Rng::new(SplitMix64::new(self.seed ^ (id as u64).wrapping_mul(PHI64)).next_u64());
        let n_tasks = row
            .tasks
            .unwrap_or_else(|| montage::draw_size(&self.spec, &mut rng));
        let spec = match row.datasize {
            // pin the job's total datasize: montage_dag draws from
            // (lo, hi), so a degenerate range fixes the draw
            Some(d) => {
                let mut s = self.spec.clone();
                s.datasize = (d, d);
                s
            }
            None => self.spec.clone(),
        };
        let mut job = montage::montage_dag(id, row.arrival, n_tasks, &spec, &self.sites, &mut rng);
        if let Some(name) = row.name {
            job.name = name;
        }
        debug_assert!(job.validate().is_ok());
        job
    }
}

impl WorkloadSource for TraceSource {
    fn next_job(&mut self) -> Option<JobSpec> {
        let line = self.next_line()?;
        let row = match self.dialect {
            Dialect::Unknown => {
                if line.starts_with('{') {
                    self.dialect = Dialect::Jsonl;
                    self.parse_jsonl_row(&line)
                } else {
                    self.dialect = Dialect::Csv;
                    self.parse_csv_header(&line);
                    let data = self.next_line()?;
                    self.parse_csv_row(&data)
                }
            }
            Dialect::Csv => self.parse_csv_row(&line),
            Dialect::Jsonl => self.parse_jsonl_row(&line),
        };
        if row.arrival < self.last_arrival {
            panic!(
                "trace: line {}: arrival {} goes backwards (previous {}) — traces must be sorted",
                self.line_no, row.arrival, self.last_arrival
            );
        }
        self.last_arrival = row.arrival;
        Some(self.build_job(row))
    }

    /// Traces are streamed; the total is unknown until EOF.
    fn hint_total(&self) -> Option<usize> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::source::collect;
    use std::io::Cursor;

    fn src(text: &str) -> TraceSource {
        TraceSource::from_reader(
            Box::new(Cursor::new(text.to_string())),
            WorkloadSpec::scaled(10, 0.07),
            vec![0, 1, 2],
            4242,
        )
    }

    #[test]
    fn csv_with_all_columns() {
        let jobs = collect(&mut src(
            "# a comment\n\narrival,tasks,datasize,name\n0,10,500,etl\n7,20,,\n7,,,adhoc\n",
        ));
        assert_eq!(jobs.len(), 3);
        assert_eq!(jobs[0].arrival, 0);
        assert_eq!(jobs[0].n_tasks(), 10);
        assert_eq!(jobs[0].name, "etl");
        // datasize=500 pins the projection layer's total input
        let proj: f64 = jobs[0]
            .tasks
            .iter()
            .filter(|t| t.deps.is_empty())
            .map(|t| t.datasize)
            .sum();
        assert!(proj > 250.0 && proj < 750.0, "proj={proj}");
        assert_eq!(jobs[1].arrival, 7);
        assert_eq!(jobs[1].n_tasks(), 20);
        assert_eq!(jobs[1].name, "montage-1"); // generator default
        assert_eq!(jobs[2].name, "adhoc"); // tasks drawn from mix
        for j in &jobs {
            j.validate().unwrap();
        }
    }

    #[test]
    fn jsonl_dialect() {
        let jobs = collect(&mut src(
            "{\"arrival\": 3, \"tasks\": 5, \"name\": \"a\"}\n{\"arrival\": 9, \"datasize\": 100.0}\n",
        ));
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].arrival, 3);
        assert_eq!(jobs[0].n_tasks(), 5);
        assert_eq!(jobs[0].name, "a");
        assert_eq!(jobs[1].arrival, 9);
    }

    #[test]
    fn hint_total_is_unknown() {
        assert_eq!(src("arrival\n0\n").hint_total(), None);
    }

    #[test]
    fn per_job_seeding_is_read_order_independent() {
        // the same row at the same id yields the same DAG even when the
        // preceding rows change shape (different draws)
        let a = collect(&mut src("arrival,tasks\n0,3\n5,\n9,7\n"));
        let b = collect(&mut src("arrival,tasks\n0,9\n5,\n9,7\n"));
        assert_eq!(a[2].n_tasks(), b[2].n_tasks());
        let da: f64 = a[2].total_datasize();
        let db: f64 = b[2].total_datasize();
        assert_eq!(da.to_bits(), db.to_bits());
        // ...and the middle job (tasks unspecified) is also stable
        assert_eq!(a[1].n_tasks(), b[1].n_tasks());
    }

    #[test]
    #[should_panic(expected = "goes backwards")]
    fn unsorted_trace_panics() {
        collect(&mut src("arrival\n9\n3\n"));
    }

    #[test]
    #[should_panic(expected = "arrival")]
    fn csv_without_arrival_column_panics() {
        collect(&mut src("tasks,name\n3,x\n"));
    }

    #[test]
    #[should_panic(expected = "bad JSON")]
    fn malformed_jsonl_panics() {
        collect(&mut src("{\"arrival\": 1}\n{nope\n"));
    }
}
