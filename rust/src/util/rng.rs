//! Deterministic pseudo-random number generation.
//!
//! All experiments are seeded: the paper averages ten repetitions per
//! setting, and we reproduce that with ten distinct seeds derived from the
//! experiment id. The generator is xoshiro256**, seeded via SplitMix64 —
//! both are the standard public-domain constructions.

/// SplitMix64 — used to expand a 64-bit seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** 1.0 — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Marsaglia polar transform.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Construct from a 64-bit seed (expanded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream, e.g. one per cluster or per job.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        let span = hi - lo + 1;
        // Lemire's nearly-divisionless bounded generation.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(span as u128);
        let mut l = m as u64;
        if l < span {
            let t = span.wrapping_neg() % span;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(span as u128);
                l = m as u64;
            }
        }
        lo + (m >> 64) as u64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_u64(lo as u64, hi as u64) as usize
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via the Marsaglia polar method (cached spare).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with mean/std, truncated at `floor` (resource capacities are
    /// positive; the paper models VM power and WAN bandwidth as normal).
    pub fn normal_pos(&mut self, mean: f64, std: f64, floor: f64) -> f64 {
        let x = mean + std * self.gauss();
        if x < floor {
            floor
        } else {
            x
        }
    }

    /// Exponential with rate lambda (job inter-arrival times).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        -u.ln() / lambda
    }

    /// Poisson draw (Knuth for small means, normal approximation above 64).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 64.0 {
            let x = mean + mean.sqrt() * self.gauss();
            return x.max(0.0).round() as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Pareto draw with scale x_m and shape alpha (heavy-tailed degrees).
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        let mut u = self.f64();
        if u <= 0.0 {
            u = f64::MIN_POSITIVE;
        }
        x_m / u.powf(1.0 / alpha)
    }

    /// Sample an index according to non-negative weights.
    pub fn weighted_index(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.range_usize(0, weights.len().saturating_sub(1));
        }
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_usize(0, i);
            xs.swap(i, j);
        }
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range_usize(0, xs.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn range_u64_inclusive_bounds() {
        let mut r = Rng::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let x = r.range_u64(3, 5);
            assert!((3..=5).contains(&x));
            seen_lo |= x == 3;
            seen_hi |= x == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let lambda = 0.07;
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let mean = (0..n).map(|_| r.poisson(3.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn weighted_index_respects_weights() {
        let mut r = Rng::new(19);
        let w = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted_index(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn normal_pos_floor() {
        let mut r = Rng::new(23);
        for _ in 0..1000 {
            assert!(r.normal_pos(1.0, 10.0, 0.25) >= 0.25);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
