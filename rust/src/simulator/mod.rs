//! Slotted discrete-event simulator of the geo-distributed plant
//! (the CloudSim substitute — Sec 6.1).
//!
//! Semantics follow Sec 3.2/3.3:
//! * a copy of task ξ launched in cluster m runs at
//!   `min(V^P_m, mean over sources of V^T_{src,m})`, both drawn from the
//!   cluster's ground-truth distributions at launch;
//! * per-slot Bernoulli cluster-level unreachability kills every copy in
//!   the afflicted cluster;
//! * slot capacity M_k and gate bandwidths Ing_k / Eg_k (Eqs. 9–11) are
//!   enforced by the engine regardless of what a policy requests;
//! * a task completes when its fastest alive copy has processed D_l^i;
//!   sibling copies cancel and free their slots; completions propagate
//!   readiness through the DAG (Eq. 8) and the last task completes the job.

pub mod engine;
pub mod state;

pub use engine::{SimConfig, SimResult, Simulation};
pub use state::{CopyRt, JobRt, TaskRt, TaskState};
