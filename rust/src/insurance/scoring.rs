//! Copy-placement scoring: the numeric hot path of the insurer.
//!
//! Everything here is expressed over the performance modeler's histogram
//! estimates. The same math — bottleneck min-composition followed by
//! E\[max\] over the copy set — is what the batched `runtime::scorer`
//! backends compute; since the batched-hot-path refactor the insurer
//! routes every candidate through a [`crate::runtime::Scorer`] and this
//! module supplies the shared pieces both paths build on:
//!
//! * [`assemble_score`] — turn one candidate's combined rate into a
//!   [`CandidateScore`] (floor rate + `pro`), including the "no existing
//!   copies → the combined rate *is* the solo rate" branch, so the scalar
//!   and batched paths cannot drift apart.
//! * [`existing_cdf_and_rate`] — the frozen copy set's CDF product and
//!   its E\[max\] byproduct, accumulated exactly like
//!   `Hist::expected_max` so batched scores stay bit-identical to the
//!   scalar algebra.
//! * [`score_candidates`]/[`score_candidates_cached`] — the per-candidate
//!   scalar reference path (tests, benches, and `--scorer scalar`).
//!
//! Nothing here is thread-count sensitive: the batched path may shard a
//! round's rows across OS threads (`SimConfig::score_threads`), but every
//! function in this module is pure over frozen per-slot state, and the
//! shard merge preserves row order — so the scalar reference remains the
//! bit-exact oracle for the sharded path too.
//!
//! Telemetry note: this module stays *uninstrumented* by design. The
//! `crate::obs` counters (rows scored, rejections by reason) and wall
//! spans live at the call sites in `insurance::pingan`, so the scoring
//! math remains pure functions with no observable side channel.

use crate::dist::Hist;
use crate::perfmodel::PerfModel;
use crate::workload::job::OpKind;

/// Score of one candidate cluster for one task.
#[derive(Clone, Debug)]
pub struct CandidateScore {
    pub cluster: usize,
    /// E[r(x+1)] if the copy lands here (x = existing copies).
    pub rate: f64,
    /// E[r(1)] of this copy alone (floor checks use the solo rate).
    pub solo_rate: f64,
    /// pro after adding the copy.
    pub pro: f64,
}

/// Assemble one candidate's [`CandidateScore`] from its combined rate.
/// `combined = None` means the task has no existing copies, where the
/// combined rate is the solo rate by definition — the scalar branch both
/// scoring paths must share bit for bit (no E\[max\] is ever computed
/// there, so f64 telescoping differences cannot creep in).
pub fn assemble_score(
    model: &PerfModel,
    existing_clusters: &[usize],
    cluster: usize,
    datasize: f64,
    solo_rate: f64,
    combined: Option<f64>,
) -> CandidateScore {
    let rate = combined.unwrap_or(solo_rate);
    let pro = pro_with_candidate(model, existing_clusters, cluster, datasize, rate);
    CandidateScore {
        cluster,
        rate,
        solo_rate,
        pro,
    }
}

/// The frozen copy set's combined CDF (`Π_i F_i(v_j)` per bin, each factor
/// clamped at 1 like `Hist::expected_max` does) and, as a byproduct of the
/// same sweep, `E[max over existing]` — the task's current rate.
///
/// Returns `(ones, 0.0)` for an empty copy set, matching the scalar
/// path's `current_rate = 0.0` convention. The accumulation order mirrors
/// `Hist::expected_max` exactly: scoring a candidate against the returned
/// CDF row multiplies `cand_cdf * product`, which is bit-identical to the
/// scalar `product * cand_cdf` because IEEE multiplication commutes.
pub fn existing_cdf_and_rate(existing: &[&Hist], values: &[f64]) -> (Vec<f64>, f64) {
    let v = values.len();
    let mut cdf = vec![1.0f64; v];
    if existing.is_empty() {
        return (cdf, 0.0);
    }
    let mut accs = vec![0.0f64; existing.len()];
    let mut prev = 0.0f64;
    let mut e = 0.0f64;
    for (j, slot) in cdf.iter_mut().enumerate() {
        let mut combined = 1.0f64;
        for (acc, h) in accs.iter_mut().zip(existing) {
            *acc += h.pmf()[j];
            combined *= acc.min(1.0);
        }
        *slot = combined;
        e += values[j] * (combined - prev);
        prev = combined;
    }
    (cdf, e)
}

/// Evaluate every cluster in `candidates` for a task with `existing` copy
/// rate-hists in `existing_clusters`. Returns scores aligned to input.
/// Scalar reference path (per-candidate E\[max\]).
#[allow(clippy::too_many_arguments)]
pub fn score_candidates(
    model: &PerfModel,
    sources: &[usize],
    op: OpKind,
    datasize: f64,
    existing: &[Hist],
    existing_clusters: &[usize],
    candidates: &[usize],
) -> Vec<CandidateScore> {
    candidates
        .iter()
        .map(|&m| {
            let cand = model.rate_hist(sources, m, op);
            let solo = cand.mean();
            let combined = if existing.is_empty() {
                None
            } else {
                Some(model.exp_rate_with(existing, &cand))
            };
            assemble_score(model, existing_clusters, m, datasize, solo, combined)
        })
        .collect()
}

/// Like [`score_candidates`] but over precomputed per-cluster (solo rate,
/// rate hist) pairs — the insurer's per-slot cache layout. This is the
/// scalar reference the batched path is tested against (`--scorer
/// scalar` runs the insurer on it).
pub fn score_candidates_cached(
    model: &PerfModel,
    datasize: f64,
    solo: &[(f64, Hist)],
    existing: &[Hist],
    existing_clusters: &[usize],
    candidates: &[usize],
) -> Vec<CandidateScore> {
    candidates
        .iter()
        .map(|&m| {
            let (solo_rate, cand) = &solo[m];
            let combined = if existing.is_empty() {
                None
            } else {
                Some(model.exp_rate_with(existing, cand))
            };
            assemble_score(model, existing_clusters, m, datasize, *solo_rate, combined)
        })
        .collect()
}

/// `pro` of the task if a copy is added in `candidate` (Sec 3.2: per-slot
/// survival is `1 - Π p_m` over distinct copy clusters).
pub fn pro_with_candidate(
    model: &PerfModel,
    existing_clusters: &[usize],
    candidate: usize,
    datasize: f64,
    rate: f64,
) -> f64 {
    let mut cs: Vec<usize> = existing_clusters.to_vec();
    cs.push(candidate);
    model.pro(&cs, datasize, rate)
}

/// The round-1 rate floor (Sec 4.1): a slot is acceptable only when the
/// copy's expected rate is at least `1/(1+ε)` of the task's global optimum.
pub fn passes_rate_floor(solo_rate: f64, global_best: f64, epsilon: f64) -> bool {
    solo_rate + 1e-12 >= global_best / (1.0 + epsilon)
}

/// The resource-saving admission rule for the c-th copy (c >= 2 extra):
/// `E^{c-1}[e] > (c+1)/c · E^{c}[e]`.
pub fn resource_saving_ok(datasize: f64, rate_before: f64, rate_after: f64, c: usize) -> bool {
    if rate_before <= 0.0 || rate_after <= 0.0 {
        return false;
    }
    let e_before = datasize / rate_before;
    let e_after = datasize / rate_after;
    e_before > (c as f64 + 1.0) / (c as f64) * e_after
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GeoSystem;
    use crate::config::spec::SystemSpec;
    use crate::util::rng::Rng;

    fn model() -> PerfModel {
        let mut rng = Rng::new(51);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        PerfModel::new(&sys, 64)
    }

    #[test]
    fn rate_floor_boundary() {
        assert!(passes_rate_floor(10.0, 16.0, 0.6)); // 16/1.6 = 10
        assert!(!passes_rate_floor(9.9, 16.0, 0.6));
        assert!(passes_rate_floor(5.0, 5.0, 0.2));
    }

    #[test]
    fn resource_saving_rule() {
        // c=2: requires e1 > 1.5 e2 -> rate_after > 1.5 rate_before
        assert!(resource_saving_ok(100.0, 1.0, 1.6, 2));
        assert!(!resource_saving_ok(100.0, 1.0, 1.4, 2));
        // c=3: requires e2 > (4/3) e3
        assert!(resource_saving_ok(100.0, 1.0, 1.4, 3));
        assert!(!resource_saving_ok(100.0, 1.0, 1.2, 3));
        assert!(!resource_saving_ok(100.0, 0.0, 1.0, 2));
    }

    #[test]
    fn scores_cover_candidates_and_improve_with_copies() {
        let pm = model();
        let sources = vec![1usize];
        let op = OpKind::Map;
        let scores = score_candidates(&pm, &sources, op, 500.0, &[], &[], &[0, 2, 3]);
        assert_eq!(scores.len(), 3);
        for s in &scores {
            assert!(s.rate > 0.0 && s.pro > 0.0 && s.pro <= 1.0);
            assert!((s.rate - s.solo_rate).abs() < 1e-9, "no existing copies");
        }
        // now with an existing copy: combined rate >= solo of candidate
        let existing = vec![pm.rate_hist(&sources, 0, op)];
        let with = score_candidates(&pm, &sources, op, 500.0, &existing, &[0], &[2]);
        assert!(with[0].rate >= with[0].solo_rate - 1e-9);
    }

    #[test]
    fn pro_candidate_dedups_cluster() {
        let pm = model();
        let a = pro_with_candidate(&pm, &[0], 0, 100.0, 5.0);
        let b = pm.pro(&[0], 100.0, 5.0);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn existing_cdf_matches_expected_max_bitwise() {
        // the byproduct rate equals Hist::expected_max over the same
        // family, and scoring against the CDF row reproduces the scalar
        // E[max] with the candidate appended — both to the bit
        let pm = model();
        let op = OpKind::Map;
        let grid = pm.grid().clone();
        let a = pm.rate_hist(&[1], 0, op);
        let b = pm.rate_hist(&[1], 3, op);
        let cand = pm.rate_hist(&[1], 2, op);
        let (cdf, rate) = existing_cdf_and_rate(&[&a, &b], grid.values());
        let want_rate = Hist::expected_max(&[&a, &b]);
        assert_eq!(rate.to_bits(), want_rate.to_bits());
        // candidate appended LAST in the scalar refs — the batched layout
        // multiplies cand * product instead; they must agree bitwise
        let want_with = Hist::expected_max(&[&a, &b, &cand]);
        let mut acc = 0.0f64;
        let mut prev = 0.0f64;
        let mut got = 0.0f64;
        for j in 0..grid.bins() {
            acc += cand.pmf()[j];
            let combined = acc.min(1.0) * cdf[j];
            got += grid.value(j) * (combined - prev);
            prev = combined;
        }
        assert_eq!(got.to_bits(), want_with.to_bits());
        // empty family: neutral CDF, zero current rate
        let (ones, zero) = existing_cdf_and_rate(&[], grid.values());
        assert!(ones.iter().all(|&x| x == 1.0));
        assert_eq!(zero, 0.0);
    }

    #[test]
    fn assemble_score_shares_the_no_copy_branch() {
        let pm = model();
        let s = assemble_score(&pm, &[], 2, 400.0, 7.5, None);
        assert_eq!(s.rate, 7.5);
        assert_eq!(s.solo_rate, 7.5);
        assert_eq!(s.cluster, 2);
        let s2 = assemble_score(&pm, &[0], 2, 400.0, 7.5, Some(9.0));
        assert_eq!(s2.rate, 9.0);
        assert!((s2.pro - pm.pro(&[0, 2], 400.0, 9.0)).abs() < 1e-15);
    }
}
