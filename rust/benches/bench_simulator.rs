//! Substrate benches: histogram algebra, topology/workload generation and
//! raw engine throughput — the denominators of every experiment.
//!
//! Run: `cargo bench --bench bench_simulator`
//! (set PINGAN_BENCH_FAST=1 for a quick smoke pass)

use pingan::baselines::Flutter;
use pingan::bench_harness::Bench;
use pingan::cluster::GeoSystem;
use pingan::config::spec::{SystemSpec, TimeModel, WorkloadSpec};
use pingan::dist::{Grid, Hist};
use pingan::insurance::PingAn;
use pingan::simulator::{SimConfig, Simulation};
use pingan::topology::Topology;
use pingan::util::jsonout::Json;
use pingan::util::rng::Rng;
use pingan::workload::montage;

/// Sparse fig7-style workload: PingAn over a low-λ Montage stream — long
/// idle-ish stretches between arrivals, exactly where the event-skip core
/// should touch a small fraction of the slots. Deterministic (fixed seed).
fn fig7_sparse_setup() -> (GeoSystem, Vec<pingan::workload::job::JobSpec>) {
    let mut rng = Rng::new(0xF165);
    let sys = GeoSystem::generate(&SystemSpec::small(8), &mut rng);
    let mut w = WorkloadSpec::scaled(16, 0.002);
    w.datasize = (100.0, 600.0);
    w.size_classes = vec![(1.0, (2, 30))];
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);
    (sys, jobs)
}

fn run_sparse(time_model: TimeModel) -> pingan::simulator::SimResult {
    let (sys, jobs) = fig7_sparse_setup();
    let mut cfg = SimConfig::default();
    cfg.time_model = time_model;
    Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6))
}

/// Wide-plant workload for the engine-sharding cases: 256 clusters — at 4
/// engine threads each shard owns exactly [`MIN_CLUSTERS_PER_SHARD`]
/// clusters, so the barrier really spawns — under a cheap policy, so the
/// per-cluster plant advance dominates. Deterministic (fixed seed);
/// shard1/shard4 results are bit-identical, only wall time differs.
fn run_sharded(engine_threads: usize) -> pingan::simulator::SimResult {
    let mut rng = Rng::new(0x54A2);
    let sys = GeoSystem::generate(&SystemSpec::small(256), &mut rng);
    let mut w = WorkloadSpec::scaled(6, 0.01);
    w.datasize = (100.0, 400.0);
    w.size_classes = vec![(1.0, (2, 20))];
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);
    let mut cfg = SimConfig::default();
    cfg.time_model = TimeModel::EventSkip;
    cfg.engine_threads = engine_threads;
    Simulation::new(&sys, jobs, cfg).run(&mut Flutter::new())
}

/// Streaming million-job replay: jobs flow from an incremental
/// [`pingan::workload::source::GenSource`] (never materialized as a Vec)
/// with `stream_metrics` shedding the per-job flowtime series, so
/// resident state is O(clusters + alive jobs) no matter how long the
/// trace. λ is kept well under the small plant's capacity so the alive
/// set stays small and the run terminates; event-skip makes the empty
/// slots free. Deterministic (fixed seed).
fn run_replay(n_jobs: usize) -> pingan::simulator::SimResult {
    let mut rng = Rng::new(0x1E9);
    let sys = GeoSystem::generate(&SystemSpec::small(8), &mut rng);
    let sites: Vec<usize> = (0..sys.n()).collect();
    let wseed = 0x1E9 ^ 0xABCD;
    let mut w = WorkloadSpec::scaled(n_jobs, 0.2);
    w.size_classes = vec![(1.0, (2, 8))];
    w.datasize = (50.0, 200.0);
    w.seed = wseed;
    let src = pingan::workload::source::GenSource::new(w, sites, wseed);
    let mut cfg = SimConfig::default();
    cfg.time_model = TimeModel::EventSkip;
    cfg.stream_metrics = true;
    // ~n/λ slots of simulated time; the default 2M wall would truncate
    cfg.max_slots = 20 * n_jobs.max(100_000) as u64;
    Simulation::from_source(&sys, src, cfg).run(&mut Flutter::new())
}

fn main() {
    let mut b = Bench::new("simulator");
    let fast = std::env::var("PINGAN_BENCH_FAST").ok().as_deref() == Some("1");

    // histogram algebra (the scoring inner loop)
    let grid = Grid::uniform(0.0, 400.0, 64);
    let h1 = Hist::normal(&grid, 120.0, 30.0);
    let h2 = Hist::normal(&grid, 90.0, 40.0);
    let h3 = Hist::normal(&grid, 150.0, 20.0);
    b.case("hist_min_compose_64bins", || {
        h1.min_compose(&h2).mean()
    });
    b.case("hist_expected_max_3x64bins", || {
        Hist::expected_max(&[&h1, &h2, &h3])
    });
    b.case("hist_normal_fit_64bins", || {
        Hist::normal(&grid, 100.0, 25.0).mean()
    });

    // generation
    b.case("topology_100_clusters", || {
        let mut rng = Rng::new(1);
        Topology::generate(100, 2, &mut rng).degree(0) as f64
    });
    b.case("geosystem_100_clusters", || {
        let mut rng = Rng::new(2);
        GeoSystem::generate(&SystemSpec::default(), &mut rng).total_slots() as f64
    });
    b.case("montage_100_jobs", || {
        let mut rng = Rng::new(3);
        let w = WorkloadSpec::scaled(100, 0.07);
        montage::generate(&w, &[0, 1, 2, 3], &mut rng).len() as f64
    });

    // engine throughput: one full small run under a cheap policy
    b.case("engine_run_12jobs_6clusters", || {
        let mut rng = Rng::new(4);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(12, 0.05);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let res = Simulation::new(&sys, jobs, SimConfig::default()).run(&mut Flutter::new());
        res.slots as f64
    });

    // dual-mode time core on the sparse fig7-style workload: dense walks
    // every slot, event-skip only the events — same plant, same jobs
    b.case("sim_dense", || run_sparse(TimeModel::Dense).slots as f64);
    b.case("sim_eventskip", || {
        run_sparse(TimeModel::EventSkip).events_processed as f64
    });

    // telemetry overhead: the same sparse PingAn run with wall-span
    // clocks off vs on (plane-A counters are unconditional and an
    // integer bump deep inside already-hot paths; plane B adds two
    // Instant reads per insurer round plus shard/barrier timings). CI's
    // bench smoke gates `on` ≤ 1.05× `off` plus an absolute slack so
    // telemetry can never grow into a real cost silently.
    b.case("sim_telemetry_off", || {
        let (sys, jobs) = fig7_sparse_setup();
        let mut cfg = SimConfig::default();
        cfg.time_model = TimeModel::EventSkip;
        cfg.telemetry = false;
        let res = Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6));
        res.telemetry.admissions as f64
    });
    b.case("sim_telemetry_on", || {
        let (sys, jobs) = fig7_sparse_setup();
        let mut cfg = SimConfig::default();
        cfg.time_model = TimeModel::EventSkip;
        cfg.telemetry = true;
        let res = Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(0.6));
        res.telemetry.admissions as f64
    });

    // cluster-sharded plant advance: serial vs 4 engine threads on a wide
    // plant (bit-identical results; CI's bench smoke gates shard4 wall
    // time ≤ 1.1× shard1 — sharding must never *cost* throughput)
    b.case("sim_shard1", || run_sharded(1).events_processed as f64);
    b.case("sim_shard4", || run_sharded(4).events_processed as f64);

    // streaming replay throughput: a long GenSource stream under
    // stream_metrics (the bounded-memory mode the `pingan replay` CLI and
    // the CI memory-ceiling leg exercise). Full mode replays a million
    // jobs per iteration; fast mode 50k so the smoke pass stays short.
    let replay_jobs = if fast { 50_000 } else { 1_000_000 };
    let replay_case = if fast { "sim_replay_50k" } else { "sim_replay_1m" };
    b.case(replay_case, || {
        let res = run_replay(replay_jobs);
        assert_eq!(
            res.finished_jobs, res.total_jobs,
            "replay bench left jobs unfinished (λ over capacity?)"
        );
        assert!(res.flowtimes.is_empty(), "stream_metrics kept the raw Vec");
        res.stats.p99()
    });

    // Deterministic skip-efficiency gate (no wall-clock flakiness): one
    // fixed-seed run per core; CI asserts eventskip events ≤ 25% of dense
    // slots from this line.
    let dense = run_sparse(TimeModel::Dense);
    let event = run_sparse(TimeModel::EventSkip);
    assert_eq!(
        dense.finished_jobs, dense.total_jobs,
        "dense run left jobs unfinished"
    );
    assert_eq!(
        event.finished_jobs, event.total_jobs,
        "event-skip run left jobs unfinished"
    );
    let mut j = Json::obj();
    j.set("suite", Json::str("simulator"))
        .set("dense_slots", Json::num(dense.slots as f64))
        .set("dense_events", Json::num(dense.events_processed as f64))
        .set("eventskip_slots", Json::num(event.slots as f64))
        .set("eventskip_events", Json::num(event.events_processed as f64))
        .set(
            "event_ratio",
            Json::num(event.events_processed as f64 / dense.slots.max(1) as f64),
        );
    println!("SIMGATE {}", j.to_string());
}
