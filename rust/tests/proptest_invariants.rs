//! Property-based invariants over randomized inputs (an in-tree proptest:
//! seeds sweep a generator; any failure prints the violating seed).
//!
//! Coordinator invariants covered:
//! * engine ledgers (slots, gates) never oversubscribe under any policy mix
//! * task copies never exceed the configured cap
//! * flowtimes are finite and >= critical-path lower bounds
//! * Proposition 1 (diminishing returns) on randomized distribution families
//! * reduction ratios bounded above by 1

use pingan::analysis::proposition::{check_proposition1, random_family};
use pingan::cluster::GeoSystem;
use pingan::config::spec::{PingAnSpec, SystemSpec, WorkloadSpec};
use pingan::dist::Grid;
use pingan::insurance::PingAn;
use pingan::simulator::{SimConfig, Simulation};
use pingan::util::rng::Rng;
use pingan::workload::montage;

const SEEDS: std::ops::Range<u64> = 0..12;

#[test]
fn prop_engine_invariants_hold_for_random_workloads() {
    for seed in SEEDS {
        let mut rng = Rng::new(0xABC0 + seed);
        let n_clusters = rng.range_usize(3, 10);
        let n_jobs = rng.range_usize(2, 10);
        let lambda = rng.range_f64(0.02, 0.2);
        let sys = GeoSystem::generate(&SystemSpec::small(n_clusters), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, lambda);
        w.datasize = (20.0, 400.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let eps = rng.range_f64(0.15, 0.9);
        let mut p = PingAn::with_epsilon(eps);
        for step in 0..150 {
            sim.step(&mut p);
            sim.check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
        }
    }
}

#[test]
fn prop_copy_cap_respected_for_random_caps() {
    for seed in SEEDS {
        let mut rng = Rng::new(0xBEE0 + seed);
        let cap = rng.range_usize(1, 4);
        let sys = GeoSystem::generate(&SystemSpec::small(5), &mut rng);
        let mut w = WorkloadSpec::scaled(4, 0.1);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let mut spec = PingAnSpec::with_epsilon(0.7);
        spec.max_copies = cap;
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let mut p = PingAn::new(spec);
        for _ in 0..120 {
            sim.step(&mut p);
            for j in &sim.jobs {
                for t in &j.tasks {
                    assert!(
                        t.alive_copies() <= cap,
                        "seed {seed}: cap {cap} violated ({} copies)",
                        t.alive_copies()
                    );
                }
            }
        }
    }
}

#[test]
fn prop_flowtimes_at_least_stage_depth() {
    // a job cannot finish faster than its critical path (>= 1 slot/stage)
    for seed in SEEDS {
        let mut rng = Rng::new(0xCAFE + seed);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(5, 0.05);
        w.datasize = (20.0, 200.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let depths: Vec<usize> = jobs.iter().map(|j| j.critical_path()).collect();
        let res = Simulation::new(&sys, jobs, SimConfig::default())
            .run(&mut PingAn::with_epsilon(0.6));
        for (i, f) in res.flowtimes.iter().enumerate() {
            assert!(f.is_finite(), "seed {seed}: job {i} unfinished");
            assert!(
                *f + 1.0 >= depths[i] as f64,
                "seed {seed}: job {i} flowtime {f} < critical path {}",
                depths[i]
            );
        }
    }
}

#[test]
fn prop_proposition1_on_random_families() {
    let grid = Grid::uniform(0.0, 20.0, 64);
    for seed in 0..40u64 {
        let mut rng = Rng::new(0xD00D + seed);
        let n = rng.range_usize(2, 8);
        let fam = random_family(&mut rng, n, &grid);
        check_proposition1(&fam, 1e-9)
            .unwrap_or_else(|k| panic!("seed {seed}: Prop 1 violated at k={k}"));
    }
}

#[test]
fn prop_scorer_backends_agree_on_random_batches() {
    use pingan::runtime::{CpuScorer, ScoreBatch, Scorer};
    // CPU scorer vs dist::Hist on random batches (HLO covered in lib tests)
    for seed in 0..10u64 {
        let mut rng = Rng::new(0xF00 + seed);
        let (b, k, v) = (
            rng.range_usize(1, 4),
            rng.range_usize(1, 5),
            rng.range_usize(8, 64),
        );
        let mut batch = ScoreBatch::new(b, k, v);
        batch.values = (0..v).map(|i| i as f32 * 0.25).collect();
        for x in batch.proc_pmf.iter_mut().chain(batch.trans_pmf.iter_mut()) {
            *x = rng.f64() as f32 + 1e-3;
        }
        for bi in 0..b {
            for ki in 0..k {
                let base = (bi * k + ki) * v;
                for pmf in [&mut batch.proc_pmf, &mut batch.trans_pmf] {
                    let s: f32 = pmf[base..base + v].iter().sum();
                    pmf[base..base + v].iter_mut().for_each(|e| *e /= s);
                }
            }
        }
        let out = CpuScorer.score(&batch).unwrap();
        assert_eq!(out.len(), b * k);
        let vmax = batch.values[v - 1];
        for (i, r) in out.iter().enumerate() {
            assert!(
                *r >= -1e-6 && *r <= vmax + 1e-4,
                "seed {seed} idx {i}: rate {r} outside [0, {vmax}]"
            );
        }
    }
}
