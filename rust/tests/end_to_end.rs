//! Integration: whole-stack runs across modules — plant generation,
//! workload, every scheduler, metrics — plus the paper's qualitative
//! claims at smoke scale.

use pingan::cluster::GeoSystem;
use pingan::config::spec::{PingAnSpec, SystemSpec, WorkloadSpec};
use pingan::experiments::{self, Scale};
use pingan::insurance::PingAn;
use pingan::simulator::{SimConfig, Simulation};
use pingan::util::rng::Rng;
use pingan::workload::montage;

fn setup(
    n_clusters: usize,
    n_jobs: usize,
    lambda: f64,
    seed: u64,
) -> (GeoSystem, Vec<pingan::workload::job::JobSpec>) {
    let mut rng = Rng::new(seed);
    let sys = GeoSystem::generate(&SystemSpec::small(n_clusters), &mut rng);
    let mut w = WorkloadSpec::scaled(n_jobs, lambda);
    w.datasize = (50.0, 500.0);
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);
    (sys, jobs)
}

#[test]
fn every_scheduler_completes_the_same_workload() {
    let (sys, jobs) = setup(8, 12, 0.05, 1001);
    for name in [
        "pingan",
        "spark",
        "spark-spec",
        "flutter",
        "iridium",
        "flutter+mantri",
        "flutter+dolly",
    ] {
        let mut sched = experiments::make_scheduler(name, 0.6);
        let res = Simulation::new(&sys, jobs.clone(), SimConfig::default()).run(sched.as_mut());
        assert_eq!(
            res.finished_jobs, res.total_jobs,
            "{name} left jobs unfinished"
        );
        assert!(res.avg_flowtime() > 0.0, "{name} zero flowtime");
    }
}

#[test]
fn pingan_beats_single_copy_baselines_under_failures() {
    // Under non-trivial failure rates, insurance should beat no-copy
    // Flutter on average flowtime (the paper's core claim, Fig 4).
    let mut spec = SystemSpec::small(8);
    for c in &mut spec.classes {
        c.unreach_p = (c.unreach_p.0 * 2.0, (c.unreach_p.1 * 2.0).min(0.5));
    }
    let mut rng = Rng::new(2002);
    let sys = GeoSystem::generate(&spec, &mut rng);
    let mut w = WorkloadSpec::scaled(18, 0.04);
    w.datasize = (50.0, 500.0);
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);

    let mut flutter_sum = 0.0;
    let mut pingan_sum = 0.0;
    for rep in 0..3u64 {
        let mut cfg = SimConfig::default();
        cfg.seed = 7000 + rep;
        let f = Simulation::new(&sys, jobs.clone(), cfg.clone())
            .run(&mut pingan::baselines::Flutter::new());
        let p =
            Simulation::new(&sys, jobs.clone(), cfg).run(&mut PingAn::with_epsilon(0.6));
        flutter_sum += f.avg_flowtime();
        pingan_sum += p.avg_flowtime();
    }
    assert!(
        pingan_sum < flutter_sum,
        "pingan {pingan_sum} !< flutter {flutter_sum}"
    );
}

#[test]
fn sum_flowtime_is_the_objective() {
    let (sys, jobs) = setup(6, 8, 0.05, 1003);
    let res =
        Simulation::new(&sys, jobs, SimConfig::default()).run(&mut PingAn::with_epsilon(0.6));
    let avg = res.avg_flowtime();
    let sum = res.sum_flowtime();
    assert!((sum / res.finished_jobs as f64 - avg).abs() < 1e-9);
}

#[test]
fn epsilon_validation_rejected_at_construction() {
    let r = std::panic::catch_unwind(|| PingAn::new(PingAnSpec::with_epsilon(1.5)));
    assert!(r.is_err());
}

#[test]
fn experiments_smoke_scale_pipeline() {
    let scale = Scale::smoke();
    let (sys, jobs) = experiments::sim_setup(&scale, 0.07, 0);
    assert_eq!(jobs.len(), scale.n_jobs);
    let a = experiments::run_one(&sys, jobs.clone(), "pingan", 0.6, 0);
    let b = experiments::run_one(&sys, jobs, "pingan", 0.6, 0);
    // same seed -> identical results (regeneration is reproducible)
    assert_eq!(a.flowtimes, b.flowtimes);
}

#[test]
fn reduction_ratio_pipeline_matches_fig5_semantics() {
    let (sys, jobs) = setup(6, 10, 0.05, 1004);
    let f = Simulation::new(&sys, jobs.clone(), SimConfig::default())
        .run(&mut pingan::baselines::Flutter::new());
    let p = Simulation::new(&sys, jobs, SimConfig::default())
        .run(&mut PingAn::with_epsilon(0.6));
    let rr = pingan::metrics::cdf::reduction_ratios(&f.flowtimes, &p.flowtimes);
    assert_eq!(rr.len(), f.flowtimes.len());
    for r in &rr {
        assert!(*r <= 1.0, "reduction ratio > 1 impossible");
    }
}

/// Scheduler decorator recording the full Action stream plus per-slot
/// action counts, so two runs can be compared decision for decision.
struct Recording<S> {
    inner: S,
    log: Vec<pingan::sched::Action>,
    per_slot: Vec<usize>,
}

impl<S: pingan::sched::Scheduler> pingan::sched::Scheduler for Recording<S> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn schedule(&mut self, view: &mut pingan::sched::SchedView<'_>) -> Vec<pingan::sched::Action> {
        let actions = self.inner.schedule(view);
        self.per_slot.push(actions.len());
        self.log.extend(actions.iter().copied());
        actions
    }

    fn on_task_done(&mut self, job: usize, task: usize, now: u64) {
        self.inner.on_task_done(job, task, now)
    }

    // must forward: under stream_metrics the engine recycles job slots,
    // and the inner policy's per-job cleanup hangs off this hook
    fn on_job_retired(&mut self, job: usize) {
        self.inner.on_job_retired(job)
    }

    fn next_wake(&mut self, now: u64) -> Option<u64> {
        self.inner.next_wake(now)
    }
}

/// Acceptance pin for the time-core refactor: `TimeModel::Dense` must be
/// bit-identical to the pre-refactor engine. `Simulation::step` *is* the
/// pre-refactor engine's slot loop (kept verbatim by the refactor), so
/// driving it by hand must reproduce `run()`'s Action stream and
/// `SimResult` (minus wall time) exactly — for PingAn and one baseline,
/// over a fixed-seed λ grid.
#[test]
fn dense_run_matches_the_legacy_step_loop_bit_for_bit() {
    use pingan::simulator::TimeModel;
    for sched_name in ["pingan", "flutter"] {
        for (lambda, seed) in [(0.05, 81u64), (0.12, 82)] {
            let (sys, jobs) = setup(6, 9, lambda, 5000 + seed);
            let mut cfg = SimConfig::default();
            cfg.seed = 0xD0_0D ^ seed;
            assert_eq!(cfg.time_model, TimeModel::Dense, "dense is the default");

            // run(): the refactored engine's dense path
            let mut run_rec = Recording {
                inner: experiments::make_scheduler(sched_name, 0.6),
                log: Vec::new(),
                per_slot: Vec::new(),
            };
            let res = Simulation::new(&sys, jobs.clone(), cfg.clone()).run(&mut run_rec);

            // the legacy loop: step() until every job is done
            let mut step_rec = Recording {
                inner: experiments::make_scheduler(sched_name, 0.6),
                log: Vec::new(),
                per_slot: Vec::new(),
            };
            let mut sim = Simulation::new(&sys, jobs.clone(), cfg);
            while !sim.jobs.iter().all(|j| j.is_done()) {
                assert!(sim.now() < 2_000_000, "legacy loop ran away");
                sim.step(&mut step_rec);
            }

            assert_eq!(
                run_rec.per_slot, step_rec.per_slot,
                "{sched_name} λ={lambda}: per-slot action counts diverged"
            );
            assert_eq!(
                run_rec.log, step_rec.log,
                "{sched_name} λ={lambda}: action streams diverged"
            );
            let legacy_flows: Vec<f64> = sim
                .jobs
                .iter()
                .map(|j| j.flowtime().map(|f| f as f64).unwrap_or(f64::NAN))
                .collect();
            assert_eq!(res.flowtimes, legacy_flows);
            assert_eq!(res.finished_jobs, res.total_jobs);
            assert_eq!(res.slots, sim.now());
            assert_eq!(res.copies_launched, sim.copies_launched());
            assert_eq!(res.copies_failed, sim.copies_failed());
            assert_eq!(res.events_processed, sim.events_processed());
        }
    }
}

/// Paired-seed statistical equivalence of the two time cores: identical
/// plant + job set per seed, per-job flowtime means within each other's
/// CI95 across ≥3 seeds (plus a floor for near-zero variance draws).
#[test]
fn eventskip_flowtimes_statistically_match_dense() {
    use pingan::simulator::TimeModel;
    for sched_name in ["flutter", "pingan"] {
        let mut dense_means = Vec::new();
        let mut event_means = Vec::new();
        for seed in 0..4u64 {
            let (sys, jobs) = setup(8, 14, 0.05, 6000 + seed);
            for (time_model, sink) in [
                (TimeModel::Dense, &mut dense_means),
                (TimeModel::EventSkip, &mut event_means),
            ] {
                let mut cfg = SimConfig::default();
                cfg.seed = 0xE0_0E ^ seed;
                cfg.time_model = time_model;
                let mut sched = experiments::make_scheduler(sched_name, 0.6);
                let res = Simulation::new(&sys, jobs.clone(), cfg).run(sched.as_mut());
                assert_eq!(
                    res.finished_jobs, res.total_jobs,
                    "{sched_name} seed {seed} {time_model:?}: unfinished jobs"
                );
                sink.push(res.avg_flowtime());
            }
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let ci95 = |v: &[f64]| {
            let m = mean(v);
            let var = v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (v.len() - 1) as f64;
            1.96 * (var / v.len() as f64).sqrt()
        };
        let (md, me) = (mean(&dense_means), mean(&event_means));
        let budget = (ci95(&dense_means) + ci95(&event_means)).max(0.20 * md);
        assert!(
            (md - me).abs() <= budget,
            "{sched_name}: dense mean {md:.1} vs event-skip mean {me:.1} \
             (budget {budget:.1}; per-seed dense {dense_means:?} event {event_means:?})"
        );
    }
}

/// The intra-cell-parallelism acceptance pin: `score_threads ∈ {1, 2, 4}`
/// must produce bit-identical Action streams and `SimResult`s (minus wall
/// time) on the fixed-seed λ/ε grid — for both time models and for both
/// the batched `cpu` scorer (which actually shards) and the `scalar`
/// reference (which must simply ignore the budget). The shard merge keeps
/// row order and every row's f64 arithmetic is untouched by partitioning,
/// so not a single admission may move.
#[test]
fn score_threads_are_invisible_to_the_action_stream() {
    use pingan::config::spec::ScorerKind;
    use pingan::simulator::TimeModel;
    fn run(
        sys: &GeoSystem,
        jobs: &[pingan::workload::job::JobSpec],
        eps: f64,
        kind: pingan::config::spec::ScorerKind,
        time_model: pingan::simulator::TimeModel,
        threads: usize,
    ) -> (Vec<pingan::sched::Action>, Vec<usize>, pingan::simulator::SimResult) {
        let mut spec = PingAnSpec::with_epsilon(eps);
        spec.scorer = kind;
        let mut rec = Recording {
            inner: PingAn::new(spec),
            log: Vec::new(),
            per_slot: Vec::new(),
        };
        let mut cfg = SimConfig::default();
        cfg.time_model = time_model;
        cfg.score_threads = threads;
        let res = Simulation::new(sys, jobs.to_vec(), cfg).run(&mut rec);
        (rec.log, rec.per_slot, res)
    }
    for (lambda, eps, seed) in [
        (0.05, 0.6, 71u64),
        (0.05, 0.2, 72),
        (0.10, 0.8, 73),
        (0.15, 0.4, 74),
    ] {
        let (sys, jobs) = setup(6, 10, lambda, 3000 + seed);
        for kind in [ScorerKind::Cpu, ScorerKind::Scalar] {
            // the scalar reference never builds a batch; one extra budget
            // suffices to pin that the knob is inert there
            let budgets: &[usize] = match kind {
                ScorerKind::Cpu => &[2, 4],
                _ => &[4],
            };
            for time_model in TimeModel::ALL {
                let base = run(&sys, &jobs, eps, kind, time_model, 1);
                assert_eq!(
                    base.2.finished_jobs, base.2.total_jobs,
                    "λ={lambda} ε={eps} {kind:?} {time_model:?}: unfinished baseline"
                );
                for &threads in budgets {
                    let got = run(&sys, &jobs, eps, kind, time_model, threads);
                    let tag = format!("λ={lambda} ε={eps} {kind:?} {time_model:?} t={threads}");
                    assert_eq!(got.1, base.1, "{tag}: per-slot action counts diverged");
                    assert_eq!(got.0, base.0, "{tag}: action streams diverged");
                    assert_eq!(got.2.finished_jobs, base.2.finished_jobs, "{tag}");
                    assert_eq!(got.2.copies_launched, base.2.copies_launched, "{tag}");
                    assert_eq!(got.2.copies_failed, base.2.copies_failed, "{tag}");
                    assert_eq!(got.2.slots, base.2.slots, "{tag}");
                    assert_eq!(got.2.events_processed, base.2.events_processed, "{tag}");
                    assert_eq!(got.2.flowtimes.len(), base.2.flowtimes.len(), "{tag}");
                    for (a, b) in got.2.flowtimes.iter().zip(&base.2.flowtimes) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag}: flowtime bits moved");
                    }
                }
            }
        }
    }
}

/// The cluster-sharding acceptance pin: `engine_threads ∈ {1, 2, 4}` must
/// produce bit-identical Action streams and `SimResult`s (minus wall
/// time) on the fixed-seed λ/ε grid, for both time cores. Every cluster
/// draws from its own RNG stream and every shard merge runs in cluster
/// order, so regrouping clusters into shards may not move one admission.
#[test]
fn engine_threads_are_invisible_to_the_action_stream() {
    use pingan::simulator::TimeModel;
    fn run(
        sys: &GeoSystem,
        jobs: &[pingan::workload::job::JobSpec],
        eps: f64,
        time_model: pingan::simulator::TimeModel,
        threads: usize,
    ) -> (Vec<pingan::sched::Action>, Vec<usize>, pingan::simulator::SimResult) {
        let mut rec = Recording {
            inner: PingAn::with_epsilon(eps),
            log: Vec::new(),
            per_slot: Vec::new(),
        };
        let mut cfg = SimConfig::default();
        cfg.time_model = time_model;
        cfg.engine_threads = threads;
        let res = Simulation::new(sys, jobs.to_vec(), cfg).run(&mut rec);
        (rec.log, rec.per_slot, res)
    }
    for (lambda, eps, seed) in [
        (0.05, 0.6, 71u64),
        (0.05, 0.2, 72),
        (0.10, 0.8, 73),
        (0.15, 0.4, 74),
    ] {
        let (sys, jobs) = setup(6, 10, lambda, 3000 + seed);
        for time_model in TimeModel::ALL {
            let base = run(&sys, &jobs, eps, time_model, 1);
            assert_eq!(
                base.2.finished_jobs, base.2.total_jobs,
                "λ={lambda} ε={eps} {time_model:?}: unfinished baseline"
            );
            for threads in [2usize, 4] {
                let got = run(&sys, &jobs, eps, time_model, threads);
                let tag = format!("λ={lambda} ε={eps} {time_model:?} engine_threads={threads}");
                assert_eq!(got.1, base.1, "{tag}: per-slot action counts diverged");
                assert_eq!(got.0, base.0, "{tag}: action streams diverged");
                assert_eq!(got.2.finished_jobs, base.2.finished_jobs, "{tag}");
                assert_eq!(got.2.copies_launched, base.2.copies_launched, "{tag}");
                assert_eq!(got.2.copies_failed, base.2.copies_failed, "{tag}");
                assert_eq!(got.2.slots, base.2.slots, "{tag}");
                assert_eq!(got.2.events_processed, base.2.events_processed, "{tag}");
                assert_eq!(got.2.flowtimes.len(), base.2.flowtimes.len(), "{tag}");
                for (a, b) in got.2.flowtimes.iter().zip(&base.2.flowtimes) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{tag}: flowtime bits moved");
                }
            }
        }
    }
}

/// The streaming-source acceptance pin: feeding the same job set through
/// [`pingan::workload::EagerSource`] (`Simulation::from_source`) must be
/// bit-identical to the legacy `Simulation::new` eager path — Action
/// stream, per-slot counts, flowtime bits, counters — at every
/// `score_threads` × `engine_threads` combination and on both time cores.
/// And `stream_metrics` must change *only* what is retained: the raw
/// flowtime Vec empties, while `SimResult::stats` and every engine
/// outcome stay bit-identical.
#[test]
fn workload_sources_and_stream_metrics_are_invisible_to_the_action_stream() {
    use pingan::simulator::TimeModel;
    use pingan::workload::EagerSource;
    fn run(
        sys: &GeoSystem,
        jobs: &[pingan::workload::job::JobSpec],
        time_model: TimeModel,
        score_threads: usize,
        engine_threads: usize,
        source: bool,
        stream_metrics: bool,
    ) -> (Vec<pingan::sched::Action>, Vec<usize>, pingan::simulator::SimResult) {
        let mut rec = Recording {
            inner: PingAn::with_epsilon(0.6),
            log: Vec::new(),
            per_slot: Vec::new(),
        };
        let mut cfg = SimConfig::default();
        cfg.time_model = time_model;
        cfg.score_threads = score_threads;
        cfg.engine_threads = engine_threads;
        cfg.stream_metrics = stream_metrics;
        let res = if source {
            Simulation::from_source(sys, EagerSource::new(jobs.to_vec()), cfg).run(&mut rec)
        } else {
            Simulation::new(sys, jobs.to_vec(), cfg).run(&mut rec)
        };
        (rec.log, rec.per_slot, res)
    }
    for (lambda, seed) in [(0.05, 91u64), (0.12, 92)] {
        let (sys, jobs) = setup(6, 10, lambda, 4000 + seed);
        for time_model in TimeModel::ALL {
            let base = run(&sys, &jobs, time_model, 1, 1, false, false);
            assert_eq!(
                base.2.finished_jobs, base.2.total_jobs,
                "λ={lambda} {time_model:?}: unfinished baseline"
            );
            for (st, et) in [(1usize, 1usize), (2, 2), (4, 1), (1, 4)] {
                for stream in [false, true] {
                    let got = run(&sys, &jobs, time_model, st, et, true, stream);
                    let tag = format!(
                        "λ={lambda} {time_model:?} score={st} engine={et} stream={stream}"
                    );
                    assert_eq!(got.1, base.1, "{tag}: per-slot action counts diverged");
                    assert_eq!(got.0, base.0, "{tag}: action streams diverged");
                    assert_eq!(got.2.finished_jobs, base.2.finished_jobs, "{tag}");
                    assert_eq!(got.2.copies_launched, base.2.copies_launched, "{tag}");
                    assert_eq!(got.2.copies_failed, base.2.copies_failed, "{tag}");
                    assert_eq!(got.2.slots, base.2.slots, "{tag}");
                    assert_eq!(got.2.events_processed, base.2.events_processed, "{tag}");
                    assert_eq!(got.2.telemetry, base.2.telemetry, "{tag}: counters moved");
                    // the sketch is fed identically in both metric modes
                    assert_eq!(got.2.stats, base.2.stats, "{tag}: FlowStats diverged");
                    assert_eq!(
                        got.2.avg_flowtime().to_bits(),
                        base.2.avg_flowtime().to_bits(),
                        "{tag}: mean bits moved"
                    );
                    if stream {
                        assert!(got.2.flowtimes.is_empty(), "{tag}: raw Vec kept");
                    } else {
                        assert_eq!(got.2.flowtimes.len(), base.2.flowtimes.len(), "{tag}");
                        for (a, b) in got.2.flowtimes.iter().zip(&base.2.flowtimes) {
                            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: flowtime bits moved");
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn batched_insurer_emits_identical_action_stream_to_scalar() {
    // The batched-hot-path acceptance criterion: across a fixed-seed sweep
    // grid, PingAn scoring through the batched CpuScorer must emit EXACTLY
    // the Action stream of the scalar per-candidate reference — the f64
    // kernel replays the Hist algebra bit for bit, so not a single
    // admission decision may differ.
    use pingan::config::spec::ScorerKind;
    for (lambda, eps, seed) in [
        (0.05, 0.6, 71u64),
        (0.05, 0.2, 72),
        (0.10, 0.8, 73),
        (0.15, 0.4, 74),
    ] {
        let (sys, jobs) = setup(6, 10, lambda, 3000 + seed);
        let mut runs = Vec::new();
        for kind in [ScorerKind::Scalar, ScorerKind::Cpu] {
            let mut spec = PingAnSpec::with_epsilon(eps);
            spec.scorer = kind;
            let mut rec = Recording {
                inner: PingAn::new(spec),
                log: Vec::new(),
                per_slot: Vec::new(),
            };
            let res = Simulation::new(&sys, jobs.clone(), SimConfig::default()).run(&mut rec);
            runs.push((rec.log, rec.per_slot, res));
        }
        let (scalar, batched) = (&runs[0], &runs[1]);
        assert_eq!(
            scalar.1, batched.1,
            "λ={lambda} ε={eps} seed={seed}: per-slot action counts diverged"
        );
        assert_eq!(
            scalar.0, batched.0,
            "λ={lambda} ε={eps} seed={seed}: action streams diverged"
        );
        // identical decisions force identical outcomes, to the bit
        assert_eq!(scalar.2.copies_launched, batched.2.copies_launched);
        assert_eq!(scalar.2.flowtimes, batched.2.flowtimes);
        assert_eq!(scalar.2.sum_flowtime(), batched.2.sum_flowtime());
    }
}
