//! Arrival processes shared by generators and λ-sweeps (Fig 4/5/7).

use crate::util::rng::Rng;

/// Draw `n` arrival time slots from a Poisson process of rate `lambda`
/// (exponential inter-arrivals), returned sorted.
pub fn poisson_arrivals(n: usize, lambda: f64, rng: &mut Rng) -> Vec<u64> {
    assert!(lambda > 0.0);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0;
    for _ in 0..n {
        t += rng.exponential(lambda);
        out.push(t as u64);
    }
    out
}

/// Rescale an existing workload's arrivals to a new rate — the λ sweep
/// reuses the same job DAGs and only changes arrival pressure, which
/// isolates the load effect like the paper's Poisson-parameter sweeps.
pub fn rescale_arrivals(arrivals: &[u64], from_lambda: f64, to_lambda: f64) -> Vec<u64> {
    let k = from_lambda / to_lambda;
    arrivals.iter().map(|&a| (a as f64 * k) as u64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_and_rate_correct() {
        let mut rng = Rng::new(21);
        let xs = poisson_arrivals(2000, 0.05, &mut rng);
        for w in xs.windows(2) {
            assert!(w[1] >= w[0]);
        }
        let rate = xs.len() as f64 / *xs.last().unwrap() as f64;
        assert!((rate - 0.05).abs() < 0.01, "rate={rate}");
    }

    #[test]
    fn rescale_changes_rate() {
        let mut rng = Rng::new(22);
        let xs = poisson_arrivals(1000, 0.05, &mut rng);
        let ys = rescale_arrivals(&xs, 0.05, 0.15);
        let rate = ys.len() as f64 / *ys.last().unwrap() as f64;
        assert!((rate - 0.15).abs() < 0.03, "rate={rate}");
    }
}
