//! Mini Spark-on-Yarn testbed (the Sec 5 implementation analogue).
//!
//! Reproduces the control plane of Fig 1 around the execution substrate:
//!
//! * [`components`] — ResourceManager (per-cluster container accounting),
//!   AppMaster + DAGScheduler (per-job TaskSet emission, OutputRecorder),
//!   and the TaskSetPool ordered by ascending unprocessed datasize.
//! * [`testbed`] — the driver: paces the engine in (optionally) real time,
//!   routes TaskSets through the pool to the pluggable insurer/scheduler,
//!   and **executes a real XLA payload per completed task** through the
//!   PJRT runtime (wordcount / pagerank / logreg per Table 1), validating
//!   numerics — the end-to-end proof that L1/L2/L3 compose.
//!
//! The paper's testbed is 10 VMs with Wondershaper-limited gates, benchmark
//! interference and scripted shutdowns; our substitution (DESIGN.md) keeps
//! the same mechanisms: Table-2-style heterogeneous clusters, gate
//! bandwidth enforcement, Bernoulli cluster kills.

pub mod components;
pub mod testbed;

pub use components::{AppMaster, ResourceManager, TaskSetPool};
pub use testbed::{Testbed, TestbedConfig, TestbedResult};
