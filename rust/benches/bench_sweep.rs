//! Benches the parallel sweep runner on the Fig-7 ε×λ grid: the same
//! spec at 1 and 2 worker threads (the speedup is the point of the
//! subsystem), plus a `BENCHJSON` line recording per-cell wall time from
//! a 2-thread run.
//!
//! Run: `cargo bench --bench bench_sweep`

use pingan::bench_harness::Bench;
use pingan::experiments::{figures, Scale};
use pingan::sweep;
use pingan::util::jsonout::Json;

fn main() {
    let mut b = Bench::new("sweep");
    let scale = Scale::smoke();
    let spec = figures::fig7_spec(&scale, &[0.05, 0.1], &[0.4, 0.8]);

    for threads in [1usize, 2, 4] {
        b.case(&format!("fig7_grid_{threads}_threads"), || {
            let report = sweep::run_with(&spec, threads, None);
            assert!(report.rows.iter().all(|r| r.errors == 0));
            report.rows.len() as f64
        });
    }

    // Per-cell wall times from one 2-thread run, machine-readable for
    // EXPERIMENTS.md tooling.
    let report = sweep::run_with(&spec, 2, None);
    let cells: Vec<Json> = report
        .cells
        .iter()
        .map(|c| {
            let mut j = Json::obj();
            j.set("label", Json::str(&c.scenario.label()))
                .set("wall_s", Json::num(c.wall_secs))
                .set("mean_flowtime", Json::num(c.mean_flowtime()));
            j
        })
        .collect();
    let mut j = Json::obj();
    j.set("suite", Json::str("sweep"))
        .set("case", Json::str("fig7_grid_cells"))
        .set("threads", Json::num(2.0))
        .set("cells", Json::Arr(cells));
    println!("BENCHJSON {}", j.to_string());
}
