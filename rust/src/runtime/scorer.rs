//! Batched copy-placement scoring with interchangeable backends.
//!
//! The insurer needs, for B (task, candidate-set) pairs at once,
//! `E[max(existing copies, candidate_k)]` where each candidate's rate
//! distribution is the bottleneck `min(proc, trans)` of two histograms.
//! Since the batched-hot-path refactor this module IS the insurer's
//! scoring engine: `PingAn::schedule` collects each round's (task,
//! candidate) pairs into one [`ScoreBatch`] and runs it through a
//! `Box<dyn Scorer>`.
//!
//! * [`CpuScorer`] — pure rust, f64 end to end. Its accumulation order
//!   mirrors `Hist::min_compose` + `Hist::from_pmf` + `Hist::expected_max`
//!   operation for operation, so its scores are *bit-identical* to the
//!   scalar `dist::Hist` algebra — batching must not flip an admission
//!   decision.
//! * [`HloScorer`] *(feature `pjrt`)* — the compiled `score` artifact
//!   (L1 Pallas + L2 JAX), executed through PJRT. Scores in f32: results
//!   agree with [`CpuScorer`] only to ~1e-3 relative tolerance, so
//!   knife-edge admission decisions may differ from the CPU backend.
//!   Batches are converted at the boundary and chunked/padded to the
//!   artifact's fixed [B, K, V] shape.
//!
//! The in-module tests and `tests/proptest_invariants.rs` assert the
//! backends agree, which transitively ties the rust hot path to the
//! pytest oracle (`python/compile/kernels/ref.py`).
//!
//! ## Intra-cell parallelism
//!
//! A batch's rows are independent — no kernel output reads another row —
//! so [`score_rows_sharded`] shards them into contiguous ranges
//! ([`shard_ranges`]), fills a thread-local scratch [`ScoreBatch`] per
//! shard ([`fill_rows`], reusing each scratch's allocation across slots
//! via `reset()`), scores the shards on a `std::thread::scope` pool, and
//! concatenates the outputs in shard (= row) order. Because every row's
//! f64 arithmetic is untouched by the partitioning, the merged vector is
//! **bit-identical at any thread count** — the determinism suite
//! (`tests/end_to_end.rs`, `tests/proptest_invariants.rs`) proves it.
//! Backends are therefore required to be `Send + Sync`; one shared
//! backend scores all shards concurrently.
//!
//! Telemetry (`crate::obs`) deliberately stays *outside* this module:
//! the insurer records batch fill/exec wall spans and row counts around
//! its calls into [`score_rows_sharded`], keeping the kernel itself free
//! of clocks and counters.

use anyhow::Result;

/// One batch of scoring work: B tasks × K candidates on a V-bin grid.
///
/// Shapes are dynamic — B is whatever the scheduling round produced — and
/// the buffers are reusable: [`ScoreBatch::reset`] resizes in place so the
/// insurer fills the same allocation every slot.
#[derive(Clone, Debug)]
pub struct ScoreBatch {
    pub b: usize,
    pub k: usize,
    pub v: usize,
    /// [B*K*V] processing-speed pmfs.
    pub proc_pmf: Vec<f64>,
    /// [B*K*V] transfer-bandwidth pmfs (source-averaged).
    pub trans_pmf: Vec<f64>,
    /// [B*V] product of existing copies' CDFs (ones when no copies).
    pub existing_cdf: Vec<f64>,
    /// [V] grid centers.
    pub values: Vec<f64>,
    /// [B] rows whose rate pmf is `proc_pmf` alone (a task with no remote
    /// sources has no transfer bottleneck; `PerfModel::rate_hist` returns
    /// the *unrenormalized* proc hist there, and exactness demands the
    /// kernel skip the min-composition and its normalization too).
    pub proc_only: Vec<bool>,
}

impl ScoreBatch {
    pub fn new(b: usize, k: usize, v: usize) -> ScoreBatch {
        let mut batch = ScoreBatch {
            b: 0,
            k: 0,
            v: 0,
            proc_pmf: Vec::new(),
            trans_pmf: Vec::new(),
            existing_cdf: Vec::new(),
            values: Vec::new(),
            proc_only: Vec::new(),
        };
        batch.reset(b, k, v);
        batch
    }

    /// Resize to a new [B, K, V] shape in place, keeping allocations.
    /// Rows reset to the neutral state (zero pmfs, all-ones CDF).
    pub fn reset(&mut self, b: usize, k: usize, v: usize) {
        self.b = b;
        self.k = k;
        self.v = v;
        self.proc_pmf.clear();
        self.proc_pmf.resize(b * k * v, 0.0);
        self.trans_pmf.clear();
        self.trans_pmf.resize(b * k * v, 0.0);
        self.existing_cdf.clear();
        self.existing_cdf.resize(b * v, 1.0);
        self.values.clear();
        self.values.resize(v, 0.0);
        self.proc_only.clear();
        self.proc_only.resize(b, false);
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.proc_pmf.len() == self.b * self.k * self.v, "proc shape");
        anyhow::ensure!(self.trans_pmf.len() == self.b * self.k * self.v, "trans shape");
        anyhow::ensure!(self.existing_cdf.len() == self.b * self.v, "cdf shape");
        anyhow::ensure!(self.values.len() == self.v, "values shape");
        anyhow::ensure!(self.proc_only.len() == self.b, "proc_only shape");
        Ok(())
    }
}

/// A scoring backend: returns [B*K] expected max rates (f64; the HLO
/// backend widens its f32 artifact output). `Send + Sync` because
/// [`score_rows_sharded`] scores shards concurrently through one shared
/// backend reference.
pub trait Scorer: Send + Sync {
    fn name(&self) -> &str;
    fn score(&self, batch: &ScoreBatch) -> Result<Vec<f64>>;
}

/// Pure-rust backend (also the fallback when artifacts are absent).
///
/// Bit-exactness contract: for every row this computes the same f64 the
/// scalar path would — `Hist::expected_max(&[existing...,
/// proc.min_compose(&trans)])` with `from_pmf` normalization in between —
/// by replaying the identical operations in the identical order (IEEE
/// f64 is deterministic; `a*b == b*a` covers the one reassociation).
pub struct CpuScorer;

impl Scorer for CpuScorer {
    fn name(&self) -> &str {
        "cpu"
    }

    fn score(&self, batch: &ScoreBatch) -> Result<Vec<f64>> {
        batch.validate()?;
        let (b, k, v) = (batch.b, batch.k, batch.v);
        let mut out = vec![0.0f64; b * k];
        let mut min_pmf = vec![0.0f64; v];
        for bi in 0..b {
            let exist = &batch.existing_cdf[bi * v..(bi + 1) * v];
            for ki in 0..k {
                let base = (bi * k + ki) * v;
                let p = &batch.proc_pmf[base..base + v];
                out[bi * k + ki] = if batch.proc_only[bi] {
                    // rate pmf is the (already normalized) proc pmf
                    expect_max_raw(p, exist, &batch.values)
                } else {
                    let t = &batch.trans_pmf[base..base + v];
                    // bottleneck pmf of min(P, T): one backward pass over
                    // the survival functions, same as Hist::min_compose
                    let mut sf_p = 0.0f64; // P(P > v_j)
                    let mut sf_t = 0.0f64;
                    for j in (0..v).rev() {
                        min_pmf[j] = p[j] * sf_t + t[j] * sf_p + p[j] * t[j];
                        sf_p += p[j];
                        sf_t += t[j];
                    }
                    expect_max_normalized(&min_pmf, exist, &batch.values)
                };
            }
        }
        Ok(out)
    }
}

/// `E[max(X, existing)]` for an already-normalized pmf of X: CDF product
/// against the precomputed existing-CDF row, then the expectation of the
/// implied pmf. Mirrors `Hist::expected_max`'s accumulation (including
/// the per-hist `min(1.0)` clamp) bit for bit.
// indexed loops deliberately mirror the dist::Hist reference line by line
#[allow(clippy::needless_range_loop)]
fn expect_max_raw(pmf: &[f64], exist: &[f64], values: &[f64]) -> f64 {
    let mut acc = 0.0f64;
    let mut prev = 0.0f64;
    let mut e = 0.0f64;
    for j in 0..pmf.len() {
        acc += pmf[j];
        let combined = acc.min(1.0) * exist[j];
        e += values[j] * (combined - prev);
        prev = combined;
    }
    e
}

/// Same, but the pmf is a raw (unnormalized) min-composition: fold in the
/// `1/total` factor exactly where `Hist::from_pmf` would, and degenerate
/// to its point-mass-at-bin-0 fallback (CDF ≡ 1) when the mass vanishes.
#[allow(clippy::needless_range_loop)]
fn expect_max_normalized(raw: &[f64], exist: &[f64], values: &[f64]) -> f64 {
    let total: f64 = raw.iter().sum();
    let mut prev = 0.0f64;
    let mut e = 0.0f64;
    if total > 1e-300 {
        let inv = 1.0 / total;
        let mut acc = 0.0f64;
        for j in 0..raw.len() {
            acc += raw[j] * inv;
            let combined = acc.min(1.0) * exist[j];
            e += values[j] * (combined - prev);
            prev = combined;
        }
    } else {
        for j in 0..raw.len() {
            let combined = exist[j];
            e += values[j] * (combined - prev);
            prev = combined;
        }
    }
    e
}

/// Fill one task row of a [`ScoreBatch`] from the insurer's cached flat
/// tensors — the bridge between the histogram world and the batch. `proc`
/// and `trans` are the task's [K*V] per-cluster slabs; `existing_cdf` is
/// its [V] frozen copy-set CDF product.
pub fn fill_row(
    batch: &mut ScoreBatch,
    bi: usize,
    proc: &[f64],
    trans: &[f64],
    proc_only: bool,
    existing_cdf: &[f64],
) {
    let (k, v) = (batch.k, batch.v);
    assert_eq!(proc.len(), k * v, "proc slab shape");
    assert_eq!(trans.len(), k * v, "trans slab shape");
    assert_eq!(existing_cdf.len(), v, "existing cdf shape");
    batch.proc_pmf[bi * k * v..(bi + 1) * k * v].copy_from_slice(proc);
    batch.trans_pmf[bi * k * v..(bi + 1) * k * v].copy_from_slice(trans);
    batch.existing_cdf[bi * v..(bi + 1) * v].copy_from_slice(existing_cdf);
    batch.proc_only[bi] = proc_only;
}

/// Borrowed inputs for one task row of a [`ScoreBatch`] — the insurer's
/// cached flat tensors by reference, so shards can be filled without
/// materializing one monolithic batch first.
#[derive(Clone, Copy, Debug)]
pub struct RowInput<'a> {
    /// The task's [K*V] per-cluster processing-pmf slab.
    pub proc: &'a [f64],
    /// The task's [K*V] per-cluster transfer-pmf slab.
    pub trans: &'a [f64],
    /// See [`ScoreBatch::proc_only`].
    pub proc_only: bool,
    /// The task's [V] frozen copy-set CDF product.
    pub existing_cdf: &'a [f64],
}

/// Reset `batch` to `[rows.len(), k, v]` and fill every row from `rows`
/// (allocation-reusing: the same scratch batch serves slot after slot).
pub fn fill_rows(
    batch: &mut ScoreBatch,
    k: usize,
    v: usize,
    values: &[f64],
    rows: &[RowInput<'_>],
) {
    assert_eq!(values.len(), v, "values shape");
    batch.reset(rows.len(), k, v);
    batch.values.copy_from_slice(values);
    for (bi, r) in rows.iter().enumerate() {
        fill_row(batch, bi, r.proc, r.trans, r.proc_only, r.existing_cdf);
    }
}

/// Row-range partitioning, shared with the engine sharder (the contiguous
/// in-order split is half of the bit-identity argument; see `util::shard`).
pub use crate::util::shard::shard_ranges;

/// Smallest shard worth an OS thread: spawning and joining a scoped
/// thread costs tens of microseconds, comparable to scoring a handful of
/// rows, so rounds smaller than `2 * MIN_ROWS_PER_SHARD` run serially and
/// larger ones cap their shard count at `rows / MIN_ROWS_PER_SHARD`.
/// Purely a wall-time heuristic — outputs are identical either way.
pub const MIN_ROWS_PER_SHARD: usize = 8;

/// Score `rows` through `backend`, sharded across up to `threads` OS
/// threads. `scratch` is the caller-owned pool of per-shard batches
/// (grown on demand, reused across calls). The output is merged in row
/// order, so it is **bit-identical to the serial single-batch path at
/// any thread count**: rows are scored independently by every backend
/// (the CPU kernel touches one row at a time; the HLO artifact's padded
/// chunks never mix rows), and IEEE f64 arithmetic per row is unchanged
/// by the partitioning. Errors surface in shard order, first one wins —
/// deterministic too. `threads <= 1`, or a round too small to amortize a
/// spawn (see [`MIN_ROWS_PER_SHARD`]), runs serially on `scratch[0]`
/// with no thread spawned.
pub fn score_rows_sharded(
    backend: &dyn Scorer,
    k: usize,
    v: usize,
    values: &[f64],
    rows: &[RowInput<'_>],
    threads: usize,
    scratch: &mut Vec<ScoreBatch>,
) -> Result<Vec<f64>> {
    let t = threads.max(1).min(rows.len() / MIN_ROWS_PER_SHARD).max(1);
    if scratch.len() < t {
        scratch.resize_with(t, || ScoreBatch::new(0, 0, 0));
    }
    if t == 1 {
        let batch = &mut scratch[0];
        fill_rows(batch, k, v, values, rows);
        return backend.score(batch);
    }
    let ranges = shard_ranges(rows.len(), t);
    let shard_outs: Vec<Result<Vec<f64>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = ranges
            .iter()
            .zip(scratch.iter_mut())
            .map(|(range, batch)| {
                let shard = &rows[range.clone()];
                scope.spawn(move || {
                    fill_rows(batch, k, v, values, shard);
                    backend.score(batch)
                })
            })
            .collect();
        // join in spawn order: outputs (and errors) keep shard order
        handles
            .into_iter()
            .map(|h| h.join().expect("scoring shard panicked"))
            .collect()
    });
    let mut out = Vec::with_capacity(rows.len() * k);
    for res in shard_outs {
        out.extend(res?);
    }
    Ok(out)
}

/// PJRT backend running the compiled `score` artifact. The artifact shape
/// is fixed at lowering time; dynamic batches are split into row chunks
/// and each chunk zero-padded up to [B_art, K_art, V].
#[cfg(feature = "pjrt")]
pub struct HloScorer {
    exe: xla::PjRtLoadedExecutable,
    b: usize,
    k: usize,
    v: usize,
}

#[cfg(feature = "pjrt")]
impl HloScorer {
    /// Compile the `score` artifact from an [`super::Engine`].
    pub fn new(engine: &super::Engine) -> Result<HloScorer> {
        let a = &engine.artifacts;
        Ok(HloScorer {
            exe: engine.compile("score")?,
            b: a.score_b,
            k: a.score_k,
            v: a.score_v,
        })
    }

    pub fn shape(&self) -> (usize, usize, usize) {
        (self.b, self.k, self.v)
    }

    /// Score one chunk of up to `self.b` rows, f32-padded to the artifact
    /// shape into the caller's reusable buffers (`proc`/`trans`/`cdf` are
    /// sized to the artifact; rows past `rows` keep their previous — and
    /// ignored — contents). `rows` indexes into `batch`'s row range.
    #[allow(clippy::too_many_arguments)]
    fn score_chunk(
        &self,
        batch: &ScoreBatch,
        start: usize,
        rows: usize,
        proc: &mut [f32],
        trans: &mut [f32],
        cdf: &mut [f32],
        values: &[f32],
    ) -> Result<Vec<f32>> {
        let v = self.v;
        for bi in 0..rows {
            for ki in 0..batch.k {
                let src = ((start + bi) * batch.k + ki) * v;
                let dst = (bi * self.k + ki) * v;
                for j in 0..v {
                    proc[dst + j] = batch.proc_pmf[src + j] as f32;
                }
                if batch.proc_only[start + bi] {
                    // no transfer bottleneck: min-compose against a point
                    // mass at the top bin (the identity, up to f32). Zero
                    // the row first — the buffer is reused across chunks.
                    trans[dst..dst + v].fill(0.0);
                    trans[dst + v - 1] = 1.0;
                } else {
                    for j in 0..v {
                        trans[dst + j] = batch.trans_pmf[src + j] as f32;
                    }
                }
            }
            let src = (start + bi) * v;
            let dst = bi * v;
            for j in 0..v {
                cdf[dst + j] = batch.existing_cdf[src + j] as f32;
            }
        }
        let (b, k, v) = (self.b as i64, self.k as i64, self.v as i64);
        let outs = super::pjrt::exec_f32(
            &self.exe,
            &[
                super::pjrt::literal_f32(proc, &[b, k, v])?,
                super::pjrt::literal_f32(trans, &[b, k, v])?,
                super::pjrt::literal_f32(cdf, &[b, v])?,
                super::pjrt::literal_f32(values, &[v])?,
            ],
        )?;
        Ok(outs[0].clone())
    }
}

#[cfg(feature = "pjrt")]
impl Scorer for HloScorer {
    fn name(&self) -> &str {
        "hlo"
    }

    fn score(&self, batch: &ScoreBatch) -> Result<Vec<f64>> {
        batch.validate()?;
        anyhow::ensure!(
            batch.v == self.v,
            "grid bins {} != artifact V {}",
            batch.v,
            self.v
        );
        anyhow::ensure!(
            batch.k <= self.k,
            "candidate count {} exceeds artifact K {}",
            batch.k,
            self.k
        );
        anyhow::ensure!(self.b > 0 && self.k > 0, "degenerate artifact shape");
        let mut out = vec![0.0f64; batch.b * batch.k];
        // chunk-invariant buffers: padded artifact tensors + f32 values
        let mut proc = vec![0.0f32; self.b * self.k * self.v];
        let mut trans = vec![0.0f32; self.b * self.k * self.v];
        let mut cdf = vec![1.0f32; self.b * self.v];
        let values: Vec<f32> = batch.values.iter().map(|&x| x as f32).collect();
        let mut start = 0usize;
        while start < batch.b {
            let rows = (batch.b - start).min(self.b);
            let full =
                self.score_chunk(batch, start, rows, &mut proc, &mut trans, &mut cdf, &values)?;
            for bi in 0..rows {
                for ki in 0..batch.k {
                    out[(start + bi) * batch.k + ki] = full[bi * self.k + ki] as f64;
                }
            }
            start += rows;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Grid, Hist};
    use crate::util::rng::Rng;

    fn rand_pmf(rng: &mut Rng, v: usize) -> Vec<f64> {
        let mut x: Vec<f64> = (0..v).map(|_| rng.f64() + 1e-3).collect();
        let s: f64 = x.iter().sum();
        x.iter_mut().for_each(|e| *e /= s);
        x
    }

    fn rand_batch(seed: u64, b: usize, k: usize, v: usize) -> ScoreBatch {
        let mut rng = Rng::new(seed);
        let mut batch = ScoreBatch::new(b, k, v);
        batch.values = (0..v).map(|i| i as f64 * 0.5).collect();
        for bi in 0..b {
            let pmf = rand_pmf(&mut rng, v);
            let mut cdf = Vec::with_capacity(v);
            let mut acc = 0.0f64;
            for &p in &pmf {
                acc += p;
                cdf.push(acc.min(1.0));
            }
            let mut proc = Vec::with_capacity(k * v);
            let mut trans = Vec::with_capacity(k * v);
            for _ in 0..k {
                proc.extend(rand_pmf(&mut rng, v));
                trans.extend(rand_pmf(&mut rng, v));
            }
            fill_row(&mut batch, bi, &proc, &trans, false, &cdf);
        }
        batch
    }

    fn pmf_to_hist(grid: &Grid, pmf: &[f64]) -> Hist {
        Hist::from_pmf(grid, pmf)
    }

    #[test]
    fn cpu_scorer_matches_hist_algebra_exactly() {
        // the bit-exactness contract: scoring a row through the kernel
        // equals composing the same pmfs through dist::Hist, bit for bit
        let v = 64;
        let batch = rand_batch(7, 2, 3, v);
        let cpu = CpuScorer.score(&batch).unwrap();
        let grid = Grid::uniform(0.0, (v - 1) as f64 * 0.5, v);
        for bi in 0..2 {
            for ki in 0..3 {
                let base = (bi * 3 + ki) * v;
                let hp = pmf_to_hist(&grid, &batch.proc_pmf[base..base + v]);
                let ht = pmf_to_hist(&grid, &batch.trans_pmf[base..base + v]);
                let hmin = hp.min_compose(&ht);
                // existing cdf -> hist (the test batch's cdf rows are exact
                // prefix sums of a normalized pmf, so this inverts cleanly)
                let ex = &batch.existing_cdf[bi * v..(bi + 1) * v];
                let mut ex_pmf = vec![0.0; v];
                let mut prev = 0.0;
                for j in 0..v {
                    ex_pmf[j] = (ex[j] - prev).max(0.0);
                    prev = ex[j];
                }
                let hex = pmf_to_hist(&grid, &ex_pmf);
                let want = Hist::expected_max(&[&hex, &hmin]);
                let got = cpu[bi * 3 + ki];
                assert!(
                    (got - want).abs() < 1e-9 * want.max(1.0),
                    "({bi},{ki}): got {got} want {want}"
                );
            }
        }
    }

    #[test]
    fn proc_only_rows_skip_the_bottleneck() {
        let v = 32;
        let grid = Grid::uniform(0.0, 10.0, v);
        let hp = Hist::normal(&grid, 6.0, 1.5);
        let mut batch = ScoreBatch::new(1, 1, v);
        batch.values.copy_from_slice(grid.values());
        let proc = hp.pmf().to_vec();
        let trans = vec![0.0f64; v]; // ignored for proc-only rows
        let ones = vec![1.0f64; v];
        fill_row(&mut batch, 0, &proc, &trans, true, &ones);
        let got = CpuScorer.score(&batch).unwrap()[0];
        let want = Hist::expected_max(&[&hp]);
        assert_eq!(got.to_bits(), want.to_bits(), "got {got} want {want}");
    }

    #[test]
    fn reset_reuses_buffers_across_shapes() {
        let mut batch = ScoreBatch::new(4, 3, 16);
        batch.proc_pmf[0] = 0.5;
        batch.existing_cdf[0] = 0.25;
        batch.proc_only[0] = true;
        batch.reset(2, 5, 16);
        assert_eq!((batch.b, batch.k, batch.v), (2, 5, 16));
        batch.validate().unwrap();
        assert_eq!(batch.proc_pmf[0], 0.0, "stale pmf survived reset");
        assert_eq!(batch.existing_cdf[0], 1.0, "cdf not neutral");
        assert!(!batch.proc_only[0], "stale flag survived reset");
        // growing again after shrink keeps shapes consistent
        batch.reset(6, 2, 8);
        batch.validate().unwrap();
        assert_eq!(batch.proc_pmf.len(), 6 * 2 * 8);
    }

    #[test]
    fn empty_batch_scores_to_empty() {
        let batch = ScoreBatch::new(0, 4, 16);
        assert!(CpuScorer.score(&batch).unwrap().is_empty());
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn hlo_and_cpu_agree() {
        if !std::path::Path::new("artifacts/manifest.toml").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let engine = crate::runtime::Engine::new("artifacts").unwrap();
        let hlo = HloScorer::new(&engine).unwrap();
        let (b, k, v) = hlo.shape();
        let batch = rand_batch(11, b, k, v);
        let got_hlo = hlo.score(&batch).unwrap();
        let got_cpu = CpuScorer.score(&batch).unwrap();
        assert_eq!(got_hlo.len(), got_cpu.len());
        for (i, (a, c)) in got_hlo.iter().zip(&got_cpu).enumerate() {
            assert!(
                (a - c).abs() < 1e-3 * c.abs().max(1.0),
                "idx {i}: hlo {a} vs cpu {c}"
            );
        }
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn hlo_chunks_and_pads_dynamic_batches() {
        if !std::path::Path::new("artifacts/manifest.toml").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let engine = crate::runtime::Engine::new("artifacts").unwrap();
        let hlo = HloScorer::new(&engine).unwrap();
        let (b, _, v) = hlo.shape();
        // smaller than the artifact batch AND larger (forces chunking)
        for rows in [3usize, b + 2] {
            let batch = rand_batch(13 + rows as u64, rows, 2, v);
            let got_hlo = hlo.score(&batch).unwrap();
            let got_cpu = CpuScorer.score(&batch).unwrap();
            for (a, c) in got_hlo.iter().zip(&got_cpu) {
                assert!((a - c).abs() < 1e-3 * c.abs().max(1.0));
            }
        }
    }

    #[test]
    fn fill_rows_matches_per_row_fill() {
        let (b, k, v) = (5usize, 3usize, 16usize);
        let reference = rand_batch(23, b, k, v);
        let rows: Vec<RowInput<'_>> = (0..b)
            .map(|bi| RowInput {
                proc: &reference.proc_pmf[bi * k * v..(bi + 1) * k * v],
                trans: &reference.trans_pmf[bi * k * v..(bi + 1) * k * v],
                proc_only: reference.proc_only[bi],
                existing_cdf: &reference.existing_cdf[bi * v..(bi + 1) * v],
            })
            .collect();
        let mut rebuilt = ScoreBatch::new(0, 0, 0);
        fill_rows(&mut rebuilt, k, v, &reference.values, &rows);
        assert_eq!(rebuilt.proc_pmf, reference.proc_pmf);
        assert_eq!(rebuilt.trans_pmf, reference.trans_pmf);
        assert_eq!(rebuilt.existing_cdf, reference.existing_cdf);
        assert_eq!(rebuilt.values, reference.values);
        assert_eq!(rebuilt.proc_only, reference.proc_only);
    }

    #[test]
    fn sharded_scoring_is_bit_identical_to_serial() {
        // b large enough that the MIN_ROWS_PER_SHARD heuristic actually
        // shards (37 / 8 = up to 4 shards)
        let (b, k, v) = (37usize, 3usize, 32usize);
        let batch = rand_batch(29, b, k, v);
        let serial = CpuScorer.score(&batch).unwrap();
        let rows: Vec<RowInput<'_>> = (0..b)
            .map(|bi| RowInput {
                proc: &batch.proc_pmf[bi * k * v..(bi + 1) * k * v],
                trans: &batch.trans_pmf[bi * k * v..(bi + 1) * k * v],
                proc_only: batch.proc_only[bi],
                existing_cdf: &batch.existing_cdf[bi * v..(bi + 1) * v],
            })
            .collect();
        let mut scratch: Vec<ScoreBatch> = Vec::new();
        // 1 = the serial scratch path; b+5 caps at rows/MIN_ROWS_PER_SHARD
        for threads in [1usize, 2, 3, 4, b + 5] {
            let got =
                score_rows_sharded(&CpuScorer, k, v, &batch.values, &rows, threads, &mut scratch)
                    .unwrap();
            assert_eq!(got.len(), serial.len(), "threads={threads}");
            for (i, (g, s)) in got.iter().zip(&serial).enumerate() {
                assert_eq!(g.to_bits(), s.to_bits(), "threads={threads} idx {i}: {g} vs {s}");
            }
        }
        // the scratch pool is reused, never shrunk below the largest need
        assert!(scratch.len() >= 4);
    }

    #[test]
    fn sharded_scoring_of_no_rows_is_empty() {
        let mut scratch: Vec<ScoreBatch> = Vec::new();
        let values = vec![0.0f64; 8];
        let out = score_rows_sharded(&CpuScorer, 2, 8, &values, &[], 4, &mut scratch).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn validate_rejects_bad_shapes() {
        let mut b = ScoreBatch::new(2, 2, 8);
        b.values.pop();
        assert!(b.validate().is_err());
        let mut b = ScoreBatch::new(2, 2, 8);
        b.proc_only.pop();
        assert!(b.validate().is_err());
    }
}
