//! Pull-based workload intake.
//!
//! [`WorkloadSource`] is the intake half of the million-job replay
//! redesign: instead of materializing every [`JobSpec`] up front in a
//! `Vec` (O(jobs × tasks) memory before the first slot simulates), the
//! engine pulls jobs one at a time **in nondecreasing arrival order** and
//! admits each lazily when simulated time reaches its arrival slot.
//! Combined with slab recycling (`SimConfig::stream_metrics`), resident
//! state is O(clusters + alive jobs) regardless of trace length.
//!
//! Implementors:
//!
//! * [`EagerSource`] — wraps an existing `Vec<JobSpec>`; the adapter every
//!   pre-redesign call site routes through, bit-identical to the old
//!   eager path for the repo's generators (whose output is already in
//!   arrival order).
//! * [`GenSource`] — generates the Montage workload *incrementally*,
//!   replicating [`montage::generate`]'s RNG draw sequence job by job, so
//!   a 10⁶-job synthetic replay never holds more than one spec at a time.
//! * [`crate::workload::trace::TraceSource`] — parses an
//!   Azure-Functions-style CSV/JSONL arrival trace from disk.
//! * [`ChannelSource`] — a *live* source fed over an mpsc channel by the
//!   `pingan serve` socket intake; the one implementor that can answer
//!   "no job yet" ([`SourcePoll::Pending`]) instead of "drained".
//!
//! ## Ordering contract
//!
//! `next_job` must yield arrivals nondecreasing in `JobSpec::arrival`;
//! the engine assigns slab indices in pull order, debug-asserts
//! monotonicity, and panics (with the offending ids) in release builds
//! only inside `TraceSource`, where the data is externally supplied.
//! `ChannelSource` *clamps* instead of panicking — live senders race the
//! virtual clock, so an out-of-order stamp is expected, not a bug.

use std::sync::mpsc;

use super::job::JobSpec;
use super::montage;
use crate::config::spec::WorkloadSpec;
use crate::util::rng::Rng;

/// One non-blocking intake poll (see [`WorkloadSource::poll_job`]).
pub enum SourcePoll {
    /// A job is available now.
    Job(JobSpec),
    /// No job *yet* — only live sources ([`ChannelSource`]) return this;
    /// batch sources go straight from `Job` to `Done`.
    Pending,
    /// The source is exhausted for good.
    Done,
}

/// A pull-based stream of jobs in nondecreasing arrival order.
pub trait WorkloadSource {
    /// The next job, or `None` when the workload is exhausted. May block
    /// on live sources (waits for the next submission or disconnect).
    fn next_job(&mut self) -> Option<JobSpec>;

    /// Total job count when known up front (progress reporting and
    /// `SimResult::total_jobs` accounting for truncated runs); `None`
    /// for open-ended sources such as unsized traces.
    fn hint_total(&self) -> Option<usize>;

    /// Intake poll for live sources. With `block = false` the call must
    /// return immediately ([`SourcePoll::Pending`] when nothing is
    /// available yet); with `block = true` the caller has nothing else to
    /// do and the source may sleep until a job materializes or the intake
    /// closes. The default delegates to [`WorkloadSource::next_job`] and
    /// never returns `Pending`, so every batch source keeps its exact
    /// historical engine interaction.
    fn poll_job(&mut self, block: bool) -> SourcePoll {
        let _ = block;
        match self.next_job() {
            Some(j) => SourcePoll::Job(j),
            None => SourcePoll::Done,
        }
    }
}

/// Adapter over an already-materialized workload `Vec`.
///
/// Jobs are yielded stable-sorted by arrival — for the repo's generators
/// (montage, testbed), whose output is already nondecreasing, this is the
/// identity permutation, so slab indices and hence Action streams match
/// the pre-redesign eager path bit for bit.
pub struct EagerSource {
    jobs: std::vec::IntoIter<JobSpec>,
    total: usize,
}

impl EagerSource {
    pub fn new(mut specs: Vec<JobSpec>) -> EagerSource {
        // stable: equal arrivals keep their original relative order,
        // matching the legacy engine's stable `sort_by_key` on arrival
        specs.sort_by_key(|j| j.arrival);
        let total = specs.len();
        EagerSource {
            jobs: specs.into_iter(),
            total,
        }
    }
}

impl WorkloadSource for EagerSource {
    fn next_job(&mut self) -> Option<JobSpec> {
        self.jobs.next()
    }

    fn hint_total(&self) -> Option<usize> {
        Some(self.total)
    }
}

/// Incremental Montage generator: the streaming twin of
/// [`montage::generate`].
///
/// Holds the same single [`Rng`] the batch generator uses and interleaves
/// the arrival-gap and DAG-body draws identically, so for any
/// `(spec, sites, seed)` the k-th job it yields is bit-identical to
/// `generate(...)[k]` — pinned by a test below — while never holding more
/// than the job being built.
pub struct GenSource {
    spec: WorkloadSpec,
    sites: Vec<usize>,
    rng: Rng,
    next_id: usize,
    t: f64,
}

impl GenSource {
    /// `seed` is the workload seed the batch path would have built its
    /// `Rng` from (the caller applies any env-seed mixing first).
    pub fn new(spec: WorkloadSpec, sites: Vec<usize>, seed: u64) -> GenSource {
        assert!(!sites.is_empty(), "need input sites");
        GenSource {
            spec,
            sites,
            rng: Rng::new(seed),
            next_id: 0,
            t: 0.0,
        }
    }
}

impl WorkloadSource for GenSource {
    fn next_job(&mut self) -> Option<JobSpec> {
        if self.next_id >= self.spec.n_jobs {
            return None;
        }
        let id = self.next_id;
        self.next_id += 1;
        // exact draw order of montage::generate's loop body
        self.t += self.rng.exponential(self.spec.lambda);
        let n_tasks = montage::draw_size(&self.spec, &mut self.rng);
        let job = montage::montage_dag(
            id,
            self.t as u64,
            n_tasks,
            &self.spec,
            &self.sites,
            &mut self.rng,
        );
        debug_assert!(job.validate().is_ok());
        Some(job)
    }

    fn hint_total(&self) -> Option<usize> {
        Some(self.spec.n_jobs)
    }
}

/// Create a connected live intake pair: the [`JobSender`] goes to the
/// submission side (the `pingan serve` session threads), the
/// [`ChannelSource`] feeds `Simulation::from_source`. Dropping every
/// sender closes the intake — the engine sees `Done`, drains the jobs
/// still in flight, and finishes: that *is* the graceful-shutdown path.
pub fn channel() -> (JobSender, ChannelSource) {
    let (tx, rx) = mpsc::channel();
    (JobSender { tx }, ChannelSource { rx, last: 0 })
}

/// Submission handle for a [`ChannelSource`]. Cheap to clone; any clone
/// keeps the intake open.
#[derive(Clone)]
pub struct JobSender {
    tx: mpsc::Sender<JobSpec>,
}

impl JobSender {
    /// Queue one job for admission. `Err` means the engine side has shut
    /// down (the receiver is gone).
    pub fn send(&self, job: JobSpec) -> Result<(), &'static str> {
        self.tx.send(job).map_err(|_| "engine intake closed")
    }
}

/// Live workload intake: jobs arrive over an mpsc channel from another
/// thread. The only source whose `poll_job` can answer
/// [`SourcePoll::Pending`] — the engine keeps working its queued events
/// (and blocks, CPU-free, only when it has nothing else to do).
///
/// Arrival stamps are clamped monotone on receipt rather than
/// panic-checked: a live submitter races the virtual clock, so a stamp
/// behind the last admitted arrival means "now", not "corrupt input".
/// Use the event-skip time core with this source — the dense core treats
/// an idle live source as drained.
pub struct ChannelSource {
    rx: mpsc::Receiver<JobSpec>,
    /// Largest arrival stamp yielded so far (the monotone clamp floor).
    last: u64,
}

impl ChannelSource {
    fn clamp(&mut self, mut job: JobSpec) -> JobSpec {
        job.arrival = job.arrival.max(self.last);
        self.last = job.arrival;
        job
    }
}

impl WorkloadSource for ChannelSource {
    /// Blocking pull: waits for the next submission; `None` once every
    /// [`JobSender`] clone is dropped.
    fn next_job(&mut self) -> Option<JobSpec> {
        self.rx.recv().ok().map(|j| self.clamp(j))
    }

    /// Live intake is open-ended.
    fn hint_total(&self) -> Option<usize> {
        None
    }

    fn poll_job(&mut self, block: bool) -> SourcePoll {
        if block {
            return match self.next_job() {
                Some(j) => SourcePoll::Job(j),
                None => SourcePoll::Done,
            };
        }
        match self.rx.try_recv() {
            Ok(j) => SourcePoll::Job(self.clamp(j)),
            Err(mpsc::TryRecvError::Empty) => SourcePoll::Pending,
            Err(mpsc::TryRecvError::Disconnected) => SourcePoll::Done,
        }
    }
}

/// Drain a source into a `Vec` (tests and the few call sites that truly
/// need the whole workload, e.g. workload-summary analysis).
pub fn collect(source: &mut dyn WorkloadSource) -> Vec<JobSpec> {
    let mut out = Vec::with_capacity(source.hint_total().unwrap_or(0));
    while let Some(j) = source.next_job() {
        out.push(j);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn same_job(a: &JobSpec, b: &JobSpec) -> bool {
        a.id == b.id
            && a.name == b.name
            && a.arrival == b.arrival
            && a.n_tasks() == b.n_tasks()
            && a.total_datasize().to_bits() == b.total_datasize().to_bits()
            && a.tasks.iter().zip(&b.tasks).all(|(x, y)| {
                x.idx == y.idx
                    && x.op == y.op
                    && x.datasize.to_bits() == y.datasize.to_bits()
                    && x.deps == y.deps
                    && x.input_locations == y.input_locations
            })
    }

    #[test]
    fn eager_source_sorts_stably_and_hints_total() {
        let mk = |id: usize, arrival: u64| JobSpec {
            id,
            name: format!("j{id}"),
            arrival,
            tasks: vec![crate::workload::TaskSpec {
                idx: 0,
                op: crate::workload::OpKind::Map,
                datasize: 1.0,
                deps: vec![],
                input_locations: vec![0],
            }],
        };
        let mut src = EagerSource::new(vec![mk(0, 5), mk(1, 2), mk(2, 5), mk(3, 1)]);
        assert_eq!(src.hint_total(), Some(4));
        let order: Vec<(usize, u64)> = std::iter::from_fn(|| src.next_job())
            .map(|j| (j.id, j.arrival))
            .collect();
        // sorted by arrival; ids 0 and 2 (equal arrivals) keep input order
        assert_eq!(order, vec![(3, 1), (1, 2), (0, 5), (2, 5)]);
        assert_eq!(src.next_job().map(|j| j.id), None);
    }

    #[test]
    fn gen_source_is_bit_identical_to_batch_generate() {
        let spec = WorkloadSpec::scaled(60, 0.07);
        let sites = vec![0usize, 1, 2, 3];
        let batch = montage::generate(&spec, &sites, &mut Rng::new(909));
        let mut src = GenSource::new(spec, sites, 909);
        assert_eq!(src.hint_total(), Some(60));
        let streamed = collect(&mut src);
        assert_eq!(streamed.len(), batch.len());
        for (a, b) in streamed.iter().zip(&batch) {
            assert!(same_job(a, b), "job {} diverged", a.id);
        }
    }

    #[test]
    fn channel_source_polls_pending_then_drains_on_disconnect() {
        let mk = |id: usize, arrival: u64| JobSpec {
            id,
            name: format!("j{id}"),
            arrival,
            tasks: vec![crate::workload::TaskSpec {
                idx: 0,
                op: crate::workload::OpKind::Map,
                datasize: 1.0,
                deps: vec![],
                input_locations: vec![0],
            }],
        };
        let (tx, mut src) = channel();
        assert_eq!(src.hint_total(), None);
        assert!(matches!(src.poll_job(false), SourcePoll::Pending));
        tx.send(mk(0, 5)).unwrap();
        // a stamp behind the frontier is clamped monotone, not rejected
        tx.send(mk(1, 2)).unwrap();
        let tx2 = tx.clone();
        drop(tx);
        match src.poll_job(false) {
            SourcePoll::Job(j) => assert_eq!((j.id, j.arrival), (0, 5)),
            _ => panic!("expected a job"),
        }
        match src.poll_job(true) {
            SourcePoll::Job(j) => assert_eq!((j.id, j.arrival), (1, 5)),
            _ => panic!("expected the clamped job"),
        }
        // a surviving clone keeps the intake open...
        assert!(matches!(src.poll_job(false), SourcePoll::Pending));
        drop(tx2);
        // ...and dropping the last sender closes it for good
        assert!(matches!(src.poll_job(false), SourcePoll::Done));
        assert!(src.next_job().is_none());
    }

    #[test]
    fn batch_sources_never_poll_pending() {
        let mut src = GenSource::new(WorkloadSpec::scaled(2, 0.1), vec![0], 11);
        assert!(matches!(src.poll_job(false), SourcePoll::Job(_)));
        assert!(matches!(src.poll_job(false), SourcePoll::Job(_)));
        assert!(matches!(src.poll_job(false), SourcePoll::Done));
    }

    #[test]
    fn gen_source_arrivals_are_nondecreasing() {
        let mut src = GenSource::new(WorkloadSpec::scaled(200, 0.1), vec![0, 1], 7);
        let mut prev = 0u64;
        while let Some(j) = src.next_job() {
            assert!(j.arrival >= prev);
            prev = j.arrival;
        }
    }
}
