//! Configuration layer: a TOML-subset parser plus typed experiment specs.
//!
//! Defaults reproduce the paper's Table 2 (cluster parameter ranges) and
//! Sec 6.1 (workload constitution); every knob can be overridden from a
//! config file (`--config path.toml`) or CLI options.

pub mod spec;
pub mod toml;

pub use spec::{Allocation, PingAnSpec, Principle, ScaleClass, SystemSpec, WorkloadSpec};
pub use toml::{Doc, Value};
