//! Minimal argv parser (clap is unavailable offline).
//!
//! Grammar: `pingan <command> [positional...] [--flag] [--key value]`.
//! `--key=value` is also accepted. Unknown flags are an error so typos in
//! experiment sweeps fail loudly instead of silently running the default.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (argv[1..]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    return Err("bare `--` is not supported".into());
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<f64>()
                .map_err(|_| format!("--{name}: expected a number, got `{s}`")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<usize>()
                .map_err(|_| format!("--{name}: expected an integer, got `{s}`")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<u64>()
                .map_err(|_| format!("--{name}: expected an integer, got `{s}`")),
        }
    }

    /// Comma-separated list of f64 (for sweep specs like `--lambdas 0.02,0.07`).
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>, String> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<f64>()
                        .map_err(|_| format!("--{name}: bad element `{p}`"))
                })
                .collect(),
        }
    }

    /// Reject options/flags outside the allowed set (typo protection).
    pub fn expect_known(&self, known: &[&str]) -> Result<(), String> {
        for k in self.options.keys().map(|s| s.as_str()).chain(self.flags.iter().map(|s| s.as_str())) {
            if !known.contains(&k) {
                return Err(format!(
                    "unknown option --{k}; known: {}",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn command_and_positionals() {
        let a = parse(&["figure", "fig4", "extra"]);
        assert_eq!(a.command.as_deref(), Some("figure"));
        assert_eq!(a.positional, vec!["fig4", "extra"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["simulate", "--epsilon", "0.6", "--lambda=0.07"]);
        assert_eq!(a.get("epsilon"), Some("0.6"));
        assert_eq!(a.get("lambda"), Some("0.07"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["simulate", "--verbose"]);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b"]);
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    fn numeric_parsing_and_defaults() {
        let a = parse(&["x", "--eps", "0.25"]);
        assert_eq!(a.get_f64("eps", 0.6).unwrap(), 0.25);
        assert_eq!(a.get_f64("nope", 0.6).unwrap(), 0.6);
        assert!(a.get_f64("eps", 0.0).is_ok());
        let b = parse(&["x", "--eps", "abc"]);
        assert!(b.get_f64("eps", 0.0).is_err());
    }

    #[test]
    fn f64_list() {
        let a = parse(&["x", "--ls", "0.02, 0.07,0.15"]);
        assert_eq!(a.get_f64_list("ls", &[]).unwrap(), vec![0.02, 0.07, 0.15]);
    }

    #[test]
    fn unknown_rejected() {
        let a = parse(&["x", "--whoops", "1"]);
        assert!(a.expect_known(&["eps"]).is_err());
        assert!(a.expect_known(&["whoops"]).is_ok());
    }
}
