//! Sweep determinism: the same `SweepSpec` + base seed must produce
//! bit-identical `SweepReport`s at 1, 2 and 8 worker threads, and must
//! match a direct sequential `Simulation::run` of the same cells.

use pingan::simulator::{SimConfig, Simulation};
use pingan::sweep::{self, Axis, Scenario, SweepSpec};

fn smoke_spec() -> SweepSpec {
    let mut base = Scenario::default();
    base.n_clusters = 6;
    base.n_jobs = 10;
    base.slot_divisor = 10;
    SweepSpec::new(base)
        .axis(Axis::Lambda(vec![0.05, 0.1]))
        .axis(Axis::Scheduler(vec!["flutter".into(), "pingan".into()]))
        .reps(2)
        .seed(0xD5)
}

#[test]
fn report_is_bit_identical_across_thread_counts() {
    let spec = smoke_spec();
    let r1 = sweep::run_with(&spec, 1, None);
    let r2 = sweep::run_with(&spec, 2, None);
    let r8 = sweep::run_with(&spec, 8, None);
    // precondition for the float comparisons below: every cell ran clean
    // and finished every job (no NaN flowtimes in the aggregate rows)
    assert!(r1
        .cells
        .iter()
        .all(|c| c.error.is_none() && c.finished == c.total));
    // CellResult/ScenarioRow equality is over simulated outcome only
    // (wall-clock is excluded), so these are bitwise comparisons of
    // flowtime series, seeds, and copy counts.
    assert_eq!(r1.cells, r2.cells);
    assert_eq!(r1.cells, r8.cells);
    assert_eq!(r1.rows, r2.rows);
    assert_eq!(r1.rows, r8.rows);
    assert_eq!(r1.to_csv(), r2.to_csv());
    assert_eq!(r1.to_csv(), r8.to_csv());
}

#[test]
fn parallel_run_matches_direct_sequential_simulation() {
    let spec = smoke_spec();
    let report = sweep::run_with(&spec, 4, None);
    let cells = spec.cells();
    assert_eq!(report.cells.len(), cells.len());
    for (got, cell) in report.cells.iter().zip(&cells) {
        // the long way around: materialize the cell's environment and run
        // the simulator directly, bypassing the runner entirely
        let (sys, jobs) = cell.build_env(spec.base_seed);
        let mut cfg = SimConfig::default();
        cfg.seed = cell.env_seed(spec.base_seed) ^ 0xC0FFEE;
        let mut sched = cell.make_scheduler().expect("valid scheduler");
        let direct = Simulation::new(&sys, jobs, cfg).run(sched.as_mut());
        assert_eq!(got.flowtimes.len(), direct.flowtimes.len());
        for (a, b) in got.flowtimes.iter().zip(&direct.flowtimes) {
            assert_eq!(a.to_bits(), b.to_bits(), "cell {}", cell.label());
        }
        assert_eq!(got.finished, direct.finished_jobs);
        assert_eq!(got.copies_launched, direct.copies_launched);
        assert_eq!(got.copies_failed, direct.copies_failed);
        assert_eq!(got.slots, direct.slots);
    }
}

/// Nested parallelism: sweep workers × intra-cell scoring threads. A
/// sweep over `Axis::ScoreThreads` must produce byte-identical cell JSON
/// (wall clock excluded) at any runner thread count, and the cells of
/// different scoring budgets at the same coordinates must be bitwise
/// pairs of each other — the sharded scorer may only move wall time.
#[test]
fn score_threads_axis_is_byte_identical_across_runner_threads() {
    let mut base = Scenario::default();
    base.n_clusters = 6;
    base.n_jobs = 8;
    base.slot_divisor = 10;
    base.scheduler = "pingan".to_string();
    let spec = SweepSpec::new(base)
        .axis(Axis::ScoreThreads(vec![1, 2, 4]))
        .axis(Axis::Lambda(vec![0.05]))
        .reps(2)
        .seed(0xD7);
    assert_eq!(spec.n_cells(), 6);
    let r1 = sweep::run_with(&spec, 1, None);
    let r4 = sweep::run_with(&spec, 4, None);
    assert!(r1
        .cells
        .iter()
        .all(|c| c.error.is_none() && c.finished == c.total));
    assert_eq!(r1.cells, r4.cells);
    assert_eq!(r1.rows, r4.rows);
    // the deterministic JSON (wall clock excluded) is byte-identical
    let (j1, j4) = (r1.to_json_deterministic(), r4.to_json_deterministic());
    assert_eq!(j1.to_string(), j4.to_string(), "cell JSON bytes diverged");
    // grid order: score_threads outermost, reps innermost — cells 0..2
    // ran serial, 2..4 on 2 threads, 4..6 on 4 threads. Same coordinates
    // ⇒ same env seed ⇒ bitwise-identical simulated outcome.
    for shard in [&r1.cells[2..4], &r1.cells[4..6]] {
        for (serial, sharded) in r1.cells[0..2].iter().zip(shard) {
            assert_eq!(serial.seed, sharded.seed, "env seed moved with the budget");
            assert_eq!(serial.copies_launched, sharded.copies_launched);
            assert_eq!(serial.copies_failed, sharded.copies_failed);
            assert_eq!(serial.slots, sharded.slots);
            assert_eq!(serial.events_processed, sharded.events_processed);
            assert_eq!(serial.flowtimes.len(), sharded.flowtimes.len());
            for (a, b) in serial.flowtimes.iter().zip(&sharded.flowtimes) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "cell {}: sharded scoring moved a flowtime",
                    sharded.scenario.label()
                );
            }
        }
    }
}

/// The cluster-sharding acceptance criterion: a 1000-cluster event-skip
/// cell under `engine_threads = 4` — large enough that every shard clears
/// the spawn threshold, so real OS threads advance the plant — must
/// produce byte-identical wall-free sweep JSON to `engine_threads = 1`.
/// Works only because `engine_threads` is excluded from env seeds AND
/// from cell labels (report JSON embeds labels).
#[test]
fn engine_threads_are_byte_identical_on_a_large_eventskip_cell() {
    use pingan::config::spec::TimeModel;
    let mk = |threads: usize| {
        let mut base = Scenario::default();
        base.n_clusters = 1000;
        base.n_jobs = 8;
        base.slot_divisor = 10;
        base.scheduler = "flutter".to_string();
        base.time_model = TimeModel::EventSkip;
        base.engine_threads = threads;
        SweepSpec::new(base)
            .axis(Axis::Lambda(vec![0.05]))
            .reps(1)
            .seed(0xD9)
    };
    let r1 = sweep::run_with(&mk(1), 1, None);
    let r4 = sweep::run_with(&mk(4), 1, None);
    assert!(r1
        .cells
        .iter()
        .all(|c| c.error.is_none() && c.finished == c.total));
    let (j1, j4) = (r1.to_json_deterministic(), r4.to_json_deterministic());
    assert_eq!(
        j1.to_string(),
        j4.to_string(),
        "sweep JSON bytes diverged between engine_threads 1 and 4"
    );
    // belt and braces under the JSON: the paired cells are bitwise equal
    // (Scenario PartialEq covers engine_threads, so compare outcomes)
    assert_eq!(r1.cells.len(), r4.cells.len());
    for (a, b) in r1.cells.iter().zip(&r4.cells) {
        assert_eq!(a.seed, b.seed, "env seed moved with engine_threads");
        assert_eq!(a.copies_launched, b.copies_launched);
        assert_eq!(a.copies_failed, b.copies_failed);
        assert_eq!(a.slots, b.slots);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.flowtimes.len(), b.flowtimes.len());
        for (x, y) in a.flowtimes.iter().zip(&b.flowtimes) {
            assert_eq!(x.to_bits(), y.to_bits(), "sharded plant moved a flowtime");
        }
    }
}

/// The contended-WAN acceptance criterion: the same large event-skip
/// cell under `--bandwidth-model shared` must ALSO produce byte-identical
/// wall-free sweep JSON at `engine_threads` 1 vs 4. This is exactly the
/// barrier-only re-rate contract — a shared WAN link couples transfers
/// homed in different shards, so all fair-share solves run in the serial
/// phase at the epoch barrier and shard advances stay untouched.
#[test]
fn shared_bandwidth_is_byte_identical_across_engine_threads() {
    use pingan::config::spec::{BandwidthModel, TimeModel};
    let mk = |threads: usize| {
        let mut base = Scenario::default();
        base.n_clusters = 1000;
        base.n_jobs = 8;
        base.slot_divisor = 10;
        base.scheduler = "flutter".to_string();
        base.time_model = TimeModel::EventSkip;
        base.bandwidth_model = BandwidthModel::Shared;
        base.engine_threads = threads;
        SweepSpec::new(base)
            .axis(Axis::Lambda(vec![0.05]))
            .reps(1)
            .seed(0xDB)
    };
    let r1 = sweep::run_with(&mk(1), 1, None);
    let r4 = sweep::run_with(&mk(4), 1, None);
    assert!(r1
        .cells
        .iter()
        .all(|c| c.error.is_none() && c.finished == c.total));
    // the solver really engaged: copies were re-rated under contention
    assert!(
        r1.cells.iter().any(|c| c.telemetry.rate_changes > 0),
        "shared cells saw no rate changes — solver never engaged"
    );
    let (j1, j4) = (r1.to_json_deterministic(), r4.to_json_deterministic());
    assert_eq!(
        j1.to_string(),
        j4.to_string(),
        "shared-model sweep JSON bytes diverged between engine_threads 1 and 4"
    );
    for (a, b) in r1.cells.iter().zip(&r4.cells) {
        assert_eq!(a.copies_launched, b.copies_launched);
        assert_eq!(a.events_processed, b.events_processed);
        for (x, y) in a.flowtimes.iter().zip(&b.flowtimes) {
            assert_eq!(x.to_bits(), y.to_bits(), "shared re-rate moved a flowtime");
        }
    }
}

#[test]
fn policy_axes_share_jobs_within_a_load_point() {
    // Paired comparisons: at the same (λ, rep) the flutter and pingan
    // cells must see the same job set (arrivals and shapes).
    let spec = smoke_spec();
    let cells = spec.cells();
    // grid order: λ outer, scheduler inner, rep innermost
    let flutter0 = &cells[0];
    let pingan0 = &cells[2];
    assert_eq!(flutter0.scheduler, "flutter");
    assert_eq!(pingan0.scheduler, "pingan");
    assert_eq!(flutter0.lambda, pingan0.lambda);
    assert_eq!(flutter0.rep, pingan0.rep);
    let (_, jobs_f) = flutter0.build_env(spec.base_seed);
    let (_, jobs_p) = pingan0.build_env(spec.base_seed);
    assert_eq!(jobs_f.len(), jobs_p.len());
    for (a, b) in jobs_f.iter().zip(&jobs_p) {
        assert_eq!(a.arrival, b.arrival);
        assert_eq!(a.n_tasks(), b.n_tasks());
    }
}
