//! Integration: whole-stack runs across modules — plant generation,
//! workload, every scheduler, metrics — plus the paper's qualitative
//! claims at smoke scale.

use pingan::cluster::GeoSystem;
use pingan::config::spec::{PingAnSpec, SystemSpec, WorkloadSpec};
use pingan::experiments::{self, Scale};
use pingan::insurance::PingAn;
use pingan::metrics;
use pingan::simulator::{SimConfig, Simulation};
use pingan::util::rng::Rng;
use pingan::workload::montage;

fn setup(
    n_clusters: usize,
    n_jobs: usize,
    lambda: f64,
    seed: u64,
) -> (GeoSystem, Vec<pingan::workload::job::JobSpec>) {
    let mut rng = Rng::new(seed);
    let sys = GeoSystem::generate(&SystemSpec::small(n_clusters), &mut rng);
    let mut w = WorkloadSpec::scaled(n_jobs, lambda);
    w.datasize = (50.0, 500.0);
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);
    (sys, jobs)
}

#[test]
fn every_scheduler_completes_the_same_workload() {
    let (sys, jobs) = setup(8, 12, 0.05, 1001);
    for name in [
        "pingan",
        "spark",
        "spark-spec",
        "flutter",
        "iridium",
        "flutter+mantri",
        "flutter+dolly",
    ] {
        let mut sched = experiments::make_scheduler(name, 0.6);
        let res = Simulation::new(&sys, jobs.clone(), SimConfig::default()).run(sched.as_mut());
        assert_eq!(
            res.finished_jobs, res.total_jobs,
            "{name} left jobs unfinished"
        );
        assert!(metrics::avg_flowtime(&res) > 0.0, "{name} zero flowtime");
    }
}

#[test]
fn pingan_beats_single_copy_baselines_under_failures() {
    // Under non-trivial failure rates, insurance should beat no-copy
    // Flutter on average flowtime (the paper's core claim, Fig 4).
    let mut spec = SystemSpec::small(8);
    for c in &mut spec.classes {
        c.unreach_p = (c.unreach_p.0 * 2.0, (c.unreach_p.1 * 2.0).min(0.5));
    }
    let mut rng = Rng::new(2002);
    let sys = GeoSystem::generate(&spec, &mut rng);
    let mut w = WorkloadSpec::scaled(18, 0.04);
    w.datasize = (50.0, 500.0);
    let sites: Vec<usize> = (0..sys.n()).collect();
    let jobs = montage::generate(&w, &sites, &mut rng);

    let mut flutter_sum = 0.0;
    let mut pingan_sum = 0.0;
    for rep in 0..3u64 {
        let mut cfg = SimConfig::default();
        cfg.seed = 7000 + rep;
        let f = Simulation::new(&sys, jobs.clone(), cfg.clone())
            .run(&mut pingan::baselines::Flutter::new());
        let p =
            Simulation::new(&sys, jobs.clone(), cfg).run(&mut PingAn::with_epsilon(0.6));
        flutter_sum += metrics::avg_flowtime(&f);
        pingan_sum += metrics::avg_flowtime(&p);
    }
    assert!(
        pingan_sum < flutter_sum,
        "pingan {pingan_sum} !< flutter {flutter_sum}"
    );
}

#[test]
fn sum_flowtime_is_the_objective() {
    let (sys, jobs) = setup(6, 8, 0.05, 1003);
    let res =
        Simulation::new(&sys, jobs, SimConfig::default()).run(&mut PingAn::with_epsilon(0.6));
    let avg = metrics::avg_flowtime(&res);
    let sum = metrics::sum_flowtime(&res);
    assert!((sum / res.finished_jobs as f64 - avg).abs() < 1e-9);
}

#[test]
fn epsilon_validation_rejected_at_construction() {
    let r = std::panic::catch_unwind(|| PingAn::new(PingAnSpec::with_epsilon(1.5)));
    assert!(r.is_err());
}

#[test]
fn experiments_smoke_scale_pipeline() {
    let scale = Scale::smoke();
    let (sys, jobs) = experiments::sim_setup(&scale, 0.07, 0);
    assert_eq!(jobs.len(), scale.n_jobs);
    let a = experiments::run_one(&sys, jobs.clone(), "pingan", 0.6, 0);
    let b = experiments::run_one(&sys, jobs, "pingan", 0.6, 0);
    // same seed -> identical results (regeneration is reproducible)
    assert_eq!(a.flowtimes, b.flowtimes);
}

#[test]
fn reduction_ratio_pipeline_matches_fig5_semantics() {
    let (sys, jobs) = setup(6, 10, 0.05, 1004);
    let f = Simulation::new(&sys, jobs.clone(), SimConfig::default())
        .run(&mut pingan::baselines::Flutter::new());
    let p = Simulation::new(&sys, jobs, SimConfig::default())
        .run(&mut PingAn::with_epsilon(0.6));
    let rr = pingan::metrics::cdf::reduction_ratios(&f.flowtimes, &p.flowtimes);
    assert_eq!(rr.len(), f.flowtimes.len());
    for r in &rr {
        assert!(*r <= 1.0, "reduction ratio > 1 impossible");
    }
}
