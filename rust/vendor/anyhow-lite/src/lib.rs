//! API-compatible shim for the subset of `anyhow` this repository uses:
//! an [`Error`] with a cause chain, the [`anyhow!`] and [`ensure!`]
//! macros, the [`Result`] alias, and [`Context`] for annotating std
//! errors. `{e}` prints the outermost message, `{e:#}` the whole chain —
//! matching the real crate's formatting contract.
//!
//! Like `util::cli` (clap) and `bench_harness` (criterion), this exists
//! because registry crates are unavailable offline; keeping the dependency
//! graph path-only also lets `Cargo.lock` be exact without checksums. The
//! surface mirrors `anyhow` 1.x so swapping the real crate back in is a
//! one-line `Cargo.toml` change.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted, as in the
/// real crate (`anyhow::Result<T>` and `anyhow::Result<T, E>` both work).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message plus an optional cause chain. Deliberately does NOT implement
/// `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
/// conversion (what makes `?` work on std errors) coherent, exactly like
/// the real crate.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Root error from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
            source: None,
        }
    }

    /// Wrap `self` as the cause of a new outer message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(self)),
        }
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        let mut msgs = vec![self.msg.as_str()];
        let mut cur = &self.source;
        while let Some(e) = cur {
            msgs.push(e.msg.as_str());
            cur = &e.source;
        }
        msgs.into_iter()
    }
}

impl fmt::Display for Error {
    /// `{}` is the outermost message; `{:#}` joins the chain with `: `.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = &self.source;
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = &e.source;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    /// `unwrap()`/`expect()` reports show the whole chain.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:#}", self)
    }
}

/// `?` on any std error inside a `-> anyhow::Result<_>` function. The std
/// source chain is flattened into the shim's own chain so `{:#}` keeps
/// printing root causes.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut out: Option<Error> = None;
        for msg in msgs.into_iter().rev() {
            out = Some(Error {
                msg,
                source: out.map(Box::new),
            });
        }
        out.expect("chain has at least the top message")
    }
}

/// Annotate a fallible std-error result with higher-level context.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from format arguments: `anyhow!("bad {x}")`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an error unless a condition holds:
/// `ensure!(a == b, "mismatch {a} vs {b}")`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Return early with an error: `bail!("gave up: {why}")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = anyhow!("low {}", 1).context("mid").context("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top: mid: low 1");
        assert_eq!(format!("{e:?}"), "top: mid: low 1");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["top", "mid", "low 1"]);
    }

    #[test]
    fn question_mark_and_context_on_std_errors() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "gone");
        let e = io_fail()
            .with_context(|| format!("reading {}", "x"))
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "reading x: gone");
        let e = io_fail().context("static").unwrap_err();
        assert_eq!(format!("{e}"), "static");
    }

    #[test]
    fn ensure_and_bail_return_errors() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert_eq!(format!("{}", check(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", check(11).unwrap_err()), "too big: 11");
    }
}
