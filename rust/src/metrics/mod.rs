//! Flowtime metrics: averages, CDFs and reduction ratios (the paper's
//! evaluation metrics — Sec 5 "Metric" and Sec 6.1 "Metric").

pub mod cdf;

pub use cdf::{Cdf, reduction_ratios};

use crate::simulator::SimResult;
use crate::util::stats;

/// Average job flowtime over *finished* jobs (NaN entries are unfinished;
/// the engine only leaves those when `max_slots` fires).
pub fn avg_flowtime(res: &SimResult) -> f64 {
    let done: Vec<f64> = res.flowtimes.iter().copied().filter(|f| f.is_finite()).collect();
    stats::mean(&done)
}

/// Sum of job flowtimes — the paper's objective (Eq. 1).
pub fn sum_flowtime(res: &SimResult) -> f64 {
    res.flowtimes.iter().copied().filter(|f| f.is_finite()).sum()
}

/// Fraction of jobs finishing within `within` slots (Fig 3/5 commentary).
pub fn frac_within(res: &SimResult, within: f64) -> f64 {
    if res.flowtimes.is_empty() {
        return 0.0;
    }
    res.flowtimes
        .iter()
        .filter(|f| f.is_finite() && **f <= within)
        .count() as f64
        / res.flowtimes.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::SimResult;

    fn result(flows: &[f64]) -> SimResult {
        SimResult {
            scheduler: "t".into(),
            flowtimes: flows.to_vec(),
            finished_jobs: flows.iter().filter(|f| f.is_finite()).count(),
            total_jobs: flows.len(),
            copies_launched: 0,
            copies_failed: 0,
            slots: 0,
        }
    }

    #[test]
    fn averages_skip_unfinished() {
        let r = result(&[10.0, 20.0, f64::NAN]);
        assert!((avg_flowtime(&r) - 15.0).abs() < 1e-12);
        assert!((sum_flowtime(&r) - 30.0).abs() < 1e-12);
    }

    #[test]
    fn frac_within_counts_all_jobs() {
        let r = result(&[10.0, 200.0, f64::NAN]);
        assert!((frac_within(&r, 100.0) - 1.0 / 3.0).abs() < 1e-12);
    }
}
