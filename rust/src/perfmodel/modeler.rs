//! Execution-log driven estimates of cluster performance.

use crate::cluster::GeoSystem;
use crate::dist::{Grid, Hist};
use crate::workload::job::OpKind;

const N_OPS: usize = 4;
/// Observation weight schedule: the n-th observation is blended with weight
/// max(1/n, MIN_BLEND) so estimates keep tracking drift (a recency window).
const MIN_BLEND: f64 = 0.02;
/// Prior blur factor applied to ground-truth std (published-spec coarseness).
const PRIOR_BLUR: f64 = 2.0;

/// Performance model: histograms per (cluster, op) and per cluster pair.
pub struct PerfModel {
    grid: Grid,
    n: usize,
    /// [cluster * N_OPS + op]
    proc: Vec<Hist>,
    proc_count: Vec<u64>,
    /// [from * n + to]
    trans: Vec<Hist>,
    trans_count: Vec<u64>,
    /// (observed failures, observed slots) per cluster.
    fail_obs: Vec<(u64, u64)>,
}

impl PerfModel {
    /// Build with blurred priors derived from the system's public shape.
    pub fn new(system: &GeoSystem, grid_bins: usize) -> PerfModel {
        let hi = (system.max_power.max(system.max_wan) * 1.05).max(1.0);
        let grid = Grid::uniform(0.0, hi, grid_bins.max(8));
        let n = system.n();
        let mut proc = Vec::with_capacity(n * N_OPS);
        for c in &system.clusters {
            for op in OpKind::ALL {
                // blurred prior: right mean ballpark, inflated variance
                proc.push(Hist::normal(
                    &grid,
                    c.power_mean * op.speed_skew(),
                    (c.power_std * op.speed_skew() * PRIOR_BLUR).max(1.0),
                ));
            }
        }
        let mut trans = Vec::with_capacity(n * n);
        for a in 0..n {
            for b in 0..n {
                trans.push(Hist::normal(
                    &grid,
                    system.wan_mean(a, b),
                    (system.wan_std(a, b) * PRIOR_BLUR).max(1.0),
                ));
            }
        }
        PerfModel {
            grid,
            n,
            proc,
            proc_count: vec![0; n * N_OPS],
            trans,
            trans_count: vec![0; n * n],
            fail_obs: vec![(0, 0); n],
        }
    }

    pub fn grid(&self) -> &Grid {
        &self.grid
    }

    pub fn n_clusters(&self) -> usize {
        self.n
    }

    // ---- observation ingestion (Fig 1b arrows 1-3) ----

    /// A finished task reports its data-processing speed.
    pub fn observe_proc(&mut self, cluster: usize, op: OpKind, speed: f64) {
        let i = cluster * N_OPS + op.index();
        self.proc_count[i] += 1;
        let w = (1.0 / self.proc_count[i] as f64).max(MIN_BLEND);
        let obs = Hist::point(&self.grid, speed);
        self.proc[i].blend(&obs, w);
    }

    /// A finished task reports one inter-cluster transfer bandwidth
    /// (captured at the download end `to`).
    pub fn observe_trans(&mut self, from: usize, to: usize, bw: f64) {
        let i = from * self.n + to;
        self.trans_count[i] += 1;
        let w = (1.0 / self.trans_count[i] as f64).max(MIN_BLEND);
        let obs = Hist::point(&self.grid, bw);
        self.trans[i].blend(&obs, w);
    }

    /// Heartbeat: cluster was (un)reachable this slot.
    pub fn observe_slot(&mut self, cluster: usize, failed: bool) {
        self.observe_slots(cluster, 1, failed as u64);
    }

    /// Batched heartbeat for the event-skip engine: `slots` slots elapsed
    /// on `cluster`, of which `failures` were unreachable. Identical
    /// counters to `slots` repeated [`PerfModel::observe_slot`] calls.
    /// (`failures` may exceed `slots` in a call: the event engine counts a
    /// failure event against slots it already batch-observed.)
    pub fn observe_slots(&mut self, cluster: usize, slots: u64, failures: u64) {
        let (f, s) = &mut self.fail_obs[cluster];
        *s += slots;
        *f += failures;
    }

    // ---- estimates served to the insurer ----

    pub fn proc_hist(&self, cluster: usize, op: OpKind) -> &Hist {
        &self.proc[cluster * N_OPS + op.index()]
    }

    pub fn trans_hist(&self, from: usize, to: usize) -> &Hist {
        &self.trans[from * self.n + to]
    }

    /// p̂_m with Laplace smoothing (1 pseudo-failure / 200 pseudo-slots —
    /// rare events need a conservative prior).
    pub fn p_hat(&self, cluster: usize) -> f64 {
        let (f, s) = self.fail_obs[cluster];
        (f as f64 + 1.0) / (s as f64 + 200.0)
    }

    /// The two ingredients [`PerfModel::rate_hist`] composes, without
    /// cloning the proc histogram: the per-(cluster, op) processing hist
    /// by reference, and the source-averaged transfer hist materialized
    /// on the grid (`None` when `sources` is empty — the rate is then the
    /// proc hist alone, with no transfer bottleneck). The insurer copies
    /// these pmfs straight into `runtime::ScoreBatch` rows.
    pub fn rate_components(
        &self,
        sources: &[usize],
        cluster: usize,
        op: OpKind,
    ) -> (&Hist, Option<Hist>) {
        let p = self.proc_hist(cluster, op);
        if sources.is_empty() {
            return (p, None);
        }
        // I_l^i is a set — dedup defensively (generators may repeat sites)
        let mut distinct: Vec<usize> = sources.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        let t_refs: Vec<&Hist> = distinct
            .iter()
            .map(|&s| self.trans_hist(s, cluster))
            .collect();
        (p, Some(Hist::average_of(&t_refs)))
    }

    /// Distribution of one copy's execution rate in `cluster`:
    /// `min(V^P, mean over sources of V^T)` (Sec 3.2). Local sources count
    /// as the (fast) intra-cluster transfer distribution.
    pub fn rate_hist(&self, sources: &[usize], cluster: usize, op: OpKind) -> Hist {
        match self.rate_components(sources, cluster, op) {
            (p, None) => p.clone(),
            (p, Some(t_avg)) => p.min_compose(&t_avg),
        }
    }

    /// E[r(1)] for one candidate copy.
    pub fn exp_rate1(&self, sources: &[usize], cluster: usize, op: OpKind) -> f64 {
        self.rate_hist(sources, cluster, op).mean()
    }

    /// The task's global-optimal single-copy rate E^O[r(1)] — best over all
    /// clusters, as if the task ran alone (the round-1 floor reference).
    pub fn global_best_rate(&self, sources: &[usize], op: OpKind) -> f64 {
        (0..self.n)
            .map(|m| self.exp_rate1(sources, m, op))
            .fold(0.0, f64::max)
    }

    /// E[max over existing copy-rate hists ∪ candidate] — r(x+1) scoring.
    pub fn exp_rate_with(&self, existing: &[Hist], candidate: &Hist) -> f64 {
        let mut refs: Vec<&Hist> = existing.iter().collect();
        refs.push(candidate);
        Hist::expected_max(&refs)
    }

    /// Trouble-exemption probability of a task with copies in `clusters`
    /// finishing `datasize` at combined expected rate `rate` (Sec 3.2):
    /// `pro = (1 - Π p̂_m)^{datasize/rate}` — per-slot failure only hits the
    /// task if *all* copy clusters fail simultaneously... but distinct
    /// clusters fail independently, so the per-slot survival is
    /// `1 - Π p̂_m` over the distinct clusters involved.
    pub fn pro(&self, clusters: &[usize], datasize: f64, rate: f64) -> f64 {
        if clusters.is_empty() || rate <= 0.0 {
            return 0.0;
        }
        let mut distinct: Vec<usize> = clusters.to_vec();
        distinct.sort_unstable();
        distinct.dedup();
        let p_all: f64 = distinct.iter().map(|&m| self.p_hat(m)).product();
        let e_slots = (datasize / rate).max(1.0);
        (1.0 - p_all).powf(e_slots)
    }

    /// Total observations absorbed (diagnostics / tests).
    pub fn n_observations(&self) -> u64 {
        self.proc_count.iter().sum::<u64>() + self.trans_count.iter().sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec::SystemSpec;
    use crate::util::rng::Rng;

    fn model() -> (GeoSystem, PerfModel) {
        let mut rng = Rng::new(31);
        let sys = GeoSystem::generate(&SystemSpec::small(8), &mut rng);
        let pm = PerfModel::new(&sys, 64);
        (sys, pm)
    }

    #[test]
    fn priors_track_cluster_means() {
        let (sys, pm) = model();
        for c in 0..sys.n() {
            let est = pm.proc_hist(c, OpKind::Map).mean();
            let truth = sys.clusters[c].power_mean;
            assert!(
                (est - truth).abs() / truth < 0.35,
                "cluster {c}: est {est} vs truth {truth}"
            );
        }
    }

    #[test]
    fn observations_sharpen_estimates() {
        let (_, mut pm) = model();
        let before = pm.proc_hist(0, OpKind::Map).mean();
        for _ in 0..60 {
            pm.observe_proc(0, OpKind::Map, 42.0);
        }
        let after = pm.proc_hist(0, OpKind::Map).mean();
        assert!(
            (after - 42.0).abs() < (before - 42.0).abs().max(2.0),
            "before={before} after={after}"
        );
        assert!((after - 42.0).abs() < 6.0, "after={after}");
    }

    #[test]
    fn transfer_observations_update_pairs() {
        let (_, mut pm) = model();
        for _ in 0..60 {
            pm.observe_trans(1, 2, 10.0);
        }
        assert!((pm.trans_hist(1, 2).mean() - 10.0).abs() < 5.0);
        // other pairs untouched by these observations
        assert_eq!(pm.n_observations(), 60);
    }

    #[test]
    fn p_hat_converges_with_laplace_floor() {
        let (_, mut pm) = model();
        assert!(pm.p_hat(0) > 0.0);
        for i in 0..1000 {
            pm.observe_slot(0, i % 10 == 0); // 10% failure rate
        }
        assert!((pm.p_hat(0) - 0.1).abs() < 0.03, "p={}", pm.p_hat(0));
    }

    #[test]
    fn batched_slot_observation_matches_per_slot() {
        let (_, mut a) = model();
        let (_, mut b) = model();
        for i in 0..500 {
            a.observe_slot(2, i % 25 == 0);
        }
        b.observe_slots(2, 480, 0);
        b.observe_slots(2, 20, 20);
        assert_eq!(a.p_hat(2).to_bits(), b.p_hat(2).to_bits());
    }

    #[test]
    fn rate_hist_bottlenecks_on_transfer() {
        let (sys, pm) = model();
        // remote fetch: rate should be <= pure compute rate
        let op = OpKind::Map;
        let compute = pm.proc_hist(0, op).mean();
        let with_remote = pm.exp_rate1(&[1], 0, op);
        assert!(with_remote <= compute + 1e-9);
        // WAN is far slower than compute in Table 2, so the gap is real
        assert!(with_remote < compute, "sys wan {}", sys.wan_mean(1, 0));
    }

    #[test]
    fn global_best_at_least_any_cluster() {
        let (_, pm) = model();
        let best = pm.global_best_rate(&[0], OpKind::Map);
        for m in 0..pm.n_clusters() {
            assert!(best >= pm.exp_rate1(&[0], m, OpKind::Map) - 1e-9);
        }
    }

    #[test]
    fn pro_improves_with_second_cluster() {
        let (_, mut pm) = model();
        for i in 0..500 {
            pm.observe_slot(0, i % 5 == 0); // 20%
            pm.observe_slot(1, i % 5 == 0); // 20%
        }
        let single = pm.pro(&[0], 100.0, 10.0);
        let dual = pm.pro(&[0, 1], 100.0, 10.0);
        assert!(dual > single, "single={single} dual={dual}");
        // duplicate cluster gives no reliability benefit
        let same = pm.pro(&[0, 0], 100.0, 10.0);
        assert!((same - single).abs() < 1e-12);
    }

    #[test]
    fn pro_degenerate_cases() {
        let (_, pm) = model();
        assert_eq!(pm.pro(&[], 10.0, 1.0), 0.0);
        assert_eq!(pm.pro(&[0], 10.0, 0.0), 0.0);
    }

    #[test]
    fn rate_components_compose_to_rate_hist() {
        // the batched scorer consumes the components; composing them must
        // reproduce rate_hist bit for bit (same ops, same order)
        let (_, pm) = model();
        for (sources, m) in [(vec![1usize, 3, 1], 0usize), (vec![0], 2), (vec![], 4)] {
            let want = pm.rate_hist(&sources, m, OpKind::Map);
            let (p, t) = pm.rate_components(&sources, m, OpKind::Map);
            let got = match &t {
                Some(t_avg) => p.min_compose(t_avg),
                None => p.clone(),
            };
            for (a, b) in got.pmf().iter().zip(want.pmf()) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(t.is_none(), sources.is_empty());
        }
    }

    #[test]
    fn exp_rate_with_monotone() {
        let (_, pm) = model();
        let a = pm.rate_hist(&[1], 0, OpKind::Map);
        let b = pm.rate_hist(&[1], 2, OpKind::Map);
        let solo = a.mean();
        let joint = pm.exp_rate_with(std::slice::from_ref(&a), &b);
        assert!(joint >= solo - 1e-9);
    }
}
