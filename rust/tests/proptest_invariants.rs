//! Property-based invariants over randomized inputs (an in-tree proptest:
//! seeds sweep a generator; any failure prints the violating seed).
//!
//! Coordinator invariants covered:
//! * engine ledgers (slots, gates) never oversubscribe under any policy mix
//! * task copies never exceed the configured cap
//! * flowtimes are finite and >= critical-path lower bounds
//! * Proposition 1 (diminishing returns) on randomized distribution families
//! * histogram-algebra invariants (mass, E[max] bound, min-compose bound)
//! * reduction ratios bounded above by 1

use pingan::analysis::proposition::{check_proposition1, random_family};
use pingan::cluster::GeoSystem;
use pingan::config::spec::{PingAnSpec, SystemSpec, WorkloadSpec};
use pingan::dist::{Grid, Hist};
use pingan::insurance::PingAn;
use pingan::simulator::{SimConfig, Simulation};
use pingan::util::rng::Rng;
use pingan::workload::montage;

const SEEDS: std::ops::Range<u64> = 0..12;

#[test]
fn prop_engine_invariants_hold_for_random_workloads() {
    for seed in SEEDS {
        let mut rng = Rng::new(0xABC0 + seed);
        let n_clusters = rng.range_usize(3, 10);
        let n_jobs = rng.range_usize(2, 10);
        let lambda = rng.range_f64(0.02, 0.2);
        let sys = GeoSystem::generate(&SystemSpec::small(n_clusters), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, lambda);
        w.datasize = (20.0, 400.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let eps = rng.range_f64(0.15, 0.9);
        let mut p = PingAn::with_epsilon(eps);
        for step in 0..150 {
            sim.step(&mut p);
            sim.check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
        }
    }
}

#[test]
fn prop_copy_cap_respected_for_random_caps() {
    for seed in SEEDS {
        let mut rng = Rng::new(0xBEE0 + seed);
        let cap = rng.range_usize(1, 4);
        let sys = GeoSystem::generate(&SystemSpec::small(5), &mut rng);
        let mut w = WorkloadSpec::scaled(4, 0.1);
        w.datasize = (50.0, 300.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let mut spec = PingAnSpec::with_epsilon(0.7);
        spec.max_copies = cap;
        let mut sim = Simulation::new(&sys, jobs, SimConfig::default());
        let mut p = PingAn::new(spec);
        for _ in 0..120 {
            sim.step(&mut p);
            for j in &sim.jobs {
                for t in &j.tasks {
                    assert!(
                        t.alive_copies() <= cap,
                        "seed {seed}: cap {cap} violated ({} copies)",
                        t.alive_copies()
                    );
                }
            }
        }
    }
}

#[test]
fn prop_flowtimes_at_least_stage_depth() {
    // a job cannot finish faster than its critical path (>= 1 slot/stage)
    for seed in SEEDS {
        let mut rng = Rng::new(0xCAFE + seed);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(5, 0.05);
        w.datasize = (20.0, 200.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let depths: Vec<usize> = jobs.iter().map(|j| j.critical_path()).collect();
        let res = Simulation::new(&sys, jobs, SimConfig::default())
            .run(&mut PingAn::with_epsilon(0.6));
        for (i, f) in res.flowtimes.iter().enumerate() {
            assert!(f.is_finite(), "seed {seed}: job {i} unfinished");
            assert!(
                *f + 1.0 >= depths[i] as f64,
                "seed {seed}: job {i} flowtime {f} < critical path {}",
                depths[i]
            );
        }
    }
}

#[test]
fn prop_geometric_gaps_match_bernoulli_failure_process() {
    // The event-skip failure process: sampling geometric inter-failure
    // gaps must reproduce the dense engine's Bernoulli-per-slot draws in
    // mean AND variance of per-window failure counts on a long horizon.
    use pingan::simulator::processes::geometric_gap;
    for seed in 0..8u64 {
        let mut rng = Rng::new(0x6E0_0 + seed);
        let p = rng.range_f64(0.003, 0.15);
        let window = 400u64;
        let n_windows = 100usize;
        let horizon = window * n_windows as u64;
        // per-window failure counts under per-slot Bernoulli draws
        let mut bern = vec![0.0f64; n_windows];
        for t in 0..horizon {
            if rng.chance(p) {
                bern[(t / window) as usize] += 1.0;
            }
        }
        // the same horizon walked with geometric gaps (first failure at
        // gap-1, mirroring FailureGaps::new)
        let mut geo = vec![0.0f64; n_windows];
        let mut t = geometric_gap(p, &mut rng).unwrap() - 1;
        while t < horizon {
            geo[(t / window) as usize] += 1.0;
            t += geometric_gap(p, &mut rng).unwrap();
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
        let var = |v: &[f64]| {
            let m = mean(v);
            v.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (v.len() - 1) as f64
        };
        let want_mean = window as f64 * p;
        let (mb, mg) = (mean(&bern), mean(&geo));
        // each mean estimates window·p with stderr sqrt(window·p/n); allow
        // 5 combined stderrs plus a small absolute slack
        let stderr = (want_mean / n_windows as f64).sqrt();
        assert!(
            (mb - mg).abs() <= 5.0 * std::f64::consts::SQRT_2 * stderr + 0.05 * want_mean,
            "seed {seed} p={p:.4}: window means {mb:.3} (bernoulli) vs {mg:.3} (geometric)"
        );
        assert!(
            (mb - want_mean).abs() <= 5.0 * stderr + 0.05 * want_mean,
            "seed {seed} p={p:.4}: bernoulli mean {mb:.3} vs expected {want_mean:.3}"
        );
        // window counts are Binomial(window, p) either way: the sample
        // variances must agree within sampling noise. The estimator's
        // relative sd is ~sqrt((2 + 1/mean)/n) ≈ 20% at the small-p end,
        // so gate the ratio at 3x — wide enough to never flake, tight
        // enough to catch a mis-sampled gap process (whose per-window
        // variance would be off by an order of magnitude).
        let (vb, vg) = (var(&bern), var(&geo));
        let ratio = vg / vb.max(1e-9);
        assert!(
            (1.0 / 3.0..=3.0).contains(&ratio),
            "seed {seed} p={p:.4}: variance ratio {ratio:.3} ({vg:.3} vs {vb:.3})"
        );
    }
}

#[test]
fn prop_sharded_failure_gaps_match_serial_walk() {
    // The cluster-sharding invariant at the process level: sampling the
    // failure process through any shard partition must reproduce the
    // serial (1-shard) walk EXACTLY — same failed clusters each dense
    // slot, same pending-failure slots after every event-skip advance —
    // because each cluster draws gaps only from its own stream. This is
    // stronger than distribution-identity: the sequences are bit-equal.
    use pingan::simulator::shard::EngineShards;
    for seed in SEEDS {
        let mut rng = Rng::new(0x5A4D + seed);
        let n_clusters = rng.range_usize(2, 12);
        let sys = GeoSystem::generate(&SystemSpec::small(n_clusters), &mut rng);
        let shard_count = rng.range_usize(2, 6);
        let walk_seed = rng.next_u64();

        // dense walk: per-slot Bernoulli flips over a random horizon
        let mut serial = EngineShards::new(&sys, walk_seed, 1);
        let mut sharded = EngineShards::new(&sys, walk_seed, shard_count);
        let horizon = rng.range_usize(50, 300) as u64;
        for slot in 0..horizon {
            let a = serial.advance_dense_slot();
            let b = sharded.advance_dense_slot();
            assert_eq!(
                a, b,
                "seed {seed} slot {slot} ({shard_count} shards): dense failed sets diverge"
            );
        }

        // event-skip walk: irregular jumps with random idle stretches;
        // every cluster's pending-failure slot must track the serial walk
        let mut serial = EngineShards::new(&sys, walk_seed, 1);
        let mut sharded = EngineShards::new(&sys, walk_seed, shard_count);
        let mut t = 0u64;
        let mut load_upto = 0u64;
        for step in 0..40 {
            t += rng.range_usize(1, 30) as u64;
            let idle = rng.chance(0.4);
            if idle {
                load_upto = load_upto.max(t);
            }
            let k = (t + 1).saturating_sub(load_upto);
            serial.advance_events_to(t, idle, k);
            sharded.advance_events_to(t, idle, k);
            load_upto = t + 1;
            let obs_a: Vec<_> = serial.observations().collect();
            let obs_b: Vec<_> = sharded.observations().collect();
            assert_eq!(
                obs_a, obs_b,
                "seed {seed} step {step} t={t}: heartbeat observations diverge"
            );
            for m in 0..sys.n() {
                assert_eq!(
                    serial.fail_next(m),
                    sharded.fail_next(m),
                    "seed {seed} step {step} t={t} cluster {m}: pending failure diverges"
                );
            }
        }
    }
}

#[test]
fn prop_eventskip_runs_respect_engine_bounds() {
    // the event core on randomized workloads: every job finishes, no
    // flowtime undercuts its critical path, and the skip counter is sane
    use pingan::config::spec::TimeModel;
    for seed in SEEDS {
        let mut rng = Rng::new(0xE5C0 + seed);
        let sys = GeoSystem::generate(&SystemSpec::small(6), &mut rng);
        let mut w = WorkloadSpec::scaled(5, 0.05);
        w.datasize = (20.0, 200.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let depths: Vec<usize> = jobs.iter().map(|j| j.critical_path()).collect();
        let mut cfg = SimConfig::default();
        cfg.time_model = TimeModel::EventSkip;
        let eps = rng.range_f64(0.15, 0.9);
        let res = Simulation::new(&sys, jobs, cfg).run(&mut PingAn::with_epsilon(eps));
        assert!(res.events_processed > 0, "seed {seed}: no events processed");
        for (i, f) in res.flowtimes.iter().enumerate() {
            assert!(f.is_finite(), "seed {seed}: job {i} unfinished");
            assert!(
                *f + 1.0 >= depths[i] as f64,
                "seed {seed}: job {i} flowtime {f} < critical path {}",
                depths[i]
            );
        }
    }
}

#[test]
fn prop_fair_share_backends_agree_under_random_churn() {
    // the fairness invariants at the integration level: after EVERY op
    // of a random start/finish interleaving over a random gate graph,
    // (a) no gate or transfer-cap capacity is exceeded, (b) progressive
    // filling froze at least one bottleneck per iteration, and (c) the
    // incremental backend's rates are bit-identical to the reference's.
    use pingan::simulator::bandwidth::{
        FairShare, IncrementalFairShare, ReferenceFairShare, Transfer,
    };
    for seed in SEEDS {
        let mut rng = Rng::new(0xFA15 + seed);
        let n_gates = rng.range_u64(2, 12);
        let mut reference = ReferenceFairShare::new();
        let mut incremental = IncrementalFairShare::new();
        for g in 0..n_gates {
            let cap = rng.range_f64(1.0, 50.0);
            reference.set_gate(g, cap);
            incremental.set_gate(g, cap);
        }
        let mut live: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for op in 0..80 {
            if live.is_empty() || rng.chance(0.6) {
                let k = rng.range_u64(1, 3.min(n_gates));
                let uses: Vec<(u64, f64)> = (0..k)
                    .map(|_| (rng.range_u64(0, n_gates - 1), rng.range_f64(0.1, 1.0)))
                    .collect();
                let t = Transfer::new(next_id, rng.range_f64(0.5, 40.0), uses);
                reference.start(t.clone());
                incremental.start(t);
                live.push(next_id);
                next_id += 1;
            } else {
                let slot = rng.range_usize(0, live.len() - 1);
                let id = live.swap_remove(slot);
                reference.finish(id);
                incremental.finish(id);
            }
            reference
                .check_capacities()
                .unwrap_or_else(|e| panic!("seed {seed} op {op}: reference {e}"));
            incremental
                .check_capacities()
                .unwrap_or_else(|e| panic!("seed {seed} op {op}: incremental {e}"));
            let d = reference.last_diag();
            assert!(
                d.saturated >= d.iterations,
                "seed {seed} op {op}: an iteration froze no bottleneck"
            );
            let (a, b) = (reference.rates(), incremental.rates());
            assert_eq!(a.len(), b.len(), "seed {seed} op {op}: population diverged");
            for ((ia, ra), (ib, rb)) in a.iter().zip(&b) {
                assert_eq!(ia, ib, "seed {seed} op {op}: id order diverged");
                assert_eq!(
                    ra.to_bits(),
                    rb.to_bits(),
                    "seed {seed} op {op} id {ia}: {ra} vs {rb}"
                );
            }
        }
    }
}

#[test]
fn prop_shared_bandwidth_runs_hold_invariants_and_never_speed_up() {
    // the shared model end to end on random workloads: engine ledgers
    // stay consistent while the solver re-rates, every job finishes on
    // both time cores, and the constant model never sees a rate change.
    // Per-copy, fair-sharing only lowers rates below the constant-model
    // launch draw — but a slowed task shifts later policy epochs, which
    // reshuffles later launch-time draws, so a single paired run can
    // invert. The monotone claim is therefore asserted on the AGGREGATE
    // over the whole seed sweep, where the systematic slowdown dominates
    // any per-run draw luck.
    use pingan::config::spec::{BandwidthModel, TimeModel};
    let mut total_shared = 0.0f64;
    let mut total_constant = 0.0f64;
    let mut total_rate_changes = 0u64;
    for seed in SEEDS {
        let mut rng = Rng::new(0x6A7E + seed);
        let n_clusters = rng.range_usize(3, 10);
        let n_jobs = rng.range_usize(2, 8);
        let lambda = rng.range_f64(0.02, 0.2);
        let sys = GeoSystem::generate(&SystemSpec::small(n_clusters), &mut rng);
        let mut w = WorkloadSpec::scaled(n_jobs, lambda);
        w.datasize = (20.0, 400.0);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let jobs = montage::generate(&w, &sites, &mut rng);
        let eps = rng.range_f64(0.15, 0.9);

        let mut shared_cfg = SimConfig::default();
        shared_cfg.bandwidth_model = BandwidthModel::Shared;
        let mut sim = Simulation::new(&sys, jobs.clone(), shared_cfg.clone());
        let mut p = PingAn::with_epsilon(eps);
        for step in 0..150 {
            sim.step(&mut p);
            sim.check_invariants()
                .unwrap_or_else(|e| panic!("seed {seed} step {step}: {e}"));
        }

        for time_model in [TimeModel::Dense, TimeModel::EventSkip] {
            let mut cfg = shared_cfg.clone();
            cfg.time_model = time_model;
            let shared = Simulation::new(&sys, jobs.clone(), cfg.clone())
                .run(&mut PingAn::with_epsilon(eps));
            cfg.bandwidth_model = BandwidthModel::Constant;
            let constant =
                Simulation::new(&sys, jobs.clone(), cfg).run(&mut PingAn::with_epsilon(eps));
            assert_eq!(
                shared.finished_jobs, shared.total_jobs,
                "seed {seed} {time_model:?}: shared run left jobs unfinished"
            );
            assert_eq!(
                constant.telemetry.rate_changes, 0,
                "seed {seed} {time_model:?}: constant model re-rated"
            );
            total_rate_changes += shared.telemetry.rate_changes;
            total_shared += shared.avg_flowtime();
            total_constant += constant.avg_flowtime();
        }
    }
    assert!(
        total_rate_changes > 0,
        "no random workload ever engaged the fair-share solver"
    );
    assert!(
        total_shared + 1e-6 >= total_constant,
        "fair-sharing beat the constant model in aggregate: {total_shared} < {total_constant}"
    );
}

#[test]
fn prop_hist_algebra_invariants() {
    // the foundation under every scoring path: random families conserve
    // mass, E[max] dominates the best single mean, min-composition is
    // bounded by the slower input, and blending has w=0 / w=1 fixed points
    let grid = Grid::uniform(0.0, 20.0, 64);
    for seed in 0..30u64 {
        let mut rng = Rng::new(0xA1CE + seed);
        let n = rng.range_usize(2, 6);
        let fam = random_family(&mut rng, n, &grid);
        for h in &fam {
            let mass: f64 = h.pmf().iter().sum();
            assert!((mass - 1.0).abs() < 1e-9, "seed {seed}: mass {mass}");
        }
        let refs: Vec<&Hist> = fam.iter().collect();
        let emax = Hist::expected_max(&refs);
        let best = fam.iter().map(|h| h.mean()).fold(f64::NEG_INFINITY, f64::max);
        assert!(emax >= best - 1e-9, "seed {seed}: E[max] {emax} < best mean {best}");
        let m = fam[0].min_compose(&fam[1]);
        let floor = fam[0].mean().min(fam[1].mean());
        assert!(
            m.mean() <= floor + 1e-9,
            "seed {seed}: E[min] {} > min of means {floor}",
            m.mean()
        );
        let mut w0 = fam[0].clone();
        w0.blend(&fam[1], 0.0);
        let mut w1 = fam[0].clone();
        w1.blend(&fam[1], 1.0);
        for j in 0..grid.bins() {
            assert!((w0.pmf()[j] - fam[0].pmf()[j]).abs() < 1e-9, "seed {seed}: w=0 moved");
            assert!((w1.pmf()[j] - fam[1].pmf()[j]).abs() < 1e-9, "seed {seed}: w=1 kept");
        }
    }
}

#[test]
fn prop_hist_normal_recovery() {
    // regression pin: the modeler's priors rely on Hist::normal recovering
    // the requested moments even on a coarse grid
    let grid = Grid::uniform(0.0, 20.0, 32);
    for seed in 0..20u64 {
        let mut rng = Rng::new(0xFACE + seed);
        let mean = rng.range_f64(4.0, 16.0);
        let std = rng.range_f64(0.8, 3.0);
        let h = Hist::normal(&grid, mean, std);
        assert!(
            (h.mean() - mean).abs() < grid.step(),
            "seed {seed}: mean {} vs {mean}",
            h.mean()
        );
        assert!(
            (h.std() - std).abs() < grid.step(),
            "seed {seed}: std {} vs {std}",
            h.std()
        );
    }
}

#[test]
fn prop_proposition1_on_random_families() {
    let grid = Grid::uniform(0.0, 20.0, 64);
    for seed in 0..40u64 {
        let mut rng = Rng::new(0xD00D + seed);
        let n = rng.range_usize(2, 8);
        let fam = random_family(&mut rng, n, &grid);
        check_proposition1(&fam, 1e-9)
            .unwrap_or_else(|k| panic!("seed {seed}: Prop 1 violated at k={k}"));
    }
}

#[test]
fn prop_scorer_backends_agree_on_random_batches() {
    use pingan::runtime::{CpuScorer, ScoreBatch, Scorer};
    // CPU scorer vs dist::Hist on random batches (HLO covered in lib tests)
    for seed in 0..10u64 {
        let mut rng = Rng::new(0xF00 + seed);
        let (b, k, v) = (
            rng.range_usize(1, 4),
            rng.range_usize(1, 5),
            rng.range_usize(8, 64),
        );
        let mut batch = ScoreBatch::new(b, k, v);
        batch.values = (0..v).map(|i| i as f64 * 0.25).collect();
        for x in batch.proc_pmf.iter_mut().chain(batch.trans_pmf.iter_mut()) {
            *x = rng.f64() + 1e-3;
        }
        for bi in 0..b {
            for ki in 0..k {
                let base = (bi * k + ki) * v;
                for pmf in [&mut batch.proc_pmf, &mut batch.trans_pmf] {
                    let s: f64 = pmf[base..base + v].iter().sum();
                    pmf[base..base + v].iter_mut().for_each(|e| *e /= s);
                }
            }
        }
        let out = CpuScorer.score(&batch).unwrap();
        assert_eq!(out.len(), b * k);
        let vmax = batch.values[v - 1];
        for (i, r) in out.iter().enumerate() {
            assert!(
                *r >= -1e-9 && *r <= vmax + 1e-9,
                "seed {seed} idx {i}: rate {r} outside [0, {vmax}]"
            );
        }
    }
}

#[test]
fn prop_sharded_scoring_is_bit_identical_to_serial() {
    // the intra-cell-parallelism invariant: sharding a batch's rows across
    // any number of scoring threads must reproduce the serial CpuScorer
    // output EXACTLY (f64 bit equality, not tolerance) — under random
    // batch sizes B, candidate counts K, grid resolutions V, proc-only
    // flags and shard boundaries (random thread counts, including more
    // threads than rows).
    use pingan::runtime::{scorer, CpuScorer, RowInput, ScoreBatch, Scorer};
    for seed in 0..12u64 {
        let mut rng = Rng::new(0x5AAD + seed);
        let b = rng.range_usize(1, 40);
        let k = rng.range_usize(1, 8);
        let v = rng.range_usize(8, 48);
        let values: Vec<f64> = (0..v).map(|i| i as f64 * 0.25).collect();
        // owned per-row storage the RowInputs borrow from
        let rows_data: Vec<(Vec<f64>, Vec<f64>, bool, Vec<f64>)> = (0..b)
            .map(|_| {
                let norm = |rng: &mut Rng| -> Vec<f64> {
                    let mut x: Vec<f64> = (0..v).map(|_| rng.f64() + 1e-3).collect();
                    let s: f64 = x.iter().sum();
                    x.iter_mut().for_each(|e| *e /= s);
                    x
                };
                let proc: Vec<f64> = (0..k).flat_map(|_| norm(&mut rng)).collect();
                let trans: Vec<f64> = (0..k).flat_map(|_| norm(&mut rng)).collect();
                let proc_only = rng.chance(0.3);
                let pmf = norm(&mut rng);
                let mut cdf = Vec::with_capacity(v);
                let mut acc = 0.0f64;
                for &p in &pmf {
                    acc += p;
                    cdf.push(acc.min(1.0));
                }
                (proc, trans, proc_only, cdf)
            })
            .collect();
        let rows: Vec<RowInput<'_>> = rows_data
            .iter()
            .map(|(proc, trans, proc_only, cdf)| RowInput {
                proc,
                trans,
                proc_only: *proc_only,
                existing_cdf: cdf,
            })
            .collect();
        // serial reference: one monolithic batch through fill_row
        let mut big = ScoreBatch::new(b, k, v);
        big.values.copy_from_slice(&values);
        for (bi, r) in rows.iter().enumerate() {
            scorer::fill_row(&mut big, bi, r.proc, r.trans, r.proc_only, r.existing_cdf);
        }
        let serial = CpuScorer.score(&big).unwrap();
        let mut scratch: Vec<ScoreBatch> = Vec::new();
        for threads in [1usize, 2, rng.range_usize(2, 7), b, b + 5] {
            let got =
                scorer::score_rows_sharded(&CpuScorer, k, v, &values, &rows, threads, &mut scratch)
                    .unwrap();
            assert_eq!(got.len(), serial.len(), "seed {seed} threads {threads}");
            for (i, (g, s)) in got.iter().zip(&serial).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    s.to_bits(),
                    "seed {seed} threads {threads} idx {i}: {g} vs {s}"
                );
            }
        }
        // shard boundaries themselves: cover 0..b contiguously in order
        let t = rng.range_usize(1, 9);
        let ranges = scorer::shard_ranges(b, t);
        let mut next = 0usize;
        for r in &ranges {
            assert_eq!(r.start, next, "seed {seed}: shard gap/overlap");
            next = r.end;
        }
        assert_eq!(next, b, "seed {seed}: shards dropped rows");
    }
}

#[test]
fn prop_batched_scorer_matches_scalar_scoring() {
    // the tentpole agreement property: for random tasks (sources, op,
    // existing copy set) the batched ScoreBatch/CpuScorer pipeline must
    // reproduce the scalar per-candidate `score_candidates_cached` path —
    // rates, solo rates and pro. The CPU kernel replays the Hist algebra's
    // accumulation order, so agreement is expected to the bit; asserted
    // here at 1e-12 relative to keep the property robust to refactors.
    use pingan::insurance::scoring;
    use pingan::perfmodel::PerfModel;
    use pingan::runtime::{scorer, CpuScorer, ScoreBatch, Scorer};
    use pingan::workload::job::OpKind;

    for seed in 0..8u64 {
        let mut rng = Rng::new(0xBA7C + seed);
        let n_clusters = rng.range_usize(4, 10);
        let sys = GeoSystem::generate(&SystemSpec::small(n_clusters), &mut rng);
        let pm = PerfModel::new(&sys, rng.range_usize(16, 64));
        let grid = pm.grid().clone();
        let v = grid.bins();
        let n = pm.n_clusters();
        let n_src = rng.range_usize(1, 3);
        let sources: Vec<usize> = (0..n_src).map(|_| rng.range_usize(0, n - 1)).collect();
        let op = *rng.choose(&OpKind::ALL);
        let n_exist = rng.range_usize(1, 3);
        let existing_clusters: Vec<usize> =
            (0..n_exist).map(|_| rng.range_usize(0, n - 1)).collect();
        let datasize = rng.range_f64(50.0, 2000.0);
        // the insurer's per-slot cache layout: solo hists + flat tensors
        let mut solo: Vec<(f64, Hist)> = Vec::with_capacity(n);
        let mut proc = vec![0.0f64; n * v];
        let mut trans = vec![0.0f64; n * v];
        for m in 0..n {
            let (p, t) = pm.rate_components(&sources, m, op);
            let t = t.expect("sources are non-empty");
            proc[m * v..(m + 1) * v].copy_from_slice(p.pmf());
            trans[m * v..(m + 1) * v].copy_from_slice(t.pmf());
            let h = p.min_compose(&t);
            solo.push((h.mean(), h));
        }
        let existing: Vec<Hist> = existing_clusters
            .iter()
            .map(|&m| solo[m].1.clone())
            .collect();
        let all: Vec<usize> = (0..n).collect();
        let scalar = scoring::score_candidates_cached(
            &pm,
            datasize,
            &solo,
            &existing,
            &existing_clusters,
            &all,
        );
        // batched: existing-CDF product once, one kernel run, assembly
        let refs: Vec<&Hist> = existing.iter().collect();
        let (cdf, current_rate) = scoring::existing_cdf_and_rate(&refs, grid.values());
        let want_current = Hist::expected_max(&refs);
        assert_eq!(
            current_rate.to_bits(),
            want_current.to_bits(),
            "seed {seed}: current-rate byproduct drifted"
        );
        let mut batch = ScoreBatch::new(1, n, v);
        batch.values.copy_from_slice(grid.values());
        scorer::fill_row(&mut batch, 0, &proc, &trans, false, &cdf);
        let rates = CpuScorer.score(&batch).unwrap();
        for m in 0..n {
            let got = scoring::assemble_score(
                &pm,
                &existing_clusters,
                m,
                datasize,
                solo[m].0,
                Some(rates[m]),
            );
            let want = &scalar[m];
            assert_eq!(got.cluster, want.cluster);
            assert_eq!(
                got.solo_rate.to_bits(),
                want.solo_rate.to_bits(),
                "seed {seed} m={m}: solo rate"
            );
            assert!(
                (got.rate - want.rate).abs() <= 1e-12 * want.rate.abs().max(1.0),
                "seed {seed} m={m}: rate {} vs scalar {}",
                got.rate,
                want.rate
            );
            assert!(
                (got.pro - want.pro).abs() <= 1e-12,
                "seed {seed} m={m}: pro {} vs scalar {}",
                got.pro,
                want.pro
            );
        }
    }
}
