//! Geo-cluster topology generation (BRITE substitute).
//!
//! The paper builds 100 clusters with the BRITE topology generator under a
//! heavy-tailed degree distribution, sorts clusters by degree and calls the
//! top 5% large-scale, the next 20% medium and the remaining 75% small
//! (Sec 6.1). We reproduce that with Barabási–Albert preferential attachment
//! (the construction BRITE's heavy-tailed mode implements), then derive
//! per-pair WAN distance as shortest-path hop count.

pub mod brite;

pub use brite::{ClusterScale, Topology};
