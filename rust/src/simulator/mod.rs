//! Discrete-event simulator of the geo-distributed plant (the CloudSim
//! substitute — Sec 6.1), with a dual-mode time core.
//!
//! Semantics follow Sec 3.2/3.3:
//! * a copy of task ξ launched in cluster m runs at
//!   `min(V^P_m, mean over sources of V^T_{src,m})`, both drawn from the
//!   cluster's ground-truth distributions at launch;
//! * per-slot Bernoulli cluster-level unreachability kills every copy in
//!   the afflicted cluster;
//! * slot capacity M_k and gate bandwidths Ing_k / Eg_k (Eqs. 9–11) are
//!   enforced by the engine regardless of what a policy requests;
//! * a task completes when its fastest alive copy has processed D_l^i;
//!   sibling copies cancel and free their slots; completions propagate
//!   readiness through the DAG (Eq. 8) and the last task completes the job.
//!
//! ## Shard/barrier architecture
//!
//! Per-cluster plant state is *sharded*: [`shard::EngineShard`] owns a
//! contiguous cluster range — failure gaps, slot/ingress/egress ledgers
//! and AR(1) congestion chains — and advances independently between policy
//! epochs. The engine syncs the shard set ([`shard::EngineShards`]) at a
//! deterministic barrier (`std::thread::scope` + shard-order merge) before
//! every scheduler invocation; `SchedView::over_shards` then presents the
//! unchanged logical per-cluster view to PingAn and every baseline.
//!
//! **Determinism contract.** Action streams are bit-identical at any
//! [`SimConfig::engine_threads`] value, at both time cores, because
//! (a) every cluster-local draw comes from that cluster's own RNG stream
//! (`shard::cluster_rng`, a pure function of `(seed, cluster)` — the shard
//! partition cannot reorder a stream), (b) shard boundaries and every
//! cross-shard merge are pure functions of `(n_clusters, engine_threads)`
//! resp. fixed cluster order, and (c) launch-time draws stay on the
//! engine's single global stream in the serial policy-application phase.
//! Thread spawning is therefore a pure wall-time heuristic; the
//! determinism suite (`tests/end_to_end.rs`, `tests/sweep_determinism.rs`)
//! pins it.
//!
//! **Barrier-only re-rate (shared bandwidth model).** Under
//! [`BandwidthModel::Shared`] every copy with remote inputs is an active
//! transfer in a max-min fair-share solver over cluster ingress/egress
//! gates and per-pair WAN links ([`bandwidth`]). A shared WAN link
//! couples transfers homed in *different* shards, so no shard ever
//! re-rates during an advance: all solver operations (transfer
//! start/finish at launch/teardown, and the one global rate application
//! per policy epoch — `Simulation::apply_rerates`) run in the serial
//! phase at the epoch barrier. Shard advances stay exactly the
//! constant-model ones, which is what keeps Action streams bit-identical
//! at any `engine_threads` under `shared` too (the determinism note
//! above applies unchanged: the solver touches no RNG, and its B-tree
//! iteration order is independent of thread count). A re-rate
//! checkpoints each affected copy into a fresh closed-form progress
//! segment ([`state::CopyRt::completion_slot`]) and, under the
//! event-skip core, bumps the affected tasks' copy-set epochs so their
//! predicted completions re-queue.
//!
//! ## Module layout
//!
//! * [`engine`] — thin orchestration: [`Simulation`] runs either time
//!   core, selected by [`SimConfig::time_model`] ([`TimeModel::Dense`] =
//!   the slotted reference loop, bit-reproducible; [`TimeModel::EventSkip`]
//!   = jump-to-next-event). [`SimConfig::score_threads`] is the policy's
//!   intra-cell scoring budget (via `SchedView::score_threads`);
//!   [`SimConfig::engine_threads`] is the plant's shard budget — both are
//!   pure wall-time knobs with bit-identical outputs at any value.
//! * [`shard`] — the sharded plant state and its deterministic barrier.
//! * [`events`] — the `BinaryHeap` event queues (`Arrival`,
//!   `CopyCompletion`, `ClusterFailure`, `PolicyEpoch`) with deterministic
//!   tie-breaking in the dense engine's within-slot phase order; the
//!   sharded layout routes cluster-local events to per-shard queues under
//!   a global epoch heap ([`events::ShardedEventQueue`]).
//! * [`processes`] — the per-slot stochastic processes in skippable form:
//!   geometric inter-failure gaps (same marginal Bernoulli-per-slot
//!   process) and exact k-step AR(1) congestion transitions, per-cluster
//!   ([`processes::ar1_step`]) for the shard streams.
//! * [`state`] — runtime job/task/copy state shared by both cores.
//! * [`bandwidth`] — the max-min fair-share solver (two proptest-pinned
//!   bit-identical backends: progressive-filling reference and the
//!   incremental O(log n)-maintenance solver the engine uses).

pub mod bandwidth;
pub mod engine;
pub mod events;
pub mod processes;
pub mod shard;
pub mod state;

pub use crate::config::spec::{BandwidthModel, TimeModel};
pub use engine::{SimConfig, SimResult, Simulation};
pub use events::{Event, EventQueue, ShardedEventQueue};
pub use shard::{EngineShard, EngineShards};
pub use state::{CopyRt, JobRt, TaskRt, TaskState};
