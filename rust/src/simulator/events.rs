//! The event queue behind the event-skip time core: a `BinaryHeap` of
//! timestamped [`Event`]s with fully deterministic ordering.
//!
//! Events at the same slot drain in the dense engine's within-slot phase
//! order — arrivals, then cluster failures, then copy completions, then
//! policy wakes — and ties inside a phase break on the event's own indices
//! and finally on insertion order, so two runs of the same seed pop the
//! exact same sequence regardless of heap internals. (Note: the *policy
//! epoch* itself runs after the slot's completions are applied, so a
//! scheduler at event-time t sees what the dense scheduler would first
//! see at t+1 — see `engine::run_events`.)

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One schedulable occurrence. `CopyCompletion` carries the task's copy-set
/// epoch at push time: any change to the copy set bumps the epoch and
/// re-pushes, so stale predictions are skipped on pop instead of searched
/// for and removed. (A fair-share re-rate under the shared bandwidth
/// model invalidates through the same epoch bump — a re-rated copy's
/// closed-form completion moves, so the task's queued prediction goes
/// stale exactly like on a copy start or kill.)
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// A job reaches its arrival slot.
    Arrival { job: usize },
    /// Cluster `cluster`'s sampled geometric failure gap elapses.
    ClusterFailure { cluster: usize },
    /// Task (`job`, `task`)'s fastest alive copy finishes its datasize.
    CopyCompletion { job: usize, task: usize, epoch: u64 },
    /// A scheduler-requested wake ([`crate::sched::Scheduler::next_wake`]).
    PolicyEpoch,
}

impl Event {
    /// Stable name of the event's type, for telemetry labels and trace
    /// logging (`pingan --log-level pingan::simulator=trace`). Counters
    /// keyed by this never touch RNG state — Plane A of [`crate::obs`].
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Arrival { .. } => "arrival",
            Event::ClusterFailure { .. } => "cluster-failure",
            Event::CopyCompletion { .. } => "copy-completion",
            Event::PolicyEpoch => "policy-epoch",
        }
    }

    /// Within-slot phase rank (the dense engine's step order).
    fn rank(&self) -> u8 {
        match self {
            Event::Arrival { .. } => 0,
            Event::ClusterFailure { .. } => 1,
            Event::CopyCompletion { .. } => 2,
            Event::PolicyEpoch => 3,
        }
    }

    /// Intra-phase tie-break indices.
    fn keys(&self) -> (usize, usize, u64) {
        match *self {
            Event::Arrival { job } => (job, 0, 0),
            Event::ClusterFailure { cluster } => (cluster, 0, 0),
            Event::CopyCompletion { job, task, epoch } => (job, task, epoch),
            Event::PolicyEpoch => (0, 0, 0),
        }
    }
}

/// Full deterministic ordering key of a queued event: `(time, phase rank,
/// index a, index b, epoch, insertion seq)`. Every component except the
/// trailing per-queue `seq` is a pure function of the event's identity.
pub type EventKey = (u64, u8, usize, usize, u64, u64);

#[derive(Clone, Copy, Debug)]
struct Entry {
    time: u64,
    seq: u64,
    event: Event,
}

impl Entry {
    fn key(&self) -> EventKey {
        let (a, b, e) = self.event.keys();
        (self.time, self.event.rank(), a, b, e, self.seq)
    }
}

impl PartialEq for Entry {
    fn eq(&self, other: &Entry) -> bool {
        self.key() == other.key()
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    /// Reversed so `BinaryHeap` (a max-heap) pops the earliest entry.
    fn cmp(&self, other: &Entry) -> Ordering {
        other.key().cmp(&self.key())
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Entry) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-queue of future events.
pub struct EventQueue {
    heap: BinaryHeap<Entry>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute slot `time`.
    pub fn push(&mut self, time: u64, event: Event) {
        self.heap.push(Entry {
            time,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Earliest scheduled slot, if any.
    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Full ordering key of the head entry ([`ShardedEventQueue`] compares
    /// heads across queues with it).
    pub fn peek_key(&self) -> Option<EventKey> {
        self.heap.peek().map(|e| e.key())
    }

    /// Pop the next event *only* if it is scheduled exactly at `time` —
    /// the engine drains one slot's batch with `while let Some(ev) =
    /// queue.pop_at(t)`.
    pub fn pop_at(&mut self, time: u64) -> Option<Event> {
        if self.heap.peek().map(|e| e.time) == Some(time) {
            self.heap.pop().map(|e| e.event)
        } else {
            None
        }
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

/// The sharded engine's queue layout: cluster-local events
/// ([`Event::ClusterFailure`]) route to the owning shard's queue; global
/// events — arrivals, copy completions, policy epochs — live on a shared
/// epoch heap. Pops compare head *keys* across all queues and take the
/// minimum, so the drain order is identical to one flat [`EventQueue`]:
/// keys differ at worst in the per-queue `seq`, and two entries with equal
/// `(time, rank, a, b, epoch)` necessarily describe the same event
/// identity, which always routes to the same queue — cross-queue ties are
/// impossible by construction, so per-queue seq counters never have to be
/// compared against each other.
pub struct ShardedEventQueue {
    global: EventQueue,
    shards: Vec<EventQueue>,
    /// Global cluster index → owning shard queue.
    owner: Vec<usize>,
}

impl ShardedEventQueue {
    /// `owner[m]` is the shard index of cluster `m` (see
    /// `EngineShards::owner_table`); `n_shards` queues are created.
    pub fn new(owner: &[usize], n_shards: usize) -> ShardedEventQueue {
        ShardedEventQueue {
            global: EventQueue::new(),
            shards: (0..n_shards.max(1)).map(|_| EventQueue::new()).collect(),
            owner: owner.to_vec(),
        }
    }

    pub fn len(&self) -> usize {
        self.global.len() + self.shards.iter().map(|q| q.len()).sum::<usize>()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Schedule `event` at absolute slot `time`, routed by event identity.
    pub fn push(&mut self, time: u64, event: Event) {
        match event {
            Event::ClusterFailure { cluster } => {
                self.shards[self.owner[cluster]].push(time, event)
            }
            _ => self.global.push(time, event),
        }
    }

    /// Earliest scheduled slot across every queue, if any.
    pub fn peek_time(&self) -> Option<u64> {
        let mut min: Option<u64> = self.global.peek_time();
        for q in &self.shards {
            min = match (min, q.peek_time()) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, b) => a.or(b),
            };
        }
        min
    }

    /// Pop the globally next event *only* if it is scheduled exactly at
    /// `time` — same contract as [`EventQueue::pop_at`], same drain order.
    pub fn pop_at(&mut self, time: u64) -> Option<Event> {
        let mut best: Option<(EventKey, usize)> = None;
        // queue 0 = global, 1 + si = shard si; scanned in fixed order so a
        // (provably impossible) full-key tie would still break the same way
        for (qi, q) in std::iter::once(&self.global).chain(self.shards.iter()).enumerate() {
            if let Some(k) = q.peek_key() {
                if best.map(|(bk, _)| k < bk).unwrap_or(true) {
                    best = Some((k, qi));
                }
            }
        }
        match best {
            Some(((t, ..), qi)) if t == time => {
                let q = if qi == 0 {
                    &mut self.global
                } else {
                    &mut self.shards[qi - 1]
                };
                q.pop_at(time)
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_are_distinct() {
        let evs = [
            Event::Arrival { job: 0 },
            Event::ClusterFailure { cluster: 0 },
            Event::CopyCompletion { job: 0, task: 0, epoch: 0 },
            Event::PolicyEpoch,
        ];
        let mut names: Vec<_> = evs.iter().map(|e| e.kind()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), evs.len());
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(9, Event::PolicyEpoch);
        q.push(3, Event::Arrival { job: 1 });
        q.push(7, Event::ClusterFailure { cluster: 0 });
        assert_eq!(q.peek_time(), Some(3));
        assert_eq!(q.pop_at(3), Some(Event::Arrival { job: 1 }));
        assert_eq!(q.pop_at(3), None, "nothing else at slot 3");
        assert_eq!(q.peek_time(), Some(7));
    }

    #[test]
    fn same_slot_drains_in_dense_phase_order() {
        let mut q = EventQueue::new();
        q.push(5, Event::PolicyEpoch);
        q.push(
            5,
            Event::CopyCompletion {
                job: 0,
                task: 2,
                epoch: 1,
            },
        );
        q.push(5, Event::ClusterFailure { cluster: 3 });
        q.push(5, Event::Arrival { job: 4 });
        assert_eq!(q.pop_at(5), Some(Event::Arrival { job: 4 }));
        assert_eq!(q.pop_at(5), Some(Event::ClusterFailure { cluster: 3 }));
        assert_eq!(
            q.pop_at(5),
            Some(Event::CopyCompletion {
                job: 0,
                task: 2,
                epoch: 1
            })
        );
        assert_eq!(q.pop_at(5), Some(Event::PolicyEpoch));
        assert!(q.is_empty());
    }

    #[test]
    fn intra_phase_ties_break_on_indices_then_insertion() {
        let mut q = EventQueue::new();
        q.push(2, Event::Arrival { job: 7 });
        q.push(2, Event::Arrival { job: 1 });
        q.push(2, Event::Arrival { job: 1 }); // duplicate: insertion order
        assert_eq!(q.pop_at(2), Some(Event::Arrival { job: 1 }));
        assert_eq!(q.pop_at(2), Some(Event::Arrival { job: 1 }));
        assert_eq!(q.pop_at(2), Some(Event::Arrival { job: 7 }));
    }

    #[test]
    fn sharded_queue_drains_like_a_flat_queue() {
        // same push sequence into a flat queue and sharded layouts of 1, 2
        // and 3 shard queues: the pop sequences must be identical
        let evs = [
            (4, Event::CopyCompletion { job: 1, task: 0, epoch: 2 }),
            (4, Event::ClusterFailure { cluster: 5 }),
            (4, Event::Arrival { job: 0 }),
            (1, Event::PolicyEpoch),
            (4, Event::ClusterFailure { cluster: 0 }),
            (1, Event::ClusterFailure { cluster: 3 }),
            (4, Event::ClusterFailure { cluster: 0 }), // dup: insertion order
            (7, Event::Arrival { job: 2 }),
        ];
        // 6 clusters; owner tables for 1, 2, 3 shards
        let owners: [Vec<usize>; 3] = [
            vec![0; 6],
            vec![0, 0, 0, 1, 1, 1],
            vec![0, 0, 1, 1, 2, 2],
        ];
        for owner in &owners {
            let n_shards = owner.iter().max().unwrap() + 1;
            let mut flat = EventQueue::new();
            let mut sharded = ShardedEventQueue::new(owner, n_shards);
            for &(t, e) in &evs {
                flat.push(t, e);
                sharded.push(t, e);
            }
            assert_eq!(sharded.len(), evs.len());
            while let Some(t) = flat.peek_time() {
                assert_eq!(sharded.peek_time(), Some(t), "{n_shards} shards");
                loop {
                    let a = flat.pop_at(t);
                    let b = sharded.pop_at(t);
                    assert_eq!(a, b, "{n_shards} shards at t={t}");
                    if a.is_none() {
                        break;
                    }
                }
            }
            assert!(sharded.is_empty(), "{n_shards} shards left events behind");
        }
    }

    #[test]
    fn ordering_is_deterministic_across_interleavings() {
        // two different push orders, same pop sequence
        let evs = [
            (4, Event::CopyCompletion { job: 1, task: 0, epoch: 2 }),
            (4, Event::Arrival { job: 0 }),
            (1, Event::PolicyEpoch),
            (4, Event::ClusterFailure { cluster: 2 }),
        ];
        let mut a = EventQueue::new();
        for &(t, e) in &evs {
            a.push(t, e);
        }
        let mut b = EventQueue::new();
        for &(t, e) in evs.iter().rev() {
            b.push(t, e);
        }
        for _ in 0..evs.len() {
            let t = a.peek_time().unwrap();
            assert_eq!(b.peek_time(), Some(t));
            assert_eq!(a.pop_at(t), b.pop_at(t));
        }
    }
}
