//! Declarative scenario specs: a [`Scenario`] fully describes one
//! simulation cell (plant + workload + policy + replica); a [`SweepSpec`]
//! is a base scenario plus named [`Axis`] value lists, expanded
//! deterministically into the cell grid.
//!
//! ## Seeding discipline
//!
//! Every cell derives its seeds from `(base_seed, environment fields,
//! rep)` via [`Scenario::env_seed`]. Two properties follow:
//!
//! * **Thread-count invariance** — a cell's seed depends only on its own
//!   coordinates, never on execution order, so the parallel runner
//!   produces bit-identical results at any worker count (including 1).
//! * **Paired comparisons** — *policy* fields (scheduler, ε, principle,
//!   allocation) are deliberately excluded from the seed, so every policy
//!   variant at the same (λ, plant, mix, rep) coordinates faces the
//!   identical plant and job set. Per-job reduction ratios (Fig 5) and
//!   best-baseline deltas (Fig 4) are only meaningful under this pairing.

use super::axis::{Axis, WorkloadMix};
use crate::baselines::{Dolly, Flutter, Iridium, Mantri, Spark, SpeculativeSpark};
use crate::cluster::GeoSystem;
use crate::config::spec::{
    Allocation, BandwidthModel, PingAnSpec, Principle, ScorerKind, SystemSpec, TimeModel,
    WorkloadSpec,
};
use crate::config::toml::Doc;
use crate::insurance::PingAn;
use crate::sched::Scheduler;
use crate::simulator::{SimConfig, SimResult, Simulation};
use crate::util::rng::{Rng, SplitMix64};
use crate::workload::job::JobSpec;
use crate::workload::testbed::TestbedSpec;
use crate::workload::{montage, testbed};

/// Scheduler factory shared by the sweep runner and the CLI. Unlike the
/// panicking `experiments::make_scheduler`, this returns an error the
/// runner can record per cell.
pub fn make_scheduler(
    name: &str,
    epsilon: f64,
    principle: Principle,
    allocation: Allocation,
    scorer: ScorerKind,
) -> Result<Box<dyn Scheduler>, String> {
    Ok(match name {
        "pingan" => {
            let mut spec = PingAnSpec::with_epsilon(epsilon);
            spec.principle = principle;
            spec.allocation = allocation;
            spec.scorer = scorer;
            Box::new(PingAn::try_new(spec)?)
        }
        "spark" => Box::new(Spark::new()),
        "spark-spec" => Box::new(SpeculativeSpark::new()),
        "flutter" => Box::new(Flutter::new()),
        "iridium" => Box::new(Iridium::new()),
        "flutter+mantri" => Box::new(Mantri::new()),
        "flutter+dolly" => Box::new(Dolly::new()),
        other => return Err(format!("unknown scheduler `{other}`")),
    })
}

/// All scheduler names [`make_scheduler`] accepts.
pub const SCHEDULERS: [&str; 7] = [
    "pingan",
    "spark",
    "spark-spec",
    "flutter",
    "iridium",
    "flutter+mantri",
    "flutter+dolly",
];

/// One fully-resolved sweep cell.
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    /// Policy under test (see [`SCHEDULERS`]).
    pub scheduler: String,
    /// Arrival rate λ at paper scale; divided by `slot_divisor` when the
    /// plant is shrunk, so offered load per slot matches the paper's.
    pub lambda: f64,
    /// Insurance aggressiveness ε (PingAn only; ignored by baselines).
    pub epsilon: f64,
    /// Insuring-principle variant (PingAn only).
    pub principle: Principle,
    /// Round-1 allocation discipline (PingAn only).
    pub allocation: Allocation,
    /// Scoring backend for the insurer's batched hot path (PingAn only).
    pub scorer: ScorerKind,
    /// Simulator time core (dense reference vs event-skip). A *runner*
    /// knob like `scorer`: excluded from the cell seed so dense and
    /// event-skip cells at the same coordinates face the identical plant
    /// and job set (paired equivalence checks depend on that).
    pub time_model: TimeModel,
    /// Intra-cell scoring thread budget (`SimConfig::score_threads`).
    /// Another runner knob: excluded from the cell seed, and the cell's
    /// simulated outcome is bit-identical at any value — the determinism
    /// suite sweeps it as an axis to prove exactly that.
    pub score_threads: usize,
    /// Engine shard-thread budget (`SimConfig::engine_threads`). Same
    /// contract as `score_threads`: excluded from the cell seed AND from
    /// the cell label, because sweep JSON must be byte-identical at any
    /// value (the acceptance test diffs whole report strings).
    pub engine_threads: usize,
    /// WAN bandwidth model (`SimConfig::bandwidth_model`). An
    /// *environment* knob — `shared` changes simulated outcomes — but
    /// deliberately excluded from the cell seed so a `shared` cell and
    /// its `constant` twin at the same coordinates face the identical
    /// plant and job set: contention comparisons (shared mean flowtime ≥
    /// constant) are only meaningful under that pairing. Tagged in the
    /// cell label when non-default.
    pub bandwidth_model: BandwidthModel,
    /// Replay an external arrival trace (CSV/JSONL,
    /// [`crate::workload::TraceSource`]) instead of generating the job
    /// set. The trace supplies ids/arrivals (and optionally task counts /
    /// datasizes); DAG bodies are drawn from the cell's workload spec
    /// under *per-job-id* seeding, so the same trace row always builds
    /// the same job. Excluded from the cell seed — the plant stays paired
    /// with the generated-workload cells at the same coordinates.
    pub trace: Option<String>,
    /// Run the cell with `SimConfig::stream_metrics`: drop the per-job
    /// flowtime `Vec`, keep the [`crate::metrics::FlowStats`] sketch, and
    /// recycle engine slab slots — O(clusters + alive jobs) memory. The
    /// sketch itself is bit-identical either way, so this is a runner
    /// knob (excluded from the cell seed), but it *is* tagged in the
    /// label: streamed rows report sketch quantiles, not exact ones.
    pub stream_metrics: bool,
    pub n_clusters: usize,
    pub n_jobs: usize,
    /// Shrink per-cluster VM counts by this divisor (keeps load comparable
    /// at reduced reproduction scale).
    pub slot_divisor: u64,
    /// Multiplier on every class's Table-2 unreachability range.
    pub failure_scale: f64,
    pub mix: WorkloadMix,
    /// Replica index (the paper averages ten repetitions per setting).
    pub rep: u64,
}

impl Default for Scenario {
    /// Matches `experiments::Scale::default_repro()`.
    fn default() -> Scenario {
        Scenario {
            scheduler: "pingan".to_string(),
            lambda: 0.07,
            epsilon: 0.6,
            principle: Principle::EffReli,
            allocation: Allocation::Efa,
            scorer: ScorerKind::Cpu,
            time_model: TimeModel::Dense,
            score_threads: crate::config::spec::default_score_threads(),
            engine_threads: crate::config::spec::default_engine_threads(),
            bandwidth_model: crate::config::spec::default_bandwidth_model(),
            trace: None,
            stream_metrics: crate::config::spec::default_stream_metrics(),
            n_clusters: 30,
            n_jobs: 160,
            slot_divisor: 4,
            failure_scale: 1.0,
            mix: WorkloadMix::Montage,
            rep: 0,
        }
    }
}

/// One mixing round of the seed chain (SplitMix64 over field bits).
fn hash2(a: u64, b: u64) -> u64 {
    SplitMix64::new(a ^ b.wrapping_mul(0x9E37_79B9_7F4A_7C15)).next_u64()
}

impl Scenario {
    /// The cell's environment seed: a hash of the base seed and every
    /// *environment* field plus the replica index. Policy fields
    /// (scheduler, ε, principle, allocation) are excluded on purpose —
    /// see the module docs on paired comparisons.
    pub fn env_seed(&self, base_seed: u64) -> u64 {
        let mut h = hash2(0x5EED_CE11, base_seed);
        for x in [
            self.lambda.to_bits(),
            self.n_clusters as u64,
            self.n_jobs as u64,
            self.slot_divisor,
            self.failure_scale.to_bits(),
            self.mix.id(),
            self.rep,
        ] {
            h = hash2(h, x);
        }
        h
    }

    /// The Table-2 plant spec this cell generates from: cluster count,
    /// slot shrink, and the failure-scale multiplier applied to every
    /// class's unreachability range.
    pub fn system_spec(&self, seed: u64) -> SystemSpec {
        let mut s = SystemSpec::default();
        s.n_clusters = self.n_clusters;
        s.seed = seed;
        if self.slot_divisor > 1 {
            for c in &mut s.classes {
                c.vm_count = (
                    (c.vm_count.0 / self.slot_divisor).max(2),
                    (c.vm_count.1 / self.slot_divisor).max(4),
                );
            }
        }
        if self.failure_scale != 1.0 {
            for c in &mut s.classes {
                c.unreach_p = (
                    (c.unreach_p.0 * self.failure_scale).min(0.9),
                    (c.unreach_p.1 * self.failure_scale).min(0.95),
                );
            }
        }
        s
    }

    /// Materialize the cell's environment: the geo plant and the job set.
    /// Deterministic in `(self, base_seed)`.
    pub fn build_env(&self, base_seed: u64) -> (GeoSystem, Vec<JobSpec>) {
        let seed = self.env_seed(base_seed);
        let mut rng = Rng::new(seed);
        let sys = GeoSystem::generate(&self.system_spec(seed), &mut rng);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let wseed = seed ^ 0xABCD;
        let jobs = match self.mix {
            WorkloadMix::Testbed => {
                let mut t = TestbedSpec::default();
                t.n_jobs = self.n_jobs;
                t.seed = wseed;
                let mut wrng = Rng::new(wseed);
                testbed::generate(&t, &sites, &mut wrng)
            }
            _ => {
                let effective_lambda = self.lambda / self.slot_divisor.max(1) as f64;
                let mut w = WorkloadSpec::scaled(self.n_jobs, effective_lambda);
                w.seed = wseed;
                self.mix.apply(&mut w);
                let mut wrng = Rng::new(wseed);
                montage::generate(&w, &sites, &mut wrng)
            }
        };
        (sys, jobs)
    }

    /// Materialize the cell's plant plus a streaming source over its
    /// external arrival trace. Plant generation is bit-identical to
    /// [`Scenario::build_env`] (same seed chain), and the workload spec
    /// shaping the per-row DAGs is the same one the generated path would
    /// use — a trace cell differs from its generated twin only in where
    /// ids/arrivals come from.
    pub fn build_trace_source(
        &self,
        base_seed: u64,
        path: &str,
    ) -> Result<(GeoSystem, crate::workload::TraceSource), String> {
        let seed = self.env_seed(base_seed);
        let mut rng = Rng::new(seed);
        let sys = GeoSystem::generate(&self.system_spec(seed), &mut rng);
        let sites: Vec<usize> = (0..sys.n()).collect();
        let wseed = seed ^ 0xABCD;
        let effective_lambda = self.lambda / self.slot_divisor.max(1) as f64;
        let mut w = WorkloadSpec::scaled(self.n_jobs, effective_lambda);
        w.seed = wseed;
        self.mix.apply(&mut w);
        let src = crate::workload::TraceSource::open(path, w, sites, wseed)
            .map_err(|e| format!("trace `{path}`: {e}"))?;
        Ok((sys, src))
    }

    /// Build this cell's scheduler.
    pub fn make_scheduler(&self) -> Result<Box<dyn Scheduler>, String> {
        make_scheduler(
            &self.scheduler,
            self.epsilon,
            self.principle,
            self.allocation,
            self.scorer,
        )
    }

    /// Run the cell sequentially: one plant, one job set, one policy, one
    /// `Simulation::run`. The parallel runner calls exactly this per cell,
    /// so a sweep is equivalent to this loop in grid order.
    pub fn run(&self, base_seed: u64) -> Result<SimResult, String> {
        self.run_traced(base_seed, None)
    }

    /// [`Scenario::run`] with an optional decision-trace sink attached to
    /// the scheduler before the run (`Scheduler::set_trace`; schedulers
    /// without a trace hook silently ignore it). Attaching a sink cannot
    /// change the simulated outcome — the sink only observes decisions
    /// already made.
    pub fn run_traced(
        &self,
        base_seed: u64,
        trace: Option<&crate::obs::TraceSink>,
    ) -> Result<SimResult, String> {
        let mut cfg = SimConfig::default();
        cfg.seed = self.env_seed(base_seed) ^ 0xC0FFEE;
        cfg.time_model = self.time_model;
        cfg.score_threads = self.score_threads.max(1);
        cfg.engine_threads = self.engine_threads.max(1);
        cfg.bandwidth_model = self.bandwidth_model;
        cfg.stream_metrics = self.stream_metrics;
        let mut sched = self.make_scheduler()?;
        if let Some(sink) = trace {
            sched.set_trace(sink.clone());
        }
        if let Some(path) = self.trace.clone() {
            let (sys, source) = self.build_trace_source(base_seed, &path)?;
            Ok(Simulation::from_source(&sys, source, cfg).run(sched.as_mut()))
        } else {
            let (sys, jobs) = self.build_env(base_seed);
            Ok(Simulation::new(&sys, jobs, cfg).run(sched.as_mut()))
        }
    }

    /// The cell's scenario group: every field but the replica index.
    /// Cells sharing a group aggregate into one report row.
    pub fn group(&self) -> Scenario {
        let mut g = self.clone();
        g.rep = 0;
        g
    }

    /// Compact human-readable cell label for progress lines and reports.
    /// The scorer backend and time model are tagged only when they differ
    /// from the defaults so existing report shapes stay unchanged.
    /// `engine_threads` is deliberately *never* tagged: cell labels land
    /// in report JSON, and sweep output must stay byte-identical at any
    /// engine shard count.
    pub fn label(&self) -> String {
        let scorer_tag = match self.scorer {
            ScorerKind::Cpu => String::new(),
            other => format!(" scorer={}", other.name()),
        };
        let time_tag = match self.time_model {
            TimeModel::Dense => String::new(),
            other => format!(" time={}", other.name()),
        };
        let threads_tag = if self.score_threads != 1 {
            format!(" threads={}", self.score_threads)
        } else {
            String::new()
        };
        let bw_tag = match self.bandwidth_model {
            BandwidthModel::Constant => String::new(),
            other => format!(" bw={}", other.name()),
        };
        // streamed rows report sketch quantiles, so the mode must be
        // visible wherever the row lands; traces likewise name their file
        let stream_tag = if self.stream_metrics {
            " stream-metrics"
        } else {
            ""
        };
        let trace_tag = self
            .trace
            .as_deref()
            .map(|p| format!(" trace={p}"))
            .unwrap_or_default();
        format!(
            "{} λ={} ε={} k={} fail×{} {} {}/{}{}{}{}{}{}{} rep={}",
            self.scheduler,
            self.lambda,
            self.epsilon,
            self.n_clusters,
            self.failure_scale,
            self.mix.name(),
            self.principle.name(),
            self.allocation.name(),
            scorer_tag,
            time_tag,
            threads_tag,
            bw_tag,
            stream_tag,
            trace_tag,
            self.rep
        )
    }
}

/// A declarative sweep: base scenario × axes × replicas.
#[derive(Clone, Debug, PartialEq)]
pub struct SweepSpec {
    /// Values every axis overrides; fields no axis names stay as-is.
    pub base: Scenario,
    /// Expanded row-major: first axis outermost, replicas innermost.
    pub axes: Vec<Axis>,
    /// Seed replicas per grid point.
    pub reps: u64,
    pub base_seed: u64,
}

impl SweepSpec {
    pub fn new(base: Scenario) -> SweepSpec {
        SweepSpec {
            base,
            axes: Vec::new(),
            reps: 1,
            base_seed: 0x5EED,
        }
    }

    /// Append an axis (builder style). Empty axes are rejected — they
    /// would silently produce an empty grid.
    pub fn axis(mut self, axis: Axis) -> SweepSpec {
        assert!(!axis.is_empty(), "axis `{}` has no values", axis.name());
        self.axes.push(axis);
        self
    }

    pub fn reps(mut self, reps: u64) -> SweepSpec {
        self.reps = reps.max(1);
        self
    }

    pub fn seed(mut self, base_seed: u64) -> SweepSpec {
        self.base_seed = base_seed;
        self
    }

    /// Total cell count: product of axis lengths × reps.
    pub fn n_cells(&self) -> usize {
        self.axes.iter().map(|a| a.len()).product::<usize>() * self.reps.max(1) as usize
    }

    /// Expand the grid. Deterministic: row-major over axes in declaration
    /// order (first axis outermost), replicas innermost.
    pub fn cells(&self) -> Vec<Scenario> {
        let dims: Vec<usize> = self.axes.iter().map(|a| a.len()).collect();
        let mut cells = Vec::with_capacity(self.n_cells());
        let mut idx = vec![0usize; dims.len()];
        'grid: loop {
            let mut point = self.base.clone();
            for (axis, &i) in self.axes.iter().zip(&idx) {
                axis.apply(i, &mut point);
            }
            for rep in 0..self.reps.max(1) {
                let mut cell = point.clone();
                cell.rep = rep;
                cells.push(cell);
            }
            // odometer increment, last axis fastest
            let mut k = dims.len();
            loop {
                if k == 0 {
                    break 'grid;
                }
                k -= 1;
                idx[k] += 1;
                if idx[k] < dims[k] {
                    break;
                }
                idx[k] = 0;
            }
        }
        cells
    }

    /// Build a sweep from a `[sweep]` TOML section (`config::toml`).
    ///
    /// Scalar keys override the base scenario (`scheduler`, `lambda`,
    /// `epsilon`, `clusters`, `jobs`, `slot_divisor`, `failure_scale`,
    /// `mix`, `scorer`, `time_model`, `score_threads`, `engine_threads`,
    /// `bandwidth_model`, `reps`, `seed`); array keys declare axes in a
    /// fixed order (`schedulers`, `lambdas`, `epsilons`, `cluster_counts`,
    /// `failure_scales`, `mixes`, `time_models`, `score_thread_counts`,
    /// `engine_thread_counts`, `bandwidth_models`).
    pub fn from_doc(doc: &Doc) -> Result<SweepSpec, String> {
        let mut base = Scenario::default();
        base.scheduler = doc.get_str("sweep.scheduler", &base.scheduler)?.to_string();
        base.lambda = doc.get_f64("sweep.lambda", base.lambda)?;
        base.epsilon = doc.get_f64("sweep.epsilon", base.epsilon)?;
        base.n_clusters = doc.get_usize("sweep.clusters", base.n_clusters)?;
        base.n_jobs = doc.get_usize("sweep.jobs", base.n_jobs)?;
        base.slot_divisor = doc.get_usize("sweep.slot_divisor", base.slot_divisor as usize)? as u64;
        base.failure_scale = doc.get_f64("sweep.failure_scale", base.failure_scale)?;
        base.mix = WorkloadMix::parse(doc.get_str("sweep.mix", base.mix.name())?)?;
        base.scorer = ScorerKind::parse(doc.get_str("sweep.scorer", base.scorer.name())?)?;
        base.time_model =
            TimeModel::parse(doc.get_str("sweep.time_model", base.time_model.name())?)?;
        base.score_threads = doc.get_usize("sweep.score_threads", base.score_threads)?.max(1);
        base.engine_threads = doc
            .get_usize("sweep.engine_threads", base.engine_threads)?
            .max(1);
        base.bandwidth_model = BandwidthModel::parse(
            doc.get_str("sweep.bandwidth_model", base.bandwidth_model.name())?,
        )?;
        let trace_path = doc.get_str("sweep.trace", "")?;
        if !trace_path.is_empty() {
            base.trace = Some(trace_path.to_string());
        }
        base.stream_metrics = doc.get_bool("sweep.stream_metrics", base.stream_metrics)?;
        let mut spec = SweepSpec::new(base);
        spec.reps = doc.get_usize("sweep.reps", 1)?.max(1) as u64;
        spec.base_seed = doc.get_usize("sweep.seed", spec.base_seed as usize)? as u64;
        if let Some(v) = doc.get_strs("sweep.schedulers")? {
            spec = spec.axis(Axis::Scheduler(v));
        }
        if let Some(v) = doc.get_f64s("sweep.lambdas")? {
            spec = spec.axis(Axis::Lambda(v));
        }
        if let Some(v) = doc.get_f64s("sweep.epsilons")? {
            spec = spec.axis(Axis::Epsilon(v));
        }
        if let Some(v) = doc.get_f64s("sweep.cluster_counts")? {
            spec = spec.axis(Axis::Clusters(v.iter().map(|&x| x as usize).collect()));
        }
        if let Some(v) = doc.get_f64s("sweep.failure_scales")? {
            spec = spec.axis(Axis::FailureScale(v));
        }
        if let Some(v) = doc.get_strs("sweep.mixes")? {
            let mixes: Result<Vec<WorkloadMix>, String> =
                v.iter().map(|s| WorkloadMix::parse(s)).collect();
            spec = spec.axis(Axis::Mix(mixes?));
        }
        if let Some(v) = doc.get_strs("sweep.time_models")? {
            let models: Result<Vec<TimeModel>, String> =
                v.iter().map(|s| TimeModel::parse(s)).collect();
            spec = spec.axis(Axis::TimeModel(models?));
        }
        if let Some(v) = doc.get_f64s("sweep.score_thread_counts")? {
            spec = spec.axis(Axis::ScoreThreads(
                v.iter().map(|&x| (x as usize).max(1)).collect(),
            ));
        }
        if let Some(v) = doc.get_f64s("sweep.engine_thread_counts")? {
            spec = spec.axis(Axis::EngineThreads(
                v.iter().map(|&x| (x as usize).max(1)).collect(),
            ));
        }
        if let Some(v) = doc.get_strs("sweep.bandwidth_models")? {
            let models: Result<Vec<BandwidthModel>, String> =
                v.iter().map(|s| BandwidthModel::parse(s)).collect();
            spec = spec.axis(Axis::BandwidthModel(models?));
        }
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scenario {
        let mut s = Scenario::default();
        s.n_clusters = 6;
        s.n_jobs = 8;
        s.slot_divisor = 10;
        s
    }

    #[test]
    fn grid_is_row_major_with_reps_innermost() {
        let spec = SweepSpec::new(tiny())
            .axis(Axis::Lambda(vec![0.02, 0.15]))
            .axis(Axis::Epsilon(vec![0.4, 0.8]))
            .reps(2);
        assert_eq!(spec.n_cells(), 8);
        let cells = spec.cells();
        assert_eq!(cells.len(), 8);
        assert_eq!((cells[0].lambda, cells[0].epsilon, cells[0].rep), (0.02, 0.4, 0));
        assert_eq!((cells[1].lambda, cells[1].epsilon, cells[1].rep), (0.02, 0.4, 1));
        assert_eq!((cells[2].lambda, cells[2].epsilon, cells[2].rep), (0.02, 0.8, 0));
        assert_eq!((cells[7].lambda, cells[7].epsilon, cells[7].rep), (0.15, 0.8, 1));
    }

    #[test]
    fn no_axes_yields_base_times_reps() {
        let spec = SweepSpec::new(tiny()).reps(3);
        let cells = spec.cells();
        assert_eq!(cells.len(), 3);
        assert_eq!(cells[2].rep, 2);
    }

    #[test]
    fn env_seed_ignores_policy_fields() {
        let base = tiny();
        let mut other = base.clone();
        other.scheduler = "flutter".to_string();
        other.epsilon = 0.2;
        other.principle = Principle::ReliReli;
        other.allocation = Allocation::Jga;
        other.scorer = ScorerKind::Scalar;
        other.time_model = TimeModel::EventSkip;
        other.score_threads = 4;
        other.engine_threads = 4;
        other.bandwidth_model = BandwidthModel::Shared;
        other.stream_metrics = true;
        other.trace = Some("examples/trace_small.csv".to_string());
        assert_eq!(base.env_seed(7), other.env_seed(7));
        let mut env = base.clone();
        env.lambda = 0.11;
        assert_ne!(base.env_seed(7), env.env_seed(7));
        let mut rep = base.clone();
        rep.rep = 1;
        assert_ne!(base.env_seed(7), rep.env_seed(7));
        assert_ne!(base.env_seed(7), base.env_seed(8));
    }

    #[test]
    fn policy_variants_share_the_environment() {
        let a = tiny();
        let mut b = a.clone();
        b.scheduler = "flutter".to_string();
        let (_, jobs_a) = a.build_env(42);
        let (_, jobs_b) = b.build_env(42);
        assert_eq!(jobs_a.len(), jobs_b.len());
        for (x, y) in jobs_a.iter().zip(&jobs_b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.tasks.len(), y.tasks.len());
        }
    }

    #[test]
    fn failure_scale_scales_every_class() {
        let mut s = tiny();
        s.failure_scale = 3.0;
        let spec = s.system_spec(1);
        let base = SystemSpec::default();
        for (c, b) in spec.classes.iter().zip(&base.classes) {
            assert!((c.unreach_p.0 - (b.unreach_p.0 * 3.0).min(0.9)).abs() < 1e-12);
            assert!(c.unreach_p.1 <= 0.95);
        }
    }

    #[test]
    fn factory_covers_all_names_and_rejects_bad_input() {
        for n in SCHEDULERS {
            let s =
                make_scheduler(n, 0.6, Principle::EffReli, Allocation::Efa, ScorerKind::Cpu)
                    .unwrap();
            assert!(!s.name().is_empty());
        }
        assert!(
            make_scheduler("nope", 0.6, Principle::EffReli, Allocation::Efa, ScorerKind::Cpu)
                .is_err()
        );
        // invalid ε is an error, not a panic — the runner records it
        assert!(
            make_scheduler("pingan", 1.5, Principle::EffReli, Allocation::Efa, ScorerKind::Cpu)
                .is_err()
        );
        // the scalar reference backend is constructible through the factory
        assert!(make_scheduler(
            "pingan",
            0.6,
            Principle::EffReli,
            Allocation::Efa,
            ScorerKind::Scalar
        )
        .is_ok());
    }

    #[test]
    fn from_doc_builds_axes_in_order() {
        let doc = Doc::parse(
            r#"
[sweep]
jobs = 12
reps = 2
seed = 99
schedulers = ["flutter", "pingan"]
lambdas = [0.02, 0.07]
epsilons = [0.4]
mixes = ["montage", "small-jobs"]
time_models = ["dense", "event-skip"]
score_thread_counts = [1, 4]
engine_thread_counts = [1, 4]
bandwidth_models = ["constant", "shared"]
"#,
        )
        .unwrap();
        let spec = SweepSpec::from_doc(&doc).unwrap();
        assert_eq!(spec.base.n_jobs, 12);
        assert_eq!(spec.reps, 2);
        assert_eq!(spec.base_seed, 99);
        assert_eq!(spec.axes.len(), 8);
        assert_eq!(spec.axes[0].name(), "scheduler");
        assert_eq!(spec.axes[4].name(), "time_model");
        assert_eq!(spec.axes[5].name(), "score_threads");
        assert_eq!(spec.axes[6].name(), "engine_threads");
        assert_eq!(spec.axes[7].name(), "bandwidth_model");
        assert_eq!(spec.n_cells(), 2 * 2 * 1 * 2 * 2 * 2 * 2 * 2 * 2);
        let bad = Doc::parse("[sweep]\nmixes = [\"nope\"]").unwrap();
        assert!(SweepSpec::from_doc(&bad).is_err());
        let bad_tm = Doc::parse("[sweep]\ntime_model = \"warp\"").unwrap();
        assert!(SweepSpec::from_doc(&bad_tm).is_err());
    }

    #[test]
    fn score_threads_scalar_key_and_label_tag() {
        let doc = Doc::parse("[sweep]\nscore_threads = 4").unwrap();
        let spec = SweepSpec::from_doc(&doc).unwrap();
        assert_eq!(spec.base.score_threads, 4);
        assert!(spec.base.label().contains("threads=4"));
        // a zero in the TOML degrades to serial
        let doc0 = Doc::parse("[sweep]\nscore_threads = 0").unwrap();
        assert_eq!(SweepSpec::from_doc(&doc0).unwrap().base.score_threads, 1);
        // sharded and serial cells at the same coordinates are bitwise
        // paired — the deeper pin lives in tests/sweep_determinism.rs
        let mut s = tiny();
        s.score_threads = 1;
        let serial = s.run(0xE1).unwrap();
        s.score_threads = 4;
        let sharded = s.run(0xE1).unwrap();
        assert_eq!(serial.finished_jobs, serial.total_jobs);
        assert_eq!(serial.copies_launched, sharded.copies_launched);
        for (a, b) in serial.flowtimes.iter().zip(&sharded.flowtimes) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn engine_threads_scalar_key_is_label_invisible_and_paired() {
        let doc = Doc::parse("[sweep]\nengine_threads = 4").unwrap();
        let spec = SweepSpec::from_doc(&doc).unwrap();
        assert_eq!(spec.base.engine_threads, 4);
        // the knob must never leak into the label (labels land in report
        // JSON, which is byte-diffed across shard counts)
        assert_eq!(spec.base.label(), Scenario::default().label());
        let doc0 = Doc::parse("[sweep]\nengine_threads = 0").unwrap();
        assert_eq!(SweepSpec::from_doc(&doc0).unwrap().base.engine_threads, 1);
        // serial vs sharded plant at the same coordinates: bitwise paired
        let mut s = tiny();
        s.engine_threads = 1;
        let serial = s.run(0xE2).unwrap();
        s.engine_threads = 4;
        let sharded = s.run(0xE2).unwrap();
        assert_eq!(serial.finished_jobs, serial.total_jobs);
        assert_eq!(serial.copies_launched, sharded.copies_launched);
        assert_eq!(serial.flowtimes.len(), sharded.flowtimes.len());
        for (a, b) in serial.flowtimes.iter().zip(&sharded.flowtimes) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bandwidth_model_key_pairs_shared_against_constant() {
        let doc = Doc::parse("[sweep]\nbandwidth_model = \"shared\"").unwrap();
        let spec = SweepSpec::from_doc(&doc).unwrap();
        assert_eq!(spec.base.bandwidth_model, BandwidthModel::Shared);
        assert!(spec.base.label().contains("bw=shared"));
        // the default keeps every existing label byte-identical
        assert!(!Scenario::default().label().contains("bw="));
        let bad = Doc::parse("[sweep]\nbandwidth_model = \"warp\"").unwrap();
        assert!(SweepSpec::from_doc(&bad).is_err());
        // paired cells: same env seed → same plant and job set; shared
        // fair-sharing only lowers per-copy rates below the constant
        // launch draw, so in aggregate over a few base seeds the shared
        // mean flowtime dominates the constant twin's (per-pair the
        // trajectory shift can reshuffle later launch draws)
        let mut total_constant = 0.0f64;
        let mut total_shared = 0.0f64;
        for base_seed in [0xB0, 0xB1, 0xB2, 0xB3] {
            let mut s = tiny();
            s.scheduler = "flutter".to_string();
            let constant = s.run(base_seed).unwrap();
            s.bandwidth_model = BandwidthModel::Shared;
            let shared = s.run(base_seed).unwrap();
            assert_eq!(constant.total_jobs, shared.total_jobs);
            assert_eq!(shared.finished_jobs, shared.total_jobs);
            total_constant += constant.avg_flowtime();
            total_shared += shared.avg_flowtime();
        }
        assert!(
            total_shared + 1e-6 >= total_constant,
            "shared {total_shared} < constant {total_constant} in aggregate"
        );
    }

    #[test]
    fn stream_metrics_key_threads_into_the_cell_run() {
        let doc = Doc::parse("[sweep]\nstream_metrics = true").unwrap();
        let spec = SweepSpec::from_doc(&doc).unwrap();
        assert!(spec.base.stream_metrics);
        assert!(spec.base.label().contains("stream-metrics"));
        // streamed cell: no raw series, but the FlowStats sketch (and all
        // scalar results) match the exact-mode twin bit for bit
        let mut s = tiny();
        s.scheduler = "flutter".to_string();
        let exact = s.run(0xE3).unwrap();
        s.stream_metrics = true;
        let streamed = s.run(0xE3).unwrap();
        assert!(streamed.flowtimes.is_empty());
        assert!(!exact.flowtimes.is_empty());
        assert_eq!(exact.stats, streamed.stats);
        assert_eq!(exact.finished_jobs, streamed.finished_jobs);
        assert_eq!(
            exact.avg_flowtime().to_bits(),
            streamed.avg_flowtime().to_bits()
        );
    }

    #[test]
    fn trace_key_replays_an_external_trace() {
        let doc = Doc::parse("[sweep]\ntrace = \"examples/trace_small.csv\"").unwrap();
        let spec = SweepSpec::from_doc(&doc).unwrap();
        assert_eq!(spec.base.trace.as_deref(), Some("examples/trace_small.csv"));
        assert!(spec.base.label().contains("trace=examples/trace_small.csv"));
        // run the committed example trace end to end on a tiny plant
        let mut s = tiny();
        s.scheduler = "flutter".to_string();
        s.trace = Some(
            concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/trace_small.csv").to_string(),
        );
        let a = s.run(0xE4).unwrap();
        assert!(a.total_jobs > 0);
        assert_eq!(a.finished_jobs, a.total_jobs);
        // deterministic: same cell, same trace, same bits
        let b = s.run(0xE4).unwrap();
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.copies_launched, b.copies_launched);
        // a missing file is an error the runner can record, not a panic
        s.trace = Some("examples/no_such_trace.csv".to_string());
        assert!(s.run(0xE4).is_err());
    }

    #[test]
    fn time_model_threads_into_the_cell_run() {
        // one tiny cell per core: same env seed, both complete
        let mut s = tiny();
        s.scheduler = "flutter".to_string();
        let dense = s.run(0xE0).unwrap();
        s.time_model = TimeModel::EventSkip;
        let event = s.run(0xE0).unwrap();
        assert_eq!(dense.total_jobs, event.total_jobs);
        assert_eq!(dense.finished_jobs, dense.total_jobs);
        assert_eq!(event.finished_jobs, event.total_jobs);
        assert!(event.events_processed > 0);
        assert!(s.label().contains("time=event-skip"));
    }
}
